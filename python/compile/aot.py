"""AOT lowering: JAX/Pallas decoder layers → HLO text artifacts.

Build-time only (`make artifacts`); the Rust runtime
(``rust/src/runtime``) loads the text with ``HloModuleProto::from_text_file``,
compiles on the PJRT CPU client and executes — Python never runs on the
request path.

HLO **text** (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts [--seq-len 2048]
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange).

    ``print_large_constants`` is ESSENTIAL: the default printer elides big
    literals as ``constant({...})``, which XLA 0.5.1's text parser silently
    reads back as zeros — the baked model weights would vanish and every
    decoder layer would collapse to the residual identity.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # New-jax metadata carries `source_end_line` etc. that the 0.5.1 text
    # parser rejects; metadata is debug-only, drop it.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def lower_layer(name: str, cfg: model.ModelConfig, batch: int, seed: int = 0) -> str:
    """Lower one decoder layer with parameters baked in as constants, so
    the artifact's only runtime input is the activation tensor."""
    params = model.init_params(cfg, seed=seed)
    layer = model.LAYERS[name]

    def fn(x):
        return (layer(params, x),)

    spec = jax.ShapeDtypeStruct((batch, cfg.seq_len, cfg.d_model), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seq-len", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--models",
        default="attention,hyena,mamba",
        help="comma-separated subset of attention,hyena,mamba",
    )
    args = ap.parse_args()

    cfg = model.ModelConfig(seq_len=args.seq_len, d_model=args.d_model)
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {
        "seq_len": cfg.seq_len,
        "d_model": cfg.d_model,
        "batch": args.batch,
        "seed": args.seed,
        "dtype": "f32",
        "models": {},
    }
    for name in args.models.split(","):
        name = name.strip()
        if name not in model.LAYERS:
            raise SystemExit(f"unknown model `{name}`")
        text = lower_layer(name, cfg, args.batch, seed=args.seed)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["models"][name] = {
            "path": f"{name}.hlo.txt",
            "input_shape": [args.batch, cfg.seq_len, cfg.d_model],
            "output_shape": [args.batch, cfg.seq_len, cfg.d_model],
            "sha256_16": digest,
            "chars": len(text),
        }
        print(f"wrote {path}: {len(text)} chars, sha256/16={digest}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
