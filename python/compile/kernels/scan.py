"""L1 Pallas kernel: parallel linear-recurrence scan (paper §IV, Figs. 9/10).

The kernel computes the Mamba recurrence ``h[t] = a[t]·h[t−1] + b[t]``
(h[−1] = 0) along the last axis with a **Hillis–Steele scan over the
associative lift** ``(A, B)∘(A', B') = (A·A', B·A' + B')`` — log₂L steps of
stride-doubling shifts, exactly the dataflow the HS-scan-mode PCU wires into
its cross-lane fabric (Fig. 10 top; simulated cycle-accurately in
``rust/src/pcusim/programs.rs::hs_scan_program``).

Grid layout: one Pallas program per block of channels; the full length-L
sequence of a channel lives in the block (VMEM analogue). A tiled variant
(`linear_scan_tiled`) splits long sequences into R-element tiles and scans
tile aggregates recursively — the GPU-Gems tiled scan the paper adopts for
mapping long sequences across PCUs (§IV-A).

`interpret=True` is mandatory on CPU PJRT (real TPU lowering is Mosaic).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Channels per Pallas grid step.
DEFAULT_BLOCK_C = 8


def _hs_scan_kernel(a_ref, b_ref, ha_ref, hb_ref, *, length):
    """Hillis–Steele scan of the (A, B) lift over the last axis."""
    av = a_ref[...]
    bv = b_ref[...]
    steps = int(length).bit_length() - 1
    for s in range(steps):  # static → unrolls into log₂L shift-MAC stages
        d = 1 << s
        # Shifted-in prefix identity: (A, B) = (1, 0).
        a_prev = jnp.pad(av, ((0, 0), (d, 0)), constant_values=1.0)[:, :length]
        b_prev = jnp.pad(bv, ((0, 0), (d, 0)), constant_values=0.0)[:, :length]
        # combine(prev, cur): A ← A·A_prev, B ← B·... cur∘prev with cur
        # applied after prev: (A_c·A_p, B_p·A_c + B_c).
        av, bv = av * a_prev, b_prev * av + bv
    ha_ref[...] = av
    hb_ref[...] = bv


@functools.partial(jax.jit, static_argnames=("block_c",))
def linear_scan(a, b, *, block_c=DEFAULT_BLOCK_C):
    """Inclusive scan of ``h[t] = a[t]·h[t−1] + b[t]`` along the last axis.

    Shapes: ``a``, ``b`` are float32 ``(C, L)`` with power-of-two L;
    returns ``h`` of the same shape (== the lift's B component, since
    h[−1] = 0).
    """
    c, l = a.shape
    assert b.shape == (c, l)
    assert l & (l - 1) == 0, f"L={l} must be a power of two"
    bc = min(block_c, c)
    pad = (-c) % bc
    if pad:
        a = jnp.concatenate([a, jnp.ones((pad, l), jnp.float32)], axis=0)
        b = jnp.concatenate([b, jnp.zeros((pad, l), jnp.float32)], axis=0)
    grid = ((c + pad) // bc,)
    spec = pl.BlockSpec((bc, l), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((c + pad, l), jnp.float32),
        jax.ShapeDtypeStruct((c + pad, l), jnp.float32),
    ]
    _, hb = pl.pallas_call(
        functools.partial(_hs_scan_kernel, length=l),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=True,
    )(a, b)
    return hb[:c]


def linear_scan_tiled(a, b, *, r=1024, block_c=DEFAULT_BLOCK_C):
    """Tiled scan for long sequences (GPU-Gems §39.2.4, paper §IV-A):

    1. scan each R-element tile independently (one PCU per tile),
    2. scan the per-tile aggregates ``(A_tile, B_tile)``,
    3. apply each tile's incoming carry ``h_in``: ``h ← A_prefix·h_in + h``.
    """
    c, l = a.shape
    if l <= r:
        return linear_scan(a, b, block_c=block_c)
    assert l % r == 0
    t = l // r
    at = a.reshape(c * t, r)
    bt = b.reshape(c * t, r)
    # Step 1: intra-tile scans of both lift components.
    ha, hb = _linear_scan_full(at, bt, block_c=block_c)
    ha = ha.reshape(c, t, r)
    hb = hb.reshape(c, t, r)
    # Step 2: aggregates are the last element of each tile's lift.
    agg_a = ha[:, :, -1]
    agg_b = hb[:, :, -1]
    carry = linear_scan_tiled(agg_a, agg_b, r=r, block_c=block_c)  # (C, T)
    # Exclusive carries: tile j receives the scan up to tile j−1.
    h_in = jnp.pad(carry, ((0, 0), (1, 0)))[:, :t]
    # Step 3: h = A_prefix·h_in + B_prefix within each tile.
    out = ha * h_in[:, :, None] + hb
    return out.reshape(c, l)


@functools.partial(jax.jit, static_argnames=("block_c",))
def _linear_scan_full(a, b, *, block_c=DEFAULT_BLOCK_C):
    """Like `linear_scan` but returns both lift components (A, B)."""
    c, l = a.shape
    bc = min(block_c, c)
    pad = (-c) % bc
    if pad:
        a = jnp.concatenate([a, jnp.ones((pad, l), jnp.float32)], axis=0)
        b = jnp.concatenate([b, jnp.zeros((pad, l), jnp.float32)], axis=0)
    grid = ((c + pad) // bc,)
    spec = pl.BlockSpec((bc, l), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((c + pad, l), jnp.float32),
        jax.ShapeDtypeStruct((c + pad, l), jnp.float32),
    ]
    ha, hb = pl.pallas_call(
        functools.partial(_hs_scan_kernel, length=l),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=True,
    )(a, b)
    return ha[:c], hb[:c]


def cumsum_exclusive(x, *, block_c=DEFAULT_BLOCK_C):
    """Exclusive prefix sum along the last axis via the scan kernel
    (a ≡ 1 reduces the recurrence to a plain prefix sum; shift right for
    exclusivity — the paper's [2,4,6,8] → [0,2,6,12] example)."""
    inc = linear_scan(jnp.ones_like(x), x, block_c=block_c)
    return jnp.pad(inc, ((0, 0), (1, 0)))[:, : x.shape[-1]]


def _np_pow2_check(n):
    return n & (n - 1) == 0 and n > 0
