"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every Pallas kernel in this package is tested against these references in
``python/tests``; the same algorithms exist in Rust
(``rust/src/fft``, ``rust/src/scan``) and the cycle-level PCU simulator
(``rust/src/pcusim/programs.rs``), closing the cross-layer correctness loop
described in DESIGN.md §7.

All interfaces use float32 re/im pairs rather than complex dtypes so the
same signatures survive AOT lowering to the Rust PJRT runtime unchanged.
"""

import jax.numpy as jnp
import numpy as np
from jax import lax


# ---------------------------------------------------------------------------
# FFT references
# ---------------------------------------------------------------------------

def fft_ref(xr, xi):
    """Reference FFT along the last axis; returns (re, im) float32."""
    y = jnp.fft.fft(xr.astype(jnp.complex64) + 1j * xi.astype(jnp.complex64))
    return y.real.astype(jnp.float32), y.imag.astype(jnp.float32)


def ifft_ref(xr, xi):
    """Reference inverse FFT along the last axis."""
    y = jnp.fft.ifft(xr.astype(jnp.complex64) + 1j * xi.astype(jnp.complex64))
    return y.real.astype(jnp.float32), y.imag.astype(jnp.float32)


def bailey_fft_ref(xr, xi, r):
    """Bailey 4-step FFT reference (paper §III-A, Fig. 6), one level.

    Mirrors ``rust/src/fft/bailey.rs``: reshape the length-L axis as an
    R×C matrix with the DIT split ``n = n1·C + n2``, column FFTs, twiddle
    scaling ``e^{-2πi·n2·k1/L}``, row FFTs, output index ``k1 + R·k2``.
    """
    l = xr.shape[-1]
    assert l % r == 0
    c = l // r
    x = xr.astype(jnp.complex64) + 1j * xi.astype(jnp.complex64)
    # A[n1, n2] = x[n1*C + n2]  (leading batch dims preserved).
    a = x.reshape(x.shape[:-1] + (r, c))
    # Step 2: column FFTs = transforms along n1 (axis -2).
    t = jnp.fft.fft(a, axis=-2)
    # Step 3: twiddles e^{-2πi n2 k1 / L}.
    k1 = np.arange(r)[:, None]
    n2 = np.arange(c)[None, :]
    tw = np.exp(-2j * np.pi * (k1 * n2) / l).astype(np.complex64)
    t = t * tw
    # Step 4: row FFTs along n2 (axis -1); output X[k1 + R*k2].
    y = jnp.fft.fft(t, axis=-1)
    out = jnp.swapaxes(y, -1, -2).reshape(x.shape)
    return out.real.astype(jnp.float32), out.imag.astype(jnp.float32)


def fftconv_ref(u, k):
    """Circular FFT convolution of real signals along the last axis."""
    y = jnp.fft.ifft(jnp.fft.fft(u) * jnp.fft.fft(k)).real
    return y.astype(jnp.float32)


def causal_fftconv_ref(u, k):
    """Causal (linear, truncated to L) convolution via zero-padded FFT —
    the Hyena long-convolution operator."""
    l = u.shape[-1]
    n = 2 * l
    pad = [(0, 0)] * (u.ndim - 1) + [(0, n - l)]
    up = jnp.pad(u, pad)
    kp = jnp.pad(k, pad)
    y = jnp.fft.ifft(jnp.fft.fft(up) * jnp.fft.fft(kp)).real[..., :l]
    return y.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Scan references
# ---------------------------------------------------------------------------

def cumsum_exclusive_ref(x):
    """Exclusive prefix sum along the last axis (the paper's §IV-A example:
    [2,4,6,8] → [0,2,6,12])."""
    inc = jnp.cumsum(x, axis=-1)
    return (inc - x).astype(x.dtype)


def linear_scan_ref(a, b):
    """Serial reference of the Mamba recurrence h[t] = a[t]·h[t−1] + b[t]
    (h[−1] = 0), scanning the last axis. Shapes: (..., L)."""

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a_t = jnp.moveaxis(a, -1, 0)
    b_t = jnp.moveaxis(b, -1, 0)
    h0 = jnp.zeros(a_t.shape[1:], a.dtype)
    _, hs = lax.scan(step, h0, (a_t, b_t))
    return jnp.moveaxis(hs, 0, -1)


def linear_scan_assoc_ref(a, b):
    """Parallel formulation of ``linear_scan_ref`` via the associative lift
    (A, B)∘(A', B') = (A·A', B·A' + B') using ``lax.associative_scan`` —
    validates that the lift is exact."""

    def combine(p, q):
        ap, bp = p
        aq, bq = q
        return ap * aq, bp * aq + bq

    _, bb = lax.associative_scan(combine, (a, b), axis=-1)
    return bb


# ---------------------------------------------------------------------------
# Layer-level references (used by python/tests/test_model.py)
# ---------------------------------------------------------------------------

def softmax_ref(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def attention_ref(q, k, v):
    """Single-head scaled dot-product attention, (B, L, D) inputs."""
    d = q.shape[-1]
    scores = jnp.einsum("bld,bmd->blm", q, k) / jnp.sqrt(d)
    return jnp.einsum("blm,bmd->bld", softmax_ref(scores), v)
