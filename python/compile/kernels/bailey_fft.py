"""L1 Pallas kernel: Bailey 4-step FFT built from R-point tiles
(paper §III-A, Fig. 6; FFT-mode PCU of Fig. 5).

The Pallas kernel (`_fft_tile_kernel`) computes radix-2 Cooley–Tukey FFTs
over the **last axis of an (M, R) tile batch** — the software twin of the
paper's FFT-mode PCU: each of the log₂R butterfly levels is one pipeline
stage, lane *i* exchanges with lane *i ⊕ 2^s*, the twiddles sit in the FU
constant ports. The same program is simulated cycle-by-cycle in
``rust/src/pcusim/programs.rs::fft_program``.

Hardware adaptation (DESIGN.md §3): on a real TPU the (block_m, R) tile is
sized to VMEM and the static `for s in range(levels)` loop unrolls into a
fused elementwise chain on the VPU; `interpret=True` is mandatory here —
real TPU lowering emits a Mosaic custom call the CPU PJRT client cannot
execute.

All interfaces are float32 re/im pairs (AOT-friendly; see ref.py).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Default tile width — matches the 32-lane PCU of Table I.
DEFAULT_R = 32
# Rows per Pallas grid step (VMEM-footprint knob; see DESIGN.md §Perf).
# Large default: fewer grid steps → fewer dynamic-slice loop iterations in
# the lowered HLO (15× end-to-end on the L=2048 Hyena artifact; see
# EXPERIMENTS.md §Perf). On a real TPU this would be re-tiled to VMEM.
DEFAULT_BLOCK_M = 8192


def _bit_reverse_perm(n):
    """Static bit-reversal permutation of 0..n-1 (host-side numpy)."""
    bits = int(n).bit_length() - 1
    idx = np.arange(n)
    rev = np.zeros(n, dtype=np.int32)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


def _butterfly_tables(r, inverse):
    """Per-level twiddle constants, shaped (levels, R/2): level *s* uses the
    first `2^s` entries (`e^{∓2πi·j/2^{s+1}}`, j < 2^s) — the FU constant
    ports of the FFT-mode PCU, passed to the kernel as inputs (Pallas
    forbids captured traced constants)."""
    levels = int(r).bit_length() - 1
    sign = 1.0 if inverse else -1.0
    half_r = max(r // 2, 1)
    wr = np.zeros((levels, half_r), np.float32)
    wi = np.zeros((levels, half_r), np.float32)
    for s in range(levels):
        half = 1 << s
        length = half << 1
        j = np.arange(half_r) % half
        ang = sign * 2.0 * np.pi * j / length
        wr[s] = np.cos(ang)
        wi[s] = np.sin(ang)
    return wr, wi


def _fft_tile_kernel(xr_ref, xi_ref, wr_ref, wi_ref, or_ref, oi_ref, *, r, levels):
    """Radix-2 DIT FFT over the last axis of one (block_m, R) tile.

    Expects bit-reversed input order (the host permutes — on the RDU the
    PMU address generators do this for free while streaming the tile in).

    With bit-reversed input, level *s*'s stride-2^s butterfly partners are
    the two contiguous halves of each length-2^{s+1} block, so every level
    is pure reshape + slice + FMA — no gathers in the lowered HLO (a 5.6×
    win over the `jnp.take` formulation; EXPERIMENTS.md §Perf). On the PCU
    this is the same dataflow: lane i exchanges with lane i ⊕ 2^s.
    """
    xr = xr_ref[...]
    xi = xi_ref[...]
    m = xr.shape[0]
    for s in range(levels):  # static → unrolls into `levels` fused stages
        half = 1 << s
        length = half << 1
        groups = r // length
        ar4 = xr.reshape(m, groups, 2, half)
        ai4 = xi.reshape(m, groups, 2, half)
        a_r, b_r = ar4[:, :, 0, :], ar4[:, :, 1, :]
        a_i, b_i = ai4[:, :, 0, :], ai4[:, :, 1, :]
        wr = wr_ref[s, :half][None, None, :]
        wi = wi_ref[s, :half][None, None, :]
        # t = w · b; out = [a + t, a − t].
        tr = wr * b_r - wi * b_i
        ti = wr * b_i + wi * b_r
        xr = jnp.concatenate([a_r + tr, a_r - tr], axis=-1).reshape(m, r)
        xi = jnp.concatenate([a_i + ti, a_i - ti], axis=-1).reshape(m, r)
    or_ref[...] = xr
    oi_ref[...] = xi


@functools.partial(jax.jit, static_argnames=("r", "inverse", "block_m"))
def fft_tiles(xr, xi, *, r=DEFAULT_R, inverse=False, block_m=DEFAULT_BLOCK_M):
    """R-point FFTs over the last axis of `(M, R)` float32 re/im arrays."""
    m = xr.shape[0]
    assert xr.shape == (m, r) and xi.shape == (m, r), (xr.shape, r)
    levels = int(r).bit_length() - 1
    rev = _bit_reverse_perm(r)
    xr = xr[:, rev]
    xi = xi[:, rev]
    twr, twi = _butterfly_tables(r, inverse)
    bm = min(block_m, m)
    assert m % bm == 0, f"M={m} not a multiple of block_m={bm}"
    grid = (m // bm,)
    spec = pl.BlockSpec((bm, r), lambda i: (i, 0))
    # Twiddle tables are broadcast to every grid step.
    tspec = pl.BlockSpec(twr.shape, lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((m, r), jnp.float32),
        jax.ShapeDtypeStruct((m, r), jnp.float32),
    ]
    yr, yi = pl.pallas_call(
        functools.partial(_fft_tile_kernel, r=r, levels=levels),
        grid=grid,
        in_specs=[spec, spec, tspec, tspec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=True,  # CPU-PJRT executable; real-TPU lowering is Mosaic
    )(xr, xi, jnp.asarray(twr), jnp.asarray(twi))
    if inverse:
        yr = yr / r
        yi = yi / r
    return yr, yi


def bailey_fft(xr, xi, *, r=DEFAULT_R, inverse=False):
    """Bailey 4-step FFT along the last axis of `(..., L)` float32 pairs,
    decomposed entirely into R-point Pallas tile transforms.

    Follows ``rust/src/fft/bailey.rs``: with the DIT split `n = n1·C + n2`,
      1. reshape to an R×C matrix `A[n1, n2] = x[n1·C + n2]`,
      2. column FFTs (length R — the Pallas tile kernel),
      3. twiddle scaling `e^{∓2πi·n2·k1/L}`,
      4. row FFTs (length C, recursing until C ≤ R),
    output index `X[k1 + R·k2]`.
    """
    l = xr.shape[-1]
    assert l & (l - 1) == 0, f"L={l} must be a power of two"
    lead = xr.shape[:-1]
    xr2 = xr.reshape((-1, l))
    xi2 = xi.reshape((-1, l))
    yr, yi = _bailey_rec(xr2, xi2, l, r, inverse)
    if inverse:
        # Each inverse tile transform divides by its own width, so the
        # recursion has applied 1/_ifft_norm_applied(l, r) in total; rescale
        # to the correct 1/L.
        fix = _ifft_norm_applied(l, r) / l
        if fix != 1.0:
            yr = yr * fix
            yi = yi * fix
    return yr.reshape(lead + (l,)), yi.reshape(lead + (l,))


def _ifft_norm_applied(l, r):
    """Normalization already applied by inverse tile transforms in the
    recursion: each level of column tiles divides by r; the base row
    transform divides by its own length."""
    if l <= r:
        return l
    return r * _ifft_norm_applied(l // r, r)


def _bailey_rec(xr, xi, l, r, inverse):
    """Recursive 4-step on `(B, L)` arrays; returns `(B, L)`."""
    b = xr.shape[0]
    if l <= r:
        # Base tile: pad batch rows up to a block multiple if needed.
        return _tile_batch(xr, xi, l, inverse)
    c = l // r
    # Step 1: A[n1, n2] = x[n1*C + n2] → shape (B, R, C).
    ar = xr.reshape(b, r, c)
    ai = xi.reshape(b, r, c)
    # Step 2: column FFTs along n1: move axis to last, tile-transform.
    colr = jnp.swapaxes(ar, 1, 2).reshape(b * c, r)   # (B*C, R)
    coli = jnp.swapaxes(ai, 1, 2).reshape(b * c, r)
    tr, ti = _tile_batch(colr, coli, r, inverse)
    tr = tr.reshape(b, c, r)
    ti = ti.reshape(b, c, r)
    # Step 3: twiddles e^{∓2πi n2 k1 / L}; t[n2, k1] layout here.
    n2 = np.arange(c)[:, None]
    k1 = np.arange(r)[None, :]
    sign = 1.0 if inverse else -1.0
    ang = sign * 2.0 * np.pi * (n2 * k1 % l) / l
    twr = np.cos(ang).astype(np.float32)
    twi = np.sin(ang).astype(np.float32)
    ur = tr * twr - ti * twi
    ui = tr * twi + ti * twr
    # Step 4: row FFTs along n2 for each k1: rows are u[:, :, k1] (length C).
    rowr = jnp.swapaxes(ur, 1, 2).reshape(b * r, c)   # (B*R, C)
    rowi = jnp.swapaxes(ui, 1, 2).reshape(b * r, c)
    vr, vi = _bailey_rec(rowr, rowi, c, r, inverse)
    vr = vr.reshape(b, r, c)
    vi = vi.reshape(b, r, c)
    # Output X[k1 + R*k2]: axis order (k2, k1) flattened.
    outr = jnp.swapaxes(vr, 1, 2).reshape(b, l)
    outi = jnp.swapaxes(vi, 1, 2).reshape(b, l)
    return outr, outi


def _tile_batch(xr, xi, width, inverse):
    """Apply the Pallas tile kernel to `(M, width)` arrays, padding M to a
    block multiple."""
    m = xr.shape[0]
    bm = DEFAULT_BLOCK_M if m >= DEFAULT_BLOCK_M else m
    pad = (-m) % bm
    if pad:
        xr = jnp.concatenate([xr, jnp.zeros((pad, width), jnp.float32)], axis=0)
        xi = jnp.concatenate([xi, jnp.zeros((pad, width), jnp.float32)], axis=0)
    yr, yi = fft_tiles(xr, xi, r=width, inverse=inverse, block_m=bm)
    return yr[:m], yi[:m]


def causal_fftconv(u, k, *, r=DEFAULT_R):
    """Hyena long convolution: causal conv of real `(..., L)` signals via
    zero-padded Bailey FFTs — the paper's two-forward-FFTs + pointwise
    product + one-inverse-FFT kernel replacement (§II-B)."""
    l = u.shape[-1]
    n = 2 * l
    pad = [(0, 0)] * (u.ndim - 1) + [(0, n - l)]
    up = jnp.pad(u, pad).astype(jnp.float32)
    kp = jnp.pad(k, pad).astype(jnp.float32)
    zero = jnp.zeros_like(up)
    ur, ui = bailey_fft(up, zero, r=r)                 # forward FFT #1
    kr, ki = bailey_fft(kp, jnp.zeros_like(kp), r=r)   # forward FFT #2
    # Frequency-domain complex product.
    pr = ur * kr - ui * ki
    pi_ = ur * ki + ui * kr
    yr, _ = bailey_fft(pr, pi_, r=r, inverse=True)     # inverse FFT
    return yr[..., :l]
