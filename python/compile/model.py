"""L2: JAX decoder layers (paper Fig. 3) calling the L1 Pallas kernels.

Three decoder layers sharing the transformer template — LN → mixer →
out-proj → residual → LN → MLP → residual:

* ``attention_layer``  — Fig. 3A: quadratic softmax(QKᵀ)·V mixer,
* ``hyena_layer``      — Fig. 3B: FFT-convolution mixer (two forward FFTs +
  pointwise product + inverse FFT) via the Bailey Pallas kernel,
* ``mamba_layer``      — Fig. 3C: selective linear-recurrence scan mixer via
  the HS-scan Pallas kernel.

Everything is build-time Python: ``aot.py`` lowers these (with parameters
baked in) to HLO text that the Rust runtime loads and executes — Python is
never on the request path.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import bailey_fft, scan
from .kernels.ref import attention_ref, softmax_ref  # noqa: F401 (re-export for tests)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Shapes of one decoder layer (paper: D = 32)."""

    seq_len: int = 2048
    d_model: int = 32
    mlp_mult: int = 4
    fft_tile: int = 32

    @property
    def d_hidden(self):
        return self.mlp_mult * self.d_model


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic parameter pytree shared by all three layers."""
    rng = np.random.default_rng(seed)
    d, h, l = cfg.d_model, cfg.d_hidden, cfg.seq_len

    def mat(*shape):
        scale = 1.0 / np.sqrt(shape[0])
        return jnp.asarray(rng.uniform(-scale, scale, shape), jnp.float32)

    return {
        "ln1_g": jnp.ones((d,), jnp.float32),
        "ln1_b": jnp.zeros((d,), jnp.float32),
        "ln2_g": jnp.ones((d,), jnp.float32),
        "ln2_b": jnp.zeros((d,), jnp.float32),
        "wq": mat(d, d),
        "wk": mat(d, d),
        "wv": mat(d, d),
        "wo": mat(d, d),
        "mlp_w1": mat(d, h),
        "mlp_b1": jnp.zeros((h,), jnp.float32),
        "mlp_w2": mat(h, d),
        "mlp_b2": jnp.zeros((d,), jnp.float32),
        # Hyena long filters (one per conv), per-channel, length L, decayed
        # so the convolution is well-conditioned.
        "filt1": jnp.asarray(
            rng.standard_normal((d, l)) * np.exp(-np.arange(l) / (l / 8.0)) / 8.0, jnp.float32
        ),
        "filt2": jnp.asarray(
            rng.standard_normal((d, l)) * np.exp(-np.arange(l) / (l / 8.0)) / 8.0, jnp.float32
        ),
        # Mamba selective-decay parameters.
        "w_dt": mat(d, d),
        "b_dt": jnp.full((d,), -1.0, jnp.float32),
        "w_in": mat(d, d),
        "conv_k": jnp.asarray(rng.uniform(-0.5, 0.5, (d, 4)), jnp.float32),
    }


def _layer_norm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _mlp_block(x, res, p):
    """Residual → LN → GELU MLP → residual (common decoder tail)."""
    y = x + res
    z = _layer_norm(y, p["ln2_g"], p["ln2_b"])
    z = jax.nn.gelu(z @ p["mlp_w1"] + p["mlp_b1"])
    z = z @ p["mlp_w2"] + p["mlp_b2"]
    return y + z


def attention_layer(p, x):
    """Fig. 3A — (B, L, D) → (B, L, D)."""
    u = _layer_norm(x, p["ln1_g"], p["ln1_b"])
    q, k, v = u @ p["wq"], u @ p["wk"], u @ p["wv"]
    d = q.shape[-1]
    scores = jnp.einsum("bld,bmd->blm", q, k) / jnp.sqrt(d)
    # Causal mask (decoder layer).
    l = scores.shape[-1]
    mask = jnp.tril(jnp.ones((l, l), bool))
    scores = jnp.where(mask, scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    mix = jnp.einsum("blm,bmd->bld", att, v) @ p["wo"]
    return _mlp_block(mix, x, p)


def hyena_layer(p, x, *, use_pallas=True):
    """Fig. 3B — the two big GEMMs replaced by causal FFT convolutions
    (two forward FFTs + pointwise product + inverse FFT each)."""
    cfg_r = 32
    u = _layer_norm(x, p["ln1_g"], p["ln1_b"])
    q, k, v = u @ p["wq"], u @ p["wk"], u @ p["wv"]
    # Channels-last → channels-major (C, L) layout for the conv kernel.
    def conv(sig, filt):
        b, l, d = sig.shape
        s = jnp.moveaxis(sig, -1, 1).reshape(b * d, l)
        f = jnp.broadcast_to(filt, (b, d, l)).reshape(b * d, l)
        if use_pallas:
            y = bailey_fft.causal_fftconv(s, f, r=cfg_r)
        else:
            from .kernels.ref import causal_fftconv_ref

            y = causal_fftconv_ref(s, f)
        return jnp.moveaxis(y.reshape(b, d, l), 1, -1)

    y1 = conv(q, p["filt1"]) * k          # conv1 (replaces Q·Kᵀ) + gate
    y2 = conv(y1, p["filt2"]) * v         # conv2 (replaces A·V) + gate
    mix = y2 @ p["wo"]
    return _mlp_block(mix, x, p)


def mamba_layer(p, x, *, use_pallas=True):
    """Fig. 3C — selective scan mixer: h[t] = a[t]·h[t−1] + b[t] per
    channel, with input-dependent decay a (the "selective" part)."""
    u = _layer_norm(x, p["ln1_g"], p["ln1_b"])
    # Short depthwise causal conv (width 4) on the input branch.
    b, l, d = u.shape
    xc = jnp.moveaxis(u, -1, 1)  # (B, D, L)
    k = p["conv_k"]  # (D, 4)
    xp = jnp.pad(xc, ((0, 0), (0, 0), (3, 0)))
    conv = sum(xp[:, :, 3 - i : 3 - i + l] * k[None, :, i : i + 1] for i in range(4))
    xs = jax.nn.silu(jnp.moveaxis(conv, 1, -1))
    # Selective decay a ∈ (0, 1) and drive b.
    a = jax.nn.sigmoid(xs @ p["w_dt"] + p["b_dt"])
    bdrive = xs @ p["w_in"]
    # Scan per channel: (B, L, D) → (B·D, L).
    a2 = jnp.moveaxis(a, -1, 1).reshape(b * d, l)
    b2 = jnp.moveaxis(bdrive, -1, 1).reshape(b * d, l)
    if use_pallas:
        h = scan.linear_scan(a2, b2)
    else:
        from .kernels.ref import linear_scan_ref

        h = linear_scan_ref(a2, b2)
    h = jnp.moveaxis(h.reshape(b, d, l), 1, -1)
    # Gate with the (SiLU'd) input branch and project out.
    mix = (h * jax.nn.silu(u)) @ p["wo"]
    return _mlp_block(mix, x, p)


LAYERS = {
    "attention": attention_layer,
    "hyena": hyena_layer,
    "mamba": mamba_layer,
}
