"""L2 correctness: decoder layers — shapes, numerics, and Pallas-vs-jnp
agreement (the kernel path must be interchangeable with the reference path
inside the full layer)."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model

CFG = model.ModelConfig(seq_len=128, d_model=32)
PARAMS = model.init_params(CFG, seed=0)


def _x(batch=2, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal((batch, CFG.seq_len, CFG.d_model)), jnp.float32
    )


@pytest.mark.parametrize("name", ["attention", "hyena", "mamba"])
def test_layer_shapes(name):
    x = _x()
    y = model.LAYERS[name](PARAMS, x)
    assert y.shape == x.shape
    assert y.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(y)))


def test_hyena_pallas_matches_reference_path():
    x = _x(seed=2)
    y_pallas = model.hyena_layer(PARAMS, x, use_pallas=True)
    y_ref = model.hyena_layer(PARAMS, x, use_pallas=False)
    assert_allclose(np.asarray(y_pallas), np.asarray(y_ref), atol=1e-3, rtol=1e-3)


def test_mamba_pallas_matches_reference_path():
    x = _x(seed=3)
    y_pallas = model.mamba_layer(PARAMS, x, use_pallas=True)
    y_ref = model.mamba_layer(PARAMS, x, use_pallas=False)
    assert_allclose(np.asarray(y_pallas), np.asarray(y_ref), atol=1e-3, rtol=1e-3)


def test_attention_is_causal():
    """Perturbing position t must not change outputs before t."""
    x = np.asarray(_x(batch=1, seed=4))
    y0 = np.asarray(model.attention_layer(PARAMS, jnp.asarray(x)))
    x2 = x.copy()
    x2[0, 100:, :] += 3.0
    y1 = np.asarray(model.attention_layer(PARAMS, jnp.asarray(x2)))
    assert_allclose(y0[0, :100], y1[0, :100], atol=1e-4)


def test_hyena_is_causal():
    x = np.asarray(_x(batch=1, seed=5))
    y0 = np.asarray(model.hyena_layer(PARAMS, jnp.asarray(x)))
    x2 = x.copy()
    x2[0, 100:, :] += 3.0
    y1 = np.asarray(model.hyena_layer(PARAMS, jnp.asarray(x2)))
    assert_allclose(y0[0, :100], y1[0, :100], atol=2e-3)


def test_mamba_is_causal():
    x = np.asarray(_x(batch=1, seed=6))
    y0 = np.asarray(model.mamba_layer(PARAMS, jnp.asarray(x)))
    x2 = x.copy()
    x2[0, 100:, :] += 3.0
    y1 = np.asarray(model.mamba_layer(PARAMS, jnp.asarray(x2)))
    assert_allclose(y0[0, :100], y1[0, :100], atol=1e-4)


def test_layers_differ_from_each_other():
    """The three mixers are genuinely different computations."""
    x = _x(seed=7)
    ya = np.asarray(model.attention_layer(PARAMS, x))
    yh = np.asarray(model.hyena_layer(PARAMS, x))
    ym = np.asarray(model.mamba_layer(PARAMS, x))
    assert not np.allclose(ya, yh, atol=1e-2)
    assert not np.allclose(ya, ym, atol=1e-2)
    assert not np.allclose(yh, ym, atol=1e-2)


def test_residual_path_preserves_signal():
    """Layers are residual: zero input stays bounded, output correlates
    with input."""
    x = _x(seed=8)
    for name, layer in model.LAYERS.items():
        y = np.asarray(layer(PARAMS, x))
        corr = np.corrcoef(np.asarray(x).ravel(), y.ravel())[0, 1]
        assert corr > 0.3, f"{name}: corr={corr}"


def test_params_deterministic():
    p1 = model.init_params(CFG, seed=0)
    p2 = model.init_params(CFG, seed=0)
    for k in p1:
        assert_allclose(np.asarray(p1[k]), np.asarray(p2[k]), atol=0)
    p3 = model.init_params(CFG, seed=1)
    assert not np.allclose(np.asarray(p1["wq"]), np.asarray(p3["wq"]))
