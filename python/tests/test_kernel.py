"""L1 correctness: Pallas kernels vs pure-jnp references.

Hypothesis sweeps shapes and value regimes; every property asserts
``assert_allclose`` against the oracles in ``compile.kernels.ref`` — the
core correctness signal for the kernels the AOT artifacts embed.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import bailey_fft as bf
from compile.kernels import ref, scan

SETTINGS = dict(max_examples=20, deadline=None)


def _rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# FFT tile kernel
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    r=st.sampled_from([8, 16, 32, 64]),
    m=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_fft_tiles_match_reference(r, m, seed):
    rng = np.random.default_rng(seed)
    xr, xi = _rand(rng, m, r), _rand(rng, m, r)
    # block_m must divide M (fft_tiles contract; bailey_fft pads for us).
    yr, yi = bf.fft_tiles(jnp.array(xr), jnp.array(xi), r=r, block_m=m)
    rr, ri = ref.fft_ref(xr, xi)
    assert_allclose(yr, rr, atol=1e-4, rtol=1e-4)
    assert_allclose(yi, ri, atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(
    logl=st.integers(5, 12),
    seed=st.integers(0, 2**31 - 1),
    r=st.sampled_from([16, 32]),
)
def test_bailey_fft_matches_reference(logl, seed, r):
    l = 1 << logl
    rng = np.random.default_rng(seed)
    xr, xi = _rand(rng, 2, l), _rand(rng, 2, l)
    yr, yi = bf.bailey_fft(jnp.array(xr), jnp.array(xi), r=r)
    rr, ri = ref.fft_ref(xr, xi)
    tol = 1e-3 * np.sqrt(l)  # fp32 butterfly accumulation
    assert_allclose(yr, rr, atol=tol, rtol=1e-3)
    assert_allclose(yi, ri, atol=tol, rtol=1e-3)


@settings(**SETTINGS)
@given(logl=st.integers(5, 11), seed=st.integers(0, 2**31 - 1))
def test_bailey_ifft_roundtrip(logl, seed):
    l = 1 << logl
    rng = np.random.default_rng(seed)
    xr, xi = _rand(rng, 1, l), _rand(rng, 1, l)
    yr, yi = bf.bailey_fft(jnp.array(xr), jnp.array(xi))
    br, bi = bf.bailey_fft(yr, yi, inverse=True)
    assert_allclose(br, xr, atol=1e-4, rtol=1e-4)
    assert_allclose(bi, xi, atol=1e-4, rtol=1e-4)


def test_bailey_matches_bailey_ref_structure():
    """The tiled decomposition agrees with the explicit 4-step reference
    (not just with jnp.fft) — validates the step structure itself."""
    rng = np.random.default_rng(7)
    xr, xi = _rand(rng, 1, 1024), _rand(rng, 1, 1024)
    rr, ri = ref.bailey_fft_ref(jnp.array(xr), jnp.array(xi), r=32)
    fr, fi = ref.fft_ref(xr, xi)
    assert_allclose(rr, fr, atol=1e-2, rtol=1e-3)
    assert_allclose(ri, fi, atol=1e-2, rtol=1e-3)


@settings(**SETTINGS)
@given(
    logl=st.integers(5, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_causal_fftconv_matches_reference(logl, seed):
    l = 1 << logl
    rng = np.random.default_rng(seed)
    u, k = _rand(rng, 3, l), _rand(rng, 3, l)
    y = bf.causal_fftconv(jnp.array(u), jnp.array(k))
    yref = ref.causal_fftconv_ref(jnp.array(u), jnp.array(k))
    assert_allclose(y, yref, atol=1e-3 * np.sqrt(l), rtol=1e-3)


def test_causal_fftconv_is_causal():
    """Output at position t must not depend on inputs after t."""
    rng = np.random.default_rng(3)
    u = _rand(rng, 1, 128)
    k = _rand(rng, 1, 128)
    y0 = np.asarray(bf.causal_fftconv(jnp.array(u), jnp.array(k)))
    u2 = u.copy()
    u2[0, 100:] += 5.0  # perturb the future
    y1 = np.asarray(bf.causal_fftconv(jnp.array(u2), jnp.array(k)))
    assert_allclose(y0[0, :100], y1[0, :100], atol=1e-4)
    assert not np.allclose(y0[0, 100:], y1[0, 100:])


def test_fft_linearity():
    rng = np.random.default_rng(11)
    xr, xi = _rand(rng, 1, 256), _rand(rng, 1, 256)
    yr2, yi2 = bf.bailey_fft(jnp.array(2 * xr), jnp.array(2 * xi))
    yr, yi = bf.bailey_fft(jnp.array(xr), jnp.array(xi))
    assert_allclose(yr2, 2 * np.asarray(yr), atol=1e-3, rtol=1e-4)
    assert_allclose(yi2, 2 * np.asarray(yi), atol=1e-3, rtol=1e-4)


def test_fft_impulse_is_flat():
    x = np.zeros((1, 64), np.float32)
    x[0, 0] = 1.0
    yr, yi = bf.bailey_fft(jnp.array(x), jnp.zeros_like(jnp.array(x)))
    assert_allclose(yr, np.ones((1, 64), np.float32), atol=1e-5)
    assert_allclose(yi, np.zeros((1, 64), np.float32), atol=1e-5)


# ---------------------------------------------------------------------------
# Scan kernel
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    c=st.integers(1, 12),
    logl=st.integers(2, 11),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_scan_matches_serial_reference(c, logl, seed):
    l = 1 << logl
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.0, 1.0, (c, l)).astype(np.float32)
    b = _rand(rng, c, l)
    h = scan.linear_scan(jnp.array(a), jnp.array(b))
    hr = ref.linear_scan_ref(jnp.array(a), jnp.array(b))
    assert_allclose(h, hr, atol=1e-4 * l, rtol=1e-3)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_linear_scan_matches_associative_reference(seed):
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.5, 1.0, (4, 512)).astype(np.float32)
    b = _rand(rng, 4, 512)
    h = scan.linear_scan(jnp.array(a), jnp.array(b))
    hr = ref.linear_scan_assoc_ref(jnp.array(a), jnp.array(b))
    assert_allclose(h, hr, atol=1e-3, rtol=1e-3)


@settings(**SETTINGS)
@given(
    logl=st.integers(6, 12),
    logr=st.integers(4, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_tiled_scan_matches_flat_scan(logl, logr, seed):
    l, r = 1 << logl, 1 << logr
    if r >= l:
        return
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.5, 1.0, (3, l)).astype(np.float32)
    b = _rand(rng, 3, l)
    ht = scan.linear_scan_tiled(jnp.array(a), jnp.array(b), r=r)
    hf = ref.linear_scan_ref(jnp.array(a), jnp.array(b))
    assert_allclose(ht, hf, atol=1e-3, rtol=1e-3)


def test_cumsum_paper_example():
    """Paper §IV-A: exclusive scan of [2,4,6,8] is [0,2,6,12]."""
    x = jnp.array([[2.0, 4.0, 6.0, 8.0]], jnp.float32)
    y = scan.cumsum_exclusive(x)
    assert_allclose(np.asarray(y), [[0.0, 2.0, 6.0, 12.0]], atol=1e-6)


def test_scan_zero_decay_passthrough():
    """a ≡ 0 → h[t] = b[t]."""
    rng = np.random.default_rng(5)
    b = _rand(rng, 2, 64)
    h = scan.linear_scan(jnp.zeros((2, 64), jnp.float32), jnp.array(b))
    assert_allclose(np.asarray(h), b, atol=1e-6)


def test_scan_unit_decay_is_cumsum():
    """a ≡ 1 → inclusive prefix sum."""
    rng = np.random.default_rng(6)
    b = _rand(rng, 2, 256)
    h = scan.linear_scan(jnp.ones((2, 256), jnp.float32), jnp.array(b))
    assert_allclose(np.asarray(h), np.cumsum(b, axis=-1), atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("l", [48, 100])
def test_scan_rejects_non_pow2(l):
    a = jnp.ones((1, l), jnp.float32)
    with pytest.raises(AssertionError):
        scan.linear_scan(a, a)
