"""AOT pipeline: lowering produces loadable HLO text whose numerics match
the eager layer (golden check of the artifact path end to end, python side).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model

CFG = model.ModelConfig(seq_len=128, d_model=32)


@pytest.mark.parametrize("name", ["attention", "hyena", "mamba"])
def test_lowering_produces_hlo_text(name):
    text = aot.lower_layer(name, CFG, batch=1)
    assert "HloModule" in text
    assert "f32[1,128,32]" in text, "entry signature should carry the input shape"


def test_jit_matches_eager_golden():
    """The jitted function (what gets lowered) matches the eager layer on a
    golden input — the numeric content the artifact freezes. (The actual
    HLO-text → PJRT execution round trip is exercised on the Rust side in
    rust/tests/integration_runtime.rs.)"""
    params = model.init_params(CFG, seed=0)
    layer = model.LAYERS["mamba"]

    def fn(x):
        return (layer(params, x),)

    rng = np.random.default_rng(0)
    x = rng.standard_normal((1, CFG.seq_len, CFG.d_model)).astype(np.float32)
    eager = np.asarray(fn(jnp.asarray(x))[0])
    jitted = np.asarray(jax.jit(fn)(jnp.asarray(x))[0])
    assert_allclose(jitted, eager, atol=1e-5, rtol=1e-5)


def test_hlo_text_is_id_safe():
    """The emitted text must be parseable by XLA 0.5.1's text parser —
    in particular it must not be a serialized proto and must be pure ASCII
    HLO with an ENTRY computation."""
    text = aot.lower_layer("hyena", CFG, batch=1)
    assert text.startswith("HloModule"), text[:64]
    assert "ENTRY" in text
    assert text.isascii()


def test_artifacts_manifest_consistent(tmp_path):
    """Full aot.main() run into a temp dir: files + manifest agree."""
    import sys

    argv = sys.argv
    sys.argv = [
        "aot",
        "--out-dir",
        str(tmp_path),
        "--seq-len",
        "128",
        "--batch",
        "2",
        "--models",
        "hyena,mamba",
    ]
    try:
        aot.main()
    finally:
        sys.argv = argv
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["seq_len"] == 128
    assert set(man["models"]) == {"hyena", "mamba"}
    for name, meta in man["models"].items():
        p = tmp_path / meta["path"]
        assert p.exists(), name
        text = p.read_text()
        assert len(text) == meta["chars"]
        assert "HloModule" in text


def test_repo_artifacts_if_present():
    """When `make artifacts` has run, the checked artifacts parse."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man_path = os.path.join(art, "manifest.json")
    if not os.path.exists(man_path):
        pytest.skip("artifacts/ not built")
    man = json.loads(open(man_path).read())
    for name, meta in man["models"].items():
        text = open(os.path.join(art, meta["path"])).read()
        assert "HloModule" in text, name
