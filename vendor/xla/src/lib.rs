//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The offline build image does not ship the real `xla` crate (nor its
//! `xla_extension` native library), so this stub provides the exact API
//! surface `ssm_rdu::runtime` compiles against and fails *at runtime* with
//! a clear "PJRT unavailable" error. Everything upstream of artifact
//! execution — the coordinator, the dynamic batcher, the session
//! subsystem, every test that uses `MockExecutor` — works unchanged;
//! artifact-backed tests and examples detect the missing `artifacts/`
//! directory first and skip gracefully.
//!
//! To run against real PJRT, replace the `xla = { path = "vendor/xla" }`
//! dependency with the real crate; the call sites in
//! `rust/src/runtime/mod.rs` match its 0.5.x API.

use std::fmt;

/// Error type matching the real crate's `std::error::Error` behaviour.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!("{what}: PJRT unavailable (offline xla stub; see vendor/xla)"))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Stub of the PJRT CPU client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Stub of a parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub of an XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of a compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub of a device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub of a host literal.
pub struct Literal;

impl Literal {
    pub fn vec1(_v: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_fails_with_clear_message() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT unavailable"), "{e}");
        assert!(HloModuleProto::from_text_file("x").is_err());
        assert!(Literal::vec1(&[1.0]).reshape(&[1]).is_err());
    }
}
