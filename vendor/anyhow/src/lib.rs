//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io registry, so `ssm-rdu` vendors the
//! exact API subset it uses: [`Error`] with a context chain, [`Result`],
//! the [`anyhow!`]/[`bail!`] macros, and the [`Context`] extension trait.
//! Display follows anyhow's convention: `{}` prints the outermost message,
//! `{:#}` prints the whole chain as `outer: inner: …`.
//!
//! If the build environment gains the real `anyhow`, delete this directory
//! and point the path dependency at the registry crate — no call sites
//! need to change.

use std::fmt;

/// A message-chain error (outermost context first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = &self.source;
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = &e.source;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:#}")
    }
}

// Like the real anyhow: any std error converts into `Error`, preserving
// its source chain as context links. (Coherent because `Error` itself
// deliberately does not implement `std::error::Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut it = msgs.into_iter().rev();
        let mut err = Error::msg(it.next().expect("at least one message"));
        for m in it {
            err = err.context(m);
        }
        err
    }
}

/// `anyhow::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (inline captures work).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("inner").context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: inner");
        assert_eq!(format!("{e:?}"), "outer: middle: inner");
    }

    #[test]
    fn from_std_error_keeps_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(format!("{e:#}").contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"));
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e}"), "reading file");
        assert!(format!("{e:#}").contains("boom"));
        let n: Option<u32> = None;
        assert_eq!(format!("{}", n.context("missing").unwrap_err()), "missing");
    }

    #[test]
    fn macros_format() {
        let x = 3;
        let e = anyhow!("value {x} bad");
        assert_eq!(format!("{e}"), "value 3 bad");
        let e = anyhow!("{} and {}", 1, 2);
        assert_eq!(format!("{e}"), "1 and 2");
        fn fails() -> Result<()> {
            bail!("nope {}", 7)
        }
        assert_eq!(format!("{}", fails().unwrap_err()), "nope 7");
    }
}
