//! Validate a `--trace` output file as Chrome trace-event JSON.
//!
//! ```bash
//! cargo run --release -- simulate --workload hyena --chips 2 --trace trace.json
//! cargo run --release --example validate_trace -- trace.json
//! ```
//!
//! CI runs exactly this pair to guarantee every shipped trace loads in
//! Perfetto: the document must parse with `util::json`, carry a
//! `traceEvents` array, and every event must be a well-formed `X`
//! (complete span), `i` (instant) or `M` (metadata) record. Exits non-zero
//! with a pointed message on the first violation.

use ssm_rdu::util::json::Json;

fn fail(msg: &str) -> ! {
    eprintln!("validate_trace: {msg}");
    std::process::exit(1);
}

fn main() {
    let path = std::env::args().nth(1).unwrap_or_else(|| "trace.json".to_string());
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) => fail(&format!("cannot read {path}: {e}")),
    };
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => fail(&format!("{path} is not valid JSON: {e}")),
    };
    let Some(events) = doc.get("traceEvents").and_then(Json::as_arr) else {
        fail(&format!("{path}: missing `traceEvents` array"));
    };

    let mut spans = 0usize;
    let mut instants = 0usize;
    let mut meta = 0usize;
    for (i, e) in events.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(Json::as_str)
            .unwrap_or_else(|| fail(&format!("{path}: event {i} has no `ph`")));
        if e.get("name").and_then(Json::as_str).is_none() {
            fail(&format!("{path}: event {i} has no `name`"));
        }
        if e.get("pid").and_then(Json::as_f64).is_none()
            || e.get("tid").and_then(Json::as_f64).is_none()
        {
            fail(&format!("{path}: event {i} lacks pid/tid"));
        }
        match ph {
            "M" => meta += 1,
            "X" => {
                let ts = e.get("ts").and_then(Json::as_f64);
                let dur = e.get("dur").and_then(Json::as_f64);
                match (ts, dur) {
                    (Some(_), Some(d)) if d >= 0.0 => spans += 1,
                    _ => fail(&format!(
                        "{path}: span event {i} needs numeric ts and non-negative dur"
                    )),
                }
            }
            "i" => {
                if e.get("ts").and_then(Json::as_f64).is_none() {
                    fail(&format!("{path}: instant event {i} has no ts"));
                }
                instants += 1;
            }
            other => fail(&format!("{path}: event {i} has unexpected ph `{other}`")),
        }
    }
    if spans == 0 {
        fail(&format!("{path}: no complete (`X`) spans — nothing would render in Perfetto"));
    }
    println!(
        "{path}: {} trace events OK ({spans} spans, {instants} instants, {meta} metadata)",
        events.len()
    );
}
