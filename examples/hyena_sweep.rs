//! Hyena design-space sweep (the paper's §III story, interactively).
//!
//! Sweeps sequence length and FFT tile size R, printing for every point the
//! latency of the four Fig. 7 designs plus the GEMM-FFT/Vector-FFT FLOP
//! ratio — showing where the FFT-mode interconnect pays off and how the
//! Bailey tile size trades FLOPs against hardware friendliness.
//!
//! Run: `cargo run --release --example hyena_sweep -- [--seq-lens 65536,262144]`

use ssm_rdu::arch::RduConfig;
use ssm_rdu::dfmodel;
use ssm_rdu::fft::{gemm_fft_flops, vector_fft_flops, BaileyVariant};
use ssm_rdu::figures::seq_label;
use ssm_rdu::util::cli::Args;
use ssm_rdu::util::fmt_time;
use ssm_rdu::util::table::Table;
use ssm_rdu::workloads::{attention_decoder, hyena_decoder, DecoderConfig};

fn main() {
    let args = Args::from_env();
    let seq_lens = args.usize_list_or("seq-lens", &[1 << 16, 1 << 18, 1 << 20]);

    let base = RduConfig::baseline();
    let fftm = RduConfig::fft_mode();

    let mut t = Table::new(
        "Hyena design-space sweep",
        &["L", "attention", "vec-fft/base", "gemm-fft/base", "vec-fft/fft-mode", "best design"],
    );
    for &l in &seq_lens {
        let dc = DecoderConfig::paper(l);
        let lat = [
            dfmodel::estimate(&attention_decoder(&dc), &base).unwrap().total_seconds,
            dfmodel::estimate(&hyena_decoder(&dc, BaileyVariant::Vector), &base).unwrap().total_seconds,
            dfmodel::estimate(&hyena_decoder(&dc, BaileyVariant::Gemm), &base).unwrap().total_seconds,
            dfmodel::estimate(&hyena_decoder(&dc, BaileyVariant::Vector), &fftm).unwrap().total_seconds,
        ];
        let names = ["attention", "vec-fft/base", "gemm-fft/base", "vec-fft/fft-mode"];
        let best = lat
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| names[i])
            .unwrap();
        t.row(&[
            seq_label(l),
            fmt_time(lat[0]),
            fmt_time(lat[1]),
            fmt_time(lat[2]),
            fmt_time(lat[3]),
            best.to_string(),
        ]);
    }
    t.print();

    // Tile-size ablation: the §III-A FLOP trade-off (GEMM-FFT overhead is
    // R/log₂R — 6.4× at R=32, 4× at R=16).
    let mut t2 = Table::new(
        "Bailey tile-size ablation (L = 1M transforms)",
        &["R", "vector-FFT GFLOP", "GEMM-FFT GFLOP", "overhead (paper: R/log2R)"],
    );
    let l = 1 << 21;
    for r in [8usize, 16, 32, 64] {
        let v = vector_fft_flops(l);
        let g = gemm_fft_flops(l, r);
        t2.row(&[
            r.to_string(),
            format!("{:.2}", v / 1e9),
            format!("{:.2}", g / 1e9),
            format!("{:.2}x", g / v),
        ]);
    }
    t2.print();
}
