//! Multi-chip sequence sharding, end to end:
//!
//! 1. verify the sharded dataflows are exact — the carry-exchange Mamba
//!    scan against the serial recurrence, the all-to-all Bailey FFT against
//!    the O(N²) DFT — including a non-power-of-two sequence remainder;
//! 2. price a sharded deployment with the DFModel strong-scaling sweep
//!    (speedup over one chip + communication share per chip count);
//! 3. serve live sessions over per-chip state caches through the
//!    continuous-batching coordinator with 4 chips.
//!
//! Run: `cargo run --example multi_chip_sharding`

use ssm_rdu::arch::{InterchipLink, RduConfig};
use ssm_rdu::coordinator::{
    ContinuousConfig, Coordinator, CoordinatorConfig, Executor, MockExecutor,
};
use ssm_rdu::fft::{dft, to_complex, BaileyVariant};
use ssm_rdu::runtime::ModelKind;
use ssm_rdu::scan::mamba_scan_serial;
use ssm_rdu::session::StateShape;
use ssm_rdu::shard::{sharded_bailey_fft, sharded_mamba_scan, strong_scaling};
use ssm_rdu::util::complex::max_abs_diff_c;
use ssm_rdu::util::{fmt_time, max_abs_diff, XorShift};
use ssm_rdu::workloads::DecoderConfig;

fn main() {
    let mut rng = XorShift::new(2024);

    // 1. Exactness. A 1003-element scan leaves a non-power-of-two
    // remainder on the last chips; the balanced partition absorbs it.
    println!("== sharded dataflow numerics ==");
    let n = 1003;
    let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
    let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let want = mamba_scan_serial(&a, &b);
    for chips in [1usize, 2, 4, 8] {
        let d = max_abs_diff(&sharded_mamba_scan(&a, &b, chips), &want);
        println!("  mamba scan N={n} on {chips} chip(s): |d| vs serial = {d:.2e}");
    }
    let xs: Vec<f64> = (0..1024).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let x = to_complex(&xs);
    let want_f = dft(&x);
    for chips in [1usize, 2, 4, 8] {
        let got = sharded_bailey_fft(&x, 32, chips, BaileyVariant::Vector);
        println!(
            "  bailey fft L=1024 R=32 on {chips} chip(s): |d| vs DFT = {:.2e}",
            max_abs_diff_c(&got, &want_f)
        );
    }

    // 2. The strong-scaling sweep at the paper shape.
    println!("\n== strong scaling @ L=1M, {} ==", InterchipLink::rdu_fabric());
    let dc = DecoderConfig::paper(1 << 20);
    let link = InterchipLink::rdu_fabric();
    for (model, cfg) in [
        (ModelKind::Mamba, RduConfig::hs_scan_mode()),
        (ModelKind::Hyena, RduConfig::fft_mode()),
    ] {
        let pts = strong_scaling(model, &dc, &[1, 2, 4, 8], &cfg, &link).expect("mappable");
        for pt in &pts {
            println!(
                "  {model} × {}: per-chip {} + comm {} = {}  speedup {:.2}x  comm {:.1}%",
                pt.est.chips,
                fmt_time(pt.est.per_chip.total_seconds),
                fmt_time(pt.est.comm_seconds),
                fmt_time(pt.est.total_seconds),
                pt.speedup,
                pt.est.comm_share() * 100.0,
            );
        }
    }

    // 3. Sharded serving: 16 sessions striped over 4 per-chip caches.
    println!("\n== sharded continuous serving (4 chips, MockExecutor) ==");
    let chips = 4;
    let mamba_shape = StateShape::mamba(4, 8, 16);
    let hyena_shape = StateShape::hyena(4, 16, 64);
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: chips,
            continuous: Some(
                ContinuousConfig::new(2 * mamba_shape.bytes(), mamba_shape, hyena_shape)
                    .with_chips(chips),
            ),
            ..Default::default()
        },
        Box::new(move || Ok(Box::new(MockExecutor::new(1, 16)) as Box<dyn Executor>)),
    )
    .expect("coordinator starts");
    let steps = 8;
    let rxs: Vec<_> = (0..16)
        .map(|i| {
            let model = if i % 2 == 0 { ModelKind::Mamba } else { ModelKind::Hyena };
            coord
                .submit_session(model, vec![0.1 * (i as f32 + 1.0); 16], steps)
                .expect("session admitted")
        })
        .collect();
    let mut tokens = 0usize;
    for rx in rxs {
        while rx.recv().is_ok() {
            tokens += 1;
        }
    }
    println!("  {tokens} tokens decoded across {chips} chips");
    if let Some(per_chip) = coord.chip_cache_stats() {
        for (chip, cs) in per_chip.iter().enumerate() {
            println!(
                "  chip {chip}: hits={} misses={} evictions={} peak={:.1} KiB",
                cs.hits,
                cs.misses,
                cs.evictions,
                cs.peak_resident_bytes as f64 / 1024.0
            );
        }
    }
    coord.shutdown();
}
