//! Mamba scan design-space sweep (the paper's §IV story, interactively).
//!
//! Sweeps sequence length across the five Fig. 11 designs and shows the
//! Amdahl decomposition (scan vs MLP vs rest) that bounds the scan-mode
//! speedup at 1.75× in the paper. Includes the full selective-SSM shape
//! (N = 16, E = 2) as an ablation against the paper's scalar-state shape.
//!
//! Run: `cargo run --release --example mamba_sweep -- [--seq-lens ...]`

use ssm_rdu::arch::RduConfig;
use ssm_rdu::dfmodel;
use ssm_rdu::figures::seq_label;
use ssm_rdu::util::cli::Args;
use ssm_rdu::util::fmt_time;
use ssm_rdu::util::table::Table;
use ssm_rdu::workloads::{mamba_decoder, DecoderConfig, ScanVariant};

fn main() {
    let args = Args::from_env();
    let seq_lens = args.usize_list_or("seq-lens", &[1 << 16, 1 << 18, 1 << 20]);

    let base = RduConfig::baseline();
    let hs = RduConfig::hs_scan_mode();
    let b = RduConfig::b_scan_mode();

    let mut t = Table::new(
        "Mamba scan design sweep (paper shape: scalar state per channel)",
        &["L", "c-scan/base", "par/base", "par/hs-mode", "par/b-mode", "scan-mode gain"],
    );
    for &l in &seq_lens {
        let dc = DecoderConfig::paper(l);
        let lat = [
            dfmodel::estimate(&mamba_decoder(&dc, ScanVariant::CScan), &base).unwrap().total_seconds,
            dfmodel::estimate(&mamba_decoder(&dc, ScanVariant::Parallel), &base).unwrap().total_seconds,
            dfmodel::estimate(&mamba_decoder(&dc, ScanVariant::Parallel), &hs).unwrap().total_seconds,
            dfmodel::estimate(&mamba_decoder(&dc, ScanVariant::Parallel), &b).unwrap().total_seconds,
        ];
        t.row(&[
            seq_label(l),
            fmt_time(lat[0]),
            fmt_time(lat[1]),
            fmt_time(lat[2]),
            fmt_time(lat[3]),
            format!("{:.2}x (paper 1.75x)", lat[1] / lat[2]),
        ]);
    }
    t.print();

    // Amdahl decomposition at 1M: why the gain is MLP-bound (paper §IV-C).
    let dc = DecoderConfig::paper(1 << 20);
    let g = mamba_decoder(&dc, ScanVariant::Parallel);
    let mut t2 = Table::new(
        "Amdahl decomposition of parallel-scan Mamba @ 1M",
        &["config", "total", "scan share", "MLP share", "rest"],
    );
    for cfg in [&base, &hs] {
        let est = dfmodel::estimate(&g, cfg).unwrap();
        let scan = est.share_where(|k| k.name.contains("scan"));
        let mlp = est.share_where(|k| k.name.starts_with("mlp."));
        t2.row(&[
            cfg.name(),
            fmt_time(est.total_seconds),
            fmt_time(scan),
            fmt_time(mlp),
            fmt_time(est.total_seconds - scan - mlp),
        ]);
    }
    t2.print();

    // Ablation: the full selective-SSM shape (N=16, E=2) re-weights the
    // scan and shifts the crossover.
    let full = DecoderConfig::mamba_full(1 << 20);
    let gf = mamba_decoder(&full, ScanVariant::Parallel);
    let e_base = dfmodel::estimate(&gf, &base).unwrap().total_seconds;
    let e_hs = dfmodel::estimate(&gf, &hs).unwrap().total_seconds;
    println!(
        "\nfull selective-SSM shape (N=16, E=2) @ 1M: baseline {} → scan-mode {} ({:.2}x)",
        fmt_time(e_base),
        fmt_time(e_hs),
        e_base / e_hs
    );
}
