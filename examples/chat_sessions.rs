//! Continuous-batching chat sessions: sweep live-session counts against
//! state-cache capacity and watch residency, eviction and modeled
//! throughput trade off.
//!
//! Each session decodes K tokens through the session subsystem
//! (SessionScheduler + StateCache) on the deterministic MockExecutor;
//! iteration batches are timed with the DFModel decode-step cost hook, so
//! the "tok/s" column is modeled RDU throughput, not host wall-clock.
//!
//!     cargo run --release --example chat_sessions -- \
//!         [--decode-steps K] [--budget-fracs 0.25,0.5,1.0]
//!
//! The punchline to look for: eviction never changes *what* is decoded
//! (state spills losslessly), only *how fast* — the spill column grows and
//! tok/s falls as the budget shrinks below the footprint.

use ssm_rdu::arch::RduConfig;
use ssm_rdu::coordinator::MockExecutor;
use ssm_rdu::session::{simulate, SimConfig};
use ssm_rdu::util::cli::Args;
use ssm_rdu::util::table::Table;

fn kib(bytes: usize) -> String {
    format!("{:.1} KiB", bytes as f64 / 1024.0)
}

fn main() {
    let args = Args::from_env();
    let decode_steps = args.usize_or("decode-steps", 16);
    let fracs: Vec<f64> = args
        .get("budget-fracs")
        .map(|s| {
            s.split(',')
                .map(|t| t.trim().parse().expect("--budget-fracs: expected floats"))
                .collect()
        })
        .unwrap_or_else(|| vec![0.25, 0.5, 1.0]);
    let rdu = RduConfig::hs_scan_mode();

    let mut t = Table::new(
        "Continuous batching: sessions × state-cache budget (MockExecutor + DFModel decode cost)",
        &[
            "sessions", "footprint", "budget", "evict", "restore", "spilled", "hit%", "batch",
            "tok/s",
        ],
    );
    for &sessions in &[16usize, 32, 64, 128] {
        for &frac in &fracs {
            let mut cfg = SimConfig::demo(sessions, decode_steps);
            let footprint = cfg.footprint_bytes();
            cfg.budget_bytes = (footprint as f64 * frac) as usize;
            let mut exec = MockExecutor::new(1, cfg.mamba_shape.d_model);
            let r = simulate(&mut exec, &cfg, &rdu).expect("simulation completes");
            assert_eq!(r.tokens as usize, sessions * decode_steps, "every session finishes");
            t.row(&[
                format!("{sessions}"),
                kib(footprint),
                kib(cfg.budget_bytes),
                format!("{}", r.cache.evictions),
                format!("{}", r.cache.restores),
                kib(r.cache.spilled_bytes as usize),
                format!("{:.1}", r.cache.hit_rate() * 100.0),
                format!("{:.1}", r.mean_batch),
                format!("{:.2e}", r.tokens_per_sim_second()),
            ]);
        }
    }
    t.print();
    println!(
        "\nEvery cell decoded sessions × {decode_steps} tokens to completion; shrinking the \
         budget below the footprint trades throughput (spill traffic at HBM bandwidth), never \
         correctness."
    );
}
