//! Workload registry tour: resolve every registered SSM decoder by name
//! and drive the whole modeling stack from the trait object — graph build,
//! fused/unfused pricing, sharded deployment and the numeric golden check.
//!
//!     cargo run --release --example workload_registry
//!
//! This is the "add your own SSM" payoff from docs/WORKLOADS.md: nothing
//! below mentions a concrete workload; a newly registered variant shows up
//! in every section automatically.

use ssm_rdu::arch::InterchipLink;
use ssm_rdu::dfmodel;
use ssm_rdu::shard;
use ssm_rdu::util::{fmt_time, table::Table};
use ssm_rdu::workloads::{ssm_workloads, DecoderConfig};

fn main() {
    let dc = DecoderConfig::paper(1 << 16); // 64K tokens
    let link = InterchipLink::rdu_fabric();

    println!("registered SSM workloads at L={}:", dc.seq_len);
    for w in ssm_workloads() {
        println!("  {:6} — {}", w.name(), w.describe());
    }

    // 1) Golden models: each workload's functional path vs its reference.
    println!("\ngolden checks (seed 7):");
    for w in ssm_workloads() {
        let gc = w.golden_check(7).expect("SSM workloads self-check");
        println!(
            "  {:6} vs {:28} |d| = {:.2e}{}",
            w.name(),
            gc.reference,
            gc.max_abs_diff,
            if gc.bit_identical { "  (bit-identical)" } else { "" }
        );
    }

    // 2) The modeling stack, uniformly: idealized dataflow bound, fused and
    //    kernel-by-kernel launch pricing on each workload's design point.
    let mut t = Table::new(
        "DFModel pricing per workload (own extended config)",
        &["Workload", "Config", "Ideal", "Fused", "Unfused", "Fusion gain"],
    );
    for w in ssm_workloads() {
        let g = w.build_graph(&dc);
        let cfg = w.extended_config();
        let ideal = dfmodel::estimate(&g, &cfg).expect("mappable");
        let fused = dfmodel::estimate_fused(&g, &cfg).expect("mappable");
        let unfused = dfmodel::estimate_unfused(&g, &cfg).expect("mappable");
        t.row(&[
            w.name().to_string(),
            cfg.name(),
            fmt_time(ideal.total_seconds),
            fmt_time(fused.total_seconds),
            fmt_time(unfused.total_seconds),
            format!("{:.2}x", unfused.total_seconds / fused.total_seconds),
        ]);
    }
    t.print();

    // 3) Sharded deployment: the workload declares its exchange pattern,
    //    the shard layer prices it.
    let mut t = Table::new(
        "4-chip sequence-sharded deployment",
        &["Workload", "Per-chip", "Exchange", "Total", "Comm share"],
    );
    for w in ssm_workloads() {
        let s = shard::sharded_estimate_workload(w, &dc, 4, &w.extended_config(), &link)
            .expect("mappable");
        t.row(&[
            w.name().to_string(),
            fmt_time(s.per_chip.total_seconds),
            fmt_time(s.comm_seconds),
            fmt_time(s.total_seconds),
            format!("{:.1}%", s.comm_share() * 100.0),
        ]);
    }
    t.print();

    // 4) Decode: the per-token cost hook the session scheduler uses.
    println!("decode-step latency (8 layers, per token):");
    for w in ssm_workloads() {
        let cost = dfmodel::decode_step_workload(w, &dc, 8, &w.extended_config());
        println!(
            "  {:6} {}  ({:.0} cycles, state {:.1} KiB/step)",
            w.name(),
            fmt_time(cost.seconds),
            cost.cycles,
            cost.state_bytes / 1024.0
        );
    }
}
