//! Genomics long-context scenario (the paper's §I motivation: "genomics and
//! bio-informatics … can scale up to a sequence length of one million").
//!
//! Models a HyenaDNA-style genomic foundation model: a stack of Hyena
//! decoder layers over nucleotide sequences from 64K to 1M base pairs.
//! For each context length the example reports, per platform, the
//! per-sequence latency and the sustained throughput in base pairs/second —
//! the numbers a genomics lab would actually size hardware with — plus the
//! attention-vs-SSM crossover that makes long-context genomics infeasible
//! on quadratic attention.
//!
//! Run: `cargo run --release --example genomics_long_context`

use ssm_rdu::arch::{GpuSpec, RduConfig};
use ssm_rdu::dfmodel;
use ssm_rdu::fft::BaileyVariant;
use ssm_rdu::figures::seq_label;
use ssm_rdu::gpu;
use ssm_rdu::util::{eng, fmt_time};
use ssm_rdu::util::table::Table;
use ssm_rdu::workloads::{attention_decoder, hyena_decoder, DecoderConfig};

/// HyenaDNA-style stack: depth × single-layer latency (layers pipeline
/// across sections; the per-layer estimate is the steady-state interval).
const DEPTH: usize = 8;

fn main() {
    let gpu_spec = GpuSpec::a100();
    let fftm = RduConfig::fft_mode();

    let mut t = Table::new(
        &format!("HyenaDNA-style genomic model: {DEPTH}-layer Hyena stack, D=32"),
        &["context (bp)", "platform", "latency/seq", "throughput (bp/s)"],
    );
    let mut crossover = Table::new(
        "attention vs Hyena crossover (single layer, FFT-mode RDU)",
        &["context (bp)", "attention", "hyena", "hyena wins by"],
    );

    for &l in &[1usize << 16, 1 << 18, 1 << 20] {
        let dc = DecoderConfig::paper(l);
        let hyena = hyena_decoder(&dc, BaileyVariant::Vector);

        let rdu = dfmodel::estimate(&hyena, &fftm).expect("mappable").total_seconds * DEPTH as f64;
        let gpu_t = gpu::estimate(&hyena, &gpu_spec).total_seconds * DEPTH as f64;
        for (platform, lat) in [("fft-mode RDU", rdu), ("A100 GPU", gpu_t)] {
            t.row(&[
                seq_label(l),
                platform.to_string(),
                fmt_time(lat),
                eng(l as f64 / lat),
            ]);
        }

        let att = dfmodel::estimate(&attention_decoder(&dc), &fftm).expect("mappable").total_seconds;
        let hy = dfmodel::estimate(&hyena, &fftm).expect("mappable").total_seconds;
        crossover.row(&[
            seq_label(l),
            fmt_time(att),
            fmt_time(hy),
            format!("{:.0}x", att / hy),
        ]);
    }
    t.print();
    crossover.print();

    println!(
        "Takeaway: at 1M bp the quadratic attention layer is ~3 orders of magnitude\n\
         slower than the FFT-based Hyena layer on the same chip — the paper's core\n\
         motivation for SSM-friendly hardware."
    );
}
