//! Author a PCU program with `define_pcu_program!` and single-step it
//! through the pcusim debugger.
//!
//! The walkthrough does what the `debug` CLI subcommand does, but from the
//! library API: author a gained Hillis–Steele scan in the DSL, break when
//! its `gain` stage first computes, dump pipeline registers and in-flight
//! NoC traffic, resume to completion, and verify the interrupted run
//! reproduces the batch engine bit for bit. A second pass breaks inside
//! the canonical fused DIF→filter→DIT convolution at its `filter` stage —
//! the snapshot there is the CI smoke contract (non-empty NoC state while
//! the dif stages behind the filter still carry cross-lane traffic).
//!
//! Run: `cargo run --release --example debug_pipeline -- \
//!     [--lanes 32] [--vectors 8] [--seed 7] [--gain 0.125]`

use ssm_rdu::arch::PcuGeometry;
use ssm_rdu::define_pcu_program;
use ssm_rdu::pcusim::dsl::ops;
use ssm_rdu::pcusim::{fused_conv_program, DebugSession, Pcu, RunOutcome};
use ssm_rdu::util::cli::Args;
use ssm_rdu::util::{C64, XorShift};

define_pcu_program! {
    /// Inclusive Hillis–Steele scan over `lanes` lanes, then a constant
    /// gain — the smallest program that mixes cross-lane and straight
    /// stages.
    fn gained_scan(lanes: usize, gain: f64) {
        name: format!("gained-scan{lanes}"),
        mode: HsScan,
        width: lanes,
        let n = lanes.trailing_zeros() as usize;
        stage shift[b in 0..n] = |i| {
            let stride = 1 << b;
            if i >= stride { ops::add(i - stride) } else { ops::pass() }
        };
        stage gain = |i| {
            let _ = i;
            ops::mul(C64::real(gain))
        };
    }
}

fn rand_batch(rng: &mut XorShift, vectors: usize, lanes: usize) -> Vec<Vec<C64>> {
    (0..vectors)
        .map(|_| {
            (0..lanes)
                .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
                .collect()
        })
        .collect()
}

/// Run `session` to the first hit of breakpoint `label`, print the hit and
/// the snapshot, then resume to completion and check against the engine.
fn debug_and_verify(pcu: Pcu, prog: &ssm_rdu::pcusim::Program, inputs: &[Vec<C64>], label: &str) {
    println!("== {} ({} levels, {} vectors) ==", prog.name, prog.levels.len(), inputs.len());
    let mut dbg = DebugSession::new(pcu, prog, inputs.to_vec());
    let id = dbg.break_on_label(label).expect("program has the named stage");
    match dbg.run() {
        RunOutcome::Break(hit) => {
            println!(
                "breakpoint {id} hit at cycle {}: stage {:?} ({label}), vector {:?}",
                hit.cycle, hit.stage, hit.vector
            );
        }
        other => panic!("expected a break at `{label}`, got {other:?}"),
    }
    let snap = dbg.snapshot();
    println!("{}", snap.render());
    println!("in-flight NoC flits at the break: {}", snap.noc.len());
    // Resume: the remaining breakpoint hits are counted, not printed.
    let mut more = 0usize;
    loop {
        match dbg.run() {
            RunOutcome::Break(_) => more += 1,
            RunOutcome::Done => break,
            other => panic!("unexpected {other:?}"),
        }
    }
    let (want_out, want_stats) = pcu.run(prog, inputs);
    assert_eq!(dbg.outputs(), &want_out[..], "resume must match the batch engine");
    assert_eq!(dbg.stats().unwrap(), want_stats, "stats must match the batch engine");
    println!(
        "resumed past {more} further hits; deterministic resume verified: {} cycles, {} vectors\n",
        want_stats.cycles,
        want_out.len()
    );
}

fn main() {
    let args = Args::from_env();
    let lanes = args.usize_or("lanes", 32);
    let vectors = args.usize_or("vectors", 8);
    let seed = args.usize_or("seed", 7) as u64;
    let gain: f64 = args.get("gain").map(|s| s.parse().expect("--gain: float")).unwrap_or(0.125);
    assert!(lanes.is_power_of_two() && lanes >= 2, "--lanes must be a power of two >= 2");

    let mut rng = XorShift::new(seed);
    let geom = PcuGeometry::new(lanes, 12);
    let inputs = rand_batch(&mut rng, vectors, lanes);

    // 1. DSL-authored scan, break at its straight gain stage.
    let scan = gained_scan(lanes, gain);
    debug_and_verify(Pcu::with_extension(geom, scan.mode), &scan, &inputs, "gain");

    // 2. The fused convolution, break at the filter stage between the DIF
    //    and DIT halves — the snapshot the CI smoke run asserts on.
    let h: Vec<C64> = (0..lanes).map(|_| C64::new(rng.uniform(-1.0, 1.0), 0.0)).collect();
    let fused = fused_conv_program(lanes, &h);
    debug_and_verify(Pcu::with_extension(geom, fused.mode), &fused, &inputs, "filter");
}
