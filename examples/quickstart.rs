//! Quickstart: the whole library in ~60 lines.
//!
//! 1. Describe the chip (Table I) and pick a configuration.
//! 2. Build a workload dataflow graph (a Hyena decoder at 1M tokens).
//! 3. Ask DFModel for the optimal mapping + latency estimate.
//! 4. Compare against the baseline RDU and the A100 GPU.
//! 5. Poke the cycle-level PCU simulator that grounds the estimates.
//!
//! Run: `cargo run --release --example quickstart`

use ssm_rdu::arch::{GpuSpec, RduConfig};
use ssm_rdu::dfmodel;
use ssm_rdu::fft::BaileyVariant;
use ssm_rdu::gpu;
use ssm_rdu::pcusim::{self, Pcu};
use ssm_rdu::util::fmt_time;
use ssm_rdu::workloads::{hyena_decoder, DecoderConfig};

fn main() {
    // 1. The paper's chip (520 PCUs of 32×12 FUs, 1.6 GHz, 8 TB/s HBM3e)
    //    in its baseline and FFT-extended configurations.
    let baseline = RduConfig::baseline();
    let fft_mode = RduConfig::fft_mode();
    println!("chip: {} / {}", baseline.spec.table1_report().render().lines().nth(3).unwrap_or(""), fft_mode);

    // 2. A Hyena decoder layer at 1M tokens, hidden dim 32 (paper §III-C).
    let cfg = DecoderConfig::paper(1 << 20);
    let hyena = hyena_decoder(&cfg, BaileyVariant::Vector);
    println!(
        "workload: {} — {} kernels, {:.2} GFLOP",
        hyena.name,
        hyena.kernels.len(),
        hyena.total_flops() / 1e9
    );

    // 3. DFModel: map and estimate on the FFT-mode RDU.
    let est = dfmodel::estimate(&hyena, &fft_mode).expect("mappable");
    println!(
        "fft-mode RDU:  {} (bottleneck: {}, {} section(s))",
        fmt_time(est.total_seconds),
        est.bottleneck(),
        est.sections
    );

    // 4. The same workload on the baseline RDU and the GPU.
    let base_est = dfmodel::estimate(&hyena, &baseline).expect("mappable");
    let gpu_est = gpu::estimate(&hyena, &GpuSpec::a100());
    println!("baseline RDU:  {} ({:.2}x slower)", fmt_time(base_est.total_seconds),
        base_est.total_seconds / est.total_seconds);
    println!("A100 GPU:      {} ({:.2}x slower — paper: 5.95x)", fmt_time(gpu_est.total_seconds),
        gpu_est.total_seconds / est.total_seconds);

    // 5. Why: the butterfly fabric turns the serialized FFT spatial.
    let prog = pcusim::fft_program(32);
    let inputs: Vec<Vec<_>> = (0..512)
        .map(|i| (0..32).map(|j| ssm_rdu::util::C64::real(((i * 31 + j) % 7) as f64)).collect())
        .collect();
    for (name, pcu) in [
        ("baseline PCU", Pcu::baseline(baseline.spec.pcu)),
        ("fft-mode PCU", Pcu::fft_mode(baseline.spec.pcu)),
    ] {
        let (_, stats) = pcu.run(&prog, &inputs);
        println!(
            "{name}: {} regime, {:.2} cycles/FFT-tile",
            if stats.spatial { "spatial" } else { "serialized" },
            stats.initiation_interval()
        );
    }
}
