//! Hot-path compute engine demo: planned real-input FFT convolution and
//! pooled execution, with live timings and oracle checks.
//!
//!     cargo run --release --example hotpath_engine

use ssm_rdu::fft::{
    fft_conv_circular, fft_conv_circular_naive, fft_conv_linear, fft_conv_linear_channels,
    ConvPlan,
};
use ssm_rdu::runtime::WorkerPool;
use ssm_rdu::shard::{sharded_mamba_scan, sharded_mamba_scan_pooled};
use ssm_rdu::util::{fmt_time, max_abs_diff, XorShift};
use std::time::Instant;

fn time<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(f());
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let mut rng = XorShift::new(7);
    let pool = WorkerPool::from_env();
    println!("worker pool: {} threads (SSM_RDU_THREADS overrides)\n", pool.threads());

    // 1) Planned real-input convolution vs the pre-plan naive complex path.
    let l = 1 << 12;
    let u = rng.vec(l, -1.0, 1.0);
    let k = rng.vec(l, -1.0, 1.0);
    let d = max_abs_diff(&fft_conv_circular(&u, &k), &fft_conv_circular_naive(&u, &k));
    let naive = time(20, || fft_conv_circular_naive(&u, &k));
    let mut plan = ConvPlan::new(l);
    let mut out = vec![0.0; l];
    let planned = time(20, || plan.circular_into(&u, &k, &mut out));
    println!(
        "circular conv L={l}: naive complex {} -> planned real {} ({:.2}x), |d|={d:.1e}",
        fmt_time(naive),
        fmt_time(planned),
        naive / planned
    );

    // 2) Per-channel Hyena convolutions over the pool, bit-identical.
    let dch = 32;
    let us: Vec<Vec<f64>> = (0..dch).map(|_| rng.vec(l, -1.0, 1.0)).collect();
    let ks: Vec<Vec<f64>> = (0..dch).map(|_| rng.vec(l, -1.0, 1.0)).collect();
    let serial = time(5, || {
        us.iter().zip(&ks).map(|(u, k)| fft_conv_linear(u, k)).collect::<Vec<_>>()
    });
    let pooled = time(5, || fft_conv_linear_channels(&us, &ks, &pool));
    let identical = fft_conv_linear_channels(&us, &ks, &pool)
        == us.iter().zip(&ks).map(|(u, k)| fft_conv_linear(u, k)).collect::<Vec<_>>();
    println!(
        "hyena channels D={dch} L={l}: serial {} -> pooled {} ({:.2}x), bit-identical: {identical}",
        fmt_time(serial),
        fmt_time(pooled),
        serial / pooled
    );

    // 3) Sharded Mamba scan with pooled per-chip phases, bit-identical.
    let n = 1 << 20;
    let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
    let b = rng.vec(n, -1.0, 1.0);
    let chips = 4;
    let serial_scan = time(5, || sharded_mamba_scan(&a, &b, chips));
    let pooled_scan = time(5, || sharded_mamba_scan_pooled(&a, &b, chips, &pool));
    let identical =
        sharded_mamba_scan_pooled(&a, &b, chips, &pool) == sharded_mamba_scan(&a, &b, chips);
    println!(
        "sharded scan N=1M chips={chips}: serial {} -> pooled {} ({:.2}x), bit-identical: {identical}",
        fmt_time(serial_scan),
        fmt_time(pooled_scan),
        serial_scan / pooled_scan
    );
}
