//! End-to-end driver (DESIGN.md E7): serve batched decoder requests
//! through the full three-layer stack and report latency/throughput.
//!
//! What this proves, in one run:
//!   * L1/L2 — the Pallas Bailey-FFT and HS-scan kernels, embedded in the
//!     JAX decoder layers, were AOT-lowered to `artifacts/*.hlo.txt`;
//!   * runtime — the Rust PJRT client loads and compiles those artifacts
//!     (Python is not running here);
//!   * L3 — the coordinator routes, batches, pads and dispatches live
//!     requests across worker threads, with metrics;
//!   * correctness — served outputs match a golden re-execution, and the
//!     Hyena/Mamba layers show their expected causal structure.
//!
//! Requires `make artifacts` (skips gracefully if missing).
//!
//! Run: `cargo run --release --example e2e_serve -- [--requests 48] [--workers 2]`

use ssm_rdu::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, Executor, PjrtExecutor};
use ssm_rdu::runtime::{default_artifacts_dir, Manifest, ModelKind};
use ssm_rdu::util::cli::Args;
use ssm_rdu::util::{fmt_time, XorShift};
use ssm_rdu::util::table::Table;
use std::time::{Duration, Instant};

fn main() {
    let args = Args::from_env();
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let n_requests = args.usize_or("requests", 48);
    let workers = args.usize_or("workers", 1);

    let manifest = match Manifest::load(dir.join("manifest.json")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("e2e_serve: artifacts not available ({e:#}); run `make artifacts` first.");
            std::process::exit(0); // graceful skip: build-time artifacts absent
        }
    };
    let elems = manifest.seq_len * manifest.d_model;
    let models: Vec<ModelKind> = manifest.models.keys().copied().collect();
    println!(
        "artifacts: L={} D={} batch={} models={models:?}",
        manifest.seq_len, manifest.d_model, manifest.batch
    );

    // Start the coordinator; each worker compiles its own PJRT set.
    let t_boot = Instant::now();
    let dir2 = dir.clone();
    let coord = Coordinator::start(
        CoordinatorConfig {
            policy: BatchPolicy {
                max_batch: manifest.batch,
                max_wait: Duration::from_millis(8),
            },
            workers,
            ..Default::default()
        },
        Box::new(move || Ok(Box::new(PjrtExecutor::load(&dir2)?) as Box<dyn Executor>)),
    )
    .expect("coordinator start");
    println!("coordinator up in {} ({} worker(s))", fmt_time(t_boot.elapsed().as_secs_f64()), workers);

    // Fire a mixed workload.
    let mut rng = XorShift::new(2024);
    let inputs: Vec<(ModelKind, Vec<f32>)> = (0..n_requests)
        .map(|i| {
            let model = models[i % models.len()];
            let x: Vec<f32> = (0..elems).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            (model, x)
        })
        .collect();

    let t0 = Instant::now();
    let rxs: Vec<_> = inputs
        .iter()
        .map(|(m, x)| coord.submit(*m, x.clone()).expect("submit"))
        .collect();
    let responses: Vec<_> = rxs.into_iter().map(|rx| rx.recv().expect("response")).collect();
    let wall = t0.elapsed().as_secs_f64();

    // Report per-model latency statistics.
    let mut t = Table::new(
        "e2e serving results",
        &["model", "requests", "mean latency", "mean batch", "tokens/s"],
    );
    for &m in &models {
        let rs: Vec<_> = responses.iter().filter(|r| r.model == m).collect();
        if rs.is_empty() {
            continue;
        }
        let mean_lat =
            rs.iter().map(|r| r.latency().as_secs_f64()).sum::<f64>() / rs.len() as f64;
        let mean_batch = rs.iter().map(|r| r.batch_size as f64).sum::<f64>() / rs.len() as f64;
        let tok_s = rs.len() as f64 * manifest.seq_len as f64 / wall;
        t.row(&[
            m.to_string(),
            rs.len().to_string(),
            fmt_time(mean_lat),
            format!("{mean_batch:.2}"),
            format!("{tok_s:.0}"),
        ]);
    }
    t.print();
    println!(
        "total: {n_requests} requests in {} → {:.1} req/s  |  {}",
        fmt_time(wall),
        n_requests as f64 / wall,
        coord.metrics.summary()
    );

    // Golden correctness check: re-execute one request directly and compare.
    let mut exec = PjrtExecutor::load(&dir).expect("golden executor");
    let (m0, x0) = &inputs[0];
    let slots = exec.batch_slots(*m0);
    let mut packed = vec![0f32; slots * elems];
    packed[..elems].copy_from_slice(x0);
    let golden = exec.execute(*m0, &packed).expect("golden exec");
    let served = &responses[0];
    assert_eq!(served.model, *m0);
    let max_diff = served
        .output
        .iter()
        .zip(&golden[..elems])
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("golden check ({m0}): max |served − direct| = {max_diff:.2e}");
    assert!(max_diff < 1e-4, "served output must match direct execution");

    // Structural sanity: the served mamba layer must be causal.
    if models.contains(&ModelKind::Mamba) {
        let mut a = vec![0.25f32; elems];
        let b = a.clone();
        // Perturb the last quarter of the sequence only.
        for v in a[elems * 3 / 4..].iter_mut() {
            *v += 1.0;
        }
        let ra = coord.call(ModelKind::Mamba, a).expect("call");
        let rb = coord.call(ModelKind::Mamba, b).expect("call");
        let prefix = elems / 2;
        let pre_diff = ra.output[..prefix]
            .iter()
            .zip(&rb.output[..prefix])
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        println!("causality check (mamba): prefix diff = {pre_diff:.2e}");
        assert!(pre_diff < 1e-4, "future tokens must not affect the past");
    }

    coord.shutdown();
    println!("e2e_serve OK");
}
