//! Shared decoder building blocks: GEMMs, norms, element-wise maps and the
//! MLP — the parts of the template common to all three decoders (Fig. 3).

use super::config::DecoderConfig;
use crate::graph::{Graph, Kernel, KernelId, OpClass};

/// FLOPs of a `m × n × k` GEMM: `2·m·n·k`.
pub fn gemm_flops(m: usize, n: usize, k: usize) -> f64 {
    2.0 * m as f64 * n as f64 * k as f64
}

/// Add a dense projection `[rows × k] · [k × n] → [rows × n]`.
pub fn gemm(g: &mut Graph, cfg: &DecoderConfig, name: &str, rows: usize, n: usize, k: usize) -> KernelId {
    let b = cfg.dtype_bytes;
    let kern = Kernel::new(
        name,
        OpClass::Gemm,
        gemm_flops(rows, n, k),
        rows as f64 * k as f64 * b,
        rows as f64 * n as f64 * b,
    )
    .with_weights(k as f64 * n as f64 * b)
    .with_stream(rows as f64, n as f64);
    g.add(kern)
}

/// Add a layer norm over `[L × d]` (mean, variance, normalize, scale+shift
/// ≈ 8 FLOP/element).
pub fn layer_norm(g: &mut Graph, cfg: &DecoderConfig, name: &str, d: usize) -> KernelId {
    let l = cfg.seq_len as f64;
    let b = cfg.dtype_bytes;
    let elems = l * d as f64;
    let kern = Kernel::new(name, OpClass::Norm, 8.0 * elems, elems * b, elems * b)
        .with_weights(2.0 * d as f64 * b)
        .with_stream(l, d as f64);
    g.add(kern)
}

/// Add an element-wise kernel over `elems` elements at `flops_per_elem`.
pub fn eltwise(
    g: &mut Graph,
    cfg: &DecoderConfig,
    name: &str,
    elems: f64,
    flops_per_elem: f64,
    n_inputs: f64,
) -> KernelId {
    let b = cfg.dtype_bytes;
    let kern = Kernel::new(
        name,
        OpClass::Elementwise,
        flops_per_elem * elems,
        n_inputs * elems * b,
        elems * b,
    )
    .with_stream(cfg.seq_len as f64, elems / cfg.seq_len as f64);
    g.add(kern)
}

/// Append the post-mixer half of the decoder: residual add → LN → MLP
/// (two GEMMs with GELU) → residual add. Returns the final kernel id.
///
/// Paper §IV-C explicitly calls out the MLP as the Amdahl bound on the
/// scan-mode speedup, so the MLP is part of every decoder graph.
pub fn mlp_block(g: &mut Graph, cfg: &DecoderConfig, after: KernelId) -> KernelId {
    let l = cfg.seq_len;
    let d = cfg.d_model;
    let h = cfg.mlp_mult * d;
    let b = cfg.dtype_bytes;
    let act = cfg.act_bytes();

    let res1 = eltwise(g, cfg, "residual1", (l * d) as f64, 1.0, 2.0);
    g.connect_stream(after, res1, act);

    let ln2 = layer_norm(g, cfg, "ln2", d);
    g.connect_stream(res1, ln2, act);

    let fc1 = gemm(g, cfg, "mlp.fc1", l, h, d);
    g.connect_stream(ln2, fc1, act);

    let gelu = eltwise(g, cfg, "mlp.gelu", (l * h) as f64, 8.0, 1.0);
    g.connect_stream(fc1, gelu, l as f64 * h as f64 * b);

    let fc2 = gemm(g, cfg, "mlp.fc2", l, d, h);
    g.connect_stream(gelu, fc2, l as f64 * h as f64 * b);

    let res2 = eltwise(g, cfg, "residual2", (l * d) as f64, 1.0, 2.0);
    g.connect_stream(fc2, res2, act);
    g.connect(res1, res2, act);
    res2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_flops_formula() {
        assert_eq!(gemm_flops(10, 20, 30), 12000.0);
    }

    #[test]
    fn mlp_block_wires_residuals() {
        let cfg = DecoderConfig::paper(1 << 12);
        let mut g = Graph::new("t");
        let src = g.add(Kernel::new("src", OpClass::Gemm, 1.0, 1.0, 1.0));
        g.input(src, 1.0);
        let last = mlp_block(&mut g, &cfg, src);
        g.output(last, cfg.act_bytes());
        assert!(g.validate().is_ok());
        // MLP GEMM flops: 2·L·4D·D × 2 directions.
        let l = cfg.seq_len;
        let d = cfg.d_model;
        let want = 2.0 * gemm_flops(l, 4 * d, d);
        let got: f64 = g
            .kernels
            .iter()
            .filter(|k| k.name.starts_with("mlp.fc"))
            .map(|k| k.flops)
            .sum();
        assert_eq!(got, want);
    }
}
