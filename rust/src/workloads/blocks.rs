//! Shared decoder building blocks: GEMMs, norms, element-wise maps, the
//! MLP and the FFT-convolution chain — the parts of the template common to
//! the registered decoders (Fig. 3; see [`super::registry`]).

use super::config::DecoderConfig;
use crate::fft::{gemm_fft_flops, vector_fft_flops, BaileyVariant};
use crate::graph::{Graph, Kernel, KernelId, OpClass};

/// FLOPs of a `m × n × k` GEMM: `2·m·n·k`.
pub fn gemm_flops(m: usize, n: usize, k: usize) -> f64 {
    2.0 * m as f64 * n as f64 * k as f64
}

/// Add a dense projection `[rows × k] · [k × n] → [rows × n]`.
pub fn gemm(g: &mut Graph, cfg: &DecoderConfig, name: &str, rows: usize, n: usize, k: usize) -> KernelId {
    let b = cfg.dtype_bytes;
    let kern = Kernel::new(
        name,
        OpClass::Gemm,
        gemm_flops(rows, n, k),
        rows as f64 * k as f64 * b,
        rows as f64 * n as f64 * b,
    )
    .with_weights(k as f64 * n as f64 * b)
    .with_stream(rows as f64, n as f64);
    g.add(kern)
}

/// Add a layer norm over `[L × d]` (mean, variance, normalize, scale+shift
/// ≈ 8 FLOP/element).
pub fn layer_norm(g: &mut Graph, cfg: &DecoderConfig, name: &str, d: usize) -> KernelId {
    let l = cfg.seq_len as f64;
    let b = cfg.dtype_bytes;
    let elems = l * d as f64;
    let kern = Kernel::new(name, OpClass::Norm, 8.0 * elems, elems * b, elems * b)
        .with_weights(2.0 * d as f64 * b)
        .with_stream(l, d as f64);
    g.add(kern)
}

/// Add an element-wise kernel over `elems` elements at `flops_per_elem`.
pub fn eltwise(
    g: &mut Graph,
    cfg: &DecoderConfig,
    name: &str,
    elems: f64,
    flops_per_elem: f64,
    n_inputs: f64,
) -> KernelId {
    let b = cfg.dtype_bytes;
    let kern = Kernel::new(
        name,
        OpClass::Elementwise,
        flops_per_elem * elems,
        n_inputs * elems * b,
        elems * b,
    )
    .with_stream(cfg.seq_len as f64, elems / cfg.seq_len as f64);
    g.add(kern)
}

/// Append the post-mixer half of the decoder: residual add → LN → MLP
/// (two GEMMs with GELU) → residual add. Returns the final kernel id.
///
/// Paper §IV-C explicitly calls out the MLP as the Amdahl bound on the
/// scan-mode speedup, so the MLP is part of every decoder graph.
pub fn mlp_block(g: &mut Graph, cfg: &DecoderConfig, after: KernelId) -> KernelId {
    let l = cfg.seq_len;
    let d = cfg.d_model;
    let h = cfg.mlp_mult * d;
    let b = cfg.dtype_bytes;
    let act = cfg.act_bytes();

    let res1 = eltwise(g, cfg, "residual1", (l * d) as f64, 1.0, 2.0);
    g.connect_stream(after, res1, act);

    let ln2 = layer_norm(g, cfg, "ln2", d);
    g.connect_stream(res1, ln2, act);

    let fc1 = gemm(g, cfg, "mlp.fc1", l, h, d);
    g.connect_stream(ln2, fc1, act);

    let gelu = eltwise(g, cfg, "mlp.gelu", (l * h) as f64, 8.0, 1.0);
    g.connect_stream(fc1, gelu, l as f64 * h as f64 * b);

    let fc2 = gemm(g, cfg, "mlp.fc2", l, d, h);
    g.connect_stream(gelu, fc2, l as f64 * h as f64 * b);

    let res2 = eltwise(g, cfg, "residual2", (l * d) as f64, 1.0, 2.0);
    g.connect_stream(fc2, res2, act);
    g.connect(res1, res2, act);
    res2
}

/// FLOPs of one N-point FFT under the chosen Bailey variant, per channel.
pub(crate) fn fft_flops(n: usize, variant: BaileyVariant, r: usize) -> f64 {
    match variant {
        BaileyVariant::Vector => vector_fft_flops(n),
        BaileyVariant::Gemm => gemm_fft_flops(n, r),
    }
}

/// The op class FFT kernels carry under each variant: Vector-FFT runs
/// butterflies (CUDA-core / FFT-mode path), GEMM-FFT runs dense R-point
/// DFT matmuls (tensor-core / systolic path).
pub(crate) fn fft_op(variant: BaileyVariant) -> OpClass {
    match variant {
        BaileyVariant::Vector => OpClass::VectorFft,
        BaileyVariant::Gemm => OpClass::GemmFft,
    }
}

/// Add one FFT-convolution module: FFT(x), FFT(filter), frequency-domain
/// complex product, iFFT. All transforms are length `fft_len` (= 2L padded)
/// over `D` independent channels. Shared by the Hyena decoder (two convs,
/// data-dependent filters) and the S4 decoder (one conv, LTI kernel).
///
/// Every edge of the conv chain is a *stream* edge (the FFT ingests its
/// producer through its corner-turn PMU buffer; the frequency product and
/// inverse transform consume in emission order), so the fusion pass can
/// cluster the whole FFT → eltwise → iFFT dataflow into one section.
pub(crate) fn fft_conv(
    g: &mut Graph,
    cfg: &DecoderConfig,
    tag: &str,
    variant: BaileyVariant,
    x: KernelId,
    filt: KernelId,
) -> KernelId {
    let n = cfg.fft_len();
    let d = cfg.d_model as f64;
    let b = cfg.dtype_bytes;
    let op = fft_op(variant);
    let per_fft = fft_flops(n, variant, cfg.fft_tile) * d;
    // Real input of N elements → N complex outputs (2 values each).
    let real_bytes = n as f64 * d * b;
    let cplx_bytes = 2.0 * real_bytes;

    let fft_x = g.add(
        Kernel::new(&format!("{tag}.fft_x"), op, per_fft, real_bytes, cplx_bytes)
            .with_stream(n as f64, d),
    );
    g.connect_stream(x, fft_x, cfg.act_bytes());

    let fft_k = g.add(
        Kernel::new(&format!("{tag}.fft_k"), op, per_fft, real_bytes, cplx_bytes)
            .with_stream(n as f64, d),
    );
    g.connect_stream(filt, fft_k, cfg.act_bytes());

    // Frequency-domain pointwise complex multiply: 6 FLOP per complex pair.
    let mul = g.add(
        Kernel::new(
            &format!("{tag}.freqmul"),
            OpClass::Elementwise,
            6.0 * n as f64 * d,
            2.0 * cplx_bytes,
            cplx_bytes,
        )
        .with_stream(n as f64, d),
    );
    g.connect_stream(fft_x, mul, cplx_bytes);
    g.connect_stream(fft_k, mul, cplx_bytes);

    let ifft = g.add(
        Kernel::new(&format!("{tag}.ifft"), op, per_fft, cplx_bytes, real_bytes)
            .with_stream(n as f64, d),
    );
    g.connect_stream(mul, ifft, cplx_bytes);
    ifft
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_flops_formula() {
        assert_eq!(gemm_flops(10, 20, 30), 12000.0);
    }

    #[test]
    fn mlp_block_wires_residuals() {
        let cfg = DecoderConfig::paper(1 << 12);
        let mut g = Graph::new("t");
        let src = g.add(Kernel::new("src", OpClass::Gemm, 1.0, 1.0, 1.0));
        g.input(src, 1.0);
        let last = mlp_block(&mut g, &cfg, src);
        g.output(last, cfg.act_bytes());
        assert!(g.validate().is_ok());
        // MLP GEMM flops: 2·L·4D·D × 2 directions.
        let l = cfg.seq_len;
        let d = cfg.d_model;
        let want = 2.0 * gemm_flops(l, 4 * d, d);
        let got: f64 = g
            .kernels
            .iter()
            .filter(|k| k.name.starts_with("mlp.fc"))
            .map(|k| k.flops)
            .sum();
        assert_eq!(got, want);
    }
}
