//! Mamba-2 **SSD** (state-space dual) decoder workload: the chunked
//! reformulation of the selective scan (Dao & Gu, 2024; surveyed in the
//! S4→Mamba line of work) that turns most scan arithmetic into dense
//! matmuls.
//!
//! The recurrence is the same first-order linear one as Mamba's
//! (`h[t] = a[t]·h[t-1] + b[t]`, [`crate::scan::recurrence`]), but SSD
//! evaluates it in `Q`-element **chunks**:
//!
//! ```text
//! intra-chunk   y_local = L ⊙ b        L[t][s] = ∏_{s<r≤t} a[r]
//!               (a lower-triangular semiseparable matmul — systolic work)
//! inter-chunk   carry[k+1] = A_k·carry[k] + B_k   over K = ⌈L/Q⌉ chunk
//!               totals (a short serial recurrence: K elements, not L)
//! combine       h[t] = seg[t]·carry_in + y_local[t]
//! ```
//!
//! The architectural point: the O(L·Q) intra-chunk work runs in **systolic
//! mode at full MAC rate on a baseline RDU** — no scan interconnect
//! extension needed — while the inherently serial part shrinks from `L`
//! elements (C-scan) to `L/Q` chunk totals. [`Workload::extended_config`]
//! is therefore the *baseline* chip: SSD trades ~`Q/6`× more FLOPs than the
//! lifted parallel scan for extension-free spatial execution.
//!
//! **Numerics.** [`ssd_scan`] is the golden chunked evaluator: it carries
//! the inter-chunk recurrence through the chunk boundary by *injecting* the
//! carry into the chunk's first step (`b'[0] = b[0] + a[0]·carry`, the same
//! mul-then-add the serial update performs) and evaluates each chunk's
//! semiseparable matvec in Horner (row-recurrence) order — which makes it
//! **bit-identical** to [`crate::scan::mamba_scan_serial`] for every length
//! and chunk size, ragged tails included (the integration tests assert
//! exact equality, as does the `--chips 2` sharded driver
//! [`crate::shard::sharded_ssd_scan`]). [`ssd_scan_semiseparable`] is the
//! explicit matmul-order evaluation the dataflow graph prices (cumulative-
//! product matrix, row sums); floating-point regrouping puts it within
//! ~1e-12 of serial, checked at the usual 1e-9 budget.

use super::blocks::{self, eltwise, gemm, layer_norm};
use super::config::DecoderConfig;
use super::registry::{DecodeDemand, GoldenCheck, ShardComm, Workload};
use crate::arch::RduConfig;
use crate::graph::{Graph, Kernel, OpClass};
use crate::runtime::ModelKind;
use crate::util::XorShift;

/// Golden chunked SSD scan seeded by `carry` (the state entering the first
/// chunk): inter-chunk recurrence via carry injection, intra-chunk Horner
/// evaluation. Bit-identical to running [`crate::scan::mamba_scan_serial`]
/// from the same state — see the module docs for why. The sharded driver
/// chains per-chip segments through this entry point.
pub fn ssd_scan_with_carry(a: &[f64], b: &[f64], q: usize, carry: f64) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "ssd_scan: a/b length mismatch");
    assert!(q >= 1, "ssd_scan: chunk size must be >= 1");
    let n = a.len();
    let mut out = Vec::with_capacity(n);
    let mut carry = carry;
    for lo in (0..n).step_by(q) {
        let hi = (lo + q).min(n);
        // Inject the carry into the chunk's first step exactly as the
        // serial update would consume it: a·h then + b (addition commutes
        // bit-exactly; multiplication order is the serial one).
        let mut h = 0.0;
        for t in lo..hi {
            let bt = if t == lo { b[t] + a[t] * carry } else { b[t] };
            h = a[t] * h + bt;
            out.push(h);
        }
        carry = h;
    }
    out
}

/// Golden chunked SSD scan from `h0 = 0` over `q`-element chunks.
pub fn ssd_scan(a: &[f64], b: &[f64], q: usize) -> Vec<f64> {
    ssd_scan_with_carry(a, b, q, 0.0)
}

/// The explicit **semiseparable-matmul** evaluation of the chunked scan —
/// the arithmetic the dataflow graph prices on the systolic arrays: per
/// chunk, materialize the cumulative-decay products and evaluate each
/// output as a row sum `Σ_s (∏_{s<r≤t} a[r])·b[s]`, then apply the
/// inter-chunk carry as `seg[t]·h_in + local[t]`. Same math as
/// [`ssd_scan`] under a different regrouping; agreement is ~1e-12
/// (checked ≤ 1e-9 against [`crate::scan::mamba_scan_serial`]).
pub fn ssd_scan_semiseparable(a: &[f64], b: &[f64], q: usize) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "ssd_scan: a/b length mismatch");
    assert!(q >= 1, "ssd_scan: chunk size must be >= 1");
    let n = a.len();
    let mut out = Vec::with_capacity(n);
    let mut h_in = 0.0;
    for lo in (0..n).step_by(q) {
        let hi = (lo + q).min(n);
        let len = hi - lo;
        // decay[i] = ∏_{lo..=lo+i} a — one cumulative-product pass; the
        // L-matrix entry ∏_{s<r≤t} is decay[t]/... recomputed as a running
        // product per row to stay division-free like the hardware would.
        let mut local = vec![0.0; len];
        let mut seg = vec![0.0; len];
        for t in 0..len {
            // Row t of the lower-triangular matvec, evaluated left to
            // right: products ∏_{s<r≤t} a[lo+r] built by suffix scaling.
            let mut row = 0.0;
            let mut prod = 1.0;
            for s in (0..=t).rev() {
                row += prod * b[lo + s];
                prod *= a[lo + s];
            }
            local[t] = row;
            seg[t] = prod; // ∏_{lo..=lo+t} a
        }
        for t in 0..len {
            out.push(seg[t] * h_in + local[t]);
        }
        h_in = *out.last().unwrap();
    }
    out
}

/// FLOPs of the SSD core over `L` positions, `C = N·d_inner` channels,
/// chunk `Q`:
///
/// * intra-chunk semiseparable matvecs — `Q²/2` MACs per chunk-channel
///   → `L·Q·C` FLOPs total (the systolic share);
/// * inter-chunk recurrence — one lifted combine (3 FLOP) per chunk total
///   → `3·⌈L/Q⌉·C`;
/// * carry combine — 2 FLOP per element → `2·L·C`.
pub fn ssd_core_flops(cfg: &DecoderConfig) -> f64 {
    let l = cfg.seq_len as f64;
    let q = cfg.ssd_chunk.max(1) as f64;
    let c = (cfg.d_inner() * cfg.state_dim.max(1)) as f64;
    let chunks = (l / q).ceil();
    l * q * c + 3.0 * chunks * c + 2.0 * l * c
}

/// Build the Mamba-2 SSD decoder layer.
///
/// Template: identical to [`super::mamba::mamba_decoder`] up to the
/// discretized `(ā, b̄)` streams, then the chunked core replaces the
/// monolithic selective scan:
///
/// `discretize → chunk_decay (cumprods) → intra_chunk_gemm (semiseparable
/// matmul, `OpClass::Gemm`) → inter_chunk_scan (serial over L/Q totals)
/// → chunk_combine → c_contract → gate → out_proj → MLP`,
///
/// every hop a stream edge so the fusion pass clusters the whole spine.
pub fn ssd_decoder(cfg: &DecoderConfig) -> Graph {
    let l = cfg.seq_len;
    let d = cfg.d_model;
    let di = cfg.d_inner();
    let n = cfg.state_dim.max(1);
    let q = cfg.ssd_chunk.max(1);
    let b = cfg.dtype_bytes;
    let act = cfg.act_bytes();
    let act_inner = l as f64 * di as f64 * b;
    let c = (di * n) as f64; // scan channels
    let chunks = (l as f64 / q as f64).ceil();
    let dt_rank = (d / 16).max(1);

    let mut g = Graph::new(&format!("ssd-decoder[Q={q}] L={l} D={d}"));

    let ln1 = layer_norm(&mut g, cfg, "ln1", d);
    g.input(ln1, act);

    let in_proj = gemm(&mut g, cfg, "in_proj", l, 2 * di, d);
    g.connect_stream(ln1, in_proj, act);

    let conv1d = eltwise(&mut g, cfg, "conv1d", (l * di) as f64, 8.0, 1.0);
    g.connect_stream(in_proj, conv1d, act_inner);
    let silu = eltwise(&mut g, cfg, "silu.x", (l * di) as f64, 4.0, 1.0);
    g.connect_stream(conv1d, silu, act_inner);

    let x_proj = gemm(&mut g, cfg, "x_proj", l, dt_rank + 2 * n, di);
    g.connect_stream(silu, x_proj, act_inner);
    let dt_proj = gemm(&mut g, cfg, "dt_proj", l, di, dt_rank);
    g.connect_stream(x_proj, dt_proj, l as f64 * dt_rank as f64 * b);

    // Discretization: ā = exp(Δ·A), b̄ = Δ·B·x — same stage as Mamba-1.
    let scan_bytes = 2.0 * l as f64 * c * b;
    let disc = g.add(
        Kernel::new(
            "discretize",
            OpClass::Elementwise,
            4.0 * l as f64 * c,
            act_inner + l as f64 * (2 * n) as f64 * b,
            scan_bytes,
        )
        .with_stream(l as f64, c),
    );
    g.connect_stream(dt_proj, disc, act_inner);
    g.connect(x_proj, disc, l as f64 * (2 * n) as f64 * b);

    // Within-chunk cumulative decay products — the generator of the
    // lower-triangular L matrix (and the seg[t] broadcast factors).
    let decay = g.add(
        Kernel::new(
            "chunk_decay",
            OpClass::Elementwise,
            l as f64 * c,
            scan_bytes / 2.0,
            l as f64 * c * b,
        )
        .with_stream(l as f64, c),
    );
    g.connect_stream(disc, decay, scan_bytes / 2.0);

    // The SSD headline: per chunk-channel a Q×Q lower-triangular matvec
    // against the b̄ stream — dense systolic work (OpClass::Gemm), L·Q·C
    // FLOPs. Both the decay matrix and the b̄ values stream in.
    let intra = g.add(
        Kernel::new(
            "intra_chunk_gemm",
            OpClass::Gemm,
            l as f64 * q as f64 * c,
            l as f64 * c * b + scan_bytes / 2.0,
            l as f64 * c * b,
        )
        .with_stream(l as f64, c),
    );
    g.connect_stream(decay, intra, l as f64 * c * b);
    g.connect_stream(disc, intra, scan_bytes / 2.0);

    // The inherently serial remainder: the recurrence over ⌈L/Q⌉ chunk
    // totals (3 FLOP per lifted combine) — L/Q elements, not L.
    let inter = g.add(
        Kernel::new(
            "inter_chunk_scan",
            OpClass::ScanSerial,
            3.0 * chunks * c,
            2.0 * chunks * c * b,
            chunks * c * b,
        )
        .with_stream(chunks, c),
    );
    g.connect_stream(intra, inter, 2.0 * chunks * c * b);

    // Broadcast-combine: h[t] = seg[t]·carry_in(chunk) + local[t].
    let combine = g.add(
        Kernel::new(
            "chunk_combine",
            OpClass::Elementwise,
            2.0 * l as f64 * c,
            l as f64 * c * b + chunks * c * b,
            l as f64 * c * b,
        )
        .with_stream(l as f64, c),
    );
    g.connect_stream(intra, combine, l as f64 * c * b);
    g.connect_stream(inter, combine, chunks * c * b);

    // Output contraction, gate and projection — the shared Mamba tail.
    let contract = g.add(
        Kernel::new(
            "c_contract",
            OpClass::Elementwise,
            2.0 * l as f64 * c,
            l as f64 * c * b + l as f64 * n as f64 * b,
            act_inner,
        )
        .with_stream(l as f64, di as f64),
    );
    g.connect_stream(combine, contract, l as f64 * c * b);
    g.connect(x_proj, contract, l as f64 * n as f64 * b);

    let gate = eltwise(&mut g, cfg, "gate.z", (l * di) as f64, 5.0, 2.0);
    g.connect_stream(contract, gate, act_inner);
    g.connect(in_proj, gate, act_inner);

    let out_proj = gemm(&mut g, cfg, "out_proj", l, d, di);
    g.connect_stream(gate, out_proj, act_inner);

    let last = blocks::mlp_block(&mut g, cfg, out_proj);
    g.output(last, act);

    debug_assert!(g.validate().is_ok());
    g
}

/// The registered Mamba-2 SSD workload (see [`mod@crate::workloads::registry`]).
pub struct SsdWorkload;

impl Workload for SsdWorkload {
    fn name(&self) -> &'static str {
        "ssd"
    }

    fn describe(&self) -> &'static str {
        "Mamba-2 SSD: chunked scan as intra-chunk matmul + inter-chunk recurrence"
    }

    fn family(&self) -> ModelKind {
        ModelKind::Mamba
    }

    fn build_graph(&self, dc: &DecoderConfig) -> Graph {
        ssd_decoder(dc)
    }

    /// SSD's core is systolic: the baseline RDU already runs it spatially,
    /// which is the design point the workload exists to demonstrate.
    fn extended_config(&self) -> RduConfig {
        RduConfig::baseline()
    }

    /// Per token SSD decodes exactly like the selective scan (chunking is
    /// a prefill-time reformulation): same projections, same `N × d_inner`
    /// recurrent state.
    fn decode_demand(&self, dc: &DecoderConfig) -> DecodeDemand {
        super::mamba::MambaWorkload.decode_demand(dc)
    }

    /// Same wire pattern and carry channels as the selective scan — the
    /// `ssd_rides_the_mamba_carry_exchange` invariant, kept by delegation
    /// like [`Workload::decode_demand`] above.
    fn shard_comm(&self, dc: &DecoderConfig) -> ShardComm {
        super::mamba::MambaWorkload.shard_comm(dc)
    }

    fn golden_check(&self, seed: u64) -> Option<GoldenCheck> {
        let mut rng = XorShift::new(seed);
        let n = 1000; // deliberately ragged vs Q
        let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let want = crate::scan::mamba_scan_serial(&a, &b);
        let mut max_d = 0.0f64;
        let mut bit_identical = true;
        for q in [1usize, 64, 256] {
            let got = ssd_scan(&a, &b, q);
            bit_identical &= got == want;
            max_d = max_d.max(crate::util::max_abs_diff(&got, &want));
        }
        Some(GoldenCheck {
            reference: "scan::mamba_scan_serial",
            max_abs_diff: max_d,
            bit_identical,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::mamba_scan_serial;
    use crate::util::max_abs_diff;

    #[test]
    fn chunked_scan_bit_identical_to_serial() {
        let mut rng = XorShift::new(71);
        for n in [1usize, 7, 100, 255, 256, 257, 1000, 1023] {
            let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
            let b = rng.vec(n, -1.0, 1.0);
            let want = mamba_scan_serial(&a, &b);
            for q in [1usize, 2, 4, 64, 256, 4096] {
                assert_eq!(ssd_scan(&a, &b, q), want, "n={n} q={q}: must not differ by a bit");
            }
        }
    }

    #[test]
    fn semiseparable_matches_serial_within_budget() {
        let mut rng = XorShift::new(72);
        for n in [1usize, 7, 100, 513] {
            let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
            let b = rng.vec(n, -1.0, 1.0);
            let want = mamba_scan_serial(&a, &b);
            for q in [4usize, 16, 64] {
                let d = max_abs_diff(&ssd_scan_semiseparable(&a, &b, q), &want);
                assert!(d < 1e-9, "n={n} q={q}: |d|={d}");
            }
        }
    }

    #[test]
    fn carry_seeding_matches_a_longer_serial_run() {
        // Seeding with chunk k's final state reproduces the serial tail —
        // the property the sharded driver chains chips with.
        let mut rng = XorShift::new(73);
        let n = 300;
        let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
        let b = rng.vec(n, -1.0, 1.0);
        let want = mamba_scan_serial(&a, &b);
        let cut = 113;
        let head = ssd_scan(&a[..cut], &b[..cut], 32);
        let tail = ssd_scan_with_carry(&a[cut..], &b[cut..], 32, *head.last().unwrap());
        let got: Vec<f64> = head.into_iter().chain(tail).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn graph_is_valid_and_core_kernels_present() {
        let cfg = DecoderConfig::paper(1 << 14);
        let g = ssd_decoder(&cfg);
        assert!(g.validate().is_ok(), "{}", g.name);
        let find = |name: &str| g.kernels.iter().find(|k| k.name == name).unwrap();
        assert_eq!(find("intra_chunk_gemm").op, OpClass::Gemm, "chunk matmuls are systolic work");
        let inter = find("inter_chunk_scan");
        assert_eq!(inter.op, OpClass::ScanSerial);
        assert_eq!(
            inter.elements,
            (cfg.seq_len as f64 / cfg.ssd_chunk as f64).ceil(),
            "serial part shrinks to L/Q chunk totals"
        );
    }

    #[test]
    fn core_flops_match_the_formula() {
        let cfg = DecoderConfig::paper(1 << 14);
        let g = ssd_decoder(&cfg);
        let core: f64 = g
            .kernels
            .iter()
            .filter(|k| {
                ["intra_chunk_gemm", "inter_chunk_scan", "chunk_combine"].contains(&k.name.as_str())
            })
            .map(|k| k.flops)
            .sum();
        assert!((core - ssd_core_flops(&cfg)).abs() / core < 1e-12);
    }

    #[test]
    fn ssd_spine_is_streamed_for_fusion() {
        let g = ssd_decoder(&DecoderConfig::paper(1 << 12));
        let id = |name: &str| g.kernels.iter().position(|k| k.name == name).unwrap();
        assert_eq!(
            g.stream_predecessors(id("intra_chunk_gemm")),
            vec![id("discretize"), id("chunk_decay")]
        );
        assert_eq!(g.stream_predecessors(id("inter_chunk_scan")), vec![id("intra_chunk_gemm")]);
        assert_eq!(
            g.stream_predecessors(id("chunk_combine")),
            vec![id("intra_chunk_gemm"), id("inter_chunk_scan")]
        );
        assert_eq!(g.stream_predecessors(id("c_contract")), vec![id("chunk_combine")]);
        assert_eq!(g.predecessors(id("gate.z")).len(), 2, "z branch buffered, not streamed");
    }

    #[test]
    fn linear_flop_scaling_in_l() {
        let f1 = ssd_decoder(&DecoderConfig::paper(1 << 18)).total_flops();
        let f2 = ssd_decoder(&DecoderConfig::paper(1 << 20)).total_flops();
        let ratio = f2 / f1;
        assert!((ratio - 4.0).abs() < 0.05, "ratio={ratio}"); // 4× length → 4× work
    }

    #[test]
    fn ssd_trades_flops_for_systolic_execution() {
        // More raw FLOPs than the lifted parallel scan (≈ Q/6×) on the
        // core, but the heavy share is Gemm class.
        let cfg = DecoderConfig::paper(1 << 16);
        let par = super::super::mamba::scan_flops(&cfg, super::super::ScanVariant::Parallel);
        assert!(ssd_core_flops(&cfg) > par, "SSD spends more arithmetic");
        let g = ssd_decoder(&cfg);
        let scan_share: f64 = g
            .kernels
            .iter()
            .filter(|k| k.op == OpClass::ScanSerial)
            .map(|k| k.elements * k.channels)
            .sum();
        assert!(
            scan_share < cfg.seq_len as f64,
            "serial updates must shrink below L (got {scan_share})"
        );
    }
}
