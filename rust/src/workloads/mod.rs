//! Decoder workloads: the builders for every registered SSM variant (and
//! the attention baseline), each emitted as a [`crate::graph::Graph`] with
//! paper-convention FLOP accounting — plus the **workload registry**
//! ([`mod@registry`]) that `simulate`/`serve`/`sweep`/`bench` resolve by name
//! and every downstream layer consumes uniformly.
//!
//! ## Modules
//!
//! * [`config::DecoderConfig`] — the shared shape knobs (the paper's
//!   D = 32, L ∈ {256K, 512K, 1M}, FP16, R = 32, plus the SSD chunk Q).
//! * [`mod@registry`] — the [`Workload`] trait (graph builder with stream
//!   edges, golden-model check, decode-step demand, shard strategy) and
//!   the name → workload table.
//! * [`attention::attention_decoder`] — Fig. 3A, quadratic `Q·Kᵀ`/`A·V`.
//! * [`hyena::hyena_decoder`] — Fig. 3B, each big GEMM replaced by two
//!   forward FFTs + pointwise product + one inverse FFT, in either the
//!   Vector-FFT or GEMM-FFT Bailey variant (§III-A).
//! * [`mamba::mamba_decoder`] — Fig. 3C, selective scan core in either
//!   C-scan or parallel-scan form (§IV-A).
//! * [`ssd::ssd_decoder`] — Mamba-2 SSD: the chunked scan as intra-chunk
//!   semiseparable matmul + inter-chunk recurrence; the golden chunked
//!   evaluator [`ssd::ssd_scan`] is bit-identical to
//!   [`crate::scan::mamba_scan_serial`].
//! * [`s4::s4_decoder`] — S4/long-conv: diagonal-SSM kernel
//!   materialization + one length-L FFT convolution through the planned
//!   real-input engine.
//! * [`blocks`] — the template pieces (GEMM/norm/eltwise/MLP/FFT-conv)
//!   the builders share.
//!
//! ## Resolving a workload by name
//!
//! ```
//! use ssm_rdu::workloads::{lookup, DecoderConfig};
//!
//! let dc = DecoderConfig::paper(1 << 12);
//! for name in ["hyena", "mamba", "ssd", "s4"] {
//!     let w = lookup(name).expect("registered");
//!     assert!(w.build_graph(&dc).validate().is_ok(), "{name}");
//! }
//! ```
//!
//! `docs/WORKLOADS.md` walks through adding a new workload end to end.

pub mod attention;
pub mod blocks;
pub mod config;
pub mod hyena;
pub mod mamba;
pub mod registry;
pub mod s4;
pub mod ssd;

pub use attention::attention_decoder;
pub use config::DecoderConfig;
pub use hyena::{hyena_conv_channels, hyena_decoder};
pub use mamba::{mamba_decoder, ScanVariant};
pub use registry::{
    family_workload, lookup, registry, registry_names, ssm_workloads, DecodeDemand, GoldenCheck,
    ShardComm, Workload,
};
pub use s4::{
    s4_conv, s4_conv_channels, s4_decoder, s4_kernel, s4_kernel_chunked, s4_kernel_scalar,
    s4_kernel_simd,
};
pub use ssd::{ssd_decoder, ssd_scan, ssd_scan_semiseparable, ssd_scan_with_carry};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::BaileyVariant;

    #[test]
    fn all_decoders_build_at_paper_sweep() {
        for cfg in DecoderConfig::paper_sweep() {
            assert!(attention_decoder(&cfg).validate().is_ok());
            assert!(hyena_decoder(&cfg, BaileyVariant::Vector).validate().is_ok());
            assert!(hyena_decoder(&cfg, BaileyVariant::Gemm).validate().is_ok());
            assert!(mamba_decoder(&cfg, ScanVariant::CScan).validate().is_ok());
            assert!(mamba_decoder(&cfg, ScanVariant::Parallel).validate().is_ok());
            // The registry resolves the same sweep uniformly.
            for w in registry() {
                assert!(w.build_graph(&cfg).validate().is_ok(), "{}", w.name());
            }
        }
    }

    #[test]
    fn flop_ordering_attention_worst() {
        let cfg = DecoderConfig::paper(1 << 20);
        let at = attention_decoder(&cfg).total_flops();
        let hy = hyena_decoder(&cfg, BaileyVariant::Vector).total_flops();
        let hg = hyena_decoder(&cfg, BaileyVariant::Gemm).total_flops();
        let ma = mamba_decoder(&cfg, ScanVariant::Parallel).total_flops();
        assert!(at > hg && hg > hy, "at={at} hg={hg} hy={hy}");
        assert!(at > ma);
    }
}
