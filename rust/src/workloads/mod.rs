//! Decoder workload builders (paper Fig. 3): the attention baseline, the
//! FFT-based Hyena decoder, and the scan-based Mamba decoder, each emitted
//! as a [`crate::graph::Graph`] with the paper's FLOP accounting.
//!
//! * [`config::DecoderConfig`] — the paper's shapes (D = 32, L ∈ {256K,
//!   512K, 1M}, FP16, R = 32).
//! * [`attention::attention_decoder`] — Fig. 3A, quadratic `Q·Kᵀ`/`A·V`.
//! * [`hyena::hyena_decoder`] — Fig. 3B, each big GEMM replaced by two
//!   forward FFTs + pointwise product + one inverse FFT, in either the
//!   Vector-FFT or GEMM-FFT Bailey variant (§III-A).
//! * [`mamba::mamba_decoder`] — Fig. 3C, selective scan core in either
//!   C-scan or parallel-scan form (§IV-A).

pub mod attention;
pub mod blocks;
pub mod config;
pub mod hyena;
pub mod mamba;

pub use attention::attention_decoder;
pub use config::DecoderConfig;
pub use hyena::{hyena_conv_channels, hyena_decoder};
pub use mamba::{mamba_decoder, ScanVariant};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::BaileyVariant;

    #[test]
    fn all_decoders_build_at_paper_sweep() {
        for cfg in DecoderConfig::paper_sweep() {
            assert!(attention_decoder(&cfg).validate().is_ok());
            assert!(hyena_decoder(&cfg, BaileyVariant::Vector).validate().is_ok());
            assert!(hyena_decoder(&cfg, BaileyVariant::Gemm).validate().is_ok());
            assert!(mamba_decoder(&cfg, ScanVariant::CScan).validate().is_ok());
            assert!(mamba_decoder(&cfg, ScanVariant::Parallel).validate().is_ok());
        }
    }

    #[test]
    fn flop_ordering_attention_worst() {
        let cfg = DecoderConfig::paper(1 << 20);
        let at = attention_decoder(&cfg).total_flops();
        let hy = hyena_decoder(&cfg, BaileyVariant::Vector).total_flops();
        let hg = hyena_decoder(&cfg, BaileyVariant::Gemm).total_flops();
        let ma = mamba_decoder(&cfg, ScanVariant::Parallel).total_flops();
        assert!(at > hg && hg > hy, "at={at} hg={hg} hy={hy}");
        assert!(at > ma);
    }
}
