//! Decoder configuration shared by all workload builders.

/// Model/shape parameters of one decoder layer (paper §III-C/§IV-C: "All
/// decoders are configured with a hidden dimension of 32" and swept over
/// sequence lengths 256K, 512K, 1M).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecoderConfig {
    /// Sequence length L.
    pub seq_len: usize,
    /// Hidden (model) dimension D.
    pub d_model: usize,
    /// MLP expansion factor (4× in the standard transformer template).
    pub mlp_mult: usize,
    /// Bytes per element (FP16 = 2).
    pub dtype_bytes: f64,
    /// Bailey FFT tile length R (paper: 16 or 32, matched to lane width).
    pub fft_tile: usize,
    /// Mamba SSM state dimension N.
    pub state_dim: usize,
    /// Mamba channel expansion factor E (d_inner = E·D).
    pub expand: usize,
    /// Mamba-2 SSD chunk length Q (intra-chunk matmul tile; Mamba-2's
    /// default block size). Only the `ssd` workload reads it.
    pub ssd_chunk: usize,
}

impl DecoderConfig {
    /// The paper's evaluation configuration at sequence length `seq_len`:
    /// D = 32, FP16, R = 32.
    ///
    /// The paper describes its Mamba decoder as "a linear time-invariant
    /// (LTI) model that evolves hidden states across the sequence" whose
    /// "core operation is a scan" (§II-B) — i.e. one scalar recurrence per
    /// hidden channel (`N = 1`, `E = 1`, scan channels = D = 32). The full
    /// selective-SSM shape (`N = 16`, `E = 2`) is available via
    /// [`DecoderConfig::mamba_full`] for ablations.
    pub fn paper(seq_len: usize) -> Self {
        Self {
            seq_len,
            d_model: 32,
            mlp_mult: 4,
            dtype_bytes: 2.0,
            fft_tile: 32,
            state_dim: 1,
            expand: 1,
            ssd_chunk: 256,
        }
    }

    /// Modern selective-SSM Mamba shape (N = 16 states, 2× channel
    /// expansion) — used by the ablation benches, not by the paper figures.
    pub fn mamba_full(seq_len: usize) -> Self {
        Self { state_dim: 16, expand: 2, ..Self::paper(seq_len) }
    }

    /// The paper's three sequence-length sweep points: 256K, 512K, 1M.
    pub fn paper_sweep() -> [Self; 3] {
        [
            Self::paper(256 * 1024),
            Self::paper(512 * 1024),
            Self::paper(1024 * 1024),
        ]
    }

    /// Mamba inner channel count `E·D`.
    pub fn d_inner(&self) -> usize {
        self.expand * self.d_model
    }

    /// Bytes of one `L × D` activation tensor.
    pub fn act_bytes(&self) -> f64 {
        self.seq_len as f64 * self.d_model as f64 * self.dtype_bytes
    }

    /// Zero-padded FFT length for linear convolution over L points.
    pub fn fft_len(&self) -> usize {
        (2 * self.seq_len).next_power_of_two()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config() {
        let c = DecoderConfig::paper(1 << 20);
        assert_eq!(c.d_model, 32);
        assert_eq!(c.d_inner(), 32);
        assert_eq!(c.fft_len(), 1 << 21);
        assert_eq!(c.act_bytes(), (1 << 20) as f64 * 32.0 * 2.0);
        let full = DecoderConfig::mamba_full(1 << 20);
        assert_eq!(full.d_inner(), 64);
        assert_eq!(full.state_dim, 16);
        assert_eq!(c.ssd_chunk, 256, "Mamba-2's default chunk length");
    }

    #[test]
    fn sweep_lengths() {
        let ls: Vec<usize> = DecoderConfig::paper_sweep().iter().map(|c| c.seq_len).collect();
        assert_eq!(ls, vec![262_144, 524_288, 1_048_576]);
    }
}
