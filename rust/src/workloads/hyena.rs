//! Hyena decoder workload graph (paper Fig. 3B): "the same structural
//! template as the attention decoder but replaces the GEMM kernel with an
//! FFT-based convolution kernel… each GEMM is replaced by three FFT
//! operations: two forward FFTs … and one inverse FFT".

use super::blocks::{self, eltwise, gemm, layer_norm};
use super::config::DecoderConfig;
use crate::fft::{gemm_fft_flops, vector_fft_flops, BaileyVariant};
use crate::graph::{Graph, Kernel, KernelId, OpClass};

/// FLOPs of one N-point FFT under the chosen Bailey variant, per channel.
fn fft_flops(n: usize, variant: BaileyVariant, r: usize) -> f64 {
    match variant {
        BaileyVariant::Vector => vector_fft_flops(n),
        BaileyVariant::Gemm => gemm_fft_flops(n, r),
    }
}

/// The op class FFT kernels carry under each variant: Vector-FFT runs
/// butterflies (CUDA-core / FFT-mode path), GEMM-FFT runs dense R-point
/// DFT matmuls (tensor-core / systolic path).
fn fft_op(variant: BaileyVariant) -> OpClass {
    match variant {
        BaileyVariant::Vector => OpClass::VectorFft,
        BaileyVariant::Gemm => OpClass::GemmFft,
    }
}

/// Add one FFT-convolution module: FFT(x), FFT(filter), frequency-domain
/// complex product, iFFT. All transforms are length `fft_len` (= 2L padded)
/// over `D` independent channels.
///
/// Every edge of the conv chain is a *stream* edge (the FFT ingests its
/// producer through its corner-turn PMU buffer; the frequency product and
/// inverse transform consume in emission order), so the fusion pass can
/// cluster the whole FFT → eltwise → iFFT dataflow into one section.
fn fft_conv(
    g: &mut Graph,
    cfg: &DecoderConfig,
    tag: &str,
    variant: BaileyVariant,
    x: KernelId,
    filt: KernelId,
) -> KernelId {
    let n = cfg.fft_len();
    let d = cfg.d_model as f64;
    let b = cfg.dtype_bytes;
    let op = fft_op(variant);
    let per_fft = fft_flops(n, variant, cfg.fft_tile) * d;
    // Real input of N elements → N complex outputs (2 values each).
    let real_bytes = n as f64 * d * b;
    let cplx_bytes = 2.0 * real_bytes;

    let fft_x = g.add(
        Kernel::new(&format!("{tag}.fft_x"), op, per_fft, real_bytes, cplx_bytes)
            .with_stream(n as f64, d),
    );
    g.connect_stream(x, fft_x, cfg.act_bytes());

    let fft_k = g.add(
        Kernel::new(&format!("{tag}.fft_k"), op, per_fft, real_bytes, cplx_bytes)
            .with_stream(n as f64, d),
    );
    g.connect_stream(filt, fft_k, cfg.act_bytes());

    // Frequency-domain pointwise complex multiply: 6 FLOP per complex pair.
    let mul = g.add(
        Kernel::new(
            &format!("{tag}.freqmul"),
            OpClass::Elementwise,
            6.0 * n as f64 * d,
            2.0 * cplx_bytes,
            cplx_bytes,
        )
        .with_stream(n as f64, d),
    );
    g.connect_stream(fft_x, mul, cplx_bytes);
    g.connect_stream(fft_k, mul, cplx_bytes);

    let ifft = g.add(
        Kernel::new(&format!("{tag}.ifft"), op, per_fft, cplx_bytes, real_bytes)
            .with_stream(n as f64, d),
    );
    g.connect_stream(mul, ifft, cplx_bytes);
    ifft
}

/// Build the Hyena decoder layer under the chosen FFT variant.
///
/// Template (Fig. 3B): LN → q/k/v projections + filter generators → first
/// FFT-conv (replacing `Q·Kᵀ`) → gate with v → second FFT-conv (replacing
/// `A·V`) → output projection → residual/LN/MLP/residual.
pub fn hyena_decoder(cfg: &DecoderConfig, variant: BaileyVariant) -> Graph {
    let l = cfg.seq_len;
    let d = cfg.d_model;
    let act = cfg.act_bytes();
    let vname = match variant {
        BaileyVariant::Vector => "vector-fft",
        BaileyVariant::Gemm => "gemm-fft",
    };
    let mut g = Graph::new(&format!("hyena-decoder[{vname}] L={l} D={d}"));

    let ln1 = layer_norm(&mut g, cfg, "ln1", d);
    g.input(ln1, act);

    let q = gemm(&mut g, cfg, "proj.q", l, d, d);
    let k = gemm(&mut g, cfg, "proj.k", l, d, d);
    let v = gemm(&mut g, cfg, "proj.v", l, d, d);
    g.connect(ln1, q, act);
    g.connect(ln1, k, act);
    g.connect(ln1, v, act);

    // Implicit long-filter generation (Hyena's positional MLP), one filter
    // per conv, cheap relative to the transforms.
    let filt1 = eltwise(&mut g, cfg, "filter1", (l * d) as f64, 4.0, 1.0);
    let filt2 = eltwise(&mut g, cfg, "filter2", (l * d) as f64, 4.0, 1.0);
    g.connect(ln1, filt1, act);
    g.connect(ln1, filt2, act);

    // First conv replaces Q·Kᵀ.
    let conv1 = fft_conv(&mut g, cfg, "conv1", variant, q, filt1);

    // Gate with k (Hyena's element-wise multiplicative gating).
    let gate1 = eltwise(&mut g, cfg, "gate1", (l * d) as f64, 1.0, 2.0);
    g.connect_stream(conv1, gate1, act);
    g.connect(k, gate1, act);

    // Second conv replaces A·V.
    let conv2 = fft_conv(&mut g, cfg, "conv2", variant, gate1, filt2);

    let gate2 = eltwise(&mut g, cfg, "gate2", (l * d) as f64, 1.0, 2.0);
    g.connect_stream(conv2, gate2, act);
    g.connect(v, gate2, act);

    let out = gemm(&mut g, cfg, "proj.out", l, d, d);
    g.connect_stream(gate2, out, act);

    let last = blocks::mlp_block(&mut g, cfg, out);
    g.output(last, act);

    debug_assert!(g.validate().is_ok());
    g
}

/// Total FFT-transform FLOPs in the decoder (6 transforms × D channels) —
/// the Fig. 7 breakdown's FFT component.
///
/// **Accounting convention:** this (and every kernel this module builds)
/// charges the paper's full-complex-transform counts so Fig. 7's design
/// ratios stay exactly reproducible; the functional engine actually
/// evaluates these convolutions through the planned real-input path, whose
/// own (≈2× cheaper) accounting is [`crate::fft::fftconv_flops_rfft`].
pub fn fft_core_flops(cfg: &DecoderConfig, variant: BaileyVariant) -> f64 {
    6.0 * cfg.d_model as f64 * fft_flops(cfg.fft_len(), variant, cfg.fft_tile)
}

/// Numeric golden model for one Hyena conv module across its D channels:
/// channel `i` is the planned real-input linear convolution of `us[i]`
/// with `ks[i]`, fanned over `pool`'s worker threads (each worker reuses
/// one `fft::ConvPlan` across its chunk of channels). Bit-identical to
/// the serial per-channel loop — pooling is a scheduling transform, not a
/// numerics one.
pub fn hyena_conv_channels(
    us: &[Vec<f64>],
    ks: &[Vec<f64>],
    pool: &crate::runtime::WorkerPool,
) -> Vec<Vec<f64>> {
    crate::fft::fft_conv_linear_channels(us, ks, pool)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graphs_are_valid() {
        for v in [BaileyVariant::Vector, BaileyVariant::Gemm] {
            let g = hyena_decoder(&DecoderConfig::paper(1 << 14), v);
            assert!(g.validate().is_ok(), "{}", g.name);
        }
    }

    #[test]
    fn gemm_fft_flop_ratio_is_6_4x_on_transforms() {
        // §III-A: GEMM-FFT does ~6.4× the FLOPs of Vector-FFT at R=32.
        let cfg = DecoderConfig::paper(1 << 18);
        let r = fft_core_flops(&cfg, BaileyVariant::Gemm) / fft_core_flops(&cfg, BaileyVariant::Vector);
        assert!((r - 6.4).abs() < 0.01, "r={r}");
    }

    #[test]
    fn whole_decoder_flop_ratio_near_paper_4_19x() {
        // §III-C: "The GEMM-FFT Hyena decoder exhibits a higher FLOP count,
        // approximately 4.19× greater than the Vector-FFT variant" — the
        // Amdahl blend of 6.4× transforms with the unchanged remainder.
        let cfg = DecoderConfig::paper(1 << 20);
        let fv = hyena_decoder(&cfg, BaileyVariant::Vector).total_flops();
        let fg = hyena_decoder(&cfg, BaileyVariant::Gemm).total_flops();
        let r = fg / fv;
        assert!(r > 3.0 && r < 6.0, "whole-decoder ratio {r} out of paper band");
    }

    #[test]
    fn log_linear_scaling() {
        // Hyena total FLOPs scale ~L·log L (vs attention's L²).
        let f1 = hyena_decoder(&DecoderConfig::paper(1 << 18), BaileyVariant::Vector).total_flops();
        let f2 = hyena_decoder(&DecoderConfig::paper(1 << 20), BaileyVariant::Vector).total_flops();
        let ratio = f2 / f1;
        assert!(ratio > 4.0 && ratio < 4.6, "ratio={ratio}");
    }

    #[test]
    fn hyena_beats_attention_on_flops() {
        let cfg = DecoderConfig::paper(1 << 20);
        let hy = hyena_decoder(&cfg, BaileyVariant::Vector).total_flops();
        let at = super::super::attention::attention_decoder(&cfg).total_flops();
        // The paper's ~2000× FLOP gap at 1M (before utilization effects).
        assert!(at / hy > 500.0, "at/hy = {}", at / hy);
    }

    #[test]
    fn six_transforms_per_decoder() {
        let g = hyena_decoder(&DecoderConfig::paper(1 << 14), BaileyVariant::Vector);
        let n = g.kernels.iter().filter(|k| k.op == OpClass::VectorFft).count();
        assert_eq!(n, 6);
    }

    #[test]
    fn conv_chains_are_stream_edges() {
        // Each conv contributes 4 stream edges (x→fft, filt→fft, 2×fft→mul,
        // mul→ifft = 5) plus conv→gate; the fusion pass depends on them.
        let g = hyena_decoder(&DecoderConfig::paper(1 << 12), BaileyVariant::Vector);
        assert!(g.stream_bytes() > 0.0);
        let id = |name: &str| g.kernels.iter().position(|k| k.name == name).unwrap();
        for tag in ["conv1", "conv2"] {
            let mul = id(&format!("{tag}.freqmul"));
            assert_eq!(g.stream_predecessors(mul).len(), 2, "{tag}: both FFTs stream in");
            let ifft = id(&format!("{tag}.ifft"));
            assert_eq!(g.stream_predecessors(ifft), vec![mul]);
        }
        // Gating second operands are deliberately *not* streams (they must
        // be buffered until the conv drains).
        let gate1 = id("gate1");
        assert_eq!(g.stream_predecessors(gate1), vec![id("conv1.ifft")]);
        assert_eq!(g.predecessors(gate1).len(), 2);
    }
}
