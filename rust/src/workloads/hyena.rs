//! Hyena decoder workload graph (paper Fig. 3B): "the same structural
//! template as the attention decoder but replaces the GEMM kernel with an
//! FFT-based convolution kernel… each GEMM is replaced by three FFT
//! operations: two forward FFTs … and one inverse FFT".

use super::blocks::{self, eltwise, fft_conv, fft_flops, gemm, layer_norm};
use super::config::DecoderConfig;
use super::registry::{DecodeDemand, GoldenCheck, ShardComm, Workload};
use crate::arch::RduConfig;
use crate::fft::BaileyVariant;
use crate::graph::Graph;
use crate::runtime::ModelKind;

/// Build the Hyena decoder layer under the chosen FFT variant.
///
/// Template (Fig. 3B): LN → q/k/v projections + filter generators → first
/// FFT-conv (replacing `Q·Kᵀ`) → gate with v → second FFT-conv (replacing
/// `A·V`) → output projection → residual/LN/MLP/residual.
pub fn hyena_decoder(cfg: &DecoderConfig, variant: BaileyVariant) -> Graph {
    let l = cfg.seq_len;
    let d = cfg.d_model;
    let act = cfg.act_bytes();
    let vname = match variant {
        BaileyVariant::Vector => "vector-fft",
        BaileyVariant::Gemm => "gemm-fft",
    };
    let mut g = Graph::new(&format!("hyena-decoder[{vname}] L={l} D={d}"));

    let ln1 = layer_norm(&mut g, cfg, "ln1", d);
    g.input(ln1, act);

    let q = gemm(&mut g, cfg, "proj.q", l, d, d);
    let k = gemm(&mut g, cfg, "proj.k", l, d, d);
    let v = gemm(&mut g, cfg, "proj.v", l, d, d);
    g.connect(ln1, q, act);
    g.connect(ln1, k, act);
    g.connect(ln1, v, act);

    // Implicit long-filter generation (Hyena's positional MLP), one filter
    // per conv, cheap relative to the transforms.
    let filt1 = eltwise(&mut g, cfg, "filter1", (l * d) as f64, 4.0, 1.0);
    let filt2 = eltwise(&mut g, cfg, "filter2", (l * d) as f64, 4.0, 1.0);
    g.connect(ln1, filt1, act);
    g.connect(ln1, filt2, act);

    // First conv replaces Q·Kᵀ.
    let conv1 = fft_conv(&mut g, cfg, "conv1", variant, q, filt1);

    // Gate with k (Hyena's element-wise multiplicative gating).
    let gate1 = eltwise(&mut g, cfg, "gate1", (l * d) as f64, 1.0, 2.0);
    g.connect_stream(conv1, gate1, act);
    g.connect(k, gate1, act);

    // Second conv replaces A·V.
    let conv2 = fft_conv(&mut g, cfg, "conv2", variant, gate1, filt2);

    let gate2 = eltwise(&mut g, cfg, "gate2", (l * d) as f64, 1.0, 2.0);
    g.connect_stream(conv2, gate2, act);
    g.connect(v, gate2, act);

    let out = gemm(&mut g, cfg, "proj.out", l, d, d);
    g.connect_stream(gate2, out, act);

    let last = blocks::mlp_block(&mut g, cfg, out);
    g.output(last, act);

    debug_assert!(g.validate().is_ok());
    g
}

/// Total FFT-transform FLOPs in the decoder (6 transforms × D channels) —
/// the Fig. 7 breakdown's FFT component.
///
/// **Accounting convention:** this (and every kernel this module builds)
/// charges the paper's full-complex-transform counts so Fig. 7's design
/// ratios stay exactly reproducible; the functional engine actually
/// evaluates these convolutions through the planned real-input path, whose
/// own (≈2× cheaper) accounting is [`crate::fft::fftconv_flops_rfft`].
pub fn fft_core_flops(cfg: &DecoderConfig, variant: BaileyVariant) -> f64 {
    6.0 * cfg.d_model as f64 * fft_flops(cfg.fft_len(), variant, cfg.fft_tile)
}

/// Numeric golden model for one Hyena conv module across its D channels:
/// channel `i` is the planned real-input linear convolution of `us[i]`
/// with `ks[i]`, fanned over `pool`'s worker threads with self-scheduling
/// claim order (each worker clones one `fft::ConvPlan` from the master
/// cache and reuses it across every channel it claims). Bit-identical to
/// the serial per-channel loop — pooling is a scheduling transform, not a
/// numerics one.
pub fn hyena_conv_channels(
    us: &[Vec<f64>],
    ks: &[Vec<f64>],
    pool: &crate::runtime::WorkerPool,
) -> Vec<Vec<f64>> {
    crate::fft::fft_conv_linear_channels(us, ks, pool)
}

/// The registered Hyena workload (see [`mod@crate::workloads::registry`]):
/// the Vector-FFT design point — the paper's best Hyena mapping.
pub struct HyenaWorkload;

impl Workload for HyenaWorkload {
    fn name(&self) -> &'static str {
        "hyena"
    }

    fn describe(&self) -> &'static str {
        "Hyena: FFT-based long convolutions with data-dependent filters"
    }

    fn family(&self) -> ModelKind {
        ModelKind::Hyena
    }

    fn build_graph(&self, dc: &DecoderConfig) -> Graph {
        hyena_decoder(dc, BaileyVariant::Vector)
    }

    fn extended_config(&self) -> RduConfig {
        RduConfig::fft_mode()
    }

    /// Three gating projections + the R-tap filter contribution per
    /// channel; the FFT filter/prefix caches (R × d complex each) are read
    /// and updated once per step.
    fn decode_demand(&self, dc: &DecoderConfig) -> DecodeDemand {
        let d = dc.d_model as f64;
        let r = dc.fft_tile as f64;
        DecodeDemand {
            mix_flops: 2.0 * 3.0 * d * d + 4.0 * r * d,
            state_bytes: 2.0 * 2.0 * r * d * 4.0,
        }
    }

    /// One all-to-all transpose per transform: two convolutions × (two
    /// forward + one inverse) = six per decoder layer.
    fn shard_comm(&self, _dc: &DecoderConfig) -> ShardComm {
        ShardComm::AllToAllTranspose { transforms: 6.0 }
    }

    fn shard_local_graph(&self, dc: &DecoderConfig, chips: usize) -> Graph {
        let local = DecoderConfig { seq_len: dc.seq_len / chips, ..*dc };
        let mut g = hyena_decoder(&local, BaileyVariant::Vector);
        super::registry::scale_distributed_fft_flops(&mut g, dc, &local);
        g
    }

    /// Planned real-input conv engine vs the pre-plan complex transform
    /// path on a deliberately non-power-of-two length.
    fn golden_check(&self, seed: u64) -> Option<GoldenCheck> {
        let mut rng = crate::util::XorShift::new(seed);
        let l = 1000;
        let u = rng.vec(l, -1.0, 1.0);
        let k = rng.vec(l, -1.0, 1.0);
        let got = crate::fft::fft_conv_linear(&u, &k);
        let want = crate::fft::fft_conv_linear_naive(&u, &k);
        Some(GoldenCheck {
            reference: "fft::fft_conv_linear_naive",
            max_abs_diff: crate::util::max_abs_diff(&got, &want),
            bit_identical: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpClass;

    #[test]
    fn graphs_are_valid() {
        for v in [BaileyVariant::Vector, BaileyVariant::Gemm] {
            let g = hyena_decoder(&DecoderConfig::paper(1 << 14), v);
            assert!(g.validate().is_ok(), "{}", g.name);
        }
    }

    #[test]
    fn gemm_fft_flop_ratio_is_6_4x_on_transforms() {
        // §III-A: GEMM-FFT does ~6.4× the FLOPs of Vector-FFT at R=32.
        let cfg = DecoderConfig::paper(1 << 18);
        let r = fft_core_flops(&cfg, BaileyVariant::Gemm) / fft_core_flops(&cfg, BaileyVariant::Vector);
        assert!((r - 6.4).abs() < 0.01, "r={r}");
    }

    #[test]
    fn whole_decoder_flop_ratio_near_paper_4_19x() {
        // §III-C: "The GEMM-FFT Hyena decoder exhibits a higher FLOP count,
        // approximately 4.19× greater than the Vector-FFT variant" — the
        // Amdahl blend of 6.4× transforms with the unchanged remainder.
        let cfg = DecoderConfig::paper(1 << 20);
        let fv = hyena_decoder(&cfg, BaileyVariant::Vector).total_flops();
        let fg = hyena_decoder(&cfg, BaileyVariant::Gemm).total_flops();
        let r = fg / fv;
        assert!(r > 3.0 && r < 6.0, "whole-decoder ratio {r} out of paper band");
    }

    #[test]
    fn log_linear_scaling() {
        // Hyena total FLOPs scale ~L·log L (vs attention's L²).
        let f1 = hyena_decoder(&DecoderConfig::paper(1 << 18), BaileyVariant::Vector).total_flops();
        let f2 = hyena_decoder(&DecoderConfig::paper(1 << 20), BaileyVariant::Vector).total_flops();
        let ratio = f2 / f1;
        assert!(ratio > 4.0 && ratio < 4.6, "ratio={ratio}");
    }

    #[test]
    fn hyena_beats_attention_on_flops() {
        let cfg = DecoderConfig::paper(1 << 20);
        let hy = hyena_decoder(&cfg, BaileyVariant::Vector).total_flops();
        let at = super::super::attention::attention_decoder(&cfg).total_flops();
        // The paper's ~2000× FLOP gap at 1M (before utilization effects).
        assert!(at / hy > 500.0, "at/hy = {}", at / hy);
    }

    #[test]
    fn six_transforms_per_decoder() {
        let g = hyena_decoder(&DecoderConfig::paper(1 << 14), BaileyVariant::Vector);
        let n = g.kernels.iter().filter(|k| k.op == OpClass::VectorFft).count();
        assert_eq!(n, 6);
    }

    #[test]
    fn conv_chains_are_stream_edges() {
        // Each conv contributes 4 stream edges (x→fft, filt→fft, 2×fft→mul,
        // mul→ifft = 5) plus conv→gate; the fusion pass depends on them.
        let g = hyena_decoder(&DecoderConfig::paper(1 << 12), BaileyVariant::Vector);
        assert!(g.stream_bytes() > 0.0);
        let id = |name: &str| g.kernels.iter().position(|k| k.name == name).unwrap();
        for tag in ["conv1", "conv2"] {
            let mul = id(&format!("{tag}.freqmul"));
            assert_eq!(g.stream_predecessors(mul).len(), 2, "{tag}: both FFTs stream in");
            let ifft = id(&format!("{tag}.ifft"));
            assert_eq!(g.stream_predecessors(ifft), vec![mul]);
        }
        // Gating second operands are deliberately *not* streams (they must
        // be buffered until the conv drains).
        let gate1 = id("gate1");
        assert_eq!(g.stream_predecessors(gate1), vec![id("conv1.ifft")]);
        assert_eq!(g.predecessors(gate1).len(), 2);
    }
}
