//! **S4 / long-conv** decoder workload: a *linear time-invariant* diagonal
//! state-space layer (S4D lineage) whose token mixer is one length-L FFT
//! convolution against a kernel **materialized from the SSM parameters**.
//!
//! Where Hyena generates its filters from the input (data-dependent), S4's
//! kernel is fixed per layer: the impulse response of a diagonal SSM,
//!
//! ```text
//! k[t] = Σ_n  c[n] · λ[n]^t          t = 0 … L−1   (per channel)
//! y    = causal_conv(u, k)           via FFT, zero-padded to 2L
//! ```
//!
//! so the graph reads the kernel parameters straight from DRAM (a graph
//! input, not a projection of the activations) and spends one FFT-conv —
//! three transforms per layer against Hyena's six. The convolution reuses
//! the planned real-input engine ([`crate::fft::plan::RealFftPlan`] via
//! [`crate::fft::fft_conv_linear`]) and fans independent channels over the
//! [`crate::runtime::pool::WorkerPool`] ([`s4_conv_channels`]), so the hot
//! path is shared with Hyena bit for bit.
//!
//! Golden contract: [`s4_conv`] (planned rfft path) matches the pre-plan
//! naive complex path [`crate::fft::fft_conv_linear_naive`] and the direct
//! O(L²) convolution ≤ 1e-9 on non-power-of-two lengths (observed ~1e-12;
//! asserted by the integration tests and [`Workload::golden_check`]).

use super::blocks::{self, eltwise, fft_conv, gemm, layer_norm};
use super::config::DecoderConfig;
use super::registry::{DecodeDemand, GoldenCheck, ShardComm, Workload};
use crate::arch::RduConfig;
use crate::fft::BaileyVariant;
use crate::graph::{Graph, Kernel, OpClass};
use crate::runtime::{ModelKind, WorkerPool};
use crate::util::XorShift;

/// Materialize one channel's length-`l` S4D kernel from its `N` diagonal
/// modes: `k[t] = Σ_n c[n]·λ[n]^t`, powers built by one cumulative product
/// per mode (no `powi` re-derivation — the same no-recomputation discipline
/// as the FFT plan tables). Routes through [`s4_kernel_simd`] (explicit
/// lanes where the host has them, [`s4_kernel_chunked`] otherwise); the
/// mode-at-a-time loop survives as [`s4_kernel_scalar`], the oracle.
pub fn s4_kernel(lambda: &[f64], c: &[f64], l: usize) -> Vec<f64> {
    s4_kernel_simd(lambda, c, l)
}

/// Scalar oracle for [`s4_kernel_chunked`]: one mode at a time, one
/// cumulative power product per mode.
pub fn s4_kernel_scalar(lambda: &[f64], c: &[f64], l: usize) -> Vec<f64> {
    assert_eq!(lambda.len(), c.len(), "s4_kernel: lambda/c length mismatch");
    let mut k = vec![0.0; l];
    for (&cn, &ln) in c.iter().zip(lambda) {
        let mut p = 1.0;
        for kt in k.iter_mut() {
            *kt += cn * p;
            p *= ln;
        }
    }
    k
}

/// Kernel materialization with [`crate::scan::LANES`]-wide mode blocks:
/// four modes' power accumulators advance together per position (each
/// lane's `p *= λ` is the scalar update verbatim), and their four
/// contributions land in `k[t]` as one pairwise-reduced sum. The pairwise
/// reduction **reassociates** the mode sum relative to the scalar
/// mode-at-a-time loop, so this path is not bit-identical — it agrees with
/// [`s4_kernel_scalar`] to ≤ 1e-9 (the property harness pins it around
/// 1e-15 for stable `|λ| < 1` modes), the same documented budget as the
/// FFT factorization changes.
pub fn s4_kernel_chunked(lambda: &[f64], c: &[f64], l: usize) -> Vec<f64> {
    assert_eq!(lambda.len(), c.len(), "s4_kernel: lambda/c length mismatch");
    const LANES: usize = crate::scan::LANES;
    let mut k = vec![0.0; l];
    let modes = lambda.len();
    let blocks = modes / LANES;
    for blk in 0..blocks {
        let m0 = blk * LANES;
        let cb: [f64; LANES] = c[m0..m0 + LANES].try_into().unwrap();
        let lb: [f64; LANES] = lambda[m0..m0 + LANES].try_into().unwrap();
        let mut p = [1.0f64; LANES];
        for kt in k.iter_mut() {
            *kt += (cb[0] * p[0] + cb[1] * p[1]) + (cb[2] * p[2] + cb[3] * p[3]);
            for l in 0..LANES {
                p[l] *= lb[l];
            }
        }
    }
    for m in blocks * LANES..modes {
        let (cn, ln) = (c[m], lambda[m]);
        let mut p = 1.0;
        for kt in k.iter_mut() {
            *kt += cn * p;
            p *= ln;
        }
    }
    k
}

/// [`s4_kernel_chunked`] with explicit lanes (`crate::scan::simd` rules:
/// runtime-detected AVX/NEON, separate mul/add, chunked fallback). The
/// pairwise mode reduction keeps the chunked association *exactly* —
/// `(t0+t1) + (t2+t3)` — so this path is **bit-identical to the chunked
/// twin** (asserted in tests) and carries the same documented ≤ 1e-9
/// reassociation budget against [`s4_kernel_scalar`].
pub fn s4_kernel_simd(lambda: &[f64], c: &[f64], l: usize) -> Vec<f64> {
    assert_eq!(lambda.len(), c.len(), "s4_kernel: lambda/c length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx") {
            let mut k = vec![0.0; l];
            // SAFETY: AVX presence checked above.
            unsafe { s4_kernel_avx(lambda, c, &mut k) };
            return k;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            let mut k = vec![0.0; l];
            // SAFETY: NEON presence checked above.
            unsafe { s4_kernel_neon(lambda, c, &mut k) };
            return k;
        }
    }
    s4_kernel_chunked(lambda, c, l)
}

/// Scalar tail shared by the lane backends: modes past the last full
/// 4-block, identical to the chunked tail.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn s4_kernel_tail(lambda: &[f64], c: &[f64], from: usize, k: &mut [f64]) {
    for m in from..lambda.len() {
        let (cn, ln) = (c[m], lambda[m]);
        let mut p = 1.0;
        for kt in k.iter_mut() {
            *kt += cn * p;
            p *= ln;
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn s4_kernel_avx(lambda: &[f64], c: &[f64], k: &mut [f64]) {
    use core::arch::x86_64::*;
    const LANES: usize = crate::scan::LANES;
    let modes = lambda.len();
    let blocks = modes / LANES;
    for blk in 0..blocks {
        let m0 = blk * LANES;
        let cv = _mm256_loadu_pd(c.as_ptr().add(m0));
        let lv = _mm256_loadu_pd(lambda.as_ptr().add(m0));
        let mut pv = _mm256_set1_pd(1.0);
        for kt in k.iter_mut() {
            let t = _mm256_mul_pd(cv, pv);
            // Pairwise exactly as chunked: (t0+t1) + (t2+t3).
            let lo = _mm256_castpd256_pd128(t);
            let hi = _mm256_extractf128_pd::<1>(t);
            let pair = _mm_hadd_pd(lo, hi); // [t0+t1, t2+t3]
            let sum = _mm_cvtsd_f64(pair) + _mm_cvtsd_f64(_mm_unpackhi_pd(pair, pair));
            *kt += sum;
            pv = _mm256_mul_pd(pv, lv);
        }
    }
    s4_kernel_tail(lambda, c, blocks * LANES, k);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn s4_kernel_neon(lambda: &[f64], c: &[f64], k: &mut [f64]) {
    use core::arch::aarch64::*;
    const LANES: usize = crate::scan::LANES;
    let modes = lambda.len();
    let blocks = modes / LANES;
    for blk in 0..blocks {
        let m0 = blk * LANES;
        let c01 = vld1q_f64(c.as_ptr().add(m0));
        let c23 = vld1q_f64(c.as_ptr().add(m0 + 2));
        let l01 = vld1q_f64(lambda.as_ptr().add(m0));
        let l23 = vld1q_f64(lambda.as_ptr().add(m0 + 2));
        let mut p01 = vdupq_n_f64(1.0);
        let mut p23 = vdupq_n_f64(1.0);
        for kt in k.iter_mut() {
            let t01 = vmulq_f64(c01, p01);
            let t23 = vmulq_f64(c23, p23);
            // Pairwise exactly as chunked: (t0+t1) + (t2+t3).
            let pair = vpaddq_f64(t01, t23); // [t0+t1, t2+t3]
            let sum = vgetq_lane_f64::<0>(pair) + vgetq_lane_f64::<1>(pair);
            *kt += sum;
            p01 = vmulq_f64(p01, l01);
            p23 = vmulq_f64(p23, l23);
        }
    }
    s4_kernel_tail(lambda, c, blocks * LANES, k);
}

/// One channel's S4 token mixer: materialize the kernel, then the causal
/// FFT convolution through the planned real-input engine.
pub fn s4_conv(u: &[f64], lambda: &[f64], c: &[f64]) -> Vec<f64> {
    let k = s4_kernel(lambda, c, u.len());
    crate::fft::fft_conv_linear(u, &k)
}

/// [`s4_conv`] through the pre-plan naive complex transform path — the
/// independent oracle the golden contract checks against.
pub fn s4_conv_naive(u: &[f64], lambda: &[f64], c: &[f64]) -> Vec<f64> {
    let k = s4_kernel(lambda, c, u.len());
    crate::fft::fft_conv_linear_naive(u, &k)
}

/// Per-channel S4 convolutions fanned over the worker pool: channel `i`
/// convolves `us[i]` with the kernel of `(lambdas[i], cs[i])`. Kernel
/// materialization and convolution both run inside the worker; workers
/// self-schedule channels (`map_stealing`) and each one's cached
/// [`crate::fft::ConvPlan`] (a master-cache clone) serves every channel it
/// claims. **Bit-identical** to the serial per-channel loop (per-channel
/// independence; each channel's value depends only on its own inputs).
pub fn s4_conv_channels(
    us: &[Vec<f64>],
    lambdas: &[Vec<f64>],
    cs: &[Vec<f64>],
    pool: &WorkerPool,
) -> Vec<Vec<f64>> {
    assert_eq!(us.len(), lambdas.len(), "s4_conv_channels: channel count mismatch");
    assert_eq!(us.len(), cs.len(), "s4_conv_channels: channel count mismatch");
    pool.map_stealing(us.len(), |i| s4_conv(&us[i], &lambdas[i], &cs[i]))
}

/// FLOPs of materializing all `D` channel kernels: one MAC plus one power
/// update per (mode, position, channel) → `3·N·L·D`.
pub fn s4_kernel_flops(cfg: &DecoderConfig) -> f64 {
    3.0 * cfg.state_dim.max(1) as f64 * cfg.seq_len as f64 * cfg.d_model as f64
}

/// Build the S4 long-conv decoder layer.
///
/// Template: LN → u/v projections → kernel materialization (from DRAM-
/// resident SSM parameters — LTI, so *not* fed by the activations) →
/// FFT-conv (replacing the token mixer) → gate with v → output projection
/// → residual/LN/MLP/residual. One conv per layer: three transforms where
/// Hyena pays six.
pub fn s4_decoder(cfg: &DecoderConfig) -> Graph {
    let l = cfg.seq_len;
    let d = cfg.d_model;
    let n = cfg.state_dim.max(1);
    let b = cfg.dtype_bytes;
    let act = cfg.act_bytes();
    let mut g = Graph::new(&format!("s4-decoder[N={n}] L={l} D={d}"));

    let ln1 = layer_norm(&mut g, cfg, "ln1", d);
    g.input(ln1, act);

    let u = gemm(&mut g, cfg, "proj.u", l, d, d);
    let v = gemm(&mut g, cfg, "proj.v", l, d, d);
    g.connect(ln1, u, act);
    g.connect(ln1, v, act);

    // Kernel materialization: k[t] = Σ_n c[n]·λ[n]^t per channel. The
    // (λ, c) parameter pairs are layer weights read from DRAM — the LTI
    // signature that distinguishes S4 from Hyena's input-generated filters.
    let kgen = g.add(
        Kernel::new(
            "s4_kernel",
            OpClass::Elementwise,
            s4_kernel_flops(cfg),
            2.0 * n as f64 * d as f64 * b,
            l as f64 * d as f64 * b,
        )
        .with_weights(2.0 * n as f64 * d as f64 * b)
        .with_stream(l as f64, d as f64),
    );
    g.input(kgen, 2.0 * n as f64 * d as f64 * b);

    // The long convolution (the single token mixer).
    let conv = fft_conv(&mut g, cfg, "conv", BaileyVariant::Vector, u, kgen);

    // Gate with the v branch (GLU-style multiplicative gating).
    let gate = eltwise(&mut g, cfg, "gate", (l * d) as f64, 1.0, 2.0);
    g.connect_stream(conv, gate, act);
    g.connect(v, gate, act);

    let out = gemm(&mut g, cfg, "proj.out", l, d, d);
    g.connect_stream(gate, out, act);

    let last = blocks::mlp_block(&mut g, cfg, out);
    g.output(last, act);

    debug_assert!(g.validate().is_ok());
    g
}

/// The registered S4 long-conv workload (see [`mod@crate::workloads::registry`]).
pub struct S4Workload;

impl Workload for S4Workload {
    fn name(&self) -> &'static str {
        "s4"
    }

    fn describe(&self) -> &'static str {
        "S4: diagonal-SSM kernel materialization + length-L FFT convolution"
    }

    /// S4 rides the Hyena serving family: the same per-session FFT-cache
    /// state shapes and artifacts.
    fn family(&self) -> ModelKind {
        ModelKind::Hyena
    }

    fn build_graph(&self, dc: &DecoderConfig) -> Graph {
        s4_decoder(dc)
    }

    fn extended_config(&self) -> RduConfig {
        RduConfig::fft_mode()
    }

    /// Two gating projections + output projection, plus the diagonal state
    /// update `x = λ x + b·u` and readout `y = Σ c·x` over N modes per
    /// channel; N × d states read and written once per step (f32).
    fn decode_demand(&self, dc: &DecoderConfig) -> DecodeDemand {
        let d = dc.d_model as f64;
        let n = dc.state_dim.max(1) as f64;
        DecodeDemand {
            mix_flops: 2.0 * 3.0 * d * d + 6.0 * n * d,
            state_bytes: 2.0 * n * d * 4.0,
        }
    }

    /// One conv per layer: two forward + one inverse transform, each with
    /// its all-to-all transpose — half of Hyena's exchange traffic.
    fn shard_comm(&self, _dc: &DecoderConfig) -> ShardComm {
        ShardComm::AllToAllTranspose { transforms: 3.0 }
    }

    fn shard_local_graph(&self, dc: &DecoderConfig, chips: usize) -> Graph {
        let local = DecoderConfig { seq_len: dc.seq_len / chips, ..*dc };
        let mut g = s4_decoder(&local);
        super::registry::scale_distributed_fft_flops(&mut g, dc, &local);
        g
    }

    /// Planned-rfft S4 conv vs the naive complex path on a non-pow2 length.
    fn golden_check(&self, seed: u64) -> Option<GoldenCheck> {
        let mut rng = XorShift::new(seed);
        let l = 1000;
        let n_modes = 4;
        let u = rng.vec(l, -1.0, 1.0);
        let lambda: Vec<f64> = (0..n_modes).map(|_| rng.uniform(0.5, 0.99)).collect();
        let c = rng.vec(n_modes, -1.0, 1.0);
        let got = s4_conv(&u, &lambda, &c);
        let want = s4_conv_naive(&u, &lambda, &c);
        Some(GoldenCheck {
            reference: "fft::fft_conv_linear_naive",
            max_abs_diff: crate::util::max_abs_diff(&got, &want),
            bit_identical: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::conv::direct_conv_linear;
    use crate::util::max_abs_diff;

    #[test]
    fn kernel_is_the_mode_sum_of_powers() {
        let k = s4_kernel(&[0.5, 0.25], &[1.0, 2.0], 4);
        // t=0: 1+2; t=1: 0.5+0.5; t=2: 0.25+0.125; t=3: 0.125+0.03125.
        assert_eq!(k, vec![3.0, 1.0, 0.375, 0.15625]);
    }

    #[test]
    fn chunked_kernel_matches_scalar_oracle() {
        // Mode-block reassociation budget: ≤1e-9 documented, ~1e-15 typical.
        let mut rng = XorShift::new(94);
        for modes in [1usize, 3, 4, 5, 8, 11] {
            for l in [1usize, 17, 500] {
                let lambda: Vec<f64> = (0..modes).map(|_| rng.uniform(-0.99, 0.99)).collect();
                let c = rng.vec(modes, -1.0, 1.0);
                let d = max_abs_diff(
                    &s4_kernel_chunked(&lambda, &c, l),
                    &s4_kernel_scalar(&lambda, &c, l),
                );
                assert!(d < 1e-9, "modes={modes} l={l}: |d|={d}");
            }
        }
    }

    #[test]
    fn simd_kernel_is_bit_identical_to_chunked() {
        // The lane backends keep the chunked pairwise association exactly,
        // so simd == chunked bit for bit (and both share the ≤1e-9 budget
        // against the scalar oracle).
        let mut rng = XorShift::new(95);
        for modes in [1usize, 3, 4, 5, 8, 11] {
            for l in [1usize, 17, 500] {
                let lambda: Vec<f64> = (0..modes).map(|_| rng.uniform(-0.99, 0.99)).collect();
                let c = rng.vec(modes, -1.0, 1.0);
                assert_eq!(
                    s4_kernel_simd(&lambda, &c, l),
                    s4_kernel_chunked(&lambda, &c, l),
                    "modes={modes} l={l}"
                );
                let d = max_abs_diff(
                    &s4_kernel_simd(&lambda, &c, l),
                    &s4_kernel_scalar(&lambda, &c, l),
                );
                assert!(d < 1e-9, "modes={modes} l={l}: |d|={d}");
            }
        }
    }

    #[test]
    fn conv_matches_direct_oracle_non_pow2() {
        let mut rng = XorShift::new(91);
        for l in [100usize, 777, 1000] {
            let u = rng.vec(l, -1.0, 1.0);
            let lambda: Vec<f64> = (0..4).map(|_| rng.uniform(0.5, 0.99)).collect();
            let c = rng.vec(4, -1.0, 1.0);
            let k = s4_kernel(&lambda, &c, l);
            let d = max_abs_diff(&s4_conv(&u, &lambda, &c), &direct_conv_linear(&u, &k));
            assert!(d < 1e-9, "L={l}: |d|={d}");
        }
    }

    #[test]
    fn planned_matches_naive_path() {
        let mut rng = XorShift::new(92);
        let l = 1000; // non-pow2: pads to 2048 internally
        let u = rng.vec(l, -1.0, 1.0);
        let lambda: Vec<f64> = (0..8).map(|_| rng.uniform(0.5, 0.99)).collect();
        let c = rng.vec(8, -1.0, 1.0);
        let d = max_abs_diff(&s4_conv(&u, &lambda, &c), &s4_conv_naive(&u, &lambda, &c));
        assert!(d < 1e-9, "|d|={d}");
    }

    #[test]
    fn pooled_channels_bit_identical_to_serial() {
        let mut rng = XorShift::new(93);
        let ch = 8;
        let l = 500;
        let us: Vec<Vec<f64>> = (0..ch).map(|_| rng.vec(l, -1.0, 1.0)).collect();
        let lambdas: Vec<Vec<f64>> =
            (0..ch).map(|_| (0..4).map(|_| rng.uniform(0.5, 0.99)).collect()).collect();
        let cs: Vec<Vec<f64>> = (0..ch).map(|_| rng.vec(4, -1.0, 1.0)).collect();
        let serial: Vec<Vec<f64>> = (0..ch).map(|i| s4_conv(&us[i], &lambdas[i], &cs[i])).collect();
        let pooled = s4_conv_channels(&us, &lambdas, &cs, &WorkerPool::new(3));
        assert_eq!(pooled, serial, "pooling must not change a single bit");
    }

    #[test]
    fn graph_is_valid_with_three_transforms() {
        let g = s4_decoder(&DecoderConfig::paper(1 << 14));
        assert!(g.validate().is_ok(), "{}", g.name);
        let n = g.kernels.iter().filter(|k| k.op == OpClass::VectorFft).count();
        assert_eq!(n, 3, "one conv = two forward FFTs + one inverse");
    }

    #[test]
    fn kernel_generator_is_a_graph_input_not_a_projection() {
        // LTI: the kernel comes from DRAM-resident parameters, so s4_kernel
        // must have an external input edge and no activation predecessors.
        let g = s4_decoder(&DecoderConfig::paper(1 << 12));
        let kgen = g.kernels.iter().position(|k| k.name == "s4_kernel").unwrap();
        assert!(g.predecessors(kgen).is_empty(), "kernel gen is input-independent");
        assert!(g.edges.iter().any(|e| e.src.is_none() && e.dst == Some(kgen)));
    }

    #[test]
    fn conv_chain_is_streamed_for_fusion() {
        let g = s4_decoder(&DecoderConfig::paper(1 << 12));
        let id = |name: &str| g.kernels.iter().position(|k| k.name == name).unwrap();
        assert_eq!(g.stream_predecessors(id("conv.freqmul")).len(), 2);
        assert_eq!(g.stream_predecessors(id("conv.ifft")), vec![id("conv.freqmul")]);
        assert_eq!(g.stream_predecessors(id("gate")), vec![id("conv.ifft")]);
    }

    #[test]
    fn s4_is_cheaper_than_hyena_per_layer() {
        // One conv vs two: the transform share halves.
        let dc = DecoderConfig::paper(1 << 18);
        let s4 = s4_decoder(&dc).total_flops();
        let hy = super::super::hyena::hyena_decoder(&dc, BaileyVariant::Vector).total_flops();
        assert!(s4 < hy, "s4={s4} hyena={hy}");
    }

    #[test]
    fn log_linear_scaling() {
        let f1 = s4_decoder(&DecoderConfig::paper(1 << 18)).total_flops();
        let f2 = s4_decoder(&DecoderConfig::paper(1 << 20)).total_flops();
        let ratio = f2 / f1;
        assert!(ratio > 4.0 && ratio < 4.6, "ratio={ratio}");
    }
}
