//! Mamba decoder workload graph (paper Fig. 3C): a selective state-space
//! layer whose core is an exclusive scan applying the recurrence
//! `h[t] = a[t]·h[t−1] + b[t]` across the sequence (§II-B, §IV-A).

use super::blocks::{self, eltwise, gemm, layer_norm};
use super::config::DecoderConfig;
use super::registry::{DecodeDemand, GoldenCheck, ShardComm, Workload};
use crate::arch::RduConfig;
use crate::graph::{Graph, Kernel, OpClass};
use crate::runtime::ModelKind;

/// Which scan algorithm the decoder's core uses (paper Fig. 11 designs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScanVariant {
    /// The sequential C-scan: one element at a time (§IV-A).
    CScan,
    /// Parallel scan (Hillis–Steele / Blelloch, tiled per §IV-A).
    Parallel,
}

impl ScanVariant {
    pub fn label(self) -> &'static str {
        match self {
            ScanVariant::CScan => "c-scan",
            ScanVariant::Parallel => "parallel-scan",
        }
    }
}

/// FLOPs of the selective scan over `L` positions, `C = d_inner·N` state
/// channels:
///
/// * serial — `2` FLOP per element-update (`a·h + b`) → `2·L·C`;
/// * parallel — the Blelloch lift on `(a, b)` pairs costs 3 FLOP per
///   combine (`a₂·a₁`, `a₂·b₁ + b₂`) over `2·L` combines → `6·L·C`.
pub fn scan_flops(cfg: &DecoderConfig, variant: ScanVariant) -> f64 {
    let l = cfg.seq_len as f64;
    let c = (cfg.d_inner() * cfg.state_dim) as f64;
    match variant {
        ScanVariant::CScan => 2.0 * l * c,
        ScanVariant::Parallel => 6.0 * l * c,
    }
}

/// Build the Mamba decoder layer under the chosen scan variant.
///
/// Template: LN → input projection (x, z branches) → short depthwise conv +
/// SiLU → SSM parameter projections (x_proj, dt_proj) → discretization →
/// **selective scan** → output contraction `y = C·h` → gate with z →
/// output projection → residual/LN/MLP/residual.
pub fn mamba_decoder(cfg: &DecoderConfig, variant: ScanVariant) -> Graph {
    let l = cfg.seq_len;
    let d = cfg.d_model;
    let di = cfg.d_inner();
    let n = cfg.state_dim;
    let b = cfg.dtype_bytes;
    let act = cfg.act_bytes();
    let act_inner = l as f64 * di as f64 * b;
    let dt_rank = (d / 16).max(1);

    let mut g = Graph::new(&format!("mamba-decoder[{}] L={l} D={d}", variant.label()));

    let ln1 = layer_norm(&mut g, cfg, "ln1", d);
    g.input(ln1, act);

    // Input projection produces both the x branch and the z gate branch.
    let in_proj = gemm(&mut g, cfg, "in_proj", l, 2 * di, d);
    g.connect_stream(ln1, in_proj, act);

    // Short depthwise causal conv (kernel width 4) + SiLU on the x branch.
    let conv1d = eltwise(&mut g, cfg, "conv1d", (l * di) as f64, 8.0, 1.0);
    g.connect_stream(in_proj, conv1d, act_inner);
    let silu = eltwise(&mut g, cfg, "silu.x", (l * di) as f64, 4.0, 1.0);
    g.connect_stream(conv1d, silu, act_inner);

    // Data-dependent SSM parameters: B, C, Δ (the "selective" part).
    let x_proj = gemm(&mut g, cfg, "x_proj", l, dt_rank + 2 * n, di);
    g.connect_stream(silu, x_proj, act_inner);
    let dt_proj = gemm(&mut g, cfg, "dt_proj", l, di, dt_rank);
    g.connect_stream(x_proj, dt_proj, l as f64 * dt_rank as f64 * b);

    // Discretization: ā = exp(Δ·A), b̄ = Δ·B·x per (position, channel,
    // state) ≈ 4 FLOP each.
    let disc = g.add(
        Kernel::new(
            "discretize",
            OpClass::Elementwise,
            4.0 * (l * di * n) as f64,
            act_inner + l as f64 * (2 * n) as f64 * b,
            2.0 * (l * di * n) as f64 * b,
        )
        .with_stream(l as f64, (di * n) as f64),
    );
    g.connect_stream(dt_proj, disc, act_inner);
    g.connect(x_proj, disc, l as f64 * (2 * n) as f64 * b);

    // The selective scan: h[t] = ā[t]·h[t−1] + b̄[t] over L positions for
    // every (channel, state) pair.
    let scan_op = match variant {
        ScanVariant::CScan => OpClass::ScanSerial,
        ScanVariant::Parallel => OpClass::ScanParallel,
    };
    let scan_bytes = 2.0 * (l * di * n) as f64 * b;
    let scan = g.add(
        Kernel::new("selective_scan", scan_op, scan_flops(cfg, variant), scan_bytes, scan_bytes / 2.0)
            .with_stream(l as f64, (di * n) as f64),
    );
    g.connect_stream(disc, scan, scan_bytes);

    // Output contraction y[t,c] = Σ_n C[t,n]·h[t,c,n].
    let contract = g.add(
        Kernel::new(
            "c_contract",
            OpClass::Elementwise,
            2.0 * (l * di * n) as f64,
            scan_bytes / 2.0 + l as f64 * n as f64 * b,
            act_inner,
        )
        .with_stream(l as f64, di as f64),
    );
    g.connect_stream(scan, contract, scan_bytes / 2.0);
    g.connect(x_proj, contract, l as f64 * n as f64 * b);

    // Gate with the z branch (SiLU(z) ⊙ y).
    let gate = eltwise(&mut g, cfg, "gate.z", (l * di) as f64, 5.0, 2.0);
    g.connect_stream(contract, gate, act_inner);
    g.connect(in_proj, gate, act_inner);

    let out_proj = gemm(&mut g, cfg, "out_proj", l, d, di);
    g.connect_stream(gate, out_proj, act_inner);

    let last = blocks::mlp_block(&mut g, cfg, out_proj);
    g.output(last, act);

    debug_assert!(g.validate().is_ok());
    g
}

/// The registered Mamba (selective-scan) workload (see
/// [`mod@crate::workloads::registry`]): the parallel-scan design point — the
/// paper's best Mamba mapping.
pub struct MambaWorkload;

impl Workload for MambaWorkload {
    fn name(&self) -> &'static str {
        "mamba"
    }

    fn describe(&self) -> &'static str {
        "Mamba: selective scan (lifted first-order linear recurrence)"
    }

    fn family(&self) -> ModelKind {
        ModelKind::Mamba
    }

    fn build_graph(&self, dc: &DecoderConfig) -> Graph {
        mamba_decoder(dc, ScanVariant::Parallel)
    }

    fn extended_config(&self) -> RduConfig {
        RduConfig::hs_scan_mode()
    }

    /// In/out projections (d → 2·d_inner, d_inner → d) + the selective
    /// scan update `h = Ā h + B̄ x` and readout `y = C h` over `N × d_inner`
    /// state; state is read and written once per step (f32).
    fn decode_demand(&self, dc: &DecoderConfig) -> DecodeDemand {
        let d = dc.d_model as f64;
        let di = dc.d_inner() as f64;
        let n = dc.state_dim.max(1) as f64;
        DecodeDemand {
            mix_flops: 2.0 * (d * 2.0 * di + di * d) + 6.0 * n * di,
            state_bytes: 2.0 * n * di * 4.0,
        }
    }

    fn shard_comm(&self, dc: &DecoderConfig) -> ShardComm {
        ShardComm::CarryExchange { channels: dc.state_dim.max(1) * dc.d_inner() }
    }

    /// Sharded/tiled scan drivers vs the serial recurrence on a ragged
    /// length (associative regrouping: ~1e-12, budget 1e-9).
    fn golden_check(&self, seed: u64) -> Option<GoldenCheck> {
        let mut rng = crate::util::XorShift::new(seed);
        let n = 1000;
        let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let want = crate::scan::mamba_scan_serial(&a, &b);
        let tiled = crate::scan::recurrence::mamba_scan_tiled(&a, &b, 32);
        Some(GoldenCheck {
            reference: "scan::mamba_scan_serial",
            max_abs_diff: crate::util::max_abs_diff(&tiled, &want),
            bit_identical: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graphs_are_valid() {
        for v in [ScanVariant::CScan, ScanVariant::Parallel] {
            let g = mamba_decoder(&DecoderConfig::paper(1 << 14), v);
            assert!(g.validate().is_ok(), "{}", g.name);
        }
    }

    #[test]
    fn scan_flops_formulas() {
        // Paper shape: C = D = 32 scalar-state channels.
        let cfg = DecoderConfig::paper(1 << 10);
        assert_eq!(scan_flops(&cfg, ScanVariant::CScan), 2.0 * 1024.0 * 32.0);
        assert_eq!(scan_flops(&cfg, ScanVariant::Parallel), 6.0 * 1024.0 * 32.0);
        // Full selective-SSM shape: C = 64 × 16 = 1024.
        let full = DecoderConfig::mamba_full(1 << 10);
        assert_eq!(scan_flops(&full, ScanVariant::CScan), 2.0 * 1024.0 * 1024.0);
    }

    #[test]
    fn linear_scaling() {
        let f1 = mamba_decoder(&DecoderConfig::paper(1 << 18), ScanVariant::Parallel).total_flops();
        let f2 = mamba_decoder(&DecoderConfig::paper(1 << 20), ScanVariant::Parallel).total_flops();
        let ratio = f2 / f1;
        assert!((ratio - 4.0).abs() < 0.05, "ratio={ratio}"); // 4× length → 4× work
    }

    #[test]
    fn mamba_beats_attention_on_flops() {
        let cfg = DecoderConfig::paper(1 << 20);
        let ma = mamba_decoder(&cfg, ScanVariant::Parallel).total_flops();
        let at = super::super::attention::attention_decoder(&cfg).total_flops();
        assert!(at / ma > 500.0, "at/ma = {}", at / ma);
    }

    #[test]
    fn scan_gate_proj_spine_is_streamed() {
        // The scan → gate → proj chain the fusion pass clusters: every hop
        // is a stream edge; the z-gate's second operand is buffered.
        let g = mamba_decoder(&DecoderConfig::paper(1 << 12), ScanVariant::Parallel);
        let id = |name: &str| g.kernels.iter().position(|k| k.name == name).unwrap();
        assert_eq!(g.stream_predecessors(id("selective_scan")), vec![id("discretize")]);
        assert_eq!(g.stream_predecessors(id("c_contract")), vec![id("selective_scan")]);
        assert_eq!(g.stream_predecessors(id("gate.z")), vec![id("c_contract")]);
        assert_eq!(g.stream_predecessors(id("out_proj")), vec![id("gate.z")]);
        assert_eq!(g.predecessors(id("gate.z")).len(), 2, "z branch is buffered, not streamed");
    }

    #[test]
    fn one_scan_kernel_with_stream_metadata() {
        let cfg = DecoderConfig::paper(1 << 14);
        let g = mamba_decoder(&cfg, ScanVariant::CScan);
        let scans: Vec<_> = g.kernels.iter().filter(|k| k.op == OpClass::ScanSerial).collect();
        assert_eq!(scans.len(), 1);
        assert_eq!(scans[0].elements, cfg.seq_len as f64);
        assert_eq!(scans[0].channels, (cfg.d_inner() * cfg.state_dim) as f64);
    }

    #[test]
    fn mlp_dominates_nonscan_flops() {
        // Paper §IV-C: the scan-mode speedup is Amdahl-bounded by the MLP.
        let cfg = DecoderConfig::paper(1 << 20);
        let g = mamba_decoder(&cfg, ScanVariant::Parallel);
        let mlp: f64 = g.kernels.iter().filter(|k| k.name.starts_with("mlp.")).map(|k| k.flops).sum();
        let total = g.total_flops();
        assert!(mlp / total > 0.2, "mlp share = {}", mlp / total);
    }
}
