//! The workload registry: one trait every SSM decoder variant implements,
//! and one table every downstream layer resolves by name.
//!
//! Before the registry, adding an SSM variant was cross-cutting surgery:
//! the mapper, fusion pass, sharded estimates, decode-cost hook, figures
//! and CLI each matched on a hand-wired pair of enum arms. Now a workload
//! is **one module plus one registry line**:
//!
//! * [`Workload::build_graph`] — the decoder-layer dataflow graph, stream
//!   edges marked, that [`crate::dfmodel`] maps, fuses and prices;
//! * [`Workload::extended_config`] — which PCU interconnect extension (if
//!   any) the workload's core kernels want, for the design-point tables;
//! * [`Workload::decode_demand`] — per-layer decode-step flop/state demand
//!   the [`crate::dfmodel::decode`] cost hook turns into per-token latency
//!   for the [`crate::session`] continuous-batching scheduler;
//! * [`Workload::shard_comm`] / [`Workload::shard_local_graph`] — the
//!   sequence-sharding pattern [`crate::shard::estimate`] prices over the
//!   inter-chip link;
//! * [`Workload::golden_check`] — the workload's numeric self-check against
//!   its reference path (`simulate` prints these, the integration tests
//!   assert them).
//!
//! Registered workloads: `attention` (the quadratic baseline), `hyena`
//! (FFT long convolution), `mamba` (selective scan), `ssd` (Mamba-2
//! chunked state-space dual, [`super::ssd`]) and `s4` (diagonal-SSM
//! long convolution, [`super::s4`]).
//!
//! Look a workload up by name and drive the whole modeling stack from the
//! trait object:
//!
//! ```
//! use ssm_rdu::workloads::{lookup, registry_names, DecoderConfig};
//!
//! let ssd = lookup("ssd").expect("ssd is registered");
//! let g = ssd.build_graph(&DecoderConfig::paper(1 << 12));
//! assert!(g.validate().is_ok());
//! let est = ssm_rdu::dfmodel::estimate(&g, &ssd.extended_config()).unwrap();
//! assert!(est.total_seconds > 0.0);
//! assert!(registry_names().contains(&"s4"));
//! assert!(lookup("gpt2").is_none());
//! ```
//!
//! `docs/WORKLOADS.md` is the author guide: paper equations → trait
//! methods → modules, with SSD as the worked example.

use super::config::DecoderConfig;
use crate::arch::RduConfig;
use crate::graph::Graph;
use crate::runtime::ModelKind;

/// Per-layer decode-step demand of a workload's token mixer (the MLP is
/// added by the cost hook, which is template-shared across decoders).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodeDemand {
    /// Arithmetic of one token's mixer pass (projections + state update).
    pub mix_flops: f64,
    /// Recurrent-state bytes touched per step (read + write, f32 states).
    pub state_bytes: f64,
}

/// How a workload's forward pass shards across chips — plain data that
/// [`crate::shard::estimate`] prices over an [`crate::arch::InterchipLink`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ShardComm {
    /// Sequence split with an inter-chip exclusive-prefix **carry
    /// exchange**: one composed lifted pair per scan channel on the wire
    /// (the scan family — Mamba, SSD).
    CarryExchange {
        /// Scan channels whose carries travel (`N × d_inner`).
        channels: usize,
    },
    /// Sequence split with `transforms` all-to-all **transposes** of the
    /// padded frequency-domain tensor per layer (the FFT family — Hyena's
    /// six transforms, S4's three).
    AllToAllTranspose { transforms: f64 },
    /// No sequence-local phase to shard (attention).
    Unsupported,
}

/// Result of a workload's numeric golden-model self-check.
#[derive(Debug, Clone, PartialEq)]
pub struct GoldenCheck {
    /// The reference path the functional model was checked against.
    pub reference: &'static str,
    /// Max absolute element difference observed.
    pub max_abs_diff: f64,
    /// Whether the check demands (and observed) exact equality.
    pub bit_identical: bool,
}

/// One SSM decoder variant, end to end: graph builder, design point,
/// decode hook, shard strategy and golden model. See the module docs for
/// how each method is consumed; `docs/WORKLOADS.md` for how to write one.
pub trait Workload: Sync {
    /// Registry key (`--workload <name>` on the CLI).
    fn name(&self) -> &'static str;

    /// One-line description for tables and usage errors.
    fn describe(&self) -> &'static str;

    /// Serving-stack family: which [`ModelKind`] artifact/state shapes the
    /// session layer uses for this workload (SSD rides Mamba's recurrent
    /// states, S4 rides Hyena's FFT caches).
    fn family(&self) -> ModelKind;

    /// Is this an SSM decoder (swept, fused, sharded by default), or a
    /// baseline included only for comparison figures?
    fn is_ssm(&self) -> bool {
        true
    }

    /// Build the decoder-layer dataflow graph at shape `dc`, with
    /// producer→consumer stream edges marked for the fusion pass.
    fn build_graph(&self, dc: &DecoderConfig) -> Graph;

    /// The RDU configuration whose PCU extension serves this workload's
    /// core kernels (baseline when no extension helps — SSD's point is
    /// precisely that its chunked matmuls need none).
    fn extended_config(&self) -> RduConfig;

    /// Per-layer decode-step demand (see [`DecodeDemand`]).
    fn decode_demand(&self, dc: &DecoderConfig) -> DecodeDemand;

    /// Sequence-sharding communication pattern (see [`ShardComm`]).
    fn shard_comm(&self, dc: &DecoderConfig) -> ShardComm;

    /// One chip's local graph for a `chips`-way sequence shard. The default
    /// builds the graph at `L / chips`; FFT-family workloads override it to
    /// rescale transform flops to the *global* transform length the
    /// distributed 4-step actually runs.
    fn shard_local_graph(&self, dc: &DecoderConfig, chips: usize) -> Graph {
        self.build_graph(&DecoderConfig { seq_len: dc.seq_len / chips, ..*dc })
    }

    /// Run the workload's numeric golden model against its reference path
    /// (`None` for baselines without one).
    fn golden_check(&self, seed: u64) -> Option<GoldenCheck>;
}

/// Every registered workload, in presentation order. The first entry whose
/// [`Workload::family`] matches a [`ModelKind`] is that family's canonical
/// workload (used by the ModelKind-keyed serving wrappers), so the classic
/// decoders precede their variants.
pub fn registry() -> &'static [&'static dyn Workload] {
    static REGISTRY: [&dyn Workload; 5] = [
        &super::attention::AttentionWorkload,
        &super::hyena::HyenaWorkload,
        &super::mamba::MambaWorkload,
        &super::ssd::SsdWorkload,
        &super::s4::S4Workload,
    ];
    &REGISTRY
}

/// Look a workload up by its registry name.
pub fn lookup(name: &str) -> Option<&'static dyn Workload> {
    registry().iter().copied().find(|w| w.name() == name)
}

/// All registered workload names (CLI usage errors print these).
pub fn registry_names() -> Vec<&'static str> {
    registry().iter().map(|w| w.name()).collect()
}

/// The registered SSM workloads (everything but the attention baseline).
pub fn ssm_workloads() -> Vec<&'static dyn Workload> {
    registry().iter().copied().filter(|w| w.is_ssm()).collect()
}

/// The canonical workload of a serving-stack family — the bridge from the
/// ModelKind-keyed serving APIs (coordinator, session cache) into the
/// registry.
pub fn family_workload(kind: ModelKind) -> &'static dyn Workload {
    registry()
        .iter()
        .copied()
        .find(|w| w.family() == kind)
        .expect("every ModelKind has a registered workload")
}

/// Scale the FFT kernels of a chips-distributed local graph: the
/// distributed Bailey 4-step runs *global* `fft_len(global)`-point
/// transforms with the butterfly work split evenly across chips, so a
/// chip's FFT flops are `5·(n/P)·log₂ n`, not the `5·(n/P)·log₂(n/P)` the
/// local-length graph priced. Shared by the Hyena and S4
/// [`Workload::shard_local_graph`] overrides.
pub(crate) fn scale_distributed_fft_flops(
    g: &mut Graph,
    global: &DecoderConfig,
    local: &DecoderConfig,
) {
    use crate::graph::OpClass;
    let ratio = (global.fft_len() as f64).log2() / (local.fft_len() as f64).log2().max(1.0);
    for k in &mut g.kernels {
        if matches!(k.op, OpClass::VectorFft | OpClass::GemmFft) {
            k.flops *= ratio;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique_and_stable() {
        let names = registry_names();
        assert_eq!(names, vec!["attention", "hyena", "mamba", "ssd", "s4"]);
        let mut sorted = names.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), names.len(), "duplicate registry names");
    }

    #[test]
    fn lookup_roundtrips_every_name() {
        for w in registry() {
            let found = lookup(w.name()).expect("registered name resolves");
            assert_eq!(found.name(), w.name());
        }
        assert!(lookup("transformer-xl").is_none());
    }

    #[test]
    fn ssm_workloads_excludes_the_baseline() {
        let ssm: Vec<&str> = ssm_workloads().iter().map(|w| w.name()).collect();
        assert_eq!(ssm, vec!["hyena", "mamba", "ssd", "s4"]);
    }

    #[test]
    fn family_lookup_prefers_the_classic_decoders() {
        assert_eq!(family_workload(ModelKind::Mamba).name(), "mamba");
        assert_eq!(family_workload(ModelKind::Hyena).name(), "hyena");
        assert_eq!(family_workload(ModelKind::Attention).name(), "attention");
    }

    #[test]
    fn every_workload_builds_a_valid_graph() {
        let dc = DecoderConfig::paper(1 << 12);
        for w in registry() {
            let g = w.build_graph(&dc);
            assert!(g.validate().is_ok(), "{}: {:?}", w.name(), g.validate());
            assert!(g.total_flops() > 0.0, "{}", w.name());
        }
    }

    #[test]
    fn ssm_graphs_carry_stream_edges_for_fusion() {
        let dc = DecoderConfig::paper(1 << 12);
        for w in ssm_workloads() {
            let g = w.build_graph(&dc);
            assert!(g.stream_bytes() > 0.0, "{}: fusion needs stream edges", w.name());
        }
    }

    #[test]
    fn golden_checks_pass_for_every_ssm_workload() {
        for w in ssm_workloads() {
            let gc = w.golden_check(17).expect("SSM workloads self-check");
            assert!(
                gc.max_abs_diff < 1e-9,
                "{} vs {}: |d|={}",
                w.name(),
                gc.reference,
                gc.max_abs_diff
            );
            if gc.bit_identical {
                assert_eq!(gc.max_abs_diff, 0.0, "{}", w.name());
            }
        }
        assert!(family_workload(ModelKind::Attention).golden_check(17).is_none());
    }

    #[test]
    fn shard_strategies_match_the_families() {
        let dc = DecoderConfig::paper(1 << 16);
        assert!(matches!(
            lookup("mamba").unwrap().shard_comm(&dc),
            ShardComm::CarryExchange { .. }
        ));
        assert!(matches!(
            lookup("ssd").unwrap().shard_comm(&dc),
            ShardComm::CarryExchange { .. }
        ));
        match lookup("hyena").unwrap().shard_comm(&dc) {
            ShardComm::AllToAllTranspose { transforms } => assert_eq!(transforms, 6.0),
            other => panic!("hyena: {other:?}"),
        }
        match lookup("s4").unwrap().shard_comm(&dc) {
            ShardComm::AllToAllTranspose { transforms } => assert_eq!(transforms, 3.0),
            other => panic!("s4: {other:?}"),
        }
        assert_eq!(lookup("attention").unwrap().shard_comm(&dc), ShardComm::Unsupported);
    }

    #[test]
    fn decode_demands_are_positive_for_ssms() {
        let dc = DecoderConfig::mamba_full(1 << 16);
        for w in ssm_workloads() {
            let d = w.decode_demand(&dc);
            assert!(d.mix_flops > 0.0, "{}", w.name());
            assert!(d.state_bytes > 0.0, "{}: SSM decode carries state", w.name());
        }
    }

    #[test]
    fn distributed_fft_rescale_raises_only_fft_flops() {
        let global = DecoderConfig::paper(1 << 16);
        let local = DecoderConfig { seq_len: global.seq_len / 4, ..global };
        let w = lookup("hyena").unwrap();
        let mut g = w.build_graph(&local);
        let before = g.total_flops();
        let fft_before: f64 = g
            .kernels
            .iter()
            .filter(|k| {
                matches!(k.op, crate::graph::OpClass::VectorFft | crate::graph::OpClass::GemmFft)
            })
            .map(|k| k.flops)
            .sum();
        scale_distributed_fft_flops(&mut g, &global, &local);
        let ratio = (global.fft_len() as f64).log2() / (local.fft_len() as f64).log2();
        let expect = before + fft_before * (ratio - 1.0);
        assert!((g.total_flops() - expect).abs() / expect < 1e-12);
    }
}
