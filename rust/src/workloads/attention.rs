//! Attention decoder workload graph (paper Fig. 3A): the quadratic
//! self-attention baseline every SSM design is compared against.

use super::blocks::{self, gemm, gemm_flops, layer_norm};
use super::config::DecoderConfig;
use super::registry::{DecodeDemand, GoldenCheck, ShardComm, Workload};
use crate::arch::RduConfig;
use crate::graph::{Graph, Kernel, OpClass};
use crate::runtime::ModelKind;

/// Build the attention decoder layer: LN → QKV projections →
/// `Q·Kᵀ` (GEMM, 2·L²·D) → softmax → `A·V` (GEMM, 2·L²·D) → output
/// projection → residual/LN/MLP/residual.
pub fn attention_decoder(cfg: &DecoderConfig) -> Graph {
    let l = cfg.seq_len;
    let d = cfg.d_model;
    let b = cfg.dtype_bytes;
    let act = cfg.act_bytes();
    let lsq = l as f64 * l as f64;

    let mut g = Graph::new(&format!("attention-decoder L={l} D={d}"));

    let ln1 = layer_norm(&mut g, cfg, "ln1", d);
    g.input(ln1, act);

    let q = gemm(&mut g, cfg, "proj.q", l, d, d);
    let k = gemm(&mut g, cfg, "proj.k", l, d, d);
    let v = gemm(&mut g, cfg, "proj.v", l, d, d);
    g.connect(ln1, q, act);
    g.connect(ln1, k, act);
    g.connect(ln1, v, act);

    // Scores: Q·Kᵀ — the quadratic kernel (L×L output).
    let scores = g.add(
        Kernel::new("attn.qk", OpClass::Gemm, gemm_flops(l, l, d), 2.0 * act, lsq * b)
            .with_stream(l as f64, l as f64),
    );
    g.connect(q, scores, act);
    g.connect(k, scores, act);

    // Softmax over each of the L rows: max + exp + sum + divide ≈ 5 FLOP/elem.
    let softmax = g.add(
        Kernel::new("attn.softmax", OpClass::Softmax, 5.0 * lsq, lsq * b, lsq * b)
            .with_stream(l as f64, l as f64),
    );
    g.connect(scores, softmax, lsq * b);

    // Attention output: A·V.
    let av = g.add(
        Kernel::new("attn.av", OpClass::Gemm, gemm_flops(l, d, l), lsq * b + act, act)
            .with_stream(l as f64, d as f64),
    );
    g.connect(softmax, av, lsq * b);
    g.connect(v, av, act);

    let out = gemm(&mut g, cfg, "proj.out", l, d, d);
    g.connect(av, out, act);

    let last = blocks::mlp_block(&mut g, cfg, out);
    g.output(last, act);

    debug_assert!(g.validate().is_ok());
    g
}

/// Closed-form FLOP count of the attention core (scores + softmax + AV):
/// `4·L²·D + 5·L²` — the quadratic term dominating Fig. 7/11's Design 1.
pub fn attention_core_flops(cfg: &DecoderConfig) -> f64 {
    let l = cfg.seq_len as f64;
    let d = cfg.d_model as f64;
    4.0 * l * l * d + 5.0 * l * l
}

/// The registered attention baseline (see [`mod@crate::workloads::registry`]):
/// not an SSM — present so every comparison figure resolves through the
/// same registry path as the SSM decoders.
pub struct AttentionWorkload;

impl Workload for AttentionWorkload {
    fn name(&self) -> &'static str {
        "attention"
    }

    fn describe(&self) -> &'static str {
        "quadratic self-attention baseline (Fig. 3A)"
    }

    fn family(&self) -> ModelKind {
        ModelKind::Attention
    }

    fn is_ssm(&self) -> bool {
        false
    }

    fn build_graph(&self, dc: &DecoderConfig) -> Graph {
        attention_decoder(dc)
    }

    /// No PCU extension helps the quadratic GEMMs — they already run in
    /// systolic mode on the baseline chip.
    fn extended_config(&self) -> RduConfig {
        RduConfig::baseline()
    }

    /// QKV + output projections; the KV cache grows with context and is
    /// not O(1) — its traffic is out of scope for the SSM session cache.
    fn decode_demand(&self, dc: &DecoderConfig) -> DecodeDemand {
        let d = dc.d_model as f64;
        DecodeDemand { mix_flops: 2.0 * 4.0 * d * d, state_bytes: 0.0 }
    }

    /// Quadratic token mixing has no sequence-local phase to shard.
    fn shard_comm(&self, _dc: &DecoderConfig) -> ShardComm {
        ShardComm::Unsupported
    }

    fn golden_check(&self, _seed: u64) -> Option<GoldenCheck> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graph_is_valid() {
        let g = attention_decoder(&DecoderConfig::paper(1 << 14));
        assert!(g.validate().is_ok());
        assert_eq!(g.kernels.len(), 14);
    }

    #[test]
    fn quadratic_core_dominates_at_paper_lengths() {
        let cfg = DecoderConfig::paper(1 << 18); // 256K
        let g = attention_decoder(&cfg);
        let core = attention_core_flops(&cfg);
        let total = g.total_flops();
        assert!(core / total > 0.99, "core={core} total={total}");
    }

    #[test]
    fn flops_scale_quadratically() {
        let f1 = attention_decoder(&DecoderConfig::paper(1 << 18)).total_flops();
        let f2 = attention_decoder(&DecoderConfig::paper(1 << 19)).total_flops();
        let ratio = f2 / f1;
        assert!((ratio - 4.0).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn core_flops_match_graph() {
        let cfg = DecoderConfig::paper(1 << 16);
        let g = attention_decoder(&cfg);
        let got: f64 = g
            .kernels
            .iter()
            .filter(|k| k.name.starts_with("attn."))
            .map(|k| k.flops)
            .sum();
        assert_eq!(got, attention_core_flops(&cfg));
    }
}
