//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from Rust — the L3 hot path's compute engine.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`); see
//! `aot.py` and /opt/xla-example/README.md for why serialized protos are
//! rejected by the image's xla_extension 0.5.1. One compiled executable per
//! model variant; Python is never on the request path.

//!
//! The host execution engine also lives here: [`team`] is the resident
//! [`WorkerTeam`] (spawned once, `SSM_RDU_THREADS`-wide) that executes all
//! pooled work; [`pool`] keeps the dependency-free [`WorkerPool`] API that
//! fans hot-path golden-model work (per-channel convolutions, per-chip
//! shards, per-session decode steps, batch packing) as a thin facade over
//! the team. [`eventcount`] is the futex-style park/wake primitive both
//! the team and [`steal`]'s sharded work-stealing queues
//! ([`StealQueues`] / [`StealBoard`]) sleep on, and [`topology`] probes
//! `/sys` NUMA layout for home-worker placement (ARCHITECTURE.md §5.4–5.5).

pub mod eventcount;
pub mod manifest;
pub mod pool;
pub mod steal;
pub mod team;
pub mod topology;

pub use eventcount::EventCount;
pub use manifest::{Manifest, ModelMeta};
pub use pool::WorkerPool;
pub use steal::{Claim, StealBoard, StealQueues, EVENT_LOOP_TICK};
pub use team::{worker_index, with_scratch_f64, WorkerTeam};
pub use topology::Topology;

use crate::Result;
use anyhow::{anyhow, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Which decoder layer a request targets (the artifact set of `aot.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelKind {
    Attention,
    Hyena,
    Mamba,
}

impl ModelKind {
    pub const ALL: [ModelKind; 3] = [ModelKind::Attention, ModelKind::Hyena, ModelKind::Mamba];

    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Attention => "attention",
            ModelKind::Hyena => "hyena",
            ModelKind::Mamba => "mamba",
        }
    }

    pub fn from_name(s: &str) -> Option<ModelKind> {
        Self::ALL.into_iter().find(|m| m.name() == s)
    }
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One compiled decoder-layer executable.
pub struct LoadedModel {
    pub kind: ModelKind,
    pub meta: ModelMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    /// Execute on a packed `(batch, seq_len, d_model)` activation buffer.
    ///
    /// `input.len()` must equal the artifact's full input element count —
    /// the dynamic batcher pads partial batches before calling this.
    pub fn execute(&self, input: &[f32]) -> Result<Vec<f32>> {
        let want: usize = self.meta.input_shape.iter().product();
        if input.len() != want {
            return Err(anyhow!(
                "{}: input has {} elements, artifact expects {want}",
                self.kind,
                input.len()
            ));
        }
        let dims: Vec<i64> = self.meta.input_shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Elements in one request's activation (`seq_len × d_model`).
    pub fn elems_per_slot(&self) -> usize {
        self.meta.input_shape[1] * self.meta.input_shape[2]
    }

    /// Batch slots in the artifact.
    pub fn batch_slots(&self) -> usize {
        self.meta.input_shape[0]
    }
}

/// A PJRT CPU client with every artifact from a manifest compiled.
pub struct Runtime {
    pub manifest: Manifest,
    models: BTreeMap<ModelKind, LoadedModel>,
    pub artifacts_dir: PathBuf,
}

impl Runtime {
    /// Load and compile every model listed in `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        let mut models = BTreeMap::new();
        for (kind, meta) in &manifest.models {
            let path = dir.join(&meta.path);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-UTF8 path"))?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            models.insert(*kind, LoadedModel { kind: *kind, meta: meta.clone(), exe });
        }
        Ok(Self { manifest, models, artifacts_dir: dir })
    }

    /// Load a subset of models (cheaper for tests/examples).
    pub fn load_subset(dir: impl AsRef<Path>, kinds: &[ModelKind]) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let mut manifest = Manifest::load(dir.join("manifest.json"))?;
        manifest.models.retain(|k, _| kinds.contains(k));
        if manifest.models.is_empty() {
            return Err(anyhow!("no requested models present in manifest"));
        }
        let client = xla::PjRtClient::cpu()?;
        let mut models = BTreeMap::new();
        for (kind, meta) in &manifest.models {
            let path = dir.join(&meta.path);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-UTF8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            models.insert(*kind, LoadedModel { kind: *kind, meta: meta.clone(), exe });
        }
        Ok(Self { manifest, models, artifacts_dir: dir })
    }

    /// Access a compiled model.
    pub fn model(&self, kind: ModelKind) -> Result<&LoadedModel> {
        self.models
            .get(&kind)
            .ok_or_else(|| anyhow!("model `{kind}` not loaded (artifact missing?)"))
    }

    /// Kinds available in this runtime.
    pub fn kinds(&self) -> Vec<ModelKind> {
        self.models.keys().copied().collect()
    }
}

/// Default artifacts directory: `$SSM_RDU_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("SSM_RDU_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_kind_names_roundtrip() {
        for k in ModelKind::ALL {
            assert_eq!(ModelKind::from_name(k.name()), Some(k));
        }
        assert_eq!(ModelKind::from_name("gpt"), None);
    }

    #[test]
    fn missing_dir_is_clean_error() {
        let err = match Runtime::load("/nonexistent/path") {
            Err(e) => e,
            Ok(_) => panic!("load of missing dir must fail"),
        };
        let s = format!("{err:#}");
        assert!(s.contains("manifest"), "{s}");
    }
}
