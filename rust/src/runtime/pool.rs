//! Dependency-free worker-pool facade — the fan-out API for every
//! embarrassingly parallel axis in the golden models: per-channel Hyena
//! convolutions (`crate::fft::conv`), per-chip sharded scan/FFT execution
//! (`crate::shard`), per-session decode steps
//! (`crate::session::driver::simulate_pooled`), and large batch packing in
//! the coordinator. No crates are added: the build stays offline-vendorable.
//!
//! ## Design
//!
//! * **Facade over a resident team.** Since PR 9 a `WorkerPool` owns no
//!   threads: `map`/`map_stealing`/`for_each_mut` submit to the
//!   process-long [`super::team::WorkerTeam`] (ARCHITECTURE.md §5.5), so
//!   the per-call thread spawn/join is gone and per-worker state (plan
//!   caches, scratch arenas, sticky executors) stays warm across batches.
//!   Closures may still borrow locals — the facade blocks until the
//!   submitted job completes. The pre-PR-9 spawn-per-call path survives as
//!   [`WorkerPool::map_spawn`], kept honest as the baseline for the
//!   `team_resident_vs_spawn` bench gate.
//! * **Width is fan-out, not threads.** `threads` now means "how many
//!   contiguous chunks to cut" (`map`/`for_each_mut`); physical
//!   parallelism is the team's width (`SSM_RDU_THREADS` at first use).
//!   With the default `from_env` width the two coincide.
//! * **Deterministic chunking.** Jobs `0..n` are split into at most
//!   `threads` *contiguous* balanced chunks; outputs are reassembled in
//!   index order. Combined with per-job independence this makes every
//!   pooled path **bit-identical** to its serial loop — asserted by the
//!   integration tests, because the benches' pooled-vs-serial comparison
//!   is only meaningful if pooling is purely a scheduling transform.
//!   [`WorkerPool::map_stealing`] keeps the same bit-identity guarantee
//!   with *self-scheduling* claim order instead of pre-chunking, for
//!   skewed per-job costs.
//! * **Panic = panic.** A panicking job panics the calling thread with the
//!   original payload (`resume_unwind`, not a generic join message); no
//!   work is silently dropped and the team stays reusable.

use super::team::WorkerTeam;
use std::ops::Range;
use std::sync::OnceLock;

/// A fixed-width fan-out helper; see the module docs.
#[derive(Debug, Clone, Copy)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// A pool that fans out over `threads` workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// A pool that runs everything on the calling thread.
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Width from the environment: `SSM_RDU_THREADS` if set (0 or unset →
    /// the machine's available parallelism). **Cached after the first
    /// read**: a later change to the env var is silently ignored, which is
    /// correct for servers (width is a process invariant) but wrong for
    /// harnesses that sweep widths — those must use
    /// [`WorkerPool::from_env_uncached`] or [`WorkerPool::with_threads`].
    pub fn from_env() -> Self {
        static THREADS: OnceLock<usize> = OnceLock::new();
        Self::new(*THREADS.get_or_init(env_threads))
    }

    /// Like [`WorkerPool::from_env`] but re-reads `SSM_RDU_THREADS` on
    /// every call — use from benches/tests that set the env var after the
    /// process has already done pooled work.
    pub fn from_env_uncached() -> Self {
        Self::new(env_threads())
    }

    /// Explicit width when given, else a fresh env read — the harness
    /// pattern for "CLI flag overrides `SSM_RDU_THREADS`".
    pub fn with_threads(threads: Option<usize>) -> Self {
        match threads {
            Some(t) => Self::new(t),
            None => Self::from_env_uncached(),
        }
    }

    /// Worker width of this pool.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run jobs `0..jobs` and collect their outputs in index order. Jobs
    /// are chunked contiguously into at most `threads` tasks executed by
    /// the resident team; with one thread (or ≤ 1 job) this is exactly the
    /// serial loop, inline on the caller.
    pub fn map<T, F>(&self, jobs: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let _t = crate::telemetry::span("pool", "pool.map").arg("jobs", jobs as f64);
        pool_maps_counter().fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if self.threads == 1 || jobs <= 1 {
            return (0..jobs).map(f).collect();
        }
        WorkerTeam::global().map_chunked(jobs, self.threads, f)
    }

    /// The pre-PR-9 `map`: spawn scoped workers, run, join — one OS thread
    /// per chunk, created and destroyed inside the call. Bit-identical to
    /// [`WorkerPool::map`]; kept (not as a dead branch but as a measured
    /// baseline) so the `team_resident_vs_spawn` bench gate can price
    /// residency against real spawn/join cost forever.
    pub fn map_spawn<T, F>(&self, jobs: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let _t = crate::telemetry::span("pool", "pool.map_spawn").arg("jobs", jobs as f64);
        pool_maps_counter().fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if self.threads == 1 || jobs <= 1 {
            return (0..jobs).map(f).collect();
        }
        let ranges = chunk_ranges(jobs, self.threads);
        let mut chunks: Vec<Vec<T>> = Vec::with_capacity(ranges.len());
        std::thread::scope(|s| {
            let handles: Vec<_> = ranges
                .iter()
                .map(|r| {
                    let f = &f;
                    let r = r.clone();
                    s.spawn(move || {
                        let _c =
                            crate::telemetry::span("pool", "pool.chunk").arg("len", r.len() as f64);
                        r.map(f).collect::<Vec<T>>()
                    })
                })
                .collect();
            for h in handles {
                match h.join() {
                    Ok(chunk) => chunks.push(chunk),
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
        });
        chunks.into_iter().flatten().collect()
    }

    /// Run jobs `0..jobs` with **self-scheduling** workers: instead of
    /// pre-chunking, each worker repeatedly claims the next unclaimed index
    /// from a shared atomic counter. When per-job cost is skewed (mixed
    /// lengths, cold caches, NUMA noise) no worker is left holding a long
    /// contiguous tail while the others idle — the stealing analogue for
    /// flat fan-outs, used by the per-channel conv paths. Output is in
    /// index order and **bit-identical** to [`Self::map`]: each job's value
    /// depends only on its index and lands in its own slot, so claim order
    /// cannot affect any result.
    pub fn map_stealing<T, F>(&self, jobs: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let _t = crate::telemetry::span("pool", "pool.map_stealing").arg("jobs", jobs as f64);
        pool_maps_counter().fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if self.threads == 1 || jobs <= 1 {
            return (0..jobs).map(f).collect();
        }
        WorkerTeam::global().map_indexed(jobs, f)
    }

    /// Mutate each item in place, `f(index, item)`, chunked contiguously
    /// over the workers. The disjoint `split_at_mut` chunks make this safe
    /// without locks; order of observation per item is the serial order
    /// because each item is touched exactly once.
    pub fn for_each_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        let _t = crate::telemetry::span("pool", "pool.for_each_mut").arg("items", n as f64);
        pool_maps_counter().fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if self.threads == 1 || n <= 1 {
            for (i, it) in items.iter_mut().enumerate() {
                f(i, it);
            }
            return;
        }
        WorkerTeam::global().for_each_mut_chunked(items, self.threads, f)
    }
}

/// Fresh `SSM_RDU_THREADS` read: the env var if set and nonzero, else the
/// machine's available parallelism. Shared with the team's first spawn.
pub(crate) fn env_threads() -> usize {
    std::env::var("SSM_RDU_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&v| v > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// The `pool.dispatches` counter (map + for_each_mut calls), resolved once
/// so the hot path pays only the relaxed add.
fn pool_maps_counter() -> &'static std::sync::atomic::AtomicU64 {
    static CELL: OnceLock<&'static std::sync::atomic::AtomicU64> = OnceLock::new();
    CELL.get_or_init(|| crate::telemetry::counter("pool.dispatches"))
}

/// Balanced contiguous partition of `0..n` into at most `parts` non-empty
/// ranges (the first `n % parts` ranges take one extra element).
pub fn chunk_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push(lo..lo + len);
        lo += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_index_order() {
        for threads in [1usize, 2, 3, 8, 33] {
            let pool = WorkerPool::new(threads);
            let got = pool.map(100, |i| i * i);
            let want: Vec<usize> = (0..100).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn map_handles_degenerate_sizes() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.map(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map(1, |i| i + 7), vec![7]);
        assert_eq!(pool.map(3, |i| i), vec![0, 1, 2], "more threads than jobs");
    }

    #[test]
    fn map_actually_fans_out() {
        // The facade submits to the resident team and the submitter never
        // executes, so *all* work leaves this thread. (Deterministic
        // multi-worker participation is asserted in `team::tests`, where
        // the team width is pinned rather than env-dependent.)
        let pool = WorkerPool::new(4);
        let main_id = std::thread::current().id();
        let ids = pool.map(64, |_| std::thread::current().id());
        assert!(ids.iter().all(|&id| id != main_id), "work must leave the main thread");
    }

    #[test]
    fn map_spawn_matches_map_bit_for_bit() {
        for threads in [1usize, 2, 3, 8, 33] {
            let pool = WorkerPool::new(threads);
            let want = pool.map_spawn(101, |i| (i * 31) as f64 / 7.0);
            let got = pool.map(101, |i| (i * 31) as f64 / 7.0);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn map_panics_with_original_message_and_pool_stays_usable() {
        let pool = WorkerPool::new(4);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map(16, |i| {
                if i == 11 {
                    panic!("map job {i} exploded");
                }
                i
            });
        }))
        .expect_err("panicking job must panic the caller");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("map job 11 exploded"), "original payload expected, got {msg:?}");
        // The resident team survives a panicking job.
        assert_eq!(pool.map(8, |i| i * 2), (0..8).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_stealing_panics_with_original_message_and_pool_stays_usable() {
        let pool = WorkerPool::new(3);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map_stealing(16, |i| {
                if i == 5 {
                    panic!("stolen job {i} exploded");
                }
                i
            });
        }))
        .expect_err("panicking job must panic the caller");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("stolen job 5 exploded"), "original payload expected, got {msg:?}");
        assert_eq!(pool.map_stealing(8, |i| i + 1), (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn map_spawn_panics_with_original_message() {
        let pool = WorkerPool::new(4);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map_spawn(16, |i| {
                if i == 3 {
                    panic!("spawned job {i} exploded");
                }
                i
            });
        }))
        .expect_err("panicking job must panic the caller");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("spawned job 3 exploded"), "original payload expected, got {msg:?}");
    }

    #[test]
    fn with_threads_override_beats_env() {
        assert_eq!(WorkerPool::with_threads(Some(7)).threads(), 7);
        assert!(WorkerPool::with_threads(None).threads() >= 1);
        assert!(WorkerPool::from_env_uncached().threads() >= 1);
    }

    #[test]
    fn map_stealing_matches_map_bit_for_bit() {
        for threads in [1usize, 2, 3, 8, 33] {
            let pool = WorkerPool::new(threads);
            let want = pool.map(101, |i| (i * 31) as f64 / 7.0);
            let got = pool.map_stealing(101, |i| (i * 31) as f64 / 7.0);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn map_stealing_handles_degenerate_sizes() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.map_stealing(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map_stealing(1, |i| i + 7), vec![7]);
        assert_eq!(pool.map_stealing(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn map_stealing_runs_every_job_exactly_once() {
        let pool = WorkerPool::new(5);
        let calls = AtomicUsize::new(0);
        let got = pool.map_stealing(200, |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(calls.load(Ordering::Relaxed), 200);
        assert!(got.iter().enumerate().all(|(i, &x)| x == i));
    }

    #[test]
    fn for_each_mut_touches_every_item_once() {
        let pool = WorkerPool::new(3);
        let mut xs = vec![0usize; 97];
        let calls = AtomicUsize::new(0);
        pool.for_each_mut(&mut xs, |i, x| {
            *x = i + 1;
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 97);
        assert!(xs.iter().enumerate().all(|(i, &x)| x == i + 1));
    }

    #[test]
    fn chunks_are_balanced_and_cover() {
        for &(n, parts) in &[(0usize, 4usize), (1, 4), (10, 3), (100, 7), (5, 9)] {
            let rs = chunk_ranges(n, parts);
            let mut next = 0;
            for r in &rs {
                assert_eq!(r.start, next);
                next = r.end;
            }
            assert_eq!(next, n, "n={n} parts={parts}");
            if n > 0 {
                let lens: Vec<usize> = rs.iter().map(|r| r.len()).collect();
                let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                assert!(max - min <= 1, "balanced: {lens:?}");
                assert!(*min >= 1, "no empty chunks when n>0: {lens:?}");
            }
        }
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = WorkerPool::serial();
        let main_id = std::thread::current().id();
        let ids = pool.map(8, |_| std::thread::current().id());
        assert!(ids.iter().all(|&id| id == main_id));
    }

    #[test]
    fn zero_width_requests_clamp_to_one() {
        assert_eq!(WorkerPool::new(0).threads(), 1);
    }
}
