//! Resident worker team — the process-long execution engine behind
//! [`super::WorkerPool`] (ARCHITECTURE.md §5.5).
//!
//! The paper's thesis is that SSM speedups come from keeping dataflows
//! *resident* — configure once, stream forever — instead of paying launch
//! overhead per call. PR 9 applies the same principle to the host engine:
//! where `WorkerPool` used to spawn and join OS threads on every `map`,
//! a single [`WorkerTeam`] is spawned once (width from `SSM_RDU_THREADS`)
//! and every pooled call becomes a **submission**: the caller publishes a
//! type-erased job to the injector deque, wakes the team through an
//! [`EventCount`] (microsecond park/wake instead of thread spawn), and
//! parks until the job's task counter drains.
//!
//! ## Ownership rules
//!
//! * **Jobs may borrow caller locals.** The borrow is erased to a raw
//!   pointer when the job is published; safety is restored by the
//!   completion barrier — [`WorkerTeam::run`] does not return until every
//!   task has finished (`pending == 0`), and workers never invoke a job
//!   after its claim counter passes `tasks`. The borrowed closure thus
//!   strictly outlives every call through the raw pointer.
//! * **External callers park; workers help.** A submitter that is not a
//!   team worker contributes no execution — all work lands on the team
//!   (so "work leaves the calling thread" stays a hard guarantee). A team
//!   *worker* that submits (nested pooled calls) claims tasks of its own
//!   job instead of parking, which makes nesting deadlock-free at any
//!   team width: every claimed task is finishable by the thread that
//!   claimed it.
//! * **Per-worker epochs.** Each idle worker snapshots the injector
//!   eventcount's epoch *before* its last empty re-check, then parks keyed
//!   to that epoch ([`EventCount::wait`]) — a publish between the check
//!   and the park bumps the epoch and the sleep is elided, so no wakeup
//!   is ever missed and no polling tick is needed.
//! * **Sticky state.** Workers are process-long, so everything
//!   thread-local becomes resident for free: the per-thread FFT plan
//!   cache (`crate::fft::plan::with_conv_plan`) stays warm across
//!   batches, [`with_scratch_f64`] reuses a per-worker arena (first touch
//!   on the owning worker — NUMA-local where that matters), and
//!   `crate::session::driver::simulate_pooled` keeps one executor per
//!   worker alive across iteration batches (`team.sticky_hit` counts the
//!   reuses).
//!
//! ## Why a panic doesn't kill the team
//!
//! Tasks run under `catch_unwind`; the first payload is stashed on the
//! job and re-raised **in the submitting thread** via `resume_unwind`, so
//! the caller observes the original panic message (not a generic join
//! error) and the workers keep running — the team is reusable after a
//! panicking job, which `tests` assert.

use super::eventcount::EventCount;
use super::pool::chunk_ranges;
use std::any::Any;
use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Fallback park bound for idle workers — correctness never depends on it
/// (see [`EventCount`]); it only bounds the damage of a hypothetical lost
/// wake. Matches the steal-board fallback, wired to the coordinator tick.
const PARK_FALLBACK: Duration = super::steal::EVENT_LOOP_TICK;

/// A type-erased task body: call with a task index. Lifetime is erased on
/// submission (see the module-level ownership rules).
type RawTask = *const (dyn Fn(usize) + Sync);

/// One submitted fan-out: `tasks` indices claimed off an atomic counter.
struct Job {
    run: RawTask,
    tasks: usize,
    /// Next unclaimed task index (may overshoot `tasks` by one per
    /// claimant; claims at or past `tasks` are no-ops).
    next: AtomicUsize,
    /// Tasks not yet finished; the submitter parks until this hits zero.
    pending: AtomicUsize,
    /// First panic payload raised by a task, re-raised in the submitter.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    /// Wakes the parked submitter when `pending` drains.
    done: EventCount,
}

// SAFETY: `run` is only dereferenced while the submitting `run()` frame is
// alive (completion barrier, see module docs); everything else is atomics
// and mutexes. The closure behind `run` is `Sync`, so concurrent calls
// from several workers are permitted by its own bound.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct TeamShared {
    /// FIFO of live jobs; workers serve the front, exhausted jobs are
    /// retired on the next grab. One short lock — never held across task
    /// execution.
    injector: Mutex<VecDeque<Arc<Job>>>,
    /// Park/wake protocol for idle workers.
    ec: EventCount,
    shutdown: AtomicBool,
}

impl TeamShared {
    /// First job with unclaimed tasks, retiring fully-claimed ones.
    fn grab_job(&self) -> Option<Arc<Job>> {
        let mut inj = self.injector.lock().expect("team injector poisoned");
        while let Some(front) = inj.front() {
            if front.next.load(Ordering::Relaxed) >= front.tasks {
                inj.pop_front();
            } else {
                return Some(Arc::clone(front));
            }
        }
        None
    }
}

/// Claim and execute tasks of `job` until its counter is exhausted.
fn execute_claims(job: &Job) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.tasks {
            return;
        }
        // SAFETY: i < tasks and the submitter has not returned (pending
        // has not drained), so the erased closure is alive.
        let body = unsafe { &*job.run };
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| body(i))) {
            let mut slot = job.panic.lock().expect("team panic slot poisoned");
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            job.done.notify_all();
        }
    }
}

thread_local! {
    /// Set once in each team worker; `None` on every other thread.
    static WORKER_INDEX: Cell<Option<usize>> = const { Cell::new(None) };
    /// Per-worker reusable f64 arena (see [`with_scratch_f64`]).
    static SCRATCH_F64: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// The index of the current thread within its [`WorkerTeam`], or `None`
/// when called from a non-team thread (the main thread, coordinator
/// workers, tests).
pub fn worker_index() -> Option<usize> {
    WORKER_INDEX.with(|c| c.get())
}

/// Run `f` over a zeroed thread-local scratch slice of `len` f64s,
/// reusing the calling thread's arena when its capacity already suffices
/// (counted as `team.sticky_hit`: on a resident worker the first call
/// faults the pages in — first-touch on the worker's own NUMA node — and
/// every later batch reuses them warm).
pub fn with_scratch_f64<R>(len: usize, f: impl FnOnce(&mut [f64]) -> R) -> R {
    SCRATCH_F64.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.capacity() >= len {
            sticky_hit_counter().fetch_add(1, Ordering::Relaxed);
        }
        buf.clear();
        buf.resize(len, 0.0);
        f(&mut buf)
    })
}

/// A process-long team of worker threads; see the module docs. The
/// process-wide instance behind the [`super::WorkerPool`] facades is
/// [`WorkerTeam::global`]; tests build private teams to pin widths.
pub struct WorkerTeam {
    shared: Arc<TeamShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    width: usize,
}

impl WorkerTeam {
    /// Spawn a team of `width` resident workers (clamped to ≥ 1).
    pub fn new(width: usize) -> Self {
        let width = width.max(1);
        let shared = Arc::new(TeamShared {
            injector: Mutex::new(VecDeque::new()),
            ec: EventCount::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..width)
            .map(|wid| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("ssm-team-{wid}"))
                    .spawn(move || worker_main(shared, wid))
                    .expect("WorkerTeam: failed to spawn worker")
            })
            .collect();
        Self { shared, handles, width }
    }

    /// The process-wide resident team. Spawned on first use, `SSM_RDU_THREADS`
    /// wide (0/unset → available parallelism; the width is read **once** —
    /// a resident team cannot resize to a changed env var, which is why
    /// width-sensitive benches pin widths via [`WorkerTeam::new`] or
    /// `WorkerPool` facades instead of the env).
    pub fn global() -> &'static WorkerTeam {
        static TEAM: OnceLock<WorkerTeam> = OnceLock::new();
        TEAM.get_or_init(|| WorkerTeam::new(super::pool::env_threads()))
    }

    /// Number of resident workers.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Submit `tasks` task indices and block until all have executed.
    /// The core primitive every facade builds on; panics in tasks re-raise
    /// here with their original payload (team stays alive and reusable).
    pub fn run<F>(&self, tasks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if tasks == 0 {
            return;
        }
        let _t = crate::telemetry::span("team", "team.run").arg("tasks", tasks as f64);
        let body: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: lifetime erasure per the module-level ownership rules;
        // this frame outlives every dereference (completion barrier below).
        let raw: RawTask = unsafe { std::mem::transmute(body) };
        let job = Arc::new(Job {
            run: raw,
            tasks,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(tasks),
            panic: Mutex::new(None),
            done: EventCount::new(),
        });
        self.shared
            .injector
            .lock()
            .expect("team injector poisoned")
            .push_back(Arc::clone(&job));
        self.shared.ec.notify_all();
        if worker_index().is_some() {
            // Nested submission from a team worker: help instead of
            // parking, so a width-1 team cannot deadlock on itself.
            execute_claims(&job);
        }
        while job.pending.load(Ordering::Acquire) != 0 {
            let key = job.done.epoch();
            if job.pending.load(Ordering::Acquire) == 0 {
                break;
            }
            job.done.wait(key, PARK_FALLBACK);
        }
        // Retire our injector entry if no worker already did.
        self.shared
            .injector
            .lock()
            .expect("team injector poisoned")
            .retain(|j| !Arc::ptr_eq(j, &job));
        let payload = job.panic.lock().expect("team panic slot poisoned").take();
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }

    /// `WorkerPool::map` semantics on the team: jobs `0..jobs` split into
    /// at most `chunks` contiguous balanced ranges (the *pool's* width,
    /// independent of team width), outputs reassembled in index order —
    /// bit-identical to the serial loop.
    pub fn map_chunked<T, F>(&self, jobs: usize, chunks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let ranges = chunk_ranges(jobs, chunks);
        let slots: Vec<Mutex<Option<Vec<T>>>> =
            (0..ranges.len()).map(|_| Mutex::new(None)).collect();
        self.run(ranges.len(), |c| {
            let _c = crate::telemetry::span("pool", "pool.chunk")
                .arg("len", ranges[c].len() as f64);
            let vals: Vec<T> = ranges[c].clone().map(&f).collect();
            *slots[c].lock().expect("team chunk slot poisoned") = Some(vals);
        });
        slots
            .into_iter()
            .flat_map(|s| {
                s.into_inner()
                    .expect("team chunk slot poisoned")
                    .expect("chunk completed (run() barriers on completion)")
            })
            .collect()
    }

    /// `WorkerPool::map_stealing` semantics on the team: one task per job
    /// index, claimed self-scheduled off the job's atomic counter. Each
    /// value lands in its own slot, so claim order cannot affect results.
    pub fn map_indexed<T, F>(&self, jobs: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let slots: Vec<Mutex<Option<T>>> = (0..jobs).map(|_| Mutex::new(None)).collect();
        self.run(jobs, |i| {
            *slots[i].lock().expect("team job slot poisoned") = Some(f(i));
        });
        slots
            .into_iter()
            .map(|s| {
                s.into_inner()
                    .expect("team job slot poisoned")
                    .expect("job completed (run() barriers on completion)")
            })
            .collect()
    }

    /// `WorkerPool::for_each_mut` semantics on the team: disjoint
    /// contiguous chunks of `items` mutated in place, `f(index, item)`.
    pub fn for_each_mut_chunked<T, F>(&self, items: &mut [T], chunks: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let ranges = chunk_ranges(items.len(), chunks);
        let base = SendPtr(items.as_mut_ptr());
        self.run(ranges.len(), |c| {
            let _c = crate::telemetry::span("pool", "pool.chunk")
                .arg("len", ranges[c].len() as f64);
            for j in ranges[c].clone() {
                // SAFETY: ranges are disjoint, so each item is aliased by
                // exactly one task; `items` outlives run()'s barrier.
                let item = unsafe { &mut *base.0.add(j) };
                f(j, item);
            }
        });
    }
}

impl Drop for WorkerTeam {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.ec.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw-pointer wrapper so disjoint-chunk tasks can share a slice base.
struct SendPtr<T>(*mut T);
// SAFETY: dereferences are confined to disjoint index ranges per task.
unsafe impl<T: Send> Sync for SendPtr<T> {}

fn worker_main(shared: Arc<TeamShared>, wid: usize) {
    WORKER_INDEX.with(|c| c.set(Some(wid)));
    loop {
        // Epoch before the empty re-check: a publish in between bumps it
        // and the park below is elided (no missed wake, no polling tick).
        let key = shared.ec.epoch();
        if let Some(job) = shared.grab_job() {
            execute_claims(&job);
            continue;
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        park_counter().fetch_add(1, Ordering::Relaxed);
        let parked = shared.ec.wait(key, PARK_FALLBACK);
        wake_counter().fetch_add(1, Ordering::Relaxed);
        crate::telemetry::instant_arg("team", "team.wake", "park_us", parked.as_micros() as f64);
    }
}

/// `team.park`: times a worker committed to parking (found no work).
fn park_counter() -> &'static AtomicU64 {
    static CELL: OnceLock<&'static AtomicU64> = OnceLock::new();
    CELL.get_or_init(|| crate::telemetry::counter("team.park"))
}

/// `team.wake`: times a parked worker resumed (notify or fallback).
fn wake_counter() -> &'static AtomicU64 {
    static CELL: OnceLock<&'static AtomicU64> = OnceLock::new();
    CELL.get_or_init(|| crate::telemetry::counter("team.wake"))
}

/// `team.sticky_hit`: reuses of per-worker resident state (scratch arenas,
/// sticky executors) that a spawn-per-call pool would have rebuilt.
pub(crate) fn sticky_hit_counter() -> &'static AtomicU64 {
    static CELL: OnceLock<&'static AtomicU64> = OnceLock::new();
    CELL.get_or_init(|| crate::telemetry::counter("team.sticky_hit"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Instant;

    #[test]
    fn run_executes_every_task_exactly_once() {
        let team = WorkerTeam::new(3);
        let calls = AtomicUsize::new(0);
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        team.run(100, |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 100);
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn external_submitter_does_not_execute() {
        let team = WorkerTeam::new(2);
        let main_id = std::thread::current().id();
        let ids = team.map_indexed(16, |_| std::thread::current().id());
        assert!(ids.iter().all(|&id| id != main_id), "work must leave the submitter");
    }

    #[test]
    fn multiple_workers_participate() {
        // Deterministic multi-worker check: the first claimant spins until
        // a second worker starts a task, so ≥2 distinct workers must run
        // (the submitter never helps; notify_all wakes the whole team).
        let team = WorkerTeam::new(4);
        let started = Arc::new(AtomicUsize::new(0));
        let ids = team.map_indexed(4, |_| {
            started.fetch_add(1, Ordering::SeqCst);
            let t0 = Instant::now();
            while started.load(Ordering::SeqCst) < 2 {
                assert!(t0.elapsed() < Duration::from_secs(10), "second worker never arrived");
                std::thread::yield_now();
            }
            std::thread::current().id()
        });
        let distinct: std::collections::HashSet<_> = ids.iter().collect();
        assert!(distinct.len() >= 2, "expected at least two workers, got {distinct:?}");
    }

    #[test]
    fn map_chunked_is_bit_identical_to_serial() {
        let team = WorkerTeam::new(4);
        for chunks in [1usize, 2, 3, 8, 33] {
            let got = team.map_chunked(101, chunks, |i| (i * 31) as f64 / 7.0);
            let want: Vec<f64> = (0..101).map(|i| (i * 31) as f64 / 7.0).collect();
            assert_eq!(got, want, "chunks={chunks}");
        }
    }

    #[test]
    fn map_indexed_matches_map_chunked() {
        let team = WorkerTeam::new(3);
        let want = team.map_chunked(97, 3, |i| i * i);
        let got = team.map_indexed(97, |i| i * i);
        assert_eq!(got, want);
    }

    #[test]
    fn for_each_mut_touches_every_item_once() {
        let team = WorkerTeam::new(3);
        let mut xs = vec![0usize; 97];
        team.for_each_mut_chunked(&mut xs, 5, |i, x| *x = i + 1);
        assert!(xs.iter().enumerate().all(|(i, &x)| x == i + 1));
    }

    #[test]
    fn nested_submission_from_a_worker_completes() {
        // A task that itself fans out exercises the help-don't-park rule;
        // run it on a width-1 team, where parking instead would deadlock.
        let team = Arc::new(WorkerTeam::new(1));
        let t2 = Arc::clone(&team);
        let sums = team.map_indexed(3, move |i| {
            let inner = t2.map_chunked(4, 4, |j| i * 10 + j);
            inner.iter().sum::<usize>()
        });
        assert_eq!(sums, vec![6, 46, 86]);
    }

    #[test]
    fn panic_propagates_original_message_and_team_survives() {
        let team = WorkerTeam::new(2);
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            team.run(8, |i| {
                if i == 5 {
                    panic!("boom in task {i}");
                }
            });
        }))
        .expect_err("panicking task must panic the submitter");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("boom in task 5"), "original payload expected, got {msg:?}");
        // The team is reusable: the next submission completes normally.
        let got = team.map_indexed(10, |i| i + 1);
        assert_eq!(got, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn scratch_arena_reuses_capacity() {
        let before = sticky_hit_counter().load(Ordering::Relaxed);
        let a = with_scratch_f64(64, |buf| {
            buf[0] = 1.0;
            buf.len()
        });
        let b = with_scratch_f64(32, |buf| {
            assert_eq!(buf[0], 0.0, "arena re-zeroes");
            buf.len()
        });
        assert_eq!((a, b), (64, 32));
        assert!(
            sticky_hit_counter().load(Ordering::Relaxed) > before,
            "second call fits the warm arena"
        );
    }

    #[test]
    fn worker_index_is_set_on_workers_only() {
        assert_eq!(worker_index(), None, "submitter is not a team worker");
        let team = WorkerTeam::new(2);
        let idxs = team.map_indexed(8, |_| worker_index());
        assert!(idxs.iter().all(|w| w.is_some()));
        assert!(idxs.iter().all(|w| w.unwrap() < 2));
    }

    #[test]
    fn global_team_is_resident_across_calls() {
        let t1 = WorkerTeam::global() as *const WorkerTeam;
        let t2 = WorkerTeam::global() as *const WorkerTeam;
        assert_eq!(t1, t2);
        assert!(WorkerTeam::global().width() >= 1);
    }
}
