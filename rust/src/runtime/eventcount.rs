//! Eventcount: the futex-style park/wake protocol behind the resident
//! engine (ARCHITECTURE.md §5.5).
//!
//! A classic eventcount decouples *what* a waiter is waiting for (checked
//! under the caller's own lock or atomics) from *how* it sleeps. The
//! protocol is two-phase:
//!
//! ```text
//!   waiter                                 notifier
//!   ──────                                 ────────
//!   key = ec.epoch()        ①
//!   check for work → none   ②             publish work        ③
//!   ec.wait(key, fallback)  ④             ec.notify_all()     ⑤
//! ```
//!
//! [`EventCount::notify_all`] bumps the epoch **after** the notifier has
//! published its work, so a waiter that read its key at ① and found
//! nothing at ② either (a) parks and is unparked by ⑤, or (b) observes
//! `epoch != key` inside [`EventCount::wait`] and never sleeps — the
//! missed-wakeup race of a naked `park()` is closed by the epoch check
//! under the sleeper-registry lock. Wake latency is one `unpark` (a futex
//! wake on Linux): **microseconds**, versus the 50 ms worst case of the
//! `Condvar::wait_timeout` tick it replaces in [`super::steal::StealBoard`].
//!
//! The `fallback` timeout is pure defence in depth (a bounded re-check
//! even if a notify were lost to a bug); correctness never depends on it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::thread::{self, Thread};
use std::time::{Duration, Instant};

/// A notify-all eventcount over `std::thread::park` (futex-backed on
/// Linux). See the module docs for the waiting protocol.
#[derive(Debug, Default)]
pub struct EventCount {
    /// Bumped once per notify; waiters key their sleep to the value they
    /// observed before checking for work.
    epoch: AtomicU64,
    /// Threads currently committed to sleeping on the current epoch.
    sleepers: Mutex<Vec<Thread>>,
}

impl EventCount {
    pub const fn new() -> Self {
        Self { epoch: AtomicU64::new(0), sleepers: Mutex::new(Vec::new()) }
    }

    /// Phase ① of the wait protocol: read the epoch **before** checking
    /// the condition you intend to sleep on.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// Wake every sleeper and invalidate every key handed out before this
    /// call. Call **after** publishing the work waiters look for.
    pub fn notify_all(&self) {
        self.epoch.fetch_add(1, Ordering::SeqCst);
        let mut sleepers = self.sleepers.lock().expect("EventCount sleepers poisoned");
        for t in sleepers.drain(..) {
            t.unpark();
        }
    }

    /// Phase ④: sleep until a notify invalidates `key`, or `fallback`
    /// elapses. Returns the parked duration (zero if the sleep was elided
    /// because a notify already landed). Spurious wakeups re-check and
    /// re-park; a stale `unpark` token from an earlier registration at
    /// worst makes one future park return immediately.
    pub fn wait(&self, key: u64, fallback: Duration) -> Duration {
        {
            let mut sleepers = self.sleepers.lock().expect("EventCount sleepers poisoned");
            if self.epoch.load(Ordering::SeqCst) != key {
                return Duration::ZERO; // the wake already happened
            }
            sleepers.push(thread::current());
        }
        let t0 = Instant::now();
        let deadline = t0 + fallback;
        while self.epoch.load(Ordering::SeqCst) == key {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            thread::park_timeout(deadline - now);
        }
        // Deregister (a fallback-timeout exit leaves us in the list; a
        // notify has already drained us — `retain` covers both).
        let me = thread::current().id();
        let mut sleepers = self.sleepers.lock().expect("EventCount sleepers poisoned");
        sleepers.retain(|t| t.id() != me);
        t0.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn notify_before_wait_elides_the_sleep() {
        let ec = EventCount::new();
        let key = ec.epoch();
        ec.notify_all();
        let parked = ec.wait(key, Duration::from_secs(5));
        assert_eq!(parked, Duration::ZERO, "stale key must not sleep");
    }

    #[test]
    fn notify_wakes_a_parked_waiter_fast() {
        let ec = Arc::new(EventCount::new());
        let flag = Arc::new(AtomicBool::new(false));
        let (ec2, flag2) = (Arc::clone(&ec), Arc::clone(&flag));
        let h = std::thread::spawn(move || {
            loop {
                let key = ec2.epoch();
                if flag2.load(Ordering::SeqCst) {
                    return;
                }
                // Fallback far above the test timeout: a lost wake hangs.
                ec2.wait(key, Duration::from_secs(60));
            }
        });
        std::thread::sleep(Duration::from_millis(20)); // let it park
        flag.store(true, Ordering::SeqCst);
        ec.notify_all();
        h.join().unwrap();
    }

    #[test]
    fn fallback_timeout_bounds_a_lost_wake() {
        let ec = EventCount::new();
        let key = ec.epoch();
        let t0 = Instant::now();
        ec.wait(key, Duration::from_millis(10));
        assert!(t0.elapsed() >= Duration::from_millis(10));
        assert!(t0.elapsed() < Duration::from_secs(5), "fallback must be bounded");
    }

    #[test]
    fn many_waiters_all_wake() {
        let ec = Arc::new(EventCount::new());
        let go = Arc::new(AtomicBool::new(false));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let (ec, go) = (Arc::clone(&ec), Arc::clone(&go));
                std::thread::spawn(move || {
                    loop {
                        let key = ec.epoch();
                        if go.load(Ordering::SeqCst) {
                            return;
                        }
                        ec.wait(key, Duration::from_secs(60));
                    }
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(20));
        go.store(true, Ordering::SeqCst);
        ec.notify_all();
        for h in handles {
            h.join().unwrap();
        }
    }
}
