//! Artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`): which models were lowered, with what shapes.

use super::ModelKind;
use crate::util::json::Json;
use crate::Result;
use anyhow::{anyhow, Context};
use std::collections::BTreeMap;
use std::path::Path;

/// Metadata of one lowered model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMeta {
    /// Artifact file name, relative to the artifacts directory.
    pub path: String,
    /// `[batch, seq_len, d_model]`.
    pub input_shape: [usize; 3],
    pub output_shape: [usize; 3],
}

/// Parsed manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub seq_len: usize,
    pub d_model: usize,
    pub batch: usize,
    pub models: BTreeMap<ModelKind, ModelMeta>,
}

fn shape3(j: &Json, key: &str) -> Result<[usize; 3]> {
    let arr = j
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest: missing array `{key}`"))?;
    if arr.len() != 3 {
        return Err(anyhow!("manifest: `{key}` must have 3 dims, got {}", arr.len()));
    }
    let mut out = [0usize; 3];
    for (o, v) in out.iter_mut().zip(arr) {
        *o = v.as_usize().ok_or_else(|| anyhow!("manifest: bad dim in `{key}`"))?;
    }
    Ok(out)
}

impl Manifest {
    /// Parse a manifest document.
    pub fn parse(doc: &str) -> Result<Self> {
        let j = Json::parse(doc).context("manifest.json")?;
        let field = |k: &str| {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest: missing numeric `{k}`"))
        };
        let mut models = BTreeMap::new();
        let model_obj = j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: missing `models`"))?;
        for (name, meta) in model_obj {
            let kind = ModelKind::from_name(name)
                .ok_or_else(|| anyhow!("manifest: unknown model `{name}`"))?;
            let path = meta
                .get("path")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest: `{name}` missing path"))?
                .to_string();
            models.insert(
                kind,
                ModelMeta {
                    path,
                    input_shape: shape3(meta, "input_shape")?,
                    output_shape: shape3(meta, "output_shape")?,
                },
            );
        }
        if models.is_empty() {
            return Err(anyhow!("manifest: no models"));
        }
        Ok(Self {
            seq_len: field("seq_len")?,
            d_model: field("d_model")?,
            batch: field("batch")?,
            models,
        })
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let doc = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
        "seq_len": 2048, "d_model": 32, "batch": 4, "seed": 0, "dtype": "f32",
        "models": {
            "hyena": {"path": "hyena.hlo.txt",
                      "input_shape": [4, 2048, 32],
                      "output_shape": [4, 2048, 32],
                      "sha256_16": "abc", "chars": 10}
        }
    }"#;

    #[test]
    fn parses_valid_manifest() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.seq_len, 2048);
        assert_eq!(m.batch, 4);
        let hy = &m.models[&ModelKind::Hyena];
        assert_eq!(hy.input_shape, [4, 2048, 32]);
        assert_eq!(hy.path, "hyena.hlo.txt");
    }

    #[test]
    fn rejects_unknown_model() {
        let doc = DOC.replace("\"hyena\"", "\"gpt2\"");
        assert!(Manifest::parse(&doc).is_err());
    }

    #[test]
    fn rejects_bad_shape() {
        let doc = DOC.replace("[4, 2048, 32]", "[4, 2048]");
        assert!(Manifest::parse(&doc).is_err());
    }

    #[test]
    fn rejects_empty_models() {
        let doc = r#"{"seq_len": 1, "d_model": 1, "batch": 1, "models": {}}"#;
        assert!(Manifest::parse(doc).is_err());
    }

    #[test]
    fn missing_numeric_key_names_the_key() {
        let doc = DOC.replace("\"seq_len\": 2048,", "");
        let err = Manifest::parse(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("seq_len"), "{err:#}");
        let doc = DOC.replace("\"batch\": 4,", "");
        assert!(format!("{:#}", Manifest::parse(&doc).unwrap_err()).contains("batch"));
    }

    #[test]
    fn rejects_wrong_dim_count_both_directions() {
        // Too few dims is covered by rejects_bad_shape; too many:
        let doc = DOC.replace("[4, 2048, 32]", "[4, 2048, 32, 1]");
        let err = Manifest::parse(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("3 dims"), "{err:#}");
    }

    #[test]
    fn rejects_non_integer_dim() {
        for bad in ["[4, 2048.5, 32]", "[4, \"x\", 32]", "[4, -2048, 32]"] {
            let doc = DOC.replace("[4, 2048, 32]", bad);
            let err = match Manifest::parse(&doc) {
                Err(e) => e,
                Ok(_) => panic!("dim {bad} must be rejected"),
            };
            assert!(format!("{err:#}").contains("dim"), "{bad}: {err:#}");
        }
    }

    #[test]
    fn rejects_missing_path_and_missing_models() {
        let doc = DOC.replace("\"path\": \"hyena.hlo.txt\",", "");
        let err = Manifest::parse(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("path"), "{err:#}");
        let doc = r#"{"seq_len": 1, "d_model": 1, "batch": 1}"#;
        assert!(format!("{:#}", Manifest::parse(doc).unwrap_err()).contains("models"));
    }
}
