//! Sharded work-stealing queues — the dispatch substrate that replaced the
//! coordinator's iteration-barrier lockstep (see ARCHITECTURE.md §5.4).
//!
//! Two layers, split so the scheduling *policy* is testable without
//! threads:
//!
//! * [`StealQueues`] — the pure data structure: one deque per chip plus
//!   per-chip `outstanding` (queued + executing) counters. No locks, no
//!   blocking; the deterministic interleaving stress test drives it
//!   single-threaded through randomized push/pop/steal/complete schedules.
//! * [`StealBoard`] — [`StealQueues`] behind a `Mutex` + an
//!   [`super::eventcount::EventCount`] with a `closed` flag: the blocking
//!   facade the coordinator's worker threads spin on. One lock for all
//!   chips is deliberate — claims are O(µs) bookkeeping while step
//!   execution (the millisecond part) runs with the lock released, so the
//!   lock is never held across real work. Idle workers park on the
//!   eventcount (µs wake on push) instead of the old 50 ms `Condvar`
//!   timeout tick; [`EVENT_LOOP_TICK`] survives only as the fallback
//!   re-check bound, and parked time is surfaced as `steal.park_us`.
//!
//! ## Ownership and stealing rules
//!
//! * Every item is pushed to its **home chip**'s deque (the chip holding
//!   the session's cached state). Workers prefer their own home deque and
//!   pop from the **front** (FIFO: oldest step first, preserving arrival
//!   order per chip).
//! * An idle worker **steals from the busiest** other chip — the one with
//!   the longest queue — from the **back** of that deque (the youngest
//!   work, the classic owner/thief split: the owner keeps draining the
//!   front undisturbed).
//! * Steal granularity is **one step**: steps are milliseconds, so single-
//!   step steals rebalance fast without batching heuristics.
//! * `outstanding` is charged to the item's **origin** chip from push until
//!   [`StealQueues::complete`] — a stolen step still counts against the
//!   chip that owns its session state, which is what the spill/restore
//!   budget accounting needs.

use super::eventcount::EventCount;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// The continuous coordinator's event-loop tick: how often its dispatch
/// loop re-checks timers/arrivals when nothing else wakes it, and the
/// fallback bound on every eventcount park ([`StealBoard::next`], the
/// resident team). Correctness never depends on it — pushes wake parked
/// threads in microseconds via the eventcount — it only bounds the damage
/// of a hypothetical lost wake. One named constant instead of scattered
/// `50`s so the coordinator and the parking paths cannot drift apart.
pub const EVENT_LOOP_TICK: Duration = Duration::from_millis(50);

/// An item claimed from the queues: the payload plus where it came from and
/// whether it was stolen (for telemetry and the completion credit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Claim<T> {
    /// The chip whose deque held the item (its origin/home chip).
    pub origin: usize,
    /// True when the claimant's home chip differs from `origin`.
    pub stolen: bool,
    /// The claimed work item.
    pub item: T,
}

/// Per-chip work deques with origin-charged outstanding counters. The pure
/// core of the work-stealing dispatcher — single-threaded by itself; wrap
/// it in [`StealBoard`] (or your own lock) to share across threads.
#[derive(Debug)]
pub struct StealQueues<T> {
    queues: Vec<VecDeque<T>>,
    /// Queued + executing items charged to each origin chip.
    outstanding: Vec<usize>,
}

impl<T> StealQueues<T> {
    /// Empty queues for `chips` chips (clamped to ≥ 1).
    pub fn new(chips: usize) -> Self {
        let chips = chips.max(1);
        Self { queues: (0..chips).map(|_| VecDeque::new()).collect(), outstanding: vec![0; chips] }
    }

    /// Number of chips (deques).
    pub fn chips(&self) -> usize {
        self.queues.len()
    }

    /// Enqueue an item on its home chip's deque.
    pub fn push(&mut self, chip: usize, item: T) {
        self.queues[chip].push_back(item);
        self.outstanding[chip] += 1;
    }

    /// Pop the oldest item queued on `home` (FIFO). The item stays charged
    /// to `home`'s outstanding count until [`Self::complete`].
    pub fn pop_home(&mut self, home: usize) -> Option<T> {
        self.queues[home].pop_front()
    }

    /// Steal the youngest item from the busiest chip other than `home`
    /// (back of the longest queue). Returns the origin chip with the item.
    pub fn steal_from_busiest(&mut self, home: usize) -> Option<(usize, T)> {
        let victim = (0..self.queues.len())
            .filter(|&c| c != home && !self.queues[c].is_empty())
            .max_by_key(|&c| self.queues[c].len())?;
        self.queues[victim].pop_back().map(|it| (victim, it))
    }

    /// Claim work for a worker homed on `home`: own deque first, then steal
    /// from the busiest other chip.
    pub fn claim(&mut self, home: usize) -> Option<Claim<T>> {
        if let Some(item) = self.pop_home(home) {
            return Some(Claim { origin: home, stolen: false, item });
        }
        self.steal_from_busiest(home)
            .map(|(origin, item)| Claim { origin, stolen: true, item })
    }

    /// Mark one item from `origin` finished, releasing its outstanding
    /// charge. Call with the `origin` of the [`Claim`], not the executing
    /// worker's home.
    pub fn complete(&mut self, origin: usize) {
        assert!(self.outstanding[origin] > 0, "StealQueues: complete({origin}) with none due");
        self.outstanding[origin] -= 1;
    }

    /// Items currently queued (not yet claimed) on `chip`.
    pub fn queued(&self, chip: usize) -> usize {
        self.queues[chip].len()
    }

    /// Items charged to `chip` (queued + executing).
    pub fn outstanding(&self, chip: usize) -> usize {
        self.outstanding[chip]
    }

    /// Total queued items across all chips.
    pub fn total_queued(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Total outstanding (queued + executing) items across all chips.
    pub fn total_outstanding(&self) -> usize {
        self.outstanding.iter().sum()
    }

    /// True when nothing is queued or executing anywhere.
    pub fn is_idle(&self) -> bool {
        self.total_outstanding() == 0
    }
}

/// The blocking facade over [`StealQueues`]: a single `Mutex` + an
/// [`EventCount`] plus a `closed` flag. Workers call [`StealBoard::next`]
/// in a loop and exit when it returns `None` (closed and fully drained).
#[derive(Debug)]
pub struct StealBoard<T> {
    inner: Mutex<BoardState<T>>,
    ec: EventCount,
}

#[derive(Debug)]
struct BoardState<T> {
    queues: StealQueues<T>,
    closed: bool,
}

impl<T> StealBoard<T> {
    /// A fresh open board for `chips` chips.
    pub fn new(chips: usize) -> Self {
        Self {
            inner: Mutex::new(BoardState { queues: StealQueues::new(chips), closed: false }),
            ec: EventCount::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BoardState<T>> {
        self.inner.lock().expect("StealBoard lock poisoned")
    }

    /// Enqueue one item on `chip`'s deque and wake the parked workers.
    pub fn push(&self, chip: usize, item: T) {
        self.lock().queues.push(chip, item);
        self.ec.notify_all();
    }

    /// Enqueue a batch on `chip`'s deque and wake all workers (a wave may
    /// hold work for several of them, stolen or not).
    pub fn push_many(&self, chip: usize, items: impl IntoIterator<Item = T>) {
        let mut st = self.lock();
        for it in items {
            st.queues.push(chip, it);
        }
        drop(st);
        self.ec.notify_all();
    }

    /// Block until work is claimable for a worker homed on `home` (own
    /// deque first, else steal from the busiest chip), or until the board
    /// is closed and every deque is empty — then `None`, the worker's exit
    /// signal. In-flight items elsewhere don't delay the `None`: execution
    /// happens outside the lock, and completion is reported via
    /// [`Self::complete`].
    ///
    /// Parking follows the eventcount protocol: the epoch key is read
    /// *before* the claim re-check, so a push that lands between the empty
    /// check and the park elides the sleep. Time actually spent parked is
    /// accumulated in the `steal.park_us` counter (with a per-wake
    /// `steal.park` trace instant) — park/wake stalls used to be invisible
    /// in Perfetto.
    pub fn next(&self, home: usize) -> Option<Claim<T>> {
        loop {
            let key = self.ec.epoch();
            {
                let mut st = self.lock();
                if let Some(c) = st.queues.claim(home) {
                    return Some(c);
                }
                if st.closed {
                    return None;
                }
            }
            let parked = self.ec.wait(key, EVENT_LOOP_TICK);
            if !parked.is_zero() {
                let us = parked.as_micros() as u64;
                steal_park_us_counter().fetch_add(us, Ordering::Relaxed);
                crate::telemetry::instant_arg("steal", "steal.park", "park_us", us as f64);
            }
        }
    }

    /// Release the outstanding charge of a finished claim (pass the claim's
    /// `origin`), waking the dispatcher if it is waiting for drain.
    pub fn complete(&self, origin: usize) {
        self.lock().queues.complete(origin);
        self.ec.notify_all();
    }

    /// Close the board: workers drain the remaining queued items and then
    /// exit as [`Self::next`] starts returning `None`.
    pub fn close(&self) {
        self.lock().closed = true;
        self.ec.notify_all();
    }

    /// Total outstanding (queued + executing) items across all chips.
    pub fn total_outstanding(&self) -> usize {
        self.lock().queues.total_outstanding()
    }

    /// Items currently queued (unclaimed) across all chips.
    pub fn total_queued(&self) -> usize {
        self.lock().queues.total_queued()
    }
}

/// `steal.park_us`: cumulative microseconds steal-board workers spent
/// parked waiting for work (resolved once; the hot path pays one add).
fn steal_park_us_counter() -> &'static AtomicU64 {
    static CELL: OnceLock<&'static AtomicU64> = OnceLock::new();
    CELL.get_or_init(|| crate::telemetry::counter("steal.park_us"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn home_pops_fifo_and_counts_outstanding() {
        let mut q = StealQueues::new(2);
        q.push(0, 'a');
        q.push(0, 'b');
        assert_eq!(q.queued(0), 2);
        assert_eq!(q.outstanding(0), 2);
        assert_eq!(q.pop_home(0), Some('a'), "home pops oldest first");
        assert_eq!(q.queued(0), 1);
        assert_eq!(q.outstanding(0), 2, "claimed-but-running stays charged");
        q.complete(0);
        assert_eq!(q.outstanding(0), 1);
        assert_eq!(q.pop_home(1), None);
    }

    #[test]
    fn steal_takes_youngest_from_busiest_other_chip() {
        let mut q = StealQueues::new(3);
        q.push(1, 10);
        q.push(2, 20);
        q.push(2, 21);
        q.push(2, 22);
        let (victim, item) = q.steal_from_busiest(0).unwrap();
        assert_eq!((victim, item), (2, 22), "busiest chip, back of its deque");
        assert_eq!(q.outstanding(2), 3, "steal keeps the origin charge");
        q.complete(2);
        assert_eq!(q.outstanding(2), 2);
        // Never steals from its own home even when home is busiest.
        let mut own = StealQueues::new(2);
        own.push(0, 1);
        own.push(0, 2);
        assert_eq!(own.steal_from_busiest(0), None);
    }

    #[test]
    fn claim_prefers_home_then_steals() {
        let mut q = StealQueues::new(2);
        q.push(0, 'h');
        q.push(1, 's');
        let first = q.claim(0).unwrap();
        assert_eq!((first.origin, first.stolen, first.item), (0, false, 'h'));
        let second = q.claim(0).unwrap();
        assert_eq!((second.origin, second.stolen, second.item), (1, true, 's'));
        assert_eq!(q.claim(0), None);
        assert!(!q.is_idle(), "two claims still executing");
        q.complete(0);
        q.complete(1);
        assert!(q.is_idle());
    }

    #[test]
    #[should_panic(expected = "complete(0) with none due")]
    fn complete_without_outstanding_panics() {
        StealQueues::<u8>::new(1).complete(0);
    }

    #[test]
    fn board_drains_then_workers_exit_on_close() {
        let board = Arc::new(StealBoard::new(2));
        let done = Arc::new(AtomicUsize::new(0));
        for item in 0..10 {
            board.push(item % 2, item);
        }
        let handles: Vec<_> = (0..3)
            .map(|wid| {
                let board = Arc::clone(&board);
                let done = Arc::clone(&done);
                std::thread::spawn(move || {
                    let home = wid % 2;
                    while let Some(claim) = board.next(home) {
                        done.fetch_add(1, Ordering::Relaxed);
                        board.complete(claim.origin);
                    }
                })
            })
            .collect();
        // Wait for drain, then close; workers must all exit.
        while board.total_outstanding() > 0 {
            std::thread::yield_now();
        }
        board.close();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(done.load(Ordering::Relaxed), 10, "every item ran exactly once");
        assert_eq!(board.total_queued(), 0);
    }

    #[test]
    fn push_wakes_a_parked_worker_before_the_fallback_tick() {
        // The eventcount must deliver a push to a parked worker in
        // microseconds; well under one EVENT_LOOP_TICK is the loose,
        // scheduler-noise-proof bound we assert.
        let board = Arc::new(StealBoard::new(1));
        let board2 = Arc::clone(&board);
        let h = std::thread::spawn(move || board2.next(0));
        std::thread::sleep(Duration::from_millis(20)); // let it park
        let t0 = Instant::now();
        board.push(0, 42);
        let claim = h.join().unwrap().expect("board is open");
        assert!(
            t0.elapsed() < EVENT_LOOP_TICK,
            "wake took {:?}, expected well under the {:?} fallback tick",
            t0.elapsed(),
            EVENT_LOOP_TICK
        );
        assert_eq!(claim.item, 42);
        board.complete(claim.origin);
        board.close();
    }

    #[test]
    fn close_with_queued_work_still_drains() {
        let board = Arc::new(StealBoard::new(1));
        board.push_many(0, 0..5);
        board.close();
        let board2 = Arc::clone(&board);
        let h = std::thread::spawn(move || {
            let mut got = 0;
            while let Some(claim) = board2.next(0) {
                got += 1;
                board2.complete(claim.origin);
            }
            got
        });
        assert_eq!(h.join().unwrap(), 5, "closing does not drop queued work");
    }
}
