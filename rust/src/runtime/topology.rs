//! Host topology probe and home-worker placement (ARCHITECTURE.md §5.5).
//!
//! The steal scheduler gives every worker a **home chip** whose deque (and
//! `StateCache`) it serves first. Placement decides which chip that is.
//! Two policies:
//!
//! * **Block homing** (default): workers serving the same chip are
//!   *contiguous* (`worker_homes` via [`block_homes`]). On multi-socket
//!   hosts adjacent threads overwhelmingly land on the same NUMA node, so
//!   a chip's deque, its cached state, and the scratch arenas its workers
//!   first-touched all stay node-local. [`Topology::probe`] reads
//!   `/sys/devices/system/node/node*/cpulist` to report how many nodes
//!   the host actually has (no `/sys` → one node, a clean no-op).
//! * **Round-robin** (`SSM_RDU_PIN_HOMES=0`): the pre-PR-9 `wid % chips`
//!   interleave, kept as the opt-out and for A/B runs.
//!
//! Both policies serve every chip that can be served (`min(workers,
//! chips)` distinct homes) — only the *grouping* differs, so scheduling
//! results stay bit-identical either way (homing is a locality hint, not
//! a correctness input). The `/sys` parsing is split into pure helpers
//! ([`parse_cpulist`]) so the probe is testable without real sysfs.

use super::pool::chunk_ranges;
use std::path::Path;

/// NUMA layout of the host: the CPU ids of each node.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    nodes: Vec<Vec<usize>>,
}

impl Topology {
    /// Probe `/sys/devices/system/node`. Hosts without the tree (non-Linux,
    /// containers with masked sysfs) get a single node holding the
    /// machine's available parallelism — every policy then degrades to the
    /// single-node behaviour, by construction a no-op.
    pub fn probe() -> Self {
        Self::from_sysfs(Path::new("/sys/devices/system/node"))
    }

    /// Probe an arbitrary sysfs-shaped directory (tests point this at a
    /// fixture tree).
    pub fn from_sysfs(root: &Path) -> Self {
        let mut nodes: Vec<(usize, Vec<usize>)> = Vec::new();
        if let Ok(entries) = std::fs::read_dir(root) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let name = name.to_string_lossy();
                let Some(idx) = name.strip_prefix("node").and_then(|s| s.parse::<usize>().ok())
                else {
                    continue;
                };
                let Ok(list) = std::fs::read_to_string(entry.path().join("cpulist")) else {
                    continue;
                };
                let cpus = parse_cpulist(&list);
                if !cpus.is_empty() {
                    nodes.push((idx, cpus));
                }
            }
        }
        nodes.sort_by_key(|(idx, _)| *idx);
        let nodes: Vec<Vec<usize>> = nodes.into_iter().map(|(_, cpus)| cpus).collect();
        if nodes.is_empty() {
            let width = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            return Self { nodes: vec![(0..width).collect()] };
        }
        Self { nodes }
    }

    /// Number of NUMA nodes (≥ 1).
    pub fn nodes(&self) -> usize {
        self.nodes.len()
    }

    /// CPU ids belonging to `node`.
    pub fn cpus(&self, node: usize) -> &[usize] {
        &self.nodes[node]
    }
}

/// Parse a sysfs `cpulist` string (`"0-3,8,10-11"`) into CPU ids.
/// Malformed fragments are skipped rather than failing the probe — a
/// placement hint must never take the server down.
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((lo, hi)) = part.split_once('-') {
            if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<usize>(), hi.trim().parse::<usize>()) {
                if lo <= hi {
                    out.extend(lo..=hi);
                }
            }
        } else if let Ok(v) = part.parse::<usize>() {
            out.push(v);
        }
    }
    out
}

/// Block homing: worker `w` serves the chip whose contiguous worker block
/// contains `w` (balanced blocks, first `workers % chips` blocks one
/// wider — the same split as `chunk_ranges`).
pub fn block_homes(workers: usize, chips: usize) -> Vec<usize> {
    let mut homes = vec![0usize; workers];
    for (c, r) in chunk_ranges(workers, chips.max(1)).iter().enumerate() {
        for w in r.clone() {
            homes[w] = c;
        }
    }
    homes
}

/// Round-robin homing: the pre-PR-9 `wid % chips` interleave.
pub fn round_robin_homes(workers: usize, chips: usize) -> Vec<usize> {
    (0..workers).map(|w| w % chips.max(1)).collect()
}

/// Home-chip assignment for `workers` steal workers over `chips` chips:
/// block homing unless `SSM_RDU_PIN_HOMES` is `0`/`off`/`false` (then the
/// legacy round-robin interleave).
pub fn worker_homes(workers: usize, chips: usize) -> Vec<usize> {
    let pin = std::env::var("SSM_RDU_PIN_HOMES")
        .map(|v| !matches!(v.trim(), "0" | "off" | "false"))
        .unwrap_or(true);
    if pin {
        block_homes(workers, chips)
    } else {
        round_robin_homes(workers, chips)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_parses_ranges_singles_and_junk() {
        assert_eq!(parse_cpulist("0-3,8,10-11\n"), vec![0, 1, 2, 3, 8, 10, 11]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("2-1"), Vec::<usize>::new(), "inverted range skipped");
        assert_eq!(parse_cpulist("x,3,4-y,7"), vec![3, 7], "malformed fragments skipped");
    }

    #[test]
    fn block_homes_are_contiguous_and_cover_served_chips() {
        for &(workers, chips) in &[(8usize, 2usize), (7, 3), (4, 4), (3, 8), (1, 1), (16, 5)] {
            let homes = block_homes(workers, chips);
            assert_eq!(homes.len(), workers);
            // Non-decreasing ⇒ contiguous blocks.
            assert!(homes.windows(2).all(|w| w[0] <= w[1]), "{workers}x{chips}: {homes:?}");
            let served: std::collections::HashSet<_> = homes.iter().collect();
            assert_eq!(served.len(), workers.min(chips), "{workers}x{chips}: {homes:?}");
            assert!(homes.iter().all(|&c| c < chips.max(1)));
        }
    }

    #[test]
    fn block_and_round_robin_serve_the_same_chip_set() {
        for &(workers, chips) in &[(8usize, 2usize), (7, 3), (3, 8)] {
            let a: std::collections::HashSet<_> = block_homes(workers, chips).into_iter().collect();
            let b: std::collections::HashSet<_> =
                round_robin_homes(workers, chips).into_iter().collect();
            assert_eq!(a, b, "{workers}x{chips}");
        }
    }

    #[test]
    fn probe_without_sysfs_degrades_to_one_node() {
        let topo = Topology::from_sysfs(Path::new("/nonexistent/sysfs"));
        assert_eq!(topo.nodes(), 1);
        assert!(!topo.cpus(0).is_empty());
    }

    #[test]
    fn probe_reads_a_fixture_tree() {
        let dir = std::env::temp_dir().join("ssm_rdu_topo_fixture");
        let _ = std::fs::remove_dir_all(&dir);
        for (node, list) in [("node0", "0-3\n"), ("node1", "4-7\n")] {
            let d = dir.join(node);
            std::fs::create_dir_all(&d).unwrap();
            std::fs::write(d.join("cpulist"), list).unwrap();
        }
        // Entries that must be ignored: non-node dirs, nodes sans cpulist.
        std::fs::create_dir_all(dir.join("possible")).unwrap();
        std::fs::create_dir_all(dir.join("node9")).unwrap();
        let topo = Topology::from_sysfs(&dir);
        assert_eq!(topo.nodes(), 2);
        assert_eq!(topo.cpus(0), &[0, 1, 2, 3]);
        assert_eq!(topo.cpus(1), &[4, 5, 6, 7]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn real_probe_never_panics_and_has_a_node() {
        let topo = Topology::probe();
        assert!(topo.nodes() >= 1);
    }
}
