//! RDU chip-level specification (paper Table I) and configuration.

use super::mem::MemTech;
use super::pcu::{PcuGeometry, PcuMode};
use crate::util::table::Table;
use std::collections::BTreeSet;
use std::fmt;

/// Chip-level architectural specification of the RDU (paper Table I).
#[derive(Debug, Clone, PartialEq)]
pub struct RduSpec {
    /// Number of Pattern Compute Units on the die.
    pub n_pcu: usize,
    /// Geometry of each PCU.
    pub pcu: PcuGeometry,
    /// Number of Pattern Memory Units on the die.
    pub n_pmu: usize,
    /// SRAM capacity of each PMU in bytes.
    pub pmu_bytes: usize,
    /// Clock frequency in Hz.
    pub clock_hz: f64,
    /// Off-chip memory technology.
    pub dram: MemTech,
}

impl RduSpec {
    /// The paper's Table I configuration:
    /// 520 PCUs (32×12), 520 PMUs (1.5 MB), 1.6 GHz, 8 TB/s HBM3e.
    pub fn table1() -> Self {
        Self {
            n_pcu: 520,
            pcu: PcuGeometry::table1(),
            n_pmu: 520,
            pmu_bytes: (1.5 * (1 << 20) as f64) as usize,
            clock_hz: 1.6e9,
            dram: MemTech::Hbm3e,
        }
    }

    /// Peak chip FLOP/s (FP16): `n_pcu × lanes × stages × 2 × clock`.
    ///
    /// For Table I: 520 × 384 × 2 × 1.6 GHz = 638.98 TFLOPS — the paper
    /// rounds this to "640 TFLOPS" in Table I and uses the exact value in
    /// Tables II/III.
    pub fn peak_flops(&self) -> f64 {
        self.n_pcu as f64 * self.pcu.peak_flops(self.clock_hz)
    }

    /// Total on-chip SRAM in bytes (520 × 1.5 MB = 780 MB for Table I).
    pub fn sram_bytes(&self) -> usize {
        self.n_pmu * self.pmu_bytes
    }

    /// Off-chip bandwidth in bytes/s.
    pub fn dram_bandwidth(&self) -> f64 {
        self.dram.bandwidth()
    }

    /// Render the Table I specification block.
    pub fn table1_report(&self) -> Table {
        let mut t = Table::new("TABLE I — RDU architectural specification", &["Specification", "Value"]);
        t.row(&["Compute".into(), format!("{} PCUs, {} each", self.n_pcu, self.pcu)]);
        t.row(&[
            "On-chip SRAM".into(),
            format!("{} PMUs, {:.1} MB each", self.n_pmu, self.pmu_bytes as f64 / (1 << 20) as f64),
        ]);
        t.row(&[
            "Clock frequency".into(),
            // Table I rounds 638.98 to "640TFLOPS"; match that rounding.
            format!(
                "{:.1}GHz, {:.0}TFLOPS FP16",
                self.clock_hz / 1e9,
                (self.peak_flops() / 1e13).round() * 10.0
            ),
        ]);
        t.row(&["Off-chip DRAM".into(), format!("{}", self.dram)]);
        t
    }
}

/// An RDU configuration = chip spec + the set of PCU interconnect extensions
/// fabricated into the tiles. The paper evaluates:
///   * baseline        — no extensions,
///   * FFT-mode RDU    — `{Fft}`,
///   * HS-scan-mode    — `{HsScan}`,
///   * B-scan-mode     — `{BScan}`.
#[derive(Debug, Clone, PartialEq)]
pub struct RduConfig {
    pub spec: RduSpec,
    /// Extension modes available in every PCU (baseline modes are always
    /// available).
    pub extensions: BTreeSet<PcuMode>,
}

impl RduConfig {
    /// Baseline RDU: Table I spec, no interconnect extensions.
    pub fn baseline() -> Self {
        Self { spec: RduSpec::table1(), extensions: BTreeSet::new() }
    }

    /// FFT-mode RDU (paper §III-B).
    pub fn fft_mode() -> Self {
        Self::baseline().with_extension(PcuMode::Fft)
    }

    /// HS-scan-mode RDU (paper §IV-B).
    pub fn hs_scan_mode() -> Self {
        Self::baseline().with_extension(PcuMode::HsScan)
    }

    /// B-scan-mode RDU (paper §IV-B).
    pub fn b_scan_mode() -> Self {
        Self::baseline().with_extension(PcuMode::BScan)
    }

    /// Add one extension mode.
    pub fn with_extension(mut self, mode: PcuMode) -> Self {
        assert!(mode.is_extension(), "{mode} is a baseline mode, not an extension");
        self.extensions.insert(mode);
        self
    }

    /// Replace the chip spec (for scaled/ablation studies).
    pub fn with_spec(mut self, spec: RduSpec) -> Self {
        self.spec = spec;
        self
    }

    /// Is `mode` available in this configuration's PCUs?
    pub fn supports(&self, mode: PcuMode) -> bool {
        !mode.is_extension() || self.extensions.contains(&mode)
    }

    /// Human-readable configuration name, matching the paper's design labels.
    pub fn name(&self) -> String {
        if self.extensions.is_empty() {
            "baseline RDU".to_string()
        } else {
            let modes: Vec<&str> = self.extensions.iter().map(|m| m.label()).collect();
            format!("{}-mode RDU", modes.join("+"))
        }
    }
}

impl fmt::Display for RduConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_peak_is_paper_63898_tflops() {
        // Table II lists the RDU at 638.98 TFLOPS; Table I rounds to 640.
        let spec = RduSpec::table1();
        let tflops = spec.peak_flops() / 1e12;
        assert!((tflops - 638.98).abs() < 0.01, "got {tflops}");
    }

    #[test]
    fn table1_sram_is_780_mb() {
        let spec = RduSpec::table1();
        assert_eq!(spec.sram_bytes(), 520 * (1536 << 10));
    }

    #[test]
    fn baseline_supports_only_baseline_modes() {
        let cfg = RduConfig::baseline();
        for m in PcuMode::BASELINE {
            assert!(cfg.supports(m), "{m}");
        }
        for m in PcuMode::EXTENSIONS {
            assert!(!cfg.supports(m), "{m}");
        }
    }

    #[test]
    fn fft_mode_adds_only_fft() {
        let cfg = RduConfig::fft_mode();
        assert!(cfg.supports(PcuMode::Fft));
        assert!(!cfg.supports(PcuMode::HsScan));
        assert!(!cfg.supports(PcuMode::BScan));
        assert_eq!(cfg.name(), "fft-mode RDU");
    }

    #[test]
    #[should_panic]
    fn baseline_mode_as_extension_panics() {
        RduConfig::baseline().with_extension(PcuMode::Systolic);
    }

    #[test]
    fn table1_report_renders() {
        let r = RduSpec::table1().table1_report().render();
        assert!(r.contains("520 PCUs, 32x12 each"), "{r}");
        assert!(r.contains("640TFLOPS"), "{r}");
    }
}
