//! GPU architectural specification (paper Tables II and III).
//!
//! The paper's GPU baseline is an NVIDIA A100 whose FP16 throughput is split
//! between tensor cores (GEMM-shaped kernels) and CUDA cores (everything
//! else), with the CUDA-core path at ¼ the tensor-core throughput. For the
//! cross-platform studies all platforms are given the same 8 TB/s HBM3e.

use super::mem::MemTech;

/// GPU specification used by the analytical model in [`crate::gpu`].
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// Peak FP16 tensor-core FLOP/s (GEMM path).
    pub tensor_flops: f64,
    /// Peak FP16 CUDA-core FLOP/s (vector path: FFT butterflies, scans,
    /// element-wise, softmax).
    pub cuda_flops: f64,
    /// Off-chip memory.
    pub dram: MemTech,
}

impl GpuSpec {
    /// Table II/III A100: 311.87 TFLOPS GEMM, 77.97 TFLOPS vector,
    /// modeled with 8 TB/s HBM3e like the RDU for a fair comparison.
    pub fn a100() -> Self {
        Self {
            name: "NVIDIA A100".to_string(),
            tensor_flops: 311.87e12,
            cuda_flops: 77.97e12,
            dram: MemTech::Hbm3e,
        }
    }

    /// Tensor-core : CUDA-core throughput ratio (paper: "the tensor cores
    /// offer 4× higher compute throughput compared to the CUDA cores").
    pub fn tensor_to_cuda_ratio(&self) -> f64 {
        self.tensor_flops / self.cuda_flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_matches_table2() {
        let g = GpuSpec::a100();
        assert!((g.tensor_flops / 1e12 - 311.87).abs() < 1e-9);
        assert!((g.cuda_flops / 1e12 - 77.97).abs() < 1e-9);
    }

    #[test]
    fn tensor_cores_are_4x_cuda_cores() {
        let r = GpuSpec::a100().tensor_to_cuda_ratio();
        assert!((r - 4.0).abs() < 0.01, "ratio={r}");
    }
}
