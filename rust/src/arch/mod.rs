//! Architecture descriptions: the RDU chip (paper Table I), its PCU geometry
//! and execution modes, and the comparison platforms (A100 GPU, VGA ASIC —
//! Tables II/III) plus memory technologies.
//!
//! This module holds *specifications only*; behaviour lives in
//! [`crate::pcusim`] (cycle-level PCU simulation), [`crate::dfmodel`] (RDU
//! performance model), [`crate::gpu`] and [`crate::vga`] (comparison models).

pub mod gpu;
pub mod mem;
pub mod pcu;
pub mod rdu;
pub mod vga;

pub use gpu::GpuSpec;
pub use mem::MemTech;
pub use pcu::{PcuGeometry, PcuMode};
pub use rdu::{RduConfig, RduSpec};
pub use vga::VgaSpec;
