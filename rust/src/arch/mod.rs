//! Architecture descriptions: the RDU chip (paper Table I), its PCU geometry
//! and execution modes, the comparison platforms (A100 GPU, VGA ASIC —
//! Tables II/III), memory technologies, and the inter-chip interconnect used
//! by the multi-chip sharding subsystem.
//!
//! This module holds *specifications only*; behaviour lives in
//! [`crate::pcusim`] (cycle-level PCU simulation), [`crate::dfmodel`] (RDU
//! performance model), [`crate::gpu`] and [`crate::vga`] (comparison models),
//! and [`crate::shard`] (multi-chip dataflows over [`interchip`] links).

pub mod gpu;
pub mod interchip;
pub mod mem;
pub mod pcu;
pub mod rdu;
pub mod vga;

pub use gpu::GpuSpec;
pub use interchip::{prefix_exchange_steps, InterchipLink};
pub use mem::MemTech;
pub use pcu::{PcuGeometry, PcuMode};
pub use rdu::{RduConfig, RduSpec};
pub use vga::VgaSpec;
