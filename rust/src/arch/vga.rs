//! VGA fixed-function ASIC specification (paper Table II, ref. [22]).
//!
//! VGA is a domain-specific accelerator for long-sequence model inference
//! supporting GEMM and FFT pipelines. The paper scales its configuration to
//! match the RDU's compute throughput (655.36 TFLOPS for both GEMM and FFT)
//! and gives it the same 8 TB/s HBM3e. VGA has *no* scan support — the paper
//! uses this to argue the RDU's generality (it cannot run Mamba).

use super::mem::MemTech;

/// VGA specification used by the analytical model in [`crate::vga`].
#[derive(Debug, Clone, PartialEq)]
pub struct VgaSpec {
    pub name: String,
    /// Peak FP16 FLOP/s of the GEMM pipeline.
    pub gemm_flops: f64,
    /// Peak FP16 FLOP/s of the FFT pipeline.
    pub fft_flops: f64,
    /// Off-chip memory.
    pub dram: MemTech,
}

impl VgaSpec {
    /// Table II configuration: scaled to RDU-class throughput.
    pub fn table2() -> Self {
        Self {
            name: "VGA (scaled)".to_string(),
            gemm_flops: 655.36e12,
            fft_flops: 655.36e12,
            dram: MemTech::Hbm3e,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_vga_throughput() {
        let v = VgaSpec::table2();
        assert_eq!(v.gemm_flops, 655.36e12);
        assert_eq!(v.fft_flops, v.gemm_flops);
    }
}
