//! Inter-chip interconnect model: the link and collective-cost abstractions
//! behind multi-chip sequence sharding ([`crate::shard`]).
//!
//! The paper maps one decoder onto one RDU; past a single die the sharded
//! dataflows of [`crate::shard`] add an inter-chip communication term. This
//! module prices the point-to-point primitive and the three collective
//! exchange patterns built on it:
//!
//! * **point-to-point** — [`InterchipLink::transfer_seconds`]: one message,
//!   `latency + bytes / bandwidth` (the α–β model).
//! * **all-to-all** — [`InterchipLink::all_to_all_seconds`]: the distributed
//!   FFT's row/column transpose; every chip exchanges a personalized slice
//!   with every peer over `P − 1` rounds.
//! * **ring all-reduce** — [`InterchipLink::ring_allreduce_seconds`]: the
//!   tensor-sharded decode step's per-layer activation reduction,
//!   `2·(P − 1)` steps of `bytes / P` each.
//! * **prefix (carry) exchange** — [`InterchipLink::prefix_exchange_seconds`]:
//!   the sharded Blelloch scan's inter-chip exclusive-prefix of per-chip
//!   carries, an up-sweep plus down-sweep of `⌈log₂P⌉` rounds each.
//!
//! Like [`super::mem::MemTech`], this is a *specification*: pure cost
//! arithmetic consumed by [`crate::dfmodel`] and [`crate::shard::estimate`].

use std::fmt;

/// One inter-chip link: sustained bandwidth plus per-message latency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InterchipLink {
    /// Sustained per-link bandwidth in bytes/second.
    pub bandwidth: f64,
    /// Per-message overhead in seconds (serialization + switch traversal).
    pub latency: f64,
}

impl InterchipLink {
    /// Accelerator-fabric class link (NVLink/ICI-class): 600 GB/s, 1 µs.
    pub fn rdu_fabric() -> Self {
        Self { bandwidth: 600e9, latency: 1e-6 }
    }

    /// Host-interconnect class link (PCIe 5.0 x16): 64 GB/s, 2 µs.
    pub fn pcie5() -> Self {
        Self { bandwidth: 64e9, latency: 2e-6 }
    }

    /// Custom link parameters.
    pub fn custom(bandwidth: f64, latency: f64) -> Self {
        Self { bandwidth, latency }
    }

    /// One point-to-point message of `bytes` (α–β cost). Zero bytes cost
    /// nothing — no message is sent.
    pub fn transfer_seconds(&self, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        self.latency + bytes / self.bandwidth
    }

    /// All-to-all personalized exchange among `chips` peers where each chip
    /// holds `bytes_per_chip` of the redistributed tensor: `P − 1` rounds,
    /// each moving a `bytes_per_chip / P` slice to one peer.
    pub fn all_to_all_seconds(&self, chips: usize, bytes_per_chip: f64) -> f64 {
        if chips <= 1 {
            return 0.0;
        }
        let p = chips as f64;
        (p - 1.0) * self.transfer_seconds(bytes_per_chip / p)
    }

    /// Ring all-reduce of a replicated `bytes` tensor: reduce-scatter plus
    /// all-gather, `2·(P − 1)` steps of `bytes / P` each.
    pub fn ring_allreduce_seconds(&self, chips: usize, bytes: f64) -> f64 {
        if chips <= 1 {
            return 0.0;
        }
        let p = chips as f64;
        2.0 * (p - 1.0) * self.transfer_seconds(bytes / p)
    }

    /// Inter-chip exclusive-prefix carry exchange (sharded Blelloch scan):
    /// an up-sweep and a down-sweep of `⌈log₂P⌉` rounds each, every round
    /// moving one `bytes` carry between chip pairs.
    pub fn prefix_exchange_seconds(&self, chips: usize, bytes: f64) -> f64 {
        prefix_exchange_steps(chips) as f64 * self.transfer_seconds(bytes)
    }
}

impl fmt::Display for InterchipLink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{:.0} GB/s link, {:.1} µs latency",
            self.bandwidth / 1e9,
            self.latency * 1e6
        )
    }
}

/// Rounds of the inter-chip exclusive-prefix exchange: `2·⌈log₂P⌉`
/// (Blelloch up-sweep + down-sweep across chips), 0 for a single chip.
pub fn prefix_exchange_steps(chips: usize) -> usize {
    if chips <= 1 {
        return 0;
    }
    2 * ceil_log2(chips)
}

/// `⌈log₂n⌉` for `n ≥ 1`.
fn ceil_log2(n: usize) -> usize {
    (usize::BITS - (n - 1).leading_zeros()) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_is_alpha_beta() {
        let l = InterchipLink::custom(100e9, 1e-6);
        // 100 GB at 100 GB/s = 1 s + 1 µs latency.
        assert!((l.transfer_seconds(100e9) - (1.0 + 1e-6)).abs() < 1e-12);
        assert_eq!(l.transfer_seconds(0.0), 0.0, "no message, no cost");
    }

    #[test]
    fn single_chip_collectives_are_free() {
        let l = InterchipLink::rdu_fabric();
        assert_eq!(l.all_to_all_seconds(1, 1e9), 0.0);
        assert_eq!(l.ring_allreduce_seconds(1, 1e9), 0.0);
        assert_eq!(l.prefix_exchange_seconds(1, 1e9), 0.0);
        assert_eq!(prefix_exchange_steps(1), 0);
    }

    #[test]
    fn prefix_steps_are_two_log2() {
        assert_eq!(prefix_exchange_steps(2), 2);
        assert_eq!(prefix_exchange_steps(4), 4);
        assert_eq!(prefix_exchange_steps(8), 6);
        // Non-power-of-two chip counts round the tree depth up.
        assert_eq!(prefix_exchange_steps(5), 6);
    }

    #[test]
    fn all_to_all_grows_with_chips_at_fixed_total() {
        // Strong scaling: total tensor fixed, per-chip share shrinks, but
        // latency-bound rounds grow — more chips must not get cheaper
        // once latency dominates.
        let l = InterchipLink::rdu_fabric();
        let total = 1e6; // 1 MB tensor
        let t2 = l.all_to_all_seconds(2, total / 2.0);
        let t8 = l.all_to_all_seconds(8, total / 8.0);
        assert!(t2 > 0.0 && t8 > 0.0);
        // At 8 chips, 7 rounds × 1 µs latency alone exceeds the 2-chip time.
        assert!(t8 > 7.0 * l.latency * 0.999, "t8={t8}");
    }

    #[test]
    fn ring_allreduce_latency_bound_for_small_tensors() {
        let l = InterchipLink::rdu_fabric();
        // A tiny activation vector: cost is dominated by 2(P-1) latencies.
        let t = l.ring_allreduce_seconds(4, 128.0);
        assert!((t - 6.0 * l.transfer_seconds(32.0)).abs() < 1e-15);
    }
}
