//! Pattern Compute Unit (PCU) geometry and execution modes (paper §II-A, Fig. 2).
//!
//! A PCU is a pipelined SIMD array of `lanes × stages` functional units (FUs).
//! Each FU has four input sources (two lane-dimension, one stage-dimension,
//! one constant) and supports scalar add, scalar multiply and MAC. The paper's
//! contribution is three *additional* cross-lane interconnect fabrics between
//! pipeline stages — FFT butterflies, Hillis–Steele shifts and Blelloch tree
//! links — enabling spatial mapping of FFT and scan dataflows.

use std::fmt;

/// Execution mode of a PCU. The first three are the baseline modes of the
/// Plasticine/SambaNova-style RDU (paper Fig. 2); the last three are the
/// paper's proposed extensions (Figs. 5 and 10).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PcuMode {
    /// Data flows left→right, lane-parallel; no cross-lane traffic.
    ElementWise,
    /// Data flows left→right and top→down; MAC chains for GEMM.
    Systolic,
    /// Left→right with an inter-stage reduction-tree interconnect.
    Reduction,
    /// Paper §III-B: butterfly interconnects between pipeline stages so a
    /// radix-2 FFT unrolls spatially across the pipeline.
    Fft,
    /// Paper §IV-B: Hillis–Steele shift interconnects (lane *i* also reads
    /// lane *i − 2^s* at stage boundary *s*).
    HsScan,
    /// Paper §IV-B: Blelloch up-sweep/down-sweep tree interconnects.
    BScan,
}

impl PcuMode {
    /// The three baseline modes every RDU PCU supports.
    pub const BASELINE: [PcuMode; 3] = [PcuMode::ElementWise, PcuMode::Systolic, PcuMode::Reduction];

    /// The paper's proposed extension modes.
    pub const EXTENSIONS: [PcuMode; 3] = [PcuMode::Fft, PcuMode::HsScan, PcuMode::BScan];

    /// Is this one of the paper's proposed extension modes?
    pub fn is_extension(self) -> bool {
        matches!(self, PcuMode::Fft | PcuMode::HsScan | PcuMode::BScan)
    }

    /// Short label used in tables and reports.
    pub fn label(self) -> &'static str {
        match self {
            PcuMode::ElementWise => "element-wise",
            PcuMode::Systolic => "systolic",
            PcuMode::Reduction => "reduction",
            PcuMode::Fft => "fft",
            PcuMode::HsScan => "hs-scan",
            PcuMode::BScan => "b-scan",
        }
    }
}

impl fmt::Display for PcuMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Physical shape of a PCU's FU array.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PcuGeometry {
    /// SIMD width (vertical dimension in Fig. 2).
    pub lanes: usize,
    /// Pipeline depth (horizontal dimension in Fig. 2).
    pub stages: usize,
}

impl PcuGeometry {
    /// Construct a geometry; lanes must be a power of two (the butterfly and
    /// scan fabrics are defined on power-of-two lane counts).
    pub fn new(lanes: usize, stages: usize) -> Self {
        assert!(lanes.is_power_of_two(), "PCU lanes must be a power of two, got {lanes}");
        assert!(stages > 0, "PCU needs at least one pipeline stage");
        Self { lanes, stages }
    }

    /// The production-scale PCU of Table I: 32 lanes × 12 stages.
    pub fn table1() -> Self {
        Self::new(32, 12)
    }

    /// The synthesis-study PCU of §V / Table IV: 8 lanes × 6 stages.
    pub fn synthesis() -> Self {
        Self::new(8, 6)
    }

    /// Total functional units in the array.
    pub fn fu_count(self) -> usize {
        self.lanes * self.stages
    }

    /// Peak FLOP/s of one PCU at `clock_hz`: every FU retires one MAC
    /// (2 flops) per cycle.
    pub fn peak_flops(self, clock_hz: f64) -> f64 {
        self.fu_count() as f64 * 2.0 * clock_hz
    }

    /// Number of radix-2 butterfly / scan levels for a full-width tile:
    /// `log₂(lanes)`.
    pub fn levels(self) -> usize {
        self.lanes.trailing_zeros() as usize
    }

    /// Can a full radix-2 FFT over `lanes` points unroll spatially across the
    /// pipeline? Requires `log₂(lanes) ≤ stages`.
    pub fn fits_fft(self) -> bool {
        self.levels() <= self.stages
    }

    /// Can a Blelloch scan over `lanes` points unroll spatially? Requires
    /// `2·log₂(lanes) − 1 ≤ stages` (the root up-sweep and the first
    /// down-sweep level share a stage boundary; see `pcusim::programs`).
    pub fn fits_bscan(self) -> bool {
        2 * self.levels() <= self.stages
    }
}

impl fmt::Display for PcuGeometry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}", self.lanes, self.stages)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_geometry() {
        let g = PcuGeometry::table1();
        assert_eq!(g.fu_count(), 384);
        assert_eq!(g.levels(), 5);
        assert!(g.fits_fft());
        assert!(g.fits_bscan());
    }

    #[test]
    fn synthesis_geometry() {
        let g = PcuGeometry::synthesis();
        assert_eq!(g.fu_count(), 48);
        assert_eq!(g.levels(), 3);
        assert!(g.fits_fft());
        assert!(g.fits_bscan()); // 2·3 = 6 ≤ 6
    }

    #[test]
    fn peak_flops_one_pcu() {
        // 384 FUs × 2 flop × 1.6 GHz = 1.2288 TFLOP/s per PCU.
        let g = PcuGeometry::table1();
        assert_eq!(g.peak_flops(1.6e9), 384.0 * 2.0 * 1.6e9);
    }

    #[test]
    #[should_panic]
    fn non_pow2_lanes_panics() {
        PcuGeometry::new(24, 6);
    }

    #[test]
    fn mode_classification() {
        for m in PcuMode::BASELINE {
            assert!(!m.is_extension());
        }
        for m in PcuMode::EXTENSIONS {
            assert!(m.is_extension());
        }
    }
}
