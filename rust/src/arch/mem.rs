//! Memory technology models (paper §II-C: DFModel supports DDR and HBM).

use std::fmt;

/// Off-chip memory technology with its sustained bandwidth.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MemTech {
    /// HBM3e stack — the paper models all three platforms with 8 TB/s HBM3e.
    Hbm3e,
    /// HBM2e (A100's native memory, ~2 TB/s) — kept for ablations.
    Hbm2e,
    /// DDR5 channel group, ~0.4 TB/s — kept for ablations.
    Ddr5,
    /// Custom bandwidth in bytes/s.
    Custom(f64),
}

impl MemTech {
    /// Sustained bandwidth in bytes/second.
    pub fn bandwidth(self) -> f64 {
        match self {
            MemTech::Hbm3e => 8e12,
            MemTech::Hbm2e => 2e12,
            MemTech::Ddr5 => 0.4e12,
            MemTech::Custom(bw) => bw,
        }
    }

    /// Time to move `bytes` at this technology's bandwidth.
    pub fn transfer_time(self, bytes: f64) -> f64 {
        bytes / self.bandwidth()
    }
}

impl fmt::Display for MemTech {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MemTech::Hbm3e => write!(f, "HBM3e (8 TB/s)"),
            MemTech::Hbm2e => write!(f, "HBM2e (2 TB/s)"),
            MemTech::Ddr5 => write!(f, "DDR5 (0.4 TB/s)"),
            MemTech::Custom(bw) => write!(f, "custom ({:.2} TB/s)", bw / 1e12),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_memory_is_8tbs() {
        assert_eq!(MemTech::Hbm3e.bandwidth(), 8e12);
    }

    #[test]
    fn transfer_time_scales() {
        // 8 TB at 8 TB/s = 1 s.
        assert!((MemTech::Hbm3e.transfer_time(8e12) - 1.0).abs() < 1e-12);
        // Custom override.
        assert_eq!(MemTech::Custom(1e12).transfer_time(2e12), 2.0);
    }
}
