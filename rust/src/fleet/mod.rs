//! Fleet tier: a front-end router over N simulated multi-chip nodes, with
//! live session migration and trace-driven load generation.
//!
//! One node runs the single-node serving stack ([`crate::session`]'s
//! scheduler + per-chip state caches + a [`crate::coordinator::Executor`]);
//! the fleet puts a placement [`Router`] in front of several of them and
//! drives everything in modeled time:
//!
//! ```text
//!   loadgen trace ──▶ Router ──place──▶ Node 0 [chip0|chip1] ─┐
//!   (Poisson/bursty/   │               Node 1 [chip0|chip1] ─┤ tokens,
//!    diurnal arrivals)  │   migrate     ...                   │ latencies
//!                       ╰──◀─────────▶ Node N-1 ─────────────┘
//!                          α–β link      │
//!                          (bytes/s+lat) ╰─ checkpoint store (fail-stop)
//! ```
//!
//! * [`loadgen`] — arrival-process traces (Poisson, bursty, diurnal) with
//!   mixed prefill/decode lengths and tenant affinity keys.
//! * [`router`] — placement policies (round-robin, least-loaded,
//!   locality-affine) and the session → node table.
//! * [`node`] — the simulated node: continuous batching in modeled time,
//!   eager execution with buffered delivery, export/resume hooks.
//! * [`migrate`] — the checkpoint → transfer → resume lifecycle and the
//!   write-through [`CheckpointStore`] that makes fail-stop lossless.
//! * [`sim`] — the event loop, drain/fail scenarios, and the SLO report
//!   (p50/p99/p999 token latency, goodput, per-node attribution).
//!
//! The `fleet` CLI subcommand wires this to telemetry (per-node tracks,
//! migration spans, `fleet.*` counters); `docs/FLEET.md` is the operator
//! guide and `docs/ARCHITECTURE.md` §9 the design rationale.

pub mod loadgen;
pub mod migrate;
pub mod node;
pub mod router;
pub mod sim;

pub use loadgen::{generate, Arrival, ArrivalProcess, TraceConfig};
pub use migrate::{Checkpoint, CheckpointStore, MigrationStats};
pub use node::{Delivered, Node, SessionPayload, StepCosts};
pub use router::{PlacementPolicy, Router, RouterStats, AFFINITY_OVERLOAD};
pub use sim::{
    calibrate_single_node, mock_factory, run_fleet, FleetConfig, FleetReport, FleetScenario,
    NodeReport,
};
