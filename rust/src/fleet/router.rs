//! The fleet front-end router: session placement across nodes.
//!
//! The router owns the session → node table and the placement policy. It
//! never touches state or tokens — those move through
//! [`super::node::Node`] exports and the α–β-priced transfers in
//! [`super::sim`] — it only *decides* where sessions live:
//!
//! * **round-robin** — rotate over eligible nodes; the no-information
//!   baseline.
//! * **least-loaded** — place on the node with the fewest live sessions
//!   (ties break to the lowest node id, keeping placement deterministic).
//! * **locality-affine** — hash the arrival's affinity key (tenant/user
//!   class) to a preferred node, so a tenant's sessions co-locate and its
//!   working set stays in one node's caches; fall back to least-loaded
//!   when the preferred node is draining, failed, or more than
//!   [`AFFINITY_OVERLOAD`]× plus slack above the least-loaded node (a hot
//!   tenant must not melt one node while others idle).
//!
//! Draining and failed nodes are never placement-eligible; when no node is
//! eligible the placement fails and the caller counts the session refused.

use super::node::Node;
use crate::session::SessionId;
use std::collections::BTreeMap;

/// Load multiplier past which the affine policy abandons the preferred
/// node: preferred is used while `live ≤ AFFINITY_OVERLOAD · least + 2`.
pub const AFFINITY_OVERLOAD: usize = 2;

/// Session placement policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    RoundRobin,
    LeastLoaded,
    LocalityAffine,
}

impl PlacementPolicy {
    /// Parse a CLI name (`round-robin`, `least-loaded`, `affine`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "round-robin" | "rr" => Some(Self::RoundRobin),
            "least-loaded" | "ll" => Some(Self::LeastLoaded),
            "affine" | "locality-affine" => Some(Self::LocalityAffine),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::RoundRobin => "round-robin",
            Self::LeastLoaded => "least-loaded",
            Self::LocalityAffine => "affine",
        }
    }
}

/// Router placement/migration counters.
#[derive(Debug, Clone, Default)]
pub struct RouterStats {
    /// Successful initial placements.
    pub placed: u64,
    /// Arrivals refused because no node was eligible.
    pub refused: u64,
    /// Affine placements that landed on the preferred node.
    pub affinity_hits: u64,
    /// Affine placements that overflowed to the least-loaded fallback.
    pub affinity_spills: u64,
    /// Live migrations started (drain, rebalance, scripted moves).
    pub migrations: u64,
    /// Sessions re-placed after a node fail-stop.
    pub failovers: u64,
}

/// The placement table + policy.
pub struct Router {
    policy: PlacementPolicy,
    assignments: BTreeMap<SessionId, usize>,
    rr_next: usize,
    pub stats: RouterStats,
}

impl Router {
    pub fn new(policy: PlacementPolicy) -> Self {
        Self { policy, assignments: BTreeMap::new(), rr_next: 0, stats: RouterStats::default() }
    }

    pub fn policy(&self) -> PlacementPolicy {
        self.policy
    }

    /// Choose a node for a session with affinity key `affinity`. Returns
    /// `None` when every node is draining or failed. Does **not** record
    /// the assignment — call [`assign`](Self::assign) once the session
    /// actually lands (placement and arrival are separated by a transfer
    /// for migrations).
    pub fn place(&mut self, affinity: u64, nodes: &[Node]) -> Option<usize> {
        let eligible: Vec<usize> =
            (0..nodes.len()).filter(|&n| !nodes[n].draining && !nodes[n].failed).collect();
        if eligible.is_empty() {
            return None;
        }
        let least = *eligible
            .iter()
            .min_by_key(|&&n| (nodes[n].live(), n))
            .expect("eligible is non-empty");
        let chosen = match self.policy {
            PlacementPolicy::LeastLoaded => least,
            PlacementPolicy::RoundRobin => {
                // Next eligible node at or after the rotor.
                let k = eligible
                    .iter()
                    .position(|&n| n >= self.rr_next % nodes.len())
                    .unwrap_or(0);
                let n = eligible[k];
                self.rr_next = n + 1;
                n
            }
            PlacementPolicy::LocalityAffine => {
                let preferred = (affinity % nodes.len() as u64) as usize;
                let ok = eligible.contains(&preferred)
                    && nodes[preferred].live() <= AFFINITY_OVERLOAD * nodes[least].live() + 2;
                if ok {
                    self.stats.affinity_hits += 1;
                    preferred
                } else {
                    self.stats.affinity_spills += 1;
                    least
                }
            }
        };
        Some(chosen)
    }

    /// Record that `id` now lives on `node`.
    pub fn assign(&mut self, id: SessionId, node: usize) {
        self.assignments.insert(id, node);
    }

    /// Which node serves `id` (`None` while retired, lost, or in transit).
    pub fn node_of(&self, id: SessionId) -> Option<usize> {
        self.assignments.get(&id).copied()
    }

    /// Drop `id` from the table (retirement, loss, or transfer start).
    pub fn unassign(&mut self, id: SessionId) {
        self.assignments.remove(&id);
    }

    /// Sessions currently assigned to `node`, ascending.
    pub fn sessions_on(&self, node: usize) -> Vec<SessionId> {
        self.assignments.iter().filter(|&(_, &n)| n == node).map(|(&id, _)| id).collect()
    }

    /// Total assigned sessions (excludes in-transit).
    pub fn assigned(&self) -> usize {
        self.assignments.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MemTech;
    use crate::coordinator::MockExecutor;
    use crate::fleet::node::StepCosts;
    use crate::runtime::ModelKind;
    use crate::session::{SchedulerConfig, SessionInfo, StateShape};

    fn test_nodes(n: usize) -> Vec<Node> {
        (0..n)
            .map(|id| {
                Node::new(
                    id,
                    2,
                    1 << 20,
                    4096,
                    MemTech::Hbm3e,
                    SchedulerConfig::default(),
                    StepCosts { mamba: 1e-6, hyena: 2e-6 },
                    Box::new(MockExecutor::new(1, 1)),
                )
            })
            .collect()
    }

    fn admit(node: &mut Node, id: SessionId) {
        let shape = StateShape::mamba(2, 4, 8);
        node.admit(
            id,
            SessionInfo { model: ModelKind::Mamba, shape, decode_steps: 4 },
            vec![0.5; 8],
        );
    }

    #[test]
    fn least_loaded_picks_emptiest_with_lowest_id_ties() {
        let mut nodes = test_nodes(3);
        let mut r = Router::new(PlacementPolicy::LeastLoaded);
        assert_eq!(r.place(0, &nodes), Some(0), "all empty: lowest id");
        admit(&mut nodes[0], 1);
        r.assign(1, 0);
        assert_eq!(r.place(0, &nodes), Some(1), "node 0 now loaded");
        admit(&mut nodes[1], 2);
        admit(&mut nodes[2], 3);
        assert_eq!(r.place(0, &nodes), Some(0), "tie at 1 breaks to lowest id");
    }

    #[test]
    fn round_robin_rotates_and_skips_ineligible() {
        let mut nodes = test_nodes(3);
        let mut r = Router::new(PlacementPolicy::RoundRobin);
        assert_eq!(r.place(0, &nodes), Some(0));
        assert_eq!(r.place(0, &nodes), Some(1));
        assert_eq!(r.place(0, &nodes), Some(2));
        assert_eq!(r.place(0, &nodes), Some(0), "wraps");
        nodes[1].draining = true;
        assert_eq!(r.place(0, &nodes), Some(2), "skips the draining node");
    }

    #[test]
    fn affine_prefers_hash_node_until_overloaded() {
        let mut nodes = test_nodes(2);
        let mut r = Router::new(PlacementPolicy::LocalityAffine);
        // affinity 1 → node 1 while balanced.
        assert_eq!(r.place(1, &nodes), Some(1));
        assert_eq!(r.stats.affinity_hits, 1);
        // Pile sessions onto node 1 until the overload bound trips
        // (least = 0 live → bound is 2·0 + 2 = 2).
        for id in 1..=3 {
            admit(&mut nodes[1], id);
            r.assign(id, 1);
        }
        assert_eq!(r.place(1, &nodes), Some(0), "overloaded preferred spills");
        assert_eq!(r.stats.affinity_spills, 1);
        // A failed preferred node also spills.
        nodes[1].failed = true;
        assert_eq!(r.place(1, &nodes), Some(0));
        assert_eq!(r.stats.affinity_spills, 2);
    }

    #[test]
    fn no_eligible_node_refuses() {
        let mut nodes = test_nodes(2);
        nodes[0].draining = true;
        nodes[1].failed = true;
        let mut r = Router::new(PlacementPolicy::LeastLoaded);
        assert_eq!(r.place(0, &nodes), None);
    }

    #[test]
    fn assignment_table_round_trips() {
        let mut r = Router::new(PlacementPolicy::LeastLoaded);
        r.assign(7, 1);
        r.assign(9, 1);
        r.assign(8, 0);
        assert_eq!(r.node_of(7), Some(1));
        assert_eq!(r.sessions_on(1), vec![7, 9]);
        assert_eq!(r.assigned(), 3);
        r.unassign(7);
        assert_eq!(r.node_of(7), None);
        assert_eq!(r.sessions_on(1), vec![9]);
    }

    #[test]
    fn policy_names_parse_and_round_trip() {
        for p in [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::LeastLoaded,
            PlacementPolicy::LocalityAffine,
        ] {
            assert_eq!(PlacementPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(PlacementPolicy::parse("ll"), Some(PlacementPolicy::LeastLoaded));
        assert_eq!(PlacementPolicy::parse("bogus"), None);
    }
}
