//! Migration and fail-stop checkpointing: what moves when a session leaves
//! a node, and the durable store that makes fail-stop lossless.
//!
//! The migration lifecycle is **checkpoint → transfer → resume**:
//!
//! 1. **checkpoint** — detach the scheduler ticket
//!    ([`crate::session::MigratedSession`]: model, shape, decode budget,
//!    tokens done) and the moving payload
//!    ([`super::node::SessionPayload`]: the `SsmState`, the last token,
//!    the unprefilled prompt). Together they are a [`Checkpoint`] — the
//!    complete session, no executor-side residue (executors are stateless
//!    beyond the `SsmState`).
//! 2. **transfer** — the checkpoint's bytes cross the node-to-node link at
//!    the α–β price ([`crate::arch::InterchipLink::transfer_seconds`]);
//!    the session is *in transit* and schedulable nowhere.
//! 3. **resume** — the destination inserts the state into a chip cache,
//!    re-admits the ticket at its carried progress, and the next decode
//!    step produces exactly the token the source would have produced.
//!
//! [`CheckpointStore`] is the fail-stop half: with checkpointing on, the
//! fleet writes a session's checkpoint through on admission and after
//! every delivered token (modeled as asynchronous — it never adds to batch
//! time, which is why `puts`/`bytes_written` are tracked for the report
//! instead). A fail-stop recovers every session of the dead node from the
//! store at its last *delivered* token: in-flight steps were never
//! delivered, so re-executing them is exactly-once delivery, and zero
//! tokens are lost.

use super::node::SessionPayload;
use crate::session::{MigratedSession, SessionId};
use std::collections::BTreeMap;

/// A complete detached session: scheduler ticket + moving payload.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub ticket: MigratedSession,
    pub payload: SessionPayload,
}

impl Checkpoint {
    /// Bytes on the wire (what the α–β transfer prices).
    pub fn bytes(&self) -> usize {
        self.payload.bytes()
    }
}

/// Write-through checkpoint store (the durable side of fail-stop).
#[derive(Debug, Default)]
pub struct CheckpointStore {
    map: BTreeMap<SessionId, Checkpoint>,
    /// Checkpoint writes since start (admissions + per-token updates).
    pub puts: u64,
    /// Cumulative checkpoint bytes written.
    pub bytes_written: u64,
}

impl CheckpointStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write (or overwrite) `id`'s checkpoint.
    pub fn put(&mut self, id: SessionId, ck: Checkpoint) {
        self.puts += 1;
        self.bytes_written += ck.bytes() as u64;
        self.map.insert(id, ck);
    }

    /// Remove and return `id`'s checkpoint (fail-stop recovery).
    pub fn take(&mut self, id: SessionId) -> Option<Checkpoint> {
        self.map.remove(&id)
    }

    /// Drop `id`'s checkpoint (retirement).
    pub fn remove(&mut self, id: SessionId) {
        self.map.remove(&id);
    }

    /// Checkpointed sessions.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Fleet-wide migration/failover counters for the report.
#[derive(Debug, Clone, Default)]
pub struct MigrationStats {
    /// Live migrations started (drains + scripted moves).
    pub migrations: u64,
    /// Fail-stop recoveries started.
    pub failovers: u64,
    /// Bytes moved across the node-to-node link.
    pub bytes_moved: u64,
    /// Modeled α–β transfer time summed over all moves.
    pub transfer_seconds: f64,
    /// Checkpoint-store writes (informational; modeled off the critical
    /// path).
    pub checkpoint_puts: u64,
    /// Checkpoint-store bytes written.
    pub checkpoint_bytes: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelKind;
    use crate::session::{Phase, SessionInfo, SsmState, StateShape};

    fn checkpoint(tokens_done: usize) -> Checkpoint {
        let shape = StateShape::mamba(2, 4, 8); // 256 B
        let state = SsmState::zeros(&shape).unwrap();
        Checkpoint {
            ticket: MigratedSession {
                info: SessionInfo { model: ModelKind::Mamba, shape, decode_steps: 8 },
                phase: Phase::Decode,
                tokens_done,
            },
            payload: SessionPayload {
                state: Some(state),
                last_token: Some(vec![1.0; 8]), // 32 B
                prompt: None,
            },
        }
    }

    #[test]
    fn checkpoint_bytes_price_state_and_token() {
        let ck = checkpoint(1);
        assert_eq!(ck.bytes(), 256 + 32);
    }

    #[test]
    fn store_overwrites_and_accounts() {
        let mut s = CheckpointStore::new();
        assert!(s.is_empty());
        s.put(1, checkpoint(1));
        s.put(1, checkpoint(2));
        s.put(2, checkpoint(1));
        assert_eq!(s.len(), 2, "overwrite does not duplicate");
        assert_eq!(s.puts, 3, "every write counts");
        assert_eq!(s.bytes_written, 3 * 288);
        let ck = s.take(1).expect("present");
        assert_eq!(ck.ticket.tokens_done, 2, "latest write wins");
        assert!(s.take(1).is_none());
        s.remove(2);
        assert!(s.is_empty());
    }
}
