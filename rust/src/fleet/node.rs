//! One simulated serving node: the fleet's unit of capacity and failure.
//!
//! A node wraps the same serving stack the single-node coordinator runs —
//! a [`SessionScheduler`] doing continuous batching, one [`StateCache`] per
//! chip (sessions stripe across chips by id, as in
//! [`crate::coordinator::ContinuousConfig`]), and an [`Executor`] — but
//! driven in *modeled* time by the fleet event loop instead of threads:
//! the node executes a whole iteration batch eagerly when it starts, prices
//! it with the [`crate::dfmodel::decode`] cost hook (batch time = slowest
//! step + spill traffic, exactly the [`crate::session::driver`] model), and
//! buffers the results until the batch's modeled completion instant.
//! Buffering is what makes fail-stop honest: a node killed mid-batch
//! simply drops the buffer, and the aborted steps re-execute elsewhere
//! from checkpointed state — deterministically producing the same tokens,
//! because executors are stateless beyond the [`SsmState`] that travels
//! with the session (true of [`crate::coordinator::MockExecutor`]; a
//! requirement on any future PJRT decode path).

use crate::coordinator::Executor;
use crate::runtime::ModelKind;
use crate::session::{
    CacheStats, MemoryBudget, MigratedSession, Phase, SchedStats, SchedulerConfig, ScheduledStep,
    SessionId, SessionInfo, SessionScheduler, SsmState, StateCache,
};
use crate::telemetry;
use crate::Result;
use anyhow::anyhow;
use std::collections::BTreeMap;
use std::time::Instant;

/// Per-model decode-step costs (modeled seconds per token), shared by every
/// node so the fleet's timing model is uniform. Prefill of a `P`-token
/// prompt costs `P ×` the per-token figure, as in the session driver.
#[derive(Debug, Clone, Copy)]
pub struct StepCosts {
    pub mamba: f64,
    pub hyena: f64,
}

impl StepCosts {
    pub fn of(&self, model: ModelKind) -> f64 {
        match model {
            ModelKind::Hyena => self.hyena,
            _ => self.mamba,
        }
    }

    /// The slower of the two families — the conservative per-step figure
    /// capacity calibration uses.
    pub fn worst(&self) -> f64 {
        self.mamba.max(self.hyena)
    }
}

/// Everything that travels with a session when it leaves a node: the
/// checkpointed decode state, the last emitted token (the next decode
/// step's input), and — for sessions that never prefilled — the prompt.
#[derive(Debug, Clone, Default)]
pub struct SessionPayload {
    pub state: Option<SsmState>,
    pub last_token: Option<Vec<f32>>,
    pub prompt: Option<Vec<f32>>,
}

impl SessionPayload {
    /// Bytes on the wire for the α–β transfer price: state bytes plus 4 B
    /// per f32 of token/prompt.
    pub fn bytes(&self) -> usize {
        self.state.as_ref().map(|s| s.bytes()).unwrap_or(0)
            + self.last_token.as_ref().map(|t| t.len() * 4).unwrap_or(0)
            + self.prompt.as_ref().map(|p| p.len() * 4).unwrap_or(0)
    }
}

/// One token delivered at a batch's completion instant.
#[derive(Debug)]
pub struct Delivered {
    pub id: SessionId,
    /// 0-based token index within the session (strictly sequential).
    pub step: usize,
    pub token: Vec<f32>,
    /// Post-step state snapshot for write-through checkpointing; `None`
    /// once the session retired (nothing left to checkpoint).
    pub state: Option<SsmState>,
    pub retired: bool,
}

/// A buffered step result awaiting its batch's completion instant.
struct PendingStep {
    step: ScheduledStep,
    token: Vec<f32>,
    state_snapshot: Option<SsmState>,
}

/// One simulated multi-chip node.
pub struct Node {
    pub id: usize,
    chips: usize,
    sched: SessionScheduler,
    caches: Vec<StateCache>,
    exec: Box<dyn Executor>,
    costs: StepCosts,
    prompts: BTreeMap<SessionId, Vec<f32>>,
    last_token: BTreeMap<SessionId, Vec<f32>>,
    /// Modeled instant the in-flight batch completes (stale when idle).
    pub busy_until: f64,
    pending: Vec<PendingStep>,
    /// Router stops placing here; remaining sessions evacuate at the next
    /// batch boundary.
    pub draining: bool,
    /// Fail-stopped: the node executes nothing further.
    pub failed: bool,
    pub batches: u64,
    pub batched_steps: u64,
    pub tokens: u64,
}

impl Node {
    /// Build a node with `chips` state caches splitting `cache_bytes`
    /// evenly (floored at one `max_state_bytes` each so a single state
    /// always fits, as `serve --continuous` does), spilling at `dram`
    /// prices. Cache spill/restore instants land on globally numbered chip
    /// tracks (`id · chips + c`) so a fleet trace keeps per-chip
    /// attribution across nodes.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        chips: usize,
        cache_bytes: usize,
        max_state_bytes: usize,
        dram: crate::arch::MemTech,
        sched: SchedulerConfig,
        costs: StepCosts,
        exec: Box<dyn Executor>,
    ) -> Self {
        let chips = chips.max(1);
        let per_chip = (cache_bytes / chips).max(max_state_bytes.max(1));
        let caches = (0..chips)
            .map(|c| {
                let global = id * chips + c;
                let mut cache = StateCache::new(MemoryBudget::new(per_chip), dram);
                cache.set_track(telemetry::chip_track(global));
                telemetry::name_track(
                    telemetry::PID_HOST,
                    telemetry::chip_track(global),
                    format!("node {id} chip {c}"),
                );
                cache
            })
            .collect();
        telemetry::name_track(
            telemetry::PID_HOST,
            telemetry::node_track(id),
            format!("node {id}"),
        );
        Self {
            id,
            chips,
            sched: SessionScheduler::new(sched),
            caches,
            exec,
            costs,
            prompts: BTreeMap::new(),
            last_token: BTreeMap::new(),
            busy_until: 0.0,
            pending: Vec::new(),
            draining: false,
            failed: false,
            batches: 0,
            batched_steps: 0,
            tokens: 0,
        }
    }

    /// The chip cache holding session `id`'s state (sessions stripe by id).
    fn cache_of(&mut self, id: SessionId) -> &mut StateCache {
        let c = (id as usize) % self.chips;
        &mut self.caches[c]
    }

    /// Admit a brand-new session with its synthesized prompt.
    pub fn admit(&mut self, id: SessionId, info: SessionInfo, prompt: Vec<f32>) {
        self.prompts.insert(id, prompt);
        self.sched.admit(id, info, Instant::now());
    }

    /// Live sessions on this node (admitted, not retired/exported).
    pub fn live(&self) -> usize {
        self.sched.live()
    }

    /// Is a batch currently executing (results buffered, completion
    /// pending)?
    pub fn batch_in_flight(&self) -> bool {
        !self.pending.is_empty()
    }

    /// True when the node can start a batch right now (draining nodes
    /// never start new batches — they evacuate at the current boundary).
    pub fn ready(&self) -> bool {
        !self.failed && !self.draining && self.pending.is_empty() && !self.sched.is_idle()
    }

    /// Start (and eagerly execute) the next iteration batch at modeled
    /// instant `now`. Returns the batch's completion instant, or `None`
    /// when the node has nothing to run. Results are buffered until
    /// [`complete_batch`](Self::complete_batch).
    pub fn start_batch(&mut self, now: f64) -> Result<Option<f64>> {
        if !self.ready() {
            return Ok(None);
        }
        let steps = self.sched.next_batch();
        if steps.is_empty() {
            return Ok(None);
        }
        let spill0: f64 = self.caches.iter().map(|c| c.stats.spill_seconds).sum();
        let mut batch_seconds = 0.0f64;
        let mut pending = Vec::with_capacity(steps.len());
        for s in steps {
            let (token, snapshot) = match s.phase {
                Phase::Prefill => {
                    let prompt = self
                        .prompts
                        .remove(&s.id)
                        .ok_or_else(|| anyhow!("session {} has no prompt on node {}", s.id, self.id))?;
                    let shape = self.shape_of(s.id, s.model)?;
                    let ptoks = (prompt.len() / shape.d_model.max(1)).max(1);
                    let (state, first) = self.exec.begin_session(s.model, &prompt, &shape)?;
                    let snapshot = state.clone();
                    // First touch: the session's state buffer lands in this
                    // chip's cache here and every later decode reuses it —
                    // the same placement instant the coordinator emits, so
                    // fleet traces carry the per-chip placement story too.
                    let chip = (s.id as usize) % self.chips;
                    telemetry::instant_on(
                        "placement",
                        "place.first_touch",
                        telemetry::chip_track(self.id * self.chips + chip),
                        "chip",
                        chip as f64,
                    );
                    self.cache_of(s.id).insert(s.id, state);
                    batch_seconds = batch_seconds.max(self.costs.of(s.model) * ptoks as f64);
                    (first, snapshot)
                }
                Phase::Decode => {
                    let token = self
                        .last_token
                        .get(&s.id)
                        .cloned()
                        .ok_or_else(|| anyhow!("session {} has no previous token", s.id))?;
                    let mut state = self
                        .cache_of(s.id)
                        .checkout(s.id)
                        .ok_or_else(|| anyhow!("session {} lost its cached state", s.id))?;
                    let out = self.exec.step_decode(s.model, &mut state, &token)?;
                    let snapshot = state.clone();
                    self.cache_of(s.id).checkin(s.id, state);
                    batch_seconds = batch_seconds.max(self.costs.of(s.model));
                    (out, snapshot)
                }
            };
            pending.push(PendingStep { step: s, token, state_snapshot: Some(snapshot) });
        }
        let spill1: f64 = self.caches.iter().map(|c| c.stats.spill_seconds).sum();
        batch_seconds += spill1 - spill0;
        self.batches += 1;
        self.batched_steps += pending.len() as u64;
        self.busy_until = now + batch_seconds;
        telemetry::instant_on(
            "fleet",
            "node.batch",
            telemetry::node_track(self.id),
            "steps",
            pending.len() as f64,
        );
        self.pending = pending;
        Ok(Some(self.busy_until))
    }

    /// Deliver the buffered batch at its completion instant. Retired
    /// sessions free their cache slot and token buffer.
    pub fn complete_batch(&mut self) -> Vec<Delivered> {
        let pending = std::mem::take(&mut self.pending);
        let now = Instant::now();
        let mut out = Vec::with_capacity(pending.len());
        for mut p in pending {
            let s = p.step;
            self.tokens += 1;
            self.last_token.insert(s.id, p.token.clone());
            let retired = self.sched.on_step_done(s.id, now)
                == crate::session::StepOutcome::Retired;
            if retired {
                self.cache_of(s.id).remove(s.id);
                self.last_token.remove(&s.id);
                p.state_snapshot = None;
            }
            out.push(Delivered {
                id: s.id,
                step: s.step,
                token: p.token,
                state: p.state_snapshot,
                retired,
            });
        }
        out
    }

    /// Fail-stop the node: cancel the in-flight batch (no tokens from it
    /// are ever delivered) and refuse all further work. The sessions'
    /// recovery happens fleet-side from the checkpoint store — nothing is
    /// read back from a failed node.
    pub fn fail(&mut self) {
        self.failed = true;
        for p in &self.pending {
            self.sched.abort_step(p.step.id);
        }
        self.pending.clear();
        telemetry::instant_on("fleet", "node.fail", telemetry::node_track(self.id), "node", self.id as f64);
    }

    /// Detach a live session for migration: scheduler ticket plus the
    /// moving payload (state checked out of the chip cache, last token,
    /// unprefilled prompt). `None` while the session has a step in the
    /// in-flight batch — migrate at the batch boundary.
    pub fn export_session(&mut self, id: SessionId) -> Option<(MigratedSession, SessionPayload)> {
        let ticket = self.sched.export(id)?;
        let payload = SessionPayload {
            state: self.cache_of(id).remove(id),
            last_token: self.last_token.remove(&id),
            prompt: self.prompts.remove(&id),
        };
        Some((ticket, payload))
    }

    /// Attach a migrated/recovered session: payload pieces land in the chip
    /// cache and token buffers, the ticket re-enters the scheduler at its
    /// carried progress.
    pub fn resume_session(&mut self, id: SessionId, ticket: MigratedSession, payload: SessionPayload) {
        if let Some(state) = payload.state {
            self.cache_of(id).insert(id, state);
        }
        if let Some(token) = payload.last_token {
            self.last_token.insert(id, token);
        }
        if let Some(prompt) = payload.prompt {
            self.prompts.insert(id, prompt);
        }
        self.sched.admit_migrated(id, ticket, Instant::now());
    }

    /// State shape of a live session (carried in its [`SessionInfo`]).
    fn shape_of(&self, id: SessionId, model: ModelKind) -> Result<crate::session::StateShape> {
        self.sched
            .info(id)
            .map(|i| i.shape)
            .ok_or_else(|| anyhow!("session {id} ({model}) unknown to node {} scheduler", self.id))
    }

    /// Ids of every live session on this node, ascending.
    pub fn live_ids(&self) -> Vec<SessionId> {
        self.sched.live_ids()
    }

    /// Scheduler lifecycle counters.
    pub fn sched_stats(&self) -> SchedStats {
        self.sched.stats.clone()
    }

    /// Per-chip cache counters (index = local chip id).
    pub fn chip_stats(&self) -> Vec<CacheStats> {
        self.caches.iter().map(|c| c.stats.clone()).collect()
    }

    /// Node-level rollup of the per-chip counters.
    pub fn cache_stats(&self) -> CacheStats {
        CacheStats::merge_all(&self.chip_stats())
    }

    /// Mean iteration-batch occupancy.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_steps as f64 / self.batches as f64
        }
    }
}
