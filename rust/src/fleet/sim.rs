//! The fleet simulator: an event-driven loop over N nodes in modeled time.
//!
//! Arrivals (from [`super::loadgen`]), batch completions, scripted
//! drain/fail/migrate events, and migration resumes are processed in
//! global time order; after every event, each idle non-draining node with
//! ready work starts its next iteration batch at the current instant. A
//! batch executes eagerly when it starts but delivers its tokens at its
//! modeled completion instant ([`super::node::Node`] explains why that
//! buffering makes fail-stop honest). Ties at one instant resolve by a
//! fixed priority — completions, then scenario events, then resumes, then
//! arrivals, then ascending id — so every run of the same config, trace,
//! and scenario is bit-identical, including the token streams themselves.
//!
//! Per-token latency is `completion − max(arrival, previous completion)`:
//! queueing delay, batch co-residency stalls, spill traffic, and migration
//! transfers all surface in it. The SLO report counts a token as *good*
//! when its latency is at or under [`FleetConfig::slo_us`]; goodput is
//! good tokens per modeled second.

use super::loadgen::Arrival;
use super::migrate::{Checkpoint, CheckpointStore, MigrationStats};
use super::node::{Node, SessionPayload, StepCosts};
use super::router::{PlacementPolicy, Router, RouterStats};
use crate::arch::{InterchipLink, RduConfig};
use crate::coordinator::{Executor, ExecutorFactory, MockExecutor};
use crate::dfmodel::decode::decode_step_workload;
use crate::runtime::ModelKind;
use crate::session::driver::cost_config;
use crate::session::{
    CacheStats, MigratedSession, Phase, SchedStats, SchedulerConfig, SessionId, SessionInfo,
    StateShape,
};
use crate::telemetry;
use crate::util::XorShift;
use crate::Result;
use anyhow::anyhow;
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;

/// One fleet topology + serving policy.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub nodes: usize,
    pub chips_per_node: usize,
    /// Per-node resident state budget in bytes, split across the node's
    /// chips (floored at one state per chip).
    pub node_cache_bytes: usize,
    pub sched: SchedulerConfig,
    pub mamba_shape: StateShape,
    pub hyena_shape: StateShape,
    pub policy: PlacementPolicy,
    /// Node-to-node network link (α–β priced); migrations and failover
    /// restores cross it.
    pub network: InterchipLink,
    pub rdu: RduConfig,
    /// Per-token latency SLO in µs; `≤ 0` disables the SLO cut (every
    /// token counts as good).
    pub slo_us: f64,
    /// Write-through checkpointing: fail-stop recovers every session at
    /// its last delivered token (zero lost tokens). Off, a fail-stop
    /// loses the dead node's sessions.
    pub checkpointing: bool,
    /// Record every delivered token value per session in the report (the
    /// bit-identity tests' hook; costs memory on large traces).
    pub record_tokens: bool,
    /// Seed for prompt synthesis (per-session streams derive from it).
    pub seed: u64,
}

impl FleetConfig {
    /// A small realistic fleet: the session driver's demo shapes, a
    /// PCIe-class node-to-node network, least-loaded placement, and a
    /// per-node budget of 32 worst-case states per node so saturation
    /// exercises the spill path.
    pub fn demo(nodes: usize, chips_per_node: usize) -> Self {
        let mamba_shape = StateShape::mamba(8, 16, 64);
        let hyena_shape = StateShape::hyena(8, 64, 256);
        let max_state = mamba_shape.bytes().max(hyena_shape.bytes());
        Self {
            nodes: nodes.max(1),
            chips_per_node: chips_per_node.max(1),
            node_cache_bytes: 32 * max_state,
            sched: SchedulerConfig::default(),
            mamba_shape,
            hyena_shape,
            policy: PlacementPolicy::LeastLoaded,
            network: InterchipLink::pcie5(),
            rdu: RduConfig::hs_scan_mode(),
            slo_us: 0.0,
            checkpointing: true,
            record_tokens: false,
            seed: 7,
        }
    }

    pub fn shape_for(&self, model: ModelKind) -> StateShape {
        match model {
            ModelKind::Hyena => self.hyena_shape,
            _ => self.mamba_shape,
        }
    }

    /// Largest single state either family allocates.
    pub fn max_state_bytes(&self) -> usize {
        self.mamba_shape.bytes().max(self.hyena_shape.bytes())
    }

    /// Per-model decode-step prices from the DFModel cost hook — the same
    /// table [`crate::session::driver::simulate`] uses, so single-node and
    /// fleet modeled times agree.
    pub fn step_costs(&self) -> StepCosts {
        let per = |model: ModelKind| {
            let shape = self.shape_for(model);
            let w = crate::workloads::family_workload(model);
            decode_step_workload(w, &cost_config(&shape), shape.layers, &self.rdu).seconds
        };
        StepCosts { mamba: per(ModelKind::Mamba), hyena: per(ModelKind::Hyena) }
    }
}

/// Scripted operational events driven against the fleet.
#[derive(Debug, Clone, Default)]
pub struct FleetScenario {
    /// `(time, node)`: begin draining `node` — no new placements, every
    /// session live-migrates away at the next batch boundary.
    pub drain: Vec<(f64, usize)>,
    /// `(time, node)`: fail-stop `node` — its in-flight batch is aborted
    /// undelivered and its sessions recover from the checkpoint store.
    pub fail: Vec<(f64, usize)>,
    /// `(time, session, dest)`: live-migrate one session to `dest` (at the
    /// next batch boundary if its step is in flight).
    pub migrate: Vec<(f64, SessionId, usize)>,
}

/// Per-node slice of the fleet report (per-node attribution — chips of a
/// node roll up together instead of flattening into one fleet-wide table).
#[derive(Debug, Clone)]
pub struct NodeReport {
    pub node: usize,
    pub tokens: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub sched: SchedStats,
    /// Node-level rollup of the per-chip counters
    /// ([`CacheStats::merge_all`]).
    pub cache: CacheStats,
    /// Per-chip counters (index = local chip id), kept for drill-down.
    pub per_chip: Vec<CacheStats>,
    pub drained: bool,
    pub failed: bool,
}

/// The SLO report for one fleet run.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Sessions in the trace.
    pub sessions: u64,
    /// Sessions that delivered every token.
    pub completed: u64,
    /// Sessions lost (fail-stop without checkpointing, or no eligible
    /// node).
    pub lost_sessions: u64,
    pub tokens: u64,
    /// Modeled instant of the last token delivery.
    pub sim_seconds: f64,
    pub throughput_tok_s: f64,
    /// SLO-meeting tokens per modeled second.
    pub goodput_tok_s: f64,
    pub slo_us: f64,
    /// Fraction of tokens at or under the SLO (1.0 when the SLO is off).
    pub slo_attainment: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    pub mean_us: f64,
    pub max_us: f64,
    pub migrations: MigrationStats,
    pub router: RouterStats,
    pub per_node: Vec<NodeReport>,
    /// Every delivered token per session, in order (only when
    /// [`FleetConfig::record_tokens`]).
    pub token_log: BTreeMap<SessionId, Vec<Vec<f32>>>,
}

impl FleetReport {
    /// One-line SLO summary for logs.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "sessions={} completed={} lost={} tokens={} sim_s={:.6} tok/s={:.0}",
            self.sessions,
            self.completed,
            self.lost_sessions,
            self.tokens,
            self.sim_seconds,
            self.throughput_tok_s,
        );
        if self.slo_us > 0.0 {
            s.push_str(&format!(
                " | SLO {:.0}µs: attained={:.1}% goodput={:.0} tok/s",
                self.slo_us,
                self.slo_attainment * 100.0,
                self.goodput_tok_s,
            ));
        }
        s.push_str(&format!(
            " | p50={:.0}µs p99={:.0}µs p999={:.0}µs | migrations={} failovers={}",
            self.p50_us, self.p99_us, self.p999_us, self.migrations.migrations,
            self.migrations.failovers,
        ));
        s
    }

    /// Per-node table: one line per node with its chip-rollup cache
    /// counters, then a fleet total line.
    pub fn node_table(&self) -> String {
        let mut out = String::from(
            "node     tokens  batches  mean  admit  mig.in mig.out   hits misses  evict  spill KiB   hit%  flags\n",
        );
        let mut fleet = CacheStats::default();
        for n in &self.per_node {
            fleet.merge(&n.cache);
            let flags = match (n.failed, n.drained) {
                (true, _) => "FAILED",
                (false, true) => "drained",
                _ => "",
            };
            out.push_str(&format!(
                "{:>4} {:>10} {:>8} {:>5.1} {:>6} {:>7} {:>7} {:>6} {:>6} {:>6} {:>10.1} {:>6.1}  {}\n",
                n.node,
                n.tokens,
                n.batches,
                n.mean_batch,
                n.sched.admitted,
                n.sched.migrated_in,
                n.sched.migrated_out,
                n.cache.hits,
                n.cache.misses,
                n.cache.evictions,
                n.cache.spilled_bytes as f64 / 1024.0,
                n.cache.hit_rate() * 100.0,
                flags,
            ));
        }
        out.push_str(&format!(
            "fleet {:>9} {:>8}       {:>6} {:>7} {:>7} {:>6} {:>6} {:>6} {:>10.1} {:>6.1}\n",
            self.tokens,
            self.per_node.iter().map(|n| n.batches).sum::<u64>(),
            self.per_node.iter().map(|n| n.sched.admitted).sum::<u64>(),
            self.per_node.iter().map(|n| n.sched.migrated_in).sum::<u64>(),
            self.per_node.iter().map(|n| n.sched.migrated_out).sum::<u64>(),
            fleet.hits,
            fleet.misses,
            fleet.evictions,
            fleet.spilled_bytes as f64 / 1024.0,
            fleet.hit_rate() * 100.0,
        ));
        out
    }
}

/// Executor factory for model-free fleet runs: the deterministic
/// [`MockExecutor`] (its decode depends only on the session's own state,
/// which is what makes migrated trajectories bit-identical).
pub fn mock_factory() -> ExecutorFactory {
    Box::new(|| Ok(Box::new(MockExecutor::new(1, 1)) as Box<dyn Executor>))
}

/// Per-session progress ledger (the conservation check's ground truth).
struct Ledger {
    arrival: f64,
    affinity: u64,
    info: SessionInfo,
    expected: u64,
    delivered: u64,
    prev_done: f64,
    done: bool,
    lost: bool,
}

#[derive(Debug, Clone, Copy)]
enum ScenKind {
    Drain(usize),
    Fail(usize),
    Migrate(SessionId, usize),
}

struct ScenEv {
    at: f64,
    seq: u64,
    kind: ScenKind,
}

struct Resume {
    at: f64,
    id: SessionId,
    ticket: MigratedSession,
    payload: SessionPayload,
    dest: usize,
    failover: bool,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Complete(usize),
    Scen(usize),
    Resume(usize),
    Arrive,
}

struct FleetSim<'a> {
    cfg: &'a FleetConfig,
    nodes: Vec<Node>,
    router: Router,
    store: CheckpointStore,
    scen: Vec<ScenEv>,
    resumes: Vec<Resume>,
    /// Scripted moves waiting for an in-flight step to finish.
    pending_migrations: BTreeMap<SessionId, usize>,
    ledgers: BTreeMap<SessionId, Ledger>,
    latencies: Vec<f64>,
    token_log: BTreeMap<SessionId, Vec<Vec<f32>>>,
    clock: f64,
    last_delivery: f64,
    mig: MigrationStats,
}

/// Run `trace` (time-sorted [`Arrival`]s) against a fleet of
/// `cfg.nodes` × `cfg.chips_per_node` chips under `scenario`, building each
/// node's executor from `factory`. Deterministic in all inputs. Errors on
/// executor failures, malformed scenarios, or a conservation violation
/// (a token delivered out of order — which would mean the migration or
/// recovery machinery replayed or skipped a step).
pub fn run_fleet(
    cfg: &FleetConfig,
    trace: &[Arrival],
    scenario: &FleetScenario,
    factory: &ExecutorFactory,
) -> Result<FleetReport> {
    let _run = telemetry::span("fleet", "run")
        .arg("nodes", cfg.nodes as f64)
        .arg("sessions", trace.len() as f64);
    for w in trace.windows(2) {
        if w[1].at < w[0].at {
            return Err(anyhow!("arrival trace is not time-sorted"));
        }
    }
    let costs = cfg.step_costs();
    let nodes: Vec<Node> = (0..cfg.nodes.max(1))
        .map(|id| {
            Ok(Node::new(
                id,
                cfg.chips_per_node,
                cfg.node_cache_bytes,
                cfg.max_state_bytes(),
                cfg.rdu.spec.dram,
                cfg.sched,
                costs,
                factory()?,
            ))
        })
        .collect::<Result<_>>()?;

    let mut scen = Vec::new();
    let mut seq = 0u64;
    for &(at, node) in &scenario.drain {
        scen.push(ScenEv { at, seq, kind: ScenKind::Drain(node) });
        seq += 1;
    }
    for &(at, node) in &scenario.fail {
        scen.push(ScenEv { at, seq, kind: ScenKind::Fail(node) });
        seq += 1;
    }
    for &(at, id, dest) in &scenario.migrate {
        scen.push(ScenEv { at, seq, kind: ScenKind::Migrate(id, dest) });
        seq += 1;
    }
    for e in &scen {
        let node = match e.kind {
            ScenKind::Drain(n) | ScenKind::Fail(n) => n,
            ScenKind::Migrate(_, d) => d,
        };
        if node >= nodes.len() {
            return Err(anyhow!("scenario names node {node}, fleet has {}", nodes.len()));
        }
        if !e.at.is_finite() || e.at < 0.0 {
            return Err(anyhow!("scenario event at non-finite/negative time {}", e.at));
        }
    }

    let mut sim = FleetSim {
        cfg,
        nodes,
        router: Router::new(cfg.policy),
        store: CheckpointStore::new(),
        scen,
        resumes: Vec::new(),
        pending_migrations: BTreeMap::new(),
        ledgers: BTreeMap::new(),
        latencies: Vec::new(),
        token_log: BTreeMap::new(),
        clock: 0.0,
        last_delivery: 0.0,
        mig: MigrationStats::default(),
    };
    sim.run(trace)
}

impl FleetSim<'_> {
    fn run(&mut self, trace: &[Arrival]) -> Result<FleetReport> {
        let mut next_arrival = 0usize;
        loop {
            // Pick the earliest event; fixed tie priority keeps runs
            // deterministic (completions < scenario < resumes < arrivals).
            let mut best: Option<(f64, u8, u64, Ev)> = None;
            let mut consider = |cand: (f64, u8, u64, Ev), best: &mut Option<(f64, u8, u64, Ev)>| {
                let better = match best {
                    None => true,
                    Some((t, p, s, _)) => {
                        (cand.0, cand.1, cand.2) < (*t, *p, *s)
                    }
                };
                if better {
                    *best = Some(cand);
                }
            };
            for (i, n) in self.nodes.iter().enumerate() {
                if n.batch_in_flight() {
                    consider((n.busy_until, 0, i as u64, Ev::Complete(i)), &mut best);
                }
            }
            for (i, e) in self.scen.iter().enumerate() {
                consider((e.at, 1, e.seq, Ev::Scen(i)), &mut best);
            }
            for (i, r) in self.resumes.iter().enumerate() {
                consider((r.at, 2, r.id, Ev::Resume(i)), &mut best);
            }
            if next_arrival < trace.len() {
                let a = &trace[next_arrival];
                consider((a.at, 3, a.id, Ev::Arrive), &mut best);
            }
            let Some((t, _, _, ev)) = best else { break };
            self.clock = t;
            match ev {
                Ev::Complete(n) => self.on_complete(n)?,
                Ev::Scen(i) => {
                    let e = self.scen.swap_remove(i);
                    match e.kind {
                        ScenKind::Drain(n) => self.on_drain(n)?,
                        ScenKind::Fail(n) => self.on_fail(n)?,
                        ScenKind::Migrate(id, dest) => self.on_migrate(id, dest)?,
                    }
                }
                Ev::Resume(i) => {
                    let r = self.resumes.swap_remove(i);
                    self.on_resume(r);
                }
                Ev::Arrive => {
                    let a = trace[next_arrival];
                    next_arrival += 1;
                    self.on_arrival(&a);
                }
            }
            // Every idle, non-draining node with ready work starts its next
            // batch at the current instant.
            for i in 0..self.nodes.len() {
                if self.nodes[i].ready() {
                    self.nodes[i].start_batch(self.clock)?;
                }
            }
        }
        for (id, lg) in &self.ledgers {
            if !lg.done && !lg.lost {
                return Err(anyhow!(
                    "fleet stalled: session {id} delivered {}/{} tokens",
                    lg.delivered,
                    lg.expected
                ));
            }
        }
        Ok(self.report(trace.len() as u64))
    }

    fn on_arrival(&mut self, a: &Arrival) {
        let shape = self.cfg.shape_for(a.model);
        let info =
            SessionInfo { model: a.model, shape, decode_steps: a.decode_steps };
        let mut rng = XorShift::new(self.cfg.seed ^ a.id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let prompt: Vec<f32> = (0..a.prompt_tokens * shape.d_model)
            .map(|_| rng.uniform(-1.0, 1.0) as f32)
            .collect();
        let mut lg = Ledger {
            arrival: self.clock,
            affinity: a.affinity,
            info,
            expected: a.decode_steps as u64,
            delivered: 0,
            prev_done: self.clock,
            done: false,
            lost: false,
        };
        match self.router.place(a.affinity, &self.nodes) {
            Some(dest) => {
                if self.cfg.checkpointing {
                    self.store.put(
                        a.id,
                        Checkpoint {
                            ticket: MigratedSession { info, phase: Phase::Prefill, tokens_done: 0 },
                            payload: SessionPayload {
                                prompt: Some(prompt.clone()),
                                ..Default::default()
                            },
                        },
                    );
                }
                self.nodes[dest].admit(a.id, info, prompt);
                self.router.assign(a.id, dest);
                self.router.stats.placed += 1;
                telemetry::counter("fleet.placements").fetch_add(1, Ordering::Relaxed);
                telemetry::instant_on(
                    "fleet",
                    "place",
                    telemetry::node_track(dest),
                    "session",
                    a.id as f64,
                );
            }
            None => {
                lg.lost = true;
                self.router.stats.refused += 1;
                telemetry::counter("fleet.lost_sessions").fetch_add(1, Ordering::Relaxed);
            }
        }
        self.ledgers.insert(a.id, lg);
    }

    fn on_complete(&mut self, n: usize) -> Result<()> {
        let delivered = self.nodes[n].complete_batch();
        for d in delivered {
            let lg = self
                .ledgers
                .get_mut(&d.id)
                .ok_or_else(|| anyhow!("token for unknown session {}", d.id))?;
            if d.step as u64 != lg.delivered {
                return Err(anyhow!(
                    "conservation violation: session {} delivered token {} but {} were done",
                    d.id,
                    d.step,
                    lg.delivered
                ));
            }
            lg.delivered += 1;
            self.latencies.push(self.clock - lg.prev_done);
            lg.prev_done = self.clock;
            self.last_delivery = self.clock;
            if self.cfg.record_tokens {
                self.token_log.entry(d.id).or_default().push(d.token.clone());
            }
            if d.retired {
                lg.done = true;
                self.store.remove(d.id);
                self.router.unassign(d.id);
                self.pending_migrations.remove(&d.id);
            } else if self.cfg.checkpointing {
                let info = lg.info;
                let tokens_done = lg.delivered as usize;
                self.store.put(
                    d.id,
                    Checkpoint {
                        ticket: MigratedSession { info, phase: Phase::Decode, tokens_done },
                        payload: SessionPayload {
                            state: d.state,
                            last_token: Some(d.token),
                            ..Default::default()
                        },
                    },
                );
            }
        }
        // Scripted moves waiting on this node's batch boundary.
        let waiting: Vec<(SessionId, usize)> = self
            .pending_migrations
            .iter()
            .filter(|&(id, _)| self.router.node_of(*id) == Some(n))
            .map(|(&id, &dest)| (id, dest))
            .collect();
        for (id, dest) in waiting {
            self.pending_migrations.remove(&id);
            self.start_migration(id, Some(dest), false)?;
        }
        // A draining node evacuates everything at its batch boundary.
        if self.nodes[n].draining {
            self.evacuate(n)?;
        }
        Ok(())
    }

    fn on_drain(&mut self, n: usize) -> Result<()> {
        if self.nodes[n].failed {
            return Ok(());
        }
        self.nodes[n].draining = true;
        telemetry::counter("fleet.drains").fetch_add(1, Ordering::Relaxed);
        telemetry::instant_on("fleet", "node.drain", telemetry::node_track(n), "node", n as f64);
        if !self.nodes[n].batch_in_flight() {
            self.evacuate(n)?;
        }
        Ok(())
    }

    /// Live-migrate every session off node `n` (which must have no batch
    /// in flight).
    fn evacuate(&mut self, n: usize) -> Result<()> {
        for id in self.router.sessions_on(n) {
            self.start_migration(id, None, false)?;
        }
        Ok(())
    }

    /// Checkpoint → transfer → resume for one session: export it from its
    /// node, price the payload across the network link, and schedule the
    /// resume on `dest` (or wherever the policy places it).
    fn start_migration(&mut self, id: SessionId, dest: Option<usize>, failover: bool) -> Result<()> {
        let Some(src) = self.router.node_of(id) else { return Ok(()) };
        let affinity = self.ledgers.get(&id).map(|l| l.affinity).unwrap_or(0);
        let dest = match dest {
            Some(d) if d == src => return Ok(()), // already home
            Some(d) if !self.nodes[d].failed && !self.nodes[d].draining => d,
            _ => match self.router.place(affinity, &self.nodes) {
                Some(d) if d != src => d,
                _ => return Ok(()), // nowhere better to go; stay put
            },
        };
        let Some((ticket, payload)) = self.nodes[src].export_session(id) else {
            return Err(anyhow!("migration of session {id}: step still in flight on node {src}"));
        };
        self.router.unassign(id);
        let bytes = payload.bytes();
        let secs = self.cfg.network.transfer_seconds(bytes as f64);
        self.mig.migrations += 1;
        self.mig.bytes_moved += bytes as u64;
        self.mig.transfer_seconds += secs;
        self.router.stats.migrations += 1;
        telemetry::counter("fleet.migrations").fetch_add(1, Ordering::Relaxed);
        {
            let _t = telemetry::span("fleet", "migrate")
                .arg("bytes", bytes as f64)
                .arg("modeled_us", secs * 1e6);
        }
        telemetry::instant_on(
            "fleet",
            "migrate.out",
            telemetry::node_track(src),
            "bytes",
            bytes as f64,
        );
        self.resumes.push(Resume { at: self.clock + secs, id, ticket, payload, dest, failover });
        Ok(())
    }

    fn on_fail(&mut self, n: usize) -> Result<()> {
        if self.nodes[n].failed {
            return Ok(());
        }
        self.nodes[n].fail();
        telemetry::counter("fleet.failstops").fetch_add(1, Ordering::Relaxed);
        for id in self.router.sessions_on(n) {
            self.router.unassign(id);
            self.pending_migrations.remove(&id);
            if !self.cfg.checkpointing {
                if let Some(lg) = self.ledgers.get_mut(&id) {
                    lg.lost = true;
                }
                self.store.remove(id);
                telemetry::counter("fleet.lost_sessions").fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let ck = self
                .store
                .take(id)
                .ok_or_else(|| anyhow!("session {id} has no checkpoint to recover from"))?;
            let affinity = self.ledgers.get(&id).map(|l| l.affinity).unwrap_or(0);
            let Some(dest) = self.router.place(affinity, &self.nodes) else {
                if let Some(lg) = self.ledgers.get_mut(&id) {
                    lg.lost = true;
                }
                telemetry::counter("fleet.lost_sessions").fetch_add(1, Ordering::Relaxed);
                continue;
            };
            let bytes = ck.bytes();
            let secs = self.cfg.network.transfer_seconds(bytes as f64);
            self.mig.failovers += 1;
            self.mig.bytes_moved += bytes as u64;
            self.mig.transfer_seconds += secs;
            self.router.stats.failovers += 1;
            telemetry::counter("fleet.failovers").fetch_add(1, Ordering::Relaxed);
            self.resumes.push(Resume {
                at: self.clock + secs,
                id,
                ticket: ck.ticket,
                payload: ck.payload,
                dest,
                failover: true,
            });
        }
        Ok(())
    }

    fn on_migrate(&mut self, id: SessionId, dest: usize) -> Result<()> {
        let Some(lg) = self.ledgers.get(&id) else { return Ok(()) };
        if lg.done || lg.lost {
            return Ok(());
        }
        let Some(src) = self.router.node_of(id) else {
            return Ok(()); // in transit; the scripted move is superseded
        };
        if src == dest {
            return Ok(());
        }
        if self.nodes[src].batch_in_flight() {
            // Step in flight: migrate at this node's batch boundary.
            self.pending_migrations.insert(id, dest);
            return Ok(());
        }
        self.start_migration(id, Some(dest), false)
    }

    fn on_resume(&mut self, r: Resume) {
        let dest = if self.nodes[r.dest].failed || self.nodes[r.dest].draining {
            // Destination changed state mid-transfer: re-place (one more
            // network hop).
            let affinity = self.ledgers.get(&r.id).map(|l| l.affinity).unwrap_or(0);
            match self.router.place(affinity, &self.nodes) {
                Some(d) => {
                    let secs = self.cfg.network.transfer_seconds(r.payload.bytes() as f64);
                    self.mig.transfer_seconds += secs;
                    self.mig.bytes_moved += r.payload.bytes() as u64;
                    self.resumes.push(Resume { at: self.clock + secs, dest: d, ..r });
                    return;
                }
                None => {
                    if let Some(lg) = self.ledgers.get_mut(&r.id) {
                        lg.lost = true;
                    }
                    telemetry::counter("fleet.lost_sessions").fetch_add(1, Ordering::Relaxed);
                    return;
                }
            }
        } else {
            r.dest
        };
        telemetry::instant_on(
            "fleet",
            if r.failover { "failover.in" } else { "migrate.in" },
            telemetry::node_track(dest),
            "bytes",
            r.payload.bytes() as f64,
        );
        self.nodes[dest].resume_session(r.id, r.ticket, r.payload);
        self.router.assign(r.id, dest);
    }

    fn report(&mut self, sessions: u64) -> FleetReport {
        let mut sorted = self.latencies.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
        let q = |q: f64| -> f64 {
            if sorted.is_empty() {
                return 0.0;
            }
            let idx = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len()) - 1;
            sorted[idx] * 1e6
        };
        let tokens = sorted.len() as u64;
        let slo = self.cfg.slo_us * 1e-6;
        let good = if self.cfg.slo_us > 0.0 {
            sorted.iter().filter(|&&l| l <= slo).count() as u64
        } else {
            tokens
        };
        let sim_seconds = self.last_delivery;
        let per_sec = |n: u64| if sim_seconds > 0.0 { n as f64 / sim_seconds } else { 0.0 };
        self.mig.checkpoint_puts = self.store.puts;
        self.mig.checkpoint_bytes = self.store.bytes_written;
        let per_node = self
            .nodes
            .iter()
            .map(|n| NodeReport {
                node: n.id,
                tokens: n.tokens,
                batches: n.batches,
                mean_batch: n.mean_batch(),
                sched: n.sched_stats(),
                cache: n.cache_stats(),
                per_chip: n.chip_stats(),
                drained: n.draining,
                failed: n.failed,
            })
            .collect();
        FleetReport {
            sessions,
            completed: self.ledgers.values().filter(|l| l.done).count() as u64,
            lost_sessions: self.ledgers.values().filter(|l| l.lost).count() as u64,
            tokens,
            sim_seconds,
            throughput_tok_s: per_sec(tokens),
            goodput_tok_s: per_sec(good),
            slo_us: self.cfg.slo_us,
            slo_attainment: if tokens == 0 { 1.0 } else { good as f64 / tokens as f64 },
            p50_us: q(0.50),
            p99_us: q(0.99),
            p999_us: q(0.999),
            mean_us: if tokens == 0 {
                0.0
            } else {
                sorted.iter().sum::<f64>() * 1e6 / tokens as f64
            },
            max_us: sorted.last().copied().unwrap_or(0.0) * 1e6,
            migrations: self.mig.clone(),
            router: self.router.stats.clone(),
            per_node,
            token_log: std::mem::take(&mut self.token_log),
        }
    }
}

/// Measure a single node's achievable token throughput and median latency
/// by replaying `trace` with every arrival at `t = 0` (full overload) on a
/// one-node fleet. The CLI and the fleet bench calibrate offered load and
/// the default SLO from this — scale-free against the modeled step costs.
pub fn calibrate_single_node(
    cfg: &FleetConfig,
    trace: &[Arrival],
    factory: &ExecutorFactory,
) -> Result<(f64, f64)> {
    let mut one = cfg.clone();
    one.nodes = 1;
    one.slo_us = 0.0;
    one.record_tokens = false;
    let burst: Vec<Arrival> = trace.iter().map(|a| Arrival { at: 0.0, ..*a }).collect();
    let r = run_fleet(&one, &burst, &FleetScenario::default(), factory)?;
    Ok((r.throughput_tok_s, r.p50_us))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::loadgen::{generate, TraceConfig};

    fn burst_trace(n: usize, decode_steps: usize) -> Vec<Arrival> {
        (1..=n)
            .map(|i| Arrival {
                id: i as SessionId,
                at: 0.0,
                model: if i % 2 == 0 { ModelKind::Hyena } else { ModelKind::Mamba },
                prompt_tokens: 16,
                decode_steps,
                affinity: i as u64 % 4,
            })
            .collect()
    }

    #[test]
    fn fleet_completes_a_poisson_trace() {
        let cfg = FleetConfig::demo(2, 2);
        let costs = cfg.step_costs();
        assert!(costs.worst() > 0.0, "decode steps must cost modeled time");
        // Arrival rate scaled to the modeled step cost so the run has both
        // queueing and idle stretches.
        let rate = 0.5 / costs.worst();
        let trace = generate(&TraceConfig::poisson(24, rate, 3));
        let r = run_fleet(&cfg, &trace, &FleetScenario::default(), &mock_factory()).unwrap();
        assert_eq!(r.sessions, 24);
        assert_eq!(r.completed, 24);
        assert_eq!(r.lost_sessions, 0);
        let expect: u64 = trace.iter().map(|a| a.decode_steps as u64).sum();
        assert_eq!(r.tokens, expect, "every decoded token delivered exactly once");
        assert!(r.sim_seconds > 0.0);
        assert!(r.throughput_tok_s > 0.0);
        assert!(r.p50_us > 0.0 && r.p50_us <= r.p99_us && r.p99_us <= r.p999_us);
        assert_eq!(r.slo_attainment, 1.0, "SLO off: every token is good");
        assert_eq!(r.per_node.len(), 2);
        assert_eq!(r.per_node.iter().map(|n| n.tokens).sum::<u64>(), expect);
        assert!(r.router.placed == 24);
        let table = r.node_table();
        assert!(table.contains("fleet"), "{table}");
    }

    #[test]
    fn slo_cut_separates_goodput_from_throughput() {
        let cfg = FleetConfig::demo(1, 1);
        let trace = burst_trace(8, 8);
        let mut strict = cfg.clone();
        strict.slo_us = 1e-9; // nothing is this fast
        let r = run_fleet(&strict, &trace, &FleetScenario::default(), &mock_factory()).unwrap();
        assert_eq!(r.slo_attainment, 0.0);
        assert_eq!(r.goodput_tok_s, 0.0);
        assert!(r.throughput_tok_s > 0.0);
        let mut loose = cfg;
        loose.slo_us = 1e12;
        let r = run_fleet(&loose, &trace, &FleetScenario::default(), &mock_factory()).unwrap();
        assert_eq!(r.slo_attainment, 1.0);
        assert!((r.goodput_tok_s - r.throughput_tok_s).abs() < 1e-9);
        assert!(r.summary().contains("SLO"));
    }

    #[test]
    fn drain_migrates_everything_losslessly() {
        let cfg = FleetConfig::demo(2, 2);
        let trace = burst_trace(12, 32);
        let probe = run_fleet(&cfg, &trace, &FleetScenario::default(), &mock_factory()).unwrap();
        let scenario =
            FleetScenario { drain: vec![(probe.sim_seconds * 0.3, 0)], ..Default::default() };
        let r = run_fleet(&cfg, &trace, &scenario, &mock_factory()).unwrap();
        assert_eq!(r.completed, 12, "drain loses nothing");
        assert_eq!(r.lost_sessions, 0);
        assert_eq!(r.tokens, probe.tokens);
        assert!(r.migrations.migrations > 0, "drain must move sessions");
        assert!(r.migrations.bytes_moved > 0);
        assert!(r.migrations.transfer_seconds > 0.0);
        assert!(r.per_node[0].drained);
        // Everything the drained node gave up landed on node 1.
        assert_eq!(r.per_node[1].sched.migrated_in, r.per_node[0].sched.migrated_out);
    }

    #[test]
    fn fail_stop_with_checkpointing_loses_zero_tokens() {
        let cfg = FleetConfig::demo(2, 2);
        let trace = burst_trace(12, 32);
        let probe = run_fleet(&cfg, &trace, &FleetScenario::default(), &mock_factory()).unwrap();
        let scenario =
            FleetScenario { fail: vec![(probe.sim_seconds * 0.4, 0)], ..Default::default() };
        let r = run_fleet(&cfg, &trace, &scenario, &mock_factory()).unwrap();
        assert_eq!(r.completed, 12, "checkpointed fail-stop is lossless");
        assert_eq!(r.lost_sessions, 0);
        assert_eq!(r.tokens, probe.tokens, "exactly-once delivery across the failure");
        assert!(r.migrations.failovers > 0, "failover must have happened");
        assert!(r.per_node[0].failed);
        assert!(r.migrations.checkpoint_puts > 0);
    }

    #[test]
    fn fail_stop_without_checkpointing_loses_sessions() {
        let mut cfg = FleetConfig::demo(2, 2);
        cfg.checkpointing = false;
        let trace = burst_trace(12, 64);
        let probe = run_fleet(&cfg, &trace, &FleetScenario::default(), &mock_factory()).unwrap();
        let scenario =
            FleetScenario { fail: vec![(probe.sim_seconds * 0.4, 0)], ..Default::default() };
        let r = run_fleet(&cfg, &trace, &scenario, &mock_factory()).unwrap();
        assert!(r.lost_sessions > 0, "no checkpoints: the dead node's sessions are gone");
        assert_eq!(r.completed + r.lost_sessions, 12, "every session accounted for");
        assert_eq!(r.migrations.failovers, 0);
    }

    #[test]
    fn scripted_migration_mid_decode_is_transparent() {
        let mut cfg = FleetConfig::demo(2, 2);
        cfg.record_tokens = true;
        let trace = burst_trace(6, 16);
        let base = run_fleet(&cfg, &trace, &FleetScenario::default(), &mock_factory()).unwrap();
        let probe_mid = base.sim_seconds * 0.5;
        // Session 1's location is policy-dependent, so script a move to
        // each node: the one naming its current home is a no-op.
        let scenario = FleetScenario {
            migrate: vec![(probe_mid, 1, 1), (probe_mid, 1, 0)],
            ..Default::default()
        };
        let r = run_fleet(&cfg, &trace, &scenario, &mock_factory()).unwrap();
        assert_eq!(r.completed, 6);
        assert!(r.migrations.migrations > 0, "one of the two scripted moves must apply");
        assert_eq!(
            r.token_log, base.token_log,
            "migration must not change any session's token trajectory"
        );
    }

    #[test]
    fn calibration_reports_positive_capacity() {
        let cfg = FleetConfig::demo(2, 2);
        let trace = burst_trace(8, 16);
        let (tok_s, p50_us) = calibrate_single_node(&cfg, &trace, &mock_factory()).unwrap();
        assert!(tok_s > 0.0);
        assert!(p50_us > 0.0);
    }
}
