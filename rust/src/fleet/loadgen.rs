//! Trace-driven load generation: deterministic session-arrival traces for
//! the fleet simulator.
//!
//! A trace is a time-sorted list of [`Arrival`]s — each one session with an
//! arrival instant, a model family, a prompt length, a decode budget, and a
//! placement-affinity key (a tenant id class the locality-affine policy
//! keys on). Three arrival processes are modeled:
//!
//! * **Poisson** — memoryless arrivals at a constant rate; the steady-state
//!   baseline every queueing result assumes.
//! * **Bursty** — an on/off cycle: a high-rate burst for the leading `duty`
//!   fraction of every period, a low base rate for the rest. Exercises
//!   admission-queue growth and the router's load-spreading under spikes.
//! * **Diurnal** — a sinusoidal swing around a mean rate, the day/night
//!   traffic envelope a long-running fleet actually sees.
//!
//! Non-constant rates are sampled exactly with Lewis–Shedler thinning:
//! candidate gaps are drawn from the process's *peak* rate and accepted
//! with probability `rate(t) / peak`, so the accepted stream is a true
//! inhomogeneous Poisson process with the configured intensity. Everything
//! derives from one [`crate::util::XorShift`] seed: the same
//! [`TraceConfig`] always yields the bit-identical trace, which is what
//! lets the fleet tests replay a trace against different topologies and
//! compare token streams exactly.

use crate::runtime::ModelKind;
use crate::session::SessionId;
use crate::util::XorShift;

/// Arrival-process shapes for [`generate`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Constant-rate memoryless arrivals (`rate` sessions/second).
    Poisson { rate: f64 },
    /// On/off cycle: `burst_rate` for the first `duty` fraction of every
    /// `period` seconds, `base_rate` for the remainder.
    Bursty { base_rate: f64, burst_rate: f64, period: f64, duty: f64 },
    /// Sinusoidal day/night swing: `mean_rate · (1 + amplitude·sin(2πt/period))`,
    /// clamped at zero (an `amplitude` of 1.0 idles the troughs entirely).
    Diurnal { mean_rate: f64, amplitude: f64, period: f64 },
}

impl ArrivalProcess {
    /// Instantaneous arrival intensity at time `t` (sessions/second).
    pub fn rate_at(&self, t: f64) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, period, duty } => {
                let phase = (t % period.max(1e-12)) / period.max(1e-12);
                if phase < duty {
                    burst_rate
                } else {
                    base_rate
                }
            }
            ArrivalProcess::Diurnal { mean_rate, amplitude, period } => {
                let s = (2.0 * std::f64::consts::PI * t / period.max(1e-12)).sin();
                (mean_rate * (1.0 + amplitude * s)).max(0.0)
            }
        }
    }

    /// Upper bound on [`rate_at`](Self::rate_at) over all `t` — the
    /// thinning envelope.
    pub fn peak_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, .. } => base_rate.max(burst_rate),
            ArrivalProcess::Diurnal { mean_rate, amplitude, .. } => {
                mean_rate * (1.0 + amplitude.abs())
            }
        }
    }

    /// CLI name of the process shape.
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Bursty { .. } => "bursty",
            ArrivalProcess::Diurnal { .. } => "diurnal",
        }
    }
}

/// One generated session arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Session id, unique and dense from 1.
    pub id: SessionId,
    /// Arrival instant in modeled seconds from trace start (nondecreasing).
    pub at: f64,
    pub model: ModelKind,
    /// Prompt length in tokens (scales the modeled prefill cost).
    pub prompt_tokens: usize,
    /// Tokens the session decodes (the prefill's first token counts).
    pub decode_steps: usize,
    /// Placement-affinity key — a tenant/user class; the locality-affine
    /// policy maps it to a preferred node.
    pub affinity: u64,
}

/// One load-generation scenario: how many sessions arrive, under what
/// process, with what prompt/decode length mixes.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Sessions in the trace.
    pub sessions: usize,
    pub process: ArrivalProcess,
    /// `(prompt_tokens, weight)` mix; weights need not sum to 1.
    pub prompt_mix: Vec<(usize, f64)>,
    /// `(decode_steps, weight)` mix.
    pub decode_mix: Vec<(usize, f64)>,
    /// Distinct affinity keys (tenants) to draw from.
    pub tenants: usize,
    /// PRNG seed; the whole trace is a pure function of this config.
    pub seed: u64,
}

impl TraceConfig {
    /// Default interactive-serving mix: mostly short prompts with a long
    /// tail, short-to-medium decodes.
    pub fn default_mixes() -> (Vec<(usize, f64)>, Vec<(usize, f64)>) {
        (
            vec![(16, 0.50), (64, 0.35), (256, 0.15)],
            vec![(8, 0.50), (32, 0.35), (128, 0.15)],
        )
    }

    fn with_process(sessions: usize, process: ArrivalProcess, seed: u64) -> Self {
        let (prompt_mix, decode_mix) = Self::default_mixes();
        Self { sessions, process, prompt_mix, decode_mix, tenants: 8, seed }
    }

    /// Constant-rate trace.
    pub fn poisson(sessions: usize, rate: f64, seed: u64) -> Self {
        Self::with_process(sessions, ArrivalProcess::Poisson { rate }, seed)
    }

    /// Bursty trace: 4× the base rate for the leading 20% of every cycle,
    /// with the cycle sized to span several bursts across the trace.
    pub fn bursty(sessions: usize, base_rate: f64, seed: u64) -> Self {
        let period = (sessions as f64 / base_rate.max(1e-9) / 8.0).max(1e-6);
        Self::with_process(
            sessions,
            ArrivalProcess::Bursty {
                base_rate,
                burst_rate: 4.0 * base_rate,
                period,
                duty: 0.2,
            },
            seed,
        )
    }

    /// Diurnal trace: ±80% sinusoidal swing around `mean_rate`, two full
    /// day/night cycles across the trace.
    pub fn diurnal(sessions: usize, mean_rate: f64, seed: u64) -> Self {
        let period = (sessions as f64 / mean_rate.max(1e-9) / 2.0).max(1e-6);
        Self::with_process(
            sessions,
            ArrivalProcess::Diurnal { mean_rate, amplitude: 0.8, period },
            seed,
        )
    }

    /// Weighted mean of the prompt-length mix (for capacity estimates).
    pub fn mean_prompt_tokens(&self) -> f64 {
        weighted_mean(&self.prompt_mix)
    }

    /// Weighted mean of the decode-length mix.
    pub fn mean_decode_tokens(&self) -> f64 {
        weighted_mean(&self.decode_mix)
    }
}

fn weighted_mean(mix: &[(usize, f64)]) -> f64 {
    let total: f64 = mix.iter().map(|(_, w)| w).sum();
    if total <= 0.0 {
        return 0.0;
    }
    mix.iter().map(|&(v, w)| v as f64 * w).sum::<f64>() / total
}

/// Draw one value from a `(value, weight)` mix.
fn pick(mix: &[(usize, f64)], rng: &mut XorShift) -> usize {
    let total: f64 = mix.iter().map(|(_, w)| w.max(0.0)).sum();
    if total <= 0.0 || mix.is_empty() {
        return 1;
    }
    let mut r = rng.next_f64() * total;
    for &(v, w) in mix {
        r -= w.max(0.0);
        if r <= 0.0 {
            return v;
        }
    }
    mix.last().map(|&(v, _)| v).unwrap_or(1)
}

/// Generate the arrival trace for `cfg`: `cfg.sessions` arrivals, sorted by
/// time, ids dense from 1. Deterministic in `cfg` (bit-identical replays).
pub fn generate(cfg: &TraceConfig) -> Vec<Arrival> {
    let peak = cfg.process.peak_rate();
    assert!(peak > 0.0, "arrival process needs a positive peak rate");
    let mut rng = XorShift::new(cfg.seed);
    let mut out = Vec::with_capacity(cfg.sessions);
    let mut t = 0.0f64;
    let mut id: SessionId = 0;
    while out.len() < cfg.sessions {
        // Candidate gap at the envelope rate; `1 - u ∈ (0, 1]` keeps the
        // log finite.
        let u = rng.next_f64();
        t += -(1.0 - u).ln() / peak;
        // Thinning: accept with probability rate(t)/peak.
        if rng.next_f64() * peak > cfg.process.rate_at(t) {
            continue;
        }
        id += 1;
        let model = if rng.next_f64() < 0.5 { ModelKind::Mamba } else { ModelKind::Hyena };
        let prompt_tokens = pick(&cfg.prompt_mix, &mut rng).max(1);
        let decode_steps = pick(&cfg.decode_mix, &mut rng).max(1);
        let affinity = rng.next_u64() % cfg.tenants.max(1) as u64;
        out.push(Arrival { id, at: t, model, prompt_tokens, decode_steps, affinity });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_and_well_formed() {
        let cfg = TraceConfig::poisson(200, 50.0, 11);
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a, b, "same config, same trace");
        assert_eq!(a.len(), 200);
        for (i, arr) in a.iter().enumerate() {
            assert_eq!(arr.id, (i + 1) as SessionId, "ids dense from 1");
            assert!(arr.prompt_tokens >= 1 && arr.decode_steps >= 1);
            assert!(arr.affinity < 8);
            if i > 0 {
                assert!(arr.at >= a[i - 1].at, "arrivals sorted by time");
            }
        }
        let c = generate(&TraceConfig { seed: 12, ..cfg });
        assert_ne!(a, c, "different seed, different trace");
    }

    #[test]
    fn poisson_mean_gap_matches_rate() {
        let rate = 100.0;
        let trace = generate(&TraceConfig::poisson(4000, rate, 3));
        let span = trace.last().unwrap().at - trace[0].at;
        let mean_gap = span / (trace.len() - 1) as f64;
        let expect = 1.0 / rate;
        assert!(
            (mean_gap - expect).abs() < 0.15 * expect,
            "mean gap {mean_gap:.5}s vs 1/rate {expect:.5}s"
        );
    }

    #[test]
    fn bursty_concentrates_arrivals_in_the_duty_window() {
        let process =
            ArrivalProcess::Bursty { base_rate: 10.0, burst_rate: 200.0, period: 1.0, duty: 0.2 };
        let (prompt_mix, decode_mix) = TraceConfig::default_mixes();
        let cfg = TraceConfig {
            sessions: 2000,
            process,
            prompt_mix,
            decode_mix,
            tenants: 8,
            seed: 9,
        };
        let trace = generate(&cfg);
        let in_burst = trace.iter().filter(|a| (a.at % 1.0) < 0.2).count();
        // Burst window carries 200·0.2 = 40 of the 48 arrivals/cycle ≈ 83%.
        assert!(
            in_burst as f64 > 0.7 * trace.len() as f64,
            "burst window holds {} of {}",
            in_burst,
            trace.len()
        );
        assert_eq!(process.peak_rate(), 200.0);
        assert_eq!(process.rate_at(0.1), 200.0);
        assert_eq!(process.rate_at(0.5), 10.0);
    }

    #[test]
    fn diurnal_rate_swings_and_clamps() {
        let p = ArrivalProcess::Diurnal { mean_rate: 100.0, amplitude: 1.0, period: 4.0 };
        assert!((p.rate_at(1.0) - 200.0).abs() < 1e-9, "crest at quarter period");
        assert!(p.rate_at(3.0).abs() < 1e-9, "trough idles");
        assert_eq!(p.peak_rate(), 200.0);
        // Troughs thin arrivals: the first half-period (high rate) carries
        // far more than the second.
        let (prompt_mix, decode_mix) = TraceConfig::default_mixes();
        let cfg = TraceConfig {
            sessions: 1000,
            process: p,
            prompt_mix,
            decode_mix,
            tenants: 4,
            seed: 21,
        };
        let trace = generate(&cfg);
        let first_half = trace.iter().filter(|a| (a.at % 4.0) < 2.0).count();
        assert!(first_half as f64 > 0.75 * trace.len() as f64, "{first_half}");
    }

    #[test]
    fn mixes_only_emit_configured_lengths() {
        let cfg = TraceConfig::poisson(500, 80.0, 4);
        let trace = generate(&cfg);
        for a in &trace {
            assert!(matches!(a.prompt_tokens, 16 | 64 | 256), "{}", a.prompt_tokens);
            assert!(matches!(a.decode_steps, 8 | 32 | 128), "{}", a.decode_steps);
        }
        // All three bins appear and both models occur.
        assert!(trace.iter().any(|a| a.prompt_tokens == 256));
        assert!(trace.iter().any(|a| a.decode_steps == 128));
        assert!(trace.iter().any(|a| a.model == ModelKind::Mamba));
        assert!(trace.iter().any(|a| a.model == ModelKind::Hyena));
        assert!((TraceConfig::poisson(1, 1.0, 1).mean_prompt_tokens() - 68.8).abs() < 1e-9);
        assert!((TraceConfig::poisson(1, 1.0, 1).mean_decode_tokens() - 34.4).abs() < 1e-9);
    }

    #[test]
    fn named_constructors_choose_their_process() {
        assert_eq!(TraceConfig::poisson(10, 5.0, 1).process.name(), "poisson");
        assert_eq!(TraceConfig::bursty(10, 5.0, 1).process.name(), "bursty");
        assert_eq!(TraceConfig::diurnal(10, 5.0, 1).process.name(), "diurnal");
    }
}
