//! Serving metrics: throughput counters and lock-free latency histograms —
//! one for one-shot request latency, one for per-token decode latency in
//! continuous mode.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Logarithmic latency histogram: bucket i covers [2^i, 2^{i+1}) µs.
const BUCKETS: usize = 32;

/// Shared counters updated by workers, snapshotted by observers.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub failures: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub exec_nanos: AtomicU64,
    pub queue_nanos: AtomicU64,
    /// Decoded tokens (continuous mode).
    pub tokens: AtomicU64,
    /// Cumulative per-token latency (queue + step execution).
    pub token_nanos: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
    token_latency_us: [AtomicU64; BUCKETS],
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket(us: u64) -> usize {
        (63 - us.max(1).leading_zeros() as usize).min(BUCKETS - 1)
    }

    /// Record one completed request.
    pub fn record_response(&self, queue: Duration, exec: Duration) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.exec_nanos.fetch_add(exec.as_nanos() as u64, Ordering::Relaxed);
        self.queue_nanos.fetch_add(queue.as_nanos() as u64, Ordering::Relaxed);
        let us = (queue + exec).as_micros() as u64;
        self.latency_us[Self::bucket(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one decoded token of a live session.
    pub fn record_token(&self, queue: Duration, exec: Duration) {
        self.tokens.fetch_add(1, Ordering::Relaxed);
        let total = queue + exec;
        self.token_nanos.fetch_add(total.as_nanos() as u64, Ordering::Relaxed);
        let us = total.as_micros() as u64;
        self.token_latency_us[Self::bucket(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one dispatched batch of `n` requests (or session steps).
    pub fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(n as u64, Ordering::Relaxed);
    }

    fn quantile_from(hist: &[AtomicU64; BUCKETS], q: f64) -> u64 {
        let counts: Vec<u64> = hist.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }

    /// Request-latency quantile estimate (bucket upper bound).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        Self::quantile_from(&self.latency_us, q)
    }

    /// Per-token latency quantile estimate (bucket upper bound).
    pub fn token_quantile_us(&self, q: f64) -> u64 {
        Self::quantile_from(&self.token_latency_us, q)
    }

    /// Mean per-token latency in microseconds (0.0 when no tokens yet).
    pub fn mean_token_us(&self) -> f64 {
        let t = self.tokens.load(Ordering::Relaxed);
        if t == 0 {
            return 0.0;
        }
        self.token_nanos.load(Ordering::Relaxed) as f64 / 1e3 / t as f64
    }

    /// Mean batch occupancy.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let resp = self.responses.load(Ordering::Relaxed);
        let mut s = format!(
            "responses={resp} failures={} batches={} mean_batch={:.2} p50={}µs p95={}µs",
            self.failures.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.latency_quantile_us(0.50),
            self.latency_quantile_us(0.95),
        );
        let tokens = self.tokens.load(Ordering::Relaxed);
        if tokens > 0 {
            s.push_str(&format!(
                " tokens={tokens} tok_mean={:.0}µs tok_p50={}µs tok_p95={}µs",
                self.mean_token_us(),
                self.token_quantile_us(0.50),
                self.token_quantile_us(0.95),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_log2() {
        assert_eq!(Metrics::bucket(1), 0);
        assert_eq!(Metrics::bucket(2), 1);
        assert_eq!(Metrics::bucket(1000), 9);
        assert_eq!(Metrics::bucket(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn quantiles_monotone() {
        let m = Metrics::new();
        for i in 0..100 {
            m.record_response(Duration::from_micros(i * 10), Duration::from_micros(50));
        }
        let p50 = m.latency_quantile_us(0.5);
        let p95 = m.latency_quantile_us(0.95);
        assert!(p50 <= p95, "p50={p50} p95={p95}");
        assert!(p50 > 0);
    }

    #[test]
    fn batch_occupancy() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(2);
        assert_eq!(m.mean_batch_size(), 3.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile_us(0.9), 0);
        assert_eq!(m.token_quantile_us(0.9), 0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert_eq!(m.mean_token_us(), 0.0);
        assert!(!m.summary().contains("tokens="), "token section only when tokens flow");
    }

    #[test]
    fn token_latency_tracked_separately() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.record_token(Duration::from_micros(100), Duration::from_micros(100));
        }
        assert_eq!(m.tokens.load(Ordering::Relaxed), 10);
        assert_eq!(m.responses.load(Ordering::Relaxed), 0, "tokens are not responses");
        assert!(m.token_quantile_us(0.5) >= 200);
        assert!((m.mean_token_us() - 200.0).abs() < 1.0);
        assert!(m.summary().contains("tokens=10"));
        assert_eq!(m.latency_quantile_us(0.5), 0, "request histogram untouched");
    }
}
