//! Serving metrics: throughput counters and lock-free latency histograms —
//! one for one-shot request latency, one for per-token decode latency in
//! continuous mode.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Logarithmic latency histogram: bucket i covers [2^i, 2^{i+1}) µs.
const BUCKETS: usize = 32;

/// Shared counters updated by workers, snapshotted by observers.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub failures: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub exec_nanos: AtomicU64,
    pub queue_nanos: AtomicU64,
    /// Decoded tokens (continuous mode).
    pub tokens: AtomicU64,
    /// Cumulative per-token latency (queue + step execution).
    pub token_nanos: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
    token_latency_us: [AtomicU64; BUCKETS],
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket(us: u64) -> usize {
        (63 - us.max(1).leading_zeros() as usize).min(BUCKETS - 1)
    }

    /// Record one completed request.
    pub fn record_response(&self, queue: Duration, exec: Duration) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.exec_nanos.fetch_add(exec.as_nanos() as u64, Ordering::Relaxed);
        self.queue_nanos.fetch_add(queue.as_nanos() as u64, Ordering::Relaxed);
        let us = (queue + exec).as_micros() as u64;
        self.latency_us[Self::bucket(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one decoded token of a live session.
    pub fn record_token(&self, queue: Duration, exec: Duration) {
        self.tokens.fetch_add(1, Ordering::Relaxed);
        let total = queue + exec;
        self.token_nanos.fetch_add(total.as_nanos() as u64, Ordering::Relaxed);
        let us = total.as_micros() as u64;
        self.token_latency_us[Self::bucket(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one dispatched batch of `n` requests (or session steps).
    pub fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Histogram quantile with sub-bucket linear interpolation: the target
    /// rank is located in its log2 bucket [2^i, 2^{i+1}) and positioned
    /// linearly within it, so tail quantiles move smoothly with load
    /// instead of snapping to power-of-two bucket upper bounds.
    fn quantile_from(hist: &[AtomicU64; BUCKETS], q: f64) -> u64 {
        let counts: Vec<u64> = hist.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let lo = (1u64 << i) as f64;
                let hi = (1u64 << (i + 1)) as f64;
                let frac = (target - seen) as f64 / c as f64;
                return (lo + frac * (hi - lo)).round() as u64;
            }
            seen += c;
        }
        1u64 << BUCKETS
    }

    /// Request-latency quantile estimate (interpolated within its bucket).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        Self::quantile_from(&self.latency_us, q)
    }

    /// Per-token latency quantile estimate (interpolated within its bucket).
    pub fn token_quantile_us(&self, q: f64) -> u64 {
        Self::quantile_from(&self.token_latency_us, q)
    }

    /// Request-latency p99 in microseconds.
    pub fn latency_p99_us(&self) -> u64 {
        self.latency_quantile_us(0.99)
    }

    /// Request-latency p999 in microseconds.
    pub fn latency_p999_us(&self) -> u64 {
        self.latency_quantile_us(0.999)
    }

    /// Per-token p99 in microseconds (continuous mode).
    pub fn token_p99_us(&self) -> u64 {
        self.token_quantile_us(0.99)
    }

    /// Per-token p999 in microseconds (continuous mode).
    pub fn token_p999_us(&self) -> u64 {
        self.token_quantile_us(0.999)
    }

    /// Mean per-token latency in microseconds (0.0 when no tokens yet).
    pub fn mean_token_us(&self) -> f64 {
        let t = self.tokens.load(Ordering::Relaxed);
        if t == 0 {
            return 0.0;
        }
        self.token_nanos.load(Ordering::Relaxed) as f64 / 1e3 / t as f64
    }

    /// Mean batch occupancy.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let resp = self.responses.load(Ordering::Relaxed);
        let mut s = format!(
            "responses={resp} failures={} batches={} mean_batch={:.2} \
             p50={}µs p95={}µs p99={}µs p999={}µs",
            self.failures.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.latency_quantile_us(0.50),
            self.latency_quantile_us(0.95),
            self.latency_p99_us(),
            self.latency_p999_us(),
        );
        let tokens = self.tokens.load(Ordering::Relaxed);
        if tokens > 0 {
            s.push_str(&format!(
                " tokens={tokens} tok_mean={:.0}µs tok_p50={}µs tok_p95={}µs \
                 tok_p99={}µs tok_p999={}µs",
                self.mean_token_us(),
                self.token_quantile_us(0.50),
                self.token_quantile_us(0.95),
                self.token_p99_us(),
                self.token_p999_us(),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_log2() {
        assert_eq!(Metrics::bucket(1), 0);
        assert_eq!(Metrics::bucket(2), 1);
        assert_eq!(Metrics::bucket(1000), 9);
        assert_eq!(Metrics::bucket(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn quantiles_monotone() {
        let m = Metrics::new();
        for i in 0..100 {
            m.record_response(Duration::from_micros(i * 10), Duration::from_micros(50));
        }
        let p50 = m.latency_quantile_us(0.5);
        let p95 = m.latency_quantile_us(0.95);
        assert!(p50 <= p95, "p50={p50} p95={p95}");
        assert!(p50 > 0);
    }

    #[test]
    fn quantiles_interpolate_within_buckets() {
        let m = Metrics::new();
        // 100 samples spread across bucket 9 ([512, 1024) µs): the old
        // upper-bound estimate pinned every quantile here to 1024.
        for i in 0..100u64 {
            m.record_response(Duration::ZERO, Duration::from_micros(512 + 5 * i));
        }
        let p25 = m.latency_quantile_us(0.25);
        let p50 = m.latency_quantile_us(0.50);
        let p99 = m.latency_p99_us();
        assert!(
            p25 < p50 && p50 < p99,
            "interpolation must separate in-bucket quantiles: {p25} {p50} {p99}"
        );
        // Rank 50 of 100 sits exactly halfway into [512, 1024) → 768.
        assert_eq!(p50, 768);
        assert!(p99 < 1024);
        assert_eq!(m.latency_p999_us(), m.latency_quantile_us(0.999));
    }

    #[test]
    fn summary_reports_tail_quantiles() {
        let m = Metrics::new();
        m.record_response(Duration::ZERO, Duration::from_micros(100));
        let s = m.summary();
        assert!(s.contains("p99="), "summary must carry p99: {s}");
        assert!(s.contains("p999="), "summary must carry p999: {s}");
        m.record_token(Duration::ZERO, Duration::from_micros(10));
        let s = m.summary();
        assert!(s.contains("tok_p99=") && s.contains("tok_p999="), "{s}");
    }

    #[test]
    fn batch_occupancy() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(2);
        assert_eq!(m.mean_batch_size(), 3.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile_us(0.9), 0);
        assert_eq!(m.token_quantile_us(0.9), 0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert_eq!(m.mean_token_us(), 0.0);
        assert!(!m.summary().contains("tokens="), "token section only when tokens flow");
    }

    #[test]
    fn quantile_edge_cases_empty_single_bucket_and_extremes() {
        // Empty histogram: every quantile is 0, including the extremes.
        let m = Metrics::new();
        for q in [0.0, 0.5, 1.0] {
            assert_eq!(m.latency_quantile_us(q), 0, "empty histogram must report 0 at q={q}");
        }
        // Single-bucket histogram: all mass in bucket 0 ([1, 2) µs).
        // Interpolation may not escape the bucket, and q=0 must clamp the
        // target rank up to 1 rather than underflow.
        for _ in 0..7 {
            m.record_response(Duration::ZERO, Duration::from_micros(1));
        }
        for q in [0.0, 0.5, 0.999, 1.0] {
            let v = m.latency_quantile_us(q);
            assert!((1..=2).contains(&v), "q={q} escaped the only bucket: {v}");
        }
        assert!(m.latency_quantile_us(0.0) <= m.latency_quantile_us(1.0));
    }

    #[test]
    fn quantile_saturating_top_bucket() {
        // Durations beyond 2^31 µs all saturate into the top bucket; the
        // interpolated estimate must stay inside [2^31, 2^32] and never
        // overflow or return the old `1 << BUCKETS` sentinel.
        let m = Metrics::new();
        for _ in 0..4 {
            m.record_response(Duration::ZERO, Duration::from_micros(u64::MAX / 2));
        }
        let lo = 1u64 << (BUCKETS - 1);
        let hi = 1u64 << BUCKETS;
        for q in [0.5, 0.99, 0.999, 1.0] {
            let v = m.latency_quantile_us(q);
            assert!(
                (lo..=hi).contains(&v),
                "q={q} must interpolate within the saturating top bucket: {v}"
            );
        }
        // Ranks 2 of 4 and 4 of 4 land at frac 0.5 and 1.0 of the bucket.
        assert_eq!(m.latency_quantile_us(0.5), lo + (hi - lo) / 2);
        assert_eq!(m.latency_quantile_us(1.0), hi);
    }

    #[test]
    fn token_latency_tracked_separately() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.record_token(Duration::from_micros(100), Duration::from_micros(100));
        }
        assert_eq!(m.tokens.load(Ordering::Relaxed), 10);
        assert_eq!(m.responses.load(Ordering::Relaxed), 0, "tokens are not responses");
        let p50 = m.token_quantile_us(0.5);
        assert!((128..=256).contains(&p50), "p50={p50} must land in the samples' bucket");
        assert!(m.token_p999_us() >= p50);
        assert!((m.mean_token_us() - 200.0).abs() < 1.0);
        assert!(m.summary().contains("tokens=10"));
        assert_eq!(m.latency_quantile_us(0.5), 0, "request histogram untouched");
    }
}
