//! Dynamic batcher: per-model request queues flushed by size or deadline —
//! the vLLM-router-style policy adapted to fixed-batch AOT artifacts.
//!
//! A batch launches when either (a) `max_batch` requests of one model are
//! queued, or (b) the oldest queued request has waited `max_wait`. Partial
//! batches are padded to the artifact's batch dimension by the worker.

use super::request::Request;
use crate::runtime::ModelKind;
use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

/// A group of requests sharing one PJRT dispatch.
#[derive(Debug)]
pub struct Batch {
    pub model: ModelKind,
    pub requests: Vec<(Request, Sender<super::request::Response>)>,
}

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max requests per batch (clamped to the artifact's batch slots).
    pub max_batch: usize,
    /// Max time the oldest request may wait before a partial batch launches.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 4, max_wait: Duration::from_millis(5) }
    }
}

/// Per-model FIFO queues with deadline tracking.
pub struct DynamicBatcher {
    policy: BatchPolicy,
    queues: BTreeMap<ModelKind, VecDeque<(Request, Sender<super::request::Response>)>>,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { policy, queues: BTreeMap::new() }
    }

    /// Enqueue one request.
    pub fn push(&mut self, req: Request, reply: Sender<super::request::Response>) {
        self.queues.entry(req.model).or_default().push_back((req, reply));
    }

    /// Total queued requests.
    pub fn queued(&self) -> usize {
        self.queues.values().map(VecDeque::len).sum()
    }

    /// Pop a batch that is ready *now* (full, or past deadline), if any.
    pub fn pop_ready(&mut self, now: Instant) -> Option<Batch> {
        // Full batches first (throughput), then expired partials (latency).
        let full = self
            .queues
            .iter()
            .find(|(_, q)| q.len() >= self.policy.max_batch)
            .map(|(&m, _)| m);
        let model = full.or_else(|| {
            self.queues
                .iter()
                .filter(|(_, q)| !q.is_empty())
                .find(|(_, q)| {
                    now.duration_since(q.front().unwrap().0.submitted) >= self.policy.max_wait
                })
                .map(|(&m, _)| m)
        })?;
        let q = self.queues.get_mut(&model).unwrap();
        let n = q.len().min(self.policy.max_batch);
        let requests: Vec<_> = q.drain(..n).collect();
        Some(Batch { model, requests })
    }

    /// Earliest queue deadline, for the dispatcher's timed wait.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.front())
            .map(|(r, _)| r.submitted + self.policy.max_wait)
            .min()
    }

    /// Drain everything (shutdown path).
    pub fn drain_all(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        for (&model, q) in self.queues.iter_mut() {
            while !q.is_empty() {
                let n = q.len().min(self.policy.max_batch);
                out.push(Batch { model, requests: q.drain(..n).collect() });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(id: u64, model: ModelKind) -> Request {
        Request::new(id, model, vec![0.0; 4])
    }

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn full_batch_launches_immediately() {
        let mut b = DynamicBatcher::new(policy(2, 1000));
        let (tx, _rx) = channel();
        b.push(req(1, ModelKind::Hyena), tx.clone());
        assert!(b.pop_ready(Instant::now()).is_none(), "partial batch must wait");
        b.push(req(2, ModelKind::Hyena), tx);
        let batch = b.pop_ready(Instant::now()).expect("full batch ready");
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn partial_batch_launches_after_deadline() {
        let mut b = DynamicBatcher::new(policy(8, 5));
        let (tx, _rx) = channel();
        b.push(req(1, ModelKind::Mamba), tx);
        assert!(b.pop_ready(Instant::now()).is_none());
        let later = Instant::now() + Duration::from_millis(6);
        let batch = b.pop_ready(later).expect("deadline batch");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.model, ModelKind::Mamba);
    }

    #[test]
    fn models_batch_independently() {
        let mut b = DynamicBatcher::new(policy(2, 1000));
        let (tx, _rx) = channel();
        b.push(req(1, ModelKind::Hyena), tx.clone());
        b.push(req(2, ModelKind::Mamba), tx.clone());
        assert!(b.pop_ready(Instant::now()).is_none(), "no cross-model batching");
        b.push(req(3, ModelKind::Hyena), tx);
        let batch = b.pop_ready(Instant::now()).unwrap();
        assert_eq!(batch.model, ModelKind::Hyena);
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn fifo_order_within_model() {
        let mut b = DynamicBatcher::new(policy(3, 0));
        let (tx, _rx) = channel();
        for id in 1..=3 {
            b.push(req(id, ModelKind::Attention), tx.clone());
        }
        let batch = b.pop_ready(Instant::now()).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = DynamicBatcher::new(policy(8, 10));
        assert!(b.next_deadline().is_none());
        let (tx, _rx) = channel();
        let r1 = req(1, ModelKind::Hyena);
        let t1 = r1.submitted;
        b.push(r1, tx);
        assert_eq!(b.next_deadline(), Some(t1 + Duration::from_millis(10)));
    }

    #[test]
    fn size_flush_outranks_deadline_flush() {
        // When one model has a *full* batch and another has an *expired*
        // partial, the full batch launches first (throughput before
        // latency), then the expired partial on the next pop.
        let mut b = DynamicBatcher::new(policy(2, 5));
        let (tx, _rx) = channel();
        let mut old = req(1, ModelKind::Mamba);
        old.submitted = Instant::now() - Duration::from_millis(50); // long expired
        b.push(old, tx.clone());
        b.push(req(2, ModelKind::Hyena), tx.clone());
        b.push(req(3, ModelKind::Hyena), tx);
        let now = Instant::now();
        let first = b.pop_ready(now).expect("something is ready");
        assert_eq!(first.model, ModelKind::Hyena, "full batch wins");
        assert_eq!(first.requests.len(), 2);
        let second = b.pop_ready(now).expect("expired partial still flushes");
        assert_eq!(second.model, ModelKind::Mamba);
        assert_eq!(second.requests.len(), 1);
        assert!(b.pop_ready(now).is_none());
    }

    #[test]
    fn fresh_partial_waits_while_expired_partial_flushes() {
        let mut b = DynamicBatcher::new(policy(8, 5));
        let (tx, _rx) = channel();
        let mut old = req(1, ModelKind::Mamba);
        old.submitted = Instant::now() - Duration::from_millis(50);
        b.push(old, tx.clone());
        b.push(req(2, ModelKind::Hyena), tx); // fresh, far from deadline
        let now = Instant::now();
        let batch = b.pop_ready(now).expect("expired partial is ready");
        assert_eq!(batch.model, ModelKind::Mamba);
        assert!(b.pop_ready(now).is_none(), "fresh partial keeps waiting");
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn drain_all_chunks_by_max_batch() {
        let mut b = DynamicBatcher::new(policy(2, 1000));
        let (tx, _rx) = channel();
        for id in 0..5 {
            b.push(req(id, ModelKind::Mamba), tx.clone());
        }
        let batches = b.drain_all();
        assert_eq!(batches.len(), 3); // 2 + 2 + 1
        assert_eq!(b.queued(), 0);
    }
}
