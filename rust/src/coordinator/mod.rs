//! L3 serving coordinator: request router, dynamic batcher, worker pool and
//! metrics — the leader process that owns the event loop while PJRT
//! executables (built once from JAX/Pallas) do the math.
//!
//! Architecture (vLLM-router-shaped, std-thread implementation — tokio is
//! not vendored in the offline image):
//!
//! ```text
//!  clients ──submit()──▶ dispatcher thread ──Batch──▶ worker 0 (own PJRT set)
//!                        │  per-model queues │        worker 1
//!                        │  size/deadline    │        …
//!                        ╰── metrics ◀───────┴── responses ──▶ reply channels
//! ```
//!
//! * [`request`] — request/response types.
//! * [`batcher`] — the dynamic batching policy (flush on full or deadline).
//! * [`executor`] — the PJRT backend + a deterministic mock for tests.
//! * [`metrics`] — throughput counters and latency histogram.

pub mod batcher;
pub mod executor;
pub mod metrics;
pub mod request;

pub use batcher::{Batch, BatchPolicy, DynamicBatcher};
pub use executor::{Executor, ExecutorFactory, MockExecutor, PjrtExecutor};
pub use metrics::Metrics;
pub use request::{Request, Response};

use crate::runtime::ModelKind;
use crate::Result;
use anyhow::anyhow;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorConfig {
    pub policy: BatchPolicy,
    /// Worker threads, each owning its own executor (its own compiled PJRT
    /// executables — they are not shared across threads).
    pub workers: usize,
    /// Backpressure: maximum requests in flight (queued + executing).
    /// `submit` fails fast once this is reached, so a slow backend sheds
    /// load instead of growing an unbounded queue.
    pub max_inflight: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self { policy: BatchPolicy::default(), workers: 1, max_inflight: 4096 }
    }
}

enum Msg {
    Submit(Request, Sender<Response>),
    Shutdown,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Sender<Msg>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    running: Arc<AtomicBool>,
    max_inflight: usize,
}

impl Coordinator {
    /// Start the dispatcher and `cfg.workers` worker threads; each worker
    /// builds its executor from `factory`.
    pub fn start(cfg: CoordinatorConfig, factory: ExecutorFactory) -> Result<Self> {
        if cfg.workers == 0 {
            return Err(anyhow!("coordinator needs at least one worker"));
        }
        let metrics = Arc::new(Metrics::new());
        let running = Arc::new(AtomicBool::new(true));
        let (tx, rx) = channel::<Msg>();
        let (batch_tx, batch_rx) = channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        // Worker pool. Executors are built *inside* each thread (PJRT
        // executables are thread-affine); a handshake channel surfaces
        // construction failures to the caller.
        let factory = Arc::new(factory);
        let mut workers = Vec::with_capacity(cfg.workers);
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        for wid in 0..cfg.workers {
            let rx = Arc::clone(&batch_rx);
            let metrics = Arc::clone(&metrics);
            let factory = Arc::clone(&factory);
            let ready = ready_tx.clone();
            workers.push(std::thread::Builder::new().name(format!("ssm-rdu-worker-{wid}")).spawn(
                move || match factory() {
                    Ok(exec) => {
                        let _ = ready.send(Ok(()));
                        worker_loop(exec, rx, metrics);
                    }
                    Err(e) => {
                        let _ = ready.send(Err(e));
                    }
                },
            )?);
        }
        drop(ready_tx);
        for _ in 0..cfg.workers {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("worker died before handshake"))??;
        }

        // Dispatcher.
        let policy = cfg.policy;
        let metrics2 = Arc::clone(&metrics);
        let running2 = Arc::clone(&running);
        let dispatcher = std::thread::Builder::new().name("ssm-rdu-dispatch".into()).spawn(
            move || dispatcher_loop(policy, rx, batch_tx, metrics2, running2),
        )?;

        Ok(Self {
            tx,
            metrics,
            next_id: AtomicU64::new(1),
            dispatcher: Some(dispatcher),
            workers,
            running,
            max_inflight: cfg.max_inflight,
        })
    }

    /// Requests currently in flight (submitted − completed − failed).
    pub fn inflight(&self) -> u64 {
        let m = &self.metrics;
        m.requests
            .load(Ordering::Relaxed)
            .saturating_sub(m.responses.load(Ordering::Relaxed))
            .saturating_sub(m.failures.load(Ordering::Relaxed))
    }

    /// Submit one request; returns the channel its response arrives on.
    ///
    /// Fails fast with a backpressure error when `max_inflight` is reached.
    pub fn submit(&self, model: ModelKind, input: Vec<f32>) -> Result<Receiver<Response>> {
        if !self.running.load(Ordering::SeqCst) {
            return Err(anyhow!("coordinator is shut down"));
        }
        if self.inflight() >= self.max_inflight as u64 {
            return Err(anyhow!(
                "backpressure: {} requests in flight (max {})",
                self.inflight(),
                self.max_inflight
            ));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = channel();
        self.tx
            .send(Msg::Submit(Request::new(id, model, input), rtx))
            .map_err(|_| anyhow!("dispatcher gone"))?;
        Ok(rrx)
    }

    /// Submit and wait for the response.
    pub fn call(&self, model: ModelKind, input: Vec<f32>) -> Result<Response> {
        let rx = self.submit(model, input)?;
        rx.recv().map_err(|_| anyhow!("worker dropped the request"))
    }

    /// Graceful shutdown: flush queues, join threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.running.swap(false, Ordering::SeqCst) {
            let _ = self.tx.send(Msg::Shutdown);
            if let Some(d) = self.dispatcher.take() {
                let _ = d.join();
            }
            for w in self.workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn dispatcher_loop(
    policy: BatchPolicy,
    rx: Receiver<Msg>,
    batch_tx: Sender<Batch>,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
) {
    let mut batcher = DynamicBatcher::new(policy);
    loop {
        // Launch everything that is ready.
        while let Some(b) = batcher.pop_ready(Instant::now()) {
            metrics.record_batch(b.requests.len());
            if batch_tx.send(b).is_err() {
                return; // workers gone
            }
        }
        // Wait for the next event: new request or queue deadline.
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Submit(req, reply)) => batcher.push(req, reply),
            Ok(Msg::Shutdown) => break,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        }
        if !running.load(Ordering::SeqCst) {
            break;
        }
    }
    // Flush remaining work so no caller hangs.
    for b in batcher.drain_all() {
        metrics.record_batch(b.requests.len());
        if batch_tx.send(b).is_err() {
            break;
        }
    }
}

fn worker_loop(
    mut exec: Box<dyn Executor>,
    rx: Arc<Mutex<Receiver<Batch>>>,
    metrics: Arc<Metrics>,
) {
    loop {
        // Hold the lock only to receive.
        let batch = {
            let guard = rx.lock().expect("batch channel lock poisoned");
            match guard.recv() {
                Ok(b) => b,
                Err(_) => return, // dispatcher gone and queue drained
            }
        };
        run_batch(exec.as_mut(), batch, &metrics);
    }
}

/// Pack, execute and scatter one batch (shared by the worker loop and the
/// integration tests).
pub fn run_batch(exec: &mut dyn Executor, batch: Batch, metrics: &Metrics) {
    let model = batch.model;
    let slots = exec.batch_slots(model).max(1);
    let elems = exec.slot_elems(model);
    let n = batch.requests.len();
    debug_assert!(n <= slots, "batcher must respect artifact slots");

    // Pack into the artifact's fixed batch shape, zero-padding empty slots.
    let launched = Instant::now();
    let mut packed = vec![0f32; slots * elems];
    let mut ok = true;
    for (i, (req, _)) in batch.requests.iter().enumerate() {
        if req.input.len() != elems {
            ok = false;
            break;
        }
        packed[i * elems..(i + 1) * elems].copy_from_slice(&req.input);
    }

    let result = if ok {
        exec.execute(model, &packed)
    } else {
        Err(anyhow!("request activation size != artifact slot size {elems}"))
    };
    let exec_time = launched.elapsed();

    match result {
        Ok(out) => {
            for (i, (req, reply)) in batch.requests.into_iter().enumerate() {
                let queue_time = launched.duration_since(req.submitted);
                metrics.record_response(queue_time, exec_time);
                let _ = reply.send(Response {
                    id: req.id,
                    model,
                    output: out[i * elems..(i + 1) * elems].to_vec(),
                    queue_time,
                    exec_time,
                    batch_size: n,
                });
            }
        }
        Err(_) => {
            // Failure: drop reply senders so callers observe RecvError
            // rather than hanging; count the failures.
            metrics.failures.fetch_add(n as u64, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mock_factory(slots: usize, elems: usize) -> ExecutorFactory {
        Box::new(move || Ok(Box::new(MockExecutor::new(slots, elems)) as Box<dyn Executor>))
    }

    #[test]
    fn single_request_roundtrip() {
        let c = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                workers: 1,
                ..Default::default()
            },
            mock_factory(4, 8),
        )
        .unwrap();
        let resp = c.call(ModelKind::Mamba, vec![1.0; 8]).unwrap();
        assert_eq!(resp.output, vec![2.0; 8]);
        assert_eq!(resp.batch_size, 1);
        c.shutdown();
    }

    #[test]
    fn batches_fill_under_load() {
        let c = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) },
                workers: 1,
                ..Default::default()
            },
            mock_factory(4, 2),
        )
        .unwrap();
        let rxs: Vec<_> =
            (0..8).map(|i| c.submit(ModelKind::Hyena, vec![i as f32, 0.0]).unwrap()).collect();
        let mut sizes = Vec::new();
        for rx in rxs {
            sizes.push(rx.recv().unwrap().batch_size);
        }
        // Under a burst of 8 with max_batch 4, full batches form.
        assert!(sizes.contains(&4), "sizes={sizes:?}");
        assert!((c.metrics.mean_batch_size() - 0.0).abs() > 0.0);
        c.shutdown();
    }

    #[test]
    fn wrong_input_size_fails_cleanly() {
        let c = Coordinator::start(CoordinatorConfig::default(), mock_factory(4, 8)).unwrap();
        let rx = c.submit(ModelKind::Attention, vec![1.0; 3]).unwrap();
        assert!(rx.recv().is_err(), "bad-size request must not hang");
        assert_eq!(c.metrics.failures.load(Ordering::Relaxed), 1);
        c.shutdown();
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let c = Coordinator::start(CoordinatorConfig::default(), mock_factory(1, 1)).unwrap();
        let metrics = Arc::clone(&c.metrics);
        c.shutdown();
        let _ = metrics; // metrics survive shutdown
    }

    #[test]
    fn multiple_workers_share_load() {
        let c = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
                workers: 4,
                ..Default::default()
            },
            Box::new(move || {
                let mut m = MockExecutor::new(1, 4);
                m.delay = Duration::from_millis(10);
                Ok(Box::new(m) as Box<dyn Executor>)
            }),
        )
        .unwrap();
        let t0 = Instant::now();
        let rxs: Vec<_> =
            (0..8).map(|_| c.submit(ModelKind::Mamba, vec![0.0; 4]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let elapsed = t0.elapsed();
        // 8 × 10 ms serialized would be ≥ 80 ms; 4 workers should roughly
        // halve that at minimum.
        assert!(elapsed < Duration::from_millis(70), "elapsed={elapsed:?}");
        c.shutdown();
    }
}
