//! L3 serving coordinator: request router, batcher, worker pool and
//! metrics — the leader process that owns the event loop while PJRT
//! executables (built once from JAX/Pallas) do the math.
//!
//! Architecture (vLLM-router-shaped, std-thread implementation — tokio is
//! not vendored in the offline image). Two batching modes share the worker
//! pool:
//!
//! ```text
//!  one-shot ──submit()─────────▶ dispatcher ──────Batch channel─────▶ worker 0..W
//!                                │ DynamicBatcher: per-model queues,│  each owns
//!                                │ flush on size or deadline        │  its own
//!  sessions ──submit_session()─▶ │ SessionScheduler: prefill→decode │  executor
//!   (--continuous)               │ steps pushed as they become ready│
//!                                │            │ per-chip deques     ▼
//!                                │   StealBoard[chip0 | chip1 | …] ─▶ home-chip pop
//!                                │        ▲        (idle workers steal the busiest
//!                                │        │         chip's youngest step)
//!                                │        ╰── Msg::Feedback ◀── step results
//!                                ╰── metrics ◀─────┴── responses / tokens ──▶ clients
//! ```
//!
//! * [`request`] — request/response types (+ session metadata).
//! * [`batcher`] — the dynamic batching policy (flush on full or deadline).
//! * [`executor`] — the PJRT backend + a deterministic mock for tests; the
//!   mock also implements the stateful `begin_session`/`step_decode` pair.
//! * [`metrics`] — throughput counters, request- and token-latency
//!   histograms.
//!
//! Continuous mode (`CoordinatorConfig::continuous`) replaces the
//! flush-on-deadline batcher with the [`crate::session`] subsystem: the
//! dispatcher owns a [`SessionScheduler`] and one [`StateCache`] *per
//! chip* ([`ContinuousConfig::chips`]); ready steps are pushed onto their
//! home chip's deque in a [`crate::runtime::StealBoard`] **as they become
//! ready** — there is no iteration barrier. Workers drain their home
//! chip's deque FIFO and, when idle, steal the youngest step from the
//! busiest other chip, so one slow chip (or one slow spill/restore) no
//! longer stalls the fleet: decode steps of other sessions overlap a
//! session's `StateCache` spill/restore because the cache lock is held
//! only for checkout/checkin bookkeeping while the step executes
//! unlocked. Completions feed back so the scheduler retires sessions and
//! re-admits the next decode step; per-session step ordering is preserved
//! because the scheduler keeps at most one step per session in flight.
//! Steal traffic is counted in `coordinator.steals` and marked with
//! `steal.task` instants on the trace (ARCHITECTURE.md §5.4).

pub mod batcher;
pub mod executor;
pub mod metrics;
pub mod request;

pub use batcher::{Batch, BatchPolicy, DynamicBatcher};
pub use executor::{Executor, ExecutorFactory, MockExecutor, PjrtExecutor};
pub use metrics::Metrics;
pub use request::{Request, Response, SessionMeta};

use crate::arch::MemTech;
use crate::runtime::{ModelKind, StealBoard};
use crate::session::{
    CacheStats, MemoryBudget, Phase, SchedStats, SchedulerConfig, SessionId, SessionInfo,
    SessionScheduler, StateCache, StateShape, StepOutcome,
};
use crate::Result;
use anyhow::anyhow;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Continuous-batching (session serving) configuration.
#[derive(Debug, Clone, Copy)]
pub struct ContinuousConfig {
    pub sched: SchedulerConfig,
    /// *Per-chip* resident state budget in bytes (see [`MemoryBudget`]):
    /// each chip owns its own [`StateCache`] sized to its own SRAM, so a
    /// deployment's total resident state is `chips × budget_bytes`.
    pub budget_bytes: usize,
    /// State shape for Mamba sessions.
    pub mamba_shape: StateShape,
    /// State shape for Hyena sessions.
    pub hyena_shape: StateShape,
    /// RDU chips backing the deployment. Sessions are pinned to a home chip
    /// (`session id mod chips`) whose cache holds their state; ready steps
    /// land on the home chip's deque of the [`StealBoard`], and idle
    /// workers steal across chips. The scheduler's one-step-per-session
    /// in-flight rule provides the ordering the inter-chip exchange
    /// requires (see [`crate::shard`]).
    pub chips: usize,
}

impl ContinuousConfig {
    pub fn new(budget_bytes: usize, mamba_shape: StateShape, hyena_shape: StateShape) -> Self {
        Self { sched: SchedulerConfig::default(), budget_bytes, mamba_shape, hyena_shape, chips: 1 }
    }

    /// Shard the deployment over `chips` chips (clamped to ≥ 1).
    pub fn with_chips(mut self, chips: usize) -> Self {
        self.chips = chips.max(1);
        self
    }

    pub fn shape_for(&self, model: ModelKind) -> StateShape {
        match model {
            ModelKind::Hyena => self.hyena_shape,
            _ => self.mamba_shape,
        }
    }
}

/// A session's home chip: sessions are striped across chips by id.
fn chip_of(id: SessionId, chips: usize) -> usize {
    (id % chips.max(1) as u64) as usize
}

/// Coordinator configuration.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorConfig {
    pub policy: BatchPolicy,
    /// Worker threads, each owning its own executor (its own compiled PJRT
    /// executables — they are not shared across threads).
    pub workers: usize,
    /// Backpressure: maximum requests (or live sessions) in flight.
    /// `submit`/`submit_session` fail fast once this is reached, so a slow
    /// backend sheds load instead of growing an unbounded queue.
    pub max_inflight: usize,
    /// `Some(_)` switches the dispatcher from the dynamic batcher to the
    /// continuous-batching session scheduler.
    pub continuous: Option<ContinuousConfig>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self { policy: BatchPolicy::default(), workers: 1, max_inflight: 4096, continuous: None }
    }
}

/// One step of one session, ready for a worker.
struct StepTask {
    session: SessionId,
    model: ModelKind,
    phase: Phase,
    /// 0-based token index this step produces.
    step: usize,
    /// Home chip whose state cache holds this session.
    chip: usize,
    shape: StateShape,
    /// Prompt for prefill, previous token for decode.
    input: Vec<f32>,
    reply: Sender<Response>,
    issued: Instant,
}

/// Worker → dispatcher completion report.
struct StepFeedback {
    session: SessionId,
    /// The produced token (feeds the next decode step's input).
    token: Option<Vec<f32>>,
    ok: bool,
}

enum Msg {
    Submit(Request, Sender<Response>),
    Feedback(StepFeedback),
    Shutdown,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Sender<Msg>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    running: Arc<AtomicBool>,
    max_inflight: usize,
    /// One state cache per chip (continuous mode only).
    caches: Option<Arc<Vec<Mutex<StateCache>>>>,
    scheduler: Option<Arc<Mutex<SessionScheduler>>>,
    /// Per-chip work-stealing deques (continuous mode only). The
    /// dispatcher closes the board on exit; shutdown closes it again
    /// defensively so workers can never hang on join.
    board: Option<Arc<StealBoard<StepTask>>>,
}

impl Coordinator {
    /// Start the dispatcher and `cfg.workers` worker threads; each worker
    /// builds its executor from `factory`.
    pub fn start(cfg: CoordinatorConfig, factory: ExecutorFactory) -> Result<Self> {
        if cfg.workers == 0 {
            return Err(anyhow!("coordinator needs at least one worker"));
        }
        let metrics = Arc::new(Metrics::new());
        let running = Arc::new(AtomicBool::new(true));
        let (tx, rx) = channel::<Msg>();
        let (work_tx, work_rx) = channel::<Batch>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        // Continuous mode dispatches through per-chip stealing deques
        // instead of the shared batch channel.
        let board =
            cfg.continuous.map(|cc| Arc::new(StealBoard::<StepTask>::new(cc.chips.max(1))));

        let caches = cfg.continuous.map(|cc| {
            Arc::new(
                (0..cc.chips.max(1))
                    .map(|chip| {
                        let mut cache = StateCache::new(
                            MemoryBudget::new(cc.budget_bytes),
                            MemTech::Hbm3e,
                        );
                        // Route this cache's spill/restore instants onto a
                        // per-chip trace track, regardless of which worker
                        // thread happens to service the chip.
                        let track = crate::telemetry::chip_track(chip);
                        cache.set_track(track);
                        if crate::telemetry::enabled() {
                            crate::telemetry::name_track(
                                crate::telemetry::PID_HOST,
                                track,
                                format!("chip {chip}"),
                            );
                        }
                        Mutex::new(cache)
                    })
                    .collect::<Vec<_>>(),
            )
        });
        let scheduler =
            cfg.continuous.map(|cc| Arc::new(Mutex::new(SessionScheduler::new(cc.sched))));

        // Worker pool. Executors are built *inside* each thread (PJRT
        // executables are thread-affine); a handshake channel surfaces
        // construction failures to the caller. Continuous-mode workers are
        // homed per `topology::worker_homes` — contiguous worker blocks per
        // chip by default, so a chip's deque/state/arenas stay NUMA-local
        // (`SSM_RDU_PIN_HOMES=0` restores the old `wid % chips` interleave)
        // — and claim steps from the steal board; batch-mode workers share
        // the batch channel.
        let factory = Arc::new(factory);
        let mut workers = Vec::with_capacity(cfg.workers);
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let chips = cfg.continuous.map(|cc| cc.chips.max(1)).unwrap_or(1);
        let homes = crate::runtime::topology::worker_homes(cfg.workers, chips);
        for wid in 0..cfg.workers {
            let metrics = Arc::clone(&metrics);
            let factory = Arc::clone(&factory);
            let ready = ready_tx.clone();
            let feedback = tx.clone();
            let spawn = std::thread::Builder::new().name(format!("ssm-rdu-worker-{wid}"));
            workers.push(match &board {
                Some(b) => {
                    let board = Arc::clone(b);
                    let caches =
                        Arc::clone(caches.as_ref().expect("continuous mode builds caches"));
                    let home = homes[wid];
                    spawn.spawn(move || match factory() {
                        Ok(exec) => {
                            let _ = ready.send(Ok(()));
                            steal_worker_loop(exec, home, board, caches, metrics, feedback);
                        }
                        Err(e) => {
                            let _ = ready.send(Err(e));
                        }
                    })?
                }
                None => {
                    let rx = Arc::clone(&work_rx);
                    spawn.spawn(move || match factory() {
                        Ok(exec) => {
                            let _ = ready.send(Ok(()));
                            worker_loop(exec, rx, metrics);
                        }
                        Err(e) => {
                            let _ = ready.send(Err(e));
                        }
                    })?
                }
            });
        }
        drop(ready_tx);
        for _ in 0..cfg.workers {
            let up = ready_rx.recv().map_err(|_| anyhow!("worker died before handshake"));
            if let Err(e) = up.and_then(|r| r) {
                // Unblock the already-spawned steal workers before erroring
                // (batch workers exit on their own when work_tx drops).
                if let Some(b) = &board {
                    b.close();
                }
                return Err(e);
            }
        }

        // Dispatcher: dynamic batcher or continuous session scheduler.
        let metrics2 = Arc::clone(&metrics);
        let running2 = Arc::clone(&running);
        let dispatcher = match cfg.continuous {
            None => {
                let policy = cfg.policy;
                std::thread::Builder::new().name("ssm-rdu-dispatch".into()).spawn(move || {
                    dispatcher_loop(policy, rx, work_tx, metrics2, running2)
                })?
            }
            Some(cc) => {
                let sched = Arc::clone(scheduler.as_ref().expect("continuous scheduler"));
                let caches2 = Arc::clone(caches.as_ref().expect("continuous caches"));
                let board2 = Arc::clone(board.as_ref().expect("continuous board"));
                std::thread::Builder::new().name("ssm-rdu-dispatch".into()).spawn(move || {
                    continuous_loop(cc, rx, board2, sched, caches2, metrics2, running2)
                })?
            }
        };

        Ok(Self {
            tx,
            metrics,
            next_id: AtomicU64::new(1),
            dispatcher: Some(dispatcher),
            workers,
            running,
            max_inflight: cfg.max_inflight,
            caches,
            scheduler,
            board,
        })
    }

    /// Requests (or live sessions) currently in flight:
    /// submitted − completed − failed.
    pub fn inflight(&self) -> u64 {
        let m = &self.metrics;
        m.requests
            .load(Ordering::Relaxed)
            .saturating_sub(m.responses.load(Ordering::Relaxed))
            .saturating_sub(m.failures.load(Ordering::Relaxed))
    }

    /// Submit one one-shot request; returns the channel its response
    /// arrives on.
    ///
    /// Fails fast with a backpressure error when `max_inflight` is reached.
    /// Backpressure audit: a rejected request is refused *before* the
    /// in-flight counter moves, and a request the dispatcher never received
    /// rolls its slot back — neither path can leak in-flight accounting.
    pub fn submit(&self, model: ModelKind, input: Vec<f32>) -> Result<Receiver<Response>> {
        if !self.running.load(Ordering::SeqCst) {
            return Err(anyhow!("coordinator is shut down"));
        }
        if self.caches.is_some() {
            return Err(anyhow!("coordinator is in continuous mode; use submit_session"));
        }
        if self.inflight() >= self.max_inflight as u64 {
            return Err(anyhow!(
                "backpressure: {} requests in flight (max {})",
                self.inflight(),
                self.max_inflight
            ));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = channel();
        if self.tx.send(Msg::Submit(Request::new(id, model, input), rtx)).is_err() {
            // Roll the slot back: the request never entered the system.
            self.metrics.requests.fetch_sub(1, Ordering::Relaxed);
            return Err(anyhow!("dispatcher gone"));
        }
        Ok(rrx)
    }

    /// Open a decode session (continuous mode only): the prompt is
    /// prefilled, then `decode_steps` token [`Response`]s stream over the
    /// returned channel (the prefill's first token included); the channel
    /// closes after the last token.
    pub fn submit_session(
        &self,
        model: ModelKind,
        prompt: Vec<f32>,
        decode_steps: usize,
    ) -> Result<Receiver<Response>> {
        if !self.running.load(Ordering::SeqCst) {
            return Err(anyhow!("coordinator is shut down"));
        }
        if self.caches.is_none() {
            return Err(anyhow!(
                "continuous mode is off; set CoordinatorConfig::continuous to serve sessions"
            ));
        }
        if model == ModelKind::Attention {
            return Err(anyhow!("sessions cache O(1) SSM state; attention is not servable here"));
        }
        if decode_steps == 0 {
            return Err(anyhow!("decode_steps must be ≥ 1"));
        }
        if prompt.is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        if self.inflight() >= self.max_inflight as u64 {
            return Err(anyhow!(
                "backpressure: {} sessions in flight (max {})",
                self.inflight(),
                self.max_inflight
            ));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = channel();
        if self
            .tx
            .send(Msg::Submit(Request::session_open(id, model, prompt, decode_steps), rtx))
            .is_err()
        {
            self.metrics.requests.fetch_sub(1, Ordering::Relaxed);
            return Err(anyhow!("dispatcher gone"));
        }
        Ok(rrx)
    }

    /// Submit and wait for the response.
    pub fn call(&self, model: ModelKind, input: Vec<f32>) -> Result<Response> {
        let rx = self.submit(model, input)?;
        rx.recv().map_err(|_| anyhow!("worker dropped the request"))
    }

    /// Fleet-wide snapshot of the state-cache counters, folded across all
    /// chips (continuous mode only).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.caches.as_ref().map(|cs| {
            let mut agg = CacheStats::default();
            for c in cs.iter() {
                agg.merge(&c.lock().expect("state cache lock").stats);
            }
            agg
        })
    }

    /// Per-chip snapshots of the state-cache counters (continuous mode
    /// only), indexed by chip.
    pub fn chip_cache_stats(&self) -> Option<Vec<CacheStats>> {
        self.caches.as_ref().map(|cs| {
            cs.iter().map(|c| c.lock().expect("state cache lock").stats.clone()).collect()
        })
    }

    /// Bytes of session state currently resident across all chips
    /// (continuous mode only).
    pub fn cache_resident_bytes(&self) -> Option<usize> {
        self.caches.as_ref().map(|cs| {
            cs.iter().map(|c| c.lock().expect("state cache lock").resident_bytes()).sum()
        })
    }

    /// Snapshot of the scheduler counters (continuous mode only).
    pub fn scheduler_stats(&self) -> Option<SchedStats> {
        self.scheduler.as_ref().map(|s| s.lock().expect("scheduler lock").stats.clone())
    }

    /// Graceful shutdown: flush queues, join threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.running.swap(false, Ordering::SeqCst) {
            let _ = self.tx.send(Msg::Shutdown);
            if let Some(d) = self.dispatcher.take() {
                let _ = d.join();
            }
            // The dispatcher closes the board on every exit path; close it
            // again defensively (idempotent) so a panicked dispatcher can
            // never leave workers waiting forever.
            if let Some(b) = &self.board {
                b.close();
            }
            for w in self.workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn dispatcher_loop(
    policy: BatchPolicy,
    rx: Receiver<Msg>,
    work_tx: Sender<Batch>,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
) {
    let mut batcher = DynamicBatcher::new(policy);
    loop {
        // Launch everything that is ready.
        while let Some(b) = batcher.pop_ready(Instant::now()) {
            metrics.record_batch(b.requests.len());
            crate::telemetry::instant_arg(
                "coordinator",
                "batch.cut",
                "size",
                b.requests.len() as f64,
            );
            if let Err(e) = work_tx.send(b) {
                // Workers gone: the batch is lost; account for it so
                // in-flight tracking cannot leak.
                metrics.failures.fetch_add(e.0.requests.len() as u64, Ordering::Relaxed);
                return;
            }
        }
        // Wait for the next event: new request or queue deadline.
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(crate::runtime::EVENT_LOOP_TICK);
        match rx.recv_timeout(timeout) {
            Ok(Msg::Submit(req, reply)) => batcher.push(req, reply),
            Ok(Msg::Feedback(_)) => {} // continuous-mode only; ignore here
            Ok(Msg::Shutdown) => break,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        }
        if !running.load(Ordering::SeqCst) {
            break;
        }
    }
    // Shutdown: requests may still sit in the channel behind the Shutdown
    // message — pull them into the batcher so they flush too and no
    // caller hangs with a leaked in-flight slot.
    for m in rx.try_iter() {
        if let Msg::Submit(req, reply) = m {
            batcher.push(req, reply);
        }
    }
    for b in batcher.drain_all() {
        metrics.record_batch(b.requests.len());
        if let Err(e) = work_tx.send(b) {
            metrics.failures.fetch_add(e.0.requests.len() as u64, Ordering::Relaxed);
            break;
        }
    }
}

/// Dispatcher-side bookkeeping for one live session.
struct SessionSide {
    reply: Sender<Response>,
    /// Taken at prefill dispatch.
    prompt: Option<Vec<f32>>,
    /// The most recent token — the next decode step's input.
    last_token: Vec<f32>,
}

/// State of the continuous dispatcher's event handling.
enum Control {
    Continue,
    Shutdown,
}

fn continuous_loop(
    cc: ContinuousConfig,
    rx: Receiver<Msg>,
    board: Arc<StealBoard<StepTask>>,
    scheduler: Arc<Mutex<SessionScheduler>>,
    caches: Arc<Vec<Mutex<StateCache>>>,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
) {
    let chips = caches.len().max(1);
    let mut side: BTreeMap<SessionId, SessionSide> = BTreeMap::new();
    // Steps dispatched to workers whose feedback has not arrived yet —
    // pure accounting for the shutdown drain. There is deliberately **no
    // iteration barrier** on it: ready steps are pushed to the per-chip
    // deques the moment the scheduler admits them, and the scheduler's
    // one-step-per-session in-flight rule is what keeps per-session
    // ordering (a session's next step cannot be issued until its previous
    // step's feedback updated `last_token` right here in this thread).
    let mut outstanding: usize = 0;

    let handle = |msg: Msg,
                      side: &mut BTreeMap<SessionId, SessionSide>,
                      outstanding: &mut usize|
     -> Control {
        match msg {
            Msg::Submit(req, reply) => {
                if let Some(meta) = req.session {
                    scheduler.lock().expect("scheduler lock").admit(
                        req.id,
                        SessionInfo {
                            model: req.model,
                            shape: cc.shape_for(req.model),
                            decode_steps: meta.decode_steps,
                        },
                        Instant::now(),
                    );
                    side.insert(
                        req.id,
                        SessionSide { reply, prompt: Some(req.input), last_token: Vec::new() },
                    );
                } else {
                    // One-shot submits are refused at `submit()` in this
                    // mode; account defensively if one slips through.
                    metrics.failures.fetch_add(1, Ordering::Relaxed);
                }
                Control::Continue
            }
            Msg::Feedback(fb) => {
                *outstanding = outstanding.saturating_sub(1);
                handle_feedback(fb, &scheduler, &caches, &metrics, side);
                Control::Continue
            }
            Msg::Shutdown => Control::Shutdown,
        }
    };

    'event: loop {
        // Block for one event, then drain everything already queued so the
        // scheduler sees the full picture before cutting the next wave.
        match rx.recv_timeout(crate::runtime::EVENT_LOOP_TICK) {
            Ok(msg) => {
                if let Control::Shutdown = handle(msg, &mut side, &mut outstanding) {
                    break 'event;
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break 'event,
        }
        while let Ok(msg) = rx.try_recv() {
            if let Control::Shutdown = handle(msg, &mut side, &mut outstanding) {
                break 'event;
            }
        }
        if !running.load(Ordering::SeqCst) {
            break;
        }
        // Expire sessions idle past the timeout (their reply channels close
        // so clients unblock; their cached state is dropped).
        let expired = scheduler.lock().expect("scheduler lock").expire(Instant::now());
        for id in expired {
            crate::telemetry::instant_arg("coordinator", "session.expire", "id", id as f64);
            side.remove(&id);
            caches[chip_of(id, chips)].lock().expect("state cache lock").remove(id);
            metrics.failures.fetch_add(1, Ordering::Relaxed);
        }
        // Push every ready step onto its home chip's deque immediately —
        // no waiting for the previous wave to drain. `next_batch` marks
        // issued sessions in flight, so the loop terminates once every
        // live session has a step queued or executing.
        loop {
            let steps = scheduler.lock().expect("scheduler lock").next_batch();
            if steps.is_empty() {
                break;
            }
            // One span per scheduler wave on the dispatcher track; the
            // per-chip cuts below show how the wave sharded.
            let _wave = crate::telemetry::span("coordinator", "sched.wave")
                .arg("steps", steps.len() as f64);
            let mut tasks = Vec::with_capacity(steps.len());
            for s in steps {
                let Some(entry) = side.get_mut(&s.id) else {
                    // Bookkeeping lost (should not happen): fail the session
                    // rather than strand it in flight.
                    scheduler.lock().expect("scheduler lock").fail(s.id);
                    caches[chip_of(s.id, chips)].lock().expect("state cache lock").remove(s.id);
                    metrics.failures.fetch_add(1, Ordering::Relaxed);
                    continue;
                };
                let input = match s.phase {
                    Phase::Prefill => entry.prompt.take().unwrap_or_default(),
                    Phase::Decode => entry.last_token.clone(),
                };
                tasks.push(StepTask {
                    session: s.id,
                    model: s.model,
                    phase: s.phase,
                    step: s.step,
                    chip: chip_of(s.id, chips),
                    shape: cc.shape_for(s.model),
                    input,
                    reply: entry.reply.clone(),
                    issued: Instant::now(),
                });
            }
            if tasks.is_empty() {
                continue;
            }
            // Sharded dispatch: each step lands on its home chip's deque.
            // Workers homed elsewhere steal from the busiest deque when
            // idle, so chips with deep queues shed load instead of
            // stalling the wave.
            let mut per_chip: BTreeMap<usize, Vec<StepTask>> = BTreeMap::new();
            for t in tasks {
                per_chip.entry(t.chip).or_default().push(t);
            }
            for (chip, tasks) in per_chip {
                metrics.record_batch(tasks.len());
                crate::telemetry::instant_arg(
                    "coordinator",
                    "batch.cut",
                    "chip",
                    chip as f64,
                );
                outstanding += tasks.len();
                board.push_many(chip, tasks);
            }
        }
    }
    // Shutdown: let in-flight steps land (their tokens were already paid
    // for), then fail whatever is still live so in-flight accounting
    // returns to zero and clients' channels close.
    let deadline = Instant::now() + Duration::from_millis(500);
    while outstanding > 0 && Instant::now() < deadline {
        match rx.recv_timeout(Duration::from_millis(10)) {
            Ok(Msg::Feedback(fb)) => {
                outstanding = outstanding.saturating_sub(1);
                handle_feedback(fb, &scheduler, &caches, &metrics, &mut side);
            }
            Ok(Msg::Submit(req, _reply)) => {
                // A session that raced shutdown: never admitted, so count
                // it out of the in-flight accounting (the dropped reply
                // unblocks the client).
                if req.session.is_some() {
                    metrics.failures.fetch_add(1, Ordering::Relaxed);
                }
            }
            Ok(Msg::Shutdown) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    for m in rx.try_iter() {
        if let Msg::Submit(req, _reply) = m {
            if req.session.is_some() {
                metrics.failures.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    metrics.failures.fetch_add(side.len() as u64, Ordering::Relaxed);
    // Retire the steal board: workers drain whatever is still queued and
    // then exit on `None` (close is idempotent with `shutdown_inner`'s
    // defensive close).
    board.close();
}

fn handle_feedback(
    fb: StepFeedback,
    scheduler: &Arc<Mutex<SessionScheduler>>,
    caches: &Arc<Vec<Mutex<StateCache>>>,
    metrics: &Metrics,
    side: &mut BTreeMap<SessionId, SessionSide>,
) {
    let cache = &caches[chip_of(fb.session, caches.len())];
    if !fb.ok {
        // The worker already counted the failure; end the session.
        scheduler.lock().expect("scheduler lock").fail(fb.session);
        side.remove(&fb.session);
        cache.lock().expect("state cache lock").remove(fb.session);
        return;
    }
    if let Some(token) = fb.token {
        if let Some(entry) = side.get_mut(&fb.session) {
            entry.last_token = token;
        }
    }
    match scheduler.lock().expect("scheduler lock").on_step_done(fb.session, Instant::now()) {
        StepOutcome::Retired => {
            // Dropping the side entry closes the client's channel after its
            // final token; one session = one completed "request".
            side.remove(&fb.session);
            cache.lock().expect("state cache lock").remove(fb.session);
            metrics.responses.fetch_add(1, Ordering::Relaxed);
        }
        StepOutcome::Continue | StepOutcome::Unknown => {}
    }
}

fn worker_loop(
    mut exec: Box<dyn Executor>,
    rx: Arc<Mutex<Receiver<Batch>>>,
    metrics: Arc<Metrics>,
) {
    loop {
        // Hold the lock only to receive.
        let batch = {
            let guard = rx.lock().expect("work channel lock poisoned");
            match guard.recv() {
                Ok(b) => b,
                Err(_) => return, // dispatcher gone and queue drained
            }
        };
        run_batch(exec.as_mut(), batch, &metrics);
    }
}

/// Process-wide count of session steps a worker executed for a chip other
/// than its home (i.e. steals). Cross-referenced with the `steal.task`
/// instants on the Perfetto timeline.
fn steals_counter() -> &'static std::sync::atomic::AtomicU64 {
    static C: std::sync::OnceLock<&'static std::sync::atomic::AtomicU64> =
        std::sync::OnceLock::new();
    C.get_or_init(|| crate::telemetry::counter("coordinator.steals"))
}

/// Continuous-mode worker body: claim session steps from the steal board
/// (home chip first, then the busiest other chip's youngest step) until the
/// dispatcher closes the board.
fn steal_worker_loop(
    mut exec: Box<dyn Executor>,
    home: usize,
    board: Arc<StealBoard<StepTask>>,
    caches: Arc<Vec<Mutex<StateCache>>>,
    metrics: Arc<Metrics>,
    feedback: Sender<Msg>,
) {
    while let Some(claim) = board.next(home) {
        if claim.stolen {
            steals_counter().fetch_add(1, Ordering::Relaxed);
            crate::telemetry::instant_arg(
                "coordinator",
                "steal.task",
                "from_chip",
                claim.origin as f64,
            );
        }
        run_step(exec.as_mut(), claim.item, &caches, &metrics, &feedback);
        board.complete(claim.origin);
    }
}

/// Pack, execute and scatter one batch (shared by the worker loop and the
/// integration tests).
pub fn run_batch(exec: &mut dyn Executor, batch: Batch, metrics: &Metrics) {
    let model = batch.model;
    let slots = exec.batch_slots(model).max(1);
    let elems = exec.slot_elems(model);
    let n = batch.requests.len();
    debug_assert!(n <= slots, "batcher must respect artifact slots");

    // Pack into the artifact's fixed batch shape, zero-padding empty slots.
    // Large batches fan the per-slot copies across the worker pool (the
    // pack is pure disjoint memcpy, so pooling is bit-identical); small
    // ones stay serial — thread spawn would dominate.
    const PAR_PACK_MIN_ELEMS: usize = 1 << 20;
    let _batch_span = crate::telemetry::span("coordinator", "batch.run").arg("batch", n as f64);
    let launched = Instant::now();
    let mut packed = vec![0f32; slots * elems];
    let ok = batch.requests.iter().all(|(req, _)| req.input.len() == elems);
    if ok {
        let _pack = crate::telemetry::span("coordinator", "batch.pack")
            .arg("elems", (n * elems) as f64);
        if n > 1 && n * elems >= PAR_PACK_MIN_ELEMS {
            let pool = crate::runtime::WorkerPool::from_env();
            let mut slices: Vec<&mut [f32]> = packed[..n * elems].chunks_mut(elems).collect();
            pool.for_each_mut(&mut slices, |i, slot| {
                slot.copy_from_slice(&batch.requests[i].0.input);
            });
        } else {
            for (i, (req, _)) in batch.requests.iter().enumerate() {
                packed[i * elems..(i + 1) * elems].copy_from_slice(&req.input);
            }
        }
    }

    let result = if ok {
        let _exec = crate::telemetry::span("coordinator", "batch.execute").arg("batch", n as f64);
        exec.execute(model, &packed)
    } else {
        Err(anyhow!("request activation size != artifact slot size {elems}"))
    };
    let exec_time = launched.elapsed();

    match result {
        Ok(out) => {
            for (i, (req, reply)) in batch.requests.into_iter().enumerate() {
                let queue_time = launched.duration_since(req.submitted);
                metrics.record_response(queue_time, exec_time);
                let _ = reply.send(Response {
                    id: req.id,
                    model,
                    output: out[i * elems..(i + 1) * elems].to_vec(),
                    queue_time,
                    exec_time,
                    batch_size: n,
                    token_index: None,
                });
            }
        }
        Err(_) => {
            // Failure: drop reply senders so callers observe RecvError
            // rather than hanging; count the failures.
            metrics.failures.fetch_add(n as u64, Ordering::Relaxed);
        }
    }
}

/// Execute one session step against the shared state cache, streaming the
/// produced token to its client and reporting completion back to the
/// dispatcher. Steal granularity is exactly one step, so the cache lock is
/// held only for this step's checkout/checkin bookkeeping — decode compute
/// for one session overlaps another session's spill/restore on the same
/// chip.
fn run_step(
    exec: &mut dyn Executor,
    task: StepTask,
    caches: &Arc<Vec<Mutex<StateCache>>>,
    metrics: &Metrics,
    feedback: &Sender<Msg>,
) {
    // The session's home chip owns its state; a stolen step still locks the
    // *origin* chip's cache. A chip id out of range is a dispatcher bug —
    // index loudly.
    let cache = &caches[task.chip];
    let queue_time = task.issued.elapsed();
    // The exec span lives on the worker's own track (per-chip tracks
    // carry only instants: concurrent same-chip work on two workers
    // would break span nesting) and names the chip via an argument.
    let _step = crate::telemetry::span(
        "coordinator",
        match task.phase {
            Phase::Prefill => "step.prefill",
            Phase::Decode => "step.decode",
        },
    )
    .arg("chip", task.chip as f64)
    .arg("queue_us", queue_time.as_secs_f64() * 1e6);
    let t0 = Instant::now();
    let result: Result<Vec<f32>> = match task.phase {
        Phase::Prefill => {
            exec.begin_session(task.model, &task.input, &task.shape).map(|(state, first)| {
                // First touch: the session's state buffer is allocated and
                // written *here*, on the worker servicing the claim — with
                // block homing (`runtime::topology`) that is a home worker
                // of `task.chip`, so the pages land on the NUMA node that
                // services every later decode of this session.
                crate::telemetry::instant_arg(
                    "placement",
                    "place.first_touch",
                    "chip",
                    task.chip as f64,
                );
                cache.lock().expect("state cache lock").insert(task.session, state);
                first
            })
        }
        Phase::Decode => {
            // Checkout holds the lock only for bookkeeping; the decode
            // step itself runs without the cache locked.
            let state = cache.lock().expect("state cache lock").checkout(task.session);
            match state {
                None => Err(anyhow!("session {} has no cached state", task.session)),
                Some(mut st) => {
                    let r = exec.step_decode(task.model, &mut st, &task.input);
                    cache.lock().expect("state cache lock").checkin(task.session, st);
                    r
                }
            }
        }
    };
    let exec_time = t0.elapsed();
    match result {
        Ok(token) => {
            metrics.record_token(queue_time, exec_time);
            let _ = task.reply.send(Response {
                id: task.session,
                model: task.model,
                output: token.clone(),
                queue_time,
                exec_time,
                batch_size: 1,
                token_index: Some(task.step),
            });
            let _ = feedback.send(Msg::Feedback(StepFeedback {
                session: task.session,
                token: Some(token),
                ok: true,
            }));
        }
        Err(_) => {
            metrics.failures.fetch_add(1, Ordering::Relaxed);
            let _ = feedback.send(Msg::Feedback(StepFeedback {
                session: task.session,
                token: None,
                ok: false,
            }));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mock_factory(slots: usize, elems: usize) -> ExecutorFactory {
        Box::new(move || Ok(Box::new(MockExecutor::new(slots, elems)) as Box<dyn Executor>))
    }

    fn continuous_cfg(budget_states: usize) -> CoordinatorConfig {
        let mamba = StateShape::mamba(2, 4, 8); // 256 B per session
        let hyena = StateShape::hyena(2, 8, 8); // 256 B per session
        CoordinatorConfig {
            workers: 2,
            continuous: Some(ContinuousConfig::new(budget_states * 256, mamba, hyena)),
            ..Default::default()
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let c = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                workers: 1,
                ..Default::default()
            },
            mock_factory(4, 8),
        )
        .unwrap();
        let resp = c.call(ModelKind::Mamba, vec![1.0; 8]).unwrap();
        assert_eq!(resp.output, vec![2.0; 8]);
        assert_eq!(resp.batch_size, 1);
        c.shutdown();
    }

    #[test]
    fn batches_fill_under_load() {
        let c = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) },
                workers: 1,
                ..Default::default()
            },
            mock_factory(4, 2),
        )
        .unwrap();
        let rxs: Vec<_> =
            (0..8).map(|i| c.submit(ModelKind::Hyena, vec![i as f32, 0.0]).unwrap()).collect();
        let mut sizes = Vec::new();
        for rx in rxs {
            sizes.push(rx.recv().unwrap().batch_size);
        }
        // Under a burst of 8 with max_batch 4, full batches form.
        assert!(sizes.contains(&4), "sizes={sizes:?}");
        assert!((c.metrics.mean_batch_size() - 0.0).abs() > 0.0);
        c.shutdown();
    }

    #[test]
    fn wrong_input_size_fails_cleanly() {
        let c = Coordinator::start(CoordinatorConfig::default(), mock_factory(4, 8)).unwrap();
        let rx = c.submit(ModelKind::Attention, vec![1.0; 3]).unwrap();
        assert!(rx.recv().is_err(), "bad-size request must not hang");
        assert_eq!(c.metrics.failures.load(Ordering::Relaxed), 1);
        c.shutdown();
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let c = Coordinator::start(CoordinatorConfig::default(), mock_factory(1, 1)).unwrap();
        let metrics = Arc::clone(&c.metrics);
        c.shutdown();
        let _ = metrics; // metrics survive shutdown
    }

    #[test]
    fn multiple_workers_share_load() {
        let c = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
                workers: 4,
                ..Default::default()
            },
            Box::new(move || {
                let mut m = MockExecutor::new(1, 4);
                m.delay = Duration::from_millis(10);
                Ok(Box::new(m) as Box<dyn Executor>)
            }),
        )
        .unwrap();
        let t0 = Instant::now();
        let rxs: Vec<_> =
            (0..8).map(|_| c.submit(ModelKind::Mamba, vec![0.0; 4]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let elapsed = t0.elapsed();
        // 8 × 10 ms serialized would be ≥ 80 ms; 4 workers should roughly
        // halve that at minimum.
        assert!(elapsed < Duration::from_millis(70), "elapsed={elapsed:?}");
        c.shutdown();
    }

    #[test]
    fn rejected_submit_does_not_leak_inflight() {
        let c = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
                workers: 1,
                max_inflight: 1,
                ..Default::default()
            },
            Box::new(move || {
                let mut m = MockExecutor::new(1, 2);
                m.delay = Duration::from_millis(20);
                Ok(Box::new(m) as Box<dyn Executor>)
            }),
        )
        .unwrap();
        let rx = c.submit(ModelKind::Mamba, vec![0.0; 2]).unwrap();
        // The worker is busy for 20 ms, so this rejection is deterministic.
        assert!(c.submit(ModelKind::Mamba, vec![0.0; 2]).is_err(), "backpressure rejects");
        rx.recv().unwrap();
        // Rejection must not have consumed an in-flight slot.
        for _ in 0..100 {
            if c.inflight() == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(c.inflight(), 0, "rejected request leaked an in-flight slot");
        let rx = c.submit(ModelKind::Mamba, vec![0.0; 2]).expect("slot is free again");
        rx.recv().unwrap();
        c.shutdown();
    }

    #[test]
    fn continuous_sessions_decode_to_completion() {
        // 12 live sessions but a budget of only 3 resident states: the
        // cache must evict and the sessions must still finish.
        let c = Coordinator::start(continuous_cfg(3), mock_factory(1, 8)).unwrap();
        let steps = 5usize;
        let rxs: Vec<_> = (0..12)
            .map(|i| {
                let model = if i % 2 == 0 { ModelKind::Mamba } else { ModelKind::Hyena };
                c.submit_session(model, vec![0.25 * (i as f32 + 1.0); 8], steps).unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let mut got = 0usize;
            let mut last_index = None;
            while let Ok(r) = rx.recv() {
                assert_eq!(r.output.len(), 8, "token width");
                assert_eq!(r.token_index, Some(got), "tokens stream in order");
                last_index = r.token_index;
                got += 1;
            }
            assert_eq!(got, steps, "session {i} decoded to completion");
            assert_eq!(last_index, Some(steps - 1));
        }
        assert_eq!(c.metrics.tokens.load(Ordering::Relaxed), 12 * steps as u64);
        assert_eq!(c.metrics.responses.load(Ordering::Relaxed), 12, "one response per session");
        assert_eq!(c.inflight(), 0);
        let cs = c.cache_stats().unwrap();
        assert!(cs.evictions > 0, "3-state budget under 12 sessions must evict: {cs:?}");
        assert!(cs.peak_resident_bytes as usize <= 3 * 256, "budget invariant");
        let ss = c.scheduler_stats().unwrap();
        assert_eq!(ss.retired, 12);
        assert_eq!(ss.admitted, 12);
        assert!(c.metrics.token_quantile_us(0.5) > 0, "per-token latency recorded");
        c.shutdown();
    }

    #[test]
    fn eviction_is_transparent_to_decode_numerics() {
        let run = |budget_states: usize| -> Vec<Vec<Vec<f32>>> {
            let c = Coordinator::start(continuous_cfg(budget_states), mock_factory(1, 8)).unwrap();
            let rxs: Vec<_> = (0..6)
                .map(|i| {
                    c.submit_session(ModelKind::Mamba, vec![0.1 * (i as f32 + 1.0); 8], 4).unwrap()
                })
                .collect();
            let streams = rxs
                .into_iter()
                .map(|rx| {
                    let mut s = Vec::new();
                    while let Ok(r) = rx.recv() {
                        s.push(r.output);
                    }
                    s
                })
                .collect();
            c.shutdown();
            streams
        };
        let roomy = run(64);
        let tight = run(1);
        assert_eq!(roomy, tight, "spill/restore must not change decode outputs");
    }

    #[test]
    fn sharded_chips_serve_sessions_to_completion() {
        // Sessions striped over 4 per-chip caches must decode to the same
        // outputs as the single-chip run (sharding is transparent to
        // numerics), and every chip must see cache traffic.
        let run = |chips: usize| {
            let mamba = StateShape::mamba(2, 4, 8);
            let hyena = StateShape::hyena(2, 8, 8);
            let c = Coordinator::start(
                CoordinatorConfig {
                    workers: 4,
                    continuous: Some(
                        ContinuousConfig::new(2 * 256, mamba, hyena).with_chips(chips),
                    ),
                    ..Default::default()
                },
                mock_factory(1, 8),
            )
            .unwrap();
            let rxs: Vec<_> = (0..12)
                .map(|i| {
                    let model = if i % 2 == 0 { ModelKind::Mamba } else { ModelKind::Hyena };
                    c.submit_session(model, vec![0.5 * (i as f32 + 1.0); 8], 4).unwrap()
                })
                .collect();
            let streams: Vec<Vec<Vec<f32>>> = rxs
                .into_iter()
                .map(|rx| {
                    let mut s = Vec::new();
                    while let Ok(r) = rx.recv() {
                        s.push(r.output);
                    }
                    s
                })
                .collect();
            let per_chip = c.chip_cache_stats().unwrap();
            let agg = c.cache_stats().unwrap();
            c.shutdown();
            (streams, per_chip, agg)
        };
        let (one, chips1, _) = run(1);
        let (four, chips4, agg4) = run(4);
        assert_eq!(one, four, "sharding must not change decode outputs");
        assert!(four.iter().all(|s| s.len() == 4), "all sessions complete");
        assert_eq!(chips1.len(), 1);
        assert_eq!(chips4.len(), 4);
        for (chip, cs) in chips4.iter().enumerate() {
            assert!(cs.hits + cs.misses > 0, "chip {chip} saw no decode traffic: {cs:?}");
            // Per-chip budget invariant: 2 states of 256 B each.
            assert!(cs.peak_resident_bytes <= 2 * 256, "chip {chip}: {cs:?}");
        }
        let folded: u64 = chips4.iter().map(|c| c.hits + c.misses).sum();
        assert_eq!(agg4.hits + agg4.misses, folded, "aggregate folds per-chip counters");
    }

    #[test]
    fn one_shot_and_sessions_do_not_mix() {
        let c = Coordinator::start(continuous_cfg(4), mock_factory(1, 8)).unwrap();
        assert!(c.submit(ModelKind::Mamba, vec![0.0; 8]).is_err(), "one-shot refused");
        assert!(
            c.submit_session(ModelKind::Attention, vec![0.0; 8], 2).is_err(),
            "attention has no SSM state"
        );
        assert!(c.submit_session(ModelKind::Mamba, vec![], 2).is_err(), "empty prompt");
        assert!(c.submit_session(ModelKind::Mamba, vec![0.0; 8], 0).is_err(), "zero steps");
        c.shutdown();
        let c2 = Coordinator::start(CoordinatorConfig::default(), mock_factory(1, 8)).unwrap();
        assert!(
            c2.submit_session(ModelKind::Mamba, vec![0.0; 8], 2).is_err(),
            "sessions need continuous mode"
        );
        c2.shutdown();
    }
}
