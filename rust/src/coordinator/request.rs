//! Request/response types of the serving coordinator.

use crate::runtime::ModelKind;
use std::time::{Duration, Instant};

/// Session metadata carried by a session-opening request in continuous
/// mode. Phase and token progress are tracked by the scheduler, not here —
/// every submitted session starts at prefill.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionMeta {
    /// Total tokens the session will decode.
    pub decode_steps: usize,
}

/// A single inference request: one activation tensor for one decoder model.
///
/// One-shot requests (`session: None`) run through the dynamic batcher;
/// session-opening requests carry [`SessionMeta`] and are admitted to the
/// continuous-batching scheduler, with `input` holding the prompt.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub model: ModelKind,
    /// Flattened `(seq_len × d_model)` activation (the prompt, for
    /// session-opening requests).
    pub input: Vec<f32>,
    pub submitted: Instant,
    pub session: Option<SessionMeta>,
}

impl Request {
    pub fn new(id: u64, model: ModelKind, input: Vec<f32>) -> Self {
        Self { id, model, input, submitted: Instant::now(), session: None }
    }

    /// A session-opening request: `prompt` is prefilled, then
    /// `decode_steps` tokens stream back (the prefill's token included).
    pub fn session_open(id: u64, model: ModelKind, prompt: Vec<f32>, decode_steps: usize) -> Self {
        Self {
            id,
            model,
            input: prompt,
            submitted: Instant::now(),
            session: Some(SessionMeta { decode_steps }),
        }
    }
}

/// The completed result for one request — or, for a live session, one
/// decoded token (the reply channel then carries `decode_steps` of these,
/// closing after the last).
#[derive(Debug, Clone)]
pub struct Response {
    /// Request id; for session tokens, the session id.
    pub id: u64,
    pub model: ModelKind,
    pub output: Vec<f32>,
    /// Time spent queued before its batch launched.
    pub queue_time: Duration,
    /// Backend execution time of the batch that carried this request.
    pub exec_time: Duration,
    /// How many requests (or session steps) shared the batch.
    pub batch_size: usize,
    /// For session tokens: this token's 0-based index in the stream.
    pub token_index: Option<usize>,
}

impl Response {
    /// End-to-end latency as observed by the client.
    pub fn latency(&self) -> Duration {
        self.queue_time + self.exec_time
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_sums_components() {
        let r = Response {
            id: 1,
            model: ModelKind::Mamba,
            output: vec![],
            queue_time: Duration::from_millis(3),
            exec_time: Duration::from_millis(7),
            batch_size: 2,
            token_index: None,
        };
        assert_eq!(r.latency(), Duration::from_millis(10));
    }

    #[test]
    fn session_open_carries_meta() {
        let r = Request::session_open(9, ModelKind::Hyena, vec![0.5; 8], 12);
        let meta = r.session.expect("session meta");
        assert_eq!(meta.decode_steps, 12);
        assert!(Request::new(1, ModelKind::Mamba, vec![]).session.is_none());
    }
}
