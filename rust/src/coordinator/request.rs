//! Request/response types of the serving coordinator.

use crate::runtime::ModelKind;
use std::time::{Duration, Instant};

/// A single inference request: one activation tensor for one decoder model.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub model: ModelKind,
    /// Flattened `(seq_len × d_model)` activation.
    pub input: Vec<f32>,
    pub submitted: Instant,
}

impl Request {
    pub fn new(id: u64, model: ModelKind, input: Vec<f32>) -> Self {
        Self { id, model, input, submitted: Instant::now() }
    }
}

/// The completed result for one request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub model: ModelKind,
    pub output: Vec<f32>,
    /// Time spent queued before its batch launched.
    pub queue_time: Duration,
    /// PJRT execution time of the batch that carried this request.
    pub exec_time: Duration,
    /// How many requests shared the batch.
    pub batch_size: usize,
}

impl Response {
    /// End-to-end latency as observed by the client.
    pub fn latency(&self) -> Duration {
        self.queue_time + self.exec_time
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_sums_components() {
        let r = Response {
            id: 1,
            model: ModelKind::Mamba,
            output: vec![],
            queue_time: Duration::from_millis(3),
            exec_time: Duration::from_millis(7),
            batch_size: 2,
        };
        assert_eq!(r.latency(), Duration::from_millis(10));
    }
}
