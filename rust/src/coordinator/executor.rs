//! Executor abstraction: what a worker thread runs batches on.
//!
//! Production uses [`PjrtExecutor`] (compiled AOT artifacts via the PJRT CPU
//! client); tests and model-free benches use [`MockExecutor`] so the
//! coordinator's routing/batching logic is exercisable without artifacts.

use crate::runtime::{ModelKind, Runtime};
use crate::session::{SsmState, StateShape};
use crate::Result;
use anyhow::anyhow;

/// A backend able to execute packed batches for a set of models.
///
/// Deliberately **not** `Send`: PJRT executables are thread-affine
/// (`Rc`-backed in the `xla` crate), so each worker thread constructs its
/// own executor via [`ExecutorFactory`] and never moves it.
pub trait Executor {
    /// Models this executor can serve.
    fn models(&self) -> Vec<ModelKind>;
    /// Elements of one request's activation for `model`.
    fn slot_elems(&self, model: ModelKind) -> usize;
    /// Batch slots the compiled artifact expects for `model`.
    fn batch_slots(&self, model: ModelKind) -> usize;
    /// Execute a fully packed `(batch_slots × slot_elems)` buffer; returns
    /// the packed outputs of the same shape.
    fn execute(&mut self, model: ModelKind, packed: &[f32]) -> Result<Vec<f32>>;

    /// Open a decode session: prefill `prompt`, build the initial recurrent
    /// state, and return `(state, first_token)` where the token is a
    /// `shape.d_model`-wide activation.
    ///
    /// Default: unsupported — the AOT artifact set only lowers full-sequence
    /// forward passes, so [`PjrtExecutor`] cannot step-decode until per-token
    /// kernels are lowered. [`MockExecutor`] implements it for the
    /// continuous-batching path.
    fn begin_session(
        &mut self,
        model: ModelKind,
        prompt: &[f32],
        shape: &StateShape,
    ) -> Result<(SsmState, Vec<f32>)> {
        let _ = (model, prompt, shape);
        Err(anyhow!("this executor does not support stateful decode (begin_session)"))
    }

    /// One decode step: consume the previous token activation, advance
    /// `state` in place, and return the next token activation.
    fn step_decode(
        &mut self,
        model: ModelKind,
        state: &mut SsmState,
        token: &[f32],
    ) -> Result<Vec<f32>> {
        let _ = (model, state, token);
        Err(anyhow!("this executor does not support stateful decode (step_decode)"))
    }
}

/// The production executor: one compiled PJRT executable per model.
pub struct PjrtExecutor {
    runtime: Runtime,
}

impl PjrtExecutor {
    pub fn new(runtime: Runtime) -> Self {
        Self { runtime }
    }

    /// Load artifacts from a directory (convenience).
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(Self::new(Runtime::load(dir)?))
    }
}

impl Executor for PjrtExecutor {
    fn models(&self) -> Vec<ModelKind> {
        self.runtime.kinds()
    }

    fn slot_elems(&self, model: ModelKind) -> usize {
        self.runtime.model(model).map(|m| m.elems_per_slot()).unwrap_or(0)
    }

    fn batch_slots(&self, model: ModelKind) -> usize {
        self.runtime.model(model).map(|m| m.batch_slots()).unwrap_or(0)
    }

    fn execute(&mut self, model: ModelKind, packed: &[f32]) -> Result<Vec<f32>> {
        self.runtime.model(model)?.execute(packed)
    }
}

/// Deterministic mock: output = input + 1, with a configurable artificial
/// latency — lets tests assert batching/routing behaviour precisely.
///
/// The stateful-decode mock is equally deterministic and *state-dependent*
/// (so a lost or corrupted cache entry is observable in the outputs):
/// prefill fills the state with the prompt mean and emits
/// `mean(prompt) + 1` as the first token; each decode step emits
/// `token + mean(state) + 1` and then advances every state element by
/// 0.125. Results depend only on the session's own history — never on
/// batch composition or eviction order.
pub struct MockExecutor {
    pub slots: usize,
    pub elems: usize,
    pub delay: std::time::Duration,
    /// Fail every request whose packed buffer contains this poison value —
    /// failure-injection hook for coordinator tests.
    pub poison: Option<f32>,
}

impl MockExecutor {
    pub fn new(slots: usize, elems: usize) -> Self {
        Self { slots, elems, delay: std::time::Duration::ZERO, poison: None }
    }
}

impl Executor for MockExecutor {
    fn models(&self) -> Vec<ModelKind> {
        ModelKind::ALL.to_vec()
    }

    fn slot_elems(&self, _model: ModelKind) -> usize {
        self.elems
    }

    fn batch_slots(&self, _model: ModelKind) -> usize {
        self.slots
    }

    fn execute(&mut self, _model: ModelKind, packed: &[f32]) -> Result<Vec<f32>> {
        if packed.len() != self.slots * self.elems {
            return Err(anyhow!("mock: bad packed size {}", packed.len()));
        }
        if let Some(p) = self.poison {
            if packed.contains(&p) {
                return Err(anyhow!("mock: poisoned batch"));
            }
        }
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(packed.iter().map(|v| v + 1.0).collect())
    }

    fn begin_session(
        &mut self,
        model: ModelKind,
        prompt: &[f32],
        shape: &StateShape,
    ) -> Result<(SsmState, Vec<f32>)> {
        if prompt.is_empty() {
            return Err(anyhow!("mock: empty prompt"));
        }
        if shape.model != model {
            return Err(anyhow!("mock: state shape is for {}, request is {model}", shape.model));
        }
        if let Some(p) = self.poison {
            if prompt.contains(&p) {
                return Err(anyhow!("mock: poisoned prompt"));
            }
        }
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let mean = prompt.iter().sum::<f32>() / prompt.len() as f32;
        let mut state = SsmState::zeros(shape)?;
        state.fill(mean);
        Ok((state, vec![mean + 1.0; shape.d_model]))
    }

    fn step_decode(
        &mut self,
        _model: ModelKind,
        state: &mut SsmState,
        token: &[f32],
    ) -> Result<Vec<f32>> {
        let d = state.shape().d_model;
        if token.len() != d {
            return Err(anyhow!("mock: token has {} elems, state d_model is {d}", token.len()));
        }
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let s = state.mean();
        let out = token.iter().map(|t| t + s + 1.0).collect();
        state.add_scalar(0.125);
        Ok(out)
    }
}

/// Factory constructing one executor per worker thread (PJRT executables are
/// not shared across threads; each worker owns its own compiled set).
pub type ExecutorFactory = Box<dyn Fn() -> Result<Box<dyn Executor>> + Send + Sync>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_executes_plus_one() {
        let mut m = MockExecutor::new(2, 3);
        let out = m.execute(ModelKind::Hyena, &[1.0; 6]).unwrap();
        assert_eq!(out, vec![2.0; 6]);
    }

    #[test]
    fn mock_rejects_bad_size() {
        let mut m = MockExecutor::new(2, 3);
        assert!(m.execute(ModelKind::Hyena, &[1.0; 5]).is_err());
    }

    #[test]
    fn mock_poison_injects_failure() {
        let mut m = MockExecutor::new(1, 2);
        m.poison = Some(-999.0);
        assert!(m.execute(ModelKind::Mamba, &[1.0, -999.0]).is_err());
        assert!(m.execute(ModelKind::Mamba, &[1.0, 2.0]).is_ok());
    }

    #[test]
    fn mock_decode_is_deterministic_and_state_dependent() {
        let shape = StateShape::mamba(2, 4, 8);
        let mut m = MockExecutor::new(1, 8);
        let prompt = vec![0.5; 16];
        let (mut state, first) = m.begin_session(ModelKind::Mamba, &prompt, &shape).unwrap();
        assert_eq!(first, vec![1.5; 8]);
        assert_eq!(state.mean(), 0.5);
        let t1 = m.step_decode(ModelKind::Mamba, &mut state, &first).unwrap();
        // 1.5 (token) + 0.5 (state mean) + 1.0 = 3.0
        assert_eq!(t1, vec![3.0; 8]);
        assert!((state.mean() - 0.625).abs() < 1e-6, "state advanced");
        // A replayed session produces the identical stream.
        let (mut s2, f2) = m.begin_session(ModelKind::Mamba, &prompt, &shape).unwrap();
        assert_eq!(f2, first);
        assert_eq!(m.step_decode(ModelKind::Mamba, &mut s2, &f2).unwrap(), t1);
    }

    #[test]
    fn mock_decode_validates_shapes() {
        let shape = StateShape::mamba(1, 2, 4);
        let mut m = MockExecutor::new(1, 4);
        assert!(m.begin_session(ModelKind::Mamba, &[], &shape).is_err(), "empty prompt");
        assert!(
            m.begin_session(ModelKind::Hyena, &[1.0], &shape).is_err(),
            "model/shape mismatch"
        );
        let (mut state, _) = m.begin_session(ModelKind::Mamba, &[1.0], &shape).unwrap();
        assert!(m.step_decode(ModelKind::Mamba, &mut state, &[0.0; 3]).is_err(), "bad token width");
    }

    #[test]
    fn pjrt_has_no_step_decode() {
        // Default trait impls refuse stateful decode (artifacts only lower
        // full-sequence passes). Exercise via a minimal custom executor.
        struct NoDecode;
        impl Executor for NoDecode {
            fn models(&self) -> Vec<ModelKind> {
                vec![]
            }
            fn slot_elems(&self, _m: ModelKind) -> usize {
                0
            }
            fn batch_slots(&self, _m: ModelKind) -> usize {
                0
            }
            fn execute(&mut self, _m: ModelKind, _p: &[f32]) -> Result<Vec<f32>> {
                Ok(vec![])
            }
        }
        let mut e = NoDecode;
        assert!(e.begin_session(ModelKind::Mamba, &[1.0], &StateShape::mamba(1, 1, 1)).is_err());
        let mut st = SsmState::zeros(&StateShape::mamba(1, 1, 1)).unwrap();
        assert!(e.step_decode(ModelKind::Mamba, &mut st, &[0.0]).is_err());
    }
}
