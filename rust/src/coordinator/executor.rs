//! Executor abstraction: what a worker thread runs batches on.
//!
//! Production uses [`PjrtExecutor`] (compiled AOT artifacts via the PJRT CPU
//! client); tests and model-free benches use [`MockExecutor`] so the
//! coordinator's routing/batching logic is exercisable without artifacts.

use crate::runtime::{ModelKind, Runtime};
use crate::Result;
use anyhow::anyhow;

/// A backend able to execute packed batches for a set of models.
///
/// Deliberately **not** `Send`: PJRT executables are thread-affine
/// (`Rc`-backed in the `xla` crate), so each worker thread constructs its
/// own executor via [`ExecutorFactory`] and never moves it.
pub trait Executor {
    /// Models this executor can serve.
    fn models(&self) -> Vec<ModelKind>;
    /// Elements of one request's activation for `model`.
    fn slot_elems(&self, model: ModelKind) -> usize;
    /// Batch slots the compiled artifact expects for `model`.
    fn batch_slots(&self, model: ModelKind) -> usize;
    /// Execute a fully packed `(batch_slots × slot_elems)` buffer; returns
    /// the packed outputs of the same shape.
    fn execute(&mut self, model: ModelKind, packed: &[f32]) -> Result<Vec<f32>>;
}

/// The production executor: one compiled PJRT executable per model.
pub struct PjrtExecutor {
    runtime: Runtime,
}

impl PjrtExecutor {
    pub fn new(runtime: Runtime) -> Self {
        Self { runtime }
    }

    /// Load artifacts from a directory (convenience).
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(Self::new(Runtime::load(dir)?))
    }
}

impl Executor for PjrtExecutor {
    fn models(&self) -> Vec<ModelKind> {
        self.runtime.kinds()
    }

    fn slot_elems(&self, model: ModelKind) -> usize {
        self.runtime.model(model).map(|m| m.elems_per_slot()).unwrap_or(0)
    }

    fn batch_slots(&self, model: ModelKind) -> usize {
        self.runtime.model(model).map(|m| m.batch_slots()).unwrap_or(0)
    }

    fn execute(&mut self, model: ModelKind, packed: &[f32]) -> Result<Vec<f32>> {
        self.runtime.model(model)?.execute(packed)
    }
}

/// Deterministic mock: output = input + 1, with a configurable artificial
/// latency — lets tests assert batching/routing behaviour precisely.
pub struct MockExecutor {
    pub slots: usize,
    pub elems: usize,
    pub delay: std::time::Duration,
    /// Fail every request whose packed buffer contains this poison value —
    /// failure-injection hook for coordinator tests.
    pub poison: Option<f32>,
}

impl MockExecutor {
    pub fn new(slots: usize, elems: usize) -> Self {
        Self { slots, elems, delay: std::time::Duration::ZERO, poison: None }
    }
}

impl Executor for MockExecutor {
    fn models(&self) -> Vec<ModelKind> {
        ModelKind::ALL.to_vec()
    }

    fn slot_elems(&self, _model: ModelKind) -> usize {
        self.elems
    }

    fn batch_slots(&self, _model: ModelKind) -> usize {
        self.slots
    }

    fn execute(&mut self, _model: ModelKind, packed: &[f32]) -> Result<Vec<f32>> {
        if packed.len() != self.slots * self.elems {
            return Err(anyhow!("mock: bad packed size {}", packed.len()));
        }
        if let Some(p) = self.poison {
            if packed.contains(&p) {
                return Err(anyhow!("mock: poisoned batch"));
            }
        }
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(packed.iter().map(|v| v + 1.0).collect())
    }
}

/// Factory constructing one executor per worker thread (PJRT executables are
/// not shared across threads; each worker owns its own compiled set).
pub type ExecutorFactory = Box<dyn Fn() -> Result<Box<dyn Executor>> + Send + Sync>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_executes_plus_one() {
        let mut m = MockExecutor::new(2, 3);
        let out = m.execute(ModelKind::Hyena, &[1.0; 6]).unwrap();
        assert_eq!(out, vec![2.0; 6]);
    }

    #[test]
    fn mock_rejects_bad_size() {
        let mut m = MockExecutor::new(2, 3);
        assert!(m.execute(ModelKind::Hyena, &[1.0; 5]).is_err());
    }

    #[test]
    fn mock_poison_injects_failure() {
        let mut m = MockExecutor::new(1, 2);
        m.poison = Some(-999.0);
        assert!(m.execute(ModelKind::Mamba, &[1.0, -999.0]).is_err());
        assert!(m.execute(ModelKind::Mamba, &[1.0, 2.0]).is_ok());
    }
}
