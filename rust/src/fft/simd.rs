//! Explicit-lane butterfly pass for the radix-2 FFT plans (PR 9).
//!
//! [`butterfly_block`] runs one combining stage over an aligned block: for
//! `k < half`, with `w = tw[k·stride]` (conjugated when inverse),
//!
//! ```text
//! a = x[k];  b = x[k + half] · w;
//! x[k] = a + b;  x[k + half] = a − b;
//! ```
//!
//! The scalar loop is the portable body; on x86_64-with-AVX two butterflies
//! run per iteration in one 256-bit lane group, on aarch64-with-NEON one
//! per 128-bit pair. Both are **bit-identical** to the scalar loop:
//!
//! * The complex product is expanded into exactly the scalar `Mul`'s four
//!   products, one subtraction and one addition per butterfly — via
//!   `addsub` on AVX, and via multiplying by a `[-w.im, w.im]` pair on
//!   NEON (IEEE-754 guarantees `a + (−b) ≡ a − b` and `x·(−w) ≡ −(x·w)`
//!   exactly, and `re·im + im·re` commutes bit-for-bit).
//! * No FMA anywhere — the scalar path rounds after every product.
//! * `C64` is `repr(C)`, so a vector load of `x[k..k+2]` reads
//!   `[re₀, im₀, re₁, im₁]` by layout contract.
//!
//! The property harness fuzzes plan outputs against the naive DFT and the
//! flat oracle, so a backend drifting by one bit fails `tests/prop.rs`.

use crate::util::C64;

/// One radix-2 combining stage over `block` (length = 2·half): butterfly
/// `k` pairs `block[k]` with `block[k + half]` under twiddle
/// `tw[k·stride]`. Dispatches to the widest bit-identical backend.
pub(crate) fn butterfly_block(block: &mut [C64], stride: usize, tw: &[C64], inverse: bool) {
    let half = block.len() / 2;
    #[cfg(target_arch = "x86_64")]
    {
        if half >= 2 && std::arch::is_x86_feature_detected!("avx") {
            // SAFETY: AVX presence checked above.
            unsafe { butterfly_block_avx(block, stride, tw, inverse) };
            return;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            // SAFETY: NEON presence checked above.
            unsafe { butterfly_block_neon(block, stride, tw, inverse) };
            return;
        }
    }
    butterfly_block_scalar(block, stride, tw, inverse);
}

/// The portable body — and the reference the lane paths must match bit
/// for bit.
fn butterfly_block_scalar(block: &mut [C64], stride: usize, tw: &[C64], inverse: bool) {
    let half = block.len() / 2;
    for k in 0..half {
        let mut w = tw[k * stride];
        if inverse {
            w = w.conj();
        }
        let a = block[k];
        let b = block[k + half] * w;
        block[k] = a + b;
        block[k + half] = a - b;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn butterfly_block_avx(block: &mut [C64], stride: usize, tw: &[C64], inverse: bool) {
    use core::arch::x86_64::*;
    let half = block.len() / 2;
    let p = block.as_mut_ptr() as *mut f64;
    // half is a power of two ≥ 2 here, so the pair loop covers everything.
    for k in (0..half).step_by(2) {
        let mut w0 = tw[k * stride];
        let mut w1 = tw[(k + 1) * stride];
        if inverse {
            w0 = w0.conj();
            w1 = w1.conj();
        }
        let wv = _mm256_setr_pd(w0.re, w0.im, w1.re, w1.im);
        let wre = _mm256_movedup_pd(wv); //            [re0, re0, re1, re1]
        let wim = _mm256_permute_pd::<0b1111>(wv); //  [im0, im0, im1, im1]
        let bv = _mm256_loadu_pd(p.add(2 * (k + half)));
        let bsw = _mm256_permute_pd::<0b0101>(bv); //  [im, re] per complex
        // (b.re·w.re − b.im·w.im, b.im·w.re + b.re·w.im): the scalar
        // products verbatim, addsub doing the one sub / one add per lane
        // pair. No FMA.
        let prod = _mm256_addsub_pd(_mm256_mul_pd(bv, wre), _mm256_mul_pd(bsw, wim));
        let av = _mm256_loadu_pd(p.add(2 * k));
        _mm256_storeu_pd(p.add(2 * k), _mm256_add_pd(av, prod));
        _mm256_storeu_pd(p.add(2 * (k + half)), _mm256_sub_pd(av, prod));
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn butterfly_block_neon(block: &mut [C64], stride: usize, tw: &[C64], inverse: bool) {
    use core::arch::aarch64::*;
    let half = block.len() / 2;
    let p = block.as_mut_ptr() as *mut f64;
    for k in 0..half {
        let mut w = tw[k * stride];
        if inverse {
            w = w.conj();
        }
        let wre = vdupq_n_f64(w.re);
        // [−w.im, w.im]: multiplying the swapped b by this yields
        // [−(b.im·w.im), b.re·w.im], so one vadd gives the scalar's
        // (sub, add) pair exactly (IEEE: a + (−b) ≡ a − b).
        let wim = vld1q_f64([-w.im, w.im].as_ptr());
        let bv = vld1q_f64(p.add(2 * (k + half)));
        let bsw = vextq_f64::<1>(bv, bv); // [b.im, b.re]
        let prod = vaddq_f64(vmulq_f64(bv, wre), vmulq_f64(bsw, wim));
        let av = vld1q_f64(p.add(2 * k));
        vst1q_f64(p.add(2 * k), vaddq_f64(av, prod));
        vst1q_f64(p.add(2 * (k + half)), vsubq_f64(av, prod));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;
    use std::f64::consts::PI;

    fn random_block(rng: &mut XorShift, n: usize) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0))).collect()
    }

    fn twiddles(n: usize) -> Vec<C64> {
        (0..n).map(|j| C64::cis(-2.0 * PI * j as f64 / n as f64)).collect()
    }

    #[test]
    fn dispatched_pass_is_bit_identical_to_scalar() {
        let mut rng = XorShift::new(601);
        for len in [2usize, 4, 8, 64, 256] {
            let tw = twiddles(256 * len); // oversized table, strided reads
            for stride in [1usize, 2, 16] {
                for inverse in [false, true] {
                    let x = random_block(&mut rng, len);
                    let mut got = x.clone();
                    let mut want = x;
                    butterfly_block(&mut got, stride, &tw, inverse);
                    butterfly_block_scalar(&mut want, stride, &tw, inverse);
                    assert_eq!(got, want, "len={len} stride={stride} inverse={inverse}");
                }
            }
        }
    }

    #[test]
    fn unit_twiddle_pass_is_the_plain_sum_difference() {
        // With w = 1 the butterfly is (a+b, a−b) exactly.
        let tw = twiddles(4);
        let mut x = vec![C64::new(1.0, 2.0), C64::new(3.0, -4.0)];
        butterfly_block(&mut x, 0, &tw, false); // stride 0 → w = tw[0] = 1
        assert_eq!(x[0], C64::new(4.0, -2.0));
        assert_eq!(x[1], C64::new(-2.0, 6.0));
    }
}
