//! Radix-2 Cooley–Tukey FFT (decimation in time, iterative, in-place).
//!
//! This is the algorithm whose *variable-distance butterflies* motivate the
//! paper's FFT-mode PCU: stage `s` exchanges elements at distance `2^s`,
//! which a SIMD pipeline without cross-lane links cannot route (§III-B).

use crate::util::C64;
use std::f64::consts::PI;

/// In-place bit-reversal permutation.
fn bit_reverse_permute(x: &mut [C64]) {
    let n = x.len();
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        let j = j as usize;
        if j > i {
            x.swap(i, j);
        }
    }
}

/// Forward radix-2 FFT. `x.len()` must be a power of two.
pub fn fft(x: &[C64]) -> Vec<C64> {
    let mut buf = x.to_vec();
    fft_in_place(&mut buf);
    buf
}

/// Forward radix-2 FFT, in place.
pub fn fft_in_place(x: &mut [C64]) {
    let n = x.len();
    assert!(super::is_pow2(n), "fft: length {n} is not a power of two");
    if n == 1 {
        return;
    }
    bit_reverse_permute(x);
    // Precompute per-stage twiddles lazily: stage `len` uses w = e^{-2πi/len}.
    let mut len = 2;
    while len <= n {
        let ang = -2.0 * PI / len as f64;
        let wlen = C64::cis(ang);
        let half = len / 2;
        for start in (0..n).step_by(len) {
            let mut w = C64::ONE;
            for k in 0..half {
                let a = x[start + k];
                let b = x[start + k + half] * w;
                x[start + k] = a + b;
                x[start + k + half] = a - b;
                w = w * wlen;
            }
        }
        len <<= 1;
    }
}

/// Inverse FFT via conjugation: `ifft(x) = conj(fft(conj(x)))/N`.
pub fn ifft(x: &[C64]) -> Vec<C64> {
    let n = x.len() as f64;
    let conj: Vec<C64> = x.iter().map(|z| z.conj()).collect();
    fft(&conj).into_iter().map(|z| z.conj().scale(1.0 / n)).collect()
}

/// Number of butterfly operations in an N-point radix-2 FFT: `N/2·log₂N`.
pub fn butterfly_count(n: usize) -> usize {
    assert!(super::is_pow2(n));
    n / 2 * n.trailing_zeros() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{dft::dft, to_complex};
    use crate::util::complex::max_abs_diff_c;
    use crate::util::{prop, XorShift};

    #[test]
    fn matches_dft_small_sizes() {
        let mut rng = XorShift::new(21);
        for logn in 0..=10 {
            let n = 1 << logn;
            let x = to_complex(&rng.vec(n, -1.0, 1.0));
            let got = fft(&x);
            let want = dft(&x);
            assert!(
                max_abs_diff_c(&got, &want) < 1e-8,
                "n={n}: diff={}",
                max_abs_diff_c(&got, &want)
            );
        }
    }

    #[test]
    fn ifft_inverts_fft() {
        let mut rng = XorShift::new(22);
        let x: Vec<_> = (0..256)
            .map(|_| crate::util::C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
            .collect();
        let rt = ifft(&fft(&x));
        assert!(max_abs_diff_c(&x, &rt) < 1e-10);
    }

    #[test]
    #[should_panic(expected = "not a power of two")]
    fn non_pow2_rejected() {
        fft(&vec![C64::ZERO; 24]);
    }

    #[test]
    fn butterfly_count_formula() {
        assert_eq!(butterfly_count(8), 12);
        assert_eq!(butterfly_count(1024), 512 * 10);
    }

    #[test]
    fn prop_fft_equals_dft_random_lengths() {
        prop::quick(
            "fft == dft",
            |r| {
                let n = 1usize << r.range(0, 8);
                r.vec(n, -2.0, 2.0)
            },
            prop::shrink_vec_f64,
            |xs| {
                if !crate::fft::is_pow2(xs.len()) {
                    return Ok(()); // shrinker may produce non-pow2; skip
                }
                let x = to_complex(xs);
                let d = max_abs_diff_c(&fft(&x), &dft(&x));
                if d < 1e-7 {
                    Ok(())
                } else {
                    Err(format!("diff {d}"))
                }
            },
        );
    }

    #[test]
    fn prop_parseval() {
        prop::quick(
            "parseval",
            |r| { let n = 1usize << r.range(1, 9); r.vec(n, -1.0, 1.0) },
            prop::no_shrink,
            |xs| {
                let x = to_complex(xs);
                let y = fft(&x);
                let ex: f64 = x.iter().map(|z| z.abs().powi(2)).sum();
                let ey: f64 =
                    y.iter().map(|z| z.abs().powi(2)).sum::<f64>() / x.len() as f64;
                if (ex - ey).abs() < 1e-7 * ex.max(1.0) {
                    Ok(())
                } else {
                    Err(format!("energy {ex} vs {ey}"))
                }
            },
        );
    }
}
