//! Planned FFT engine — the hot-path transform substrate.
//!
//! The naive [`super::cooley_tukey`] transform re-derives its twiddle
//! factors with `sin`/`cos` on every call and accumulates error through the
//! incremental `w *= wlen` recurrence; every convolution in the Hyena
//! golden-model chain then pays three full-size *complex* transforms on
//! purely *real* signals, plus a fresh `Vec` per stage. FlashFFTConv-style
//! kernel engineering shows this layer is exactly where FFT-based SSM
//! throughput is won, so this module provides the planned counterpart:
//!
//! * [`FftPlan`] — caches the bit-reversal permutation and a single
//!   half-length twiddle table `tw[j] = e^{-2πi·j/N}` at construction;
//!   stage `len` indexes it at stride `N/len`, so steady-state transforms
//!   do **no trig and no allocation**, and every twiddle is a direct table
//!   value rather than the tail of a multiplicative recurrence.
//! * [`RealFftPlan`] — real-input forward/inverse transforms via the
//!   N/2-point complex-packing trick: pack `z[j] = x[2j] + i·x[2j+1]`, run
//!   one half-size complex FFT, and unpack the half-spectrum `X[0..=N/2]`
//!   with an O(N) butterfly. Roughly halves the flops and memory traffic
//!   of every transform over real data.
//! * [`ConvPlan`] — a circular/linear convolution engine over two cached
//!   half-spectrum scratch buffers: two real forward transforms, one
//!   half-spectrum product, one real inverse — allocation-free after the
//!   first call at a given length.
//! * [`with_conv_plan`] — a per-thread plan cache keyed by transform
//!   length, so the drop-in wrappers ([`super::fft_conv_circular`] /
//!   [`super::fft_conv_linear`]) reuse plans without locking. Scope note:
//!   the cache lives as long as its thread — long-lived callers (the main
//!   thread, the pooled sim's worker team) amortize plans across calls,
//!   while scoped pool workers amortize only across the channels of one
//!   call's chunk and rebuild on the next call.
//!
//! All planned paths are oracle-checked against [`super::dft::dft`] and
//! the direct convolution in `super::conv`; the acceptance tolerance is
//! 1e-9 (they land around 1e-11).

use super::is_pow2;
use crate::util::C64;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::f64::consts::PI;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// A reusable plan for N-point complex FFTs: bit-reversal table + twiddle
/// table, both precomputed once. Methods take `&self`, so one plan can be
/// shared read-only across worker-pool threads.
#[derive(Debug, Clone)]
pub struct FftPlan {
    n: usize,
    /// Bit-reversed index of each position (permutation applied in place).
    rev: Vec<u32>,
    /// `tw[j] = e^{-2πi·j/N}` for `j < N/2`; stage `len` reads stride `N/len`.
    tw: Vec<C64>,
}

impl FftPlan {
    /// Build a plan for N-point transforms. N must be a power of two.
    pub fn new(n: usize) -> Self {
        assert!(is_pow2(n), "FftPlan: length {n} is not a power of two");
        let bits = n.trailing_zeros();
        let rev = (0..n as u32)
            .map(|i| if n == 1 { 0 } else { i.reverse_bits() >> (32 - bits) })
            .collect();
        let tw = (0..n / 2).map(|j| C64::cis(-2.0 * PI * j as f64 / n as f64)).collect();
        Self { n, rev, tw }
    }

    /// Transform length this plan was built for.
    pub fn points(&self) -> usize {
        self.n
    }

    fn check(&self, got: usize) {
        assert_eq!(
            got, self.n,
            "FftPlan for N={} used on a length-{got} buffer; plans are per-length — \
             build a new plan (or use fft::with_conv_plan's keyed cache)",
            self.n
        );
    }

    /// Forward FFT in place.
    pub fn fft_in_place(&self, x: &mut [C64]) {
        self.transform(x, false);
    }

    /// Inverse FFT in place, including the 1/N normalization.
    pub fn ifft_in_place(&self, x: &mut [C64]) {
        self.transform(x, true);
        let s = 1.0 / self.n as f64;
        for v in x.iter_mut() {
            *v = v.scale(s);
        }
    }

    /// Inverse FFT in place **without** the 1/N normalization — for callers
    /// that fold the scaling into an adjacent pass (see [`RealFftPlan`]).
    pub fn inverse_unnormalized_in_place(&self, x: &mut [C64]) {
        self.transform(x, true);
    }

    /// Radix-2 DIT butterflies over the precomputed tables. The `inverse`
    /// transform conjugates each table entry instead of rebuilding it.
    fn transform(&self, x: &mut [C64], inverse: bool) {
        self.check(x.len());
        let n = self.n;
        if n == 1 {
            return;
        }
        for i in 0..n {
            let j = self.rev[i] as usize;
            if j > i {
                x.swap(i, j);
            }
        }
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let stride = n / len;
            for start in (0..n).step_by(len) {
                for k in 0..half {
                    let mut w = self.tw[k * stride];
                    if inverse {
                        w = w.conj();
                    }
                    let a = x[start + k];
                    let b = x[start + k + half] * w;
                    x[start + k] = a + b;
                    x[start + k + half] = a - b;
                }
            }
            len <<= 1;
        }
    }
}

/// A reusable plan for N-point **real-input** transforms via the N/2-point
/// complex-packing trick. Holds its own packing scratch, so `rfft_into` /
/// `irfft_into` are allocation-free; methods therefore take `&mut self`
/// (one plan per thread — see [`with_conv_plan`]).
#[derive(Debug, Clone)]
pub struct RealFftPlan {
    n: usize,
    m: usize,
    inner: FftPlan,
    /// `w[k] = e^{-2πi·k/N}` for `k < N/2` — the pack/unpack twiddles.
    w: Vec<C64>,
    /// Packing scratch, length N/2.
    pack: Vec<C64>,
}

impl RealFftPlan {
    /// Build a plan for N-point real transforms. N must be a power of two
    /// with N ≥ 2 (the packing trick needs an even length).
    pub fn new(n: usize) -> Self {
        assert!(
            is_pow2(n) && n >= 2,
            "RealFftPlan: length {n} must be a power of two >= 2"
        );
        let m = n / 2;
        Self {
            n,
            m,
            inner: FftPlan::new(m),
            w: (0..m).map(|k| C64::cis(-2.0 * PI * k as f64 / n as f64)).collect(),
            pack: vec![C64::ZERO; m],
        }
    }

    /// Signal length this plan was built for.
    pub fn points(&self) -> usize {
        self.n
    }

    /// Half-spectrum length: `N/2 + 1` bins (bins 0 and N/2 are real).
    pub fn spectrum_len(&self) -> usize {
        self.m + 1
    }

    /// Forward real FFT: `x` (length N, real) → half-spectrum `out`
    /// (length N/2+1). The upper half of the full spectrum is the conjugate
    /// mirror `X[N-k] = conj(X[k])` and is never materialized.
    pub fn rfft_into(&mut self, x: &[f64], out: &mut [C64]) {
        assert_eq!(
            x.len(),
            self.n,
            "RealFftPlan for N={} used on a length-{} signal",
            self.n,
            x.len()
        );
        assert_eq!(out.len(), self.m + 1, "rfft_into: spectrum buffer must hold N/2+1 bins");
        let m = self.m;
        for j in 0..m {
            self.pack[j] = C64::new(x[2 * j], x[2 * j + 1]);
        }
        self.inner.fft_in_place(&mut self.pack);
        // Unpack: Xe[k] = (Z[k] + conj(Z[m−k]))/2 (even samples' spectrum),
        //         Xo[k] = −i·(Z[k] − conj(Z[m−k]))/2 (odd samples'),
        //         X[k]  = Xe[k] + w^k·Xo[k].
        for k in 0..m {
            let zk = self.pack[k];
            let zmk = self.pack[if k == 0 { 0 } else { m - k }].conj();
            let xe = (zk + zmk).scale(0.5);
            let d = zk - zmk;
            let xo = C64::new(d.im * 0.5, -d.re * 0.5);
            out[k] = xe + self.w[k] * xo;
        }
        // X[N/2] = Xe[0] − Xo[0] = Re(Z[0]) − Im(Z[0]), exactly real.
        out[m] = C64::real(self.pack[0].re - self.pack[0].im);
    }

    /// Inverse real FFT: half-spectrum `spec` (length N/2+1) → real `out`
    /// (length N), 1/N normalization included (folded into the unpack).
    pub fn irfft_into(&mut self, spec: &[C64], out: &mut [f64]) {
        assert_eq!(spec.len(), self.m + 1, "irfft_into: spectrum must hold N/2+1 bins");
        assert_eq!(
            out.len(),
            self.n,
            "RealFftPlan for N={} asked to fill a length-{} signal",
            self.n,
            out.len()
        );
        let m = self.m;
        // Repack: Ye[k] = (X[k] + conj(X[m−k]))/2, Yo[k] = (X[k] −
        // conj(X[m−k]))/2 · conj(w^k), Z[k] = Ye[k] + i·Yo[k].
        for k in 0..m {
            let a = spec[k];
            let b = spec[m - k].conj();
            let ye = (a + b).scale(0.5);
            let yo = (a - b).scale(0.5) * self.w[k].conj();
            self.pack[k] = C64::new(ye.re - yo.im, ye.im + yo.re);
        }
        self.inner.inverse_unnormalized_in_place(&mut self.pack);
        let s = 1.0 / m as f64;
        for j in 0..m {
            out[2 * j] = self.pack[j].re * s;
            out[2 * j + 1] = self.pack[j].im * s;
        }
    }
}

/// A planned real-input convolution engine: all scratch (two half-spectra,
/// two zero-padding buffers) lives in the plan, so circular and linear
/// convolutions are allocation-free after construction.
#[derive(Debug, Clone)]
pub struct ConvPlan {
    rp: RealFftPlan,
    spec_u: Vec<C64>,
    spec_k: Vec<C64>,
    padded_u: Vec<f64>,
    padded_k: Vec<f64>,
    full: Vec<f64>,
}

impl ConvPlan {
    /// Build a convolution plan for N-point circular convolutions (N a
    /// power of two ≥ 2). Linear convolutions of length L require
    /// `N ≥ 2·L` so the zero-padding absorbs the wrap-around.
    pub fn new(n: usize) -> Self {
        let rp = RealFftPlan::new(n);
        let bins = rp.spectrum_len();
        Self {
            rp,
            spec_u: vec![C64::ZERO; bins],
            spec_k: vec![C64::ZERO; bins],
            padded_u: vec![0.0; n],
            padded_k: vec![0.0; n],
            full: vec![0.0; n],
        }
    }

    /// Transform length of the plan.
    pub fn points(&self) -> usize {
        self.rp.points()
    }

    /// Circular convolution of two length-N real signals into `out`:
    /// `rfft(u) ⊙ rfft(k) → irfft`, two half-size transforms each way.
    pub fn circular_into(&mut self, u: &[f64], k: &[f64], out: &mut [f64]) {
        assert_eq!(u.len(), k.len(), "ConvPlan::circular: length mismatch");
        self.rp.rfft_into(u, &mut self.spec_u);
        self.rp.rfft_into(k, &mut self.spec_k);
        for (a, b) in self.spec_u.iter_mut().zip(&self.spec_k) {
            *a = *a * *b;
        }
        self.rp.irfft_into(&self.spec_u, out);
    }

    /// Circular convolution, allocating the output.
    pub fn circular(&mut self, u: &[f64], k: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.points()];
        self.circular_into(u, k, &mut out);
        out
    }

    /// Causal/linear convolution of a length-L signal with a length-L
    /// filter, truncated to the first L outputs (Hyena semantics). The
    /// plan's N must be ≥ 2·L; inputs are zero-padded into plan scratch.
    pub fn linear(&mut self, u: &[f64], k: &[f64]) -> Vec<f64> {
        let l = u.len();
        assert_eq!(l, k.len(), "ConvPlan::linear: length mismatch");
        let n = self.points();
        assert!(
            n >= 2 * l,
            "ConvPlan::linear: plan N={n} cannot hold 2x length-{l} zero-padded inputs"
        );
        self.padded_u[..l].copy_from_slice(u);
        self.padded_u[l..].fill(0.0);
        self.padded_k[..l].copy_from_slice(k);
        self.padded_k[l..].fill(0.0);
        self.rp.rfft_into(&self.padded_u, &mut self.spec_u);
        self.rp.rfft_into(&self.padded_k, &mut self.spec_k);
        for (a, b) in self.spec_u.iter_mut().zip(&self.spec_k) {
            *a = *a * *b;
        }
        self.rp.irfft_into(&self.spec_u, &mut self.full);
        self.full[..l].to_vec()
    }
}

/// A planned **complex** convolution engine (three full-size transforms,
/// no real packing): the controlled baseline the perf bench compares the
/// real path against, isolating the rfft win from the planning win.
#[derive(Debug, Clone)]
pub struct CplxConvPlan {
    plan: FftPlan,
    fu: Vec<C64>,
    fk: Vec<C64>,
}

impl CplxConvPlan {
    /// Build a planned complex convolution engine for N-point signals.
    pub fn new(n: usize) -> Self {
        Self { plan: FftPlan::new(n), fu: vec![C64::ZERO; n], fk: vec![C64::ZERO; n] }
    }

    /// Circular convolution of two length-N real signals through the
    /// planned complex pipeline: FFT(u), FFT(k), product, iFFT.
    pub fn circular(&mut self, u: &[f64], k: &[f64]) -> Vec<f64> {
        assert_eq!(u.len(), k.len(), "CplxConvPlan::circular: length mismatch");
        assert_eq!(
            u.len(),
            self.fu.len(),
            "CplxConvPlan for N={} used on another length",
            self.fu.len()
        );
        for (dst, &v) in self.fu.iter_mut().zip(u) {
            *dst = C64::real(v);
        }
        for (dst, &v) in self.fk.iter_mut().zip(k) {
            *dst = C64::real(v);
        }
        self.plan.fft_in_place(&mut self.fu);
        self.plan.fft_in_place(&mut self.fk);
        for (a, b) in self.fu.iter_mut().zip(&self.fk) {
            *a = *a * *b;
        }
        self.plan.ifft_in_place(&mut self.fu);
        self.fu.iter().map(|z| z.re).collect()
    }
}

thread_local! {
    /// Per-thread convolution plans keyed by transform length. Thread-local
    /// so worker-pool threads never contend on a lock, at the cost of one
    /// plan per (thread, length) pair — a few KiB each at serving lengths.
    static CONV_PLANS: RefCell<BTreeMap<usize, ConvPlan>> =
        const { RefCell::new(BTreeMap::new()) };
}

/// The plan-cache hit/miss counters, resolved once so the steady-state
/// cost on the conv hot path is a single relaxed `fetch_add`.
fn plan_cache_counters() -> (&'static AtomicU64, &'static AtomicU64) {
    static HITS: OnceLock<&'static AtomicU64> = OnceLock::new();
    static MISSES: OnceLock<&'static AtomicU64> = OnceLock::new();
    (
        HITS.get_or_init(|| crate::telemetry::counter("fft.plan_cache.hits")),
        MISSES.get_or_init(|| crate::telemetry::counter("fft.plan_cache.misses")),
    )
}

/// Run `f` against this thread's cached [`ConvPlan`] for length `n`,
/// building (and keeping) the plan on first use. This is what makes the
/// drop-in wrappers `fft_conv_circular`/`fft_conv_linear` allocation-free
/// in steady state without changing their signatures. Cache traffic shows
/// up in the `fft.plan_cache.hits`/`fft.plan_cache.misses` counters
/// (`--metrics`); note the cache is per-thread, so a fresh worker's first
/// conv of each length is a miss.
pub fn with_conv_plan<T>(n: usize, f: impl FnOnce(&mut ConvPlan) -> T) -> T {
    CONV_PLANS.with(|cell| {
        let mut plans = cell.borrow_mut();
        let (hits, misses) = plan_cache_counters();
        let plan = match plans.entry(n) {
            std::collections::btree_map::Entry::Occupied(e) => {
                hits.fetch_add(1, Ordering::Relaxed);
                e.into_mut()
            }
            std::collections::btree_map::Entry::Vacant(v) => {
                misses.fetch_add(1, Ordering::Relaxed);
                v.insert(ConvPlan::new(n))
            }
        };
        f(plan)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{dft::dft, to_complex};
    use crate::util::complex::max_abs_diff_c;
    use crate::util::{max_abs_diff, prop, XorShift};

    #[test]
    fn planned_fft_matches_dft() {
        let mut rng = XorShift::new(81);
        for logn in 0..=10 {
            let n = 1 << logn;
            let x: Vec<C64> = (0..n)
                .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
                .collect();
            let plan = FftPlan::new(n);
            let mut got = x.clone();
            plan.fft_in_place(&mut got);
            let d = max_abs_diff_c(&got, &dft(&x));
            assert!(d < 1e-8, "n={n}: diff={d}");
        }
    }

    #[test]
    fn planned_fft_matches_naive_fft() {
        // Same transform, different twiddle provenance (table vs recurrence):
        // both are oracle-exact, and must agree far below the 1e-9 budget.
        let mut rng = XorShift::new(82);
        let x = to_complex(&rng.vec(1 << 12, -1.0, 1.0));
        let plan = FftPlan::new(x.len());
        let mut got = x.clone();
        plan.fft_in_place(&mut got);
        let d = max_abs_diff_c(&got, &crate::fft::fft(&x));
        assert!(d < 1e-10, "diff={d}");
    }

    #[test]
    fn planned_ifft_roundtrips() {
        let mut rng = XorShift::new(83);
        let x: Vec<C64> = (0..512)
            .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
            .collect();
        let plan = FftPlan::new(512);
        let mut buf = x.clone();
        plan.fft_in_place(&mut buf);
        plan.ifft_in_place(&mut buf);
        assert!(max_abs_diff_c(&buf, &x) < 1e-11);
    }

    #[test]
    #[should_panic(expected = "FftPlan for N=1024")]
    fn plan_rejects_mismatched_length() {
        let plan = FftPlan::new(1024);
        let mut wrong = vec![C64::ZERO; 512];
        plan.fft_in_place(&mut wrong);
    }

    #[test]
    #[should_panic(expected = "RealFftPlan for N=256")]
    fn real_plan_rejects_mismatched_length() {
        let mut plan = RealFftPlan::new(256);
        let mut spec = vec![C64::ZERO; plan.spectrum_len()];
        plan.rfft_into(&[0.0; 128], &mut spec);
    }

    #[test]
    fn rfft_matches_full_fft_half_spectrum() {
        let mut rng = XorShift::new(84);
        for logn in 1..=11 {
            let n = 1 << logn;
            let x = rng.vec(n, -1.0, 1.0);
            let mut plan = RealFftPlan::new(n);
            let mut spec = vec![C64::ZERO; plan.spectrum_len()];
            plan.rfft_into(&x, &mut spec);
            let full = crate::fft::fft(&to_complex(&x));
            let d = max_abs_diff_c(&spec, &full[..n / 2 + 1]);
            assert!(d < 1e-9, "n={n}: diff={d}");
            assert_eq!(spec[0].im, 0.0, "DC bin is exactly real");
            assert_eq!(spec[n / 2].im, 0.0, "Nyquist bin is exactly real");
        }
    }

    #[test]
    fn irfft_inverts_rfft() {
        let mut rng = XorShift::new(85);
        for logn in 1..=11 {
            let n = 1 << logn;
            let x = rng.vec(n, -1.0, 1.0);
            let mut plan = RealFftPlan::new(n);
            let mut spec = vec![C64::ZERO; plan.spectrum_len()];
            let mut back = vec![0.0; n];
            plan.rfft_into(&x, &mut spec);
            plan.irfft_into(&spec, &mut back);
            let d = max_abs_diff(&back, &x);
            assert!(d < 1e-12, "n={n}: diff={d}");
        }
    }

    #[test]
    fn conv_plan_matches_direct_oracle() {
        let mut rng = XorShift::new(86);
        for logn in 1..=9 {
            let n = 1 << logn;
            let u = rng.vec(n, -1.0, 1.0);
            let k = rng.vec(n, -1.0, 1.0);
            let got = ConvPlan::new(n).circular(&u, &k);
            let want = crate::fft::conv::direct_conv_circular(&u, &k);
            let d = max_abs_diff(&got, &want);
            assert!(d < 1e-9, "n={n}: diff={d}");
        }
    }

    #[test]
    fn conv_plan_is_deterministic_across_reuse() {
        // Scratch reuse must not leak state between calls.
        let mut rng = XorShift::new(87);
        let u = rng.vec(256, -1.0, 1.0);
        let k = rng.vec(256, -1.0, 1.0);
        let other = rng.vec(256, -1.0, 1.0);
        let mut plan = ConvPlan::new(256);
        let first = plan.circular(&u, &k);
        let _ = plan.circular(&other, &k); // dirty the scratch
        assert_eq!(plan.circular(&u, &k), first);
        let lin_first = plan.linear(&u[..100], &k[..100]);
        let _ = plan.linear(&other[..37], &k[..37]); // shorter: tests re-zeroing
        assert_eq!(plan.linear(&u[..100], &k[..100]), lin_first);
    }

    #[test]
    fn cplx_conv_plan_matches_real_conv_plan() {
        let mut rng = XorShift::new(88);
        let u = rng.vec(1024, -1.0, 1.0);
        let k = rng.vec(1024, -1.0, 1.0);
        let real = ConvPlan::new(1024).circular(&u, &k);
        let cplx = CplxConvPlan::new(1024).circular(&u, &k);
        let d = max_abs_diff(&real, &cplx);
        assert!(d < 1e-9, "diff={d}");
    }

    #[test]
    fn thread_local_cache_reuses_plans() {
        let ptr1 = with_conv_plan(512, |p| p as *const ConvPlan as usize);
        let ptr2 = with_conv_plan(512, |p| p as *const ConvPlan as usize);
        assert_eq!(ptr1, ptr2, "same length must hit the same cached plan");
        let ptr3 = with_conv_plan(1024, |p| p as *const ConvPlan as usize);
        assert_ne!(ptr1, ptr3, "different lengths get different plans");
    }

    #[test]
    fn prop_rfft_matches_dft() {
        prop::quick(
            "rfft == dft half-spectrum",
            |r| {
                let n = 1usize << r.range(1, 10);
                r.vec(n, -2.0, 2.0)
            },
            prop::no_shrink,
            |xs| {
                let n = xs.len();
                let mut plan = RealFftPlan::new(n);
                let mut spec = vec![C64::ZERO; plan.spectrum_len()];
                plan.rfft_into(xs, &mut spec);
                let want = dft(&to_complex(xs));
                let d = max_abs_diff_c(&spec, &want[..n / 2 + 1]);
                if d < 1e-7 {
                    Ok(())
                } else {
                    Err(format!("n={n} diff {d}"))
                }
            },
        );
    }
}
