//! Planned FFT engine — the hot-path transform substrate.
//!
//! The naive [`super::cooley_tukey`] transform re-derives its twiddle
//! factors with `sin`/`cos` on every call and accumulates error through the
//! incremental `w *= wlen` recurrence; every convolution in the Hyena
//! golden-model chain then pays three full-size *complex* transforms on
//! purely *real* signals, plus a fresh `Vec` per stage. FlashFFTConv-style
//! kernel engineering shows this layer is exactly where FFT-based SSM
//! throughput is won, so this module provides the planned counterpart:
//!
//! * [`FftPlan`] — caches the bit-reversal permutation and a single
//!   half-length twiddle table `tw[j] = e^{-2πi·j/N}` at construction;
//!   stage `len` indexes it at stride `N/len`, so steady-state transforms
//!   do **no trig and no allocation**, and every twiddle is a direct table
//!   value rather than the tail of a multiplicative recurrence. Above
//!   [`FFT_BLOCK_POINTS`] the butterfly stages run **cache-blocked**: a
//!   depth-first recursion finishes all early stages of each half before
//!   combining, so long transforms stop sweeping the whole array once per
//!   stage. The traversal is *bit-identical* to the breadth-first loop
//!   (same butterflies on the same values in dependency order — only the
//!   order across independent blocks changes), and the breadth-first loop
//!   is kept verbatim as the [`FftPlan::fft_in_place_flat`] oracle.
//! * [`SplitRadixFftPlan`] — conjugate-pair split-radix DIT recursion:
//!   ~25% fewer butterfly flops than radix-2 at the same length. A
//!   different factorization of the same DFT, so outputs differ from the
//!   radix-2 oracle only by reassociation round-off (≤1e-9, documented —
//!   the property harness enforces the budget differentially).
//! * [`RealFftPlan`] — real-input forward/inverse transforms via the
//!   N/2-point complex-packing trick: pack `z[j] = x[2j] + i·x[2j+1]`, run
//!   one half-size complex FFT, and unpack the half-spectrum `X[0..=N/2]`
//!   with an O(N) butterfly. Roughly halves the flops and memory traffic
//!   of every transform over real data. The inner complex engine is
//!   selected per length ([`FftEngine`]): radix-2 below
//!   [`SPLIT_RADIX_MIN_POINTS`] inner points, split-radix at and above it
//!   (linear convolutions of L ≥ 16k land there), with
//!   [`RealFftPlan::with_engine`] pinning either engine for differential
//!   tests.
//! * [`ConvPlan`] — a circular/linear convolution engine over two cached
//!   half-spectrum scratch buffers: two real forward transforms, one
//!   half-spectrum product, one real inverse — allocation-free after the
//!   first call at a given length.
//! * [`PlanCache`] + [`with_conv_plan`] — a **bounded LRU** of plans per
//!   thread, keyed by transform length, so the drop-in wrappers
//!   ([`super::fft_conv_circular`] / [`super::fft_conv_linear`]) reuse
//!   plans without locking. Misses clone from a process-wide **master
//!   cache**: the tables are built (O(N log N) trig) once per length per
//!   process and every later thread-local miss is a memcpy — so scoped
//!   pool workers with cold thread-local caches no longer pay the trig
//!   rebuild that used to flatten pooled speedups.
//!
//! All planned paths are oracle-checked against [`super::dft::dft`] and
//! the direct convolution in `super::conv`; the acceptance tolerance is
//! 1e-9 (they land around 1e-11). The blocked traversal is additionally
//! asserted *bit-identical* to the flat oracle in `tests/prop.rs`.

use super::is_pow2;
use crate::util::C64;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::f64::consts::PI;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Recursion base of the cache-blocked butterfly traversal, in complex
/// points: 4096 points × 16 B = 64 KiB per block, sized so a block's
/// working set lives in L1/L2 while the early stages run. Transforms at or
/// below this length use the breadth-first loop unchanged.
pub const FFT_BLOCK_POINTS: usize = 4096;

/// Inner-transform length (in complex points) at and above which
/// [`RealFftPlan::new`] routes through the split-radix engine. A linear
/// convolution of length L pads to N = 2·L and packs to N/2 inner points,
/// so L = 16384 → N = 32768 → m = 16384 is the first split-radix length —
/// exactly the L ≥ 16k regime where the radix-2 path was decaying.
pub const SPLIT_RADIX_MIN_POINTS: usize = 1 << 14;

/// A reusable plan for N-point complex FFTs: bit-reversal table + twiddle
/// table, both precomputed once. Methods take `&self`, so one plan can be
/// shared read-only across worker-pool threads.
#[derive(Debug, Clone)]
pub struct FftPlan {
    n: usize,
    /// Bit-reversed index of each position (permutation applied in place).
    rev: Vec<u32>,
    /// `tw[j] = e^{-2πi·j/N}` for `j < N/2`; stage `len` reads stride `N/len`.
    tw: Vec<C64>,
}

impl FftPlan {
    /// Build a plan for N-point transforms. N must be a power of two.
    pub fn new(n: usize) -> Self {
        assert!(is_pow2(n), "FftPlan: length {n} is not a power of two");
        let bits = n.trailing_zeros();
        let rev = (0..n as u32)
            .map(|i| if n == 1 { 0 } else { i.reverse_bits() >> (32 - bits) })
            .collect();
        let tw = (0..n / 2).map(|j| C64::cis(-2.0 * PI * j as f64 / n as f64)).collect();
        Self { n, rev, tw }
    }

    /// Transform length this plan was built for.
    pub fn points(&self) -> usize {
        self.n
    }

    fn check(&self, got: usize) {
        assert_eq!(
            got, self.n,
            "FftPlan for N={} used on a length-{got} buffer; plans are per-length — \
             build a new plan (or use fft::with_conv_plan's keyed cache)",
            self.n
        );
    }

    /// Forward FFT in place. Transforms longer than [`FFT_BLOCK_POINTS`]
    /// take the cache-blocked traversal (bit-identical to the flat loop).
    pub fn fft_in_place(&self, x: &mut [C64]) {
        self.transform(x, false);
    }

    /// Inverse FFT in place, including the 1/N normalization.
    pub fn ifft_in_place(&self, x: &mut [C64]) {
        self.transform(x, true);
        let s = 1.0 / self.n as f64;
        for v in x.iter_mut() {
            *v = v.scale(s);
        }
    }

    /// Inverse FFT in place **without** the 1/N normalization — for callers
    /// that fold the scaling into an adjacent pass (see [`RealFftPlan`]).
    pub fn inverse_unnormalized_in_place(&self, x: &mut [C64]) {
        self.transform(x, true);
    }

    /// Forward FFT in place through the original breadth-first stage-major
    /// loop, kept verbatim as the differential oracle for the cache-blocked
    /// traversal — the property harness asserts the two are bit-identical.
    pub fn fft_in_place_flat(&self, x: &mut [C64]) {
        self.check(x.len());
        if self.n == 1 {
            return;
        }
        self.permute(x);
        self.stages_flat(x, false);
    }

    /// Inverse counterpart of [`Self::fft_in_place_flat`] (1/N included).
    pub fn ifft_in_place_flat(&self, x: &mut [C64]) {
        self.check(x.len());
        if self.n > 1 {
            self.permute(x);
            self.stages_flat(x, true);
        }
        let s = 1.0 / self.n as f64;
        for v in x.iter_mut() {
            *v = v.scale(s);
        }
    }

    /// Forward FFT in place with an explicit cache-block recursion base.
    /// The production entry points use [`FFT_BLOCK_POINTS`]; the property
    /// harness passes tiny bases so the blocked recursion is exercised at
    /// test-sized transforms. `base` must be a power of two ≥ 2.
    pub fn fft_in_place_blocked(&self, x: &mut [C64], base: usize) {
        assert!(
            is_pow2(base) && base >= 2,
            "FftPlan: block base {base} must be a power of two >= 2"
        );
        self.check(x.len());
        if self.n == 1 {
            return;
        }
        self.permute(x);
        self.stages_blocked(x, base, false);
    }

    /// Radix-2 DIT butterflies over the precomputed tables. The `inverse`
    /// transform conjugates each table entry instead of rebuilding it.
    fn transform(&self, x: &mut [C64], inverse: bool) {
        self.check(x.len());
        if self.n == 1 {
            return;
        }
        self.permute(x);
        self.stages_blocked(x, FFT_BLOCK_POINTS, inverse);
    }

    /// Apply the bit-reversal permutation in place.
    fn permute(&self, x: &mut [C64]) {
        for i in 0..self.n {
            let j = self.rev[i] as usize;
            if j > i {
                x.swap(i, j);
            }
        }
    }

    /// Breadth-first butterfly stages `len = 2 ..= x.len()` over one
    /// aligned block. The block length must divide the plan length; stage
    /// `len` reads the *global* table at stride `n/len`, so a butterfly
    /// sees the same twiddle whether it runs flat or inside a block.
    fn stages_flat(&self, x: &mut [C64], inverse: bool) {
        let m = x.len();
        let mut len = 2;
        while len <= m {
            let stride = self.n / len;
            for start in (0..m).step_by(len) {
                // Explicit-lane butterflies (crate::fft::simd), bit-identical
                // to the scalar loop by the no-FMA/exact-expansion rules —
                // flat and blocked traversals share the same pass, so their
                // differential contract is untouched.
                super::simd::butterfly_block(&mut x[start..start + len], stride, &self.tw, inverse);
            }
            len <<= 1;
        }
    }

    /// The single combining stage at `len = x.len()` — the last stage of a
    /// blocked recursion level.
    fn stage_last(&self, x: &mut [C64], inverse: bool) {
        let stride = self.n / x.len();
        super::simd::butterfly_block(x, stride, &self.tw, inverse);
    }

    /// Depth-first cache-blocked traversal: finish *all* stages of each
    /// half while its working set is still cache-resident, then run the one
    /// combining stage at this level. Every butterfly computes the same
    /// values as the flat loop (dependency order is preserved; only the
    /// order across independent blocks changes), so the result is
    /// bit-identical — asserted against [`Self::fft_in_place_flat`] by the
    /// property harness.
    fn stages_blocked(&self, x: &mut [C64], base: usize, inverse: bool) {
        let m = x.len();
        if m <= base {
            self.stages_flat(x, inverse);
            return;
        }
        let (lo, hi) = x.split_at_mut(m / 2);
        self.stages_blocked(lo, base, inverse);
        self.stages_blocked(hi, base, inverse);
        self.stage_last(x, inverse);
    }
}

/// A split-radix (conjugate-pair DIT) FFT plan: the size-N transform
/// decomposes into one size-N/2 transform over the even samples and two
/// size-N/4 transforms over the `4k+1` / `4k+3` odd samples, saving ~25%
/// of the butterfly flops vs radix-2. Out-of-place (`fft_into`), no
/// bit-reversal pass; the full-circle twiddle table `tw[j] = e^{-2πi·j/N}`
/// serves every recursion level at stride `N/m`.
///
/// This is a different *factorization* of the same DFT, so its outputs are
/// not bit-identical to the radix-2 plan — they agree to the documented
/// ≤1e-9 reassociation budget (observed ~1e-12 at N = 32768), which the
/// property harness enforces differentially against [`FftPlan`].
#[derive(Debug, Clone)]
pub struct SplitRadixFftPlan {
    n: usize,
    /// Full-circle table `tw[j] = e^{-2πi·j/N}` for `j < N`: the combine at
    /// size m reads `w¹ = tw[k·(N/m)]` and `w³ = tw[3k·(N/m) mod N]`.
    tw: Vec<C64>,
}

impl SplitRadixFftPlan {
    /// Build a plan for N-point transforms. N must be a power of two.
    pub fn new(n: usize) -> Self {
        assert!(is_pow2(n), "SplitRadixFftPlan: length {n} is not a power of two");
        let tw = (0..n).map(|j| C64::cis(-2.0 * PI * j as f64 / n as f64)).collect();
        Self { n, tw }
    }

    /// Transform length this plan was built for.
    pub fn points(&self) -> usize {
        self.n
    }

    fn check(&self, xl: usize, ol: usize) {
        assert!(
            xl == self.n && ol == self.n,
            "SplitRadixFftPlan for N={} used on length-{xl}/{ol} buffers",
            self.n
        );
    }

    /// Forward FFT: `out = FFT(x)`.
    pub fn fft_into(&self, x: &[C64], out: &mut [C64]) {
        self.check(x.len(), out.len());
        self.rec(x, 0, 1, out, false);
    }

    /// Inverse FFT including the 1/N normalization.
    pub fn ifft_into(&self, x: &[C64], out: &mut [C64]) {
        self.inverse_unnormalized_into(x, out);
        let s = 1.0 / self.n as f64;
        for v in out.iter_mut() {
            *v = v.scale(s);
        }
    }

    /// Inverse FFT **without** the 1/N normalization — for callers that
    /// fold the scaling into an adjacent pass (see [`RealFftPlan`]).
    pub fn inverse_unnormalized_into(&self, x: &[C64], out: &mut [C64]) {
        self.check(x.len(), out.len());
        self.rec(x, 0, 1, out, true);
    }

    /// The recursion: `out` (length m) receives the transform of the
    /// strided samples `x[off], x[off+stride], …`. Sub-results land at
    /// U → `out[..m/2]`, Z → `out[m/2..3m/4]`, Z' → `out[3m/4..]`, then the
    /// combine rewrites the four slots `{k, k+q, h+k, 3q+k}` in place per k
    /// (all distinct for k < q = m/4, h = m/2).
    fn rec(&self, x: &[C64], off: usize, stride: usize, out: &mut [C64], inverse: bool) {
        let m = out.len();
        if m == 1 {
            out[0] = x[off];
            return;
        }
        if m == 2 {
            let a = x[off];
            let b = x[off + stride];
            out[0] = a + b;
            out[1] = a - b;
            return;
        }
        let q = m / 4;
        let h = m / 2;
        {
            let (u, zz) = out.split_at_mut(h);
            let (z1, z3) = zz.split_at_mut(q);
            self.rec(x, off, 2 * stride, u, inverse);
            self.rec(x, off + stride, 4 * stride, z1, inverse);
            self.rec(x, off + 3 * stride, 4 * stride, z3, inverse);
        }
        let step = self.n / m;
        for k in 0..q {
            let mut w1 = self.tw[k * step];
            let mut w3 = self.tw[(3 * k * step) % self.n];
            if inverse {
                w1 = w1.conj();
                w3 = w3.conj();
            }
            let uk = out[k];
            let uq = out[k + q];
            let t1 = w1 * out[h + k];
            let t3 = w3 * out[3 * q + k];
            let s = t1 + t3;
            let d = t1 - t3;
            // d rotated by −i (forward) / +i (inverse).
            let rot = if inverse { C64::new(-d.im, d.re) } else { C64::new(d.im, -d.re) };
            out[k] = uk + s;
            out[h + k] = uk - s;
            out[k + q] = uq + rot;
            out[3 * q + k] = uq - rot;
        }
    }
}

/// Which complex engine a [`RealFftPlan`] runs its inner transform on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FftEngine {
    /// Iterative radix-2 DIT over [`FftPlan`] (cache-blocked above
    /// [`FFT_BLOCK_POINTS`], bit-identical to the flat oracle).
    Radix2,
    /// Conjugate-pair split-radix recursion ([`SplitRadixFftPlan`]):
    /// ~25% fewer butterfly flops; agrees with radix-2 to the documented
    /// ≤1e-9 reassociation budget.
    SplitRadix,
}

/// A reusable plan for N-point **real-input** transforms via the N/2-point
/// complex-packing trick. Holds its own packing scratch, so `rfft_into` /
/// `irfft_into` are allocation-free; methods therefore take `&mut self`
/// (one plan per thread — see [`with_conv_plan`]).
#[derive(Debug, Clone)]
pub struct RealFftPlan {
    n: usize,
    m: usize,
    engine: FftEngine,
    inner: FftPlan,
    /// Split-radix engine + its out-of-place result buffer, only built when
    /// `engine == SplitRadix` (m ≥ [`SPLIT_RADIX_MIN_POINTS`] by default).
    sr: Option<(SplitRadixFftPlan, Vec<C64>)>,
    /// `w[k] = e^{-2πi·k/N}` for `k < N/2` — the pack/unpack twiddles.
    w: Vec<C64>,
    /// Packing scratch, length N/2.
    pack: Vec<C64>,
}

impl RealFftPlan {
    /// Build a plan for N-point real transforms. N must be a power of two
    /// with N ≥ 2 (the packing trick needs an even length). The inner
    /// engine is split-radix when the packed length N/2 reaches
    /// [`SPLIT_RADIX_MIN_POINTS`], radix-2 below.
    pub fn new(n: usize) -> Self {
        let m = n / 2;
        let engine = if m >= SPLIT_RADIX_MIN_POINTS {
            FftEngine::SplitRadix
        } else {
            FftEngine::Radix2
        };
        Self::with_engine(n, engine)
    }

    /// Build a plan with the inner engine pinned — the differential tests
    /// use this to run both engines at the same (small) length.
    pub fn with_engine(n: usize, engine: FftEngine) -> Self {
        assert!(
            is_pow2(n) && n >= 2,
            "RealFftPlan: length {n} must be a power of two >= 2"
        );
        let m = n / 2;
        let sr = match engine {
            FftEngine::Radix2 => None,
            FftEngine::SplitRadix => Some((SplitRadixFftPlan::new(m), vec![C64::ZERO; m])),
        };
        Self {
            n,
            m,
            engine,
            inner: FftPlan::new(m),
            sr,
            w: (0..m).map(|k| C64::cis(-2.0 * PI * k as f64 / n as f64)).collect(),
            pack: vec![C64::ZERO; m],
        }
    }

    /// Signal length this plan was built for.
    pub fn points(&self) -> usize {
        self.n
    }

    /// Which complex engine the inner transform runs on.
    pub fn engine(&self) -> FftEngine {
        self.engine
    }

    /// Run the inner forward transform on `self.pack` via the selected
    /// engine. Split-radix is out-of-place, so its result buffer is swapped
    /// back into `pack` — still allocation-free.
    fn forward_packed(&mut self) {
        match &mut self.sr {
            None => self.inner.fft_in_place(&mut self.pack),
            Some((sr, buf)) => {
                sr.fft_into(&self.pack, buf);
                std::mem::swap(&mut self.pack, buf);
            }
        }
    }

    /// Inner unnormalized inverse transform on `self.pack` (the 1/m scale
    /// is folded into the unpack pass by the caller).
    fn inverse_packed(&mut self) {
        match &mut self.sr {
            None => self.inner.inverse_unnormalized_in_place(&mut self.pack),
            Some((sr, buf)) => {
                sr.inverse_unnormalized_into(&self.pack, buf);
                std::mem::swap(&mut self.pack, buf);
            }
        }
    }

    /// Half-spectrum length: `N/2 + 1` bins (bins 0 and N/2 are real).
    pub fn spectrum_len(&self) -> usize {
        self.m + 1
    }

    /// Forward real FFT: `x` (length N, real) → half-spectrum `out`
    /// (length N/2+1). The upper half of the full spectrum is the conjugate
    /// mirror `X[N-k] = conj(X[k])` and is never materialized.
    pub fn rfft_into(&mut self, x: &[f64], out: &mut [C64]) {
        assert_eq!(
            x.len(),
            self.n,
            "RealFftPlan for N={} used on a length-{} signal",
            self.n,
            x.len()
        );
        assert_eq!(out.len(), self.m + 1, "rfft_into: spectrum buffer must hold N/2+1 bins");
        let m = self.m;
        for j in 0..m {
            self.pack[j] = C64::new(x[2 * j], x[2 * j + 1]);
        }
        self.forward_packed();
        // Unpack: Xe[k] = (Z[k] + conj(Z[m−k]))/2 (even samples' spectrum),
        //         Xo[k] = −i·(Z[k] − conj(Z[m−k]))/2 (odd samples'),
        //         X[k]  = Xe[k] + w^k·Xo[k].
        for k in 0..m {
            let zk = self.pack[k];
            let zmk = self.pack[if k == 0 { 0 } else { m - k }].conj();
            let xe = (zk + zmk).scale(0.5);
            let d = zk - zmk;
            let xo = C64::new(d.im * 0.5, -d.re * 0.5);
            out[k] = xe + self.w[k] * xo;
        }
        // X[N/2] = Xe[0] − Xo[0] = Re(Z[0]) − Im(Z[0]), exactly real.
        out[m] = C64::real(self.pack[0].re - self.pack[0].im);
    }

    /// Inverse real FFT: half-spectrum `spec` (length N/2+1) → real `out`
    /// (length N), 1/N normalization included (folded into the unpack).
    pub fn irfft_into(&mut self, spec: &[C64], out: &mut [f64]) {
        assert_eq!(spec.len(), self.m + 1, "irfft_into: spectrum must hold N/2+1 bins");
        assert_eq!(
            out.len(),
            self.n,
            "RealFftPlan for N={} asked to fill a length-{} signal",
            self.n,
            out.len()
        );
        let m = self.m;
        // Repack: Ye[k] = (X[k] + conj(X[m−k]))/2, Yo[k] = (X[k] −
        // conj(X[m−k]))/2 · conj(w^k), Z[k] = Ye[k] + i·Yo[k].
        for k in 0..m {
            let a = spec[k];
            let b = spec[m - k].conj();
            let ye = (a + b).scale(0.5);
            let yo = (a - b).scale(0.5) * self.w[k].conj();
            self.pack[k] = C64::new(ye.re - yo.im, ye.im + yo.re);
        }
        self.inverse_packed();
        let s = 1.0 / m as f64;
        for j in 0..m {
            out[2 * j] = self.pack[j].re * s;
            out[2 * j + 1] = self.pack[j].im * s;
        }
    }
}

/// A planned real-input convolution engine: all scratch (two half-spectra,
/// two zero-padding buffers) lives in the plan, so circular and linear
/// convolutions are allocation-free after construction.
#[derive(Debug, Clone)]
pub struct ConvPlan {
    rp: RealFftPlan,
    spec_u: Vec<C64>,
    spec_k: Vec<C64>,
    padded_u: Vec<f64>,
    padded_k: Vec<f64>,
    full: Vec<f64>,
}

impl ConvPlan {
    /// Build a convolution plan for N-point circular convolutions (N a
    /// power of two ≥ 2). Linear convolutions of length L require
    /// `N ≥ 2·L` so the zero-padding absorbs the wrap-around.
    pub fn new(n: usize) -> Self {
        let rp = RealFftPlan::new(n);
        let bins = rp.spectrum_len();
        Self {
            rp,
            spec_u: vec![C64::ZERO; bins],
            spec_k: vec![C64::ZERO; bins],
            padded_u: vec![0.0; n],
            padded_k: vec![0.0; n],
            full: vec![0.0; n],
        }
    }

    /// Transform length of the plan.
    pub fn points(&self) -> usize {
        self.rp.points()
    }

    /// Which complex engine the plan's real transforms run on.
    pub fn engine(&self) -> FftEngine {
        self.rp.engine()
    }

    /// Circular convolution of two length-N real signals into `out`:
    /// `rfft(u) ⊙ rfft(k) → irfft`, two half-size transforms each way.
    pub fn circular_into(&mut self, u: &[f64], k: &[f64], out: &mut [f64]) {
        assert_eq!(u.len(), k.len(), "ConvPlan::circular: length mismatch");
        self.rp.rfft_into(u, &mut self.spec_u);
        self.rp.rfft_into(k, &mut self.spec_k);
        for (a, b) in self.spec_u.iter_mut().zip(&self.spec_k) {
            *a = *a * *b;
        }
        self.rp.irfft_into(&self.spec_u, out);
    }

    /// Circular convolution, allocating the output.
    pub fn circular(&mut self, u: &[f64], k: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.points()];
        self.circular_into(u, k, &mut out);
        out
    }

    /// Causal/linear convolution of a length-L signal with a length-L
    /// filter, truncated to the first L outputs (Hyena semantics). The
    /// plan's N must be ≥ 2·L; inputs are zero-padded into plan scratch.
    pub fn linear(&mut self, u: &[f64], k: &[f64]) -> Vec<f64> {
        let l = u.len();
        assert_eq!(l, k.len(), "ConvPlan::linear: length mismatch");
        let n = self.points();
        assert!(
            n >= 2 * l,
            "ConvPlan::linear: plan N={n} cannot hold 2x length-{l} zero-padded inputs"
        );
        self.padded_u[..l].copy_from_slice(u);
        self.padded_u[l..].fill(0.0);
        self.padded_k[..l].copy_from_slice(k);
        self.padded_k[l..].fill(0.0);
        self.rp.rfft_into(&self.padded_u, &mut self.spec_u);
        self.rp.rfft_into(&self.padded_k, &mut self.spec_k);
        for (a, b) in self.spec_u.iter_mut().zip(&self.spec_k) {
            *a = *a * *b;
        }
        self.rp.irfft_into(&self.spec_u, &mut self.full);
        self.full[..l].to_vec()
    }
}

/// A planned **complex** convolution engine (three full-size transforms,
/// no real packing): the controlled baseline the perf bench compares the
/// real path against, isolating the rfft win from the planning win.
#[derive(Debug, Clone)]
pub struct CplxConvPlan {
    plan: FftPlan,
    fu: Vec<C64>,
    fk: Vec<C64>,
}

impl CplxConvPlan {
    /// Build a planned complex convolution engine for N-point signals.
    pub fn new(n: usize) -> Self {
        Self { plan: FftPlan::new(n), fu: vec![C64::ZERO; n], fk: vec![C64::ZERO; n] }
    }

    /// Circular convolution of two length-N real signals through the
    /// planned complex pipeline: FFT(u), FFT(k), product, iFFT.
    pub fn circular(&mut self, u: &[f64], k: &[f64]) -> Vec<f64> {
        assert_eq!(u.len(), k.len(), "CplxConvPlan::circular: length mismatch");
        assert_eq!(
            u.len(),
            self.fu.len(),
            "CplxConvPlan for N={} used on another length",
            self.fu.len()
        );
        for (dst, &v) in self.fu.iter_mut().zip(u) {
            *dst = C64::real(v);
        }
        for (dst, &v) in self.fk.iter_mut().zip(k) {
            *dst = C64::real(v);
        }
        self.plan.fft_in_place(&mut self.fu);
        self.plan.fft_in_place(&mut self.fk);
        for (a, b) in self.fu.iter_mut().zip(&self.fk) {
            *a = *a * *b;
        }
        self.plan.ifft_in_place(&mut self.fu);
        self.fu.iter().map(|z| z.re).collect()
    }
}

/// Capacity of each thread's [`PlanCache`]: plans for more than this many
/// distinct transform lengths evict the least-recently-used entry (counted
/// in `fft.plan_cache.evictions`). Re-planning an evicted length is a
/// master-cache clone, not a trig rebuild, so the cap trades bounded
/// memory for a memcpy on churn.
pub const PLAN_CACHE_CAP: usize = 24;

/// A bounded LRU of [`ConvPlan`]s keyed by transform length — the
/// structure behind [`with_conv_plan`], kept standalone so eviction and
/// reuse behaviour is deterministic to unit-test. Instance counters
/// (`hits`/`misses`/`evictions`) are plain `u64`s; [`with_conv_plan`]
/// forwards their deltas to the process-wide telemetry counters.
#[derive(Debug)]
pub struct PlanCache {
    /// length → (last-use stamp, plan).
    plans: BTreeMap<usize, (u64, ConvPlan)>,
    clock: u64,
    cap: usize,
    /// Lookups that found a resident plan.
    pub hits: u64,
    /// Lookups that had to build (or clone) a plan.
    pub misses: u64,
    /// Resident plans dropped to stay within capacity.
    pub evictions: u64,
}

impl PlanCache {
    /// An empty cache holding at most `cap` plans (`cap` ≥ 1).
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "PlanCache: capacity must be at least 1");
        Self { plans: BTreeMap::new(), clock: 0, cap, hits: 0, misses: 0, evictions: 0 }
    }

    /// Number of resident plans.
    pub fn len(&self) -> usize {
        self.plans.len()
    }

    /// True when no plans are resident.
    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    /// True when a plan for length `n` is resident (does not touch LRU
    /// order or counters).
    pub fn contains(&self, n: usize) -> bool {
        self.plans.contains_key(&n)
    }

    /// Make the plan for length `n` resident, building via `build` on a
    /// miss and evicting the least-recently-used plan when over capacity.
    /// Updates LRU order and the instance counters.
    pub fn ensure(&mut self, n: usize, build: impl FnOnce(usize) -> ConvPlan) {
        self.clock += 1;
        let stamp = self.clock;
        if let Some((t, _)) = self.plans.get_mut(&n) {
            *t = stamp;
            self.hits += 1;
            return;
        }
        self.misses += 1;
        if self.plans.len() >= self.cap {
            let lru = self.plans.iter().min_by_key(|(_, (t, _))| *t).map(|(&k, _)| k);
            if let Some(k) = lru {
                self.plans.remove(&k);
                self.evictions += 1;
            }
        }
        self.plans.insert(n, (stamp, build(n)));
    }

    /// Borrow the resident plan for length `n` (no counter or LRU effect);
    /// `None` if not resident — call [`Self::ensure`] first.
    pub fn get_mut(&mut self, n: usize) -> Option<&mut ConvPlan> {
        self.plans.get_mut(&n).map(|(_, p)| p)
    }
}

thread_local! {
    /// Per-thread convolution plans keyed by transform length. Thread-local
    /// so worker-pool threads never contend on a lock in steady state;
    /// bounded ([`PLAN_CACHE_CAP`]) so long-lived threads sweeping many
    /// lengths don't hoard plan memory.
    static CONV_PLANS: RefCell<Option<PlanCache>> = const { RefCell::new(None) };
}

/// The plan-cache telemetry counters, resolved once so the steady-state
/// cost on the conv hot path is a few relaxed `fetch_add`s.
fn plan_cache_counters() -> (&'static AtomicU64, &'static AtomicU64, &'static AtomicU64) {
    static HITS: OnceLock<&'static AtomicU64> = OnceLock::new();
    static MISSES: OnceLock<&'static AtomicU64> = OnceLock::new();
    static EVICTIONS: OnceLock<&'static AtomicU64> = OnceLock::new();
    (
        HITS.get_or_init(|| crate::telemetry::counter("fft.plan_cache.hits")),
        MISSES.get_or_init(|| crate::telemetry::counter("fft.plan_cache.misses")),
        EVICTIONS.get_or_init(|| crate::telemetry::counter("fft.plan_cache.evictions")),
    )
}

/// Fetch a [`ConvPlan`] for length `n` from the process-wide master cache,
/// building it (O(N log N) trig) at most once per length per process and
/// **cloning** it — a memcpy of the tables and scratch, no trig — for the
/// caller. This is what keeps scoped-pool workers fast: a fresh thread's
/// first conv at a length costs a table copy instead of a plan rebuild.
fn master_plan(n: usize) -> ConvPlan {
    static MASTER: OnceLock<Mutex<BTreeMap<usize, ConvPlan>>> = OnceLock::new();
    let master = MASTER.get_or_init(|| Mutex::new(BTreeMap::new()));
    {
        let cache = master.lock().expect("fft master plan cache poisoned");
        if let Some(p) = cache.get(&n) {
            return p.clone();
        }
    }
    // Build outside the lock: construction is the expensive part, and two
    // threads racing the same length just means one redundant build.
    let built = ConvPlan::new(n);
    let mut cache = master.lock().expect("fft master plan cache poisoned");
    cache.entry(n).or_insert(built).clone()
}

/// Run `f` against this thread's cached [`ConvPlan`] for length `n`,
/// cloning the plan out of the process-wide master cache on first use (so
/// only the first use of a length *in the whole process* pays trig). This
/// is what makes the drop-in wrappers `fft_conv_circular`/`fft_conv_linear`
/// allocation-free in steady state without changing their signatures.
/// Cache traffic shows up in the `fft.plan_cache.hits`/`.misses`/
/// `.evictions` counters (`--metrics`).
pub fn with_conv_plan<T>(n: usize, f: impl FnOnce(&mut ConvPlan) -> T) -> T {
    CONV_PLANS.with(|cell| {
        let mut slot = cell.borrow_mut();
        let cache = slot.get_or_insert_with(|| PlanCache::new(PLAN_CACHE_CAP));
        let before = (cache.hits, cache.misses, cache.evictions);
        cache.ensure(n, master_plan);
        let (hits, misses, evictions) = plan_cache_counters();
        hits.fetch_add(cache.hits - before.0, Ordering::Relaxed);
        misses.fetch_add(cache.misses - before.1, Ordering::Relaxed);
        evictions.fetch_add(cache.evictions - before.2, Ordering::Relaxed);
        f(cache.get_mut(n).expect("plan resident after ensure"))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{dft::dft, to_complex};
    use crate::util::complex::max_abs_diff_c;
    use crate::util::{max_abs_diff, prop, XorShift};

    #[test]
    fn planned_fft_matches_dft() {
        let mut rng = XorShift::new(81);
        for logn in 0..=10 {
            let n = 1 << logn;
            let x: Vec<C64> = (0..n)
                .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
                .collect();
            let plan = FftPlan::new(n);
            let mut got = x.clone();
            plan.fft_in_place(&mut got);
            let d = max_abs_diff_c(&got, &dft(&x));
            assert!(d < 1e-8, "n={n}: diff={d}");
        }
    }

    #[test]
    fn planned_fft_matches_naive_fft() {
        // Same transform, different twiddle provenance (table vs recurrence):
        // both are oracle-exact, and must agree far below the 1e-9 budget.
        let mut rng = XorShift::new(82);
        let x = to_complex(&rng.vec(1 << 12, -1.0, 1.0));
        let plan = FftPlan::new(x.len());
        let mut got = x.clone();
        plan.fft_in_place(&mut got);
        let d = max_abs_diff_c(&got, &crate::fft::fft(&x));
        assert!(d < 1e-10, "diff={d}");
    }

    #[test]
    fn planned_ifft_roundtrips() {
        let mut rng = XorShift::new(83);
        let x: Vec<C64> = (0..512)
            .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
            .collect();
        let plan = FftPlan::new(512);
        let mut buf = x.clone();
        plan.fft_in_place(&mut buf);
        plan.ifft_in_place(&mut buf);
        assert!(max_abs_diff_c(&buf, &x) < 1e-11);
    }

    #[test]
    #[should_panic(expected = "FftPlan for N=1024")]
    fn plan_rejects_mismatched_length() {
        let plan = FftPlan::new(1024);
        let mut wrong = vec![C64::ZERO; 512];
        plan.fft_in_place(&mut wrong);
    }

    #[test]
    #[should_panic(expected = "RealFftPlan for N=256")]
    fn real_plan_rejects_mismatched_length() {
        let mut plan = RealFftPlan::new(256);
        let mut spec = vec![C64::ZERO; plan.spectrum_len()];
        plan.rfft_into(&[0.0; 128], &mut spec);
    }

    #[test]
    fn rfft_matches_full_fft_half_spectrum() {
        let mut rng = XorShift::new(84);
        for logn in 1..=11 {
            let n = 1 << logn;
            let x = rng.vec(n, -1.0, 1.0);
            let mut plan = RealFftPlan::new(n);
            let mut spec = vec![C64::ZERO; plan.spectrum_len()];
            plan.rfft_into(&x, &mut spec);
            let full = crate::fft::fft(&to_complex(&x));
            let d = max_abs_diff_c(&spec, &full[..n / 2 + 1]);
            assert!(d < 1e-9, "n={n}: diff={d}");
            assert_eq!(spec[0].im, 0.0, "DC bin is exactly real");
            assert_eq!(spec[n / 2].im, 0.0, "Nyquist bin is exactly real");
        }
    }

    #[test]
    fn irfft_inverts_rfft() {
        let mut rng = XorShift::new(85);
        for logn in 1..=11 {
            let n = 1 << logn;
            let x = rng.vec(n, -1.0, 1.0);
            let mut plan = RealFftPlan::new(n);
            let mut spec = vec![C64::ZERO; plan.spectrum_len()];
            let mut back = vec![0.0; n];
            plan.rfft_into(&x, &mut spec);
            plan.irfft_into(&spec, &mut back);
            let d = max_abs_diff(&back, &x);
            assert!(d < 1e-12, "n={n}: diff={d}");
        }
    }

    #[test]
    fn conv_plan_matches_direct_oracle() {
        let mut rng = XorShift::new(86);
        for logn in 1..=9 {
            let n = 1 << logn;
            let u = rng.vec(n, -1.0, 1.0);
            let k = rng.vec(n, -1.0, 1.0);
            let got = ConvPlan::new(n).circular(&u, &k);
            let want = crate::fft::conv::direct_conv_circular(&u, &k);
            let d = max_abs_diff(&got, &want);
            assert!(d < 1e-9, "n={n}: diff={d}");
        }
    }

    #[test]
    fn conv_plan_is_deterministic_across_reuse() {
        // Scratch reuse must not leak state between calls.
        let mut rng = XorShift::new(87);
        let u = rng.vec(256, -1.0, 1.0);
        let k = rng.vec(256, -1.0, 1.0);
        let other = rng.vec(256, -1.0, 1.0);
        let mut plan = ConvPlan::new(256);
        let first = plan.circular(&u, &k);
        let _ = plan.circular(&other, &k); // dirty the scratch
        assert_eq!(plan.circular(&u, &k), first);
        let lin_first = plan.linear(&u[..100], &k[..100]);
        let _ = plan.linear(&other[..37], &k[..37]); // shorter: tests re-zeroing
        assert_eq!(plan.linear(&u[..100], &k[..100]), lin_first);
    }

    #[test]
    fn cplx_conv_plan_matches_real_conv_plan() {
        let mut rng = XorShift::new(88);
        let u = rng.vec(1024, -1.0, 1.0);
        let k = rng.vec(1024, -1.0, 1.0);
        let real = ConvPlan::new(1024).circular(&u, &k);
        let cplx = CplxConvPlan::new(1024).circular(&u, &k);
        let d = max_abs_diff(&real, &cplx);
        assert!(d < 1e-9, "diff={d}");
    }

    #[test]
    fn thread_local_cache_reuses_plans() {
        let ptr1 = with_conv_plan(512, |p| p as *const ConvPlan as usize);
        let ptr2 = with_conv_plan(512, |p| p as *const ConvPlan as usize);
        assert_eq!(ptr1, ptr2, "same length must hit the same cached plan");
        let ptr3 = with_conv_plan(1024, |p| p as *const ConvPlan as usize);
        assert_ne!(ptr1, ptr3, "different lengths get different plans");
    }

    #[test]
    fn blocked_traversal_is_bit_identical_to_flat() {
        // The cache-blocked recursion must equal the breadth-first oracle
        // exactly — not approximately — at every size and base.
        let mut rng = XorShift::new(90);
        for logn in 0..=12 {
            let n = 1 << logn;
            let x: Vec<C64> = (0..n)
                .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
                .collect();
            let plan = FftPlan::new(n);
            let mut flat = x.clone();
            plan.fft_in_place_flat(&mut flat);
            for base in [2usize, 8, 64, 1024] {
                let mut blocked = x.clone();
                plan.fft_in_place_blocked(&mut blocked, base);
                assert_eq!(blocked, flat, "n={n} base={base}: blocked != flat");
            }
            // The production entry point must also be exact (it routes
            // through the same recursion with base = FFT_BLOCK_POINTS).
            let mut prod = x.clone();
            plan.fft_in_place(&mut prod);
            assert_eq!(prod, flat, "n={n}: fft_in_place != flat oracle");
            let mut inv_flat = flat.clone();
            let mut inv_prod = flat.clone();
            plan.ifft_in_place_flat(&mut inv_flat);
            plan.ifft_in_place(&mut inv_prod);
            assert_eq!(inv_prod, inv_flat, "n={n}: inverse blocked != flat");
        }
    }

    #[test]
    fn split_radix_matches_radix2_within_budget() {
        let mut rng = XorShift::new(91);
        for logn in 0..=13 {
            let n = 1 << logn;
            let x: Vec<C64> = (0..n)
                .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
                .collect();
            let mut want = x.clone();
            FftPlan::new(n).fft_in_place(&mut want);
            let sr = SplitRadixFftPlan::new(n);
            let mut got = vec![C64::ZERO; n];
            sr.fft_into(&x, &mut got);
            let d = max_abs_diff_c(&got, &want);
            assert!(d < 1e-9, "n={n}: split-radix vs radix-2 diff={d}");
            let mut back = vec![C64::ZERO; n];
            sr.ifft_into(&got, &mut back);
            let rt = max_abs_diff_c(&back, &x);
            assert!(rt < 1e-10, "n={n}: split-radix roundtrip diff={rt}");
        }
    }

    #[test]
    fn real_plan_engines_agree_and_auto_route() {
        let mut rng = XorShift::new(92);
        let n = 1 << 10;
        let x = rng.vec(n, -1.0, 1.0);
        let mut r2 = RealFftPlan::with_engine(n, FftEngine::Radix2);
        let mut sr = RealFftPlan::with_engine(n, FftEngine::SplitRadix);
        assert_eq!(r2.engine(), FftEngine::Radix2);
        assert_eq!(sr.engine(), FftEngine::SplitRadix);
        let mut spec_a = vec![C64::ZERO; r2.spectrum_len()];
        let mut spec_b = vec![C64::ZERO; sr.spectrum_len()];
        r2.rfft_into(&x, &mut spec_a);
        sr.rfft_into(&x, &mut spec_b);
        let d = max_abs_diff_c(&spec_a, &spec_b);
        assert!(d < 1e-9, "engine spectra diverge: {d}");
        let mut back = vec![0.0; n];
        sr.irfft_into(&spec_b, &mut back);
        assert!(max_abs_diff(&back, &x) < 1e-10, "split-radix real roundtrip");
        // Auto-routing: small plans stay radix-2; plans whose packed length
        // reaches SPLIT_RADIX_MIN_POINTS flip to split-radix.
        assert_eq!(RealFftPlan::new(1 << 10).engine(), FftEngine::Radix2);
        assert_eq!(
            RealFftPlan::new(2 * SPLIT_RADIX_MIN_POINTS).engine(),
            FftEngine::SplitRadix
        );
        assert_eq!(ConvPlan::new(2 * SPLIT_RADIX_MIN_POINTS).engine(), FftEngine::SplitRadix);
    }

    #[test]
    fn split_radix_conv_matches_complex_pipeline() {
        // End-to-end at the first auto-split-radix length: the planned real
        // conv (now on the split-radix engine) must agree with the planned
        // complex pipeline, which runs the independent radix-2 engine.
        let mut rng = XorShift::new(93);
        let n = 2 * SPLIT_RADIX_MIN_POINTS;
        let u = rng.vec(n, -1.0, 1.0);
        let k = rng.vec(n, -1.0, 1.0);
        let mut plan = ConvPlan::new(n);
        assert_eq!(plan.engine(), FftEngine::SplitRadix);
        let got = plan.circular(&u, &k);
        let want = CplxConvPlan::new(n).circular(&u, &k);
        let d = max_abs_diff(&got, &want);
        assert!(d < 1e-6, "n={n}: diff={d}");
    }

    #[test]
    fn plan_cache_evicts_lru_and_counts() {
        let mut cache = PlanCache::new(2);
        cache.ensure(8, ConvPlan::new);
        cache.ensure(16, ConvPlan::new);
        assert_eq!((cache.hits, cache.misses, cache.evictions), (0, 2, 0));
        cache.ensure(8, ConvPlan::new); // touch 8 → 16 becomes LRU
        assert_eq!(cache.hits, 1);
        cache.ensure(32, ConvPlan::new); // evicts 16, not the re-touched 8
        assert_eq!((cache.misses, cache.evictions), (3, 1));
        assert!(cache.contains(8) && cache.contains(32) && !cache.contains(16));
        assert_eq!(cache.len(), 2);
        // Re-requesting the evicted length is a fresh miss + eviction.
        cache.ensure(16, ConvPlan::new);
        assert_eq!((cache.misses, cache.evictions), (4, 2));
        // The rebuilt plan still works.
        let mut rng = XorShift::new(94);
        let u = rng.vec(16, -1.0, 1.0);
        let k = rng.vec(16, -1.0, 1.0);
        let got = cache.get_mut(16).unwrap().circular(&u, &k);
        let want = crate::fft::conv::direct_conv_circular(&u, &k);
        assert!(max_abs_diff(&got, &want) < 1e-9);
    }

    #[test]
    fn plan_cache_eviction_survives_split_radix_lengths() {
        // Evicting and re-ensuring a split-radix-engined plan must round
        // trip through the master cache without losing the engine choice.
        let n = 2 * SPLIT_RADIX_MIN_POINTS;
        let mut cache = PlanCache::new(1);
        cache.ensure(n, super::master_plan);
        assert_eq!(cache.get_mut(n).unwrap().engine(), FftEngine::SplitRadix);
        cache.ensure(8, super::master_plan); // evicts the big plan
        assert_eq!(cache.evictions, 1);
        assert!(!cache.contains(n));
        cache.ensure(n, super::master_plan); // master clone, no trig rebuild
        assert_eq!(cache.get_mut(n).unwrap().engine(), FftEngine::SplitRadix);
        assert_eq!(cache.get_mut(n).unwrap().points(), n);
    }

    #[test]
    fn master_plan_clones_are_independent_and_correct() {
        let mut a = super::master_plan(64);
        let mut b = super::master_plan(64);
        let mut rng = XorShift::new(95);
        let u = rng.vec(64, -1.0, 1.0);
        let k = rng.vec(64, -1.0, 1.0);
        let ra = a.circular(&u, &k);
        let _ = b.circular(&k, &u); // dirty b's scratch independently
        let rb = b.circular(&u, &k);
        assert_eq!(ra, rb, "clones must compute identically");
        let want = crate::fft::conv::direct_conv_circular(&u, &k);
        assert!(max_abs_diff(&ra, &want) < 1e-9);
    }

    #[test]
    fn prop_rfft_matches_dft() {
        prop::quick(
            "rfft == dft half-spectrum",
            |r| {
                let n = 1usize << r.range(1, 10);
                r.vec(n, -2.0, 2.0)
            },
            prop::no_shrink,
            |xs| {
                let n = xs.len();
                let mut plan = RealFftPlan::new(n);
                let mut spec = vec![C64::ZERO; plan.spectrum_len()];
                plan.rfft_into(xs, &mut spec);
                let want = dft(&to_complex(xs));
                let d = max_abs_diff_c(&spec, &want[..n / 2 + 1]);
                if d < 1e-7 {
                    Ok(())
                } else {
                    Err(format!("n={n} diff {d}"))
                }
            },
        );
    }
}
