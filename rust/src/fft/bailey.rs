//! Bailey's 4-step FFT (paper §III-A, Fig. 6).
//!
//! Decomposes an L-point FFT over a 2-D reshape `L = R × C`:
//!
//! 1. reshape the input into an `R × C` matrix (column-major segments),
//! 2. FFT each **column** (length-R transforms — the "tiles" sized to the
//!    hardware's vector width, R = 16 or 32),
//! 3. multiply elementwise by twiddle factors `e^{-2πi·r·c/L}`,
//! 4. FFT each **row** (length-C transforms, applied recursively when C > R).
//!
//! The R-point column transforms come in the paper's two flavours:
//! [`BaileyVariant::Vector`] computes them with Cooley–Tukey butterflies
//! (optimal FLOPs, needs the FFT-mode interconnect), and
//! [`BaileyVariant::Gemm`] computes them as a dense R×R matrix multiply
//! (R/log₂R more FLOPs, but maps onto systolic hardware / tensor cores).

use super::{cooley_tukey, dft, is_pow2};
use crate::util::C64;
use std::f64::consts::PI;

/// How the R-point tile transforms are computed (paper §III-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaileyVariant {
    /// R-point tiles via Cooley–Tukey butterflies: O(L·log₂L) total FLOPs.
    Vector,
    /// R-point tiles via dense DFT matmul: O(L·R·log_R L) total FLOPs.
    Gemm,
}

/// Transform length-R slices with the selected tile algorithm.
fn tile_fft(variant: BaileyVariant, dft_mat: &[C64], x: &mut [C64]) {
    match variant {
        BaileyVariant::Vector => cooley_tukey::fft_in_place(x),
        BaileyVariant::Gemm => {
            let y = dft::dft_by_matmul(dft_mat, x);
            x.copy_from_slice(&y);
        }
    }
}

/// Bailey 4-step FFT of `x` with tile size `r`.
///
/// Requirements: `x.len()` and `r` are powers of two and `r ≤ x.len()`.
/// When the row length still exceeds `r` the row transforms recurse, so the
/// whole transform is built exclusively from R-point tiles — exactly the
/// hierarchical decomposition the paper maps onto PCUs.
pub fn bailey_fft(x: &[C64], r: usize, variant: BaileyVariant) -> Vec<C64> {
    let l = x.len();
    assert!(is_pow2(l), "bailey_fft: L={l} not a power of two");
    assert!(is_pow2(r) && r >= 2, "bailey_fft: R={r} not a power of two >= 2");
    let dft_mat = dft::dft_matrix(r);
    bailey_rec(x, r, variant, &dft_mat)
}

fn bailey_rec(x: &[C64], r: usize, variant: BaileyVariant, dft_mat: &[C64]) -> Vec<C64> {
    let l = x.len();
    if l <= r {
        // Base case: a single tile.
        let mut tile = x.to_vec();
        if l == r {
            tile_fft(variant, dft_mat, &mut tile);
        } else {
            // L smaller than the tile width: plain CT (degenerate input).
            cooley_tukey::fft_in_place(&mut tile);
        }
        return tile;
    }
    let c = l / r; // columns count: matrix is R rows x C cols, column-major in time
                   // x[n] with n = r_idx + R*c_idx  ==>  decimation: rows are strided segments.

    // Step 1+2: column FFTs. Column `ci` is the length-R sequence
    // x[ci], x[ci + C], ..., x[ci + (R-1)*C]  (stride C), per the DIT split
    // n = c_idx + C * r_idx. This is the standard 4-step indexing:
    //   X[k1 + R*k2] = Σ_{n2} e^{-2πi n2 k2 / C} · T[n2,k1]
    //   T[n2,k1]     = e^{-2πi n2 k1 / L} · Σ_{n1} x[n1*C + n2] e^{-2πi n1 k1 / R}
    let mut cols: Vec<Vec<C64>> = Vec::with_capacity(c);
    for n2 in 0..c {
        let mut col: Vec<C64> = (0..r).map(|n1| x[n1 * c + n2]).collect();
        tile_fft(variant, dft_mat, &mut col);
        cols.push(col);
    }

    // Step 3: twiddle scaling T[n2, k1] *= e^{-2πi·n2·k1/L}.
    for (n2, col) in cols.iter_mut().enumerate() {
        for (k1, v) in col.iter_mut().enumerate() {
            let ang = -2.0 * PI * ((n2 * k1) % l) as f64 / l as f64;
            *v = *v * C64::cis(ang);
        }
    }

    // Step 4: row FFTs (length C), recursing so rows are also tiled.
    let mut out = vec![C64::ZERO; l];
    for k1 in 0..r {
        let row: Vec<C64> = (0..c).map(|n2| cols[n2][k1]).collect();
        let row_f = bailey_rec(&row, r, variant, dft_mat);
        // Output index: X[k1 + R*k2].
        for (k2, v) in row_f.into_iter().enumerate() {
            out[k1 + r * k2] = v;
        }
    }
    out
}

/// Number of R-point tile transforms performed by the hierarchical Bailey
/// decomposition of an L-point FFT (used by the perf model and the PCU
/// mapping: each tile is one pass through a PCU).
pub fn tile_count(l: usize, r: usize) -> usize {
    if l <= r {
        return 1;
    }
    let c = l / r;
    // C column tiles + R recursive rows of length C.
    c + r * tile_count(c, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{dft::dft, fft, to_complex};
    use crate::util::complex::max_abs_diff_c;
    use crate::util::{prop, XorShift};

    fn rand_complex(rng: &mut XorShift, n: usize) -> Vec<C64> {
        (0..n)
            .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
            .collect()
    }

    #[test]
    fn vector_variant_matches_ct() {
        let mut rng = XorShift::new(31);
        for &(l, r) in &[(64usize, 4usize), (256, 16), (1024, 32), (4096, 32)] {
            let x = rand_complex(&mut rng, l);
            let got = bailey_fft(&x, r, BaileyVariant::Vector);
            let want = fft(&x);
            let d = max_abs_diff_c(&got, &want);
            assert!(d < 1e-8, "L={l} R={r}: diff={d}");
        }
    }

    #[test]
    fn gemm_variant_matches_ct() {
        let mut rng = XorShift::new(32);
        for &(l, r) in &[(64usize, 8usize), (512, 32), (2048, 32)] {
            let x = rand_complex(&mut rng, l);
            let got = bailey_fft(&x, r, BaileyVariant::Gemm);
            let want = fft(&x);
            let d = max_abs_diff_c(&got, &want);
            assert!(d < 1e-8, "L={l} R={r}: diff={d}");
        }
    }

    #[test]
    fn non_divisible_recursion_levels() {
        // L = 2^11, R = 32 = 2^5: log_R L is not an integer; the recursion
        // must still be exact because rows fall back to smaller tiles.
        let mut rng = XorShift::new(33);
        let x = rand_complex(&mut rng, 2048);
        let got = bailey_fft(&x, 32, BaileyVariant::Vector);
        let want = dft(&to_complex(&crate::fft::to_real(&x))); // not equal input; use fft
        let want_ct = fft(&x);
        let _ = want;
        assert!(max_abs_diff_c(&got, &want_ct) < 1e-8);
    }

    #[test]
    fn single_tile_base_case() {
        let mut rng = XorShift::new(34);
        let x = rand_complex(&mut rng, 32);
        let got = bailey_fft(&x, 32, BaileyVariant::Gemm);
        assert!(max_abs_diff_c(&got, &fft(&x)) < 1e-9);
    }

    #[test]
    fn input_shorter_than_tile() {
        let mut rng = XorShift::new(35);
        let x = rand_complex(&mut rng, 8);
        let got = bailey_fft(&x, 32, BaileyVariant::Vector);
        assert!(max_abs_diff_c(&got, &fft(&x)) < 1e-10);
    }

    #[test]
    fn tile_count_single_level() {
        // L = R^2: C = R columns + R rows of length R -> R + R*1 = 2R tiles.
        assert_eq!(tile_count(1024, 32), 32 + 32);
        assert_eq!(tile_count(32, 32), 1);
    }

    #[test]
    fn prop_bailey_matches_fft() {
        prop::quick(
            "bailey == fft",
            |rng| {
                let l = 1usize << rng.range(5, 12);
                let r = 1usize << rng.range(2, 5);
                let xs = rng.vec(2 * l, -1.0, 1.0);
                (l, r, xs)
            },
            prop::no_shrink,
            |(l, r, xs)| {
                let x: Vec<C64> = (0..*l)
                    .map(|i| C64::new(xs[2 * i], xs[2 * i + 1]))
                    .collect();
                for variant in [BaileyVariant::Vector, BaileyVariant::Gemm] {
                    let d = max_abs_diff_c(&bailey_fft(&x, *r, variant), &fft(&x));
                    if d > 1e-7 {
                        return Err(format!("L={l} R={r} {variant:?}: diff {d}"));
                    }
                }
                Ok(())
            },
        );
    }
}
