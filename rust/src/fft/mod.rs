//! FFT algorithm substrate (paper §III-A).
//!
//! Implements every FFT variant the paper discusses, with real numerics used
//! both as correctness oracles for the Pallas kernels (L1) and as functional
//! golden models for the cycle-level PCU simulator:
//!
//! * [`dft`] — naive O(N²) discrete Fourier transform (the ground truth).
//! * [`cooley_tukey`] — radix-2 Cooley–Tukey FFT, the classic
//!   O(N log₂ N) algorithm with variable-distance butterflies.
//! * [`bailey`] — Bailey's 4-step FFT: reshape to 2-D, column FFTs, twiddle
//!   scaling, row FFTs. Two variants per the paper:
//!   **Vector-FFT** (R-point tiles via Cooley–Tukey, optimal
//!   O(N log₂ N) FLOPs, needs butterfly interconnects) and
//!   **GEMM-FFT** (R-point tiles via dense DFT matrix multiplication,
//!   O(N·R·log_R N) FLOPs, maps onto systolic/tensor-core hardware).
//! * [`conv`] — FFT-based (circular and linear) convolution, the Hyena
//!   decoder's core operator.
//! * [`plan`] — the hot-path engine: [`FftPlan`] (cached bit-reversal +
//!   twiddle tables, zero trig and zero allocation in steady state,
//!   cache-blocked butterfly traversal above [`plan::FFT_BLOCK_POINTS`]),
//!   [`SplitRadixFftPlan`] (conjugate-pair split-radix, ~25% fewer
//!   butterfly flops, auto-selected for inner transforms at
//!   [`plan::SPLIT_RADIX_MIN_POINTS`] and above), [`RealFftPlan`]
//!   (real-input transforms via the N/2-point packing trick, ~half the
//!   flops on real signals, engine-routed per [`FftEngine`]), and
//!   [`ConvPlan`] (the allocation-free convolution engine behind
//!   [`fft_conv_circular`] / [`fft_conv_linear`], served from a bounded
//!   per-thread [`plan::PlanCache`] backed by a process-wide master
//!   cache).
//!
//! FLOP accounting follows the paper's convention (§III-A): a Vector-FFT of
//! length L costs `5·L·log₂L`, a GEMM-FFT costs `5·L·R·log_R L` — i.e. the
//! GEMM variant is exactly `R/log₂R`× more work (6.4× at R=32). These
//! constants feed `figures::hyena` and must not change with engine
//! optimizations; the planned real-input engine's own accounting is
//! [`conv::fftconv_flops_rfft`].
//!
//! **When the mapper picks which variant.** The Hyena workload builder
//! (`crate::workloads::hyena_decoder`) takes the [`BaileyVariant`] as the
//! design point: `Vector` kernels run spatially only on an RDU with the
//! FFT-mode butterfly interconnect (`crate::arch::RduConfig::fft_mode`) and
//! fall back to serialized stage-0 execution on a baseline chip, while
//! `Gemm` kernels map onto the baseline systolic mode everywhere at
//! `R/log₂R`× the FLOPs — exactly the Fig. 7 design space (Design 2 vs 3
//! vs 4). The DFModel mapper then allocates PCUs to whichever kernels the
//! chosen variant emits; it never switches variants itself. Past one chip,
//! [`crate::shard::sharded_bailey_fft`] distributes the 4-step
//! decomposition row/column-wise with one all-to-all transpose.

pub mod bailey;
pub mod conv;
pub mod cooley_tukey;
pub mod dft;
pub mod plan;
pub mod simd;

pub use bailey::{bailey_fft, BaileyVariant};
pub use conv::{
    fft_conv_circular, fft_conv_circular_naive, fft_conv_linear, fft_conv_linear_channels,
    fft_conv_linear_naive, fftconv_flops_rfft,
};
pub use cooley_tukey::{fft, ifft};
pub use dft::dft;
pub use plan::{
    with_conv_plan, ConvPlan, CplxConvPlan, FftEngine, FftPlan, PlanCache, RealFftPlan,
    SplitRadixFftPlan,
};

use crate::util::C64;

/// FLOPs of an L-point Vector-FFT (Cooley–Tukey butterflies): `5·L·log₂L`.
///
/// Paper convention: each of the `L/2·log₂L` butterflies is one complex
/// multiply (6 flops) + two complex adds (4 flops) = 10 flops.
pub fn vector_fft_flops(l: usize) -> f64 {
    let l = l as f64;
    5.0 * l * l.log2()
}

/// FLOPs of an L-point GEMM-FFT built from R-point dense DFTs:
/// `5·L·R·log_R L` — `R/log₂R`× the Vector-FFT count (paper: ~6.4× at R=32).
pub fn gemm_fft_flops(l: usize, r: usize) -> f64 {
    let (lf, rf) = (l as f64, r as f64);
    5.0 * lf * rf * (lf.log2() / rf.log2())
}

/// Check `n` is a power of two (required by the radix-2 substrate).
pub fn is_pow2(n: usize) -> bool {
    n != 0 && n & (n - 1) == 0
}

/// Convert a real slice to complex.
pub fn to_complex(xs: &[f64]) -> Vec<C64> {
    xs.iter().map(|&x| C64::real(x)).collect()
}

/// Real parts of a complex slice (imaginary parts must be numerically zero
/// for the conversion to be meaningful; not enforced here).
pub fn to_real(xs: &[C64]) -> Vec<f64> {
    xs.iter().map(|z| z.re).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flop_ratio_matches_paper() {
        // Paper §III-A: GEMM-FFT is ~6.4x more FLOPs at R=32.
        let l = 1 << 20;
        let ratio = gemm_fft_flops(l, 32) / vector_fft_flops(l);
        assert!((ratio - 6.4).abs() < 1e-9, "ratio={ratio}");
    }

    #[test]
    fn pow2_detection() {
        assert!(is_pow2(1));
        assert!(is_pow2(1 << 20));
        assert!(!is_pow2(0));
        assert!(!is_pow2(24));
    }

    #[test]
    fn complex_roundtrip() {
        let xs = [1.0, -2.0, 3.5];
        assert_eq!(to_real(&to_complex(&xs)), xs.to_vec());
    }
}
