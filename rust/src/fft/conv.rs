//! FFT-based convolution — the Hyena decoder's core operator (paper Fig. 3B).
//!
//! Each Hyena "attention-replacement" computes `y = iFFT(FFT(u) ⊙ FFT(k))`.
//! These functions are the golden model for the Pallas `fftconv` kernel and
//! for the PCU-simulator FFT programs.
//!
//! Since the hot-path engine pass, [`fft_conv_circular`] and
//! [`fft_conv_linear`] route through the **planned real-input** pipeline
//! ([`super::plan`]): two half-size transforms over cached twiddle/
//! bit-reversal tables and plan-held scratch, instead of three full-size
//! complex transforms with per-call trig and allocation. The pre-plan
//! implementations are kept verbatim as [`fft_conv_circular_naive`] /
//! [`fft_conv_linear_naive`] — they are the baseline the `perf_micro`
//! bench gates against (planned real must stay ≥1.5× faster at L=4k) and
//! an independent numerical oracle for the planned path.
//!
//! [`fft_conv_linear_channels`] fans the per-channel convolutions of one
//! Hyena conv module across a [`crate::runtime::WorkerPool`] with
//! self-scheduling claim order (`map_stealing`); channels are independent
//! and the result is bit-identical to the serial per-channel loop. Plan
//! reuse under pooling: since PR 9 the pool is a facade over the resident
//! `crate::runtime::WorkerTeam`, so a worker's thread-local plan cache
//! survives across calls — its *first ever* conv at a length clones the
//! plan out of the process-wide master cache (a memcpy — see
//! [`super::plan::with_conv_plan`]) and every later batch at that length
//! finds it already warm (one of the sticky-state wins the
//! `team_resident_vs_spawn` bench gate prices).

use super::plan::with_conv_plan;
use super::{cooley_tukey::{fft, ifft}, is_pow2, to_complex, to_real};
use crate::runtime::WorkerPool;
use crate::util::C64;

/// Circular convolution of two equal-length real signals via the planned
/// real-input FFT pipeline.
///
/// `y[n] = Σ_m u[m]·k[(n-m) mod N]`; N must be a power of two.
pub fn fft_conv_circular(u: &[f64], k: &[f64]) -> Vec<f64> {
    assert_eq!(u.len(), k.len(), "fft_conv_circular: length mismatch");
    assert!(is_pow2(u.len()), "fft_conv_circular: length must be 2^k");
    if u.len() == 1 {
        return vec![u[0] * k[0]];
    }
    with_conv_plan(u.len(), |p| p.circular(u, k))
}

/// Causal/linear convolution of a length-L signal with a length-L filter,
/// truncated to the first L outputs (Hyena's long-convolution semantics:
/// the transform is zero-padded to 2L to avoid wrap-around), via the
/// planned real-input pipeline.
pub fn fft_conv_linear(u: &[f64], k: &[f64]) -> Vec<f64> {
    assert_eq!(u.len(), k.len(), "fft_conv_linear: length mismatch");
    let l = u.len();
    if l == 0 {
        return Vec::new();
    }
    let n = (2 * l).next_power_of_two();
    with_conv_plan(n, |p| p.linear(u, k))
}

/// Per-channel linear convolutions fanned out over the worker pool — the
/// golden model for one Hyena conv module across its D channels. Channel
/// `i` convolves `us[i]` with `ks[i]`; workers self-schedule channels via
/// [`WorkerPool::map_stealing`] (each worker clones one plan out of the
/// master cache and reuses it for every channel it claims), so no worker
/// holds a long contiguous tail while others idle and the output stays
/// **bit-identical** to the serial per-channel loop.
pub fn fft_conv_linear_channels(
    us: &[Vec<f64>],
    ks: &[Vec<f64>],
    pool: &WorkerPool,
) -> Vec<Vec<f64>> {
    assert_eq!(us.len(), ks.len(), "fft_conv_linear_channels: channel count mismatch");
    pool.map_stealing(us.len(), |i| fft_conv_linear(&us[i], &ks[i]))
}

/// The pre-plan circular convolution: three full-size complex transforms
/// with per-call twiddle trig and fresh allocations. Kept as the perf
/// baseline (`perf_micro` gates planned-real ≥1.5× faster at L=4k) and as
/// an independent oracle for the planned path.
pub fn fft_conv_circular_naive(u: &[f64], k: &[f64]) -> Vec<f64> {
    assert_eq!(u.len(), k.len(), "fft_conv_circular: length mismatch");
    assert!(is_pow2(u.len()), "fft_conv_circular: length must be 2^k");
    let fu = fft(&to_complex(u));
    let fk = fft(&to_complex(k));
    let prod: Vec<C64> = fu.iter().zip(&fk).map(|(&a, &b)| a * b).collect();
    to_real(&ifft(&prod))
}

/// The pre-plan linear convolution (zero-pad to 2L, naive complex circular
/// conv, truncate). See [`fft_conv_circular_naive`].
pub fn fft_conv_linear_naive(u: &[f64], k: &[f64]) -> Vec<f64> {
    assert_eq!(u.len(), k.len(), "fft_conv_linear: length mismatch");
    let l = u.len();
    let n = (2 * l).next_power_of_two();
    let mut up = vec![0.0; n];
    let mut kp = vec![0.0; n];
    up[..l].copy_from_slice(u);
    kp[..l].copy_from_slice(k);
    let out = fft_conv_circular_naive(&up, &kp);
    out[..l].to_vec()
}

/// Direct O(N²) circular convolution (oracle).
pub fn direct_conv_circular(u: &[f64], k: &[f64]) -> Vec<f64> {
    let n = u.len();
    assert_eq!(n, k.len());
    let mut y = vec![0.0; n];
    for (out_idx, yo) in y.iter_mut().enumerate() {
        let mut acc = 0.0;
        for m in 0..n {
            acc += u[m] * k[(out_idx + n - m) % n];
        }
        *yo = acc;
    }
    y
}

/// Direct O(N²) causal linear convolution, truncated to N outputs (oracle).
pub fn direct_conv_linear(u: &[f64], k: &[f64]) -> Vec<f64> {
    let n = u.len();
    assert_eq!(n, k.len());
    let mut y = vec![0.0; n];
    for (out_idx, yo) in y.iter_mut().enumerate() {
        let mut acc = 0.0;
        for m in 0..=out_idx {
            acc += u[m] * k[out_idx - m];
        }
        *yo = acc;
    }
    y
}

/// FLOPs of a Hyena FFT-convolution over L points (**paper convention**,
/// §III-A): three L'-point transforms (two forward + one inverse, L' = 2L
/// padded) plus the elementwise complex product. This is what
/// `figures::hyena` and the workload graphs charge — it deliberately does
/// *not* assume the real-input packing trick, because the paper's design
/// points don't. The engine's own rfft accounting is
/// [`fftconv_flops_rfft`].
pub fn fftconv_flops(l: usize, variant: super::BaileyVariant, r: usize) -> f64 {
    let n = (2 * l).next_power_of_two();
    let fft_cost = match variant {
        super::BaileyVariant::Vector => super::vector_fft_flops(n),
        super::BaileyVariant::Gemm => super::gemm_fft_flops(n, r),
    };
    3.0 * fft_cost + 6.0 * n as f64
}

/// FLOPs of the **planned real-input** convolution over L points — the
/// engine's own accounting, *not* the paper convention (see
/// [`fftconv_flops`]): three (N/2)-point complex transforms (two forward,
/// one inverse — each a real transform via the packing trick), pack/unpack
/// butterflies (~8 flops per bin at each real boundary), and the
/// half-spectrum product — roughly half of [`fftconv_flops`].
pub fn fftconv_flops_rfft(l: usize) -> f64 {
    let n = (2 * l).next_power_of_two();
    let half = n / 2;
    // 3 half-size transforms (2 forward + 1 inverse), O(N) pack/unpack at
    // each real boundary, 6-flop complex products over N/2+1 bins.
    3.0 * super::vector_fft_flops(half) + 24.0 * half as f64 + 6.0 * (half + 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{max_abs_diff, prop, XorShift};

    #[test]
    fn circular_matches_direct() {
        let mut rng = XorShift::new(41);
        let u = rng.vec(64, -1.0, 1.0);
        let k = rng.vec(64, -1.0, 1.0);
        let d = max_abs_diff(&fft_conv_circular(&u, &k), &direct_conv_circular(&u, &k));
        assert!(d < 1e-10, "diff={d}");
    }

    #[test]
    fn planned_matches_naive_within_fft_rounding() {
        // The planned real path and the pre-plan complex path are different
        // factorizations of the same transform: equal to ~1e-11, far inside
        // the 1e-9 acceptance budget.
        let mut rng = XorShift::new(44);
        for n in [2usize, 8, 64, 1024, 4096] {
            let u = rng.vec(n, -1.0, 1.0);
            let k = rng.vec(n, -1.0, 1.0);
            let d = max_abs_diff(&fft_conv_circular(&u, &k), &fft_conv_circular_naive(&u, &k));
            assert!(d < 1e-9, "n={n}: diff={d}");
        }
    }

    #[test]
    fn linear_matches_direct() {
        let mut rng = XorShift::new(42);
        let u = rng.vec(100, -1.0, 1.0); // deliberately non-pow2
        let k = rng.vec(100, -1.0, 1.0);
        let d = max_abs_diff(&fft_conv_linear(&u, &k), &direct_conv_linear(&u, &k));
        assert!(d < 1e-9, "diff={d}");
    }

    #[test]
    fn identity_filter_is_noop() {
        let mut rng = XorShift::new(43);
        let u = rng.vec(32, -1.0, 1.0);
        let mut k = vec![0.0; 32];
        k[0] = 1.0;
        let y = fft_conv_linear(&u, &k);
        assert!(max_abs_diff(&y, &u) < 1e-11);
    }

    #[test]
    fn shift_filter_delays() {
        let mut u = vec![0.0; 16];
        u[3] = 1.0;
        let mut k = vec![0.0; 16];
        k[2] = 1.0;
        let y = fft_conv_linear(&u, &k);
        let mut want = vec![0.0; 16];
        want[5] = 1.0;
        assert!(max_abs_diff(&y, &want) < 1e-11);
    }

    #[test]
    fn pooled_channels_bit_identical_to_serial() {
        let mut rng = XorShift::new(45);
        let d = 8;
        for l in [100usize, 1024] {
            let us: Vec<Vec<f64>> = (0..d).map(|_| rng.vec(l, -1.0, 1.0)).collect();
            let ks: Vec<Vec<f64>> = (0..d).map(|_| rng.vec(l, -1.0, 1.0)).collect();
            let serial: Vec<Vec<f64>> =
                us.iter().zip(&ks).map(|(u, k)| fft_conv_linear(u, k)).collect();
            let pooled = fft_conv_linear_channels(&us, &ks, &WorkerPool::new(3));
            assert_eq!(pooled, serial, "L={l}: pooling must not change a single bit");
        }
    }

    #[test]
    fn fftconv_flop_counts_scale() {
        // Vector variant ~ 15 N log2 N; GEMM variant = R/log2R times more FFT work.
        let l = 1 << 16;
        let v = fftconv_flops(l, crate::fft::BaileyVariant::Vector, 32);
        let g = fftconv_flops(l, crate::fft::BaileyVariant::Gemm, 32);
        assert!(g / v > 6.0 && g / v < 6.5, "ratio={}", g / v);
    }

    #[test]
    fn rfft_flops_are_roughly_half_the_paper_convention() {
        // Half-size transforms: ~(log N − 1)/(2 log N) of the complex-path
        // transform flops, so the ratio sits a bit under 0.5 and approaches
        // it as L grows.
        for l in [1usize << 12, 1 << 16, 1 << 20] {
            let ratio =
                fftconv_flops_rfft(l) / fftconv_flops(l, crate::fft::BaileyVariant::Vector, 32);
            assert!(ratio > 0.35 && ratio < 0.55, "L={l}: ratio={ratio}");
        }
    }

    #[test]
    fn prop_linear_conv_matches_direct() {
        prop::quick(
            "fftconv == direct",
            |rng| {
                let n = rng.range(1, 200);
                (rng.vec(n, -1.0, 1.0), rng.vec(n, -1.0, 1.0))
            },
            prop::no_shrink,
            |(u, k)| {
                let d = max_abs_diff(&fft_conv_linear(u, k), &direct_conv_linear(u, k));
                if d < 1e-8 {
                    Ok(())
                } else {
                    Err(format!("diff {d}"))
                }
            },
        );
    }
}
