//! FFT-based convolution — the Hyena decoder's core operator (paper Fig. 3B).
//!
//! Each Hyena "attention-replacement" computes `y = iFFT(FFT(u) ⊙ FFT(k))`:
//! two forward transforms, an elementwise (gating) multiply in frequency
//! domain, and one inverse transform. These functions are the golden model
//! for the Pallas `fftconv` kernel and for the PCU-simulator FFT programs.

use super::{cooley_tukey::{fft, ifft}, is_pow2, to_complex, to_real};
use crate::util::C64;

/// Circular convolution of two equal-length real signals via FFT.
///
/// `y[n] = Σ_m u[m]·k[(n-m) mod N]`; N must be a power of two.
pub fn fft_conv_circular(u: &[f64], k: &[f64]) -> Vec<f64> {
    assert_eq!(u.len(), k.len(), "fft_conv_circular: length mismatch");
    assert!(is_pow2(u.len()), "fft_conv_circular: length must be 2^k");
    let fu = fft(&to_complex(u));
    let fk = fft(&to_complex(k));
    let prod: Vec<C64> = fu.iter().zip(&fk).map(|(&a, &b)| a * b).collect();
    to_real(&ifft(&prod))
}

/// Causal/linear convolution of a length-L signal with a length-L filter,
/// truncated to the first L outputs (Hyena's long-convolution semantics:
/// the FFT is zero-padded to 2L to avoid wrap-around).
pub fn fft_conv_linear(u: &[f64], k: &[f64]) -> Vec<f64> {
    assert_eq!(u.len(), k.len(), "fft_conv_linear: length mismatch");
    let l = u.len();
    let n = (2 * l).next_power_of_two();
    let mut up = vec![0.0; n];
    let mut kp = vec![0.0; n];
    up[..l].copy_from_slice(u);
    kp[..l].copy_from_slice(k);
    let out = fft_conv_circular(&up, &kp);
    out[..l].to_vec()
}

/// Direct O(N²) circular convolution (oracle).
pub fn direct_conv_circular(u: &[f64], k: &[f64]) -> Vec<f64> {
    let n = u.len();
    assert_eq!(n, k.len());
    let mut y = vec![0.0; n];
    for (out_idx, yo) in y.iter_mut().enumerate() {
        let mut acc = 0.0;
        for m in 0..n {
            acc += u[m] * k[(out_idx + n - m) % n];
        }
        *yo = acc;
    }
    y
}

/// Direct O(N²) causal linear convolution, truncated to N outputs (oracle).
pub fn direct_conv_linear(u: &[f64], k: &[f64]) -> Vec<f64> {
    let n = u.len();
    assert_eq!(n, k.len());
    let mut y = vec![0.0; n];
    for (out_idx, yo) in y.iter_mut().enumerate() {
        let mut acc = 0.0;
        for m in 0..=out_idx {
            acc += u[m] * k[out_idx - m];
        }
        *yo = acc;
    }
    y
}

/// FLOPs of a Hyena FFT-convolution over L points (paper convention):
/// three L'-point transforms (two forward + one inverse, L' = 2L padded)
/// plus the elementwise complex product.
pub fn fftconv_flops(l: usize, variant: super::BaileyVariant, r: usize) -> f64 {
    let n = (2 * l).next_power_of_two();
    let fft_cost = match variant {
        super::BaileyVariant::Vector => super::vector_fft_flops(n),
        super::BaileyVariant::Gemm => super::gemm_fft_flops(n, r),
    };
    3.0 * fft_cost + 6.0 * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{max_abs_diff, prop, XorShift};

    #[test]
    fn circular_matches_direct() {
        let mut rng = XorShift::new(41);
        let u = rng.vec(64, -1.0, 1.0);
        let k = rng.vec(64, -1.0, 1.0);
        let d = max_abs_diff(&fft_conv_circular(&u, &k), &direct_conv_circular(&u, &k));
        assert!(d < 1e-10, "diff={d}");
    }

    #[test]
    fn linear_matches_direct() {
        let mut rng = XorShift::new(42);
        let u = rng.vec(100, -1.0, 1.0); // deliberately non-pow2
        let k = rng.vec(100, -1.0, 1.0);
        let d = max_abs_diff(&fft_conv_linear(&u, &k), &direct_conv_linear(&u, &k));
        assert!(d < 1e-9, "diff={d}");
    }

    #[test]
    fn identity_filter_is_noop() {
        let mut rng = XorShift::new(43);
        let u = rng.vec(32, -1.0, 1.0);
        let mut k = vec![0.0; 32];
        k[0] = 1.0;
        let y = fft_conv_linear(&u, &k);
        assert!(max_abs_diff(&y, &u) < 1e-11);
    }

    #[test]
    fn shift_filter_delays() {
        let mut u = vec![0.0; 16];
        u[3] = 1.0;
        let mut k = vec![0.0; 16];
        k[2] = 1.0;
        let y = fft_conv_linear(&u, &k);
        let mut want = vec![0.0; 16];
        want[5] = 1.0;
        assert!(max_abs_diff(&y, &want) < 1e-11);
    }

    #[test]
    fn fftconv_flop_counts_scale() {
        // Vector variant ~ 15 N log2 N; GEMM variant = R/log2R times more FFT work.
        let l = 1 << 16;
        let v = fftconv_flops(l, crate::fft::BaileyVariant::Vector, 32);
        let g = fftconv_flops(l, crate::fft::BaileyVariant::Gemm, 32);
        assert!(g / v > 6.0 && g / v < 6.5, "ratio={}", g / v);
    }

    #[test]
    fn prop_linear_conv_matches_direct() {
        prop::quick(
            "fftconv == direct",
            |rng| {
                let n = rng.range(1, 200);
                (rng.vec(n, -1.0, 1.0), rng.vec(n, -1.0, 1.0))
            },
            prop::no_shrink,
            |(u, k)| {
                let d = max_abs_diff(&fft_conv_linear(u, k), &direct_conv_linear(u, k));
                if d < 1e-8 {
                    Ok(())
                } else {
                    Err(format!("diff {d}"))
                }
            },
        );
    }
}
