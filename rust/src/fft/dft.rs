//! Naive O(N²) discrete Fourier transform — the numerical ground truth every
//! FFT variant (and the Pallas kernels, transitively) is checked against.

use crate::util::C64;
use std::f64::consts::PI;

/// Forward DFT: `X[k] = Σ_n x[n]·e^{-2πi·kn/N}`.
///
/// O(N²); intended for oracle use at small-to-moderate N.
pub fn dft(x: &[C64]) -> Vec<C64> {
    let n = x.len();
    let mut out = vec![C64::ZERO; n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = C64::ZERO;
        for (j, &xj) in x.iter().enumerate() {
            // e^{-2πi·kj/n}; compute the angle mod n to bound error at large kj.
            let angle = -2.0 * PI * ((k * j) % n) as f64 / n as f64;
            acc += xj * C64::cis(angle);
        }
        *o = acc;
    }
    out
}

/// Inverse DFT: `x[n] = (1/N)·Σ_k X[k]·e^{+2πi·kn/N}`.
pub fn idft(x: &[C64]) -> Vec<C64> {
    let n = x.len();
    let mut out = vec![C64::ZERO; n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = C64::ZERO;
        for (j, &xj) in x.iter().enumerate() {
            let angle = 2.0 * PI * ((k * j) % n) as f64 / n as f64;
            acc += xj * C64::cis(angle);
        }
        *o = acc.scale(1.0 / n as f64);
    }
    out
}

/// The dense R×R DFT matrix, row-major — this is exactly the operand the
/// GEMM-FFT variant feeds to a systolic array / tensor core.
pub fn dft_matrix(r: usize) -> Vec<C64> {
    let mut m = vec![C64::ZERO; r * r];
    for k in 0..r {
        for j in 0..r {
            m[k * r + j] = C64::cis(-2.0 * PI * ((k * j) % r) as f64 / r as f64);
        }
    }
    m
}

/// Apply the dense DFT matrix to a vector: the GEMM formulation of an
/// R-point Fourier transform (O(R²) complex MACs).
pub fn dft_by_matmul(m: &[C64], x: &[C64]) -> Vec<C64> {
    let r = x.len();
    assert_eq!(m.len(), r * r, "dft_by_matmul: matrix/vector size mismatch");
    let mut out = vec![C64::ZERO; r];
    for k in 0..r {
        let mut acc = C64::ZERO;
        for j in 0..r {
            acc += m[k * r + j] * x[j];
        }
        out[k] = acc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::to_complex;
    use crate::util::complex::max_abs_diff_c;
    use crate::util::XorShift;

    #[test]
    fn dft_of_impulse_is_flat() {
        let mut x = vec![C64::ZERO; 8];
        x[0] = C64::ONE;
        let y = dft(&x);
        for z in y {
            assert!((z - C64::ONE).abs() < 1e-12);
        }
    }

    #[test]
    fn dft_of_constant_is_impulse() {
        let x = vec![C64::ONE; 8];
        let y = dft(&x);
        assert!((y[0] - C64::real(8.0)).abs() < 1e-12);
        for z in &y[1..] {
            assert!(z.abs() < 1e-12);
        }
    }

    #[test]
    fn idft_inverts_dft() {
        let mut rng = XorShift::new(5);
        let x = to_complex(&rng.vec(16, -1.0, 1.0));
        let rt = idft(&dft(&x));
        assert!(max_abs_diff_c(&x, &rt) < 1e-12);
    }

    #[test]
    fn dft_matrix_matches_direct_dft() {
        let mut rng = XorShift::new(6);
        let x = to_complex(&rng.vec(32, -1.0, 1.0));
        let m = dft_matrix(32);
        let via_matmul = dft_by_matmul(&m, &x);
        let direct = dft(&x);
        assert!(max_abs_diff_c(&via_matmul, &direct) < 1e-10);
    }

    #[test]
    fn parseval_energy_preserved() {
        let mut rng = XorShift::new(7);
        let x = to_complex(&rng.vec(64, -1.0, 1.0));
        let y = dft(&x);
        let ex: f64 = x.iter().map(|z| z.abs().powi(2)).sum();
        let ey: f64 = y.iter().map(|z| z.abs().powi(2)).sum::<f64>() / 64.0;
        assert!((ex - ey).abs() < 1e-9, "ex={ex} ey={ey}");
    }

    #[test]
    fn linearity() {
        let mut rng = XorShift::new(8);
        let a = to_complex(&rng.vec(16, -1.0, 1.0));
        let b = to_complex(&rng.vec(16, -1.0, 1.0));
        let sum: Vec<C64> = a.iter().zip(&b).map(|(&x, &y)| x + y).collect();
        let lhs = dft(&sum);
        let (da, db) = (dft(&a), dft(&b));
        let rhs: Vec<C64> = da.iter().zip(&db).map(|(&x, &y)| x + y).collect();
        assert!(max_abs_diff_c(&lhs, &rhs) < 1e-10);
    }
}
