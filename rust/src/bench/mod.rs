//! Mini benchmark harness — a criterion-flavoured stand-in (the `criterion`
//! crate is not vendored in the offline image).
//!
//! Usage in a `harness = false` bench target:
//!
//! ```no_run
//! use ssm_rdu::bench::Bencher;
//! let mut b = Bencher::from_env("fig7_hyena");
//! b.bench("map attention L=1M", || { /* work */ });
//! b.finish();
//! ```
//!
//! Each benchmark is warmed up, then timed over enough iterations to cover a
//! target measurement window; mean / stddev / min are reported. `--quick`
//! (or env `SSM_RDU_BENCH_QUICK=1`) shrinks the window for CI runs.

use std::time::{Duration, Instant};

/// One benchmark's statistics, in seconds.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Benchmark label.
    pub name: String,
    /// Number of timed iterations.
    pub iters: u64,
    /// Mean wall time per iteration.
    pub mean: f64,
    /// Sample standard deviation per iteration.
    pub stddev: f64,
    /// Fastest iteration.
    pub min: f64,
}

impl Stats {
    fn fmt_line(&self) -> String {
        format!(
            "{:<48} {:>12}/iter  (min {:>12}, sd {:>10}, n={})",
            self.name,
            crate::util::fmt_time(self.mean),
            crate::util::fmt_time(self.min),
            crate::util::fmt_time(self.stddev),
            self.iters
        )
    }
}

/// Collects and prints benchmark results for one bench target.
pub struct Bencher {
    group: String,
    warmup: Duration,
    measure: Duration,
    results: Vec<Stats>,
}

impl Bencher {
    /// Create a bencher with explicit windows.
    pub fn new(group: &str, warmup: Duration, measure: Duration) -> Self {
        println!("\n### bench group: {group}\n");
        Self {
            group: group.to_string(),
            warmup,
            measure,
            results: Vec::new(),
        }
    }

    /// Create from the environment: honours `--quick` in argv and
    /// `SSM_RDU_BENCH_QUICK` for short CI runs.
    pub fn from_env(group: &str) -> Self {
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("SSM_RDU_BENCH_QUICK").is_ok();
        if quick {
            Self::new(group, Duration::from_millis(20), Duration::from_millis(100))
        } else {
            Self::new(group, Duration::from_millis(200), Duration::from_millis(1000))
        }
    }

    /// Time a closure. The closure should perform one logical iteration and
    /// return a value (returned values are black-boxed to defeat DCE).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Stats {
        // Warmup, also estimates per-iter cost.
        let wstart = Instant::now();
        let mut witers: u64 = 0;
        while wstart.elapsed() < self.warmup {
            black_box(f());
            witers += 1;
        }
        let est = wstart.elapsed().as_secs_f64() / witers.max(1) as f64;
        let target_iters =
            ((self.measure.as_secs_f64() / est.max(1e-9)).ceil() as u64).clamp(5, 5_000_000);

        // Timed runs: collect per-batch samples to get a stddev without
        // timing overhead dominating sub-microsecond bodies.
        let batches = 10u64.min(target_iters);
        let per_batch = (target_iters / batches).max(1);
        let mut samples = Vec::with_capacity(batches as usize);
        for _ in 0..batches {
            let t0 = Instant::now();
            for _ in 0..per_batch {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / per_batch as f64);
        }
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = if samples.len() > 1 {
            samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / (n - 1.0)
        } else {
            0.0
        };
        let stats = Stats {
            name: name.to_string(),
            iters: batches * per_batch,
            mean,
            stddev: var.sqrt(),
            min: samples.iter().copied().fold(f64::INFINITY, f64::min),
        };
        println!("{}", stats.fmt_line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Run a closure once (for report-style "benches" that print a paper
    /// table rather than timing a hot loop) while still recording wall time.
    pub fn report<T, F: FnOnce() -> T>(&mut self, name: &str, f: F) -> T {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<48} {:>12}  (one-shot report)",
            name,
            crate::util::fmt_time(dt)
        );
        self.results.push(Stats {
            name: name.to_string(),
            iters: 1,
            mean: dt,
            stddev: 0.0,
            min: dt,
        });
        out
    }

    /// Access collected results.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Print the closing summary.
    pub fn finish(self) {
        println!(
            "\n### {}: {} benchmark(s) complete\n",
            self.group,
            self.results.len()
        );
    }
}

/// Opaque value sink to prevent the optimizer from deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher::new(
            "test",
            Duration::from_millis(5),
            Duration::from_millis(20),
        );
        let s = b.bench("noop-ish", || 1 + 1).clone();
        assert!(s.mean >= 0.0);
        assert!(s.min <= s.mean * 1.5 + 1e-9);
        assert!(s.iters >= 5);
        b.finish();
    }

    #[test]
    fn report_runs_once() {
        let mut b = Bencher::new(
            "test",
            Duration::from_millis(1),
            Duration::from_millis(1),
        );
        let mut count = 0;
        b.report("one-shot", || count += 1);
        assert_eq!(count, 1);
        assert_eq!(b.results()[0].iters, 1);
    }
}
