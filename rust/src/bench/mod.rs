//! Mini benchmark harness — a criterion-flavoured stand-in (the `criterion`
//! crate is not vendored in the offline image).
//!
//! Usage in a `harness = false` bench target:
//!
//! ```no_run
//! use ssm_rdu::bench::Bencher;
//! let mut b = Bencher::from_env("fig7_hyena");
//! b.bench("map attention L=1M", || { /* work */ });
//! b.finish();
//! ```
//!
//! Each benchmark is warmed up, then timed over enough iterations to cover a
//! target measurement window; mean / stddev / min are reported. `--quick`
//! (or env `SSM_RDU_BENCH_QUICK=1`) shrinks the window for CI runs.
//!
//! ## Machine-readable output
//!
//! [`Bencher::finish`] also emits the run as JSON when asked: pass `--json`
//! (default path `BENCH_<group>.json` in the **workspace root**) or
//! `--json=PATH`, or set `SSM_RDU_BENCH_JSON` (`1` → default path,
//! anything else → that path). Relative paths resolve against the
//! workspace root, not the invoking cwd — `cargo bench` happens to run
//! benches from the workspace root, but direct `target/release/deps/...`
//! invocations and IDE runners don't, and the perf-trajectory tooling
//! globs `BENCH_*.json` at the repo root. Besides the wall-time stats,
//! benches can attach *model-derived* scalars with [`Bencher::metric`] —
//! the `fusion` and `perf_micro` benches record DFModel latencies and
//! planned-vs-naive speedups this way, seeding the repo's `BENCH_*.json`
//! perf trajectory that CI archives and gates on.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Minimum timed iterations per benchmark, however slow one iteration is.
/// The perf gates ratchet on `min_s`; a floor keeps that minimum a real
/// order statistic instead of a one-shot sample.
pub const MIN_TIMED_ITERS: u64 = 20;

/// One benchmark's statistics, in seconds.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Benchmark label.
    pub name: String,
    /// Number of timed iterations.
    pub iters: u64,
    /// Mean wall time per iteration.
    pub mean: f64,
    /// Sample standard deviation per iteration.
    pub stddev: f64,
    /// Fastest iteration.
    pub min: f64,
}

impl Stats {
    fn fmt_line(&self) -> String {
        format!(
            "{:<48} {:>12}/iter  (min {:>12}, sd {:>10}, n={})",
            self.name,
            crate::util::fmt_time(self.mean),
            crate::util::fmt_time(self.min),
            crate::util::fmt_time(self.stddev),
            self.iters
        )
    }
}

/// Collects and prints benchmark results for one bench target.
pub struct Bencher {
    group: String,
    warmup: Duration,
    measure: Duration,
    results: Vec<Stats>,
    /// Named model-derived scalars for the JSON report, in insertion order.
    metrics: Vec<(String, f64)>,
}

impl Bencher {
    /// Create a bencher with explicit windows.
    pub fn new(group: &str, warmup: Duration, measure: Duration) -> Self {
        println!("\n### bench group: {group}\n");
        Self {
            group: group.to_string(),
            warmup,
            measure,
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Create from the environment: honours `--quick` in argv and
    /// `SSM_RDU_BENCH_QUICK` for short CI runs.
    pub fn from_env(group: &str) -> Self {
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("SSM_RDU_BENCH_QUICK").is_ok();
        if quick {
            Self::new(group, Duration::from_millis(20), Duration::from_millis(100))
        } else {
            Self::new(group, Duration::from_millis(200), Duration::from_millis(1000))
        }
    }

    /// Time a closure. The closure should perform one logical iteration and
    /// return a value (returned values are black-boxed to defeat DCE).
    ///
    /// Every bench gets a warmup pass (at least one iteration, even with a
    /// zero warmup window) before any timing, and at least
    /// [`MIN_TIMED_ITERS`] timed iterations — the ratchet gates compare
    /// `min_s` across runs, and a near-single-sample minimum is noise.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Stats {
        // Warmup, also estimates per-iter cost. `loop` (not `while`)
        // guarantees one pass: caches, lazy statics and resident workers
        // are warm before the first timed sample no matter the window.
        let wstart = Instant::now();
        let mut witers: u64 = 0;
        loop {
            black_box(f());
            witers += 1;
            if wstart.elapsed() >= self.warmup {
                break;
            }
        }
        let est = wstart.elapsed().as_secs_f64() / witers as f64;
        let target_iters = ((self.measure.as_secs_f64() / est.max(1e-9)).ceil() as u64)
            .clamp(MIN_TIMED_ITERS, 5_000_000);

        // Timed runs: collect per-batch samples to get a stddev without
        // timing overhead dominating sub-microsecond bodies.
        let batches = 10u64.min(target_iters);
        let per_batch = (target_iters / batches).max(1);
        let mut samples = Vec::with_capacity(batches as usize);
        for _ in 0..batches {
            let t0 = Instant::now();
            for _ in 0..per_batch {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / per_batch as f64);
        }
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = if samples.len() > 1 {
            samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / (n - 1.0)
        } else {
            0.0
        };
        let stats = Stats {
            name: name.to_string(),
            iters: batches * per_batch,
            mean,
            stddev: var.sqrt(),
            min: samples.iter().copied().fold(f64::INFINITY, f64::min),
        };
        println!("{}", stats.fmt_line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Run a closure once (for report-style "benches" that print a paper
    /// table rather than timing a hot loop) while still recording wall time.
    pub fn report<T, F: FnOnce() -> T>(&mut self, name: &str, f: F) -> T {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<48} {:>12}  (one-shot report)",
            name,
            crate::util::fmt_time(dt)
        );
        self.results.push(Stats {
            name: name.to_string(),
            iters: 1,
            mean: dt,
            stddev: 0.0,
            min: dt,
        });
        out
    }

    /// Access collected results.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Record a named model-derived scalar (a latency from DFModel, a
    /// speedup, a byte count) for the JSON report. Names should be unique
    /// within a group.
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    /// Serialize the run — group, per-bench wall-time stats, recorded
    /// metrics — as a self-describing JSON document.
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            let mut out = String::with_capacity(s.len());
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out
        }
        fn num(v: f64) -> String {
            if v.is_finite() {
                format!("{v}")
            } else {
                "null".to_string()
            }
        }
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"ssm-rdu-bench-v1\",\n");
        s.push_str(&format!("  \"group\": \"{}\",\n", esc(&self.group)));
        s.push_str("  \"benches\": [\n");
        for (i, r) in self.results.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"iters\": {}, \"mean_s\": {}, \"stddev_s\": {}, \
                 \"min_s\": {}}}{}\n",
                esc(&r.name),
                r.iters,
                num(r.mean),
                num(r.stddev),
                num(r.min),
                if i + 1 < self.results.len() { "," } else { "" },
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"metrics\": {\n");
        for (i, (name, v)) in self.metrics.iter().enumerate() {
            s.push_str(&format!(
                "    \"{}\": {}{}\n",
                esc(name),
                num(*v),
                if i + 1 < self.metrics.len() { "," } else { "" },
            ));
        }
        s.push_str("  }\n}\n");
        s
    }

    /// Write the JSON report to `path`.
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    /// Where the JSON report should go, if requested: `--json[=PATH]` in
    /// argv, or the `SSM_RDU_BENCH_JSON` env var (`1`/`true` → the default
    /// `BENCH_<group>.json` in the workspace root, anything else → that
    /// path, resolved against the workspace root when relative).
    fn json_destination(&self) -> Option<PathBuf> {
        for a in std::env::args() {
            if a == "--json" {
                return Some(default_json_path(&self.group));
            }
            if let Some(p) = a.strip_prefix("--json=") {
                return Some(resolve_json_path(PathBuf::from(p)));
            }
        }
        match std::env::var("SSM_RDU_BENCH_JSON") {
            Ok(v) if v == "1" || v.eq_ignore_ascii_case("true") => {
                Some(default_json_path(&self.group))
            }
            Ok(v) if !v.is_empty() => Some(resolve_json_path(PathBuf::from(v))),
            _ => None,
        }
    }

    /// Print the closing summary (and write the JSON report if requested —
    /// see the module docs).
    pub fn finish(self) {
        println!(
            "\n### {}: {} benchmark(s) complete\n",
            self.group,
            self.results.len()
        );
        if let Some(path) = self.json_destination() {
            match self.write_json(&path) {
                Ok(()) => println!("wrote {}", path.display()),
                Err(e) => eprintln!("failed to write {}: {e}", path.display()),
            }
        }
    }
}

/// The workspace root (baked in at compile time): where every
/// `BENCH_*.json` lands so the perf-trajectory tooling and CI artifact
/// globs always find them, regardless of the invoking cwd.
pub fn workspace_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

/// Default JSON report path for a bench group: `<workspace>/BENCH_<group>.json`.
pub fn default_json_path(group: &str) -> PathBuf {
    workspace_root().join(format!("BENCH_{group}.json"))
}

/// Resolve an explicitly requested report path: absolute paths pass
/// through, relative ones anchor at the workspace root (not the cwd).
fn resolve_json_path(p: PathBuf) -> PathBuf {
    if p.is_absolute() {
        p
    } else {
        workspace_root().join(p)
    }
}

/// Opaque value sink to prevent the optimizer from deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher::new(
            "test",
            Duration::from_millis(5),
            Duration::from_millis(20),
        );
        let s = b.bench("noop-ish", || 1 + 1).clone();
        assert!(s.mean >= 0.0);
        assert!(s.min <= s.mean * 1.5 + 1e-9);
        assert!(s.iters >= MIN_TIMED_ITERS);
        b.finish();
    }

    #[test]
    fn json_round_trips_through_the_vendored_parser() {
        use crate::util::json::Json;
        let mut b = Bencher::new(
            "json-test",
            Duration::from_millis(1),
            Duration::from_millis(2),
        );
        b.bench("tiny \"quoted\"", || 2 + 2);
        b.metric("fused_s", 1.5e-4);
        b.metric("unfused_s", 4.5e-4);
        b.metric("bad", f64::NAN);
        let doc = b.to_json();
        let j = Json::parse(&doc).expect("bench JSON must parse");
        assert_eq!(j.get("group").unwrap().as_str(), Some("json-test"));
        assert_eq!(j.get("schema").unwrap().as_str(), Some("ssm-rdu-bench-v1"));
        let benches = j.get("benches").unwrap().as_arr().unwrap();
        assert_eq!(benches.len(), 1);
        assert_eq!(benches[0].get("name").unwrap().as_str(), Some("tiny \"quoted\""));
        assert!(benches[0].get("mean_s").unwrap().as_f64().unwrap() >= 0.0);
        let metrics = j.get("metrics").unwrap();
        assert_eq!(metrics.get("unfused_s").unwrap().as_f64(), Some(4.5e-4));
        assert_eq!(metrics.get("bad"), Some(&Json::Null));
    }

    #[test]
    fn empty_group_json_is_valid() {
        use crate::util::json::Json;
        let b = Bencher::new("empty", Duration::from_millis(1), Duration::from_millis(1));
        let j = Json::parse(&b.to_json()).unwrap();
        assert_eq!(j.get("benches").unwrap().as_arr().unwrap().len(), 0);
        assert!(j.get("metrics").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn json_paths_anchor_at_the_workspace_root() {
        let root = workspace_root();
        assert!(root.join("Cargo.toml").exists(), "manifest dir is the workspace root");
        assert_eq!(default_json_path("hotpath"), root.join("BENCH_hotpath.json"));
        assert_eq!(
            resolve_json_path(PathBuf::from("sub/out.json")),
            root.join("sub/out.json"),
            "relative paths resolve against the workspace, not the cwd"
        );
        let abs = root.join("abs.json");
        assert_eq!(resolve_json_path(abs.clone()), abs);
    }

    #[test]
    fn report_runs_once() {
        let mut b = Bencher::new(
            "test",
            Duration::from_millis(1),
            Duration::from_millis(1),
        );
        let mut count = 0;
        b.report("one-shot", || count += 1);
        assert_eq!(count, 1);
        assert_eq!(b.results()[0].iters, 1);
    }
}
