//! Hand-assembled PCU program constructors, kept verbatim as **differential
//! oracles** for the `define_pcu_program!` migration.
//!
//! Every builder in [`crate::pcusim::programs`] was originally written as
//! the explicit level-pushing loops below. When the constructors moved to
//! the DSL, the originals moved here unchanged (modulo `legacy_` name
//! prefixes), so the migration is *provable* rather than trusted:
//! `tests/integration_pcusim_dsl.rs` asserts, for every program and a grid
//! of lane counts and batch lengths, that the macro-built program has
//! structurally identical levels, byte-identical outputs, and identical
//! `ExecStats` to its oracle here. The twiddle expressions are kept
//! *textually* identical to the DSL helpers so the comparison is exact
//! float equality, not epsilon closeness.
//!
//! This module is test collateral, not API: nothing in the crate calls it
//! outside the differential tests, and it can be deleted once a release
//! has shipped with the wall green. Until then it also documents what the
//! DSL replaced.

use crate::arch::PcuMode;
use crate::pcusim::program::{Level, Op, Program};
use crate::pcusim::programs::bit_reverse;
use crate::util::C64;
use std::f64::consts::PI;

/// Decimation-in-time butterfly levels over `lanes` points with twiddles
/// `e^{sign·2πi·j/len}` — the original shared helper of the DIT builders.
#[allow(clippy::needless_range_loop)] // lanes indexed by butterfly position math
fn dit_levels(lanes: usize, sign: f64) -> Vec<Level> {
    assert!(lanes.is_power_of_two() && lanes >= 2);
    let levels_n = lanes.trailing_zeros() as usize;
    let mut levels = Vec::with_capacity(levels_n);
    for b in 0..levels_n {
        let half = 1 << b;
        let len = half << 1;
        let mut ops = vec![Op::Pass; lanes];
        for i in 0..lanes {
            let j = i % len;
            if j < half {
                // x[i] ← x[i] + w_j · x[i+half]
                let w = C64::cis(sign * 2.0 * PI * j as f64 / len as f64);
                ops[i] = Op::Mac { src: i + half, c: w };
            } else {
                // x[i] ← x[i−half] − w_{j−half} · x[i]  =  (−w)·a + b
                let w = C64::cis(sign * 2.0 * PI * (j - half) as f64 / len as f64);
                ops[i] = Op::MacSelf { src: i - half, c: C64::real(-1.0) * w };
            }
        }
        levels.push(Level::new(ops));
    }
    levels
}

/// Oracle for `fft_program`.
pub fn legacy_fft_program(lanes: usize) -> Program {
    Program::new(&format!("fft{lanes}"), PcuMode::Fft, dit_levels(lanes, -1.0))
}

/// Oracle for `idit_fft_program`.
pub fn legacy_idit_fft_program(lanes: usize) -> Program {
    Program::new(&format!("idit-fft{lanes}"), PcuMode::Fft, dit_levels(lanes, 1.0))
}

/// Oracle for `dif_fft_program`.
#[allow(clippy::needless_range_loop)] // lanes indexed by butterfly position math
pub fn legacy_dif_fft_program(lanes: usize) -> Program {
    assert!(lanes.is_power_of_two() && lanes >= 2);
    let levels_n = lanes.trailing_zeros() as usize;
    let mut levels = Vec::with_capacity(levels_n);
    for step in 0..levels_n {
        let half = lanes >> (step + 1);
        let len = half << 1;
        let mut ops = vec![Op::Pass; lanes];
        for i in 0..lanes {
            let j = i % len;
            if j < half {
                // Upper lane: u ← u + v.
                ops[i] = Op::Add { src: i + half };
            } else {
                // Lower lane: v ← w_{j−half} · (u − v).
                let w = C64::cis(-2.0 * PI * (j - half) as f64 / len as f64);
                ops[i] = Op::TwiddleSub { src: i - half, c: w };
            }
        }
        levels.push(Level::new(ops));
    }
    Program::new(&format!("dif-fft{lanes}"), PcuMode::Fft, levels)
}

/// Oracle for `freq_filter_program`.
pub fn legacy_freq_filter_program(h: &[C64]) -> Program {
    let n = h.len();
    assert!(n.is_power_of_two() && n >= 2);
    let hf = crate::fft::fft(h);
    let ops = bit_reverse(&hf).iter().map(|z| Op::MulConst(z.scale(1.0 / n as f64))).collect();
    Program::new(&format!("freq-filter{n}"), PcuMode::ElementWise, vec![Level::new(ops)])
}

/// Oracle for `fused_conv_program`.
pub fn legacy_fused_conv_program(lanes: usize, h: &[C64]) -> Program {
    assert_eq!(h.len(), lanes, "filter length must match lane count");
    let mut levels = legacy_dif_fft_program(lanes).levels;
    levels.extend(legacy_freq_filter_program(h).levels);
    levels.extend(dit_levels(lanes, 1.0));
    Program::new(&format!("fused-conv{lanes}"), PcuMode::Fft, levels)
}

/// Oracle for `unfused_conv_programs`.
pub fn legacy_unfused_conv_programs(lanes: usize, h: &[C64]) -> [Program; 3] {
    assert_eq!(h.len(), lanes, "filter length must match lane count");
    [legacy_dif_fft_program(lanes), legacy_freq_filter_program(h), legacy_idit_fft_program(lanes)]
}

/// Oracle for `hs_scan_program`.
#[allow(clippy::needless_range_loop)] // lanes indexed by shift-distance math
pub fn legacy_hs_scan_program(lanes: usize) -> Program {
    assert!(lanes.is_power_of_two() && lanes >= 2);
    let levels_n = lanes.trailing_zeros() as usize;
    let mut levels = Vec::with_capacity(levels_n);
    for b in 0..levels_n {
        let stride = 1 << b;
        let mut ops = vec![Op::Pass; lanes];
        for i in stride..lanes {
            ops[i] = Op::Add { src: i - stride };
        }
        levels.push(Level::new(ops));
    }
    Program::new(&format!("hs-scan{lanes}"), PcuMode::HsScan, levels)
}

/// Oracle for `b_scan_program`.
pub fn legacy_b_scan_program(lanes: usize) -> Program {
    assert!(lanes.is_power_of_two() && lanes >= 2);
    let levels_n = lanes.trailing_zeros() as usize;
    let mut levels = Vec::with_capacity(2 * levels_n);
    // Up-sweep: at stride 2^b, tree nodes accumulate their left sibling.
    for b in 0..levels_n {
        let stride = 1 << b;
        let group = stride << 1;
        let mut ops = vec![Op::Pass; lanes];
        for i in ((group - 1)..lanes).step_by(group) {
            ops[i] = Op::Add { src: i - stride };
        }
        levels.push(Level::new(ops));
    }
    // Down-sweep. First level folds the root-zeroing: after the up-sweep the
    // root would be set to 0, so its left child receives Const(0) and the
    // root receives the left child's value.
    for (step, _) in (0..levels_n).enumerate() {
        let stride = 1 << (levels_n - 1 - step);
        let group = stride << 1;
        let mut ops = vec![Op::Pass; lanes];
        for i in ((group - 1)..lanes).step_by(group) {
            if step == 0 {
                // Root pair: left child ← 0, root ← left child.
                ops[i - stride] = Op::Const(C64::ZERO);
                ops[i] = Op::Take { src: i - stride };
            } else {
                // t = x[i−k]; x[i−k] = x[i]; x[i] = t + x[i].
                ops[i - stride] = Op::Take { src: i };
                ops[i] = Op::Add { src: i - stride };
            }
        }
        levels.push(Level::new(ops));
    }
    Program::new(&format!("b-scan{lanes}"), PcuMode::BScan, levels)
}

/// Oracle for `reduction_program`.
pub fn legacy_reduction_program(lanes: usize) -> Program {
    assert!(lanes.is_power_of_two() && lanes >= 2);
    let levels_n = lanes.trailing_zeros() as usize;
    let mut levels = Vec::with_capacity(levels_n);
    for b in 0..levels_n {
        let stride = 1 << b;
        let group = stride << 1;
        let mut ops = vec![Op::Pass; lanes];
        for i in (0..lanes).step_by(group) {
            ops[i] = Op::Add { src: i + stride };
        }
        levels.push(Level::new(ops));
    }
    Program::new(&format!("reduce{lanes}"), PcuMode::Reduction, levels)
}

/// Oracle for `twiddle_program`.
pub fn legacy_twiddle_program(factors: &[C64]) -> Program {
    let ops = factors.iter().map(|&c| Op::MulConst(c)).collect();
    Program::new("twiddle", PcuMode::ElementWise, vec![Level::new(ops)])
}
