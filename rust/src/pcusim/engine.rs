//! Cycle-level functional execution of PCU programs.
//!
//! Two execution regimes, matching the paper's performance argument:
//!
//! * **Spatial** — the program's levels are unrolled across consecutive
//!   pipeline stages ("akin to an ASIC-style implementation", §III-B).
//!   Throughput is one input vector per cycle; a batch of `V` vectors takes
//!   `V + stages − 1` cycles.
//! * **Serialized** — the fallback when the PCU's interconnect cannot wire
//!   the program's cross-lane traffic (e.g. Vector-FFT on a baseline PCU,
//!   §III-B): only the first pipeline stage executes a level per cycle, the
//!   vector recirculates once per level, and the remaining `stages − 1`
//!   stages forward data unchanged. Throughput collapses to one vector per
//!   `levels` cycles with 1/`stages` of the FUs doing useful work.
//!
//! [`Pcu::run`] picks the regime by program validation, so the same call
//! reproduces both sides of the paper's baseline-vs-extended comparison.

use crate::arch::{PcuGeometry, PcuMode};
use crate::pcusim::program::{Level, MapError, Op, Program};
use crate::util::C64;

/// Execution statistics for one program run over a batch of input vectors.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecStats {
    /// Total cycles including pipeline fill/drain.
    pub cycles: u64,
    /// FU-cycles spent on useful arithmetic.
    pub useful_fu_cycles: u64,
    /// FU-cycles available (`cycles × lanes × stages`).
    pub total_fu_cycles: u64,
    /// Input vectors processed.
    pub vectors: u64,
    /// Whether the run was spatially mapped (true) or serialized (false).
    pub spatial: bool,
}

impl ExecStats {
    /// Fraction of FU-cycles doing useful arithmetic — the quantity the
    /// paper's utilization argument is about (1/12 for Vector-FFT on the
    /// baseline 32×12 PCU vs ~5/12 on the FFT-mode PCU).
    pub fn utilization(&self) -> f64 {
        if self.total_fu_cycles == 0 {
            return 0.0;
        }
        self.useful_fu_cycles as f64 / self.total_fu_cycles as f64
    }

    /// Steady-state initiation interval in cycles per vector.
    pub fn initiation_interval(&self) -> f64 {
        if self.vectors == 0 {
            return 0.0;
        }
        self.cycles as f64 / self.vectors as f64
    }
}

/// A PCU instance: geometry plus whether the extension interconnect required
/// by the program under test is fabricated.
#[derive(Debug, Clone, Copy)]
pub struct Pcu {
    pub geom: PcuGeometry,
    /// Extension modes available (paper: baseline = none; FFT-mode RDU =
    /// `Fft`; …). Baseline modes are always available.
    pub extensions: &'static [PcuMode],
}

impl Pcu {
    /// Baseline PCU: element-wise / systolic / reduction only.
    pub fn baseline(geom: PcuGeometry) -> Self {
        Self { geom, extensions: &[] }
    }

    /// PCU with the FFT butterfly fabric.
    pub fn fft_mode(geom: PcuGeometry) -> Self {
        Self { geom, extensions: &[PcuMode::Fft] }
    }

    /// PCU with the Hillis–Steele fabric.
    pub fn hs_scan_mode(geom: PcuGeometry) -> Self {
        Self { geom, extensions: &[PcuMode::HsScan] }
    }

    /// PCU with the Blelloch fabric.
    pub fn b_scan_mode(geom: PcuGeometry) -> Self {
        Self { geom, extensions: &[PcuMode::BScan] }
    }

    /// PCU carrying whichever extension fabric `mode` names — the baseline
    /// PCU for the three baseline modes. This is how mode-generic callers
    /// (the `debug` CLI, the property harness) pick the fabric a program's
    /// `mode` field asks for without a six-way match.
    pub fn with_extension(geom: PcuGeometry, mode: PcuMode) -> Self {
        match mode {
            PcuMode::Fft => Self::fft_mode(geom),
            PcuMode::HsScan => Self::hs_scan_mode(geom),
            PcuMode::BScan => Self::b_scan_mode(geom),
            PcuMode::ElementWise | PcuMode::Systolic | PcuMode::Reduction => Self::baseline(geom),
        }
    }

    /// Does this PCU support `mode`?
    pub fn supports(&self, mode: PcuMode) -> bool {
        !mode.is_extension() || self.extensions.contains(&mode)
    }

    /// Functionally evaluate one level against the previous level's outputs.
    /// `pub(crate)` so the single-step debugger (`pcusim::debug`) advances
    /// pipeline registers through the *same* op semantics the batch engine
    /// uses — one implementation, two drivers.
    pub(crate) fn eval_level(level: &Level, prev: &[C64]) -> Vec<C64> {
        level
            .ops
            .iter()
            .enumerate()
            .map(|(lane, op)| {
                let a = prev[lane];
                match *op {
                    Op::Pass => a,
                    Op::Const(c) => c,
                    Op::Add { src } => a + prev[src],
                    Op::Sub { src } => a - prev[src],
                    Op::MulConst(c) => a * c,
                    Op::Mac { src, c } => a + c * prev[src],
                    Op::MacSelf { src, c } => c * a + prev[src],
                    Op::TwiddleSub { src, c } => c * (prev[src] - a),
                    Op::Take { src } => prev[src],
                }
            })
            .collect()
    }

    /// Functional result of the program on one vector (regime-independent).
    pub fn eval(&self, prog: &Program, input: &[C64]) -> Vec<C64> {
        assert_eq!(input.len(), self.geom.lanes, "input width != lanes");
        let mut cur = input.to_vec();
        for level in &prog.levels {
            cur = Self::eval_level(level, &cur);
        }
        cur
    }

    /// Can `prog` be spatially mapped on this PCU?
    pub fn mappable(&self, prog: &Program) -> Result<(), MapError> {
        prog.validate_spatial(self.geom, self.supports(prog.mode))
    }

    /// Run `prog` over a batch of input vectors, choosing the spatial regime
    /// when the interconnect allows it and the serialized fallback otherwise.
    pub fn run(&self, prog: &Program, inputs: &[Vec<C64>]) -> (Vec<Vec<C64>>, ExecStats) {
        match self.mappable(prog) {
            Ok(()) => self.run_spatial(prog, inputs),
            Err(_) => self.run_serialized(prog, inputs),
        }
    }

    /// Spatial regime: levels pinned to stages, one vector enters per cycle.
    pub fn run_spatial(&self, prog: &Program, inputs: &[Vec<C64>]) -> (Vec<Vec<C64>>, ExecStats) {
        self.mappable(prog).expect("run_spatial: program not mappable");
        let outputs: Vec<Vec<C64>> = inputs.iter().map(|v| self.eval(prog, v)).collect();
        let v = inputs.len() as u64;
        let cycles = v + self.geom.stages as u64 - 1;
        let useful = v * prog.useful_ops() as u64;
        let stats = ExecStats {
            cycles,
            useful_fu_cycles: useful,
            total_fu_cycles: cycles * self.geom.fu_count() as u64,
            vectors: v,
            spatial: true,
        };
        (outputs, stats)
    }

    /// Serialized fallback: one level per cycle at stage 0, recirculating —
    /// the paper's "only the first stage of the pipeline" regime.
    pub fn run_serialized(&self, prog: &Program, inputs: &[Vec<C64>]) -> (Vec<Vec<C64>>, ExecStats) {
        let outputs: Vec<Vec<C64>> = inputs.iter().map(|v| self.eval(prog, v)).collect();
        let v = inputs.len() as u64;
        let levels = prog.levels.len().max(1) as u64;
        // Each vector occupies stage 0 for `levels` separate cycles; every
        // recirculation still traverses the full pipeline, so the drain adds
        // `stages − 1` per level of the last vector.
        let cycles = v * levels + (self.geom.stages as u64 - 1) * levels;
        let useful = v * prog.useful_ops() as u64;
        let stats = ExecStats {
            cycles,
            useful_fu_cycles: useful,
            total_fu_cycles: cycles * self.geom.fu_count() as u64,
            vectors: v,
            spatial: false,
        };
        (outputs, stats)
    }

    /// Systolic-mode streamed matrix–vector product: weights `w[lane][stage]`
    /// are resident in the FU constant ports; each cycle a new column vector
    /// `x` of length `stages` streams across the array and every FU performs
    /// one MAC — the full-utilization GEMM regime the baseline RDU is built
    /// around (paper Fig. 2, systolic mode).
    pub fn run_systolic_matvec(
        &self,
        w: &[Vec<f64>],
        xs: &[Vec<f64>],
    ) -> (Vec<Vec<f64>>, ExecStats) {
        assert_eq!(w.len(), self.geom.lanes, "weight rows != lanes");
        assert!(w.iter().all(|r| r.len() == self.geom.stages), "weight cols != stages");
        let outputs: Vec<Vec<f64>> = xs
            .iter()
            .map(|x| {
                assert_eq!(x.len(), self.geom.stages, "x length != stages");
                (0..self.geom.lanes)
                    .map(|lane| w[lane].iter().zip(x).map(|(wi, xi)| wi * xi).sum())
                    .collect()
            })
            .collect();
        let v = xs.len() as u64;
        let cycles = v + self.geom.stages as u64 - 1;
        let useful = v * self.geom.fu_count() as u64;
        let stats = ExecStats {
            cycles,
            useful_fu_cycles: useful,
            total_fu_cycles: cycles * self.geom.fu_count() as u64,
            vectors: v,
            spatial: true,
        };
        (outputs, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pcusim::program::{Level, Op};

    fn geom() -> PcuGeometry {
        PcuGeometry::synthesis()
    }

    /// An element-wise doubling program (no cross-lane traffic).
    fn double_prog() -> Program {
        Program::new(
            "double",
            PcuMode::ElementWise,
            vec![Level::new(vec![Op::MulConst(C64::real(2.0)); 8])],
        )
    }

    #[test]
    fn eval_elementwise() {
        let pcu = Pcu::baseline(geom());
        let x: Vec<C64> = (0..8).map(|i| C64::real(i as f64)).collect();
        let y = pcu.eval(&double_prog(), &x);
        for (i, v) in y.iter().enumerate() {
            assert_eq!(v.re, 2.0 * i as f64);
        }
    }

    #[test]
    fn spatial_throughput_one_vector_per_cycle() {
        let pcu = Pcu::baseline(geom());
        let inputs: Vec<Vec<C64>> = (0..100).map(|_| vec![C64::real(1.0); 8]).collect();
        let (_, stats) = pcu.run(&double_prog(), &inputs);
        assert!(stats.spatial);
        assert_eq!(stats.cycles, 100 + 5);
        assert!((stats.initiation_interval() - 1.05).abs() < 1e-12);
    }

    #[test]
    fn serialized_fallback_on_missing_fabric() {
        // A multi-level program needing the HS fabric on a baseline PCU
        // serializes (one level per recirculation); the serialization
        // penalty is proportional to the level count.
        let levels: Vec<Level> = (0..3)
            .map(|b| {
                let stride = 1usize << b;
                let mut ops = vec![Op::Pass; 8];
                for (i, op) in ops.iter_mut().enumerate().skip(stride) {
                    *op = Op::Add { src: i - stride };
                }
                Level::new(ops)
            })
            .collect();
        let prog = Program::new("hs-scan8", PcuMode::HsScan, levels);
        let pcu = Pcu::baseline(geom());
        let inputs: Vec<Vec<C64>> = (0..10).map(|_| vec![C64::real(1.0); 8]).collect();
        let (outs, stats) = pcu.run(&prog, &inputs);
        assert!(!stats.spatial);
        // Functional result is identical to the spatial regime.
        let hs = Pcu::hs_scan_mode(geom());
        let (outs2, stats2) = hs.run(&prog, &inputs);
        assert!(stats2.spatial);
        assert_eq!(outs, outs2);
        // Serialized is slower per vector.
        assert!(stats.initiation_interval() > stats2.initiation_interval());
    }

    #[test]
    fn serialized_utilization_is_one_over_stages() {
        // Fully-busy single level on all lanes, long batch: utilization
        // approaches lanes·useful / (lanes·stages) = 1/stages.
        let prog = Program::new(
            "busy",
            PcuMode::ElementWise,
            vec![Level::new(vec![Op::MulConst(C64::real(3.0)); 8])],
        );
        let pcu = Pcu::baseline(geom());
        let inputs: Vec<Vec<C64>> = (0..10_000).map(|_| vec![C64::real(1.0); 8]).collect();
        let (_, stats) = pcu.run_serialized(&prog, &inputs);
        let u = stats.utilization();
        assert!((u - 1.0 / 6.0).abs() < 1e-3, "u={u}");
    }

    #[test]
    fn systolic_matvec_full_utilization() {
        let pcu = Pcu::baseline(geom());
        // w[lane][stage] = lane identity-ish weights.
        let w: Vec<Vec<f64>> = (0..8).map(|l| vec![(l + 1) as f64; 6]).collect();
        let xs: Vec<Vec<f64>> = (0..50).map(|_| vec![1.0; 6]).collect();
        let (ys, stats) = pcu.run_systolic_matvec(&w, &xs);
        assert_eq!(ys[0][3], 4.0 * 6.0);
        let u = stats.utilization();
        assert!(u > 0.9, "u={u}"); // fill/drain keeps it just under 1.0
    }

    #[test]
    fn with_extension_picks_matching_fabric() {
        for mode in PcuMode::EXTENSIONS {
            let pcu = Pcu::with_extension(geom(), mode);
            assert!(pcu.supports(mode), "{mode}");
        }
        for mode in PcuMode::BASELINE {
            let pcu = Pcu::with_extension(geom(), mode);
            assert!(pcu.extensions.is_empty(), "{mode}");
            assert!(pcu.supports(mode), "{mode}: baseline modes always supported");
        }
    }

    #[test]
    fn stats_utilization_zero_guard() {
        let s = ExecStats {
            cycles: 0,
            useful_fu_cycles: 0,
            total_fu_cycles: 0,
            vectors: 0,
            spatial: true,
        };
        assert_eq!(s.utilization(), 0.0);
        assert_eq!(s.initiation_interval(), 0.0);
    }
}
