//! Measured pipeline utilization of the paper's kernels on baseline vs
//! extended PCUs — the structural numbers behind DFModel's throughput table.
//!
//! These are *measurements* of the cycle-level engine, not hand-entered
//! constants: each function builds the canonical program, runs a long batch
//! through [`Pcu::run`], and reports the steady-state figures. DFModel
//! (`crate::dfmodel::throughput`) consumes the derived
//! [`pipeline_factor`] — the fraction of peak pipeline issue slots a kernel
//! sustains:
//!
//! * spatial mapping (extension fabric present): the program occupies
//!   `levels` of the `stages` pipeline stages at initiation interval 1 →
//!   factor `levels/stages` (5/12 for a 32-point FFT on the 32×12 PCU);
//! * serialized fallback (paper §III-B: "only the first stage of the
//!   pipeline"): initiation interval `levels`, one stage busy →
//!   factor `1/stages` (1/12) regardless of program depth.

use crate::arch::{PcuGeometry, PcuMode, RduConfig};
use crate::pcusim::engine::Pcu;
use crate::pcusim::program::Program;
use crate::pcusim::programs;
use crate::util::{C64, XorShift};
use std::collections::HashMap;
use std::sync::Mutex;
use std::sync::OnceLock;

/// Measurement memo: the steady-state figures are deterministic per
/// (program kind, geometry, fabric availability), and DFModel queries them
/// for every kernel of every estimate — cache them process-wide.
/// Key: (kind, lanes, stages, extension available).
type MemoKey = (u8, usize, usize, bool);

fn memo() -> &'static Mutex<HashMap<MemoKey, Measurement>> {
    static MEMO: OnceLock<Mutex<HashMap<MemoKey, Measurement>>> = OnceLock::new();
    MEMO.get_or_init(|| Mutex::new(HashMap::new()))
}

fn memoized(key: MemoKey, compute: impl FnOnce() -> Measurement) -> Measurement {
    if let Some(m) = memo().lock().unwrap().get(&key) {
        return *m;
    }
    let m = compute();
    memo().lock().unwrap().insert(key, m);
    m
}

/// Steady-state measurement of a program on a PCU.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measurement {
    /// Was the program spatially mapped (fabric present)?
    pub spatial: bool,
    /// Cycles per input vector in steady state.
    pub initiation_interval: f64,
    /// Fraction of FU-cycles doing useful arithmetic.
    pub fu_utilization: f64,
    /// Fraction of pipeline issue slots sustained:
    /// `busy_stages / (stages × initiation_interval)`.
    pub pipeline_factor: f64,
}

/// Run `prog` on `pcu` with a batch long enough to amortize fill/drain and
/// extract steady-state figures.
pub fn measure(pcu: &Pcu, prog: &Program) -> Measurement {
    let lanes = pcu.geom.lanes;
    let mut rng = XorShift::new(0x5eed);
    let batch: Vec<Vec<C64>> = (0..4096)
        .map(|_| (0..lanes).map(|_| C64::real(rng.uniform(-1.0, 1.0))).collect())
        .collect();
    let (_, stats) = pcu.run(prog, &batch);
    let levels = prog.levels.len() as f64;
    let stages = pcu.geom.stages as f64;
    let ii = stats.initiation_interval();
    let pipeline_factor = if stats.spatial { levels / stages } else { 1.0 / stages };
    Measurement {
        spatial: stats.spatial,
        initiation_interval: ii,
        fu_utilization: stats.utilization(),
        pipeline_factor,
    }
}

/// Measurement for the `lanes`-point Vector-FFT tile on an RDU config.
/// Memoized — see [`memoized`].
pub fn vector_fft(cfg: &RduConfig) -> Measurement {
    let geom = cfg.spec.pcu;
    let avail = cfg.supports(PcuMode::Fft);
    memoized((0, geom.lanes, geom.stages, avail), || {
        let pcu = if avail { Pcu::fft_mode(geom) } else { Pcu::baseline(geom) };
        measure(&pcu, &programs::fft_program(geom.lanes))
    })
}

/// Measurement for the `lanes`-element Hillis–Steele scan tile. Memoized.
pub fn hs_scan(cfg: &RduConfig) -> Measurement {
    let geom = cfg.spec.pcu;
    let avail = cfg.supports(PcuMode::HsScan);
    memoized((1, geom.lanes, geom.stages, avail), || {
        let pcu = if avail { Pcu::hs_scan_mode(geom) } else { Pcu::baseline(geom) };
        measure(&pcu, &programs::hs_scan_program(geom.lanes))
    })
}

/// Measurement for the `lanes`-element Blelloch scan tile. Memoized.
pub fn b_scan(cfg: &RduConfig) -> Measurement {
    let geom = cfg.spec.pcu;
    let avail = cfg.supports(PcuMode::BScan);
    memoized((2, geom.lanes, geom.stages, avail), || {
        let pcu = if avail { Pcu::b_scan_mode(geom) } else { Pcu::baseline(geom) };
        measure(&pcu, &programs::b_scan_program(geom.lanes))
    })
}

/// Best parallel-scan measurement available on `cfg` — the paper shows
/// HS-mode and B-mode deliver identical end-to-end performance ("each mode
/// supports a throughput of one scan per cycle"), so DFModel takes
/// whichever fabric the config provides.
pub fn parallel_scan(cfg: &RduConfig) -> Measurement {
    let hs = hs_scan(cfg);
    let b = b_scan(cfg);
    if b.spatial && !hs.spatial {
        b
    } else {
        hs
    }
}

/// Convenience: the `1/stages` serialized factor for a geometry.
pub fn serialized_factor(geom: PcuGeometry) -> f64 {
    1.0 / geom.stages as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_mode_vs_baseline_paper_factors() {
        // Paper §III-B/§III-C: baseline = first-stage-only (1/12), FFT-mode
        // unrolls the 5 butterfly levels spatially (5/12) — a 5× speedup on
        // the FFT kernel itself before Amdahl blending.
        let base = vector_fft(&RduConfig::baseline());
        let fft = vector_fft(&RduConfig::fft_mode());
        assert!(!base.spatial);
        assert!(fft.spatial);
        assert!((base.pipeline_factor - 1.0 / 12.0).abs() < 1e-12);
        assert!((fft.pipeline_factor - 5.0 / 12.0).abs() < 1e-12);
        // Initiation interval: 5 cycles/vector serialized vs ~1 spatial.
        assert!(base.initiation_interval > 4.9);
        assert!(fft.initiation_interval < 1.1);
    }

    #[test]
    fn scan_mode_one_scan_per_cycle() {
        for cfg in [RduConfig::hs_scan_mode(), RduConfig::b_scan_mode()] {
            let m = parallel_scan(&cfg);
            assert!(m.spatial, "{}", cfg.name());
            assert!(m.initiation_interval < 1.1, "{}: II={}", cfg.name(), m.initiation_interval);
        }
    }

    #[test]
    fn baseline_scan_serializes() {
        let m = parallel_scan(&RduConfig::baseline());
        assert!(!m.spatial);
        assert!((m.pipeline_factor - 1.0 / 12.0).abs() < 1e-12);
        // HS over 32 lanes has 5 levels → II ≈ 5 cycles/vector.
        assert!(m.initiation_interval > 4.9);
    }

    #[test]
    fn hs_and_b_modes_equivalent_throughput() {
        // Paper §IV-C: "Both the HS-scan-mode and B-scan-mode RDUs achieve
        // identical performance, as each mode supports a throughput of one
        // scan per cycle."
        let hs = parallel_scan(&RduConfig::hs_scan_mode());
        let b = parallel_scan(&RduConfig::b_scan_mode());
        assert!((hs.initiation_interval - b.initiation_interval).abs() < 0.01);
    }

    #[test]
    fn fu_utilization_matches_pipeline_factor_shape() {
        // For the all-lanes-busy HS scan the FU utilization is bounded by
        // the pipeline factor (Pass lanes reduce it further).
        let m = hs_scan(&RduConfig::hs_scan_mode());
        assert!(m.fu_utilization <= m.pipeline_factor + 1e-9);
        assert!(m.fu_utilization > 0.0);
    }

    #[test]
    fn serialized_factor_table1() {
        assert!((serialized_factor(PcuGeometry::table1()) - 1.0 / 12.0).abs() < 1e-15);
    }
}
