//! Canonical PCU programs for the paper's kernels: the radix-2 FFT
//! (Fig. 5), the Hillis–Steele scan and the Blelloch scan (Figs. 9/10),
//! plus the baseline reduction tree. Each builder emits a [`Program`] whose
//! level-*b* cross-lane traffic exactly matches the mode's boundary-*b*
//! fabric, so `Program::validate_spatial` succeeds on the extended PCU and
//! fails (→ serialized fallback) on the baseline PCU.
//!
//! Every constructor is authored through the
//! [`define_pcu_program!`](crate::define_pcu_program) DSL
//! ([`crate::pcusim::dsl`]): named stages, per-lane op expressions, folded
//! constants, and cross-lane routes checked against `topology::allows` at
//! construction. The original hand-assembled loop builders live on in
//! [`crate::pcusim::legacy`] as differential oracles —
//! `tests/integration_pcusim_dsl.rs` proves each migration produces
//! structurally identical levels, byte-identical outputs, and identical
//! `ExecStats`.
//!
//! Functional correctness of every program is asserted against the
//! [`crate::fft`] / [`crate::scan`] substrates in the tests below — the same
//! oracles the Pallas kernels are tested against in `python/tests`, closing
//! the cross-layer loop promised in DESIGN.md §7.

use crate::define_pcu_program;
use crate::pcusim::dsl::ops;
use crate::pcusim::program::{Op, Program};
use crate::util::{C64, XorShift};
use std::f64::consts::PI;

/// Bit-reversal permutation of a power-of-two-length slice. On the RDU this
/// reordering is performed by the PMU's address generators while streaming
/// the tile into the PCU (the paper's PMUs own all address computation), so
/// it costs no PCU cycles.
pub fn bit_reverse(x: &[C64]) -> Vec<C64> {
    let n = x.len();
    assert!(n.is_power_of_two());
    let bits = n.trailing_zeros();
    (0..n)
        .map(|i| {
            let j = ((i as u32).reverse_bits() >> (32 - bits)) as usize;
            x[j]
        })
        .collect()
}

/// `log₂(lanes)` with the power-of-two precondition every butterfly/scan
/// program shares.
fn log2_lanes(lanes: usize) -> usize {
    assert!(lanes.is_power_of_two() && lanes >= 2);
    lanes.trailing_zeros() as usize
}

/// Per-lane decimation-in-time butterfly op at level `b` (stride `2^b`),
/// twiddle sign `sign` (−1 forward, +1 inverse) — the stage body shared by
/// [`fft_program`], [`idit_fft_program`] and [`fused_conv_program`]. The
/// twiddle expressions are textually identical to the legacy oracles so the
/// differential tests compare exact floats.
fn dit_butterfly(b: usize, i: usize, sign: f64) -> Op {
    let half = 1 << b;
    let len = half << 1;
    let j = i % len;
    if j < half {
        // x[i] ← x[i] + w_j · x[i+half]
        let w = C64::cis(sign * 2.0 * PI * j as f64 / len as f64);
        ops::mac(i + half, w)
    } else {
        // x[i] ← x[i−half] − w_{j−half} · x[i]  =  (−w)·a + b
        let w = C64::cis(sign * 2.0 * PI * (j - half) as f64 / len as f64);
        ops::mac_self(i - half, C64::real(-1.0) * w)
    }
}

/// Per-lane decimation-in-frequency butterfly op at level `step` (stride
/// `lanes/2^{step+1}`) — shared by [`dif_fft_program`] and
/// [`fused_conv_program`].
fn dif_butterfly(lanes: usize, step: usize, i: usize) -> Op {
    let half = lanes >> (step + 1);
    let len = half << 1;
    let j = i % len;
    if j < half {
        // Upper lane: u ← u + v.
        ops::add(i + half)
    } else {
        // Lower lane: v ← w_{j−half} · (u − v).
        let w = C64::cis(-2.0 * PI * (j - half) as f64 / len as f64);
        ops::twiddle_sub(i - half, w)
    }
}

/// Folded frequency-domain filter taps: `FFT(h)` permuted to bit-reversed
/// order (matching the DIF output that consumes them) and pre-scaled by
/// `1/N` — the constant-folding step of [`freq_filter_program`] and
/// [`fused_conv_program`].
fn freq_filter_taps(h: &[C64]) -> Vec<C64> {
    let n = h.len();
    assert!(n.is_power_of_two() && n >= 2);
    let hf = crate::fft::fft(h);
    bit_reverse(&hf).iter().map(|z| z.scale(1.0 / n as f64)).collect()
}

define_pcu_program! {
    /// Radix-2 decimation-in-time FFT over `lanes` complex points, expecting
    /// bit-reversed input (see [`bit_reverse`]). Level *b* performs the
    /// stride-`2^b` butterflies: the pair-leader lane computes `a + w·b`
    /// (MAC) and the partner lane computes `a_partner − w·b_self` via the
    /// mirrored MAC — exactly the dataflow Fig. 5 unrolls across the
    /// pipeline.
    pub fn fft_program(lanes: usize) {
        name: format!("fft{lanes}"),
        mode: Fft,
        width: lanes,
        let n = log2_lanes(lanes);
        stage bfly[b in 0..n] = |i| dit_butterfly(b, i, -1.0);
    }
}

define_pcu_program! {
    /// Unnormalized inverse DIT FFT: bit-reversed input → natural-order
    /// output, conjugate twiddles, **no** 1/N scaling (the fused convolution
    /// folds the 1/N into the frequency-domain filter constants — see
    /// [`freq_filter_program`]).
    pub fn idit_fft_program(lanes: usize) {
        name: format!("idit-fft{lanes}"),
        mode: Fft,
        width: lanes,
        let n = log2_lanes(lanes);
        stage ibfly[b in 0..n] = |i| dit_butterfly(b, i, 1.0);
    }
}

define_pcu_program! {
    /// Radix-2 decimation-in-frequency forward FFT: natural-order input →
    /// bit-reversed output. Level *s* runs the stride-`lanes/2^{s+1}`
    /// butterflies: the upper lane computes `a + b` (Add) and the lower lane
    /// `w·(a − b)` via [`Op::TwiddleSub`]. Paired with [`idit_fft_program`]
    /// this gives a transform→inverse chain with *no* reordering in between
    /// — DIF emits exactly the bit-reversed order DIT ingests — which is
    /// what makes the fused convolution a single straight-line spatial
    /// program.
    pub fn dif_fft_program(lanes: usize) {
        name: format!("dif-fft{lanes}"),
        mode: Fft,
        width: lanes,
        let n = log2_lanes(lanes);
        stage dif[step in 0..n] = |i| dif_butterfly(lanes, step, i);
    }
}

define_pcu_program! {
    /// Frequency-domain filter multiply for the fused convolution: one
    /// element-wise level whose per-lane constants are `FFT(h)` permuted to
    /// bit-reversed order (matching the DIF output the level consumes) and
    /// pre-scaled by `1/N` (folding the inverse transform's normalization
    /// into the resident filter — zero extra levels).
    pub fn freq_filter_program(h: &[C64]) {
        name: format!("freq-filter{}", h.len()),
        mode: ElementWise,
        width: h.len(),
        let taps = freq_filter_taps(h);
        stage filter = |i| ops::mul(taps[i]);
    }
}

define_pcu_program! {
    /// The **fused** FFT→filter→iFFT circular-convolution pipeline, the
    /// pcusim-level ground truth for the mapper's fusion pass: DIF forward
    /// levels, one filter-multiply level, DIT inverse levels —
    /// `2·log₂(N)+1` stages, natural-order input *and* output,
    /// intermediates never leaving the pipeline registers. On the Table I
    /// PCU (32×12) it occupies 11 of 12 stages of a single FFT-mode PCU; on
    /// a baseline PCU it serializes.
    ///
    /// [`unfused_conv_programs`] exposes the identical arithmetic as three
    /// separate program launches; the integration tests assert the two are
    /// bit-identical (fusion is a scheduling transform, not a numerics one).
    pub fn fused_conv_program(lanes: usize, h: &[C64]) {
        name: format!("fused-conv{lanes}"),
        mode: Fft,
        width: lanes,
        let n = log2_lanes(lanes);
        let taps = {
            assert_eq!(h.len(), lanes, "filter length must match lane count");
            freq_filter_taps(h)
        };
        stage dif[step in 0..n] = |i| dif_butterfly(lanes, step, i);
        stage filter = |i| ops::mul(taps[i]);
        stage idit[b in 0..n] = |i| dit_butterfly(b, i, 1.0);
    }
}

/// The unfused counterpart of [`fused_conv_program`]: the same three stages
/// as separate program launches (forward DIF, filter multiply, inverse
/// DIT), each intermediate staged through a PMU/DRAM buffer between
/// launches. Same levels, same constants, same order — running them
/// back-to-back is bit-identical to the fused pipeline. (A composition of
/// three DSL programs, not a fourth dataflow.)
pub fn unfused_conv_programs(lanes: usize, h: &[C64]) -> [Program; 3] {
    assert_eq!(h.len(), lanes, "filter length must match lane count");
    [dif_fft_program(lanes), freq_filter_program(h), idit_fft_program(lanes)]
}

define_pcu_program! {
    /// Inclusive Hillis–Steele scan over `lanes` elements: level *b* has
    /// lane *i ≥ 2^b* add lane *i − 2^b* (Fig. 9 left / Fig. 10 top).
    pub fn hs_scan_program(lanes: usize) {
        name: format!("hs-scan{lanes}"),
        mode: HsScan,
        width: lanes,
        let n = log2_lanes(lanes);
        stage shift[b in 0..n] = |i| {
            let stride = 1 << b;
            if i >= stride { ops::add(i - stride) } else { ops::pass() }
        };
    }
}

define_pcu_program! {
    /// Exclusive Blelloch scan over `lanes` elements: `log₂(lanes)` up-sweep
    /// levels build the reduction tree, then `log₂(lanes)` down-sweep levels
    /// distribute prefixes (Fig. 9 right / Fig. 10 bottom). The root zeroing
    /// is folded into the first down-sweep level, so the program needs
    /// exactly `2·log₂(lanes)` stages.
    pub fn b_scan_program(lanes: usize) {
        name: format!("b-scan{lanes}"),
        mode: BScan,
        width: lanes,
        let n = log2_lanes(lanes);
        // Up-sweep: at stride 2^b, tree nodes accumulate their left sibling.
        stage up[b in 0..n] = |i| {
            let stride = 1 << b;
            let group = stride << 1;
            if i % group == group - 1 { ops::add(i - stride) } else { ops::pass() }
        };
        // Down-sweep: the tree pair (left child at `group`-offset stride−1,
        // parent at group−1) exchanges; step 0 folds the root zeroing.
        stage down[step in 0..n] = |i| {
            let stride = 1 << (n - 1 - step);
            let group = stride << 1;
            if i % group == group - 1 {
                // Parent: root takes its (zeroed) left child at step 0,
                // otherwise t + x[i] with t the left child's old value.
                if step == 0 { ops::take(i - stride) } else { ops::add(i - stride) }
            } else if i % group == stride - 1 {
                // Left child: zeroed at the root step, else takes the parent.
                if step == 0 { ops::cnst(C64::ZERO) } else { ops::take(i + stride) }
            } else {
                ops::pass()
            }
        };
    }
}

define_pcu_program! {
    /// Baseline reduction-tree sum into lane 0 (Fig. 2, reduction mode).
    pub fn reduction_program(lanes: usize) {
        name: format!("reduce{lanes}"),
        mode: Reduction,
        width: lanes,
        let n = log2_lanes(lanes);
        stage fold[b in 0..n] = |i| {
            let stride = 1 << b;
            let group = stride << 1;
            if i % group == 0 { ops::add(i + stride) } else { ops::pass() }
        };
    }
}

define_pcu_program! {
    /// Element-wise multiply by per-lane constants — the Bailey
    /// twiddle-scaling step (§III-A step 3), runnable on any PCU in
    /// element-wise mode. Width is `factors.len()`, not necessarily a power
    /// of two: with no cross-lane traffic the DSL skips the fabric check.
    pub fn twiddle_program(factors: &[C64]) {
        name: "twiddle",
        mode: ElementWise,
        width: factors.len(),
        stage twiddle = |i| ops::mul(factors[i]);
    }
}

/// Names accepted by [`demo_program`] — the `debug` CLI's program registry.
pub const DEMO_PROGRAM_NAMES: [&str; 9] = [
    "fft",
    "dif_fft",
    "idit_fft",
    "freq_filter",
    "fused_conv",
    "hs_scan",
    "b_scan",
    "reduction",
    "twiddle",
];

/// Look up a canonical program by name for the `debug` CLI and examples.
/// `-` and `_` are interchangeable in `name`. Programs that need constants
/// (filter taps, twiddle factors) derive them deterministically from
/// `seed`, so a debug session is reproducible from its command line.
pub fn demo_program(name: &str, lanes: usize, seed: u64) -> Option<Program> {
    let mut rng = XorShift::new(seed | 1);
    let rand_c: Vec<C64> = (0..lanes)
        .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
        .collect();
    match name.replace('-', "_").as_str() {
        "fft" => Some(fft_program(lanes)),
        "dif_fft" => Some(dif_fft_program(lanes)),
        "idit_fft" => Some(idit_fft_program(lanes)),
        "freq_filter" => Some(freq_filter_program(&rand_c)),
        "fused_conv" => Some(fused_conv_program(lanes, &rand_c)),
        "hs_scan" => Some(hs_scan_program(lanes)),
        "b_scan" => Some(b_scan_program(lanes)),
        "reduction" => Some(reduction_program(lanes)),
        "twiddle" => {
            let f: Vec<C64> =
                (0..lanes).map(|i| C64::cis(-PI * i as f64 / lanes as f64)).collect();
            Some(twiddle_program(&f))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::PcuGeometry;
    use crate::fft::cooley_tukey;
    use crate::pcusim::engine::Pcu;
    use crate::scan::{blelloch_exclusive, c_scan_exclusive, hillis_steele_inclusive};
    use crate::util::complex::max_abs_diff_c;
    use crate::util::XorShift;

    fn rand_c(rng: &mut XorShift, n: usize) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0))).collect()
    }

    #[test]
    fn fft_program_matches_cooley_tukey_8() {
        let mut rng = XorShift::new(7);
        let pcu = Pcu::fft_mode(PcuGeometry::synthesis());
        let prog = fft_program(8);
        for _ in 0..20 {
            let x = rand_c(&mut rng, 8);
            let got = pcu.eval(&prog, &bit_reverse(&x));
            let want = cooley_tukey::fft(&x);
            assert!(max_abs_diff_c(&got, &want) < 1e-12);
        }
    }

    #[test]
    fn fft_program_matches_cooley_tukey_32() {
        let mut rng = XorShift::new(8);
        let pcu = Pcu::fft_mode(PcuGeometry::table1());
        let prog = fft_program(32);
        for _ in 0..10 {
            let x = rand_c(&mut rng, 32);
            let got = pcu.eval(&prog, &bit_reverse(&x));
            let want = cooley_tukey::fft(&x);
            assert!(max_abs_diff_c(&got, &want) < 1e-11);
        }
    }

    #[test]
    fn fft_program_maps_spatially_only_with_fft_fabric() {
        let prog = fft_program(8);
        assert!(Pcu::fft_mode(PcuGeometry::synthesis()).mappable(&prog).is_ok());
        assert!(Pcu::baseline(PcuGeometry::synthesis()).mappable(&prog).is_err());
        // ...and the scan fabrics don't help:
        assert!(Pcu::hs_scan_mode(PcuGeometry::synthesis()).mappable(&prog).is_err());
    }

    #[test]
    fn hs_program_matches_substrate() {
        let mut rng = XorShift::new(9);
        for lanes in [8usize, 32] {
            let geom = if lanes == 8 { PcuGeometry::synthesis() } else { PcuGeometry::table1() };
            let pcu = Pcu::hs_scan_mode(geom);
            let prog = hs_scan_program(lanes);
            let xs = rng.vec(lanes, -2.0, 2.0);
            let x: Vec<C64> = xs.iter().map(|&v| C64::real(v)).collect();
            let got: Vec<f64> = pcu.eval(&prog, &x).iter().map(|z| z.re).collect();
            let want = hillis_steele_inclusive(&xs);
            assert!(crate::util::max_abs_diff(&got, &want) < 1e-12);
        }
    }

    #[test]
    fn b_program_matches_substrate() {
        let mut rng = XorShift::new(10);
        for lanes in [8usize, 32] {
            let geom = if lanes == 8 { PcuGeometry::synthesis() } else { PcuGeometry::table1() };
            let pcu = Pcu::b_scan_mode(geom);
            let prog = b_scan_program(lanes);
            assert!(pcu.mappable(&prog).is_ok(), "b-scan{lanes} should map spatially");
            let xs = rng.vec(lanes, -2.0, 2.0);
            let x: Vec<C64> = xs.iter().map(|&v| C64::real(v)).collect();
            let got: Vec<f64> = pcu.eval(&prog, &x).iter().map(|z| z.re).collect();
            let want = blelloch_exclusive(&xs);
            assert!(crate::util::max_abs_diff(&got, &want) < 1e-12, "lanes={lanes}");
            // Cross-check against the serial C-scan oracle too.
            let want2 = c_scan_exclusive(&xs);
            assert!(crate::util::max_abs_diff(&got, &want2) < 1e-12);
        }
    }

    #[test]
    fn scan_programs_fail_on_baseline_and_wrong_fabric() {
        let hs = hs_scan_program(8);
        let b = b_scan_program(8);
        let base = Pcu::baseline(PcuGeometry::synthesis());
        assert!(base.mappable(&hs).is_err());
        assert!(base.mappable(&b).is_err());
        // HS program does not fit the B fabric and vice versa.
        assert!(Pcu::b_scan_mode(PcuGeometry::synthesis()).mappable(&hs).is_err());
        assert!(Pcu::hs_scan_mode(PcuGeometry::synthesis()).mappable(&b).is_err());
    }

    #[test]
    fn reduction_program_sums_on_baseline() {
        let pcu = Pcu::baseline(PcuGeometry::synthesis());
        let prog = reduction_program(8);
        assert!(pcu.mappable(&prog).is_ok(), "reduction is a baseline mode");
        let x: Vec<C64> = (1..=8).map(|i| C64::real(i as f64)).collect();
        let y = pcu.eval(&prog, &x);
        assert_eq!(y[0].re, 36.0);
    }

    #[test]
    fn twiddle_program_elementwise() {
        let pcu = Pcu::baseline(PcuGeometry::synthesis());
        let factors: Vec<C64> = (0..8).map(|i| C64::cis(-PI * i as f64 / 8.0)).collect();
        let prog = twiddle_program(&factors);
        assert!(pcu.mappable(&prog).is_ok());
        let x = vec![C64::real(1.0); 8];
        let y = pcu.eval(&prog, &x);
        for (yi, f) in y.iter().zip(&factors) {
            assert!((*yi - *f).abs() < 1e-15);
        }
    }

    #[test]
    fn dif_program_matches_cooley_tukey() {
        // DIF: natural input, bit-reversed output.
        let mut rng = XorShift::new(21);
        for lanes in [8usize, 32] {
            let geom = if lanes == 8 { PcuGeometry::synthesis() } else { PcuGeometry::table1() };
            let pcu = Pcu::fft_mode(geom);
            let prog = dif_fft_program(lanes);
            let x = rand_c(&mut rng, lanes);
            let got = bit_reverse(&pcu.eval(&prog, &x));
            let want = cooley_tukey::fft(&x);
            assert!(max_abs_diff_c(&got, &want) < 1e-11, "lanes={lanes}");
        }
    }

    #[test]
    fn idit_program_is_unnormalized_inverse() {
        let mut rng = XorShift::new(22);
        let pcu = Pcu::fft_mode(PcuGeometry::table1());
        let prog = idit_fft_program(32);
        let spectrum = rand_c(&mut rng, 32);
        let got: Vec<C64> =
            pcu.eval(&prog, &bit_reverse(&spectrum)).iter().map(|z| z.scale(1.0 / 32.0)).collect();
        let want = cooley_tukey::ifft(&spectrum);
        assert!(max_abs_diff_c(&got, &want) < 1e-11);
    }

    #[test]
    fn fused_conv_matches_fft_reference() {
        // y = iFFT(FFT(x) ⊙ FFT(h)), natural order in and out, no external
        // permutes: DIF hands DIT exactly the order it wants.
        let mut rng = XorShift::new(23);
        let lanes = 32;
        let pcu = Pcu::fft_mode(PcuGeometry::table1());
        let h = rand_c(&mut rng, lanes);
        let prog = fused_conv_program(lanes, &h);
        for _ in 0..5 {
            let x = rand_c(&mut rng, lanes);
            let got = pcu.eval(&prog, &x);
            let fx = cooley_tukey::fft(&x);
            let fh = cooley_tukey::fft(&h);
            let prod: Vec<C64> = fx.iter().zip(&fh).map(|(&a, &b)| a * b).collect();
            let want = cooley_tukey::ifft(&prod);
            assert!(max_abs_diff_c(&got, &want) < 1e-10);
        }
    }

    #[test]
    fn fused_conv_real_input_matches_planned_rfft_conv() {
        // Real signals are the serving case (Hyena activations/filters are
        // real); the planned rfft convolution engine is their golden model.
        // The fused PCU pipeline computes the same circular convolution
        // through full complex transforms, so on real inputs its outputs
        // must match the rfft path within 1e-9 with ~zero imaginary parts.
        let mut rng = XorShift::new(26);
        let lanes = 32;
        let pcu = Pcu::fft_mode(PcuGeometry::table1());
        let h_real = rng.vec(lanes, -1.0, 1.0);
        let h: Vec<C64> = h_real.iter().map(|&v| C64::real(v)).collect();
        let prog = fused_conv_program(lanes, &h);
        for _ in 0..10 {
            let x_real = rng.vec(lanes, -1.0, 1.0);
            let x: Vec<C64> = x_real.iter().map(|&v| C64::real(v)).collect();
            let got = pcu.eval(&prog, &x);
            let want = crate::fft::fft_conv_circular(&x_real, &h_real); // planned rfft path
            for (g, w) in got.iter().zip(&want) {
                assert!((g.re - w).abs() < 1e-9, "re: {} vs {w}", g.re);
                assert!(g.im.abs() < 1e-9, "imaginary leakage: {}", g.im);
            }
        }
    }

    #[test]
    fn fused_conv_bit_identical_to_unfused_chain() {
        // Fusion is a scheduling transform: the fused pipeline runs the
        // *same ops in the same order* as the three separate launches, so
        // the outputs are bit-identical, not merely close.
        let mut rng = XorShift::new(24);
        let lanes = 32;
        let pcu = Pcu::fft_mode(PcuGeometry::table1());
        let h = rand_c(&mut rng, lanes);
        let fused = fused_conv_program(lanes, &h);
        let [p1, p2, p3] = unfused_conv_programs(lanes, &h);
        for _ in 0..10 {
            let x = rand_c(&mut rng, lanes);
            let staged = pcu.eval(&p3, &pcu.eval(&p2, &pcu.eval(&p1, &x)));
            let direct = pcu.eval(&fused, &x);
            assert_eq!(staged, direct, "fused and unfused pipelines must be bit-identical");
        }
    }

    #[test]
    fn fused_conv_spatial_on_fft_mode_serialized_on_baseline() {
        let mut rng = XorShift::new(25);
        let lanes = 32;
        let h = rand_c(&mut rng, lanes);
        let prog = fused_conv_program(lanes, &h);
        // 2·log₂32 + 1 = 11 levels fit the 12-stage Table I PCU spatially.
        assert_eq!(prog.levels.len(), 11);
        let fft_pcu = Pcu::fft_mode(PcuGeometry::table1());
        assert!(fft_pcu.mappable(&prog).is_ok(), "{:?}", fft_pcu.mappable(&prog));
        let base = Pcu::baseline(PcuGeometry::table1());
        assert!(base.mappable(&prog).is_err());
        // Serialized execution is slower but functionally identical.
        let x = rand_c(&mut rng, lanes);
        let (outs_b, stats_b) = base.run(&prog, &[x.clone()]);
        let (outs_f, stats_f) = fft_pcu.run(&prog, &[x]);
        assert!(!stats_b.spatial && stats_f.spatial);
        assert_eq!(outs_b, outs_f);
    }

    #[test]
    fn program_depths_fit_geometries() {
        // Table I PCU (32×12): FFT needs 5 ≤ 12, B-scan needs 10 ≤ 12.
        assert_eq!(fft_program(32).levels.len(), 5);
        assert_eq!(b_scan_program(32).levels.len(), 10);
        assert_eq!(hs_scan_program(32).levels.len(), 5);
        // Synthesis PCU (8×6): FFT 3 ≤ 6, B-scan 6 ≤ 6.
        assert_eq!(fft_program(8).levels.len(), 3);
        assert_eq!(b_scan_program(8).levels.len(), 6);
    }

    #[test]
    fn serialized_fft_still_correct_on_baseline() {
        // The baseline PCU *can* run the FFT — just 12× slower (paper
        // §III-B). Functional output must be identical.
        let mut rng = XorShift::new(11);
        let base = Pcu::baseline(PcuGeometry::table1());
        let prog = fft_program(32);
        let x = rand_c(&mut rng, 32);
        let (outs, stats) = base.run(&prog, &[bit_reverse(&x)]);
        assert!(!stats.spatial);
        let want = cooley_tukey::fft(&x);
        assert!(max_abs_diff_c(&outs[0], &want) < 1e-11);
    }

    #[test]
    fn dsl_labels_name_the_fused_stages() {
        // The debugger and timeline rely on these names (`--break-stage
        // filter` in CI); pin them.
        let mut rng = XorShift::new(27);
        let h = rand_c(&mut rng, 8);
        let p = fused_conv_program(8, &h);
        assert_eq!(p.stage_label(0), "dif0");
        assert_eq!(p.stage_label(2), "dif2");
        assert_eq!(p.stage_label(3), "filter");
        assert_eq!(p.stage_label(4), "idit0");
        assert_eq!(p.stage_label(6), "idit2");
        assert_eq!(p.labels.len(), p.levels.len());
    }

    #[test]
    fn demo_program_registry_resolves_all_names() {
        for name in DEMO_PROGRAM_NAMES {
            let p = demo_program(name, 8, 42).unwrap_or_else(|| panic!("{name} must resolve"));
            assert!(!p.levels.is_empty(), "{name}");
            assert_eq!(p.width(), 8, "{name}");
        }
        // Dash/underscore interchangeable; unknown names are None.
        assert!(demo_program("fused-conv", 8, 42).is_some());
        assert!(demo_program("nope", 8, 42).is_none());
    }

    #[test]
    fn demo_program_deterministic_per_seed() {
        let a = demo_program("fused_conv", 8, 7).unwrap();
        let b = demo_program("fused_conv", 8, 7).unwrap();
        let c = demo_program("fused_conv", 8, 8).unwrap();
        assert_eq!(a.levels, b.levels, "same seed, same taps");
        assert_ne!(a.levels, c.levels, "different seed, different taps");
    }
}
