//! Canonical PCU programs for the paper's kernels: the radix-2 FFT
//! (Fig. 5), the Hillis–Steele scan and the Blelloch scan (Figs. 9/10),
//! plus the baseline reduction tree. Each builder emits a [`Program`] whose
//! level-*b* cross-lane traffic exactly matches the mode's boundary-*b*
//! fabric, so `Program::validate_spatial` succeeds on the extended PCU and
//! fails (→ serialized fallback) on the baseline PCU.
//!
//! Functional correctness of every program is asserted against the
//! [`crate::fft`] / [`crate::scan`] substrates in the tests below — the same
//! oracles the Pallas kernels are tested against in `python/tests`, closing
//! the cross-layer loop promised in DESIGN.md §7.

use crate::arch::PcuMode;
use crate::pcusim::program::{Level, Op, Program};
use crate::util::C64;
use std::f64::consts::PI;

/// Bit-reversal permutation of a power-of-two-length slice. On the RDU this
/// reordering is performed by the PMU's address generators while streaming
/// the tile into the PCU (the paper's PMUs own all address computation), so
/// it costs no PCU cycles.
pub fn bit_reverse(x: &[C64]) -> Vec<C64> {
    let n = x.len();
    assert!(n.is_power_of_two());
    let bits = n.trailing_zeros();
    (0..n)
        .map(|i| {
            let j = ((i as u32).reverse_bits() >> (32 - bits)) as usize;
            x[j]
        })
        .collect()
}

/// Radix-2 decimation-in-time FFT over `lanes` complex points, expecting
/// bit-reversed input (see [`bit_reverse`]). Level *b* performs the
/// stride-`2^b` butterflies: the pair-leader lane computes `a + w·b` (MAC)
/// and the partner lane computes `a_partner − w·b_self` via the mirrored MAC
/// — exactly the dataflow Fig. 5 unrolls across the pipeline.
#[allow(clippy::needless_range_loop)] // lanes indexed by butterfly position math
pub fn fft_program(lanes: usize) -> Program {
    assert!(lanes.is_power_of_two() && lanes >= 2);
    let levels_n = lanes.trailing_zeros() as usize;
    let mut levels = Vec::with_capacity(levels_n);
    for b in 0..levels_n {
        let half = 1 << b;
        let len = half << 1;
        let mut ops = vec![Op::Pass; lanes];
        for i in 0..lanes {
            let j = i % len;
            if j < half {
                // x[i] ← x[i] + w_j · x[i+half]
                let w = C64::cis(-2.0 * PI * j as f64 / len as f64);
                ops[i] = Op::Mac { src: i + half, c: w };
            } else {
                // x[i] ← x[i−half] − w_{j−half} · x[i]  =  (−w)·a + b
                let w = C64::cis(-2.0 * PI * (j - half) as f64 / len as f64);
                ops[i] = Op::MacSelf { src: i - half, c: C64::real(-1.0) * w };
            }
        }
        levels.push(Level::new(ops));
    }
    Program::new(&format!("fft{lanes}"), PcuMode::Fft, levels)
}

/// Inclusive Hillis–Steele scan over `lanes` elements: level *b* has lane
/// *i ≥ 2^b* add lane *i − 2^b* (Fig. 9 left / Fig. 10 top).
#[allow(clippy::needless_range_loop)] // lanes indexed by shift-distance math
pub fn hs_scan_program(lanes: usize) -> Program {
    assert!(lanes.is_power_of_two() && lanes >= 2);
    let levels_n = lanes.trailing_zeros() as usize;
    let mut levels = Vec::with_capacity(levels_n);
    for b in 0..levels_n {
        let stride = 1 << b;
        let mut ops = vec![Op::Pass; lanes];
        for i in stride..lanes {
            ops[i] = Op::Add { src: i - stride };
        }
        levels.push(Level::new(ops));
    }
    Program::new(&format!("hs-scan{lanes}"), PcuMode::HsScan, levels)
}

/// Exclusive Blelloch scan over `lanes` elements: `log₂(lanes)` up-sweep
/// levels build the reduction tree, then `log₂(lanes)` down-sweep levels
/// distribute prefixes (Fig. 9 right / Fig. 10 bottom). The root zeroing is
/// folded into the first down-sweep level, so the program needs exactly
/// `2·log₂(lanes)` stages.
pub fn b_scan_program(lanes: usize) -> Program {
    assert!(lanes.is_power_of_two() && lanes >= 2);
    let levels_n = lanes.trailing_zeros() as usize;
    let mut levels = Vec::with_capacity(2 * levels_n);
    // Up-sweep: at stride 2^b, tree nodes accumulate their left sibling.
    for b in 0..levels_n {
        let stride = 1 << b;
        let group = stride << 1;
        let mut ops = vec![Op::Pass; lanes];
        for i in ((group - 1)..lanes).step_by(group) {
            ops[i] = Op::Add { src: i - stride };
        }
        levels.push(Level::new(ops));
    }
    // Down-sweep. First level folds the root-zeroing: after the up-sweep the
    // root would be set to 0, so its left child receives Const(0) and the
    // root receives the left child's value.
    for (step, _) in (0..levels_n).enumerate() {
        let stride = 1 << (levels_n - 1 - step);
        let group = stride << 1;
        let mut ops = vec![Op::Pass; lanes];
        for i in ((group - 1)..lanes).step_by(group) {
            if step == 0 {
                // Root pair: left child ← 0, root ← left child.
                ops[i - stride] = Op::Const(C64::ZERO);
                ops[i] = Op::Take { src: i - stride };
            } else {
                // t = x[i−k]; x[i−k] = x[i]; x[i] = t + x[i].
                ops[i - stride] = Op::Take { src: i };
                ops[i] = Op::Add { src: i - stride };
            }
        }
        levels.push(Level::new(ops));
    }
    Program::new(&format!("b-scan{lanes}"), PcuMode::BScan, levels)
}

/// Baseline reduction-tree sum into lane 0 (Fig. 2, reduction mode).
pub fn reduction_program(lanes: usize) -> Program {
    assert!(lanes.is_power_of_two() && lanes >= 2);
    let levels_n = lanes.trailing_zeros() as usize;
    let mut levels = Vec::with_capacity(levels_n);
    for b in 0..levels_n {
        let stride = 1 << b;
        let group = stride << 1;
        let mut ops = vec![Op::Pass; lanes];
        for i in (0..lanes).step_by(group) {
            ops[i] = Op::Add { src: i + stride };
        }
        levels.push(Level::new(ops));
    }
    Program::new(&format!("reduce{lanes}"), PcuMode::Reduction, levels)
}

/// Element-wise multiply by per-lane constants — the Bailey twiddle-scaling
/// step (§III-A step 3), runnable on any PCU in element-wise mode.
pub fn twiddle_program(factors: &[C64]) -> Program {
    let ops = factors.iter().map(|&c| Op::MulConst(c)).collect();
    Program::new("twiddle", PcuMode::ElementWise, vec![Level::new(ops)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::PcuGeometry;
    use crate::fft::cooley_tukey;
    use crate::pcusim::engine::Pcu;
    use crate::scan::{blelloch_exclusive, c_scan_exclusive, hillis_steele_inclusive};
    use crate::util::complex::max_abs_diff_c;
    use crate::util::XorShift;

    fn rand_c(rng: &mut XorShift, n: usize) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0))).collect()
    }

    #[test]
    fn fft_program_matches_cooley_tukey_8() {
        let mut rng = XorShift::new(7);
        let pcu = Pcu::fft_mode(PcuGeometry::synthesis());
        let prog = fft_program(8);
        for _ in 0..20 {
            let x = rand_c(&mut rng, 8);
            let got = pcu.eval(&prog, &bit_reverse(&x));
            let want = cooley_tukey::fft(&x);
            assert!(max_abs_diff_c(&got, &want) < 1e-12);
        }
    }

    #[test]
    fn fft_program_matches_cooley_tukey_32() {
        let mut rng = XorShift::new(8);
        let pcu = Pcu::fft_mode(PcuGeometry::table1());
        let prog = fft_program(32);
        for _ in 0..10 {
            let x = rand_c(&mut rng, 32);
            let got = pcu.eval(&prog, &bit_reverse(&x));
            let want = cooley_tukey::fft(&x);
            assert!(max_abs_diff_c(&got, &want) < 1e-11);
        }
    }

    #[test]
    fn fft_program_maps_spatially_only_with_fft_fabric() {
        let prog = fft_program(8);
        assert!(Pcu::fft_mode(PcuGeometry::synthesis()).mappable(&prog).is_ok());
        assert!(Pcu::baseline(PcuGeometry::synthesis()).mappable(&prog).is_err());
        // ...and the scan fabrics don't help:
        assert!(Pcu::hs_scan_mode(PcuGeometry::synthesis()).mappable(&prog).is_err());
    }

    #[test]
    fn hs_program_matches_substrate() {
        let mut rng = XorShift::new(9);
        for lanes in [8usize, 32] {
            let geom = if lanes == 8 { PcuGeometry::synthesis() } else { PcuGeometry::table1() };
            let pcu = Pcu::hs_scan_mode(geom);
            let prog = hs_scan_program(lanes);
            let xs = rng.vec(lanes, -2.0, 2.0);
            let x: Vec<C64> = xs.iter().map(|&v| C64::real(v)).collect();
            let got: Vec<f64> = pcu.eval(&prog, &x).iter().map(|z| z.re).collect();
            let want = hillis_steele_inclusive(&xs);
            assert!(crate::util::max_abs_diff(&got, &want) < 1e-12);
        }
    }

    #[test]
    fn b_program_matches_substrate() {
        let mut rng = XorShift::new(10);
        for lanes in [8usize, 32] {
            let geom = if lanes == 8 { PcuGeometry::synthesis() } else { PcuGeometry::table1() };
            let pcu = Pcu::b_scan_mode(geom);
            let prog = b_scan_program(lanes);
            assert!(pcu.mappable(&prog).is_ok(), "b-scan{lanes} should map spatially");
            let xs = rng.vec(lanes, -2.0, 2.0);
            let x: Vec<C64> = xs.iter().map(|&v| C64::real(v)).collect();
            let got: Vec<f64> = pcu.eval(&prog, &x).iter().map(|z| z.re).collect();
            let want = blelloch_exclusive(&xs);
            assert!(crate::util::max_abs_diff(&got, &want) < 1e-12, "lanes={lanes}");
            // Cross-check against the serial C-scan oracle too.
            let want2 = c_scan_exclusive(&xs);
            assert!(crate::util::max_abs_diff(&got, &want2) < 1e-12);
        }
    }

    #[test]
    fn scan_programs_fail_on_baseline_and_wrong_fabric() {
        let hs = hs_scan_program(8);
        let b = b_scan_program(8);
        let base = Pcu::baseline(PcuGeometry::synthesis());
        assert!(base.mappable(&hs).is_err());
        assert!(base.mappable(&b).is_err());
        // HS program does not fit the B fabric and vice versa.
        assert!(Pcu::b_scan_mode(PcuGeometry::synthesis()).mappable(&hs).is_err());
        assert!(Pcu::hs_scan_mode(PcuGeometry::synthesis()).mappable(&b).is_err());
    }

    #[test]
    fn reduction_program_sums_on_baseline() {
        let pcu = Pcu::baseline(PcuGeometry::synthesis());
        let prog = reduction_program(8);
        assert!(pcu.mappable(&prog).is_ok(), "reduction is a baseline mode");
        let x: Vec<C64> = (1..=8).map(|i| C64::real(i as f64)).collect();
        let y = pcu.eval(&prog, &x);
        assert_eq!(y[0].re, 36.0);
    }

    #[test]
    fn twiddle_program_elementwise() {
        let pcu = Pcu::baseline(PcuGeometry::synthesis());
        let factors: Vec<C64> = (0..8).map(|i| C64::cis(-PI * i as f64 / 8.0)).collect();
        let prog = twiddle_program(&factors);
        assert!(pcu.mappable(&prog).is_ok());
        let x = vec![C64::real(1.0); 8];
        let y = pcu.eval(&prog, &x);
        for (yi, f) in y.iter().zip(&factors) {
            assert!((*yi - *f).abs() < 1e-15);
        }
    }

    #[test]
    fn program_depths_fit_geometries() {
        // Table I PCU (32×12): FFT needs 5 ≤ 12, B-scan needs 10 ≤ 12.
        assert_eq!(fft_program(32).levels.len(), 5);
        assert_eq!(b_scan_program(32).levels.len(), 10);
        assert_eq!(hs_scan_program(32).levels.len(), 5);
        // Synthesis PCU (8×6): FFT 3 ≤ 6, B-scan 6 ≤ 6.
        assert_eq!(fft_program(8).levels.len(), 3);
        assert_eq!(b_scan_program(8).levels.len(), 6);
    }

    #[test]
    fn serialized_fft_still_correct_on_baseline() {
        // The baseline PCU *can* run the FFT — just 12× slower (paper
        // §III-B). Functional output must be identical.
        let mut rng = XorShift::new(11);
        let base = Pcu::baseline(PcuGeometry::table1());
        let prog = fft_program(32);
        let x = rand_c(&mut rng, 32);
        let (outs, stats) = base.run(&prog, &[bit_reverse(&x)]);
        assert!(!stats.spatial);
        let want = cooley_tukey::fft(&x);
        assert!(max_abs_diff_c(&outs[0], &want) < 1e-11);
    }
}
