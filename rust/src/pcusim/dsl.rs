//! Declarative authoring of PCU programs: [`ProgramBuilder`], the per-lane
//! op constructors in [`ops`], and the [`define_pcu_program!`](crate::define_pcu_program)
//! macro.
//!
//! Hand-assembling a [`Program`] means nested loops pushing [`Level`]s of
//! [`Op`]s — workable for five butterfly levels, painful for the 11-level
//! fused convolution, and silent about route mistakes until a `mappable`
//! call at *map time*. The DSL moves both costs to authoring time, in the
//! spirit of `y86-pipe-rs`'s `define_stages!` idiom (see SNIPPETS.md):
//!
//! * a program is a list of **named stages** (`dif0…`, `filter`, `idit0…`),
//!   each an op expression over the lane index — single stages or indexed
//!   stage families (`stage bfly[b in 0..n] = |i| …`);
//! * **constant folding** happens in `let` clauses evaluated once at
//!   construction (twiddle tables, frequency-domain filter taps), not per
//!   lane or per run;
//! * every cross-lane edge is checked against [`topology::allows`] when the
//!   builder finishes: an illegal route is a [`DslError::IllegalRoute`]
//!   *naming the stage*, instead of a serialized-fallback surprise (or a
//!   bare `MapError::IllegalEdge`) when the program is later mapped.
//!
//! **Route-check-at-construction is equivalent to the map-time check.**
//! [`topology::allows`] consults the geometry only for lane/boundary bounds
//! and `log₂(lanes)`; given the same lane count it answers identically for
//! every PCU with `stages ≥ levels`. So a program that passes
//! [`ProgramBuilder::finish`] can only fail `Program::validate_spatial` for
//! the honest capacity reasons — `TooDeep`, `WidthMismatch`,
//! `ModeUnavailable` — never for a miswired edge. Programs with no
//! cross-lane traffic (e.g. `twiddle_program`) skip the geometry entirely
//! and may have any width, matching the engine's behaviour.

use crate::arch::{PcuGeometry, PcuMode};
use crate::pcusim::program::{Level, Op, Program};
use crate::pcusim::topology;
use std::fmt;

/// Concise per-lane [`Op`] constructors for DSL stage bodies. One short
/// function per FU configuration keeps `define_pcu_program!` bodies close
/// to the paper's dataflow figures (`mac(i + half, w)` reads like Fig. 5).
pub mod ops {
    use super::Op;
    use crate::util::C64;

    /// `out = a` — forward the lane value unchanged.
    pub fn pass() -> Op {
        Op::Pass
    }

    /// `out = c` — load a constant.
    pub fn cnst(c: C64) -> Op {
        Op::Const(c)
    }

    /// `out = a + b` where `b` is lane `src`'s previous-level value.
    pub fn add(src: usize) -> Op {
        Op::Add { src }
    }

    /// `out = a − b`.
    pub fn sub(src: usize) -> Op {
        Op::Sub { src }
    }

    /// `out = a · c`.
    pub fn mul(c: C64) -> Op {
        Op::MulConst(c)
    }

    /// `out = a + c·b` — the MAC butterfly workhorse.
    pub fn mac(src: usize, c: C64) -> Op {
        Op::Mac { src, c }
    }

    /// `out = c·a + b` — the mirrored MAC (butterfly subtract side).
    pub fn mac_self(src: usize, c: C64) -> Op {
        Op::MacSelf { src, c }
    }

    /// `out = c·(b − a)` — the DIF lower-lane subtract-then-twiddle.
    pub fn twiddle_sub(src: usize, c: C64) -> Op {
        Op::TwiddleSub { src, c }
    }

    /// `out = b` — take the cross-lane value (down-sweep swap).
    pub fn take(src: usize) -> Op {
        Op::Take { src }
    }
}

/// Why a DSL program failed construction. Unlike `MapError` these point at
/// the *authoring* mistake by stage name, before any PCU is in sight.
#[derive(Debug, Clone, PartialEq)]
pub enum DslError {
    /// The program declared no stages.
    Empty { program: String },
    /// A stage's op count differs from the declared lane width.
    RaggedStage { program: String, stage: String, got: usize, want: usize },
    /// A cross-lane op reads a source the mode's fabric does not wire at
    /// this stage boundary (or the source lane is out of range).
    IllegalRoute {
        program: String,
        stage: String,
        level: usize,
        dest: usize,
        src: usize,
        mode: PcuMode,
    },
    /// Cross-lane traffic requires a power-of-two lane count (the butterfly
    /// and scan fabrics are defined on power-of-two widths).
    WidthNotPowerOfTwo { program: String, width: usize },
}

impl fmt::Display for DslError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DslError::Empty { program } => write!(f, "program `{program}` has no stages"),
            DslError::RaggedStage { program, stage, got, want } => write!(
                f,
                "program `{program}` stage `{stage}`: {got} lane ops, expected {want}"
            ),
            DslError::IllegalRoute { program, stage, level, dest, src, mode } => write!(
                f,
                "program `{program}` stage `{stage}` (level {level}): lane {dest} reads \
                 lane {src}, not wired by the {mode} fabric at this boundary"
            ),
            DslError::WidthNotPowerOfTwo { program, width } => write!(
                f,
                "program `{program}`: cross-lane ops need a power-of-two lane count, got {width}"
            ),
        }
    }
}

impl std::error::Error for DslError {}

/// Incremental [`Program`] constructor with route validation at
/// [`finish`](ProgramBuilder::finish) time. The `define_pcu_program!` macro
/// expands to calls on this builder; it is equally usable by hand (the
/// property harness generates random programs through it).
#[derive(Debug, Clone)]
pub struct ProgramBuilder {
    name: String,
    mode: PcuMode,
    width: usize,
    levels: Vec<Level>,
    labels: Vec<String>,
}

impl ProgramBuilder {
    /// Start a program named `name` in interconnect `mode` over `width`
    /// lanes.
    pub fn new(name: impl Into<String>, mode: PcuMode, width: usize) -> Self {
        Self { name: name.into(), mode, width, levels: Vec::new(), labels: Vec::new() }
    }

    /// Append one named stage (one dataflow level) with `ops[lane]` per
    /// lane. Validation is deferred to [`finish`](ProgramBuilder::finish) so
    /// errors can be reported with full program context.
    pub fn stage(&mut self, label: impl Into<String>, ops: Vec<Op>) -> &mut Self {
        self.levels.push(Level::new(ops));
        self.labels.push(label.into());
        self
    }

    /// Number of stages appended so far.
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Validate and build the [`Program`]: width agreement per stage, and
    /// every cross-lane edge admitted by [`topology::allows`] for this mode
    /// at its stage boundary (the construction-time half of
    /// `Program::validate_spatial` — see the module docs for why the two
    /// agree).
    pub fn finish(self) -> Result<Program, DslError> {
        let Self { name, mode, width, levels, labels } = self;
        if levels.is_empty() {
            return Err(DslError::Empty { program: name });
        }
        for (li, level) in levels.iter().enumerate() {
            if level.ops.len() != width {
                return Err(DslError::RaggedStage {
                    program: name,
                    stage: labels[li].clone(),
                    got: level.ops.len(),
                    want: width,
                });
            }
        }
        let has_cross =
            levels.iter().any(|l| l.ops.iter().any(|o| o.cross_src().is_some()));
        if has_cross {
            if !width.is_power_of_two() {
                return Err(DslError::WidthNotPowerOfTwo { program: name, width });
            }
            // The geometry only supplies bounds to `allows`: `stages` is the
            // program's own depth (boundary i < depth always holds) and
            // `levels()` is log₂(width), identical on any same-width PCU.
            let geom = PcuGeometry::new(width, levels.len());
            for (li, level) in levels.iter().enumerate() {
                for (dest, op) in level.ops.iter().enumerate() {
                    if let Some(src) = op.cross_src() {
                        if src >= width || !topology::allows(mode, geom, li, dest, src) {
                            return Err(DslError::IllegalRoute {
                                program: name,
                                stage: labels[li].clone(),
                                level: li,
                                dest,
                                src,
                                mode,
                            });
                        }
                    }
                }
            }
        }
        Ok(Program::new(&name, mode, levels).with_labels(labels))
    }
}

/// Declare a PCU program as named stages — the `define_stages!`-style DSL
/// over [`ProgramBuilder`].
///
/// Grammar (one function per invocation):
///
/// ```text
/// define_pcu_program! {
///     /// Doc comment for the generated function.
///     pub fn my_program(arg: Ty, …) {
///         name: <expr: String or &str>,
///         mode: <PcuMode variant ident>,
///         width: <expr: usize>,
///         let folded = <expr>;                  // constant folding, 0+ times
///         stage single = |lane| <op expr>;      // one level
///         stage fam[i in <range>] = |lane| <op expr>;  // one level per i
///     }
/// }
/// ```
///
/// Expands to `$vis fn my_program(…) -> Program` that builds the stages in
/// order, labels them (`single`, `fam0`, `fam1`, …), and validates every
/// cross-lane route against `topology::allows` at construction, panicking
/// with the offending program/stage on a [`DslError`](crate::pcusim::dsl::DslError)
/// (authoring bugs are programmer errors, caught by the differential tests).
///
/// ```
/// use ssm_rdu::define_pcu_program;
/// use ssm_rdu::pcusim::dsl::ops;
///
/// define_pcu_program! {
///     /// Inclusive Hillis–Steele scan over `lanes` elements.
///     fn my_scan(lanes: usize) {
///         name: format!("my-scan{lanes}"),
///         mode: HsScan,
///         width: lanes,
///         let n = lanes.trailing_zeros() as usize;
///         stage shift[b in 0..n] = |i| {
///             let stride = 1 << b;
///             if i >= stride { ops::add(i - stride) } else { ops::pass() }
///         };
///     }
/// }
///
/// let p = my_scan(8);
/// assert_eq!(p.levels.len(), 3);
/// assert_eq!(p.stage_label(1), "shift1");
/// ```
#[macro_export]
macro_rules! define_pcu_program {
    (
        $(#[$meta:meta])*
        $vis:vis fn $fname:ident ( $($arg:ident : $argty:ty),* $(,)? ) {
            name: $name:expr,
            mode: $mode:ident,
            width: $width:expr,
            $( let $cname:ident = $cval:expr; )*
            $( stage $sname:ident $( [ $ivar:ident in $irange:expr ] )? = |$lane:ident| $body:expr; )+
        }
    ) => {
        $(#[$meta])*
        $vis fn $fname ( $($arg : $argty),* ) -> $crate::pcusim::Program {
            let __width: usize = $width;
            let mut __builder = $crate::pcusim::dsl::ProgramBuilder::new(
                $name,
                $crate::arch::PcuMode::$mode,
                __width,
            );
            $( let $cname = $cval; )*
            $(
                $crate::define_pcu_program!(
                    @stage __builder, __width, $sname $( [ $ivar in $irange ] )?, |$lane| $body
                );
            )+
            match __builder.finish() {
                Ok(p) => p,
                Err(e) => panic!("define_pcu_program!({}): {e}", stringify!($fname)),
            }
        }
    };
    (@stage $b:ident, $w:ident, $sname:ident, $mk:expr) => {
        $b.stage(stringify!($sname), (0..$w).map($mk).collect());
    };
    (@stage $b:ident, $w:ident, $sname:ident [ $ivar:ident in $irange:expr ], $mk:expr) => {
        for $ivar in $irange {
            $b.stage(
                format!("{}{}", stringify!($sname), $ivar),
                (0..$w).map($mk).collect(),
            );
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::C64;

    #[test]
    fn builder_accepts_legal_hs_chain() {
        let mut b = ProgramBuilder::new("hs4", PcuMode::HsScan, 4);
        b.stage("s0", vec![ops::pass(), ops::add(0), ops::add(1), ops::add(2)]);
        b.stage("s1", vec![ops::pass(), ops::pass(), ops::add(0), ops::add(1)]);
        let p = b.finish().unwrap();
        assert_eq!(p.levels.len(), 2);
        assert_eq!(p.stage_label(0), "s0");
        assert_eq!(p.width(), 4);
    }

    #[test]
    fn builder_rejects_empty() {
        let b = ProgramBuilder::new("none", PcuMode::ElementWise, 4);
        assert_eq!(b.finish(), Err(DslError::Empty { program: "none".into() }));
    }

    #[test]
    fn builder_rejects_ragged_stage_by_name() {
        let mut b = ProgramBuilder::new("rag", PcuMode::ElementWise, 4);
        b.stage("ok", vec![ops::pass(); 4]);
        b.stage("bad", vec![ops::pass(); 3]);
        assert_eq!(
            b.finish(),
            Err(DslError::RaggedStage {
                program: "rag".into(),
                stage: "bad".into(),
                got: 3,
                want: 4
            })
        );
    }

    #[test]
    fn builder_rejects_route_not_in_fabric() {
        // Element-wise mode wires no cross-lane edges at all.
        let mut b = ProgramBuilder::new("ew", PcuMode::ElementWise, 4);
        let mut l = vec![ops::pass(); 4];
        l[1] = ops::add(0);
        b.stage("cross", l);
        match b.finish() {
            Err(DslError::IllegalRoute { stage, level, dest, src, mode, .. }) => {
                assert_eq!((stage.as_str(), level, dest, src), ("cross", 0, 1, 0));
                assert_eq!(mode, PcuMode::ElementWise);
            }
            other => panic!("expected IllegalRoute, got {other:?}"),
        }
    }

    #[test]
    fn builder_rejects_wrong_boundary_for_scan_fabric() {
        // HS stride 1 belongs at boundary 0; declaring it at boundary 1 is
        // the classic off-by-one the construction check exists to catch.
        let mut b = ProgramBuilder::new("hs-off", PcuMode::HsScan, 4);
        b.stage("s0", vec![ops::pass(); 4]);
        let mut l = vec![ops::pass(); 4];
        l[1] = ops::add(0); // stride 1 at boundary 1 — fabric has stride 2 here
        b.stage("s1", l);
        assert!(matches!(b.finish(), Err(DslError::IllegalRoute { level: 1, .. })));
    }

    #[test]
    fn builder_rejects_out_of_range_src() {
        let mut b = ProgramBuilder::new("oob", PcuMode::Fft, 4);
        let mut l = vec![ops::pass(); 4];
        l[0] = ops::mac(4, C64::ONE); // src == width
        b.stage("s0", l);
        assert!(matches!(b.finish(), Err(DslError::IllegalRoute { src: 4, .. })));
    }

    #[test]
    fn builder_rejects_non_pow2_width_with_cross_traffic() {
        let mut b = ProgramBuilder::new("odd", PcuMode::Fft, 3);
        let mut l = vec![ops::pass(); 3];
        l[0] = ops::add(1);
        b.stage("s0", l);
        assert_eq!(
            b.finish(),
            Err(DslError::WidthNotPowerOfTwo { program: "odd".into(), width: 3 })
        );
    }

    #[test]
    fn builder_allows_any_width_without_cross_traffic() {
        // The twiddle-scaling case: element-wise, arbitrary length.
        let mut b = ProgramBuilder::new("tw", PcuMode::ElementWise, 5);
        b.stage("scale", (0..5).map(|i| ops::mul(C64::real(i as f64))).collect());
        let p = b.finish().unwrap();
        assert_eq!(p.width(), 5);
    }

    #[test]
    fn dsl_errors_display_name_the_stage() {
        let e = DslError::IllegalRoute {
            program: "p".into(),
            stage: "filter".into(),
            level: 3,
            dest: 1,
            src: 2,
            mode: PcuMode::Fft,
        };
        let msg = e.to_string();
        assert!(msg.contains("filter") && msg.contains("level 3"), "{msg}");
    }

    // Macro smoke tests: families, constant folding, labels, and the
    // construction-time panic (the exemplar programs in `programs.rs` are
    // covered by the differential wall in tests/integration_pcusim_dsl.rs).
    crate::define_pcu_program! {
        /// Two-stage FFT-mode test pipeline with folded constants.
        fn macro_demo(lanes: usize, gain: f64) {
            name: format!("demo{lanes}"),
            mode: Fft,
            width: lanes,
            let g = C64::real(gain);
            let n = lanes.trailing_zeros() as usize;
            stage bfly[b in 0..n] = |i| ops::mac(i ^ (1 << b), g);
            stage scale = |i| {
                let _ = i;
                ops::mul(g)
            };
        }
    }

    #[test]
    fn macro_builds_labeled_families() {
        let p = macro_demo(8, 2.0);
        assert_eq!(p.name, "demo8");
        assert_eq!(p.levels.len(), 4);
        assert_eq!(p.stage_label(0), "bfly0");
        assert_eq!(p.stage_label(2), "bfly2");
        assert_eq!(p.stage_label(3), "scale");
        // Constant folding: the gain landed in every MAC constant.
        assert!(matches!(p.levels[0].ops[0], Op::Mac { src: 1, c } if c == C64::real(2.0)));
    }

    crate::define_pcu_program! {
        /// Illegal on purpose: butterfly edges under element-wise mode.
        fn macro_bad(lanes: usize) {
            name: "bad",
            mode: ElementWise,
            width: lanes,
            stage oops = |i| ops::add(i ^ 1);
        }
    }

    #[test]
    fn macro_route_violation_panics_at_construction_with_fn_name() {
        let err = std::panic::catch_unwind(|| macro_bad(4)).unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("macro_bad") && msg.contains("oops"), "{msg}");
    }
}
