//! Inter-stage interconnect topologies of the PCU (paper Figs. 2, 5, 10).
//!
//! A PCU of `lanes × stages` has `stages` *boundaries*: boundary `b` feeds
//! the inputs of stage `b` from the outputs of stage `b−1` (boundary 0 feeds
//! stage 0 from the PCU input FIFO). Every mode allows the *straight* edge
//! (lane *i* → lane *i*) at every boundary; the modes differ in which
//! **cross-lane** edges exist:
//!
//! * element-wise / systolic — no cross-lane edges between stages (systolic
//!   vertical movement is *within* a stage and modeled by the engine's
//!   streamed MAC, not by boundary edges);
//! * reduction — a binary reduction tree: at boundary `b < log₂(lanes)`,
//!   lane `i` (with `i ≡ 0 mod 2^{b+1}`) also reads lane `i + 2^b`;
//! * **fft** (extension) — full butterfly pairing: every lane `i` may read
//!   its partner `i ⊕ 2^k` for any stride `2^k < lanes`. The canonical
//!   schedule (and the [`cross_lane_edges`] enumeration the mux count is
//!   built from) drives stride `2^b` at boundary `b`, but the routes are
//!   per lane *pair* and time-multiplexed, so [`allows`] accepts any
//!   butterfly stride at any boundary — which is what lets fused
//!   DIF→filter→DIT convolution pipelines schedule descending and
//!   ascending stride ladders back-to-back on one PCU;
//! * **hs-scan** (extension) — Hillis–Steele shifts: at boundary
//!   `b < log₂(lanes)`, lane `i ≥ 2^b` also reads lane `i − 2^b`;
//! * **b-scan** (extension) — Blelloch tree: up-sweep boundaries
//!   `b < log₂(lanes)` give lane `i ≡ 2^{b+1}−1 (mod 2^{b+1})` an edge from
//!   lane `i − 2^b`; down-sweep boundaries `log₂(lanes) ≤ b < 2·log₂(lanes)`
//!   connect each tree pair in *both* directions (the down-sweep swap+add).
//!
//! [`added_mux_count`] counts the cross-lane edges an extension adds — each
//! edge is one extra FU input source, i.e. one W-bit 2:1 mux plus wiring.
//! This count drives the Table IV area/power model in [`crate::synth`].

use crate::arch::{PcuGeometry, PcuMode};

/// A directed cross-lane edge at a stage boundary: the FU at
/// `(dest, stage b)` may additionally read the output of `(src, stage b−1)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Edge {
    pub boundary: usize,
    pub dest: usize,
    pub src: usize,
}

/// Enumerate the cross-lane edges a mode provides on `geom`.
///
/// Straight edges (src == dest) are implicit and not listed.
pub fn cross_lane_edges(mode: PcuMode, geom: PcuGeometry) -> Vec<Edge> {
    let lanes = geom.lanes;
    let levels = geom.levels();
    let mut edges = Vec::new();
    match mode {
        PcuMode::ElementWise | PcuMode::Systolic => {}
        PcuMode::Reduction => {
            // Binary reduction tree folded into the first `levels` boundaries.
            for b in 0..levels.min(geom.stages) {
                let stride = 1 << b;
                let group = stride << 1;
                for dest in (0..lanes).step_by(group) {
                    edges.push(Edge { boundary: b, dest, src: dest + stride });
                }
            }
        }
        PcuMode::Fft => {
            // Full butterfly pairing at each of the first `levels` boundaries
            // (paper Fig. 5): every lane reads its partner lane i ⊕ 2^b.
            for b in 0..levels.min(geom.stages) {
                let stride = 1 << b;
                for dest in 0..lanes {
                    edges.push(Edge { boundary: b, dest, src: dest ^ stride });
                }
            }
        }
        PcuMode::HsScan => {
            // Hillis–Steele shift network (paper Figs. 9/10): at step b,
            // lane i reads lane i − 2^b when it exists.
            for b in 0..levels.min(geom.stages) {
                let stride = 1 << b;
                for dest in stride..lanes {
                    edges.push(Edge { boundary: b, dest, src: dest - stride });
                }
            }
        }
        PcuMode::BScan => {
            // Up-sweep: boundaries 0..levels, tree-parent accumulation.
            for b in 0..levels.min(geom.stages) {
                let stride = 1 << b;
                let group = stride << 1;
                for dest in ((group - 1)..lanes).step_by(group) {
                    edges.push(Edge { boundary: b, dest, src: dest - stride });
                }
            }
            // Down-sweep: boundaries levels..2·levels, strides back down.
            // Each pair (i−k, i) exchanges: left child takes the parent's
            // value, the parent adds the left child's old value.
            for (step, b) in (levels..2 * levels).enumerate() {
                if b >= geom.stages {
                    break;
                }
                let stride = 1 << (levels - 1 - step);
                let group = stride << 1;
                for i in ((group - 1)..lanes).step_by(group) {
                    edges.push(Edge { boundary: b, dest: i - stride, src: i });
                    edges.push(Edge { boundary: b, dest: i, src: i - stride });
                }
            }
        }
    }
    edges
}

/// Does `mode` permit reading `(src, stage b−1)` from `(dest, stage b)`?
///
/// Evaluated in O(1) per query (the spatial validator calls this once per
/// lane per level; wide fused programs made the edge-list scan the old
/// implementation did prohibitively slow).
///
/// The scan/reduction fabrics pin each stride to the boundary of its
/// schedule, exactly as [`cross_lane_edges`] enumerates. The **FFT fabric
/// is boundary-agnostic**: the physical resource is one route + 2:1 mux per
/// butterfly lane pair `(i, i ⊕ 2^k)` (see [`added_mux_count`]), and the
/// configuration schedules which boundary drives each route — so any
/// butterfly stride may appear at any boundary. That is what lets a fused
/// DIF-FFT → filter → DIT-iFFT convolution occupy `2·log₂(lanes)+1`
/// consecutive stages of one FFT-mode PCU, with the forward transform's
/// strides descending while the inverse's ascend.
///
/// Modeling assumption, stated rather than hidden: routing one lane-pair
/// link to a *configurable* boundary needs boundary-select muxing beyond
/// the per-pair 2:1 input mux that [`added_mux_count`] (and therefore the
/// Table IV area/power reproduction) counts. The paper's Table IV prices
/// exactly its fixed-schedule fabrics, so we keep those counts faithful
/// and leave the boundary-select overhead of the fused-conv schedule
/// uncounted; a synth-model extension is the honest follow-up if fused
/// pipelines become a headline area claim.
pub fn allows(mode: PcuMode, geom: PcuGeometry, boundary: usize, dest: usize, src: usize) -> bool {
    if dest == src {
        return true; // straight edge, always present
    }
    if boundary >= geom.stages || dest >= geom.lanes || src >= geom.lanes {
        return false;
    }
    let levels = geom.levels();
    match mode {
        PcuMode::ElementWise | PcuMode::Systolic => false,
        PcuMode::Reduction => {
            if boundary >= levels {
                return false;
            }
            let stride = 1 << boundary;
            let group = stride << 1;
            dest % group == 0 && src == dest + stride
        }
        PcuMode::Fft => {
            // Any butterfly route, any boundary (time-multiplexed fabric).
            let d = dest ^ src;
            d.is_power_of_two() && d < geom.lanes
        }
        PcuMode::HsScan => {
            if boundary >= levels {
                return false;
            }
            let stride = 1 << boundary;
            dest >= stride && src == dest - stride
        }
        PcuMode::BScan => {
            if boundary < levels {
                // Up-sweep: tree parent reads its left sibling.
                let stride = 1 << boundary;
                let group = stride << 1;
                dest % group == group - 1 && src == dest - stride
            } else if boundary < 2 * levels {
                // Down-sweep: the tree pair exchanges in both directions.
                let step = boundary - levels;
                let stride = 1 << (levels - 1 - step);
                let group = stride << 1;
                let hi = dest.max(src);
                let lo = dest.min(src);
                hi % group == group - 1 && hi - lo == stride
            } else {
                false
            }
        }
    }
}

/// Number of 2:1 input muxes an extension mode adds to the PCU — one per
/// **distinct directed lane route** `(dest ← src)` the mode introduces.
///
/// The physical fabric provisions one W-bit route + destination-side 2:1 mux
/// per lane pair and time-multiplexes it across stage boundaries (the same
/// butterfly stride never appears at two boundaries in any of the modes'
/// schedules, and the B-scan down-sweep reuses the up-sweep's tree links in
/// the reverse direction). For the paper's 8×6 synthesis PCU this yields
/// **24 (FFT), 17 (HS-scan), 14 (B-scan)** — the ordering and magnitudes
/// behind Table IV (overheads 1.007× > 1.005× > 1.004×).
pub fn added_mux_count(mode: PcuMode, geom: PcuGeometry) -> usize {
    if !mode.is_extension() {
        return 0;
    }
    let mut routes: std::collections::HashSet<(usize, usize)> = std::collections::HashSet::new();
    for e in cross_lane_edges(mode, geom) {
        routes.insert((e.dest, e.src));
    }
    routes.len()
}

/// Longest wire an extension adds, in lane pitches — drives the wire-load
/// component of the Table IV power model.
pub fn max_wire_span(mode: PcuMode, geom: PcuGeometry) -> usize {
    cross_lane_edges(mode, geom)
        .iter()
        .map(|e| e.dest.abs_diff(e.src))
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn synth() -> PcuGeometry {
        PcuGeometry::synthesis() // 8×6, the Table IV geometry
    }

    #[test]
    fn baseline_modes_add_nothing() {
        for m in [PcuMode::ElementWise, PcuMode::Systolic, PcuMode::Reduction] {
            assert_eq!(added_mux_count(m, synth()), 0, "{m}");
        }
    }

    #[test]
    fn fft_edge_count_8x6() {
        // 8 lanes × log₂8 = 3 boundaries of full butterflies = 24 edges.
        assert_eq!(added_mux_count(PcuMode::Fft, synth()), 24);
    }

    #[test]
    fn hs_edge_count_8x6() {
        // (8−1) + (8−2) + (8−4) = 17 edges.
        assert_eq!(added_mux_count(PcuMode::HsScan, synth()), 17);
    }

    #[test]
    fn bscan_route_count_8x6() {
        // Up-sweep directed routes: 4 + 2 + 1 = 7. The down-sweep's add-edges
        // (i ← i−k) coincide with the up-sweep routes; only the swap
        // direction (i−k ← i) is new: +7 → 14 total.
        let n = added_mux_count(PcuMode::BScan, synth());
        assert_eq!(n, 14);
    }

    #[test]
    fn route_ordering_matches_table4() {
        // Table IV area overhead ordering: FFT (1.007×) > HS (1.005×) >
        // B-scan (1.004×) — exactly the 24 > 17 > 14 route counts.
        let fft = added_mux_count(PcuMode::Fft, synth());
        let hs = added_mux_count(PcuMode::HsScan, synth());
        let b = added_mux_count(PcuMode::BScan, synth());
        assert_eq!((fft, hs, b), (24, 17, 14));
    }

    #[test]
    fn table1_pcu_route_counts() {
        // 32×12 production PCU: butterflies 32·5 = 160, HS Σ(32−2^b) = 129,
        // B-scan 2·(16+8+4+2+1) = 62.
        let g = PcuGeometry::table1();
        assert_eq!(added_mux_count(PcuMode::Fft, g), 160);
        assert_eq!(added_mux_count(PcuMode::HsScan, g), 31 + 30 + 28 + 24 + 16);
        assert_eq!(added_mux_count(PcuMode::BScan, g), 62);
    }

    #[test]
    fn straight_edges_always_allowed() {
        for m in [PcuMode::ElementWise, PcuMode::Fft, PcuMode::BScan] {
            assert!(allows(m, synth(), 3, 5, 5), "{m}");
        }
    }

    #[test]
    fn butterfly_allowed_only_in_fft_mode() {
        assert!(allows(PcuMode::Fft, synth(), 0, 0, 1));
        assert!(!allows(PcuMode::ElementWise, synth(), 0, 0, 1));
        // Reduction tree has (0 ← 1) at boundary 0 too (tree pair):
        assert!(allows(PcuMode::Reduction, synth(), 0, 0, 1));
        // ...but not the mirrored butterfly edge (1 ← 0):
        assert!(!allows(PcuMode::Reduction, synth(), 0, 1, 0));
    }

    #[test]
    fn edges_are_unique() {
        for m in [PcuMode::Reduction, PcuMode::Fft, PcuMode::HsScan, PcuMode::BScan] {
            let edges = cross_lane_edges(m, synth());
            let set: HashSet<_> = edges.iter().copied().collect();
            assert_eq!(edges.len(), set.len(), "{m} has duplicate edges");
        }
    }

    #[test]
    fn edges_within_bounds() {
        for m in [PcuMode::Reduction, PcuMode::Fft, PcuMode::HsScan, PcuMode::BScan] {
            for e in cross_lane_edges(m, PcuGeometry::table1()) {
                assert!(e.dest < 32 && e.src < 32 && e.boundary < 12, "{m} {e:?}");
            }
        }
    }

    #[test]
    fn allows_matches_edge_enumeration() {
        // The O(1) `allows` must agree with the edge enumeration: exactly
        // for the boundary-scheduled modes, as a superset for the
        // time-multiplexed FFT fabric.
        let g = synth();
        for m in [PcuMode::Reduction, PcuMode::HsScan, PcuMode::BScan, PcuMode::Fft] {
            let edges: HashSet<Edge> = cross_lane_edges(m, g).into_iter().collect();
            for boundary in 0..g.stages {
                for dest in 0..g.lanes {
                    for src in 0..g.lanes {
                        if src == dest {
                            continue;
                        }
                        let listed = edges.contains(&Edge { boundary, dest, src });
                        let allowed = allows(m, g, boundary, dest, src);
                        if m == PcuMode::Fft {
                            assert!(!listed || allowed, "{m} {boundary} {dest} {src}");
                        } else {
                            assert_eq!(listed, allowed, "{m} {boundary} {dest} {src}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn fft_routes_are_boundary_agnostic_but_stride_limited() {
        let g = synth(); // 8 lanes
        // Stride-4 butterfly allowed even at boundary 0 and at late stages.
        assert!(allows(PcuMode::Fft, g, 0, 0, 4));
        assert!(allows(PcuMode::Fft, g, 5, 3, 7));
        // Non-butterfly routes still rejected (3 ⊕ 5 = 6, not a stride).
        assert!(!allows(PcuMode::Fft, g, 0, 3, 5));
        // Out-of-range boundary/lanes rejected.
        assert!(!allows(PcuMode::Fft, g, 6, 0, 1));
        assert!(!allows(PcuMode::Fft, g, 0, 0, 8));
    }

    #[test]
    fn wire_span() {
        // FFT's longest butterfly on 8 lanes spans 4 lane pitches.
        assert_eq!(max_wire_span(PcuMode::Fft, synth()), 4);
        assert_eq!(max_wire_span(PcuMode::HsScan, synth()), 4);
        assert_eq!(max_wire_span(PcuMode::ElementWise, synth()), 0);
    }
}
