//! Single-step debugger for PCU programs: a cycle-by-cycle re-enactment of
//! the two execution regimes in [`crate::pcusim::engine`], with visible
//! pipeline registers, NoC route traffic, breakpoints, and deterministic
//! resume.
//!
//! The batch engine computes outputs functionally and *accounts* cycles in
//! closed form (`V + stages − 1` spatial, `V·levels + (stages−1)·levels`
//! serialized). [`DebugSession`] instead advances one cycle per [`step`]
//! call, moving vectors through the stage registers exactly as the closed
//! form assumes — and its [`stats`] at completion are asserted (in the
//! integration tests) to equal the engine's `ExecStats` bit-for-bit, so the
//! debugger cannot drift from the thing it debugs. Op semantics are not
//! duplicated either: each register advance calls the engine's own
//! `eval_level`.
//!
//! State model (spatial): `stages` pipeline registers, each `None` or a
//! `(vector, values)` pair. A step shifts register *s−1* into *s*, applying
//! level *s* when one exists (stage *s* computes level *s*; deeper stages
//! forward unchanged), admits the next input vector into stage 0 through
//! level 0, and pops stage `stages−1` into the output list. Cross-lane
//! reads performed while applying level *s* are recorded as [`RouteFlit`]s
//! at fabric boundary *s* — the same `(boundary, dest, src)` triple
//! `topology::allows` admitted at construction.
//!
//! State model (serialized): one register recirculates at stage 0, applying
//! one level per cycle; after the last vector's last level, `stages − 1`
//! drain cycles per recirculation tick away with the register empty,
//! matching the engine's accounting of the trailing pass-through stages.
//!
//! [`step`]: DebugSession::step
//! [`stats`]: DebugSession::stats

use crate::pcusim::engine::{ExecStats, Pcu};
use crate::pcusim::program::Program;
use crate::util::json::Json;
use crate::util::C64;
use std::fmt;

/// One cross-lane value movement observed during a step: the fabric at
/// `boundary` carried lane `src`'s register value into lane `dest`'s FU.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouteFlit {
    /// Fabric boundary index (= level index being applied).
    pub boundary: usize,
    /// Lane whose FU consumed the value.
    pub dest: usize,
    /// Lane whose register supplied the value.
    pub src: usize,
    /// The value that crossed.
    pub value: C64,
}

/// The contents of one occupied pipeline stage at snapshot time.
#[derive(Debug, Clone, PartialEq)]
pub struct StageSnap {
    /// Pipeline stage index.
    pub stage: usize,
    /// DSL stage label (`dif0`, `filter`, …), `L{i}` for unlabeled
    /// programs, or `pass` for forward-only stages past the program depth.
    pub label: String,
    /// Input vector occupying the stage, if tracked (serialized drain
    /// snapshots carry `None`).
    pub vector: Option<usize>,
    /// Per-lane register values after the stage's level was applied.
    pub values: Vec<C64>,
}

/// A point-in-time dump of the debugger's architectural state: cycle count,
/// admitted/emitted vector counts, every occupied stage register, and the
/// NoC traffic of the most recent step. Round-trips through
/// [`Snapshot::to_json`] / [`Snapshot::from_json`] losslessly (floats are
/// serialized shortest-round-trip), which the regression tests pin.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// Program name.
    pub program: String,
    /// Execution regime.
    pub spatial: bool,
    /// Cycles elapsed.
    pub cycle: u64,
    /// Input vectors admitted so far.
    pub fed: usize,
    /// Output vectors emitted so far.
    pub emitted: usize,
    /// Occupied stage registers.
    pub stages: Vec<StageSnap>,
    /// Cross-lane traffic observed in the most recent step.
    pub noc: Vec<RouteFlit>,
}

fn f64_json(v: f64) -> String {
    // `{:?}` is shortest-round-trip for f64, and for all finite values it
    // is valid JSON number syntax.
    format!("{v:?}")
}

fn c64_from_json(j: &Json) -> Result<C64, String> {
    let a = j.as_arr().ok_or("value must be a [re, im] array")?;
    if a.len() != 2 {
        return Err(format!("value array has {} elements, want 2", a.len()));
    }
    let re = a[0].as_f64().ok_or("re must be a number")?;
    let im = a[1].as_f64().ok_or("im must be a number")?;
    Ok(C64::new(re, im))
}

impl Snapshot {
    /// Serialize to a JSON document (the `debug --json` artifact format).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "{{\"program\": \"{}\", \"spatial\": {}, \"cycle\": {}, \"fed\": {}, \"emitted\": {},",
            self.program.replace('\\', "\\\\").replace('"', "\\\""),
            self.spatial,
            self.cycle,
            self.fed,
            self.emitted
        ));
        s.push_str(" \"stages\": [");
        for (i, st) in self.stages.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            let vector =
                st.vector.map(|v| v.to_string()).unwrap_or_else(|| "null".to_string());
            let values: Vec<String> = st
                .values
                .iter()
                .map(|z| format!("[{}, {}]", f64_json(z.re), f64_json(z.im)))
                .collect();
            s.push_str(&format!(
                "{{\"stage\": {}, \"label\": \"{}\", \"vector\": {}, \"values\": [{}]}}",
                st.stage,
                st.label.replace('\\', "\\\\").replace('"', "\\\""),
                vector,
                values.join(", ")
            ));
        }
        s.push_str("], \"noc\": [");
        for (i, fl) in self.noc.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "{{\"boundary\": {}, \"dest\": {}, \"src\": {}, \"value\": [{}, {}]}}",
                fl.boundary,
                fl.dest,
                fl.src,
                f64_json(fl.value.re),
                f64_json(fl.value.im)
            ));
        }
        s.push_str("]}");
        s
    }

    /// Reconstruct a snapshot from parsed JSON (inverse of [`to_json`]).
    ///
    /// [`to_json`]: Snapshot::to_json
    pub fn from_json(j: &Json) -> Result<Snapshot, String> {
        let field = |k: &str| j.get(k).ok_or_else(|| format!("missing field `{k}`"));
        let program = field("program")?.as_str().ok_or("program must be a string")?.to_string();
        let spatial = match field("spatial")? {
            Json::Bool(b) => *b,
            _ => return Err("spatial must be a bool".into()),
        };
        let cycle = field("cycle")?.as_f64().ok_or("cycle must be a number")? as u64;
        let fed = field("fed")?.as_usize().ok_or("fed must be a non-negative integer")?;
        let emitted = field("emitted")?.as_usize().ok_or("emitted must be a non-negative integer")?;
        let mut stages = Vec::new();
        for st in field("stages")?.as_arr().ok_or("stages must be an array")? {
            let sub = |k: &str| st.get(k).ok_or_else(|| format!("stage missing field `{k}`"));
            let vector = match sub("vector")? {
                Json::Null => None,
                v => Some(v.as_usize().ok_or("vector must be an integer or null")?),
            };
            let values = sub("values")?
                .as_arr()
                .ok_or("values must be an array")?
                .iter()
                .map(c64_from_json)
                .collect::<Result<Vec<_>, _>>()?;
            stages.push(StageSnap {
                stage: sub("stage")?.as_usize().ok_or("stage must be an integer")?,
                label: sub("label")?.as_str().ok_or("label must be a string")?.to_string(),
                vector,
                values,
            });
        }
        let mut noc = Vec::new();
        for fl in field("noc")?.as_arr().ok_or("noc must be an array")? {
            let sub = |k: &str| fl.get(k).ok_or_else(|| format!("flit missing field `{k}`"));
            noc.push(RouteFlit {
                boundary: sub("boundary")?.as_usize().ok_or("boundary must be an integer")?,
                dest: sub("dest")?.as_usize().ok_or("dest must be an integer")?,
                src: sub("src")?.as_usize().ok_or("src must be an integer")?,
                value: c64_from_json(sub("value")?)?,
            });
        }
        Ok(Snapshot { program, spatial, cycle, fed, emitted, stages, noc })
    }

    /// Human-readable dump (the `debug --dump` format). Wide programs elide
    /// per-lane values past the first eight lanes.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "[{}] cycle {} ({}) — fed {}, emitted {}\n",
            self.program,
            self.cycle,
            if self.spatial { "spatial" } else { "serialized" },
            self.fed,
            self.emitted
        ));
        for st in &self.stages {
            let vec_s = st.vector.map(|v| format!("v{v}")).unwrap_or_else(|| "-".to_string());
            let shown = st.values.len().min(8);
            let vals: Vec<String> = st.values[..shown]
                .iter()
                .map(|z| format!("{:+.4}{:+.4}i", z.re, z.im))
                .collect();
            let ell = if st.values.len() > shown { ", …" } else { "" };
            out.push_str(&format!(
                "  stage {:>2} [{:<10}] {:>4}: {}{}\n",
                st.stage,
                st.label,
                vec_s,
                vals.join(" "),
                ell
            ));
        }
        if self.noc.is_empty() {
            out.push_str("  noc: (no cross-lane traffic this cycle)\n");
        } else {
            out.push_str(&format!("  noc: {} flits\n", self.noc.len()));
            for fl in self.noc.iter().take(16) {
                out.push_str(&format!(
                    "    boundary {:>2}: lane {:>2} ← lane {:>2}  ({:+.4}{:+.4}i)\n",
                    fl.boundary, fl.dest, fl.src, fl.value.re, fl.value.im
                ));
            }
            if self.noc.len() > 16 {
                out.push_str(&format!("    … {} more\n", self.noc.len() - 16));
            }
        }
        out
    }
}

/// A breakpoint condition, checked after every step.
pub enum Breakpoint {
    /// Fire when any vector computes the given program stage (level index).
    Stage(usize),
    /// Fire when the cycle counter reaches the given value.
    Cycle(u64),
    /// Fire when the predicate holds on the post-step snapshot.
    Predicate(Box<dyn Fn(&Snapshot) -> bool>),
}

impl fmt::Debug for Breakpoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Breakpoint::Stage(s) => write!(f, "Stage({s})"),
            Breakpoint::Cycle(c) => write!(f, "Cycle({c})"),
            Breakpoint::Predicate(_) => write!(f, "Predicate(..)"),
        }
    }
}

/// What one [`DebugSession::step`] did.
#[derive(Debug, Clone, PartialEq)]
pub struct StepReport {
    /// Cycle counter after the step.
    pub cycle: u64,
    /// `(level, vector)` pairs computed this cycle.
    pub computed: Vec<(usize, usize)>,
    /// Vector whose output was emitted this cycle, if any.
    pub emitted_vector: Option<usize>,
    /// Whether the run is complete after this step.
    pub done: bool,
}

/// A fired breakpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct BreakHit {
    /// Id returned when the breakpoint was registered.
    pub id: usize,
    /// Cycle at which it fired.
    pub cycle: u64,
    /// Level index that triggered a [`Breakpoint::Stage`], if that kind.
    pub stage: Option<usize>,
    /// Vector that computed the triggering level, if applicable.
    pub vector: Option<usize>,
}

/// Why [`DebugSession::run`] / [`DebugSession::run_to`] returned.
#[derive(Debug, Clone, PartialEq)]
pub enum RunOutcome {
    /// A breakpoint fired.
    Break(BreakHit),
    /// `run_to` reached its target cycle without a break.
    AtCycle(u64),
    /// The batch completed.
    Done,
}

#[derive(Clone)]
struct StageReg {
    vector: usize,
    values: Vec<C64>,
}

struct SerialState {
    vector: usize,
    level: usize,
    values: Vec<C64>,
    /// Level applied in the most recent step (labels the stage-0 snapshot).
    last_applied: Option<usize>,
    /// Remaining engine-accounted drain cycles after the last level.
    drain_left: u64,
}

/// An interactive, single-steppable execution of one program over one input
/// batch. Construct with [`DebugSession::new`], advance with
/// [`step`](DebugSession::step) / [`run`](DebugSession::run) /
/// [`run_to`](DebugSession::run_to), inspect with
/// [`snapshot`](DebugSession::snapshot). Stepping is deterministic: the
/// sequence of snapshots is a pure function of `(pcu, program, inputs)`, so
/// resuming after any break reproduces the uninterrupted run exactly.
pub struct DebugSession<'p> {
    pcu: Pcu,
    prog: &'p Program,
    inputs: Vec<Vec<C64>>,
    spatial: bool,
    cycle: u64,
    next_input: usize,
    /// Spatial regime: one register per pipeline stage.
    regs: Vec<Option<StageReg>>,
    /// Serialized regime state (`None` when spatial).
    serial: Option<SerialState>,
    outputs: Vec<Vec<C64>>,
    last_computed: Vec<(usize, usize)>,
    last_traffic: Vec<RouteFlit>,
    breakpoints: Vec<(usize, Breakpoint)>,
    next_bp_id: usize,
}

impl<'p> DebugSession<'p> {
    /// Start a session. Picks the regime the engine's [`Pcu::run`] would:
    /// spatial when `pcu.mappable(prog)` holds, serialized otherwise.
    pub fn new(pcu: Pcu, prog: &'p Program, inputs: Vec<Vec<C64>>) -> Self {
        assert!(!inputs.is_empty(), "debug session needs at least one input vector");
        assert!(!prog.levels.is_empty(), "debug session needs a non-empty program");
        for v in &inputs {
            assert_eq!(v.len(), pcu.geom.lanes, "input width != lanes");
        }
        assert_eq!(prog.width(), pcu.geom.lanes, "program width != lanes");
        let spatial = pcu.mappable(prog).is_ok();
        let stages = pcu.geom.stages;
        Self {
            pcu,
            prog,
            inputs,
            spatial,
            cycle: 0,
            next_input: 0,
            regs: (0..stages).map(|_| None).collect(),
            serial: None,
            outputs: Vec::new(),
            last_computed: Vec::new(),
            last_traffic: Vec::new(),
            breakpoints: Vec::new(),
            next_bp_id: 0,
        }
    }

    /// Whether the regime is spatial (true) or serialized (false).
    pub fn is_spatial(&self) -> bool {
        self.spatial
    }

    /// Cycles elapsed.
    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Outputs emitted so far, in input order.
    pub fn outputs(&self) -> &[Vec<C64>] {
        &self.outputs
    }

    /// Has every vector been emitted (and, when serialized, the pipeline
    /// fully drained)?
    pub fn is_done(&self) -> bool {
        let emitted_all = self.outputs.len() == self.inputs.len();
        match &self.serial {
            Some(s) => emitted_all && s.drain_left == 0,
            None => emitted_all,
        }
    }

    /// Execution statistics, available once [`is_done`](DebugSession::is_done)
    /// — constructed from the stepped cycle counter, and equal to what
    /// [`Pcu::run`] reports for the same `(program, inputs)`.
    pub fn stats(&self) -> Option<ExecStats> {
        if !self.is_done() {
            return None;
        }
        let v = self.inputs.len() as u64;
        Some(ExecStats {
            cycles: self.cycle,
            useful_fu_cycles: v * self.prog.useful_ops() as u64,
            total_fu_cycles: self.cycle * self.pcu.geom.fu_count() as u64,
            vectors: v,
            spatial: self.spatial,
        })
    }

    /// Register a breakpoint on a program stage by level index.
    pub fn break_on_stage(&mut self, level: usize) -> usize {
        self.add_bp(Breakpoint::Stage(level))
    }

    /// Register a breakpoint on a program stage by DSL label (`filter`,
    /// `dif2`, or the `L{i}` fallback). `None` if no stage has that label.
    pub fn break_on_label(&mut self, label: &str) -> Option<usize> {
        let idx = (0..self.prog.levels.len()).find(|&i| self.prog.stage_label(i) == label)?;
        Some(self.break_on_stage(idx))
    }

    /// Register a breakpoint on an absolute cycle number.
    pub fn break_on_cycle(&mut self, cycle: u64) -> usize {
        self.add_bp(Breakpoint::Cycle(cycle))
    }

    /// Register a predicate breakpoint evaluated on each post-step snapshot.
    pub fn break_when(&mut self, pred: impl Fn(&Snapshot) -> bool + 'static) -> usize {
        self.add_bp(Breakpoint::Predicate(Box::new(pred)))
    }

    /// Remove a breakpoint by id; `true` if it existed.
    pub fn clear_breakpoint(&mut self, id: usize) -> bool {
        let before = self.breakpoints.len();
        self.breakpoints.retain(|(bid, _)| *bid != id);
        self.breakpoints.len() != before
    }

    fn add_bp(&mut self, bp: Breakpoint) -> usize {
        let id = self.next_bp_id;
        self.next_bp_id += 1;
        self.breakpoints.push((id, bp));
        id
    }

    /// Advance one cycle. Panics if the run is already complete.
    pub fn step(&mut self) -> StepReport {
        assert!(!self.is_done(), "step() after completion");
        if self.spatial {
            self.step_spatial()
        } else {
            self.step_serialized()
        }
    }

    fn record_traffic(
        traffic: &mut Vec<RouteFlit>,
        prog: &Program,
        level: usize,
        prev: &[C64],
    ) {
        for (dest, op) in prog.levels[level].ops.iter().enumerate() {
            if let Some(src) = op.cross_src() {
                traffic.push(RouteFlit { boundary: level, dest, src, value: prev[src] });
            }
        }
    }

    fn step_spatial(&mut self) -> StepReport {
        let stages = self.pcu.geom.stages;
        let depth = self.prog.levels.len();
        let mut computed = Vec::new();
        let mut traffic = Vec::new();
        let mut new_regs: Vec<Option<StageReg>> = (0..stages).map(|_| None).collect();
        // Shift stage s−1 into stage s, applying level s where one exists.
        for s in 1..stages {
            if let Some(r) = self.regs[s - 1].take() {
                let values = if s < depth {
                    Self::record_traffic(&mut traffic, self.prog, s, &r.values);
                    computed.push((s, r.vector));
                    Pcu::eval_level(&self.prog.levels[s], &r.values)
                } else {
                    r.values
                };
                new_regs[s] = Some(StageReg { vector: r.vector, values });
            }
        }
        // Admit the next input vector into stage 0 through level 0.
        if self.next_input < self.inputs.len() {
            let vector = self.next_input;
            let input = &self.inputs[vector];
            Self::record_traffic(&mut traffic, self.prog, 0, input);
            computed.push((0, vector));
            let values = Pcu::eval_level(&self.prog.levels[0], input);
            new_regs[0] = Some(StageReg { vector, values });
            self.next_input += 1;
        }
        // The last stage doubles as the output latch: whatever reaches it
        // is emitted this cycle (this is what makes a batch of V vectors
        // finish in exactly V + stages − 1 cycles).
        let mut emitted_vector = None;
        if let Some(r) = new_regs[stages - 1].take() {
            emitted_vector = Some(r.vector);
            self.outputs.push(r.values);
        }
        self.regs = new_regs;
        self.cycle += 1;
        computed.sort_unstable();
        self.last_computed = computed.clone();
        self.last_traffic = traffic;
        StepReport { cycle: self.cycle, computed, emitted_vector, done: self.is_done() }
    }

    fn step_serialized(&mut self) -> StepReport {
        let stages = self.pcu.geom.stages as u64;
        let depth = self.prog.levels.len();
        let mut computed = Vec::new();
        let mut traffic = Vec::new();
        let mut emitted_vector = None;
        // Lazily start the first recirculation.
        if self.serial.is_none() {
            self.serial = Some(SerialState {
                vector: 0,
                level: 0,
                values: self.inputs[0].clone(),
                last_applied: None,
                drain_left: (stages - 1) * depth as u64,
            });
            self.next_input = 1;
        }
        let s = self.serial.as_mut().expect("serialized state initialized above");
        if s.vector < self.inputs.len() {
            // Work cycle: stage 0 applies one level to the resident vector.
            Self::record_traffic(&mut traffic, self.prog, s.level, &s.values);
            s.values = Pcu::eval_level(&self.prog.levels[s.level], &s.values);
            computed.push((s.level, s.vector));
            s.last_applied = Some(s.level);
            s.level += 1;
            if s.level == depth {
                emitted_vector = Some(s.vector);
                self.outputs.push(std::mem::take(&mut s.values));
                s.vector += 1;
                s.level = 0;
                if s.vector < self.inputs.len() {
                    s.values = self.inputs[s.vector].clone();
                    self.next_input = s.vector + 1;
                } else {
                    s.last_applied = None;
                }
            }
        } else {
            // Drain cycle: the final recirculations still traverse the
            // forward-only tail of the pipeline.
            s.drain_left -= 1;
        }
        self.cycle += 1;
        self.last_computed = computed.clone();
        self.last_traffic = traffic;
        StepReport { cycle: self.cycle, computed, emitted_vector, done: self.is_done() }
    }

    fn check_breakpoints(&self, snap_cache: &mut Option<Snapshot>) -> Option<BreakHit> {
        for (id, bp) in &self.breakpoints {
            let hit = match bp {
                Breakpoint::Stage(level) => {
                    self.last_computed.iter().find(|(l, _)| l == level).map(|&(l, v)| BreakHit {
                        id: *id,
                        cycle: self.cycle,
                        stage: Some(l),
                        vector: Some(v),
                    })
                }
                Breakpoint::Cycle(c) => (self.cycle == *c)
                    .then_some(BreakHit { id: *id, cycle: self.cycle, stage: None, vector: None }),
                Breakpoint::Predicate(pred) => {
                    let snap = snap_cache.get_or_insert_with(|| self.snapshot());
                    pred(snap).then_some(BreakHit {
                        id: *id,
                        cycle: self.cycle,
                        stage: None,
                        vector: None,
                    })
                }
            };
            if hit.is_some() {
                return hit;
            }
        }
        None
    }

    /// Step until a breakpoint fires or the batch completes. Always takes
    /// at least one step, so calling `run()` again after a break resumes
    /// past it instead of re-firing on the same cycle.
    pub fn run(&mut self) -> RunOutcome {
        loop {
            self.step();
            let mut cache = None;
            if let Some(hit) = self.check_breakpoints(&mut cache) {
                return RunOutcome::Break(hit);
            }
            if self.is_done() {
                return RunOutcome::Done;
            }
        }
    }

    /// Step until the cycle counter reaches `target`, a breakpoint fires,
    /// or the batch completes — whichever comes first.
    pub fn run_to(&mut self, target: u64) -> RunOutcome {
        while self.cycle < target {
            if self.is_done() {
                return RunOutcome::Done;
            }
            self.step();
            let mut cache = None;
            if let Some(hit) = self.check_breakpoints(&mut cache) {
                return RunOutcome::Break(hit);
            }
        }
        if self.is_done() {
            RunOutcome::Done
        } else {
            RunOutcome::AtCycle(self.cycle)
        }
    }

    /// Dump the current architectural state.
    pub fn snapshot(&self) -> Snapshot {
        let depth = self.prog.levels.len();
        let mut stages = Vec::new();
        if self.spatial {
            for (s, reg) in self.regs.iter().enumerate() {
                if let Some(r) = reg {
                    let label = if s < depth {
                        self.prog.stage_label(s)
                    } else {
                        "pass".to_string()
                    };
                    stages.push(StageSnap {
                        stage: s,
                        label,
                        vector: Some(r.vector),
                        values: r.values.clone(),
                    });
                }
            }
        } else if let Some(s) = &self.serial {
            if s.vector < self.inputs.len() {
                let label = match s.last_applied {
                    Some(li) => self.prog.stage_label(li),
                    None => "fetch".to_string(),
                };
                stages.push(StageSnap {
                    stage: 0,
                    label,
                    vector: Some(s.vector),
                    values: s.values.clone(),
                });
            }
        }
        Snapshot {
            program: self.prog.name.clone(),
            spatial: self.spatial,
            cycle: self.cycle,
            fed: self.next_input,
            emitted: self.outputs.len(),
            stages,
            noc: self.last_traffic.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::PcuGeometry;
    use crate::pcusim::programs::{fused_conv_program, hs_scan_program};
    use crate::util::XorShift;

    fn rand_batch(rng: &mut XorShift, v: usize, lanes: usize) -> Vec<Vec<C64>> {
        (0..v)
            .map(|_| {
                (0..lanes)
                    .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn spatial_cycle_count_matches_engine_closed_form() {
        let mut rng = XorShift::new(31);
        let geom = PcuGeometry::synthesis();
        let pcu = Pcu::hs_scan_mode(geom);
        let prog = hs_scan_program(8);
        let inputs = rand_batch(&mut rng, 5, 8);
        let mut dbg = DebugSession::new(pcu, &prog, inputs.clone());
        assert!(dbg.is_spatial());
        while !dbg.is_done() {
            dbg.step();
        }
        let (want_out, want_stats) = pcu.run(&prog, &inputs);
        assert_eq!(dbg.outputs(), &want_out[..]);
        assert_eq!(dbg.stats().unwrap(), want_stats);
        assert_eq!(dbg.cycle(), 5 + 6 - 1);
    }

    #[test]
    fn serialized_cycle_count_matches_engine_closed_form() {
        let mut rng = XorShift::new(32);
        let geom = PcuGeometry::synthesis();
        let pcu = Pcu::baseline(geom);
        let prog = hs_scan_program(8); // needs HS fabric → serializes
        let inputs = rand_batch(&mut rng, 3, 8);
        let mut dbg = DebugSession::new(pcu, &prog, inputs.clone());
        assert!(!dbg.is_spatial());
        while !dbg.is_done() {
            dbg.step();
        }
        let (want_out, want_stats) = pcu.run(&prog, &inputs);
        assert_eq!(dbg.outputs(), &want_out[..]);
        assert_eq!(dbg.stats().unwrap(), want_stats);
        assert_eq!(dbg.cycle(), 3 * 3 + (6 - 1) * 3);
    }

    #[test]
    fn stage_breakpoint_fires_when_level_first_computes() {
        let mut rng = XorShift::new(33);
        let pcu = Pcu::fft_mode(PcuGeometry::table1());
        let h = (0..32).map(|_| C64::new(rng.uniform(-1.0, 1.0), 0.0)).collect::<Vec<_>>();
        let prog = fused_conv_program(32, &h);
        let inputs = rand_batch(&mut rng, 4, 32);
        let mut dbg = DebugSession::new(pcu, &prog, inputs);
        let id = dbg.break_on_label("filter").expect("fused conv has a filter stage");
        // filter is level 5 at 32 lanes: vector 0 computes it when it
        // reaches stage 5, i.e. at cycle 6.
        match dbg.run() {
            RunOutcome::Break(hit) => {
                assert_eq!(hit.id, id);
                assert_eq!(hit.cycle, 6);
                assert_eq!(hit.stage, Some(5));
                assert_eq!(hit.vector, Some(0));
            }
            other => panic!("expected break, got {other:?}"),
        }
        // While vector 0 sits in the filter stage, vectors 1..5 are in the
        // dif stages generating cross-lane traffic.
        let snap = dbg.snapshot();
        assert!(!snap.noc.is_empty(), "dif stages must show NoC traffic");
        // Resuming fires again for vector 1, one cycle later.
        match dbg.run() {
            RunOutcome::Break(hit) => {
                assert_eq!(hit.cycle, 7);
                assert_eq!(hit.vector, Some(1));
            }
            other => panic!("expected second break, got {other:?}"),
        }
    }

    #[test]
    fn cycle_breakpoint_and_run_to() {
        let mut rng = XorShift::new(34);
        let pcu = Pcu::hs_scan_mode(PcuGeometry::synthesis());
        let prog = hs_scan_program(8);
        let inputs = rand_batch(&mut rng, 6, 8);
        let mut dbg = DebugSession::new(pcu, &prog, inputs);
        assert_eq!(dbg.run_to(4), RunOutcome::AtCycle(4));
        assert_eq!(dbg.cycle(), 4);
        let id = dbg.break_on_cycle(7);
        match dbg.run() {
            RunOutcome::Break(hit) => {
                assert_eq!((hit.id, hit.cycle), (id, 7));
            }
            other => panic!("expected break, got {other:?}"),
        }
        assert_eq!(dbg.run(), RunOutcome::Done);
        assert!(dbg.is_done());
    }

    #[test]
    fn resume_after_break_equals_uninterrupted_run() {
        let mut rng = XorShift::new(35);
        for (pcu, label) in [
            (Pcu::hs_scan_mode(PcuGeometry::synthesis()), "spatial"),
            (Pcu::baseline(PcuGeometry::synthesis()), "serialized"),
        ] {
            let prog = hs_scan_program(8);
            let inputs = rand_batch(&mut rng, 7, 8);
            let mut interrupted = DebugSession::new(pcu, &prog, inputs.clone());
            interrupted.break_on_stage(1);
            let mut breaks = 0usize;
            loop {
                match interrupted.run() {
                    RunOutcome::Break(_) => breaks += 1,
                    RunOutcome::Done => break,
                    other => panic!("unexpected {other:?}"),
                }
            }
            assert!(breaks > 0, "{label}: stage breakpoint never fired");
            let (want_out, want_stats) = pcu.run(&prog, &inputs);
            assert_eq!(interrupted.outputs(), &want_out[..], "{label}");
            assert_eq!(interrupted.stats().unwrap(), want_stats, "{label}");
        }
    }

    #[test]
    fn predicate_breakpoint_sees_snapshots() {
        let mut rng = XorShift::new(36);
        let pcu = Pcu::hs_scan_mode(PcuGeometry::synthesis());
        let prog = hs_scan_program(8);
        let inputs = rand_batch(&mut rng, 4, 8);
        let mut dbg = DebugSession::new(pcu, &prog, inputs);
        dbg.break_when(|s| s.emitted >= 2);
        match dbg.run() {
            RunOutcome::Break(hit) => {
                // Vector 1 exits at cycle stages + 1 = 7.
                assert_eq!(hit.cycle, 7);
            }
            other => panic!("expected break, got {other:?}"),
        }
    }

    #[test]
    fn snapshot_json_round_trip() {
        let mut rng = XorShift::new(37);
        let pcu = Pcu::hs_scan_mode(PcuGeometry::synthesis());
        let prog = hs_scan_program(8);
        let inputs = rand_batch(&mut rng, 4, 8);
        let mut dbg = DebugSession::new(pcu, &prog, inputs);
        dbg.run_to(3);
        let snap = dbg.snapshot();
        assert!(!snap.noc.is_empty());
        let doc = snap.to_json();
        let parsed = Json::parse(&doc).unwrap_or_else(|e| panic!("emitted invalid JSON: {e}"));
        let back = Snapshot::from_json(&parsed).expect("round-trip failed");
        assert_eq!(back, snap, "snapshot must survive the JSON round-trip exactly");
    }

    #[test]
    fn render_mentions_stages_and_noc() {
        let mut rng = XorShift::new(38);
        let pcu = Pcu::hs_scan_mode(PcuGeometry::synthesis());
        let prog = hs_scan_program(8);
        let inputs = rand_batch(&mut rng, 2, 8);
        let mut dbg = DebugSession::new(pcu, &prog, inputs);
        dbg.run_to(2);
        let text = dbg.snapshot().render();
        assert!(text.contains("cycle 2"));
        assert!(text.contains("shift0"), "labeled stage missing from dump:\n{text}");
        assert!(text.contains("noc:"));
    }
}
