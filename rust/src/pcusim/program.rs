//! PCU program representation and validation.
//!
//! A [`Program`] is a sequence of *levels*; each level assigns one [`Op`] to
//! every lane. Levels are the logical dataflow steps of the kernel
//! (butterfly levels of an FFT, shift steps of a Hillis–Steele scan, …).
//! Mapping a program onto a PCU assigns consecutive levels to consecutive
//! pipeline stages; an op whose cross-lane source is not wired in the PCU's
//! configured mode makes the spatial mapping invalid, in which case the
//! engine falls back to the paper's "first stage only" serialized execution
//! (§III-B: *"mapping Vector FFT onto the baseline PCU restricts execution
//! to only the first stage of the pipeline"*).

use crate::arch::{PcuGeometry, PcuMode};
use crate::pcusim::topology;
use crate::util::C64;
use std::fmt;

/// One functional-unit operation. `a` denotes the straight input (same lane,
/// previous level); `b` denotes the cross-lane input from `src`; `c` is the
/// FU's constant port — matching the paper's four-input FU (Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    /// `out = a` — forward the lane value (consumes no arithmetic FU slot).
    Pass,
    /// `out = c` — load a constant.
    Const(C64),
    /// `out = a + b` — scalar addition across lanes.
    Add { src: usize },
    /// `out = a − b` — subtraction (add with negated operand).
    Sub { src: usize },
    /// `out = a · c` — scalar multiply by constant.
    MulConst(C64),
    /// `out = a + c·b` — multiply-and-accumulate, the butterfly/scan
    /// workhorse (paper Fig. 2's MAC configuration).
    Mac { src: usize, c: C64 },
    /// `out = c·a + b` — the mirrored MAC (butterfly subtract side:
    /// `x[i] − w·x[p]` computed on lane `p` as `(−w)·a + b`). The FU's
    /// mul/add units operate "between any two of the four input sources"
    /// (paper Fig. 2), so both MAC orientations are single-FU operations.
    MacSelf { src: usize, c: C64 },
    /// `out = c·(b − a)` — the decimation-in-frequency butterfly's
    /// lower-lane op: subtract-then-twiddle. Like the MACs this is one
    /// subtract plus one multiply on two of the FU's four input sources
    /// (paper Fig. 2), just wired difference-first instead of
    /// product-first; the fused DIF→filter→DIT convolution pipeline needs
    /// it because DIF emits `(a − b)·w`, not `a − w·b`.
    TwiddleSub { src: usize, c: C64 },
    /// `out = b` — take the cross-lane value (down-sweep swap).
    Take { src: usize },
}

impl Op {
    /// Lane this op reads across the fabric, if any.
    pub fn cross_src(&self) -> Option<usize> {
        match *self {
            Op::Add { src }
            | Op::Sub { src }
            | Op::Mac { src, .. }
            | Op::MacSelf { src, .. }
            | Op::TwiddleSub { src, .. }
            | Op::Take { src } => Some(src),
            _ => None,
        }
    }

    /// Does this op perform useful arithmetic (counted for utilization)?
    pub fn is_useful(&self) -> bool {
        !matches!(self, Op::Pass)
    }

    /// Real-FLOP cost of the op under the paper's FP16 accounting
    /// (complex MAC on a complex-valued lane = 1 mul + 1 add slot; the
    /// engine works in C64 for numerical convenience but costs ops as the
    /// scalar FUs the paper describes).
    pub fn flops(&self) -> f64 {
        match self {
            Op::Pass | Op::Const(_) | Op::Take { .. } => 0.0,
            Op::Add { .. } | Op::Sub { .. } | Op::MulConst(_) => 1.0,
            Op::Mac { .. } | Op::MacSelf { .. } | Op::TwiddleSub { .. } => 2.0,
        }
    }
}

/// One dataflow level: an op per lane.
#[derive(Debug, Clone, PartialEq)]
pub struct Level {
    pub ops: Vec<Op>,
}

impl Level {
    pub fn new(ops: Vec<Op>) -> Self {
        Self { ops }
    }

    /// A level that passes every lane through unchanged.
    pub fn pass(lanes: usize) -> Self {
        Self { ops: vec![Op::Pass; lanes] }
    }

    /// Number of ops doing useful arithmetic.
    pub fn useful_ops(&self) -> usize {
        self.ops.iter().filter(|o| o.is_useful()).count()
    }

    /// Is this level mappable at `boundary` of a PCU in `mode`?
    pub fn mappable(&self, mode: PcuMode, geom: PcuGeometry, boundary: usize) -> bool {
        self.ops.iter().enumerate().all(|(dest, op)| match op.cross_src() {
            None => true,
            Some(src) => topology::allows(mode, geom, boundary, dest, src),
        })
    }
}

/// A complete PCU program: the kernel's dataflow levels plus the mode whose
/// interconnect the cross-lane traffic assumes.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    /// The interconnect mode this program's cross-lane ops require.
    pub mode: PcuMode,
    pub levels: Vec<Level>,
    /// Human-readable kernel name for reports.
    pub name: String,
    /// Per-level stage labels (`dif0`, `filter`, …). Populated by the
    /// `define_pcu_program!` DSL; empty for hand-assembled programs, in
    /// which case [`Program::stage_label`] falls back to `L{i}`.
    pub labels: Vec<String>,
}

/// Why a program cannot be spatially mapped onto a PCU configuration.
#[derive(Debug, Clone, PartialEq)]
pub enum MapError {
    /// The PCU configuration lacks the required interconnect mode.
    ModeUnavailable { required: PcuMode },
    /// More levels than pipeline stages — needs multi-pass execution.
    TooDeep { levels: usize, stages: usize },
    /// A lane op reads a source the mode's fabric does not wire.
    IllegalEdge { level: usize, dest: usize, src: usize },
    /// Lane-count mismatch between program and PCU.
    WidthMismatch { program: usize, pcu: usize },
}

impl fmt::Display for MapError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MapError::ModeUnavailable { required } => {
                write!(f, "PCU configuration lacks required mode `{required}`")
            }
            MapError::TooDeep { levels, stages } => {
                write!(f, "program has {levels} levels but PCU has {stages} stages")
            }
            MapError::IllegalEdge { level, dest, src } => {
                write!(f, "level {level}: lane {dest} reads lane {src}, not wired in this mode")
            }
            MapError::WidthMismatch { program, pcu } => {
                write!(f, "program width {program} != PCU lanes {pcu}")
            }
        }
    }
}

impl Program {
    pub fn new(name: &str, mode: PcuMode, levels: Vec<Level>) -> Self {
        let width = levels.first().map(|l| l.ops.len()).unwrap_or(0);
        assert!(
            levels.iter().all(|l| l.ops.len() == width),
            "all levels of `{name}` must have equal width"
        );
        Self { mode, levels, name: name.to_string(), labels: Vec::new() }
    }

    /// Attach per-level stage labels (the DSL's named stages). Must supply
    /// exactly one label per level.
    pub fn with_labels(mut self, labels: Vec<String>) -> Self {
        assert_eq!(
            labels.len(),
            self.levels.len(),
            "`{}`: {} labels for {} levels",
            self.name,
            labels.len(),
            self.levels.len()
        );
        self.labels = labels;
        self
    }

    /// Label of level `i`: the DSL stage name when present, `L{i}` otherwise.
    pub fn stage_label(&self, i: usize) -> String {
        self.labels.get(i).cloned().unwrap_or_else(|| format!("L{i}"))
    }

    /// Lane width of the program.
    pub fn width(&self) -> usize {
        self.levels.first().map(|l| l.ops.len()).unwrap_or(0)
    }

    /// Total useful FU ops per input vector.
    pub fn useful_ops(&self) -> usize {
        self.levels.iter().map(Level::useful_ops).sum()
    }

    /// Total real FLOPs per input vector under the paper's accounting.
    pub fn flops(&self) -> f64 {
        self.levels.iter().flat_map(|l| l.ops.iter()).map(Op::flops).sum()
    }

    /// Validate a full spatial mapping onto a PCU of `geom` configured with
    /// the extensions in `available`, level *i* at stage boundary *i*.
    pub fn validate_spatial(
        &self,
        geom: PcuGeometry,
        supports_mode: bool,
    ) -> Result<(), MapError> {
        if self.width() != geom.lanes {
            return Err(MapError::WidthMismatch { program: self.width(), pcu: geom.lanes });
        }
        if self.levels.len() > geom.stages {
            return Err(MapError::TooDeep { levels: self.levels.len(), stages: geom.stages });
        }
        let needs_cross = self
            .levels
            .iter()
            .any(|l| l.ops.iter().any(|o| o.cross_src().is_some()));
        if needs_cross && self.mode.is_extension() && !supports_mode {
            return Err(MapError::ModeUnavailable { required: self.mode });
        }
        for (li, level) in self.levels.iter().enumerate() {
            for (dest, op) in level.ops.iter().enumerate() {
                if let Some(src) = op.cross_src() {
                    if src >= geom.lanes {
                        return Err(MapError::IllegalEdge { level: li, dest, src });
                    }
                    if !topology::allows(self.mode, geom, li, dest, src) {
                        return Err(MapError::IllegalEdge { level: li, dest, src });
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> PcuGeometry {
        PcuGeometry::synthesis()
    }

    #[test]
    fn op_cross_sources() {
        assert_eq!(Op::Pass.cross_src(), None);
        assert_eq!(Op::Add { src: 3 }.cross_src(), Some(3));
        assert_eq!(Op::Mac { src: 1, c: C64::real(2.0) }.cross_src(), Some(1));
    }

    #[test]
    fn level_useful_ops() {
        let l = Level::new(vec![Op::Pass, Op::Add { src: 0 }, Op::MulConst(C64::real(1.0))]);
        assert_eq!(l.useful_ops(), 2);
    }

    #[test]
    fn program_flops() {
        let p = Program::new(
            "t",
            PcuMode::ElementWise,
            vec![Level::new(vec![Op::Mac { src: 0, c: C64::real(1.0) }; 8])],
        );
        assert_eq!(p.flops(), 16.0);
    }

    #[test]
    fn width_mismatch_detected() {
        let p = Program::new("t", PcuMode::ElementWise, vec![Level::pass(4)]);
        assert_eq!(
            p.validate_spatial(geom(), true),
            Err(MapError::WidthMismatch { program: 4, pcu: 8 })
        );
    }

    #[test]
    fn too_deep_detected() {
        let levels = (0..7).map(|_| Level::pass(8)).collect();
        let p = Program::new("t", PcuMode::ElementWise, levels);
        assert_eq!(p.validate_spatial(geom(), true), Err(MapError::TooDeep { levels: 7, stages: 6 }));
    }

    #[test]
    fn illegal_edge_detected() {
        // Butterfly edge at level 0 (1 ← 0) requires FFT mode wiring.
        let mut ops = vec![Op::Pass; 8];
        ops[1] = Op::Add { src: 0 };
        let p = Program::new("t", PcuMode::ElementWise, vec![Level::new(ops)]);
        assert_eq!(
            p.validate_spatial(geom(), true),
            Err(MapError::IllegalEdge { level: 0, dest: 1, src: 0 })
        );
    }

    #[test]
    fn mode_unavailable_detected() {
        let mut ops = vec![Op::Pass; 8];
        ops[0] = Op::Add { src: 1 };
        let p = Program::new("t", PcuMode::Fft, vec![Level::new(ops)]);
        assert_eq!(
            p.validate_spatial(geom(), false),
            Err(MapError::ModeUnavailable { required: PcuMode::Fft })
        );
        assert_eq!(p.validate_spatial(geom(), true), Ok(()));
    }

    #[test]
    fn stage_labels_and_fallback() {
        let p = Program::new("t", PcuMode::ElementWise, vec![Level::pass(4), Level::pass(4)]);
        assert_eq!(p.stage_label(0), "L0");
        let p = p.with_labels(vec!["warm".into(), "cool".into()]);
        assert_eq!(p.stage_label(0), "warm");
        assert_eq!(p.stage_label(1), "cool");
        assert_eq!(p.stage_label(7), "L7", "out-of-range falls back");
    }

    #[test]
    #[should_panic(expected = "labels for")]
    fn label_count_mismatch_panics() {
        Program::new("t", PcuMode::ElementWise, vec![Level::pass(4)])
            .with_labels(vec!["a".into(), "b".into()]);
    }

    #[test]
    #[should_panic]
    fn ragged_levels_panic() {
        Program::new(
            "bad",
            PcuMode::ElementWise,
            vec![Level::pass(8), Level::pass(4)],
        );
    }
}
