//! Per-cycle stage-occupancy timeline of a PCU program — the modeled-cycle
//! flame view of the pipeline.
//!
//! [`stage_timeline`] renders how a program occupies a PCU's pipeline
//! stages over modeled cycles, as trace events on the [`PID_PCUSIM`]
//! process where **one trace microsecond is one modeled cycle**:
//!
//! * **Spatial** (the program's mode is carried by the fabric): stage `s`
//!   processes vector `v` at cycle `s + v`, so each stage renders one span
//!   starting at cycle `s` and busy for `vectors` cycles — the classic
//!   skewed-pipeline parallelogram. Unused trailing stages forward data as
//!   `pass` spans; a fused program fills them with useful work, which is
//!   exactly what the flame view is for. Spans are named by
//!   [`Program::stage_label`], so DSL-authored programs render their stage
//!   names (`fused-conv32: filter`) and hand-assembled ones keep the `L{s}`
//!   fallback.
//! * **Serialized** (baseline fabric, §III-B): every level re-executes on
//!   stage 0, one level per cycle per vector — the timeline shows the
//!   1/stages throughput collapse as a single saturated track.
//!
//! Exported by `simulate --trace`; the cycle math mirrors
//! [`Pcu::run_spatial`] / [`Pcu::run_serialized`] and is pinned to their
//! `ExecStats.cycles` by the unit tests.

use super::engine::Pcu;
use super::program::Program;
use crate::telemetry::{name_track, EventKind, TraceEvent, PID_PCUSIM};
use std::borrow::Cow;

/// Nanoseconds per modeled cycle: 1 cycle renders as 1 µs in the trace.
const CYCLE_NS: u64 = 1_000;

/// Cap on serialized (vector × level) event counts, so a huge batch cannot
/// balloon the trace file; spatial timelines are one event per stage and
/// never truncate. Callers wanting the full picture pass fewer vectors.
const MAX_SERIALIZED_EVENTS: usize = 4096;

/// Render `prog` executing `vectors` input vectors on `pcu` as trace
/// events, starting at modeled cycle `t0_cycles` (use an offset to lay
/// several program timelines side by side on the pcusim process).
pub fn stage_timeline(pcu: &Pcu, prog: &Program, vectors: usize, t0_cycles: u64) -> Vec<TraceEvent> {
    let v = vectors.max(1) as u64;
    let levels = prog.levels.len().max(1);
    let mut out = Vec::new();
    let ev = |name: String, tid: u64, ts_cycles: u64, dur_cycles: u64, ops: f64| TraceEvent {
        name: Cow::Owned(name),
        cat: "pcusim",
        kind: EventKind::Span,
        pid: PID_PCUSIM,
        tid,
        ts_ns: ts_cycles * CYCLE_NS,
        dur_ns: dur_cycles * CYCLE_NS,
        args: [Some(("useful_ops", ops)), None],
    };
    if pcu.mappable(prog).is_ok() {
        // Spatial: stage s starts at cycle s, busy for `vectors` cycles.
        for (s, level) in prog.levels.iter().enumerate() {
            name_track(PID_PCUSIM, s as u64, format!("stage {s}"));
            out.push(ev(
                format!("{}: {}", prog.name, prog.stage_label(s)),
                s as u64,
                t0_cycles + s as u64,
                v,
                level.useful_ops() as f64,
            ));
        }
        // Trailing stages forward data until the pipeline drains.
        for s in prog.levels.len()..pcu.geom.stages {
            name_track(PID_PCUSIM, s as u64, format!("stage {s}"));
            out.push(ev(format!("{}: pass", prog.name), s as u64, t0_cycles + s as u64, v, 0.0));
        }
    } else {
        // Serialized: every level re-executes on stage 0, one cycle each.
        name_track(PID_PCUSIM, 0, "stage 0".to_string());
        let max_vectors = (MAX_SERIALIZED_EVENTS / levels).max(1) as u64;
        for vec_i in 0..v.min(max_vectors) {
            for (li, level) in prog.levels.iter().enumerate() {
                out.push(ev(
                    format!("{}: v{vec_i} {}", prog.name, prog.stage_label(li)),
                    0,
                    t0_cycles + vec_i * levels as u64 + li as u64,
                    1,
                    level.useful_ops() as f64,
                ));
            }
        }
    }
    out
}

/// Modeled cycles the timeline spans (the offset for the next program laid
/// on the same tracks): matches `ExecStats.cycles` of the corresponding
/// `run_*` driver when nothing was truncated.
pub fn timeline_cycles(events: &[TraceEvent]) -> u64 {
    events.iter().map(|e| (e.ts_ns + e.dur_ns) / CYCLE_NS).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::PcuGeometry;
    use crate::pcusim::programs::fft_program;
    use crate::util::C64;

    #[test]
    fn spatial_timeline_is_one_span_per_stage_and_matches_exec_cycles() {
        let geom = PcuGeometry::new(8, 8);
        let prog = fft_program(8);
        let pcu = Pcu::fft_mode(geom);
        assert!(pcu.mappable(&prog).is_ok(), "fft program must map on fft-mode");
        let vectors = 16usize;
        let evs = stage_timeline(&pcu, &prog, vectors, 0);
        assert_eq!(evs.len(), geom.stages, "one span per pipeline stage");
        for (s, e) in evs.iter().take(prog.levels.len()).enumerate() {
            assert_eq!(e.tid, s as u64);
            assert_eq!(e.ts_ns, s as u64 * 1_000, "stage {s} starts at cycle {s}");
            assert_eq!(e.dur_ns, vectors as u64 * 1_000, "busy for one cycle per vector");
        }
        // Total modeled cycles match the execution engine's count.
        let inputs: Vec<Vec<C64>> = vec![vec![C64::real(1.0); 8]; vectors];
        let (_, stats) = pcu.run(&prog, &inputs);
        assert!(stats.spatial);
        assert_eq!(timeline_cycles(&evs), stats.cycles);
    }

    #[test]
    fn serialized_timeline_saturates_stage_zero() {
        let geom = PcuGeometry::new(8, 8);
        let prog = fft_program(8);
        let pcu = Pcu::baseline(geom);
        assert!(pcu.mappable(&prog).is_err(), "fft program serializes on baseline");
        let vectors = 4usize;
        let evs = stage_timeline(&pcu, &prog, vectors, 0);
        assert_eq!(evs.len(), vectors * prog.levels.len(), "one event per vector × level");
        assert!(evs.iter().all(|e| e.tid == 0), "everything on stage 0");
        assert!(evs.iter().all(|e| e.dur_ns == 1_000), "one cycle each");
        // Back-to-back: cycle k hosts exactly one event.
        let mut starts: Vec<u64> = evs.iter().map(|e| e.ts_ns / 1_000).collect();
        starts.sort_unstable();
        let want: Vec<u64> = (0..(vectors * prog.levels.len()) as u64).collect();
        assert_eq!(starts, want);
    }

    #[test]
    fn spatial_spans_carry_dsl_stage_labels() {
        let geom = PcuGeometry::new(8, 8);
        let prog = fft_program(8); // DSL-authored: stages bfly0..bfly2
        let pcu = Pcu::fft_mode(geom);
        let evs = stage_timeline(&pcu, &prog, 4, 0);
        assert_eq!(evs[0].name, "fft8: bfly0");
        assert_eq!(evs[2].name, "fft8: bfly2");
        assert_eq!(evs[3].name, "fft8: pass");
        // Unlabeled programs keep the historical L{s} span names.
        let plain = crate::pcusim::legacy::legacy_fft_program(8);
        let evs2 = stage_timeline(&pcu, &plain, 4, 0);
        assert_eq!(evs2[0].name, "fft8: L0");
    }

    #[test]
    fn serialized_timeline_cycles_pin_to_exec_stats_minus_drain() {
        // The serialized export covers only the v·levels work cycles at
        // stage 0; the engine additionally accounts (stages−1)·levels drain
        // cycles. Pin the exact relation for a labeled (DSL) program.
        let geom = PcuGeometry::new(8, 8);
        let prog = fft_program(8);
        let pcu = Pcu::baseline(geom);
        let vectors = 4usize;
        let evs = stage_timeline(&pcu, &prog, vectors, 0);
        let inputs: Vec<Vec<C64>> = vec![vec![C64::real(1.0); 8]; vectors];
        let (_, stats) = pcu.run(&prog, &inputs);
        assert!(!stats.spatial);
        let drain = (geom.stages as u64 - 1) * prog.levels.len() as u64;
        assert_eq!(timeline_cycles(&evs), stats.cycles - drain);
    }

    #[test]
    fn offset_shifts_and_truncation_caps_events() {
        let geom = PcuGeometry::new(8, 8);
        let prog = fft_program(8);
        let pcu = Pcu::baseline(geom);
        let evs = stage_timeline(&pcu, &prog, 2, 100);
        assert!(evs.iter().all(|e| e.ts_ns >= 100 * 1_000));
        let huge = stage_timeline(&pcu, &prog, 1 << 20, 0);
        assert!(huge.len() <= MAX_SERIALIZED_EVENTS, "serialized export must stay bounded");
    }
}
