//! Chip-level network-on-chip model (paper §I/§II-A: "distributed pattern
//! compute units (PCUs) and pattern memory units (PMUs) coupled with
//! programmable network-on-chip (NoC) switches").
//!
//! The RDU die is a checkerboard of PCU and PMU tiles joined by a mesh of
//! switches. This module places a mapped dataflow section onto the grid and
//! computes the wire-level consequences DFModel's steady-state numbers
//! abstract away:
//!
//! * **hop counts** per tensor edge (Manhattan distance on the mesh),
//! * **pipeline fill latency** — the longest producer→consumer switch path
//!   from a graph input to a graph output: the time for the first datum to
//!   emerge, paid once per section launch (steady-state throughput is
//!   unaffected, which is why the paper's Figs. 7/11 can ignore it),
//! * **link-bandwidth audit** — whether any mesh link is oversubscribed by
//!   the streaming tensors crossing it under dimension-ordered (X–Y)
//!   routing.

use crate::arch::RduSpec;
use crate::dfmodel::Mapping;
use crate::graph::Graph;

/// Mesh position in switch-grid coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tile {
    pub x: usize,
    pub y: usize,
}

impl Tile {
    /// Manhattan distance (mesh hops) to `other`.
    pub fn hops(self, other: Tile) -> usize {
        self.x.abs_diff(other.x) + self.y.abs_diff(other.y)
    }
}

/// Per-hop latency in cycles (switch traversal + link).
pub const CYCLES_PER_HOP: f64 = 2.0;

/// Per-link bandwidth in bytes/cycle (512-bit links, matching one PCU
/// lane-vector per cycle at FP16).
pub const LINK_BYTES_PER_CYCLE: f64 = 64.0;

/// Placement of one mapped section onto the die grid.
#[derive(Debug, Clone)]
pub struct Placement {
    /// Grid side (the die is modeled square: side² ≥ n_pcu tiles).
    pub side: usize,
    /// One anchor tile per kernel (the centroid of its PCU cluster).
    pub anchors: Vec<(usize /* kernel id */, Tile)>,
}

/// NoC analysis of one placed section.
#[derive(Debug, Clone, PartialEq)]
pub struct NocReport {
    /// Total mesh hops over all internal tensor edges.
    pub total_hops: usize,
    /// Longest input→output path in hops (drives fill latency).
    pub critical_path_hops: usize,
    /// Pipeline fill latency in seconds at the chip clock.
    pub fill_seconds: f64,
    /// Peak link utilization (streamed bytes/cycle ÷ link capacity) under
    /// X–Y routing; > 1.0 means an oversubscribed link.
    pub peak_link_utilization: f64,
}

/// Grid side for a chip with `n_pcu` compute tiles (PCU/PMU checkerboard:
/// 2 tiles per PCU+PMU pair).
pub fn grid_side(spec: &RduSpec) -> usize {
    (((spec.n_pcu + spec.n_pmu) as f64).sqrt().ceil()) as usize
}

/// Place a mapping's first section greedily along a row-major snake in
/// topological order — adjacent pipeline stages land on adjacent tiles,
/// which is what a dataflow compiler's placer optimizes for.
pub fn place(graph: &Graph, mapping: &Mapping, spec: &RduSpec) -> Placement {
    let side = grid_side(spec);
    let order = graph.topo_order();
    let section = &mapping.sections[0];
    let mut anchors = Vec::with_capacity(section.kernels.len());
    // Walk tiles in snake order, advancing by each kernel's PCU allocation
    // so the anchor sits at its cluster centroid.
    let mut cursor = 0usize;
    for &kid in order.iter().filter(|k| section.kernels.contains(k)) {
        let alloc = section
            .allocs
            .iter()
            .find(|a| a.kernel == kid)
            .map(|a| a.pcus)
            .unwrap_or(1);
        let center = cursor + alloc / 2;
        let row = (center / side).min(side - 1);
        let col_raw = center % side;
        // Snake: odd rows run right-to-left.
        let col = if row.is_multiple_of(2) { col_raw } else { side - 1 - col_raw };
        anchors.push((kid, Tile { x: col, y: row }));
        cursor += alloc;
    }
    Placement { side, anchors }
}

/// Analyze the NoC behaviour of a placed section.
pub fn analyze(graph: &Graph, placement: &Placement, spec: &RduSpec) -> NocReport {
    let tile_of = |kid: usize| -> Option<Tile> {
        placement.anchors.iter().find(|(k, _)| *k == kid).map(|&(_, t)| t)
    };

    // Hop counts per internal edge.
    let mut total_hops = 0usize;
    // Link load accounting under X-then-Y routing: bytes crossing each
    // (direction-agnostic) link per streamed element.
    let mut link_load: std::collections::HashMap<(usize, usize, u8), f64> =
        std::collections::HashMap::new();
    for e in &graph.edges {
        if let (Some(s), Some(d)) = (e.src, e.dst) {
            if let (Some(a), Some(b)) = (tile_of(s), tile_of(d)) {
                total_hops += a.hops(b);
                // X leg then Y leg; charge the edge's steady-state byte rate
                // (bytes per element-cycle ≈ bytes / elements).
                let rate = if graph.kernels[s].elements > 0.0 {
                    e.bytes / graph.kernels[s].elements
                } else {
                    0.0
                };
                let (mut x, y0) = (a.x, a.y);
                while x != b.x {
                    let nx = if b.x > x { x + 1 } else { x - 1 };
                    *link_load.entry((x.min(nx), y0, 0)).or_default() += rate;
                    x = nx;
                }
                let mut y = y0;
                while y != b.y {
                    let ny = if b.y > y { y + 1 } else { y - 1 };
                    *link_load.entry((b.x, y.min(ny), 1)).or_default() += rate;
                    y = ny;
                }
            }
        }
    }

    // Critical path: longest hop-weighted path over the DAG.
    let order = graph.topo_order();
    let mut dist = vec![0usize; graph.kernels.len()];
    for &k in &order {
        for e in graph.edges.iter().filter(|e| e.src == Some(k)) {
            if let Some(d) = e.dst {
                if let (Some(a), Some(b)) = (tile_of(k), tile_of(d)) {
                    dist[d] = dist[d].max(dist[k] + a.hops(b));
                }
            }
        }
    }
    let critical = dist.into_iter().max().unwrap_or(0);
    let fill_seconds = critical as f64 * CYCLES_PER_HOP / spec.clock_hz;
    let peak = link_load
        .values()
        .fold(0.0f64, |m, &v| m.max(v / LINK_BYTES_PER_CYCLE));

    NocReport {
        total_hops,
        critical_path_hops: critical,
        fill_seconds,
        peak_link_utilization: peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::RduConfig;
    use crate::dfmodel::map_graph;
    use crate::fft::BaileyVariant;
    use crate::workloads::{hyena_decoder, DecoderConfig};

    fn setup() -> (Graph, Placement, RduSpec) {
        let cfg = RduConfig::fft_mode();
        let g = hyena_decoder(&DecoderConfig::paper(1 << 18), BaileyVariant::Vector);
        let m = map_graph(&g, &cfg).unwrap();
        let p = place(&g, &m, &cfg.spec);
        (g, p, cfg.spec)
    }

    #[test]
    fn tile_hops_manhattan() {
        assert_eq!(Tile { x: 0, y: 0 }.hops(Tile { x: 3, y: 4 }), 7);
        assert_eq!(Tile { x: 2, y: 2 }.hops(Tile { x: 2, y: 2 }), 0);
    }

    #[test]
    fn grid_fits_all_tiles() {
        let spec = RduSpec::table1();
        let side = grid_side(&spec);
        assert!(side * side >= spec.n_pcu + spec.n_pmu);
        assert_eq!(side, 33); // ceil(sqrt(1040))
    }

    #[test]
    fn placement_covers_section_kernels() {
        let (g, p, _) = setup();
        assert_eq!(p.anchors.len(), g.kernels.len().min(p.anchors.len()));
        for (_, t) in &p.anchors {
            assert!(t.x < p.side && t.y < p.side);
        }
    }

    #[test]
    fn fill_latency_negligible_vs_steady_state() {
        // The justification for DFModel ignoring fill: microseconds of
        // steady-state vs nanoseconds of fill.
        let (g, p, spec) = setup();
        let rep = analyze(&g, &p, &spec);
        assert!(rep.critical_path_hops > 0);
        assert!(rep.fill_seconds < 1e-6, "fill={}", rep.fill_seconds);
    }

    #[test]
    fn no_link_oversubscription_at_paper_shapes() {
        let (g, p, spec) = setup();
        let rep = analyze(&g, &p, &spec);
        assert!(rep.peak_link_utilization.is_finite());
        assert!(
            rep.peak_link_utilization < 8.0,
            "util={} (D=32 fp16 streams over 64B links)",
            rep.peak_link_utilization
        );
    }

    #[test]
    fn adjacent_stages_land_near_each_other() {
        // Snake placement: average hops per edge stays far below the grid
        // diameter.
        let (g, p, spec) = setup();
        let rep = analyze(&g, &p, &spec);
        let edges = g.edges.iter().filter(|e| e.src.is_some() && e.dst.is_some()).count();
        let avg = rep.total_hops as f64 / edges as f64;
        let diameter = (2 * (grid_side(&spec) - 1)) as f64;
        assert!(avg < diameter / 2.0, "avg={avg} diameter={diameter}");
    }
}
