//! Cycle-level functional simulator of the RDU's Pattern Compute Unit.
//!
//! This module is the hardware half of the reproduction: it models a PCU as
//! a `lanes × stages` pipelined SIMD array (paper Fig. 2) with per-mode
//! inter-stage interconnect fabrics (Figs. 5 and 10), executes real programs
//! with real numerics, and measures the pipeline utilizations DFModel's
//! performance estimates rest on.
//!
//! * [`topology`] — the interconnect fabrics of each [`crate::arch::PcuMode`]
//!   and the added-route counts behind Table IV.
//! * [`program`] — FU-level program IR + spatial-mapping validation.
//! * [`dsl`] — the [`define_pcu_program!`](crate::define_pcu_program)
//!   authoring macro and its [`dsl::ProgramBuilder`]: named stages, folded
//!   constants, and cross-lane routes checked against [`topology::allows`]
//!   at construction time rather than at map time.
//! * [`programs`] — canonical FFT / HS-scan / B-scan / reduction programs,
//!   all DSL-authored, verified against the [`crate::fft`] and
//!   [`crate::scan`] substrates, plus the fused DIF→filter→DIT convolution
//!   pipeline ([`programs::fused_conv_program`]) that grounds the mapper's
//!   fusion pass: bit-identical to its three-launch unfused counterpart.
//! * [`legacy`] — the pre-DSL hand-assembled constructors, kept as
//!   differential oracles for the migration tests.
//! * [`engine`] — spatial vs serialized ("first stage only", §III-B)
//!   execution with cycle and FU-utilization accounting.
//! * [`debug`] — single-step debugger over the engine: pipeline-register
//!   and NoC-traffic snapshots, stage/cycle/predicate breakpoints,
//!   deterministic resume (`debug` CLI subcommand).
//! * [`utilization`] — the measured steady-state factors DFModel consumes.
//! * [`noc`] — chip-grid placement, hop counts, fill latency and link
//!   bandwidth audit of mapped sections.
//! * [`timeline`] — per-cycle stage-occupancy export as trace events
//!   (`simulate --trace`): the pipeline flame view of a fused PCU program.
//!
//! **Spatial vs serialized, and what DFModel does with it.** A program maps
//! *spatially* (one pipeline stage per FU level, initiation interval → 1)
//! only when the PCU's interconnect fabric carries every inter-stage route
//! it needs: FFT butterflies need the FFT-mode fabric, HS-/B-scan exchanges
//! need the scan-mode fabric (paper Figs. 5/10). On a baseline PCU the same
//! program *serializes* through the first stage, paying the 1/stages
//! throughput penalty of §III-B — this measured spatial/serialized gap is
//! the per-kernel utilization factor [`crate::dfmodel`] builds every figure
//! from, so the simulator is the ground truth under the performance model,
//! which in turn prices the multi-chip dataflows of [`crate::shard`].

pub mod debug;
pub mod dsl;
pub mod engine;
pub mod legacy;
pub mod noc;
pub mod program;
pub mod programs;
pub mod timeline;
pub mod topology;
pub mod utilization;

pub use debug::{DebugSession, RunOutcome, Snapshot};
pub use dsl::{DslError, ProgramBuilder};
pub use engine::{ExecStats, Pcu};
pub use program::{Level, MapError, Op, Program};
pub use programs::{
    b_scan_program, bit_reverse, demo_program, dif_fft_program, fft_program,
    freq_filter_program, fused_conv_program, hs_scan_program, idit_fft_program,
    reduction_program, twiddle_program, unfused_conv_programs,
};
pub use timeline::{stage_timeline, timeline_cycles};
pub use utilization::Measurement;
