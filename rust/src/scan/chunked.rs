//! Chunked (SIMD-layout) scan/gate/pointwise kernels — the raw-speed pass
//! over the inner loops that dominate SSM serving time (PR 7).
//!
//! The Mamba recurrence `h[t] = a[t]·h[t−1] + b[t]` is inherently serial
//! *in time*, so the profitable vector axis is **channels**: `C`
//! independent recurrences advance in lock step, four per `[f64; LANES]`
//! accumulator block. Each lane performs *exactly* the operations the
//! scalar per-channel loop performs, on the same values, in the same
//! order — lanes never interact — so every chunked path here is
//! **bit-identical** to its `*_scalar` oracle (`assert_eq!`, not a
//! tolerance). The property harness (`tests/prop.rs`) fuzzes that claim
//! over ragged lengths and channel counts.
//!
//! Layout contract (what lets the autovectorizer keep its promise): data
//! is **time-major** — element `(t, c)` lives at `t·C + c` — so a lane
//! block loads four *adjacent* channels per step (one contiguous 32-byte
//! load), and the accumulators are fixed-size `[f64; LANES]` arrays whose
//! inner loops have a constant trip count and no cross-lane dependence.
//! That is the exact shape LLVM turns into `vfmadd`-style packed code
//! without intrinsics, which keeps the crate dependency-free and portable.
//!
//! The elementwise kernels ([`silu_slice_chunked`], [`gate_silu_chunked`])
//! chunk the same way; elementwise chunking touches each element once with
//! unchanged arithmetic, so bit-identity is immediate.

use super::recurrence::silu;

/// Vector width of the chunked kernels: four f64 lanes — one AVX2 ymm (or
/// two NEON q) register per accumulator block.
pub const LANES: usize = 4;

/// Scalar oracle for [`silu_slice_chunked`]: SiLU applied element by
/// element.
pub fn silu_slice_scalar(z: &[f64]) -> Vec<f64> {
    z.iter().map(|&v| silu(v)).collect()
}

/// SiLU over a slice in [`LANES`]-wide chunks. Bit-identical to
/// [`silu_slice_scalar`] (same per-element arithmetic, no reassociation).
pub fn silu_slice_chunked(z: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; z.len()];
    let (zc, zr) = z.split_at(z.len() - z.len() % LANES);
    let (oc, or) = out.split_at_mut(zc.len());
    for (zb, ob) in zc.chunks_exact(LANES).zip(oc.chunks_exact_mut(LANES)) {
        for l in 0..LANES {
            ob[l] = silu(zb[l]);
        }
    }
    for (o, &v) in or.iter_mut().zip(zr) {
        *o = silu(v);
    }
    out
}

/// Scalar oracle for [`gate_silu_chunked`]: `y[i] = h[i] · silu(z[i])`.
pub fn gate_silu_scalar(h: &[f64], z: &[f64]) -> Vec<f64> {
    assert_eq!(h.len(), z.len(), "gate_silu: h/z length mismatch");
    h.iter().zip(z).map(|(&hi, &zi)| hi * silu(zi)).collect()
}

/// The Mamba z-branch gate `y = h ⊙ silu(z)` in [`LANES`]-wide chunks.
/// Bit-identical to [`gate_silu_scalar`].
pub fn gate_silu_chunked(h: &[f64], z: &[f64]) -> Vec<f64> {
    assert_eq!(h.len(), z.len(), "gate_silu: h/z length mismatch");
    let mut out = vec![0.0; h.len()];
    let split = h.len() - h.len() % LANES;
    for i in (0..split).step_by(LANES) {
        let hb: [f64; LANES] = h[i..i + LANES].try_into().unwrap();
        let zb: [f64; LANES] = z[i..i + LANES].try_into().unwrap();
        let ob = &mut out[i..i + LANES];
        for l in 0..LANES {
            ob[l] = hb[l] * silu(zb[l]);
        }
    }
    for i in split..h.len() {
        out[i] = h[i] * silu(z[i]);
    }
    out
}

/// Scalar oracle for [`mamba_scan_channels_chunked`]: `C` independent
/// recurrences over time-major data, advanced one channel at a time.
/// Channel `c` of the result equals `mamba_scan_serial` of that channel's
/// strided (a, b) streams.
pub fn mamba_scan_channels_scalar(a: &[f64], b: &[f64], channels: usize) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "mamba_scan_channels: a/b length mismatch");
    assert!(channels > 0, "mamba_scan_channels: need at least one channel");
    assert_eq!(a.len() % channels, 0, "mamba_scan_channels: len must divide by channels");
    let steps = a.len() / channels;
    let mut out = vec![0.0; a.len()];
    for c in 0..channels {
        let mut h = 0.0;
        for t in 0..steps {
            let i = t * channels + c;
            h = a[i] * h + b[i];
            out[i] = h;
        }
    }
    out
}

/// Multi-channel Mamba scan with [`LANES`]-wide channel blocks: four
/// adjacent channels share one `[f64; LANES]` state accumulator, advanced
/// together down the time axis (time-major layout, element `(t, c)` at
/// `t·channels + c`). Each lane's update `h = a·h + b` is the scalar
/// channel's update verbatim — lanes never mix — so the result is
/// **bit-identical** to [`mamba_scan_channels_scalar`]. Channels beyond
/// the last full block run the scalar tail.
pub fn mamba_scan_channels_chunked(a: &[f64], b: &[f64], channels: usize) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "mamba_scan_channels: a/b length mismatch");
    assert!(channels > 0, "mamba_scan_channels: need at least one channel");
    assert_eq!(a.len() % channels, 0, "mamba_scan_channels: len must divide by channels");
    let steps = a.len() / channels;
    let mut out = vec![0.0; a.len()];
    let blocks = channels / LANES;
    for blk in 0..blocks {
        let c0 = blk * LANES;
        let mut h = [0.0f64; LANES];
        for t in 0..steps {
            let i = t * channels + c0;
            let ab: [f64; LANES] = a[i..i + LANES].try_into().unwrap();
            let bb: [f64; LANES] = b[i..i + LANES].try_into().unwrap();
            let ob = &mut out[i..i + LANES];
            for l in 0..LANES {
                h[l] = ab[l] * h[l] + bb[l];
                ob[l] = h[l];
            }
        }
    }
    for c in blocks * LANES..channels {
        let mut h = 0.0;
        for t in 0..steps {
            let i = t * channels + c;
            h = a[i] * h + b[i];
            out[i] = h;
        }
    }
    out
}

/// Scalar oracle for [`scan_gate_channels_chunked`]: the fused scan→gate
/// spine (`y = h ⊙ silu(z)`, `h` never staged) per channel.
pub fn scan_gate_channels_scalar(a: &[f64], b: &[f64], z: &[f64], channels: usize) -> Vec<f64> {
    assert_eq!(a.len(), z.len(), "scan_gate_channels: z length mismatch");
    assert_eq!(a.len(), b.len(), "scan_gate_channels: a/b length mismatch");
    assert!(channels > 0, "scan_gate_channels: need at least one channel");
    assert_eq!(a.len() % channels, 0, "scan_gate_channels: len must divide by channels");
    let steps = a.len() / channels;
    let mut out = vec![0.0; a.len()];
    for c in 0..channels {
        let mut h = 0.0;
        for t in 0..steps {
            let i = t * channels + c;
            h = a[i] * h + b[i];
            out[i] = h * silu(z[i]);
        }
    }
    out
}

/// Fused multi-channel scan→gate with [`LANES`]-wide channel blocks —
/// the chunked mirror of [`super::scan_gate_fused`] across channels.
/// Bit-identical to [`scan_gate_channels_scalar`] (per-lane ops are the
/// scalar channel's ops; the gate multiplies each lane independently).
pub fn scan_gate_channels_chunked(a: &[f64], b: &[f64], z: &[f64], channels: usize) -> Vec<f64> {
    assert_eq!(a.len(), z.len(), "scan_gate_channels: z length mismatch");
    assert_eq!(a.len(), b.len(), "scan_gate_channels: a/b length mismatch");
    assert!(channels > 0, "scan_gate_channels: need at least one channel");
    assert_eq!(a.len() % channels, 0, "scan_gate_channels: len must divide by channels");
    let steps = a.len() / channels;
    let mut out = vec![0.0; a.len()];
    let blocks = channels / LANES;
    for blk in 0..blocks {
        let c0 = blk * LANES;
        let mut h = [0.0f64; LANES];
        for t in 0..steps {
            let i = t * channels + c0;
            let ab: [f64; LANES] = a[i..i + LANES].try_into().unwrap();
            let bb: [f64; LANES] = b[i..i + LANES].try_into().unwrap();
            let zb: [f64; LANES] = z[i..i + LANES].try_into().unwrap();
            let ob = &mut out[i..i + LANES];
            for l in 0..LANES {
                h[l] = ab[l] * h[l] + bb[l];
                ob[l] = h[l] * silu(zb[l]);
            }
        }
    }
    for c in blocks * LANES..channels {
        let mut h = 0.0;
        for t in 0..steps {
            let i = t * channels + c;
            h = a[i] * h + b[i];
            out[i] = h * silu(z[i]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::mamba_scan_serial;
    use crate::util::XorShift;

    fn time_major(rng: &mut XorShift, steps: usize, channels: usize) -> Vec<f64> {
        rng.vec(steps * channels, -1.0, 1.0)
    }

    #[test]
    fn silu_chunked_bit_identical() {
        let mut rng = XorShift::new(401);
        for n in [0usize, 1, 3, 4, 5, 17, 1000, 1023] {
            let z = rng.vec(n, -4.0, 4.0);
            assert_eq!(silu_slice_chunked(&z), silu_slice_scalar(&z), "n={n}");
        }
    }

    #[test]
    fn gate_chunked_bit_identical() {
        let mut rng = XorShift::new(402);
        for n in [0usize, 1, 4, 7, 129, 1024] {
            let h = rng.vec(n, -2.0, 2.0);
            let z = rng.vec(n, -4.0, 4.0);
            assert_eq!(gate_silu_chunked(&h, &z), gate_silu_scalar(&h, &z), "n={n}");
        }
    }

    #[test]
    fn channel_scan_chunked_bit_identical() {
        // Every channel count straddling the lane width, ragged steps.
        let mut rng = XorShift::new(403);
        for channels in [1usize, 2, 3, 4, 5, 7, 8, 9, 16, 33] {
            for steps in [1usize, 2, 17, 100] {
                let a = time_major(&mut rng, steps, channels);
                let b = time_major(&mut rng, steps, channels);
                assert_eq!(
                    mamba_scan_channels_chunked(&a, &b, channels),
                    mamba_scan_channels_scalar(&a, &b, channels),
                    "channels={channels} steps={steps}"
                );
            }
        }
    }

    #[test]
    fn channel_scan_matches_per_channel_serial_scan() {
        // The scalar oracle itself is just mamba_scan_serial per strided
        // channel — anchor the whole chain to the PR-0 golden model.
        let mut rng = XorShift::new(404);
        let (steps, channels) = (50usize, 6usize);
        let a = time_major(&mut rng, steps, channels);
        let b = time_major(&mut rng, steps, channels);
        let got = mamba_scan_channels_chunked(&a, &b, channels);
        for c in 0..channels {
            let ac: Vec<f64> = (0..steps).map(|t| a[t * channels + c]).collect();
            let bc: Vec<f64> = (0..steps).map(|t| b[t * channels + c]).collect();
            let want = mamba_scan_serial(&ac, &bc);
            for t in 0..steps {
                assert_eq!(got[t * channels + c], want[t], "c={c} t={t}");
            }
        }
    }

    #[test]
    fn fused_scan_gate_chunked_bit_identical() {
        let mut rng = XorShift::new(405);
        for channels in [1usize, 4, 5, 12] {
            for steps in [1usize, 33, 128] {
                let a = time_major(&mut rng, steps, channels);
                let b = time_major(&mut rng, steps, channels);
                let z = time_major(&mut rng, steps, channels);
                assert_eq!(
                    scan_gate_channels_chunked(&a, &b, &z, channels),
                    scan_gate_channels_scalar(&a, &b, &z, channels),
                    "channels={channels} steps={steps}"
                );
            }
        }
    }

    #[test]
    fn fused_equals_gating_the_plain_channel_scan() {
        // Fusion changes staging, not arithmetic: gating the chunked scan's
        // output after the fact is the same bitstream.
        let mut rng = XorShift::new(406);
        let (steps, channels) = (64usize, 8usize);
        let a = time_major(&mut rng, steps, channels);
        let b = time_major(&mut rng, steps, channels);
        let z = time_major(&mut rng, steps, channels);
        let h = mamba_scan_channels_chunked(&a, &b, channels);
        let staged = gate_silu_chunked(&h, &z);
        assert_eq!(scan_gate_channels_chunked(&a, &b, &z, channels), staged);
    }

    #[test]
    #[should_panic(expected = "len must divide by channels")]
    fn ragged_layout_is_rejected() {
        mamba_scan_channels_chunked(&[0.0; 7], &[0.0; 7], 3);
    }
}
