//! Explicit-lane SIMD twins of the chunked scan/gate kernels (PR 9).
//!
//! [`super::chunked`] shapes the loops so the autovectorizer *can* emit
//! packed code; this module stops hoping and writes the lanes down:
//! arch-gated `core::arch` intrinsics on x86_64 (AVX, 4×f64 per ymm) and
//! aarch64 (NEON, 2×2×f64 per q-pair), runtime-detected, with the chunked
//! code as the portable fallback. No crates — the build stays
//! offline-vendorable.
//!
//! ## The bit-identity contract (the SIMD-oracle contract)
//!
//! Every function here must equal its `*_scalar` oracle **bit for bit**,
//! same as the chunked twins (WORKLOADS.md §4; fuzzed in `tests/prop.rs`).
//! Three rules keep that true:
//!
//! * **No FMA.** The scalar recurrence is `a·h` rounded, then `+ b`
//!   rounded — two roundings. A fused multiply-add rounds once and changes
//!   low bits, so the kernels use separate `mul`/`add` intrinsics
//!   (`vmulpd`+`vaddpd`, `fmul`+`fadd`), each IEEE-754-exact per lane.
//! * **Lanes never mix.** Each lane runs one channel's scalar op sequence
//!   verbatim; there are no horizontal reductions in these kernels.
//! * **Transcendentals stay scalar.** `silu` calls `exp` (libm); a vector
//!   `exp` approximation would break identity, so gates compute `silu`
//!   lane-by-lane in scalar and vectorize only the exact multiplies.
//!   This is also why the gate kernels gain less than the pure scan — the
//!   scan's inner loop is 100% exact mul/add and vectorizes whole.
//!
//! The active backend is reported by [`simd_backend`] (surfaced in the
//! bench provenance so `BENCH_hotpath.json` numbers say what ran).

use super::chunked::{
    gate_silu_chunked, mamba_scan_channels_chunked, scan_gate_channels_chunked, LANES,
};
use super::recurrence::silu;

/// Which lane implementation [`gate_silu_simd`] and friends dispatch to on
/// this host: `"avx"`, `"neon"`, or `"portable"` (the chunked fallback).
pub fn simd_backend() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx") {
            return "avx";
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return "neon";
        }
    }
    "portable"
}

/// The Mamba z-branch gate `y = h ⊙ silu(z)` with explicit lanes.
/// Bit-identical to `gate_silu_scalar` (silu stays scalar; the multiply
/// is one exact packed `mul`).
pub fn gate_silu_simd(h: &[f64], z: &[f64]) -> Vec<f64> {
    assert_eq!(h.len(), z.len(), "gate_silu: h/z length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx") {
            let mut out = vec![0.0; h.len()];
            // SAFETY: AVX presence checked above.
            unsafe { gate_silu_avx(h, z, &mut out) };
            return out;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            let mut out = vec![0.0; h.len()];
            // SAFETY: NEON presence checked above.
            unsafe { gate_silu_neon(h, z, &mut out) };
            return out;
        }
    }
    gate_silu_chunked(h, z)
}

/// Multi-channel Mamba scan (`h = a·h + b` down time, four channels per
/// accumulator) with explicit lanes. Bit-identical to
/// `mamba_scan_channels_scalar`; layout contract as in
/// [`mamba_scan_channels_chunked`].
pub fn mamba_scan_channels_simd(a: &[f64], b: &[f64], channels: usize) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "mamba_scan_channels: a/b length mismatch");
    assert!(channels > 0, "mamba_scan_channels: need at least one channel");
    assert_eq!(a.len() % channels, 0, "mamba_scan_channels: len must divide by channels");
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx") {
            let mut out = vec![0.0; a.len()];
            // SAFETY: AVX presence checked above.
            unsafe { mamba_scan_channels_avx(a, b, channels, &mut out) };
            return out;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            let mut out = vec![0.0; a.len()];
            // SAFETY: NEON presence checked above.
            unsafe { mamba_scan_channels_neon(a, b, channels, &mut out) };
            return out;
        }
    }
    mamba_scan_channels_chunked(a, b, channels)
}

/// Fused multi-channel scan→gate with explicit lanes. Bit-identical to
/// `scan_gate_channels_scalar`.
pub fn scan_gate_channels_simd(a: &[f64], b: &[f64], z: &[f64], channels: usize) -> Vec<f64> {
    assert_eq!(a.len(), z.len(), "scan_gate_channels: z length mismatch");
    assert_eq!(a.len(), b.len(), "scan_gate_channels: a/b length mismatch");
    assert!(channels > 0, "scan_gate_channels: need at least one channel");
    assert_eq!(a.len() % channels, 0, "scan_gate_channels: len must divide by channels");
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx") {
            let mut out = vec![0.0; a.len()];
            // SAFETY: AVX presence checked above.
            unsafe { scan_gate_channels_avx(a, b, z, channels, &mut out) };
            return out;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            let mut out = vec![0.0; a.len()];
            // SAFETY: NEON presence checked above.
            unsafe { scan_gate_channels_neon(a, b, z, channels, &mut out) };
            return out;
        }
    }
    scan_gate_channels_chunked(a, b, z, channels)
}

/// Scalar tail shared by every backend: channels past the last full
/// [`LANES`] block, one at a time (identical to the chunked tail).
fn scan_tail(a: &[f64], b: &[f64], channels: usize, from: usize, out: &mut [f64]) {
    let steps = a.len() / channels;
    for c in from..channels {
        let mut h = 0.0;
        for t in 0..steps {
            let i = t * channels + c;
            h = a[i] * h + b[i];
            out[i] = h;
        }
    }
}

fn scan_gate_tail(a: &[f64], b: &[f64], z: &[f64], channels: usize, from: usize, out: &mut [f64]) {
    let steps = a.len() / channels;
    for c in from..channels {
        let mut h = 0.0;
        for t in 0..steps {
            let i = t * channels + c;
            h = a[i] * h + b[i];
            out[i] = h * silu(z[i]);
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn gate_silu_avx(h: &[f64], z: &[f64], out: &mut [f64]) {
    use core::arch::x86_64::*;
    let n = h.len();
    let split = n - n % LANES;
    for i in (0..split).step_by(LANES) {
        // silu (exp) stays scalar for bit-identity; only the h·s multiply
        // is packed (one exact vmulpd).
        let s = [silu(z[i]), silu(z[i + 1]), silu(z[i + 2]), silu(z[i + 3])];
        let hv = _mm256_loadu_pd(h.as_ptr().add(i));
        let sv = _mm256_loadu_pd(s.as_ptr());
        _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_mul_pd(hv, sv));
    }
    for i in split..n {
        out[i] = h[i] * silu(z[i]);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn mamba_scan_channels_avx(a: &[f64], b: &[f64], channels: usize, out: &mut [f64]) {
    use core::arch::x86_64::*;
    let steps = a.len() / channels;
    let blocks = channels / LANES;
    for blk in 0..blocks {
        let c0 = blk * LANES;
        let mut h = _mm256_setzero_pd();
        for t in 0..steps {
            let i = t * channels + c0;
            let av = _mm256_loadu_pd(a.as_ptr().add(i));
            let bv = _mm256_loadu_pd(b.as_ptr().add(i));
            // mul then add, NOT vfmadd: the scalar oracle rounds twice.
            h = _mm256_add_pd(_mm256_mul_pd(av, h), bv);
            _mm256_storeu_pd(out.as_mut_ptr().add(i), h);
        }
    }
    scan_tail(a, b, channels, blocks * LANES, out);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn scan_gate_channels_avx(
    a: &[f64],
    b: &[f64],
    z: &[f64],
    channels: usize,
    out: &mut [f64],
) {
    use core::arch::x86_64::*;
    let steps = a.len() / channels;
    let blocks = channels / LANES;
    for blk in 0..blocks {
        let c0 = blk * LANES;
        let mut h = _mm256_setzero_pd();
        for t in 0..steps {
            let i = t * channels + c0;
            let av = _mm256_loadu_pd(a.as_ptr().add(i));
            let bv = _mm256_loadu_pd(b.as_ptr().add(i));
            h = _mm256_add_pd(_mm256_mul_pd(av, h), bv);
            let s = [silu(z[i]), silu(z[i + 1]), silu(z[i + 2]), silu(z[i + 3])];
            let sv = _mm256_loadu_pd(s.as_ptr());
            _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_mul_pd(h, sv));
        }
    }
    scan_gate_tail(a, b, z, channels, blocks * LANES, out);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn gate_silu_neon(h: &[f64], z: &[f64], out: &mut [f64]) {
    use core::arch::aarch64::*;
    let n = h.len();
    let split = n - n % LANES;
    for i in (0..split).step_by(LANES) {
        let s = [silu(z[i]), silu(z[i + 1]), silu(z[i + 2]), silu(z[i + 3])];
        let h0 = vld1q_f64(h.as_ptr().add(i));
        let h1 = vld1q_f64(h.as_ptr().add(i + 2));
        let s0 = vld1q_f64(s.as_ptr());
        let s1 = vld1q_f64(s.as_ptr().add(2));
        vst1q_f64(out.as_mut_ptr().add(i), vmulq_f64(h0, s0));
        vst1q_f64(out.as_mut_ptr().add(i + 2), vmulq_f64(h1, s1));
    }
    for i in split..n {
        out[i] = h[i] * silu(z[i]);
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn mamba_scan_channels_neon(a: &[f64], b: &[f64], channels: usize, out: &mut [f64]) {
    use core::arch::aarch64::*;
    let steps = a.len() / channels;
    let blocks = channels / LANES;
    for blk in 0..blocks {
        let c0 = blk * LANES;
        let mut h0 = vdupq_n_f64(0.0);
        let mut h1 = vdupq_n_f64(0.0);
        for t in 0..steps {
            let i = t * channels + c0;
            let a0 = vld1q_f64(a.as_ptr().add(i));
            let a1 = vld1q_f64(a.as_ptr().add(i + 2));
            let b0 = vld1q_f64(b.as_ptr().add(i));
            let b1 = vld1q_f64(b.as_ptr().add(i + 2));
            // fmul then fadd, NOT fmla: the scalar oracle rounds twice.
            h0 = vaddq_f64(vmulq_f64(a0, h0), b0);
            h1 = vaddq_f64(vmulq_f64(a1, h1), b1);
            vst1q_f64(out.as_mut_ptr().add(i), h0);
            vst1q_f64(out.as_mut_ptr().add(i + 2), h1);
        }
    }
    scan_tail(a, b, channels, blocks * LANES, out);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn scan_gate_channels_neon(
    a: &[f64],
    b: &[f64],
    z: &[f64],
    channels: usize,
    out: &mut [f64],
) {
    use core::arch::aarch64::*;
    let steps = a.len() / channels;
    let blocks = channels / LANES;
    for blk in 0..blocks {
        let c0 = blk * LANES;
        let mut h0 = vdupq_n_f64(0.0);
        let mut h1 = vdupq_n_f64(0.0);
        for t in 0..steps {
            let i = t * channels + c0;
            let a0 = vld1q_f64(a.as_ptr().add(i));
            let a1 = vld1q_f64(a.as_ptr().add(i + 2));
            let b0 = vld1q_f64(b.as_ptr().add(i));
            let b1 = vld1q_f64(b.as_ptr().add(i + 2));
            h0 = vaddq_f64(vmulq_f64(a0, h0), b0);
            h1 = vaddq_f64(vmulq_f64(a1, h1), b1);
            let s = [silu(z[i]), silu(z[i + 1]), silu(z[i + 2]), silu(z[i + 3])];
            let s0 = vld1q_f64(s.as_ptr());
            let s1 = vld1q_f64(s.as_ptr().add(2));
            vst1q_f64(out.as_mut_ptr().add(i), vmulq_f64(h0, s0));
            vst1q_f64(out.as_mut_ptr().add(i + 2), vmulq_f64(h1, s1));
        }
    }
    scan_gate_tail(a, b, z, channels, blocks * LANES, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::chunked::{
        gate_silu_scalar, mamba_scan_channels_scalar, scan_gate_channels_scalar,
    };
    use crate::util::XorShift;

    #[test]
    fn backend_is_one_of_the_known_three() {
        assert!(matches!(simd_backend(), "avx" | "neon" | "portable"));
    }

    #[test]
    fn gate_simd_bit_identical_to_scalar() {
        let mut rng = XorShift::new(501);
        for n in [0usize, 1, 3, 4, 5, 7, 129, 1024, 1025] {
            let h = rng.vec(n, -2.0, 2.0);
            let z = rng.vec(n, -4.0, 4.0);
            assert_eq!(gate_silu_simd(&h, &z), gate_silu_scalar(&h, &z), "n={n}");
        }
    }

    #[test]
    fn scan_simd_bit_identical_to_scalar() {
        let mut rng = XorShift::new(502);
        for channels in [1usize, 2, 3, 4, 5, 7, 8, 9, 16, 33] {
            for steps in [1usize, 2, 17, 100] {
                let a = rng.vec(steps * channels, -1.0, 1.0);
                let b = rng.vec(steps * channels, -1.0, 1.0);
                assert_eq!(
                    mamba_scan_channels_simd(&a, &b, channels),
                    mamba_scan_channels_scalar(&a, &b, channels),
                    "channels={channels} steps={steps}"
                );
            }
        }
    }

    #[test]
    fn fused_simd_bit_identical_to_scalar() {
        let mut rng = XorShift::new(503);
        for channels in [1usize, 4, 5, 12] {
            for steps in [1usize, 33, 128] {
                let a = rng.vec(steps * channels, -1.0, 1.0);
                let b = rng.vec(steps * channels, -1.0, 1.0);
                let z = rng.vec(steps * channels, -4.0, 4.0);
                assert_eq!(
                    scan_gate_channels_simd(&a, &b, &z, channels),
                    scan_gate_channels_scalar(&a, &b, &z, channels),
                    "channels={channels} steps={steps}"
                );
            }
        }
    }

    #[test]
    fn simd_equals_chunked_exactly() {
        // Both twins sit on the same contract, so they must agree with
        // each other too — catches a backend drifting from the fallback.
        let mut rng = XorShift::new(504);
        let (steps, channels) = (64usize, 12usize);
        let a = rng.vec(steps * channels, -1.0, 1.0);
        let b = rng.vec(steps * channels, -1.0, 1.0);
        let z = rng.vec(steps * channels, -4.0, 4.0);
        assert_eq!(
            mamba_scan_channels_simd(&a, &b, channels),
            mamba_scan_channels_chunked(&a, &b, channels)
        );
        assert_eq!(
            scan_gate_channels_simd(&a, &b, &z, channels),
            scan_gate_channels_chunked(&a, &b, &z, channels)
        );
    }
}
