//! Tiled scan for long sequences (paper §IV-A, after GPU Gems ch. 39 [16]).
//!
//! A length-N sequence is partitioned into R-element tiles, each sized to fit
//! one PCU (R = PCU lane width, mirroring the FFT tiling of §III). Each tile
//! is scanned locally by a parallel-scan PCU program, the per-tile totals are
//! scanned recursively, and the resulting tile offsets are added back.

use super::blelloch::blelloch_exclusive_op;

/// Exclusive tiled scan with tile size `r` (power of two). Handles arbitrary
/// `x.len()` by padding the final tile with the identity.
pub fn tiled_exclusive(x: &[f64], r: usize) -> Vec<f64> {
    tiled_exclusive_op(x, r, 0.0, |a, b| a + b)
}

/// Exclusive tiled scan under an arbitrary associative operator.
pub fn tiled_exclusive_op<T: Copy>(
    x: &[T],
    r: usize,
    id: T,
    op: impl Fn(T, T) -> T + Copy,
) -> Vec<T> {
    assert!(r.is_power_of_two() && r >= 2, "tiled scan: R={r} must be 2^k >= 2");
    let n = x.len();
    if n == 0 {
        return Vec::new();
    }

    // Local exclusive scan per padded tile + capture each tile's total.
    let ntiles = n.div_ceil(r);
    let mut local = Vec::with_capacity(ntiles * r);
    let mut totals = Vec::with_capacity(ntiles);
    for t in 0..ntiles {
        let lo = t * r;
        let hi = (lo + r).min(n);
        let mut tile = vec![id; r];
        tile[..hi - lo].copy_from_slice(&x[lo..hi]);
        let scanned = blelloch_exclusive_op(&tile, id, op);
        // Tile total = exclusive[last] ⊕ last input.
        totals.push(op(scanned[r - 1], tile[r - 1]));
        local.extend_from_slice(&scanned);
    }

    // Scan the tile totals (recursively tiled when there are many tiles —
    // exactly the hierarchical PCU mapping for million-point sequences).
    let offsets = if ntiles > r {
        tiled_exclusive_op(&totals, r, id, op)
    } else {
        let mut padded = vec![id; ntiles.next_power_of_two()];
        padded[..ntiles].copy_from_slice(&totals);
        blelloch_exclusive_op(&padded, id, op)[..ntiles].to_vec()
    };

    // Add offsets back and truncate padding.
    let mut out = Vec::with_capacity(n);
    for (t, &off) in offsets.iter().enumerate() {
        let lo = t * r;
        let hi = (lo + r).min(n);
        for j in lo..hi {
            out.push(op(off, local[t * r + (j - lo)]));
        }
    }
    out
}

/// Number of R-element tile scans performed for an N-point tiled scan
/// (including the recursive total-scans); each is one PCU pass in the
/// scan-mode mapping, so this drives the perf model.
pub fn tile_count(n: usize, r: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let ntiles = n.div_ceil(r);
    if ntiles > r {
        ntiles + tile_count(ntiles, r)
    } else if ntiles > 1 {
        ntiles + 1
    } else {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::serial::c_scan_exclusive;
    use crate::util::{max_abs_diff, prop};

    #[test]
    fn matches_serial_exact_tiles() {
        let x: Vec<f64> = (0..64).map(|i| i as f64).collect();
        let d = max_abs_diff(&tiled_exclusive(&x, 8), &c_scan_exclusive(&x));
        assert!(d < 1e-9);
    }

    #[test]
    fn matches_serial_ragged_tail() {
        let x: Vec<f64> = (0..53).map(|i| (i as f64).cos()).collect();
        let d = max_abs_diff(&tiled_exclusive(&x, 8), &c_scan_exclusive(&x));
        assert!(d < 1e-9);
    }

    #[test]
    fn deep_recursion_many_tiles() {
        // 4096 elements, R=4 -> 1024 tiles -> 256 -> 64 -> 16 -> 4 -> 1: 5 levels.
        let x: Vec<f64> = (0..4096).map(|i| ((i * 37) % 11) as f64 - 5.0).collect();
        let d = max_abs_diff(&tiled_exclusive(&x, 4), &c_scan_exclusive(&x));
        assert!(d < 1e-8);
    }

    #[test]
    fn empty_and_tiny() {
        assert!(tiled_exclusive(&[], 8).is_empty());
        assert_eq!(tiled_exclusive(&[5.0], 8), vec![0.0]);
    }

    #[test]
    fn tile_count_examples() {
        assert_eq!(tile_count(0, 32), 0);
        assert_eq!(tile_count(32, 32), 1);
        assert_eq!(tile_count(64, 32), 2 + 1); // 2 tiles + 1 totals scan
        // 1024 tiles of 32 over 32768 elems -> 1024 + recurse(1024, 32)
        assert_eq!(tile_count(32768, 32), 1024 + 32 + 1);
    }

    #[test]
    fn prop_matches_serial() {
        prop::quick(
            "tiled == serial",
            |rng| {
                let n = rng.range(0, 3000);
                let r = 1usize << rng.range(1, 6);
                (rng.vec(n, -10.0, 10.0), r)
            },
            prop::no_shrink,
            |(xs, r)| {
                let got = tiled_exclusive(xs, *r);
                let want = c_scan_exclusive(xs);
                let d = max_abs_diff(&got, &want);
                if d < 1e-8 {
                    Ok(())
                } else {
                    Err(format!("R={r} diff {d}"))
                }
            },
        );
    }
}
