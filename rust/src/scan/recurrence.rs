//! Mamba's selective-scan recurrence and its associative (parallel) lift.
//!
//! Mamba's core op evolves hidden state `h[t] = a[t]·h[t-1] + b[t]·x[t]`
//! per (channel, state) pair. A first-order linear recurrence is *not* a
//! plain prefix sum, but it is scannable: lift each step to the pair
//! `(a, b)` with the associative combinator
//!
//! ```text
//! (a₁, b₁) ∘ (a₂, b₂) = (a₁·a₂, a₂·b₁ + b₂)
//! ```
//!
//! and an inclusive scan of the pairs yields `h[t]` directly. This is what
//! the Pallas scan kernel computes and what the scan-mode PCU executes with
//! 2 FUs per combine (mul + MAC).

use super::hillis_steele::hillis_steele_inclusive_op;

/// One step of the lifted recurrence: coefficient and offset.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinStep {
    /// Multiplicative coefficient `a[t]` (state decay).
    pub a: f64,
    /// Additive term `b[t]` (input injection, already `b[t]·x[t]`).
    pub b: f64,
}

/// The associative combinator for first-order linear recurrences.
///
/// `combine(p, q)` composes "apply p then q": `h → q.a·(p.a·h + p.b) + q.b`.
pub fn combine(p: LinStep, q: LinStep) -> LinStep {
    LinStep {
        a: p.a * q.a,
        b: q.a * p.b + q.b,
    }
}

/// Serial (C-scan-style) evaluation of the Mamba recurrence from `h0 = 0`:
/// returns `h[0..N)` — the sequential golden model.
pub fn mamba_scan_serial(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "mamba_scan: a/b length mismatch");
    let mut h = 0.0;
    a.iter()
        .zip(b)
        .map(|(&ai, &bi)| {
            h = ai * h + bi;
            h
        })
        .collect()
}

/// Parallel evaluation via the associative lift + Hillis–Steele scan.
/// Requires a power-of-two length (hardware mapping); the tiled driver
/// handles general lengths.
pub fn mamba_scan_parallel(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "mamba_scan: a/b length mismatch");
    let steps: Vec<LinStep> = a
        .iter()
        .zip(b)
        .map(|(&a, &b)| LinStep { a, b })
        .collect();
    let scanned = hillis_steele_inclusive_op(&steps, combine);
    // h[t] = scanned[t].a * h0 + scanned[t].b with h0 = 0.
    scanned.into_iter().map(|s| s.b).collect()
}

/// Tiled parallel evaluation for arbitrary lengths: R-element tiles scanned
/// in parallel, carry composed across tiles (the long-sequence PCU mapping).
pub fn mamba_scan_tiled(a: &[f64], b: &[f64], r: usize) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    assert!(r.is_power_of_two() && r >= 2);
    let n = a.len();
    let mut out = Vec::with_capacity(n);
    // Carry is the state h at the end of the previous tile.
    let mut carry = 0.0;
    for lo in (0..n).step_by(r) {
        let hi = (lo + r).min(n);
        let mut ta = vec![1.0; r];
        let mut tb = vec![0.0; r];
        ta[..hi - lo].copy_from_slice(&a[lo..hi]);
        tb[..hi - lo].copy_from_slice(&b[lo..hi]);
        // Inject the carry into the first step: h = a0*(carry) + b0.
        tb[0] += ta[0] * carry;
        let h = mamba_scan_parallel(&ta, &tb);
        out.extend_from_slice(&h[..hi - lo]);
        carry = h[hi - lo - 1];
    }
    out
}

/// SiLU (swish) activation — the Mamba z-branch gate nonlinearity.
pub fn silu(x: f64) -> f64 {
    x / (1.0 + (-x).exp())
}

/// **Fused** scan → gate: evaluate the recurrence and apply the SiLU gate
/// `y[t] = h[t] · silu(z[t])` in one pass, never materializing the `h`
/// buffer — the software mirror of the mapper's scan→gate fusion cluster.
/// Bit-identical to gating [`mamba_scan_serial`]'s output after the fact
/// (fusion changes staging, not arithmetic); the integration tests assert
/// exact equality for ragged lengths.
pub fn scan_gate_fused(a: &[f64], b: &[f64], z: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "scan_gate: a/b length mismatch");
    assert_eq!(a.len(), z.len(), "scan_gate: z length mismatch");
    let mut h = 0.0;
    a.iter()
        .zip(b)
        .zip(z)
        .map(|((&ai, &bi), &zi)| {
            h = ai * h + bi;
            h * silu(zi)
        })
        .collect()
}

/// Unfused scan → gate: scan to a staged `h` buffer, then gate it — the
/// kernel-by-kernel baseline [`scan_gate_fused`] is checked against.
pub fn scan_gate_unfused(a: &[f64], b: &[f64], z: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), z.len(), "scan_gate: z length mismatch");
    mamba_scan_serial(a, b).iter().zip(z).map(|(&h, &zi)| h * silu(zi)).collect()
}

/// FLOPs of a Mamba selective scan over `n` steps with the paper's
/// accounting: each lifted combine is 3 flops (1 mul for `a`, 1 mul + 1 add
/// for `b`), HS-scan does `n·log₂n` combines, B-scan does `2n`.
pub fn mamba_parallel_scan_flops(n: usize, work_per_elem: f64) -> f64 {
    3.0 * work_per_elem * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{max_abs_diff, prop, XorShift};

    #[test]
    fn combinator_is_associative() {
        let mut rng = XorShift::new(51);
        for _ in 0..100 {
            let p = LinStep { a: rng.uniform(-1.0, 1.0), b: rng.uniform(-1.0, 1.0) };
            let q = LinStep { a: rng.uniform(-1.0, 1.0), b: rng.uniform(-1.0, 1.0) };
            let s = LinStep { a: rng.uniform(-1.0, 1.0), b: rng.uniform(-1.0, 1.0) };
            let l = combine(combine(p, q), s);
            let r = combine(p, combine(q, s));
            assert!((l.a - r.a).abs() < 1e-12 && (l.b - r.b).abs() < 1e-12);
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = XorShift::new(52);
        for logn in 0..=10 {
            let n = 1 << logn;
            // Decay in (0,1) like a stable SSM.
            let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
            let b = rng.vec(n, -1.0, 1.0);
            let d = max_abs_diff(&mamba_scan_parallel(&a, &b), &mamba_scan_serial(&a, &b));
            assert!(d < 1e-10, "n={n} diff={d}");
        }
    }

    #[test]
    fn tiled_matches_serial_ragged() {
        let mut rng = XorShift::new(53);
        let n = 1000;
        let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
        let b = rng.vec(n, -1.0, 1.0);
        let d = max_abs_diff(&mamba_scan_tiled(&a, &b, 32), &mamba_scan_serial(&a, &b));
        assert!(d < 1e-10, "diff={d}");
    }

    #[test]
    fn fused_and_unfused_scan_gate_bit_identical() {
        let mut rng = XorShift::new(54);
        for n in [1usize, 7, 100, 1000, 1023] {
            let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 0.99)).collect();
            let b = rng.vec(n, -1.0, 1.0);
            let z = rng.vec(n, -3.0, 3.0);
            assert_eq!(
                scan_gate_fused(&a, &b, &z),
                scan_gate_unfused(&a, &b, &z),
                "n={n}: fusion must not change a single bit"
            );
        }
    }

    #[test]
    fn silu_shape() {
        assert_eq!(silu(0.0), 0.0);
        assert!(silu(10.0) > 9.99 && silu(10.0) < 10.0);
        assert!(silu(-10.0) > -1e-3 && silu(-10.0) < 0.0);
    }

    #[test]
    fn pure_prefix_sum_special_case() {
        // a == 1 reduces the recurrence to an inclusive prefix sum.
        let b = [2.0, 4.0, 6.0, 8.0];
        let a = [1.0; 4];
        assert_eq!(mamba_scan_serial(&a, &b), vec![2.0, 6.0, 12.0, 20.0]);
        let d = max_abs_diff(
            &mamba_scan_parallel(&a, &b),
            &mamba_scan_serial(&a, &b),
        );
        assert!(d < 1e-12);
    }

    #[test]
    fn zero_decay_passes_input_through() {
        // a == 0 means h[t] = b[t].
        let a = [0.0; 8];
        let b: Vec<f64> = (0..8).map(|i| i as f64).collect();
        assert_eq!(mamba_scan_parallel(&a, &b), b);
    }

    #[test]
    fn prop_parallel_and_tiled_match_serial() {
        prop::quick(
            "mamba scan variants agree",
            |rng| {
                let n = rng.range(1, 600);
                let a: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
                let b = rng.vec(n, -2.0, 2.0);
                (a, b)
            },
            prop::no_shrink,
            |(a, b)| {
                let want = mamba_scan_serial(a, b);
                let tiled = mamba_scan_tiled(a, b, 16);
                let d1 = max_abs_diff(&tiled, &want);
                if a.len().is_power_of_two() {
                    let par = mamba_scan_parallel(a, b);
                    let d0 = max_abs_diff(&par, &want);
                    if d0 > 1e-8 {
                        return Err(format!("parallel diff {d0}"));
                    }
                }
                if d1 > 1e-8 {
                    return Err(format!("tiled diff {d1}"));
                }
                Ok(())
            },
        );
    }
}
