//! C-scan: the sequential prefix sum (paper §IV-A).
//!
//! One element per step, inherently serial — the paper's Design 2 runs this
//! on the baseline RDU and is limited to 1 element/cycle/channel no matter
//! how wide the fabric is.

/// Exclusive serial scan: `y[i] = Σ_{j<i} x[j]`, `y[0] = 0`.
pub fn c_scan_exclusive(x: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(x.len());
    let mut acc = 0.0;
    for &v in x {
        out.push(acc);
        acc += v;
    }
    out
}

/// Inclusive serial scan: `y[i] = Σ_{j<=i} x[j]`.
pub fn c_scan_inclusive(x: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(x.len());
    let mut acc = 0.0;
    for &v in x {
        acc += v;
        out.push(acc);
    }
    out
}

/// Serial exclusive scan under an arbitrary associative operator with
/// identity `id` (used by the tiled scan's tile-sum pass).
pub fn serial_exclusive_op<T: Copy>(x: &[T], id: T, op: impl Fn(T, T) -> T) -> Vec<T> {
    let mut out = Vec::with_capacity(x.len());
    let mut acc = id;
    for &v in x {
        out.push(acc);
        acc = op(acc, v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exclusive_empty_and_single() {
        assert!(c_scan_exclusive(&[]).is_empty());
        assert_eq!(c_scan_exclusive(&[5.0]), vec![0.0]);
    }

    #[test]
    fn inclusive_matches_manual() {
        assert_eq!(
            c_scan_inclusive(&[1.0, 2.0, 3.0]),
            vec![1.0, 3.0, 6.0]
        );
    }

    #[test]
    fn exclusive_shifted_inclusive() {
        let x = [3.0, 1.0, 4.0, 1.0, 5.0];
        let ex = c_scan_exclusive(&x);
        let inc = c_scan_inclusive(&x);
        for i in 1..x.len() {
            assert_eq!(ex[i], inc[i - 1]);
        }
        assert_eq!(ex[0], 0.0);
    }

    #[test]
    fn generic_op_matches_specialized() {
        let x = [2.0, 4.0, 6.0, 8.0];
        let got = serial_exclusive_op(&x, 0.0, |a, b| a + b);
        assert_eq!(got, c_scan_exclusive(&x));
    }

    #[test]
    fn generic_op_max_scan() {
        let x = [1.0, 5.0, 3.0, 7.0];
        let got = serial_exclusive_op(&x, f64::NEG_INFINITY, f64::max);
        assert_eq!(got, vec![f64::NEG_INFINITY, 1.0, 5.0, 5.0]);
    }
}
