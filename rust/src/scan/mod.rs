//! Scan algorithm substrate (paper §IV-A).
//!
//! Implements every scan variant the paper discusses, plus the *selective
//! state-space* first-order linear recurrence that is Mamba's actual scan
//! payload. These are the golden models for the Pallas scan kernel and the
//! PCU scan-mode simulator programs.
//!
//! * [`serial`] — C-scan: the inherently sequential one-element-at-a-time
//!   prefix sum (1 element/cycle/channel — the paper's Design 2 baseline).
//! * [`hillis_steele`] — HS-scan: `log₂N` steps, `N·log₂N` work, an
//!   *inclusive* scan with maximal step-parallelism (Fig. 9 left).
//! * [`blelloch`] — B-scan: `2·log₂N` steps, `2N` work, the work-efficient
//!   up-sweep/down-sweep *exclusive* scan (Fig. 9 right).
//! * [`tiled`] — the GPU-Gems tiled scan the paper adopts for long
//!   sequences: R-element tiles scanned locally (one PCU each), tile sums
//!   scanned recursively, offsets added back.
//! * [`recurrence`] — generic associative-operator scans and the Mamba
//!   `h[t] = a[t]·h[t-1] + b[t]` recurrence with its associative lift.
//! * [`chunked`] — [`LANES`]-wide channel-blocked scan/gate/pointwise
//!   kernels: the recurrence's dependence-free axis is *channels*, so four
//!   adjacent channels advance per `[f64; 4]` accumulator block
//!   (autovectorizer-friendly time-major layout), bit-identical to the
//!   `*_scalar` oracles kept beside every chunked path.
//! * [`simd`] — the chunked twins with **explicit** lanes: runtime-detected
//!   AVX/NEON `core::arch` intrinsics (separate mul/add, never FMA, so the
//!   same bit-identity contract holds), falling back to the chunked code
//!   on other hosts. [`simd_backend`] reports which path is live.
//!
//! **When the mapper picks which variant.** The workload builders expose
//! the choice as `ScanVariant` (see `crate::workloads::mamba_decoder`):
//! `CScan` emits one inherently serial kernel that the DFModel mapper pins
//! to a single PCU (1 element/cycle — the paper's Design 2), while
//! `Parallel` emits the lifted scan, which runs spatially *only* on an RDU
//! whose PCUs carry the HS-/B-scan interconnect extension
//! (`crate::arch::RduConfig::hs_scan_mode` / `b_scan_mode`); on a baseline
//! RDU it executes serialized through stage 0 and loses the 1/stages
//! factor. HS-scan spends `N·log₂N` work for `log₂N` steps; B-scan spends
//! `2N` work for `2·log₂N` steps — same steady-state throughput on the
//! extended PCU, which is why Fig. 11's HS-mode and B-mode curves overlap.
//! For sequences longer than one PCU's lanes the tiled driver
//! ([`tiled`], `mamba_scan_tiled`) splits the scan into R-element tiles,
//! and past one chip [`crate::shard::sharded_mamba_scan`] splits it across
//! chips with an inter-chip carry exchange.

pub mod blelloch;
pub mod chunked;
pub mod hillis_steele;
pub mod recurrence;
pub mod serial;
pub mod simd;
pub mod tiled;

pub use blelloch::blelloch_exclusive;
pub use chunked::{
    gate_silu_chunked, gate_silu_scalar, mamba_scan_channels_chunked, mamba_scan_channels_scalar,
    scan_gate_channels_chunked, scan_gate_channels_scalar, silu_slice_chunked, silu_slice_scalar,
    LANES,
};
pub use simd::{
    gate_silu_simd, mamba_scan_channels_simd, scan_gate_channels_simd, simd_backend,
};
pub use hillis_steele::hillis_steele_inclusive;
pub use recurrence::{
    mamba_scan_parallel, mamba_scan_serial, scan_gate_fused, scan_gate_unfused, silu,
};
pub use serial::{c_scan_exclusive, c_scan_inclusive};
pub use tiled::tiled_exclusive;

/// FLOPs for a serial C-scan over N elements: `N` additions.
pub fn c_scan_flops(n: usize) -> f64 {
    n as f64
}

/// FLOPs for a Hillis–Steele scan: `N·log₂N` (paper Fig. 9).
pub fn hs_scan_flops(n: usize) -> f64 {
    let nf = n as f64;
    nf * nf.log2()
}

/// FLOPs for a Blelloch scan: `2N` (paper Fig. 9).
pub fn b_scan_flops(n: usize) -> f64 {
    2.0 * n as f64
}

/// Parallel step count of HS-scan: `log₂N`.
pub fn hs_scan_steps(n: usize) -> usize {
    assert!(n.is_power_of_two());
    n.trailing_zeros() as usize
}

/// Parallel step count of B-scan: `2·log₂N`.
pub fn b_scan_steps(n: usize) -> usize {
    assert!(n.is_power_of_two());
    2 * n.trailing_zeros() as usize
}

/// Exclusive→inclusive conversion helper: shift left and append total.
pub fn exclusive_to_inclusive(input: &[f64], exclusive: &[f64]) -> Vec<f64> {
    assert_eq!(input.len(), exclusive.len());
    input
        .iter()
        .zip(exclusive)
        .map(|(x, e)| x + e)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_exclusive() {
        // Paper §IV-A: input [2,4,6,8] -> exclusive scan [0,2,6,12].
        let got = c_scan_exclusive(&[2.0, 4.0, 6.0, 8.0]);
        assert_eq!(got, vec![0.0, 2.0, 6.0, 12.0]);
    }

    #[test]
    fn flop_models() {
        assert_eq!(c_scan_flops(1024), 1024.0);
        assert_eq!(hs_scan_flops(1024), 1024.0 * 10.0);
        assert_eq!(b_scan_flops(1024), 2048.0);
        assert_eq!(hs_scan_steps(1024), 10);
        assert_eq!(b_scan_steps(1024), 20);
    }

    #[test]
    fn exclusive_to_inclusive_works() {
        let x = [2.0, 4.0, 6.0, 8.0];
        let ex = c_scan_exclusive(&x);
        let inc = exclusive_to_inclusive(&x, &ex);
        assert_eq!(inc, vec![2.0, 6.0, 12.0, 20.0]);
    }
}
