//! Blelloch scan (paper §IV-A, Fig. 9 right).
//!
//! `2·log₂N` parallel steps, `2N` total work: a binary-tree **up-sweep**
//! (reduction) builds partial sums, then a **down-sweep** distributes
//! prefixes back to the leaves, producing an *exclusive* scan. The
//! work-efficient variant whose tree pattern the B-scan-mode PCU wires into
//! its interconnect.

/// Exclusive Blelloch scan. `x.len()` must be a power of two.
pub fn blelloch_exclusive(x: &[f64]) -> Vec<f64> {
    blelloch_exclusive_op(x, 0.0, |a, b| a + b)
}

/// Exclusive Blelloch scan under an arbitrary associative operator with
/// identity `id`. The two phases below mirror paper Fig. 9 exactly.
pub fn blelloch_exclusive_op<T: Copy>(x: &[T], id: T, op: impl Fn(T, T) -> T) -> Vec<T> {
    let n = x.len();
    assert!(n.is_power_of_two(), "blelloch: N={n} not a power of two");
    let mut a = x.to_vec();
    if n == 1 {
        return vec![id];
    }

    // Up-sweep (reduce): for d = 1, 2, 4, ..., n/2:
    //   a[j + 2d - 1] = a[j + d - 1] ⊕ a[j + 2d - 1]
    let mut d = 1;
    while d < n {
        let stride = 2 * d;
        for j in (0..n).step_by(stride) {
            a[j + stride - 1] = op(a[j + d - 1], a[j + stride - 1]);
        }
        d = stride;
    }

    // Clear the root, then down-sweep: each node passes its value to the
    // left child and (left ⊕ value) to the right child.
    a[n - 1] = id;
    let mut d = n / 2;
    while d >= 1 {
        let stride = 2 * d;
        for j in (0..n).step_by(stride) {
            let left = a[j + d - 1];
            a[j + d - 1] = a[j + stride - 1];
            a[j + stride - 1] = op(left, a[j + stride - 1]);
        }
        d /= 2;
    }
    a
}

/// Work performed (binary-op applications) by an N-point Blelloch scan:
/// `(N−1)` in the up-sweep + `(N−1)` in the down-sweep ≈ `2N` (paper Fig. 9).
pub fn b_work(n: usize) -> usize {
    assert!(n.is_power_of_two());
    2 * (n - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::serial::c_scan_exclusive;
    use crate::util::{max_abs_diff, prop};

    #[test]
    fn paper_example() {
        assert_eq!(
            blelloch_exclusive(&[2.0, 4.0, 6.0, 8.0]),
            vec![0.0, 2.0, 6.0, 12.0]
        );
    }

    #[test]
    fn matches_serial_various_sizes() {
        for logn in 0..=10 {
            let n = 1 << logn;
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
            let d = max_abs_diff(&blelloch_exclusive(&x), &c_scan_exclusive(&x));
            assert!(d < 1e-9, "n={n} diff={d}");
        }
    }

    #[test]
    #[should_panic(expected = "not a power of two")]
    fn non_pow2_rejected() {
        blelloch_exclusive(&[1.0, 2.0, 3.0]);
    }

    #[test]
    fn generic_op_product_scan() {
        let x = [2.0, 3.0, 4.0, 5.0];
        let got = blelloch_exclusive_op(&x, 1.0, |a, b| a * b);
        assert_eq!(got, vec![1.0, 2.0, 6.0, 24.0]);
    }

    #[test]
    fn work_formula() {
        assert_eq!(b_work(8), 14);
        assert_eq!(b_work(1024), 2046);
    }

    #[test]
    fn prop_matches_serial() {
        prop::quick(
            "blelloch == serial",
            |rng| { let n = 1usize << rng.range(0, 10); rng.vec(n, -10.0, 10.0) },
            prop::no_shrink,
            |xs| {
                let d = max_abs_diff(&blelloch_exclusive(xs), &c_scan_exclusive(xs));
                if d < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("diff {d}"))
                }
            },
        );
    }

    #[test]
    fn prop_agrees_with_hillis_steele() {
        use crate::scan::hillis_steele::hillis_steele_exclusive;
        prop::quick(
            "blelloch == hillis-steele",
            |rng| { let n = 1usize << rng.range(0, 9); rng.vec(n, -5.0, 5.0) },
            prop::no_shrink,
            |xs| {
                let d = max_abs_diff(&blelloch_exclusive(xs), &hillis_steele_exclusive(xs));
                if d < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("diff {d}"))
                }
            },
        );
    }
}
