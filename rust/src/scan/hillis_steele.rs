//! Hillis–Steele scan (paper §IV-A, Fig. 9 left).
//!
//! `log₂N` parallel steps, `N·log₂N` total work. In step `i` every element
//! `j` adds the value at `j − 2^{i−1}` (when it exists). High parallelism,
//! more data movement — the variant whose cross-lane pattern the HS-scan-mode
//! PCU wires directly into its inter-stage interconnect.

/// Inclusive Hillis–Steele scan. `x.len()` must be a power of two (matching
/// the hardware mapping; arbitrary lengths are handled by the tiled driver).
pub fn hillis_steele_inclusive(x: &[f64]) -> Vec<f64> {
    hillis_steele_inclusive_op(x, |a, b| a + b)
}

/// Inclusive Hillis–Steele scan under an arbitrary associative operator.
///
/// The step structure (`offset = 1, 2, 4, …`) is exactly the dataflow in
/// paper Fig. 9; each outer iteration is one PCU pipeline stage in the
/// HS-scan-mode mapping.
pub fn hillis_steele_inclusive_op<T: Copy>(x: &[T], op: impl Fn(T, T) -> T) -> Vec<T> {
    let n = x.len();
    assert!(n.is_power_of_two(), "hillis_steele: N={n} not a power of two");
    let mut cur = x.to_vec();
    let mut next = x.to_vec();
    let mut offset = 1;
    while offset < n {
        for j in 0..n {
            next[j] = if j >= offset {
                op(cur[j - offset], cur[j])
            } else {
                cur[j]
            };
        }
        std::mem::swap(&mut cur, &mut next);
        offset <<= 1;
    }
    cur
}

/// Exclusive HS-scan: inclusive scan shifted right with 0 injected.
pub fn hillis_steele_exclusive(x: &[f64]) -> Vec<f64> {
    let inc = hillis_steele_inclusive(x);
    let mut out = Vec::with_capacity(x.len());
    out.push(0.0);
    out.extend_from_slice(&inc[..x.len().saturating_sub(1)]);
    out
}

/// Work performed (add operations) by an N-point HS-scan — matches the
/// paper's `N·log₂N` accounting (border elements that merely copy are
/// counted as occupied lanes, as in the hardware mapping).
pub fn hs_work(n: usize) -> usize {
    assert!(n.is_power_of_two());
    n * n.trailing_zeros() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::serial::{c_scan_exclusive, c_scan_inclusive};
    use crate::util::{max_abs_diff, prop};

    #[test]
    fn matches_serial_inclusive() {
        let x: Vec<f64> = (1..=16).map(|i| i as f64).collect();
        assert_eq!(hillis_steele_inclusive(&x), c_scan_inclusive(&x));
    }

    #[test]
    fn exclusive_matches_serial() {
        let x = [2.0, 4.0, 6.0, 8.0];
        assert_eq!(hillis_steele_exclusive(&x), c_scan_exclusive(&x));
    }

    #[test]
    fn single_element() {
        assert_eq!(hillis_steele_inclusive(&[7.0]), vec![7.0]);
    }

    #[test]
    #[should_panic(expected = "not a power of two")]
    fn non_pow2_rejected() {
        hillis_steele_inclusive(&[1.0, 2.0, 3.0]);
    }

    #[test]
    fn generic_op_max() {
        let x = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let got = hillis_steele_inclusive_op(&x, f64::max);
        let want = vec![3.0, 3.0, 4.0, 4.0, 5.0, 9.0, 9.0, 9.0];
        assert_eq!(got, want);
    }

    #[test]
    fn work_formula() {
        assert_eq!(hs_work(8), 24);
        assert_eq!(hs_work(1024), 10240);
    }

    #[test]
    fn prop_matches_serial() {
        prop::quick(
            "hs == serial",
            |rng| { let n = 1usize << rng.range(0, 10); rng.vec(n, -10.0, 10.0) },
            prop::no_shrink,
            |xs| {
                let d = max_abs_diff(&hillis_steele_inclusive(xs), &c_scan_inclusive(xs));
                if d < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("diff {d}"))
                }
            },
        );
    }
}
