//! Kernel descriptions: the vertices of the workload dataflow graph
//! (paper Fig. 1A — "vertices represent computation kernels").

use std::fmt;

/// Computational class of a kernel — determines which hardware resource the
//  kernel maps to on each platform (tensor cores vs CUDA cores on the GPU;
/// systolic vs FFT-mode vs scan-mode PCUs on the RDU).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Dense matrix multiplication (projections, MLP, attention scores).
    Gemm,
    /// R-point DFTs expressed as dense matmuls (Bailey GEMM-FFT, §III-A).
    GemmFft,
    /// Radix-2 butterflies (Bailey Vector-FFT, §III-A).
    VectorFft,
    /// The sequential C-scan: one element at a time (§IV-A).
    ScanSerial,
    /// Parallel scan (Hillis–Steele or Blelloch, §IV-A).
    ScanParallel,
    /// Element-wise map (gates, residuals, twiddle scaling, activations).
    Elementwise,
    /// Attention softmax (row max, exp, normalize) — a vector-path kernel.
    Softmax,
    /// Layer normalization.
    Norm,
}

impl OpClass {
    /// Does this class execute on the GPU's tensor cores (true) or CUDA
    /// cores (false)? Paper §III-C: "GEMM-FFT operations are executed on
    /// the tensor cores, while Vector-FFT operations are executed on the
    /// CUDA cores"; §IV-C: "scans are executed on CUDA cores".
    pub fn gpu_tensor_core(self) -> bool {
        matches!(self, OpClass::Gemm | OpClass::GemmFft)
    }

    /// Short label for tables.
    pub fn label(self) -> &'static str {
        match self {
            OpClass::Gemm => "gemm",
            OpClass::GemmFft => "gemm-fft",
            OpClass::VectorFft => "vector-fft",
            OpClass::ScanSerial => "c-scan",
            OpClass::ScanParallel => "par-scan",
            OpClass::Elementwise => "eltwise",
            OpClass::Softmax => "softmax",
            OpClass::Norm => "norm",
        }
    }
}

impl fmt::Display for OpClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// One computation kernel: work, tensor-traffic and streaming metadata.
///
/// Byte fields describe the kernel's *logical* tensor traffic; how much of
/// it touches DRAM depends on the execution model (dataflow keeps
/// intermediates on-chip, kernel-by-kernel stages them — paper Fig. 1B/C).
#[derive(Debug, Clone, PartialEq)]
pub struct Kernel {
    pub name: String,
    pub op: OpClass,
    /// Floating-point operations (paper accounting; see `fft`/`scan`).
    pub flops: f64,
    /// Bytes of activations read.
    pub input_bytes: f64,
    /// Bytes of activations written.
    pub output_bytes: f64,
    /// Bytes of resident parameters (weights, filters, twiddles).
    pub weight_bytes: f64,
    /// Sequence positions streamed through the kernel (drives the serial
    /// C-scan latency: one element per cycle, paper §IV-A).
    pub elements: f64,
    /// Independent channels the kernel processes (lanes of parallelism
    /// orthogonal to `elements`).
    pub channels: f64,
}

impl Kernel {
    /// Construct with explicit traffic; `elements`/`channels` default to 0/1.
    pub fn new(name: &str, op: OpClass, flops: f64, input_bytes: f64, output_bytes: f64) -> Self {
        Self {
            name: name.to_string(),
            op,
            flops,
            input_bytes,
            output_bytes,
            weight_bytes: 0.0,
            elements: 0.0,
            channels: 1.0,
        }
    }

    /// Builder: set resident parameter bytes.
    pub fn with_weights(mut self, bytes: f64) -> Self {
        self.weight_bytes = bytes;
        self
    }

    /// Builder: set streaming extent (elements × channels).
    pub fn with_stream(mut self, elements: f64, channels: f64) -> Self {
        self.elements = elements;
        self.channels = channels;
        self
    }

    /// Total logical tensor traffic (reads + writes, excluding weights).
    pub fn activation_bytes(&self) -> f64 {
        self.input_bytes + self.output_bytes
    }

    /// Arithmetic intensity in FLOP/byte over activation traffic.
    pub fn arithmetic_intensity(&self) -> f64 {
        if self.activation_bytes() == 0.0 {
            return f64::INFINITY;
        }
        self.flops / self.activation_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_core_assignment_matches_paper() {
        assert!(OpClass::Gemm.gpu_tensor_core());
        assert!(OpClass::GemmFft.gpu_tensor_core());
        assert!(!OpClass::VectorFft.gpu_tensor_core());
        assert!(!OpClass::ScanParallel.gpu_tensor_core());
        assert!(!OpClass::ScanSerial.gpu_tensor_core());
        assert!(!OpClass::Softmax.gpu_tensor_core());
    }

    #[test]
    fn intensity() {
        let k = Kernel::new("k", OpClass::Gemm, 1000.0, 100.0, 100.0);
        assert_eq!(k.arithmetic_intensity(), 5.0);
        let z = Kernel::new("z", OpClass::Gemm, 1000.0, 0.0, 0.0);
        assert!(z.arithmetic_intensity().is_infinite());
    }

    #[test]
    fn builders() {
        let k = Kernel::new("k", OpClass::ScanSerial, 10.0, 1.0, 1.0)
            .with_weights(64.0)
            .with_stream(1024.0, 32.0);
        assert_eq!(k.weight_bytes, 64.0);
        assert_eq!(k.elements, 1024.0);
        assert_eq!(k.channels, 32.0);
    }
}
