//! Workload dataflow-graph IR (paper Fig. 1A): vertices are computation
//! kernels, edges are tensors. [`crate::workloads`] builds the attention /
//! Hyena / Mamba decoder graphs; [`crate::dfmodel`], [`crate::gpu`] and
//! [`crate::vga`] consume them to estimate performance under dataflow vs
//! kernel-by-kernel execution (Fig. 1B/C).

pub mod kernel;

pub use kernel::{Kernel, OpClass};

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Index of a kernel within a [`Graph`].
pub type KernelId = usize;

/// A tensor edge between two kernels (or from the graph input / to the graph
/// output when `src`/`dst` is `None`).
#[derive(Debug, Clone, PartialEq)]
pub struct Edge {
    /// Producing kernel, or `None` for a graph input from DRAM.
    pub src: Option<KernelId>,
    /// Consuming kernel, or `None` for a graph output to DRAM.
    pub dst: Option<KernelId>,
    /// Tensor size in bytes.
    pub bytes: f64,
    /// Producer→consumer *stream* edge: the producer emits the tensor in the
    /// element order the consumer ingests it (possibly through a corner-turn
    /// PMU buffer), so a fused mapping may forward it entirely through
    /// on-chip SRAM instead of staging it in DRAM. Workload builders mark
    /// these with [`Graph::connect_stream`]; [`crate::dfmodel`]'s fusion
    /// pass grows clusters along them.
    pub stream: bool,
}

/// A workload dataflow graph.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Graph {
    pub name: String,
    pub kernels: Vec<Kernel>,
    pub edges: Vec<Edge>,
}

impl Graph {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), kernels: Vec::new(), edges: Vec::new() }
    }

    /// Add a kernel, returning its id.
    pub fn add(&mut self, k: Kernel) -> KernelId {
        self.kernels.push(k);
        self.kernels.len() - 1
    }

    /// Add an internal tensor edge.
    pub fn connect(&mut self, src: KernelId, dst: KernelId, bytes: f64) {
        assert!(src < self.kernels.len() && dst < self.kernels.len());
        self.edges.push(Edge { src: Some(src), dst: Some(dst), bytes, stream: false });
    }

    /// Add an internal tensor edge the consumer can ingest as a stream (see
    /// [`Edge::stream`]) — a fusion candidate for the dataflow mapper.
    pub fn connect_stream(&mut self, src: KernelId, dst: KernelId, bytes: f64) {
        assert!(src < self.kernels.len() && dst < self.kernels.len());
        self.edges.push(Edge { src: Some(src), dst: Some(dst), bytes, stream: true });
    }

    /// Mark a kernel as reading a graph input of `bytes` from DRAM.
    pub fn input(&mut self, dst: KernelId, bytes: f64) {
        assert!(dst < self.kernels.len());
        self.edges.push(Edge { src: None, dst: Some(dst), bytes, stream: false });
    }

    /// Mark a kernel as writing a graph output of `bytes` to DRAM.
    pub fn output(&mut self, src: KernelId, bytes: f64) {
        assert!(src < self.kernels.len());
        self.edges.push(Edge { src: Some(src), dst: None, bytes, stream: false });
    }

    /// Kernels feeding `id` through any internal edge (deduplicated).
    pub fn predecessors(&self, id: KernelId) -> Vec<KernelId> {
        let mut p: Vec<KernelId> = self
            .edges
            .iter()
            .filter(|e| e.dst == Some(id))
            .filter_map(|e| e.src)
            .collect();
        p.sort_unstable();
        p.dedup();
        p
    }

    /// Kernels feeding `id` through *stream* edges (deduplicated) — the
    /// producers the fusion pass may cluster `id` with.
    pub fn stream_predecessors(&self, id: KernelId) -> Vec<KernelId> {
        let mut p: Vec<KernelId> = self
            .edges
            .iter()
            .filter(|e| e.stream && e.dst == Some(id))
            .filter_map(|e| e.src)
            .collect();
        p.sort_unstable();
        p.dedup();
        p
    }

    /// Bytes of intermediate tensors carried by stream edges — the traffic a
    /// fully fused mapping keeps on-chip.
    pub fn stream_bytes(&self) -> f64 {
        self.edges
            .iter()
            .filter(|e| e.stream && e.src.is_some() && e.dst.is_some())
            .map(|e| e.bytes)
            .sum()
    }

    /// Total FLOPs over all kernels.
    pub fn total_flops(&self) -> f64 {
        self.kernels.iter().map(|k| k.flops).sum()
    }

    /// Total resident parameter bytes.
    pub fn total_weight_bytes(&self) -> f64 {
        self.kernels.iter().map(|k| k.weight_bytes).sum()
    }

    /// Bytes entering the graph from DRAM (dataflow execution's only reads,
    /// paper Fig. 1B).
    pub fn external_input_bytes(&self) -> f64 {
        self.edges.iter().filter(|e| e.src.is_none()).map(|e| e.bytes).sum()
    }

    /// Bytes leaving the graph to DRAM.
    pub fn external_output_bytes(&self) -> f64 {
        self.edges.iter().filter(|e| e.dst.is_none()).map(|e| e.bytes).sum()
    }

    /// Bytes of intermediate tensors between kernels — staged through DRAM
    /// under kernel-by-kernel execution (Fig. 1C), kept on-chip under
    /// dataflow execution (Fig. 1B).
    pub fn intermediate_bytes(&self) -> f64 {
        self.edges
            .iter()
            .filter(|e| e.src.is_some() && e.dst.is_some())
            .map(|e| e.bytes)
            .sum()
    }

    /// Peak bytes of any single intermediate tensor — the PMU-capacity
    /// constraint checker uses this.
    pub fn max_intermediate_bytes(&self) -> f64 {
        self.edges
            .iter()
            .filter(|e| e.src.is_some() && e.dst.is_some())
            .map(|e| e.bytes)
            .fold(0.0, f64::max)
    }

    /// FLOPs grouped by op class (the paper's Fig. 7/11 FLOP breakdowns).
    pub fn flops_by_op(&self) -> BTreeMap<&'static str, f64> {
        let mut m = BTreeMap::new();
        for k in &self.kernels {
            *m.entry(k.op.label()).or_insert(0.0) += k.flops;
        }
        m
    }

    /// Kernel ids in a valid topological order. Panics if the graph is
    /// cyclic (dataflow graphs are DAGs by construction).
    pub fn topo_order(&self) -> Vec<KernelId> {
        let n = self.kernels.len();
        let mut indeg = vec![0usize; n];
        let mut succ: Vec<Vec<KernelId>> = vec![Vec::new(); n];
        for e in &self.edges {
            if let (Some(s), Some(d)) = (e.src, e.dst) {
                indeg[d] += 1;
                succ[s].push(d);
            }
        }
        let mut ready: Vec<KernelId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(i) = ready.pop() {
            order.push(i);
            for &d in &succ[i] {
                indeg[d] -= 1;
                if indeg[d] == 0 {
                    ready.push(d);
                }
            }
        }
        assert_eq!(order.len(), n, "graph `{}` contains a cycle", self.name);
        order
    }

    /// Structural validation: edge endpoints in range, DAG, every kernel
    /// reachable from some input and reaching some output.
    pub fn validate(&self) -> Result<(), String> {
        for e in &self.edges {
            if let Some(s) = e.src {
                if s >= self.kernels.len() {
                    return Err(format!("edge src {s} out of range"));
                }
            }
            if let Some(d) = e.dst {
                if d >= self.kernels.len() {
                    return Err(format!("edge dst {d} out of range"));
                }
            }
            if e.src.is_none() && e.dst.is_none() {
                return Err("edge with neither src nor dst".to_string());
            }
            if !e.bytes.is_finite() || e.bytes < 0.0 {
                return Err(format!("edge bytes {} invalid", e.bytes));
            }
        }
        // topo_order panics on cycles; convert to an error.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.topo_order()));
        if r.is_err() {
            return Err(format!("graph `{}` contains a cycle", self.name));
        }
        for (i, k) in self.kernels.iter().enumerate() {
            let has_in = self.edges.iter().any(|e| e.dst == Some(i));
            let has_out = self.edges.iter().any(|e| e.src == Some(i));
            if !has_in || !has_out {
                return Err(format!("kernel `{}` ({i}) is dangling", k.name));
            }
        }
        Ok(())
    }

    /// Graphviz dot rendering, for DESIGN.md-style inspection.
    pub fn to_dot(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "digraph \"{}\" {{", self.name);
        let _ = writeln!(s, "  rankdir=LR; node [shape=box];");
        for (i, k) in self.kernels.iter().enumerate() {
            let _ = writeln!(
                s,
                "  k{i} [label=\"{}\\n{} | {} FLOP\"];",
                k.name,
                k.op,
                crate::util::eng(k.flops)
            );
        }
        for (j, e) in self.edges.iter().enumerate() {
            let src = match e.src {
                Some(s) => format!("k{s}"),
                None => {
                    let _ = writeln!(s, "  in{j} [shape=plaintext,label=\"DRAM\"];");
                    format!("in{j}")
                }
            };
            let dst = match e.dst {
                Some(d) => format!("k{d}"),
                None => {
                    let _ = writeln!(s, "  out{j} [shape=plaintext,label=\"DRAM\"];");
                    format!("out{j}")
                }
            };
            let style = if e.stream { ",style=bold" } else { "" };
            let _ = writeln!(
                s,
                "  {src} -> {dst} [label=\"{}B\"{style}];",
                crate::util::eng(e.bytes)
            );
        }
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> Graph {
        let mut g = Graph::new("chain");
        let a = g.add(Kernel::new("a", OpClass::Gemm, 100.0, 10.0, 10.0));
        let b = g.add(Kernel::new("b", OpClass::Softmax, 50.0, 10.0, 10.0));
        let c = g.add(Kernel::new("c", OpClass::Gemm, 100.0, 10.0, 10.0));
        g.input(a, 10.0);
        g.connect(a, b, 10.0);
        g.connect(b, c, 10.0);
        g.output(c, 10.0);
        g
    }

    #[test]
    fn totals() {
        let g = chain();
        assert_eq!(g.total_flops(), 250.0);
        assert_eq!(g.external_input_bytes(), 10.0);
        assert_eq!(g.external_output_bytes(), 10.0);
        assert_eq!(g.intermediate_bytes(), 20.0);
        assert_eq!(g.max_intermediate_bytes(), 10.0);
    }

    #[test]
    fn topo_and_validate() {
        let g = chain();
        let order = g.topo_order();
        let pos = |i: usize| order.iter().position(|&x| x == i).unwrap();
        assert!(pos(0) < pos(1) && pos(1) < pos(2));
        assert!(g.validate().is_ok());
    }

    #[test]
    fn cycle_detected() {
        let mut g = chain();
        g.connect(2, 0, 1.0);
        assert!(g.validate().is_err());
    }

    #[test]
    fn dangling_kernel_detected() {
        let mut g = chain();
        g.add(Kernel::new("orphan", OpClass::Norm, 1.0, 1.0, 1.0));
        assert!(g.validate().is_err());
    }

    #[test]
    fn flops_by_op_groups() {
        let g = chain();
        let m = g.flops_by_op();
        assert_eq!(m["gemm"], 200.0);
        assert_eq!(m["softmax"], 50.0);
    }

    #[test]
    fn dot_renders() {
        let d = chain().to_dot();
        assert!(d.contains("digraph"));
        assert!(d.contains("k0 -> k1"));
        assert!(d.contains("DRAM"));
    }

    #[test]
    fn bad_edges_rejected() {
        let mut g = chain();
        g.edges.push(Edge { src: None, dst: None, bytes: 1.0, stream: false });
        assert!(g.validate().is_err());
        let mut g2 = chain();
        g2.edges.push(Edge { src: Some(0), dst: Some(1), bytes: f64::NAN, stream: false });
        assert!(g2.validate().is_err());
    }

    #[test]
    fn stream_edges_and_neighbors() {
        let mut g = Graph::new("s");
        let a = g.add(Kernel::new("a", OpClass::Gemm, 1.0, 1.0, 1.0));
        let b = g.add(Kernel::new("b", OpClass::Elementwise, 1.0, 1.0, 1.0));
        let c = g.add(Kernel::new("c", OpClass::Gemm, 1.0, 1.0, 1.0));
        g.input(a, 1.0);
        g.input(c, 1.0);
        g.connect_stream(a, b, 8.0);
        g.connect(c, b, 4.0);
        g.output(b, 1.0);
        assert!(g.validate().is_ok());
        assert_eq!(g.predecessors(b), vec![a, c]);
        assert_eq!(g.stream_predecessors(b), vec![a]);
        assert!(g.stream_predecessors(a).is_empty());
        assert_eq!(g.stream_bytes(), 8.0);
        assert_eq!(g.intermediate_bytes(), 12.0, "stream edges are intermediates too");
        // Dot rendering styles the stream edge.
        let d = g.to_dot();
        assert!(d.contains("style=bold"), "{d}");
    }
}
