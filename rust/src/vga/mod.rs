//! Analytical model of the VGA fixed-function ASIC (paper Table II, Fig. 8;
//! ref. [22]: "VGA: hardware accelerator for scalable long sequence model
//! inference").
//!
//! VGA provides dedicated GEMM and FFT pipelines and executes dataflow-style
//! (fused, streaming), so its latency model mirrors the RDU's: pipeline
//! bottleneck + overlapped DRAM streaming, at the Table II rates. VGA is
//! *fixed-function*: it has no scan support, so Mamba workloads return an
//! error — the paper's §III-C generality argument ("the RDU [supports] a
//! broader range of workloads that VGA cannot efficiently handle, e.g.
//! Mamba models").

use crate::arch::VgaSpec;
use crate::graph::{Graph, OpClass};

/// Estimate result for a graph on VGA.
#[derive(Debug, Clone, PartialEq)]
pub struct VgaEstimate {
    pub graph_name: String,
    pub total_seconds: f64,
    pub compute_seconds: f64,
    pub memory_seconds: f64,
    /// Time on the GEMM pipeline vs the FFT/vector pipeline.
    pub gemm_seconds: f64,
    pub fft_seconds: f64,
}

/// Why VGA cannot run a workload.
#[derive(Debug, Clone, PartialEq)]
pub enum VgaError {
    /// Fixed-function VGA has no scan hardware (paper §III-C).
    UnsupportedOp { kernel: String, op: OpClass },
}

impl std::fmt::Display for VgaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VgaError::UnsupportedOp { kernel, op } => {
                write!(f, "VGA is fixed-function: kernel `{kernel}` ({op}) has no mapping")
            }
        }
    }
}

/// Which VGA pipeline a kernel maps to, if any.
fn pipeline(op: OpClass) -> Option<bool /* gemm pipeline */> {
    match op {
        OpClass::Gemm | OpClass::GemmFft => Some(true),
        // FFT pipeline also hosts the vector post/pre-processing kernels.
        OpClass::VectorFft | OpClass::Elementwise | OpClass::Softmax | OpClass::Norm => Some(false),
        OpClass::ScanSerial | OpClass::ScanParallel => None,
    }
}

/// Estimate dataflow execution of `g` on the VGA ASIC.
pub fn estimate(g: &Graph, spec: &VgaSpec) -> Result<VgaEstimate, VgaError> {
    let mut gemm_flops = 0.0;
    let mut fft_flops = 0.0;
    for k in &g.kernels {
        match pipeline(k.op) {
            Some(true) => gemm_flops += k.flops,
            Some(false) => fft_flops += k.flops,
            None => {
                return Err(VgaError::UnsupportedOp { kernel: k.name.clone(), op: k.op })
            }
        }
    }
    // The two pipelines stream concurrently; each is bounded by its rate.
    let gemm_seconds = gemm_flops / spec.gemm_flops;
    let fft_seconds = fft_flops / spec.fft_flops;
    let compute_seconds = gemm_seconds.max(fft_seconds);
    // Dataflow memory: external I/O + weights only (fused intermediates).
    let io = g.external_input_bytes() + g.external_output_bytes() + g.total_weight_bytes();
    let memory_seconds = io / spec.dram.bandwidth();
    Ok(VgaEstimate {
        graph_name: g.name.clone(),
        total_seconds: compute_seconds.max(memory_seconds),
        compute_seconds,
        memory_seconds,
        gemm_seconds,
        fft_seconds,
    })
}

/// VGA scaled so its *effective* FFT throughput matches the FFT-mode RDU's
/// (paper §III-C: "the VGA configuration is scaled to match the compute
/// throughput of the RDU") — used by the Fig. 8 bench to reproduce the
/// "VGA and RDU achieve similar performance" observation.
pub fn scaled_to_rdu_effective(rdu_effective_fft_flops: f64, rdu_gemm_flops: f64) -> VgaSpec {
    VgaSpec {
        name: "VGA (scaled to RDU effective)".to_string(),
        gemm_flops: rdu_gemm_flops,
        fft_flops: rdu_effective_fft_flops,
        dram: crate::arch::MemTech::Hbm3e,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::BaileyVariant;
    use crate::workloads::{hyena_decoder, mamba_decoder, DecoderConfig, ScanVariant};

    fn cfg() -> DecoderConfig {
        DecoderConfig::paper(1 << 20)
    }

    #[test]
    fn vga_runs_hyena_both_variants() {
        let spec = VgaSpec::table2();
        for v in [BaileyVariant::Vector, BaileyVariant::Gemm] {
            let e = estimate(&hyena_decoder(&cfg(), v), &spec).unwrap();
            assert!(e.total_seconds > 0.0);
        }
    }

    #[test]
    fn vga_rejects_mamba() {
        // Paper §III-C: VGA cannot handle Mamba.
        let spec = VgaSpec::table2();
        for v in [ScanVariant::CScan, ScanVariant::Parallel] {
            let r = estimate(&mamba_decoder(&cfg(), v), &spec);
            assert!(matches!(r, Err(VgaError::UnsupportedOp { .. })), "{v:?}");
        }
    }

    #[test]
    fn pipelines_overlap() {
        let spec = VgaSpec::table2();
        let e = estimate(&hyena_decoder(&cfg(), BaileyVariant::Vector), &spec).unwrap();
        assert!(e.compute_seconds < e.gemm_seconds + e.fft_seconds);
        assert_eq!(e.compute_seconds, e.gemm_seconds.max(e.fft_seconds));
    }

    #[test]
    fn gemm_fft_variant_loads_gemm_pipeline() {
        let spec = VgaSpec::table2();
        let ev = estimate(&hyena_decoder(&cfg(), BaileyVariant::Vector), &spec).unwrap();
        let eg = estimate(&hyena_decoder(&cfg(), BaileyVariant::Gemm), &spec).unwrap();
        assert!(eg.gemm_seconds > ev.gemm_seconds);
        assert!(eg.fft_seconds < ev.fft_seconds);
    }
}
