//! Reproduction of every table and figure in the paper's evaluation —
//! shared by the CLI (`ssm-rdu fig7 …`), the benches (`cargo bench`) and
//! the integration tests, so all three always report the same numbers.
//!
//! Each function returns a data struct plus a rendered table carrying
//! paper-vs-measured columns; EXPERIMENTS.md records the runs.

pub mod fusion;
pub mod hyena;
pub mod mamba;
pub mod overheads;
pub mod platforms;

pub use fusion::{fusion_at, fusion_at_workloads, fusion_table, FusionPoint};
pub use hyena::{fig7, Fig7};
pub use mamba::{fig11, fig12, Fig11, Fig12};
pub use overheads::table4;
pub use platforms::{fig8, Fig8};

use crate::arch::RduSpec;
use crate::util::table::Table;

/// Table I: the RDU architectural specification.
pub fn table1() -> Table {
    RduSpec::table1().table1_report()
}

/// The paper's sequence-length sweep, in tokens.
pub const PAPER_SEQ_LENS: [usize; 3] = [256 * 1024, 512 * 1024, 1024 * 1024];

/// Pretty "256K/512K/1M" labels for the sweep.
pub fn seq_label(l: usize) -> String {
    if l >= 1024 * 1024 && l.is_multiple_of(1024 * 1024) {
        format!("{}M", l / (1024 * 1024))
    } else {
        format!("{}K", l / 1024)
    }
}

/// A paper-vs-measured speedup comparison row.
#[derive(Debug, Clone, PartialEq)]
pub struct SpeedupRow {
    pub label: String,
    pub paper: f64,
    pub measured: f64,
}

impl SpeedupRow {
    pub fn new(label: &str, paper: f64, measured: f64) -> Self {
        Self { label: label.to_string(), paper, measured }
    }

    /// measured / paper ratio — 1.0 means exact reproduction.
    pub fn fidelity(&self) -> f64 {
        self.measured / self.paper
    }
}

/// Render a block of speedup rows.
pub fn speedup_table(title: &str, rows: &[SpeedupRow]) -> Table {
    let mut t = Table::new(title, &["Speedup", "Paper", "Measured", "Measured/Paper"]);
    for r in rows {
        t.row(&[
            r.label.clone(),
            format!("{:.2}x", r.paper),
            format!("{:.2}x", r.measured),
            format!("{:.2}", r.fidelity()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seq_labels() {
        assert_eq!(seq_label(256 * 1024), "256K");
        assert_eq!(seq_label(1024 * 1024), "1M");
    }

    #[test]
    fn fidelity_math() {
        let r = SpeedupRow::new("x", 2.0, 3.0);
        assert_eq!(r.fidelity(), 1.5);
    }

    #[test]
    fn table1_renders() {
        assert!(table1().render().contains("520 PCUs"));
    }
}
