//! Table II + Figure 8: the Hyena decoder across three platforms — A100
//! GPU (kernel-by-kernel), VGA ASIC (fixed-function dataflow) and the
//! FFT-mode RDU (reconfigurable dataflow).
//!
//! Paper observations (§III-C): GEMM-FFT — VGA and RDU ≈ 2× over GPU;
//! Vector-FFT — VGA and RDU ≈ 5.95× over GPU; VGA ≈ RDU on both.

use super::{seq_label, speedup_table, SpeedupRow, PAPER_SEQ_LENS};
use crate::arch::{GpuSpec, RduConfig, VgaSpec};
use crate::dfmodel;
use crate::fft::BaileyVariant;
use crate::gpu;
use crate::util::table::Table;
use crate::util::fmt_time;
use crate::vga;
use crate::workloads::{hyena_decoder, DecoderConfig};

/// Latencies of one Hyena variant on the three platforms.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformRow {
    pub variant: &'static str,
    pub seq_len: usize,
    pub gpu: f64,
    pub vga: f64,
    pub rdu: f64,
}

/// The Fig. 8 dataset.
#[derive(Debug, Clone, PartialEq)]
pub struct Fig8 {
    pub rows: Vec<PlatformRow>,
    pub speedups: Vec<SpeedupRow>,
}

/// Render Table II (platform specifications).
pub fn table2() -> Table {
    let g = GpuSpec::a100();
    let v = VgaSpec::table2();
    let r = crate::arch::RduSpec::table1();
    let mut t = Table::new(
        "TABLE II — architectural specifications of three accelerators",
        &["", "GPU", "VGA", "FFT RDU"],
    );
    t.row(&[
        "GEMM FP16 TFLOPS".into(),
        format!("{:.2}", g.tensor_flops / 1e12),
        format!("{:.2}", v.gemm_flops / 1e12),
        format!("{:.2}", r.peak_flops() / 1e12),
    ]);
    t.row(&[
        "FFT FP16 TFLOPS".into(),
        format!("{:.2}", g.cuda_flops / 1e12),
        format!("{:.2}", v.fft_flops / 1e12),
        format!("{:.2}", r.peak_flops() / 1e12),
    ]);
    t
}

/// Compute the Fig. 8 dataset over `seq_lens`.
///
/// The VGA is "scaled to match the compute throughput of the RDU"
/// (paper §III-C); we scale it to the RDU's *effective* per-class rates so
/// the paper's "VGA and RDU achieve similar performance" observation is
/// reproduced (the Table II nameplate rates are reported by [`table2`]).
pub fn fig8_at(seq_lens: &[usize]) -> Fig8 {
    let gpu_spec = GpuSpec::a100();
    let fftm = RduConfig::fft_mode();
    // Effective RDU rates, measured from the pcusim-backed throughput table.
    let probe_fft = crate::graph::Kernel::new(
        "probe",
        crate::graph::OpClass::VectorFft,
        1.0,
        1.0,
        1.0,
    );
    let eff_fft = match dfmodel::kernel_rate(&probe_fft, &fftm) {
        dfmodel::Rate::FlopsPerPcu(r) => r * fftm.spec.n_pcu as f64,
        _ => unreachable!(),
    };
    let vga_spec = vga::scaled_to_rdu_effective(eff_fft, fftm.spec.peak_flops());

    let mut rows = Vec::new();
    let mut last = [[0f64; 3]; 2];
    for &l in seq_lens {
        let dc = DecoderConfig::paper(l);
        for (vi, variant, vname) in [
            (0usize, BaileyVariant::Gemm, "gemm-fft hyena"),
            (1, BaileyVariant::Vector, "vector-fft hyena"),
        ] {
            let g = hyena_decoder(&dc, variant);
            let gpu_t = gpu::estimate(&g, &gpu_spec).total_seconds;
            let vga_t = vga::estimate(&g, &vga_spec).expect("vga runs hyena").total_seconds;
            let rdu_t = dfmodel::estimate(&g, &fftm).expect("mappable").total_seconds;
            last[vi] = [gpu_t, vga_t, rdu_t];
            rows.push(PlatformRow { variant: vname, seq_len: l, gpu: gpu_t, vga: vga_t, rdu: rdu_t });
        }
    }

    let speedups = vec![
        SpeedupRow::new("gemm-fft: RDU over GPU", 2.0, last[0][0] / last[0][2]),
        SpeedupRow::new("gemm-fft: VGA over GPU", 2.0, last[0][0] / last[0][1]),
        SpeedupRow::new("vector-fft: RDU over GPU", 5.95, last[1][0] / last[1][2]),
        SpeedupRow::new("vector-fft: VGA over GPU", 5.95, last[1][0] / last[1][1]),
        SpeedupRow::new("vector-fft: VGA over RDU (≡1.0)", 1.0, last[1][2] / last[1][1]),
    ];
    Fig8 { rows, speedups }
}

/// The paper's exact sweep.
pub fn fig8() -> Fig8 {
    fig8_at(&PAPER_SEQ_LENS)
}

impl Fig8 {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 8 — Hyena latency across platforms",
            &["Variant", "L", "GPU", "VGA (scaled)", "FFT-mode RDU"],
        );
        for r in &self.rows {
            t.row(&[
                r.variant.to_string(),
                seq_label(r.seq_len),
                fmt_time(r.gpu),
                fmt_time(r.vga),
                fmt_time(r.rdu),
            ]);
        }
        t
    }

    pub fn speedup_report(&self) -> Table {
        speedup_table("Fig. 8 — platform speedups, paper vs measured", &self.speedups)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_always_slowest() {
        let f = fig8_at(&[1 << 16]);
        for r in &f.rows {
            assert!(r.gpu > r.rdu, "{}: gpu={} rdu={}", r.variant, r.gpu, r.rdu);
            assert!(r.gpu > r.vga, "{}: gpu={} vga={}", r.variant, r.gpu, r.vga);
        }
    }

    #[test]
    fn vector_fft_gap_larger_than_gemm_fft_gap() {
        // The paper's core claim: the GPU is *much* worse at Vector-FFT
        // (CUDA cores) than at GEMM-FFT (tensor cores).
        let f = fig8_at(&[1 << 16]);
        let gemm = f.speedups[0].measured;
        let vec = f.speedups[2].measured;
        assert!(vec > gemm, "vec={vec} gemm={gemm}");
    }

    #[test]
    fn vga_tracks_rdu() {
        let f = fig8_at(&[1 << 16]);
        let parity = f.speedups[4].measured;
        assert!((parity - 1.0).abs() < 0.35, "parity={parity}");
    }

    #[test]
    fn table2_matches_paper_numbers() {
        let s = table2().render();
        assert!(s.contains("311.87"));
        assert!(s.contains("77.97"));
        assert!(s.contains("655.36"));
        assert!(s.contains("638.98"));
    }
}
