//! Fusion overhead table: fused vs kernel-by-kernel (unfused) modeled
//! latency for every registered SSM decoder on its extended RDU config,
//! with the launch counts and DRAM-staged intermediate traffic behind the
//! gap. This is the table `simulate --fuse` and `sweep --fuse` print and
//! the `fusion` bench serializes into `BENCH_fusion.json` (the bench gate
//! requires fused < unfused for **every** registered SSM workload, so a
//! newly registered variant is covered automatically).

use crate::dfmodel::{estimate_fused, estimate_unfused, fuse_graph, FusionPlan};
use crate::util::table::Table;
use crate::util::{eng, fmt_time};
use crate::workloads::{ssm_workloads, DecoderConfig, Workload};

/// Fused-vs-unfused comparison for one decoder at one sequence length.
#[derive(Debug, Clone, PartialEq)]
pub struct FusionPoint {
    pub model: &'static str,
    pub seq_len: usize,
    /// Kernel count of the decoder graph (= unfused launches).
    pub kernels: usize,
    /// Spatial-program launches under the fusion plan.
    pub launches: usize,
    /// Kernel-by-kernel modeled latency.
    pub unfused_seconds: f64,
    /// Fusion-plan modeled latency.
    pub fused_seconds: f64,
    /// Intermediate bytes staged through DRAM without fusion.
    pub staged_unfused: f64,
    /// Intermediate bytes still staged with fusion (cut edges only).
    pub staged_fused: f64,
}

impl FusionPoint {
    /// unfused / fused latency ratio.
    pub fn gain(&self) -> f64 {
        self.unfused_seconds / self.fused_seconds
    }
}

/// Compute the fusion comparison for every registered SSM decoder over
/// `seq_lens`, each on its own extended configuration.
pub fn fusion_at(seq_lens: &[usize]) -> Vec<FusionPoint> {
    fusion_at_workloads(seq_lens, &ssm_workloads())
}

/// [`fusion_at`] restricted to `workloads` — the `--workload`-filtered CLI
/// paths call this so unselected decoders are never mapped or priced.
pub fn fusion_at_workloads(
    seq_lens: &[usize],
    workloads: &[&'static dyn Workload],
) -> Vec<FusionPoint> {
    let mut points = Vec::new();
    for &l in seq_lens {
        let dc = DecoderConfig::paper(l);
        for w in workloads {
            let (model, g, cfg) = (w.name(), w.build_graph(&dc), w.extended_config());
            let plan = fuse_graph(&g, &cfg);
            let fused = estimate_fused(&g, &cfg).expect("mappable");
            let unfused = estimate_unfused(&g, &cfg).expect("mappable");
            points.push(FusionPoint {
                model,
                seq_len: l,
                kernels: g.kernels.len(),
                launches: plan.launches(),
                unfused_seconds: unfused.total_seconds,
                fused_seconds: fused.total_seconds,
                staged_unfused: FusionPlan::unfused(&g).staged_intermediate_bytes(&g),
                staged_fused: plan.staged_intermediate_bytes(&g),
            });
        }
    }
    points
}

/// Render the fusion comparison table.
pub fn fusion_table(points: &[FusionPoint]) -> Table {
    let mut t = Table::new(
        "Fused vs unfused dataflow mappings (launch-granularity DFModel)",
        &["Model", "L", "Launches", "Staged DRAM B", "Unfused", "Fused", "Speedup"],
    );
    for p in points {
        t.row(&[
            p.model.to_string(),
            super::seq_label(p.seq_len),
            format!("{} -> {}", p.kernels, p.launches),
            format!("{} -> {}", eng(p.staged_unfused), eng(p.staged_fused)),
            fmt_time(p.unfused_seconds),
            fmt_time(p.fused_seconds),
            format!("{:.2}x", p.gain()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fusion_wins_at_all_swept_lengths() {
        for p in fusion_at(&[1 << 12, 1 << 16]) {
            assert!(p.gain() > 1.0, "{p:?}");
            assert!(p.launches < p.kernels, "{p:?}");
            assert!(p.staged_fused < p.staged_unfused, "{p:?}");
        }
    }

    #[test]
    fn table_renders_every_registered_ssm() {
        let pts = fusion_at(&[1 << 12]);
        let s = fusion_table(&pts).render();
        for name in ["hyena", "mamba", "ssd", "s4"] {
            assert!(s.contains(name), "missing {name} in:\n{s}");
        }
        assert!(s.contains("x"), "{s}");
    }
}
