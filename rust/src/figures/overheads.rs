//! Table IV: area and power overheads of the enhanced PCUs — delegated to
//! the synthesis model, re-exported here so every table/figure lives under
//! `figures::`.

use crate::synth;
use crate::util::table::Table;

/// Render Table IV (model vs paper columns).
pub fn table4() -> Table {
    synth::table4_report()
}

/// The raw rows, for assertions.
pub fn table4_rows() -> Vec<synth::PcuSynthesis> {
    synth::table4_rows()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_four_rows() {
        let s = table4().render();
        for name in ["Baseline", "FFT-Mode", "HS-Scan", "B-Scan"] {
            assert!(s.contains(name), "{s}");
        }
    }
}
