//! Figures 11 and 12: the Mamba-side evaluation.
//!
//! Fig. 11 (paper §IV-C): five designs — (1) attention/baseline, (2) C-scan
//! Mamba/baseline, (3) parallel-scan Mamba/baseline, (4) parallel-scan on
//! HS-scan-mode RDU, (5) parallel-scan on B-scan-mode RDU. Paper speedups:
//! D1→D2 7.34×, D2→D3 562.98×, D3→D4,5 1.75×, D4 ≡ D5.
//!
//! Fig. 12: parallel-scan Mamba on GPU vs scan-mode RDU — paper 2.12×.

use super::{seq_label, speedup_table, SpeedupRow, PAPER_SEQ_LENS};
use crate::arch::{GpuSpec, RduConfig};
use crate::dfmodel;
use crate::gpu;
use crate::util::table::Table;
use crate::util::{eng, fmt_time};
use crate::workloads::{attention_decoder, mamba_decoder, DecoderConfig, ScanVariant};

/// One design point at one sequence length.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignPoint {
    pub design: &'static str,
    pub seq_len: usize,
    pub flops: f64,
    pub latency: f64,
    /// Latency attributed to the scan/attention core.
    pub core_latency: f64,
}

/// The Fig. 11 dataset.
#[derive(Debug, Clone, PartialEq)]
pub struct Fig11 {
    pub points: Vec<DesignPoint>,
    pub speedups: Vec<SpeedupRow>,
}

/// Paper Fig. 11 design labels.
pub const DESIGNS: [&str; 5] = [
    "(1) attention / baseline RDU",
    "(2) c-scan mamba / baseline RDU",
    "(3) parallel-scan mamba / baseline RDU",
    "(4) parallel-scan mamba / hs-scan-mode RDU",
    "(5) parallel-scan mamba / b-scan-mode RDU",
];

fn core_pred(k: &dfmodel::KernelEstimate) -> bool {
    k.name.contains("scan") || k.name.starts_with("attn.")
}

/// Compute the Fig. 11 dataset over `seq_lens`.
pub fn fig11_at(seq_lens: &[usize]) -> Fig11 {
    let base = RduConfig::baseline();
    let hs = RduConfig::hs_scan_mode();
    let b = RduConfig::b_scan_mode();
    let mut points = Vec::new();
    let mut last = [0f64; 5];

    for &l in seq_lens {
        let dc = DecoderConfig::paper(l);
        let cases = [
            (attention_decoder(&dc), &base),
            (mamba_decoder(&dc, ScanVariant::CScan), &base),
            (mamba_decoder(&dc, ScanVariant::Parallel), &base),
            (mamba_decoder(&dc, ScanVariant::Parallel), &hs),
            (mamba_decoder(&dc, ScanVariant::Parallel), &b),
        ];
        for (i, (g, cfg)) in cases.iter().enumerate() {
            let est = dfmodel::estimate(g, cfg).expect("mappable");
            last[i] = est.total_seconds;
            points.push(DesignPoint {
                design: DESIGNS[i],
                seq_len: l,
                flops: g.total_flops(),
                latency: est.total_seconds,
                core_latency: est.share_where(core_pred),
            });
        }
    }

    let speedups = vec![
        SpeedupRow::new("design 2 over design 1", 7.34, last[0] / last[1]),
        SpeedupRow::new("design 3 over design 2", 562.98, last[1] / last[2]),
        SpeedupRow::new("design 4 over design 3", 1.75, last[2] / last[3]),
        SpeedupRow::new("design 5 over design 4 (≡1.0)", 1.0, last[3] / last[4]),
    ];
    Fig11 { points, speedups }
}

/// The paper's exact sweep.
pub fn fig11() -> Fig11 {
    fig11_at(&PAPER_SEQ_LENS)
}

impl Fig11 {
    pub fn latency(&self, d: usize, seq_len: usize) -> f64 {
        self.points
            .iter()
            .find(|p| p.design == DESIGNS[d] && p.seq_len == seq_len)
            .map(|p| p.latency)
            .expect("design point present")
    }

    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 11 — Mamba designs: FLOP count and latency (DFModel)",
            &["Design", "L", "FLOPs", "Latency", "core", "rest"],
        );
        for p in &self.points {
            t.row(&[
                p.design.to_string(),
                seq_label(p.seq_len),
                eng(p.flops),
                fmt_time(p.latency),
                fmt_time(p.core_latency),
                fmt_time(p.latency - p.core_latency),
            ]);
        }
        t
    }

    pub fn speedup_report(&self) -> Table {
        speedup_table("Fig. 11 — design speedups, paper vs measured", &self.speedups)
    }
}

/// The Fig. 12 dataset: GPU vs scan-mode RDU on parallel-scan Mamba.
#[derive(Debug, Clone, PartialEq)]
pub struct Fig12 {
    pub seq_len: usize,
    pub gpu_latency: f64,
    pub gpu_compute_latency: f64,
    pub rdu_latency: f64,
    pub speedups: Vec<SpeedupRow>,
}

/// Compute Fig. 12 at one sequence length.
pub fn fig12_at(seq_len: usize) -> Fig12 {
    let dc = DecoderConfig::paper(seq_len);
    let g = mamba_decoder(&dc, ScanVariant::Parallel);
    let gpu_est = gpu::estimate(&g, &GpuSpec::a100());
    let rdu_est = dfmodel::estimate(&g, &RduConfig::hs_scan_mode()).expect("mappable");
    let speedups = vec![
        SpeedupRow::new(
            "scan-mode RDU over GPU (full kernel-by-kernel model)",
            2.12,
            gpu_est.total_seconds / rdu_est.total_seconds,
        ),
        // The paper's DFModel GPU appears compute-dominated at these shapes;
        // the compute-only ratio is the closer analogue (see EXPERIMENTS.md).
        SpeedupRow::new(
            "scan-mode RDU over GPU (compute-only)",
            2.12,
            gpu_est.compute_seconds / rdu_est.total_seconds,
        ),
    ];
    Fig12 {
        seq_len,
        gpu_latency: gpu_est.total_seconds,
        gpu_compute_latency: gpu_est.compute_seconds,
        rdu_latency: rdu_est.total_seconds,
        speedups,
    }
}

/// The paper's largest swept length.
pub fn fig12() -> Fig12 {
    fig12_at(*PAPER_SEQ_LENS.last().unwrap())
}

impl Fig12 {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 12 — parallel-scan Mamba: GPU vs scan-mode RDU",
            &["Platform", "L", "Latency"],
        );
        t.row(&[
            "NVIDIA A100 (kernel-by-kernel)".into(),
            seq_label(self.seq_len),
            fmt_time(self.gpu_latency),
        ]);
        t.row(&[
            "NVIDIA A100 (compute only)".into(),
            seq_label(self.seq_len),
            fmt_time(self.gpu_compute_latency),
        ]);
        t.row(&["scan-mode RDU (dataflow)".into(), seq_label(self.seq_len), fmt_time(self.rdu_latency)]);
        t
    }

    pub fn speedup_report(&self) -> Table {
        speedup_table("Fig. 12 — RDU-over-GPU speedup, paper vs measured", &self.speedups)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_ordering_and_hs_b_parity() {
        // Needs a paper-regime length: below L ≈ 1e5 the quadratic
        // attention is still cheaper than the serial C-scan (the crossover
        // the paper's long-sequence motivation is about).
        let f = fig11_at(&[1 << 18]);
        let d: Vec<f64> = (0..5).map(|i| f.latency(i, 1 << 18)).collect();
        assert!(d[0] > d[1] && d[1] > d[2] && d[2] > d[3], "{d:?}");
        assert!((d[3] - d[4]).abs() / d[3] < 0.01, "HS ≡ B: {d:?}");
    }

    #[test]
    fn fig12_rdu_beats_gpu() {
        let f = fig12_at(1 << 16);
        assert!(f.rdu_latency < f.gpu_latency);
        assert!(f.speedups[0].measured > 1.0);
    }

    #[test]
    fn tables_render() {
        let f = fig11_at(&[1 << 16]);
        assert!(f.table().render().contains("c-scan"));
        let g = fig12_at(1 << 16);
        assert!(g.table().render().contains("A100"));
    }
}
