//! Figure 7: FLOP count and latency of the four Hyena-side designs across
//! the paper's sequence-length sweep.
//!
//! Designs (paper §III-C): (1) attention on baseline RDU, (2) Vector-FFT
//! Hyena on baseline, (3) GEMM-FFT Hyena on baseline, (4) Vector-FFT Hyena
//! on the FFT-mode RDU. Paper speedups: D1→D2 217.74×, D2→D3 2.61×,
//! D3→D4 1.95×.
//!
//! **FLOP convention.** This figure charges the paper's full-complex
//! transform counts (`fft::conv::fftconv_flops` / `fft::vector_fft_flops`
//! through the workload graphs) so the design ratios above reproduce
//! exactly. The functional engine's planned real-input path does ~half
//! that work (`fft::fftconv_flops_rfft`) — an *implementation* win the
//! paper's design-space comparison deliberately does not assume; do not
//! "fix" these figures to the rfft counts.

use super::{seq_label, speedup_table, SpeedupRow, PAPER_SEQ_LENS};
use crate::arch::RduConfig;
use crate::dfmodel;
use crate::fft::BaileyVariant;
use crate::util::table::Table;
use crate::util::{eng, fmt_time};
use crate::workloads::{attention_decoder, hyena_decoder, DecoderConfig};

/// One design point at one sequence length.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignPoint {
    pub design: &'static str,
    pub seq_len: usize,
    pub flops: f64,
    pub latency: f64,
    /// Latency attributed to the FFT/attention core vs the rest.
    pub core_latency: f64,
}

/// The full Fig. 7 dataset.
#[derive(Debug, Clone, PartialEq)]
pub struct Fig7 {
    pub points: Vec<DesignPoint>,
    pub speedups: Vec<SpeedupRow>,
}

/// Paper Fig. 7 design labels.
pub const DESIGNS: [&str; 4] = [
    "(1) attention / baseline RDU",
    "(2) vector-fft hyena / baseline RDU",
    "(3) gemm-fft hyena / baseline RDU",
    "(4) vector-fft hyena / fft-mode RDU",
];

fn core_pred(k: &dfmodel::KernelEstimate) -> bool {
    k.name.contains("fft") || k.name.starts_with("attn.")
}

/// Compute the Fig. 7 dataset over `seq_lens`.
pub fn fig7_at(seq_lens: &[usize]) -> Fig7 {
    let base = RduConfig::baseline();
    let fftm = RduConfig::fft_mode();
    let mut points = Vec::new();
    let mut per_len_latencies: Vec<[f64; 4]> = Vec::new();

    for &l in seq_lens {
        let dc = DecoderConfig::paper(l);
        let graphs_cfgs = [
            (attention_decoder(&dc), &base),
            (hyena_decoder(&dc, BaileyVariant::Vector), &base),
            (hyena_decoder(&dc, BaileyVariant::Gemm), &base),
            (hyena_decoder(&dc, BaileyVariant::Vector), &fftm),
        ];
        let mut lat = [0f64; 4];
        for (i, (g, cfg)) in graphs_cfgs.iter().enumerate() {
            let est = dfmodel::estimate(g, cfg).expect("mappable");
            lat[i] = est.total_seconds;
            points.push(DesignPoint {
                design: DESIGNS[i],
                seq_len: l,
                flops: g.total_flops(),
                latency: est.total_seconds,
                core_latency: est.share_where(core_pred),
            });
        }
        per_len_latencies.push(lat);
    }

    // Speedups at the largest swept length (the paper reports them as
    // constant across lengths; integration tests check the stability).
    let lat = per_len_latencies.last().expect("non-empty sweep");
    let speedups = vec![
        SpeedupRow::new("design 2 over design 1", 217.74, lat[0] / lat[1]),
        SpeedupRow::new("design 3 over design 2", 2.61, lat[1] / lat[2]),
        SpeedupRow::new("design 4 over design 3", 1.95, lat[2] / lat[3]),
    ];
    Fig7 { points, speedups }
}

/// The paper's exact sweep.
pub fn fig7() -> Fig7 {
    fig7_at(&PAPER_SEQ_LENS)
}

impl Fig7 {
    /// Latency of design `d` (0-based) at `seq_len`.
    pub fn latency(&self, d: usize, seq_len: usize) -> f64 {
        self.points
            .iter()
            .find(|p| p.design == DESIGNS[d] && p.seq_len == seq_len)
            .map(|p| p.latency)
            .expect("design point present")
    }

    /// Render the per-design table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 7 — Hyena designs: FLOP count and latency (DFModel)",
            &["Design", "L", "FLOPs", "Latency", "core", "rest"],
        );
        for p in &self.points {
            t.row(&[
                p.design.to_string(),
                seq_label(p.seq_len),
                eng(p.flops),
                fmt_time(p.latency),
                fmt_time(p.core_latency),
                fmt_time(p.latency - p.core_latency),
            ]);
        }
        t
    }

    /// Render the paper-vs-measured speedups.
    pub fn speedup_report(&self) -> Table {
        speedup_table("Fig. 7 — design speedups, paper vs measured", &self.speedups)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_small_sweep_ordering() {
        // Use smaller lengths to keep the test fast; ordering must hold.
        let f = fig7_at(&[1 << 16, 1 << 17]);
        for &l in &[1 << 16, 1 << 17] {
            let d: Vec<f64> = (0..4).map(|i| f.latency(i, l)).collect();
            assert!(d[0] > d[1] && d[1] > d[2] && d[2] > d[3], "L={l}: {d:?}");
        }
    }

    #[test]
    fn speedups_all_positive() {
        let f = fig7_at(&[1 << 16]);
        for s in &f.speedups {
            assert!(s.measured > 1.0, "{}: {}", s.label, s.measured);
        }
    }

    #[test]
    fn tables_render() {
        let f = fig7_at(&[1 << 16]);
        assert!(f.table().render().contains("vector-fft"));
        assert!(f.speedup_report().render().contains("217.74x"));
    }
}
