//! Per-sequence SSM decode state: the recurrent tensors a live session keeps
//! between decode steps.
//!
//! The whole premise of SSM serving (paper §II-B) is that decode is a
//! recurrence over *cached state* rather than attention over the full
//! context, so the state footprint is O(1) in sequence length:
//!
//! * **Mamba** — the selective-scan hidden state, one
//!   `d_state × d_model` f32 block per layer (`h_t = Ā h_{t-1} + B̄ x_t`).
//! * **Hyena** — the FFT-domain long-convolution caches, per layer one
//!   complex `filter_fft` (the implicit filter, transformed once) and one
//!   complex `prefix_fft` (the running transform of the already-decoded
//!   prefix), both of `fft_points` complex values.
//!
//! Byte accounting is exact — [`SsmState::bytes`] is what the
//! [`crate::session::StateCache`] charges against its memory budget.

use crate::runtime::ModelKind;
use crate::Result;
use anyhow::anyhow;

/// Shape of one session's decode state (all layers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StateShape {
    pub model: ModelKind,
    /// Decoder layers holding state.
    pub layers: usize,
    /// Mamba SSM state dimension N (0 for Hyena).
    pub d_state: usize,
    /// Hidden dimension D (also the per-token activation width).
    pub d_model: usize,
    /// Hyena: complex FFT points kept resident per layer per cache
    /// (0 for Mamba).
    pub fft_points: usize,
}

impl StateShape {
    /// Mamba recurrent state: `layers × d_state × d_model` f32.
    pub fn mamba(layers: usize, d_state: usize, d_model: usize) -> Self {
        Self { model: ModelKind::Mamba, layers, d_state, d_model, fft_points: 0 }
    }

    /// Hyena FFT caches: per layer, filter + prefix, `fft_points` complex
    /// (2×f32) values each.
    pub fn hyena(layers: usize, d_model: usize, fft_points: usize) -> Self {
        Self { model: ModelKind::Hyena, layers, d_state: 0, d_model, fft_points }
    }

    /// Exact resident footprint of a state with this shape, in bytes.
    pub fn bytes(&self) -> usize {
        match self.model {
            ModelKind::Mamba => self.layers * self.d_state * self.d_model * 4,
            // filter_fft + prefix_fft, complex (re, im) f32 values.
            ModelKind::Hyena => self.layers * self.fft_points * 2 * 2 * 4,
            ModelKind::Attention => 0,
        }
    }
}

/// One session's decode state. Variants own their buffers; `bytes()` is
/// derived from the actual allocation so cache accounting can never drift.
#[derive(Debug, Clone, PartialEq)]
pub enum SsmState {
    Mamba {
        shape: StateShape,
        /// `layers × d_state × d_model`, layer-major.
        h: Vec<f32>,
    },
    Hyena {
        shape: StateShape,
        /// `layers × fft_points` complex values, interleaved (re, im).
        filter_fft: Vec<f32>,
        /// `layers × fft_points` complex values, interleaved (re, im).
        prefix_fft: Vec<f32>,
    },
}

impl SsmState {
    /// Allocate a zeroed state of the given shape.
    ///
    /// Attention has no O(1) recurrent state (its KV cache grows with the
    /// context), so it is rejected here — the session subsystem serves the
    /// SSM decoders.
    pub fn zeros(shape: &StateShape) -> Result<Self> {
        match shape.model {
            ModelKind::Mamba => Ok(SsmState::Mamba {
                shape: *shape,
                h: vec![0.0; shape.layers * shape.d_state * shape.d_model],
            }),
            ModelKind::Hyena => Ok(SsmState::Hyena {
                shape: *shape,
                filter_fft: vec![0.0; shape.layers * shape.fft_points * 2],
                prefix_fft: vec![0.0; shape.layers * shape.fft_points * 2],
            }),
            ModelKind::Attention => {
                Err(anyhow!("attention decode uses a growing KV cache, not O(1) SSM state"))
            }
        }
    }

    pub fn shape(&self) -> &StateShape {
        match self {
            SsmState::Mamba { shape, .. } | SsmState::Hyena { shape, .. } => shape,
        }
    }

    /// Total f32 elements across all buffers.
    pub fn elems(&self) -> usize {
        match self {
            SsmState::Mamba { h, .. } => h.len(),
            SsmState::Hyena { filter_fft, prefix_fft, .. } => {
                filter_fft.len() + prefix_fft.len()
            }
        }
    }

    /// Exact resident footprint in bytes (what the cache budget charges).
    pub fn bytes(&self) -> usize {
        self.elems() * std::mem::size_of::<f32>()
    }

    /// Mean over every element (0.0 for an empty state).
    pub fn mean(&self) -> f32 {
        let n = self.elems();
        if n == 0 {
            return 0.0;
        }
        let sum: f32 = match self {
            SsmState::Mamba { h, .. } => h.iter().sum(),
            SsmState::Hyena { filter_fft, prefix_fft, .. } => {
                filter_fft.iter().sum::<f32>() + prefix_fft.iter().sum::<f32>()
            }
        };
        sum / n as f32
    }

    /// Set every element to `v`.
    pub fn fill(&mut self, v: f32) {
        match self {
            SsmState::Mamba { h, .. } => h.iter_mut().for_each(|x| *x = v),
            SsmState::Hyena { filter_fft, prefix_fft, .. } => {
                filter_fft.iter_mut().for_each(|x| *x = v);
                prefix_fft.iter_mut().for_each(|x| *x = v);
            }
        }
    }

    /// Add `v` to every element (the mock decode's state-evolution rule).
    pub fn add_scalar(&mut self, v: f32) {
        match self {
            SsmState::Mamba { h, .. } => h.iter_mut().for_each(|x| *x += v),
            SsmState::Hyena { filter_fft, prefix_fft, .. } => {
                filter_fft.iter_mut().for_each(|x| *x += v);
                prefix_fft.iter_mut().for_each(|x| *x += v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mamba_bytes_are_exact() {
        let shape = StateShape::mamba(8, 16, 64);
        assert_eq!(shape.bytes(), 8 * 16 * 64 * 4);
        let s = SsmState::zeros(&shape).unwrap();
        assert_eq!(s.bytes(), shape.bytes());
        assert_eq!(s.elems(), 8 * 16 * 64);
    }

    #[test]
    fn hyena_bytes_count_both_caches_complex() {
        let shape = StateShape::hyena(4, 32, 256);
        // 4 layers × 256 complex points × 2 caches × (2 × 4 bytes).
        assert_eq!(shape.bytes(), 4 * 256 * 2 * 2 * 4);
        let s = SsmState::zeros(&shape).unwrap();
        assert_eq!(s.bytes(), shape.bytes());
    }

    #[test]
    fn attention_has_no_ssm_state() {
        let shape = StateShape {
            model: ModelKind::Attention,
            layers: 1,
            d_state: 0,
            d_model: 32,
            fft_points: 0,
        };
        assert!(SsmState::zeros(&shape).is_err());
    }

    #[test]
    fn fill_add_mean_roundtrip() {
        let mut s = SsmState::zeros(&StateShape::mamba(2, 4, 8)).unwrap();
        assert_eq!(s.mean(), 0.0);
        s.fill(2.0);
        assert_eq!(s.mean(), 2.0);
        s.add_scalar(0.5);
        assert!((s.mean() - 2.5).abs() < 1e-6);
    }
}
