//! Per-session state cache: LRU-resident SSM decode state under a hard byte
//! budget, with spill/restore accounting.
//!
//! XAMBA (arXiv 2502.06924) shows SSM serving on constrained hardware is
//! dominated by state-management efficiency; Fine-Grained Fusion (arXiv
//! 2504.17333) argues on-chip state residency is the area/latency lever.
//! This cache makes that trade explicit: states the budget can hold stay
//! *resident* (modeled on-chip); the LRU victim is *spilled* (modeled
//! off-chip, charged at [`crate::arch::MemTech`] bandwidth) and restored on
//! the session's next decode step.
//!
//! Invariant: resident bytes ≤ budget at all times. Spilled state is kept
//! bit-exact, so eviction is transparent to decode numerics — only the
//! modeled transfer time and the hit/evict counters change.

use super::budget::{spill_seconds, MemoryBudget};
use super::state::SsmState;
use super::SessionId;
use crate::arch::MemTech;
use std::collections::BTreeMap;

/// Cumulative cache counters (exposed through `Coordinator::cache_stats`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStats {
    /// Checkouts served from resident state.
    pub hits: u64,
    /// Checkouts that had to restore spilled state.
    pub misses: u64,
    /// Residents pushed out to the spill store (LRU victims + states that
    /// never fit).
    pub evictions: u64,
    /// Spilled states brought back for a decode step.
    pub restores: u64,
    /// Cumulative bytes moved out to the spill store.
    pub spilled_bytes: u64,
    /// Cumulative bytes restored from the spill store.
    pub restored_bytes: u64,
    /// High-water mark of resident bytes.
    pub peak_resident_bytes: u64,
    /// Modeled off-chip transfer time of all spills + restores.
    pub spill_seconds: f64,
}

impl CacheStats {
    /// Hit rate over all checkouts (1.0 when nothing ever spilled).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 1.0;
        }
        self.hits as f64 / total as f64
    }

    /// Fold another cache's counters into this one — fleet aggregation
    /// across per-chip caches. `peak_resident_bytes` sums because the chips
    /// hold disjoint states in separate SRAMs, so the fleet peak is the sum
    /// of per-chip peaks (an upper bound: the chips may peak at different
    /// times).
    pub fn merge(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.restores += other.restores;
        self.spilled_bytes += other.spilled_bytes;
        self.restored_bytes += other.restored_bytes;
        self.peak_resident_bytes += other.peak_resident_bytes;
        self.spill_seconds += other.spill_seconds;
    }

    /// Merge a slice of per-chip counters into one aggregate — the
    /// per-*node* rollup the fleet report prints. Fleet mode keeps one
    /// aggregate per node (chips of the same node share a spill DRAM and a
    /// scheduler, so their counters belong together) instead of flattening
    /// every chip in the fleet into a single table and losing attribution.
    pub fn merge_all(stats: &[CacheStats]) -> CacheStats {
        let mut total = CacheStats::default();
        for s in stats {
            total.merge(s);
        }
        total
    }
}

#[derive(Debug)]
struct Resident {
    state: SsmState,
    bytes: usize,
    /// Monotonic LRU stamp; the minimum stamp is the eviction victim.
    stamp: u64,
}

/// The session-keyed state cache.
pub struct StateCache {
    budget: MemoryBudget,
    dram: MemTech,
    resident: BTreeMap<SessionId, Resident>,
    spilled: BTreeMap<SessionId, SsmState>,
    tick: u64,
    /// Trace track spill/restore instants land on (a per-chip track for the
    /// coordinator's sharded caches; `None` → the calling thread's track).
    track: Option<u64>,
    pub stats: CacheStats,
}

impl StateCache {
    pub fn new(budget: MemoryBudget, dram: MemTech) -> Self {
        Self {
            budget,
            dram,
            resident: BTreeMap::new(),
            spilled: BTreeMap::new(),
            tick: 0,
            track: None,
            stats: CacheStats::default(),
        }
    }

    /// Route this cache's trace instants to an explicit track — the
    /// coordinator points chip `c`'s cache at
    /// [`crate::telemetry::chip_track`]`(c)` so spill/restore traffic is
    /// attributable per chip in the timeline.
    pub fn set_track(&mut self, track: u64) {
        self.track = Some(track);
    }

    /// Convenience: a byte budget with the paper's HBM3e spill path.
    pub fn with_budget_bytes(bytes: usize) -> Self {
        Self::new(MemoryBudget::new(bytes), MemTech::Hbm3e)
    }

    /// Bytes of state currently resident (always ≤ `budget_bytes`).
    pub fn resident_bytes(&self) -> usize {
        self.budget.used()
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget.capacity()
    }

    pub fn resident_len(&self) -> usize {
        self.resident.len()
    }

    pub fn spilled_len(&self) -> usize {
        self.spilled.len()
    }

    /// Is this session's state anywhere in the cache (resident or spilled)?
    pub fn contains(&self, id: SessionId) -> bool {
        self.resident.contains_key(&id) || self.spilled.contains_key(&id)
    }

    /// Insert (or replace) a session's state, evicting LRU residents as
    /// needed. A state larger than the entire budget goes straight to the
    /// spill store — it can never be resident.
    pub fn insert(&mut self, id: SessionId, state: SsmState) {
        // Replacing an existing entry must release its accounting first.
        self.remove(id);
        let bytes = state.bytes();
        if bytes > self.budget.capacity() {
            // Can never be resident: spill directly instead of pointlessly
            // evicting every resident state first.
            self.spill_out(id, state);
            return;
        }
        self.make_room(bytes);
        if self.budget.try_reserve(bytes) {
            self.tick += 1;
            self.resident.insert(id, Resident { state, bytes, stamp: self.tick });
            let used = self.budget.used() as u64;
            if used > self.stats.peak_resident_bytes {
                self.stats.peak_resident_bytes = used;
            }
        } else {
            self.spill_out(id, state);
        }
    }

    /// Take a session's state out for a decode step. Resident → hit;
    /// spilled → miss + restore (charged at off-chip bandwidth); unknown →
    /// `None`. While checked out, the state's bytes are not held against
    /// the budget — `checkin` re-reserves (evicting others if needed).
    pub fn checkout(&mut self, id: SessionId) -> Option<SsmState> {
        if let Some(r) = self.resident.remove(&id) {
            self.budget.release(r.bytes);
            self.stats.hits += 1;
            return Some(r.state);
        }
        if let Some(s) = self.spilled.remove(&id) {
            let bytes = s.bytes();
            self.stats.misses += 1;
            self.stats.restores += 1;
            self.stats.restored_bytes += bytes as u64;
            self.stats.spill_seconds += spill_seconds(bytes, self.dram);
            self.mark("cache.restore", bytes);
            return Some(s);
        }
        None
    }

    /// Return a checked-out state after its decode step.
    pub fn checkin(&mut self, id: SessionId, state: SsmState) {
        self.insert(id, state);
    }

    /// Retire a session, dropping its state entirely (not an eviction).
    pub fn remove(&mut self, id: SessionId) -> Option<SsmState> {
        if let Some(r) = self.resident.remove(&id) {
            self.budget.release(r.bytes);
            return Some(r.state);
        }
        self.spilled.remove(&id)
    }

    /// Evict LRU residents until `need` bytes fit (or nothing is left).
    fn make_room(&mut self, need: usize) {
        while !self.budget.fits(need) {
            let victim = self.resident.iter().min_by_key(|(_, r)| r.stamp).map(|(&id, _)| id);
            let Some(id) = victim else { break };
            let r = self.resident.remove(&id).expect("victim was just found resident");
            self.budget.release(r.bytes);
            self.spill_out(id, r.state);
        }
    }

    fn spill_out(&mut self, id: SessionId, state: SsmState) {
        let bytes = state.bytes();
        self.stats.evictions += 1;
        self.stats.spilled_bytes += bytes as u64;
        self.stats.spill_seconds += spill_seconds(bytes, self.dram);
        self.mark("cache.spill", bytes);
        self.spilled.insert(id, state);
    }

    /// Emit a spill/restore instant on this cache's track (no-op when
    /// tracing is disabled).
    fn mark(&self, name: &'static str, bytes: usize) {
        if !crate::telemetry::enabled() {
            return;
        }
        match self.track {
            Some(tid) => crate::telemetry::instant_on("session", name, tid, "bytes", bytes as f64),
            None => crate::telemetry::instant_arg("session", name, "bytes", bytes as f64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::state::StateShape;
    use crate::util::XorShift;

    fn state(tag: f32) -> SsmState {
        // 2 × 4 × 8 × 4 B = 256 B per state.
        let mut s = SsmState::zeros(&StateShape::mamba(2, 4, 8)).unwrap();
        s.fill(tag);
        s
    }

    const B: usize = 256;

    #[test]
    fn lru_eviction_order() {
        let mut c = StateCache::with_budget_bytes(2 * B);
        c.insert(1, state(1.0));
        c.insert(2, state(2.0));
        assert_eq!(c.resident_len(), 2);
        // Third insert evicts the least-recently-used (id 1).
        c.insert(3, state(3.0));
        assert_eq!(c.stats.evictions, 1);
        assert_eq!(c.spilled_len(), 1);
        assert!(c.contains(1), "evicted state is spilled, not lost");
        // Touch 2 (checkout/checkin refreshes its stamp), then insert 4:
        // the victim must now be 3, not 2.
        let s2 = c.checkout(2).unwrap();
        c.checkin(2, s2);
        c.insert(4, state(4.0));
        assert_eq!(c.stats.evictions, 2);
        let s2 = c.checkout(2).expect("2 still present");
        assert_eq!(c.stats.hits, 2, "2 stayed resident");
        assert_eq!(s2.mean(), 2.0);
    }

    #[test]
    fn spill_restore_is_bit_exact() {
        let mut c = StateCache::with_budget_bytes(B);
        c.insert(1, state(7.5));
        c.insert(2, state(9.0)); // evicts 1
        assert_eq!(c.stats.evictions, 1);
        let s1 = c.checkout(1).expect("restored from spill");
        assert_eq!(c.stats.misses, 1);
        assert_eq!(c.stats.restores, 1);
        assert_eq!(s1.mean(), 7.5, "spill/restore preserves state exactly");
        assert_eq!(c.stats.restored_bytes, B as u64);
        assert!(c.stats.spill_seconds > 0.0);
    }

    #[test]
    fn oversized_state_never_resident() {
        let mut c = StateCache::with_budget_bytes(B / 2);
        c.insert(1, state(1.0));
        assert_eq!(c.resident_len(), 0);
        assert_eq!(c.spilled_len(), 1);
        assert_eq!(c.stats.evictions, 1);
        assert!(c.checkout(1).is_some());
    }

    #[test]
    fn budget_invariant_under_churn() {
        let mut c = StateCache::with_budget_bytes(3 * B + B / 2);
        let mut rng = XorShift::new(17);
        let mut out: Vec<(SessionId, SsmState)> = Vec::new();
        for step in 0..500u64 {
            let id = (rng.uniform(0.0, 8.0) as SessionId) % 8;
            match step % 4 {
                0 => c.insert(id, state(id as f32)),
                1 => {
                    if let Some(s) = c.checkout(id) {
                        out.push((id, s));
                    }
                }
                2 => {
                    if let Some((id, s)) = out.pop() {
                        c.checkin(id, s);
                    }
                }
                _ => {
                    c.remove(id);
                }
            }
            // The invariant: resident bytes never exceed the budget.
            assert!(
                c.resident_bytes() <= c.budget_bytes(),
                "step {step}: {} > {}",
                c.resident_bytes(),
                c.budget_bytes()
            );
            assert_eq!(c.resident_bytes(), c.resident_len() * B, "exact accounting");
        }
        assert!(c.stats.peak_resident_bytes as usize <= c.budget_bytes());
    }

    #[test]
    fn remove_is_not_an_eviction() {
        let mut c = StateCache::with_budget_bytes(2 * B);
        c.insert(1, state(1.0));
        assert!(c.remove(1).is_some());
        assert_eq!(c.stats.evictions, 0);
        assert_eq!(c.resident_bytes(), 0);
        assert!(!c.contains(1));
        assert!(c.checkout(1).is_none());
    }

    #[test]
    fn merge_folds_all_counters() {
        let mut a =
            CacheStats { hits: 2, misses: 1, peak_resident_bytes: 512, ..Default::default() };
        let b = CacheStats {
            hits: 3,
            misses: 1,
            evictions: 4,
            peak_resident_bytes: 256,
            spill_seconds: 0.5,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.hits, 5);
        assert_eq!(a.misses, 2);
        assert_eq!(a.evictions, 4);
        assert_eq!(a.peak_resident_bytes, 768);
        assert!((a.spill_seconds - 0.5).abs() < 1e-12);
        assert!((a.hit_rate() - 5.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn merge_all_equals_pairwise_merges() {
        let chips = [
            CacheStats { hits: 2, spilled_bytes: 100, ..Default::default() },
            CacheStats { hits: 3, misses: 1, spill_seconds: 0.25, ..Default::default() },
            CacheStats { evictions: 7, peak_resident_bytes: 64, ..Default::default() },
        ];
        let node = CacheStats::merge_all(&chips);
        let mut manual = CacheStats::default();
        for c in &chips {
            manual.merge(c);
        }
        assert_eq!(node, manual);
        assert_eq!(node.hits, 5);
        assert_eq!(node.evictions, 7);
        assert_eq!(CacheStats::merge_all(&[]), CacheStats::default());
    }

    #[test]
    fn hit_rate_reflects_spills() {
        let mut c = StateCache::with_budget_bytes(10 * B);
        c.insert(1, state(1.0));
        let s = c.checkout(1).unwrap();
        c.checkin(1, s);
        assert_eq!(c.stats.hit_rate(), 1.0);
        let empty = StateCache::with_budget_bytes(0);
        assert_eq!(empty.stats.hit_rate(), 1.0, "no traffic yet");
    }
}
