//! Continuous-batching session scheduler (vLLM-style iteration-level
//! batching).
//!
//! Requests are split into two phases: **prefill** (ingest the prompt, build
//! the initial SSM state) and **decode** (one token per step over cached
//! state). Every call to [`SessionScheduler::next_batch`] assembles one
//! *iteration batch* of up to `max_batch` steps:
//!
//! 1. decode steps of live sessions first (inter-token latency is the SLO —
//!    a waiting decode step never queues behind new prompts), then
//! 2. prefills of newly admitted sessions in the remaining slots; one slot
//!    per batch is reserved for admission whenever prefills wait, so a full
//!    decode ring cannot starve new sessions forever.
//!
//! A session whose step completes re-enters the decode ring at the back, so
//! decode bandwidth round-robins fairly across live sessions. Sessions
//! retire when `decode_steps` tokens have been produced, are failed on
//! executor error, and expire after `session_timeout` without progress.
//!
//! The scheduler is deliberately pure — no channels, no state buffers —
//! so its phase machine is unit-testable; the coordinator owns the I/O.

use super::state::StateShape;
use super::SessionId;
use crate::runtime::ModelKind;
use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

/// Which serving phase a scheduled step runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Ingest the prompt and build the initial decode state.
    Prefill,
    /// Produce one token from cached state.
    Decode,
}

/// Scheduler policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Max steps per iteration batch.
    pub max_batch: usize,
    /// A session idle (no step completed) this long is expired.
    pub session_timeout: Duration,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self { max_batch: 16, session_timeout: Duration::from_secs(30) }
    }
}

/// Immutable per-session parameters fixed at admission.
#[derive(Debug, Clone, Copy)]
pub struct SessionInfo {
    pub model: ModelKind,
    pub shape: StateShape,
    /// Total tokens the session decodes (the prefill's first token counts).
    pub decode_steps: usize,
}

/// One step of one session inside an iteration batch.
#[derive(Debug, Clone, Copy)]
pub struct ScheduledStep {
    pub id: SessionId,
    pub model: ModelKind,
    pub phase: Phase,
    /// 0-based token index this step produces.
    pub step: usize,
}

/// What `on_step_done` decided about the session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// More tokens to go; the session re-entered the decode ring.
    Continue,
    /// The session produced its final token and was retired.
    Retired,
    /// No such session (already retired/failed/expired).
    Unknown,
}

/// Scheduler lifecycle counters.
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    pub admitted: u64,
    pub retired: u64,
    pub expired: u64,
    pub failed: u64,
    pub prefill_steps: u64,
    pub decode_steps: u64,
    pub batches: u64,
    /// Sessions detached via [`SessionScheduler::export`] (fleet migration).
    pub migrated_out: u64,
    /// Sessions re-attached via [`SessionScheduler::admit_migrated`].
    pub migrated_in: u64,
}

/// A session's scheduler-side record, detached by
/// [`SessionScheduler::export`] so a fleet router can move it to another
/// node's scheduler with [`SessionScheduler::admit_migrated`]. Progress
/// (`tokens_done`) travels with the ticket: the destination resumes decode
/// at exactly the next token index, never replaying or skipping one.
#[derive(Debug, Clone, Copy)]
pub struct MigratedSession {
    pub info: SessionInfo,
    pub phase: Phase,
    /// Tokens produced so far (prefill's first token included).
    pub tokens_done: usize,
}

#[derive(Debug)]
struct Entry {
    info: SessionInfo,
    phase: Phase,
    /// Tokens produced so far (prefill's first token included).
    tokens_done: usize,
    /// A step for this session is currently executing.
    in_flight: bool,
    last_activity: Instant,
}

/// The continuous-batching scheduler.
pub struct SessionScheduler {
    cfg: SchedulerConfig,
    sessions: BTreeMap<SessionId, Entry>,
    prefill_q: VecDeque<SessionId>,
    decode_q: VecDeque<SessionId>,
    pub stats: SchedStats,
}

impl SessionScheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        Self {
            cfg,
            sessions: BTreeMap::new(),
            prefill_q: VecDeque::new(),
            decode_q: VecDeque::new(),
            stats: SchedStats::default(),
        }
    }

    /// Admit a new session; it enters the prefill queue.
    pub fn admit(&mut self, id: SessionId, info: SessionInfo, now: Instant) {
        self.sessions.insert(
            id,
            Entry {
                info,
                phase: Phase::Prefill,
                tokens_done: 0,
                in_flight: false,
                last_activity: now,
            },
        );
        self.prefill_q.push_back(id);
        self.stats.admitted += 1;
    }

    /// Assemble the next iteration batch (empty when nothing is ready —
    /// either no sessions, or every live session is in flight).
    pub fn next_batch(&mut self) -> Vec<ScheduledStep> {
        let cap = self.cfg.max_batch.max(1);
        let mut out = Vec::new();
        // Decode steps first: inter-token latency beats prompt admission —
        // but hold one slot back for a waiting prefill (anti-starvation).
        let reserve = usize::from(!self.prefill_q.is_empty());
        let decode_cap = cap.saturating_sub(reserve);
        while out.len() < decode_cap {
            let Some(id) = self.decode_q.pop_front() else { break };
            let Some(e) = self.sessions.get_mut(&id) else { continue }; // stale
            if e.in_flight || e.phase != Phase::Decode {
                continue; // stale duplicate
            }
            e.in_flight = true;
            out.push(ScheduledStep {
                id,
                model: e.info.model,
                phase: Phase::Decode,
                step: e.tokens_done,
            });
            self.stats.decode_steps += 1;
        }
        // Fill remaining slots with prefills of waiting sessions.
        while out.len() < cap {
            let Some(id) = self.prefill_q.pop_front() else { break };
            let Some(e) = self.sessions.get_mut(&id) else { continue };
            if e.in_flight || e.phase != Phase::Prefill {
                continue;
            }
            e.in_flight = true;
            out.push(ScheduledStep { id, model: e.info.model, phase: Phase::Prefill, step: 0 });
            self.stats.prefill_steps += 1;
        }
        if !out.is_empty() {
            self.stats.batches += 1;
        }
        out
    }

    /// Record a completed step. Prefill transitions the session to decode;
    /// the final decode step retires it.
    pub fn on_step_done(&mut self, id: SessionId, now: Instant) -> StepOutcome {
        let Some(e) = self.sessions.get_mut(&id) else { return StepOutcome::Unknown };
        e.in_flight = false;
        e.last_activity = now;
        match e.phase {
            Phase::Prefill => {
                e.phase = Phase::Decode;
                e.tokens_done = 1; // the prefill produced the first token
            }
            Phase::Decode => e.tokens_done += 1,
        }
        if e.tokens_done >= e.info.decode_steps {
            self.sessions.remove(&id);
            self.stats.retired += 1;
            StepOutcome::Retired
        } else {
            self.decode_q.push_back(id);
            StepOutcome::Continue
        }
    }

    /// Detach a live session for migration to another node. Returns `None`
    /// if the session is unknown or has a step in flight — an executing
    /// step must finish (or be [`abort_step`](Self::abort_step)ed on
    /// fail-stop) before its session can move, otherwise the in-flight
    /// token would race the transfer. Queue entries left behind are lazily
    /// skipped as stale by [`next_batch`](Self::next_batch).
    pub fn export(&mut self, id: SessionId) -> Option<MigratedSession> {
        match self.sessions.get(&id) {
            Some(e) if !e.in_flight => {
                let e = self.sessions.remove(&id).expect("checked above");
                self.stats.migrated_out += 1;
                Some(MigratedSession { info: e.info, phase: e.phase, tokens_done: e.tokens_done })
            }
            _ => None,
        }
    }

    /// Cancel a session's in-flight step without crediting a token — the
    /// fail-stop path: the node died mid-batch, the step's result is lost,
    /// and the session must be exported at its *pre-batch* progress so the
    /// recovering node re-executes the aborted step. Returns `true` if a
    /// step was actually cancelled.
    pub fn abort_step(&mut self, id: SessionId) -> bool {
        match self.sessions.get_mut(&id) {
            Some(e) if e.in_flight => {
                e.in_flight = false;
                true
            }
            _ => false,
        }
    }

    /// Attach a session exported from another scheduler. It enters the
    /// queue matching its phase: a mid-decode session joins the back of the
    /// decode ring at its carried `tokens_done`, a not-yet-prefilled one
    /// queues for prefill as if freshly admitted.
    pub fn admit_migrated(&mut self, id: SessionId, m: MigratedSession, now: Instant) {
        self.sessions.insert(
            id,
            Entry {
                info: m.info,
                phase: m.phase,
                tokens_done: m.tokens_done,
                in_flight: false,
                last_activity: now,
            },
        );
        match m.phase {
            Phase::Prefill => self.prefill_q.push_back(id),
            Phase::Decode => self.decode_q.push_back(id),
        }
        self.stats.migrated_in += 1;
    }

    /// Drop a session whose step failed (executor error, lost state).
    pub fn fail(&mut self, id: SessionId) {
        if self.sessions.remove(&id).is_some() {
            self.stats.failed += 1;
        }
    }

    /// Expire sessions idle past `session_timeout`; returns their ids so
    /// the caller can evict cached state and drop reply channels. In-flight
    /// sessions are never expired (their step is still executing).
    pub fn expire(&mut self, now: Instant) -> Vec<SessionId> {
        let timeout = self.cfg.session_timeout;
        let dead: Vec<SessionId> = self
            .sessions
            .iter()
            .filter(|(_, e)| !e.in_flight && now.duration_since(e.last_activity) >= timeout)
            .map(|(&id, _)| id)
            .collect();
        for id in &dead {
            self.sessions.remove(id);
            self.stats.expired += 1;
        }
        dead
    }

    /// Live sessions (admitted, not yet retired/failed/expired).
    pub fn live(&self) -> usize {
        self.sessions.len()
    }

    /// Ids of every live session, ascending — what a fleet router walks to
    /// drain a node.
    pub fn live_ids(&self) -> Vec<SessionId> {
        self.sessions.keys().copied().collect()
    }

    /// Immutable parameters of a live session (`None` once it retired,
    /// failed, expired, or was exported).
    pub fn info(&self, id: SessionId) -> Option<SessionInfo> {
        self.sessions.get(&id).map(|e| e.info)
    }

    /// Sessions with a step currently executing.
    pub fn in_flight(&self) -> usize {
        self.sessions.values().filter(|e| e.in_flight).count()
    }

    pub fn is_idle(&self) -> bool {
        self.sessions.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(steps: usize) -> SessionInfo {
        SessionInfo {
            model: ModelKind::Mamba,
            shape: StateShape::mamba(2, 4, 8),
            decode_steps: steps,
        }
    }

    fn sched(max_batch: usize) -> SessionScheduler {
        SessionScheduler::new(SchedulerConfig {
            max_batch,
            session_timeout: Duration::from_secs(60),
        })
    }

    #[test]
    fn prefill_then_decode_then_retire() {
        let mut s = sched(4);
        let t = Instant::now();
        s.admit(1, info(3), t);
        let b = s.next_batch();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].phase, Phase::Prefill);
        assert!(s.next_batch().is_empty(), "in-flight session is not rescheduled");
        assert_eq!(s.on_step_done(1, t), StepOutcome::Continue);
        // Two decode steps remain (prefill produced token 0 of 3).
        for step in 1..3 {
            let b = s.next_batch();
            assert_eq!(b.len(), 1);
            assert_eq!(b[0].phase, Phase::Decode);
            assert_eq!(b[0].step, step);
            let out = s.on_step_done(1, t);
            if step == 2 {
                assert_eq!(out, StepOutcome::Retired);
            } else {
                assert_eq!(out, StepOutcome::Continue);
            }
        }
        assert!(s.is_idle());
        assert_eq!(s.stats.retired, 1);
        assert_eq!(s.on_step_done(1, t), StepOutcome::Unknown);
    }

    #[test]
    fn mixed_batches_decode_first_with_admission_slot() {
        let mut s = sched(2);
        let t = Instant::now();
        s.admit(1, info(4), t);
        s.admit(2, info(4), t);
        for step in s.next_batch() {
            s.on_step_done(step.id, t); // both prefills complete
        }
        s.admit(3, info(4), t);
        // Two decode-ready sessions + one waiting prefill, batch width 2:
        // decode takes the batch minus one reserved admission slot.
        let b = s.next_batch();
        assert_eq!(b.len(), 2);
        assert_eq!(b.iter().filter(|x| x.phase == Phase::Decode).count(), 1, "{b:?}");
        assert_eq!(b.iter().filter(|x| x.phase == Phase::Prefill).count(), 1, "{b:?}");
        for step in b {
            s.on_step_done(step.id, t);
        }
        // No prefills waiting any more → decode gets the full batch.
        let b = s.next_batch();
        assert_eq!(b.len(), 2);
        assert!(b.iter().all(|x| x.phase == Phase::Decode), "{b:?}");
    }

    #[test]
    fn decode_ring_is_round_robin() {
        let mut s = sched(1);
        let t = Instant::now();
        for id in 1..=3 {
            s.admit(id, info(10), t);
        }
        // Complete all prefills (batch width 1 → one at a time).
        for _ in 0..3 {
            let b = s.next_batch();
            s.on_step_done(b[0].id, t);
        }
        // Decode order must rotate 1, 2, 3, 1, 2, 3, …
        let mut order = Vec::new();
        for _ in 0..6 {
            let b = s.next_batch();
            order.push(b[0].id);
            s.on_step_done(b[0].id, t);
        }
        assert_eq!(order, vec![1, 2, 3, 1, 2, 3]);
    }

    #[test]
    fn one_token_session_retires_at_prefill() {
        let mut s = sched(4);
        let t = Instant::now();
        s.admit(7, info(1), t);
        let b = s.next_batch();
        assert_eq!(b[0].phase, Phase::Prefill);
        assert_eq!(s.on_step_done(7, t), StepOutcome::Retired);
        assert!(s.is_idle());
    }

    #[test]
    fn expire_skips_in_flight() {
        let mut s = SessionScheduler::new(SchedulerConfig {
            max_batch: 4,
            session_timeout: Duration::from_millis(10),
        });
        let t = Instant::now();
        s.admit(1, info(4), t);
        s.admit(2, info(4), t);
        let b = s.next_batch(); // both prefills in flight
        assert_eq!(b.len(), 2);
        s.on_step_done(1, t); // 1 idle again; 2 stays in flight
        let later = t + Duration::from_millis(50);
        let dead = s.expire(later);
        assert_eq!(dead, vec![1]);
        assert_eq!(s.stats.expired, 1);
        assert_eq!(s.live(), 1, "in-flight session 2 survives");
    }

    #[test]
    fn export_moves_progress_between_schedulers() {
        let mut src = sched(4);
        let mut dst = sched(4);
        let t = Instant::now();
        src.admit(9, info(5), t);
        // Prefill + one decode step on the source: tokens_done == 2.
        for _ in 0..2 {
            let b = src.next_batch();
            assert_eq!(b.len(), 1);
            src.on_step_done(9, t);
        }
        let m = src.export(9).expect("idle session exports");
        assert_eq!(m.tokens_done, 2);
        assert_eq!(m.phase, Phase::Decode);
        assert!(src.is_idle());
        assert_eq!(src.stats.migrated_out, 1);
        // Destination resumes at token index 2 and retires after 5 total.
        dst.admit_migrated(9, m, t);
        assert_eq!(dst.stats.migrated_in, 1);
        let b = dst.next_batch();
        assert_eq!(b[0].phase, Phase::Decode);
        assert_eq!(b[0].step, 2, "resume at the next token index");
        assert_eq!(dst.on_step_done(9, t), StepOutcome::Continue);
        for _ in 3..5 {
            let b = dst.next_batch();
            assert_eq!(b.len(), 1);
            dst.on_step_done(9, t);
        }
        assert!(dst.is_idle());
        assert_eq!(dst.stats.retired, 1);
    }

    #[test]
    fn export_refuses_in_flight_until_aborted() {
        let mut s = sched(4);
        let t = Instant::now();
        s.admit(3, info(4), t);
        let b = s.next_batch();
        assert_eq!(b.len(), 1);
        assert!(s.export(3).is_none(), "in-flight step pins the session");
        assert!(s.abort_step(3), "fail-stop cancels the step");
        assert!(!s.abort_step(3), "nothing left to cancel");
        let m = s.export(3).expect("aborted session exports");
        assert_eq!(m.tokens_done, 0, "aborted step credits no token");
        assert_eq!(m.phase, Phase::Prefill);
        assert!(s.export(99).is_none(), "unknown session");
    }

    #[test]
    fn migrated_prefill_session_queues_for_prefill() {
        let mut src = sched(4);
        let mut dst = sched(4);
        let t = Instant::now();
        src.admit(5, info(2), t);
        let m = src.export(5).expect("never scheduled, exports clean");
        dst.admit_migrated(5, m, t);
        let b = dst.next_batch();
        assert_eq!(b[0].phase, Phase::Prefill);
        assert_eq!(dst.on_step_done(5, t), StepOutcome::Continue);
    }

    #[test]
    fn stale_queue_entry_after_export_is_skipped() {
        let mut s = sched(4);
        let t = Instant::now();
        s.admit(1, info(4), t);
        s.admit(2, info(4), t);
        for step in s.next_batch() {
            s.on_step_done(step.id, t); // both now in the decode ring
        }
        let _ = s.export(1).expect("idle exports");
        // 1's decode-ring entry is stale; only 2 schedules.
        let b = s.next_batch();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].id, 2);
    }

    #[test]
    fn fail_removes_session() {
        let mut s = sched(4);
        let t = Instant::now();
        s.admit(1, info(4), t);
        let _ = s.next_batch();
        s.fail(1);
        assert!(s.is_idle());
        assert_eq!(s.stats.failed, 1);
        // Late feedback for a failed session is harmless.
        assert_eq!(s.on_step_done(1, t), StepOutcome::Unknown);
    }
}
