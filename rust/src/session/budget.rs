//! Byte-accurate memory budget for resident session state, derived from the
//! chip's memory capacities in [`crate::arch`].
//!
//! The budget models the slice of on-chip SRAM (PMUs, paper Table I) the
//! serving deployment dedicates to decode state; everything beyond it spills
//! over the off-chip interface, whose cost is modeled with
//! [`crate::arch::MemTech::transfer_time`].

use crate::arch::{MemTech, RduSpec};

/// A hard byte budget with exact reserve/release accounting.
///
/// Invariant: `used ≤ capacity` at all times — `try_reserve` refuses any
/// reservation that would exceed the budget, so the caller (the state
/// cache) must evict first.
#[derive(Debug, Clone)]
pub struct MemoryBudget {
    capacity: usize,
    used: usize,
}

impl MemoryBudget {
    /// A budget of exactly `capacity` bytes.
    pub fn new(capacity: usize) -> Self {
        Self { capacity, used: 0 }
    }

    /// A budget equal to `fraction` of the chip's total SRAM
    /// (`RduSpec::sram_bytes`, 780 MB for the Table I configuration).
    pub fn from_sram_fraction(spec: &RduSpec, fraction: f64) -> Self {
        let f = fraction.clamp(0.0, 1.0);
        Self::new((spec.sram_bytes() as f64 * f) as usize)
    }

    /// A budget of `n` PMUs' worth of SRAM (1.5 MB each for Table I).
    pub fn from_pmus(spec: &RduSpec, n: usize) -> Self {
        Self::new(n * spec.pmu_bytes)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn used(&self) -> usize {
        self.used
    }

    pub fn headroom(&self) -> usize {
        self.capacity - self.used
    }

    /// Would a reservation of `bytes` fit right now?
    pub fn fits(&self, bytes: usize) -> bool {
        self.used.saturating_add(bytes) <= self.capacity
    }

    /// Reserve `bytes`; returns false (and reserves nothing) if it would
    /// exceed the budget.
    pub fn try_reserve(&mut self, bytes: usize) -> bool {
        if self.fits(bytes) {
            self.used += bytes;
            true
        } else {
            false
        }
    }

    /// Release a previous reservation of `bytes`.
    pub fn release(&mut self, bytes: usize) {
        debug_assert!(self.used >= bytes, "releasing {bytes} B with only {} B used", self.used);
        self.used = self.used.saturating_sub(bytes);
    }
}

/// Modeled time to move `bytes` of spilled state across the off-chip
/// interface (one direction).
pub fn spill_seconds(bytes: usize, dram: MemTech) -> f64 {
    dram.transfer_time(bytes as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_release_accounting() {
        let mut b = MemoryBudget::new(100);
        assert!(b.try_reserve(60));
        assert!(!b.try_reserve(50), "would exceed capacity");
        assert_eq!(b.used(), 60);
        assert_eq!(b.headroom(), 40);
        b.release(60);
        assert_eq!(b.used(), 0);
        assert!(b.try_reserve(100));
    }

    #[test]
    fn zero_budget_fits_nothing_but_zero() {
        let mut b = MemoryBudget::new(0);
        assert!(b.fits(0));
        assert!(!b.try_reserve(1));
    }

    #[test]
    fn derived_from_table1_pmus() {
        let spec = crate::arch::RduSpec::table1();
        let b = MemoryBudget::from_pmus(&spec, 4);
        assert_eq!(b.capacity(), 4 * spec.pmu_bytes);
        let half = MemoryBudget::from_sram_fraction(&spec, 0.5);
        assert_eq!(half.capacity(), spec.sram_bytes() / 2);
    }

    #[test]
    fn spill_time_uses_mem_tech_bandwidth() {
        // 8 TB at 8 TB/s (HBM3e) = 1 s.
        let s = spill_seconds(8_000_000_000_000, MemTech::Hbm3e);
        assert!((s - 1.0).abs() < 1e-9);
    }
}
