//! Session subsystem: per-sequence SSM decode state + continuous batching.
//!
//! The paper's premise is that SSM decode is a recurrence over O(1) cached
//! state, so a serving deployment's real resource is *state residency*, not
//! attention FLOPs. This module gives that state first-class treatment and
//! schedules multi-turn/streaming decode over it:
//!
//! ```text
//!                 admit                     next_batch (ready steps)
//!  clients ──▶ SessionScheduler ───────────────▶ steps {prefill|decode}
//!               │  prefill_q → decode ring           │
//!               │  retire / timeout                  ▼ execute
//!               │                               Executor::begin_session
//!               │   checkout / checkin          Executor::step_decode
//!               ╰──────▶ StateCache ◀────────────────╯
//!                        │  resident (≤ byte budget, LRU)
//!                        ╰─ spilled  (off-chip, MemTech-priced)
//! ```
//!
//! * [`state`] — [`SsmState`]: Mamba recurrent blocks
//!   (`layers × d_state × d_model` f32) and Hyena FFT filter/prefix caches,
//!   with exact byte accounting.
//! * [`budget`] — [`MemoryBudget`]: hard byte budget derived from the
//!   chip's SRAM capacities ([`crate::arch::RduSpec`]), plus the
//!   [`crate::arch::MemTech`]-priced spill model.
//! * [`cache`] — [`StateCache`]: session-keyed LRU residency under the
//!   budget; evicted state spills losslessly and restores on demand.
//! * [`scheduler`] — [`SessionScheduler`]: vLLM-style continuous batching
//!   (decode-first iteration batches with an admission slot for prefills).
//! * [`driver`] — [`simulate`]: single-threaded serving loop over any
//!   [`crate::coordinator::Executor`], timed by the
//!   [`crate::dfmodel::decode`] cost hook — no PJRT needed.
//!
//! The threaded serving integration (worker pool, reply channels, metrics)
//! lives in [`crate::coordinator`]; `serve --continuous` wires it to the
//! CLI.

pub mod budget;
pub mod cache;
pub mod driver;
pub mod scheduler;
pub mod state;

pub use budget::{spill_seconds, MemoryBudget};
pub use cache::{CacheStats, StateCache};
pub use driver::{simulate, simulate_pooled, SimConfig, SimReport};
pub use scheduler::{
    MigratedSession, Phase, SchedStats, ScheduledStep, SchedulerConfig, SessionInfo,
    SessionScheduler, StepOutcome,
};
pub use state::{SsmState, StateShape};

/// Identifies one live decode session (the coordinator reuses request ids).
pub type SessionId = u64;
