//! Single-threaded simulation driver: runs the continuous-batching
//! scheduler + state cache against any [`Executor`] (normally the mock),
//! attaching hardware time to every iteration batch via the
//! [`crate::dfmodel::decode`] cost hook — the whole serving loop is
//! exercisable without PJRT artifacts or worker threads.
//!
//! Used by `benches/serve_sessions.rs` and `examples/chat_sessions.rs`;
//! the threaded production path lives in [`crate::coordinator`].

use super::cache::{CacheStats, StateCache};
use super::scheduler::{
    Phase, SchedStats, SchedulerConfig, SessionInfo, SessionScheduler, StepOutcome,
};
use super::state::StateShape;
use super::SessionId;
use crate::arch::RduConfig;
use crate::coordinator::Executor;
use crate::dfmodel::decode::decode_step;
use crate::runtime::ModelKind;
use crate::session::budget::MemoryBudget;
use crate::util::XorShift;
use crate::Result;
use anyhow::anyhow;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One simulated serving scenario.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Concurrent sessions (models alternate Mamba/Hyena).
    pub sessions: usize,
    /// Tokens each session decodes (prefill's first token included).
    pub decode_steps: usize,
    /// Prompt length in tokens (scales the modeled prefill cost).
    pub prompt_tokens: usize,
    pub mamba_shape: StateShape,
    pub hyena_shape: StateShape,
    pub sched: SchedulerConfig,
    /// Resident state budget in bytes.
    pub budget_bytes: usize,
    /// PRNG seed for prompt synthesis.
    pub seed: u64,
}

impl SimConfig {
    /// A small realistic scenario: 8-layer decoders, Mamba N=16 over D=64,
    /// Hyena caches matched to the same footprint class.
    pub fn demo(sessions: usize, decode_steps: usize) -> Self {
        let mamba_shape = StateShape::mamba(8, 16, 64);
        let hyena_shape = StateShape::hyena(8, 64, 256);
        let mut cfg = Self {
            sessions,
            decode_steps,
            prompt_tokens: 16,
            mamba_shape,
            hyena_shape,
            sched: SchedulerConfig::default(),
            budget_bytes: 0,
            seed: 5,
        };
        cfg.budget_bytes = cfg.footprint_bytes(); // default: everything fits
        cfg
    }

    /// Which model session `i` runs (alternating).
    pub fn model_of(&self, i: usize) -> ModelKind {
        if i % 2 == 0 {
            ModelKind::Mamba
        } else {
            ModelKind::Hyena
        }
    }

    pub fn shape_for(&self, model: ModelKind) -> StateShape {
        match model {
            ModelKind::Hyena => self.hyena_shape,
            _ => self.mamba_shape,
        }
    }

    /// Total state footprint if every session were resident at once.
    pub fn footprint_bytes(&self) -> usize {
        (0..self.sessions).map(|i| self.shape_for(self.model_of(i)).bytes()).sum()
    }
}

/// Result of one simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Tokens produced (= sessions × decode_steps on success).
    pub tokens: u64,
    /// Modeled hardware time: Σ over iteration batches of the slowest step
    /// in the batch, plus modeled spill/restore transfer time.
    pub sim_seconds: f64,
    /// Host wall-clock of the simulation itself.
    pub wall: Duration,
    pub cache: CacheStats,
    pub sched: SchedStats,
    pub batches: u64,
    pub mean_batch: f64,
}

impl SimReport {
    /// Modeled serving throughput.
    pub fn tokens_per_sim_second(&self) -> f64 {
        if self.sim_seconds <= 0.0 {
            return 0.0;
        }
        self.tokens as f64 / self.sim_seconds
    }
}

/// Decoder shape fed to the cost hook for a given state shape.
fn cost_config(shape: &StateShape) -> crate::workloads::DecoderConfig {
    crate::workloads::DecoderConfig {
        seq_len: 1, // decode cost is O(1) in sequence length
        d_model: shape.d_model,
        mlp_mult: 4,
        dtype_bytes: 2.0,
        fft_tile: 32,
        state_dim: shape.d_state.max(1),
        expand: 1,
    }
}

/// Run `cfg.sessions` sessions to completion through the scheduler + cache
/// on `exec`, timing iteration batches with the DFModel decode-cost hook
/// for `rdu`.
pub fn simulate(exec: &mut dyn Executor, cfg: &SimConfig, rdu: &RduConfig) -> Result<SimReport> {
    let t0 = Instant::now();
    let mut cache = StateCache::new(MemoryBudget::new(cfg.budget_bytes), rdu.spec.dram);
    let mut sched = SessionScheduler::new(cfg.sched);
    let mut rng = XorShift::new(cfg.seed);

    // Per-model decode-step cost (all sessions of a model share a shape).
    let step_cost = |model: ModelKind| {
        let shape = cfg.shape_for(model);
        decode_step(model, &cost_config(&shape), shape.layers, rdu).seconds
    };
    let mamba_cost = step_cost(ModelKind::Mamba);
    let hyena_cost = step_cost(ModelKind::Hyena);
    let cost_of = |model: ModelKind| match model {
        ModelKind::Hyena => hyena_cost,
        _ => mamba_cost,
    };

    let mut prompts: BTreeMap<SessionId, Vec<f32>> = BTreeMap::new();
    let mut last_token: BTreeMap<SessionId, Vec<f32>> = BTreeMap::new();
    let now = Instant::now();
    for i in 0..cfg.sessions {
        let id = (i + 1) as SessionId;
        let model = cfg.model_of(i);
        let shape = cfg.shape_for(model);
        let prompt: Vec<f32> = (0..cfg.prompt_tokens * shape.d_model)
            .map(|_| rng.uniform(-1.0, 1.0) as f32)
            .collect();
        prompts.insert(id, prompt);
        sched.admit(id, SessionInfo { model, shape, decode_steps: cfg.decode_steps }, now);
    }

    let mut tokens = 0u64;
    let mut sim_seconds = 0.0f64;
    let mut batches = 0u64;
    let mut batched_steps = 0u64;
    while !sched.is_idle() {
        let steps = sched.next_batch();
        if steps.is_empty() {
            return Err(anyhow!("scheduler stalled with {} live sessions", sched.live()));
        }
        batches += 1;
        batched_steps += steps.len() as u64;
        let spill0 = cache.stats.spill_seconds;
        // Iteration time = slowest step in the batch (steps share the chip
        // as batched lanes), plus any off-chip spill traffic it triggered.
        let mut batch_seconds = 0.0f64;
        for s in steps {
            let out = match s.phase {
                Phase::Prefill => {
                    let prompt = prompts.remove(&s.id).unwrap_or_default();
                    let shape = cfg.shape_for(s.model);
                    let (state, first) = exec.begin_session(s.model, &prompt, &shape)?;
                    cache.insert(s.id, state);
                    batch_seconds =
                        batch_seconds.max(cost_of(s.model) * cfg.prompt_tokens.max(1) as f64);
                    first
                }
                Phase::Decode => {
                    let token = last_token
                        .get(&s.id)
                        .cloned()
                        .ok_or_else(|| anyhow!("session {} has no previous token", s.id))?;
                    let mut state = cache
                        .checkout(s.id)
                        .ok_or_else(|| anyhow!("session {} lost its cached state", s.id))?;
                    let out = exec.step_decode(s.model, &mut state, &token)?;
                    cache.checkin(s.id, state);
                    batch_seconds = batch_seconds.max(cost_of(s.model));
                    out
                }
            };
            tokens += 1;
            last_token.insert(s.id, out);
            if sched.on_step_done(s.id, Instant::now()) == StepOutcome::Retired {
                cache.remove(s.id);
                last_token.remove(&s.id);
            }
        }
        sim_seconds += batch_seconds + (cache.stats.spill_seconds - spill0);
    }

    Ok(SimReport {
        tokens,
        sim_seconds,
        wall: t0.elapsed(),
        cache: cache.stats.clone(),
        sched: sched.stats.clone(),
        batches,
        mean_batch: if batches == 0 { 0.0 } else { batched_steps as f64 / batches as f64 },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExecutor;

    #[test]
    fn all_sessions_decode_to_completion() {
        let cfg = SimConfig::demo(10, 6);
        let mut exec = MockExecutor::new(1, cfg.mamba_shape.d_model);
        let r = simulate(&mut exec, &cfg, &RduConfig::hs_scan_mode()).unwrap();
        assert_eq!(r.tokens, 60);
        assert_eq!(r.sched.retired, 10);
        assert_eq!(r.cache.evictions, 0, "full budget: no eviction");
        assert!(r.sim_seconds > 0.0);
        assert!(r.mean_batch >= 1.0);
    }

    #[test]
    fn tight_budget_spills_but_stays_correct() {
        let mut cfg = SimConfig::demo(12, 5);
        let full = {
            let mut exec = MockExecutor::new(1, cfg.mamba_shape.d_model);
            simulate(&mut exec, &cfg, &RduConfig::hs_scan_mode()).unwrap()
        };
        cfg.budget_bytes = cfg.footprint_bytes() / 4;
        let tight = {
            let mut exec = MockExecutor::new(1, cfg.mamba_shape.d_model);
            simulate(&mut exec, &cfg, &RduConfig::hs_scan_mode()).unwrap()
        };
        assert_eq!(tight.tokens, full.tokens, "eviction is transparent to completion");
        assert!(tight.cache.evictions > 0, "quarter budget must evict: {:?}", tight.cache);
        assert!(tight.cache.misses > 0);
        assert!(
            tight.sim_seconds > full.sim_seconds,
            "spill traffic costs modeled time: {} vs {}",
            tight.sim_seconds,
            full.sim_seconds
        );
    }
}
