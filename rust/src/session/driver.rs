//! Simulation driver: runs the continuous-batching scheduler + state cache
//! against any [`Executor`] (normally the mock), attaching hardware time to
//! every iteration batch via the [`crate::dfmodel::decode`] cost hook — the
//! whole serving loop is exercisable without PJRT artifacts.
//!
//! Two drivers share the scheduling/caching/timing logic: the
//! single-threaded [`simulate`], and [`simulate_pooled`], which fans each
//! iteration batch's *independent session steps* across the resident
//! [`crate::runtime::team::WorkerTeam`]. Executors are thread-affine
//! (deliberately not `Send` — see [`crate::coordinator::Executor`]), so
//! each resident worker builds its own executor from the
//! [`ExecutorFactory`] the first time a simulation's work reaches it and
//! keeps it *sticky* in thread-local storage for every later batch of the
//! same simulation (keyed by a per-simulation instance id; reuse counts
//! `team.sticky_hit`); states and tokens travel to the workers instead.
//! Tokens are bit-identical between the two drivers because each step
//! depends only on its session's own state.
//!
//! Used by `benches/serve_sessions.rs` and `examples/chat_sessions.rs`;
//! the threaded production path lives in [`crate::coordinator`].

use super::cache::{CacheStats, StateCache};
use super::scheduler::{
    Phase, SchedStats, SchedulerConfig, SessionInfo, SessionScheduler, StepOutcome,
};
use super::state::{SsmState, StateShape};
use super::SessionId;
use crate::arch::RduConfig;
use crate::coordinator::{Executor, ExecutorFactory};
use crate::dfmodel::decode::decode_step_workload;
use crate::runtime::pool::chunk_ranges;
use crate::runtime::{ModelKind, WorkerTeam};
use crate::session::budget::MemoryBudget;
use crate::util::XorShift;
use crate::Result;
use anyhow::anyhow;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One simulated serving scenario.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Concurrent sessions (models alternate Mamba/Hyena).
    pub sessions: usize,
    /// Tokens each session decodes (prefill's first token included).
    pub decode_steps: usize,
    /// Prompt length in tokens (scales the modeled prefill cost).
    pub prompt_tokens: usize,
    pub mamba_shape: StateShape,
    pub hyena_shape: StateShape,
    pub sched: SchedulerConfig,
    /// Resident state budget in bytes.
    pub budget_bytes: usize,
    /// PRNG seed for prompt synthesis.
    pub seed: u64,
}

impl SimConfig {
    /// A small realistic scenario: 8-layer decoders, Mamba N=16 over D=64,
    /// Hyena caches matched to the same footprint class.
    pub fn demo(sessions: usize, decode_steps: usize) -> Self {
        let mamba_shape = StateShape::mamba(8, 16, 64);
        let hyena_shape = StateShape::hyena(8, 64, 256);
        let mut cfg = Self {
            sessions,
            decode_steps,
            prompt_tokens: 16,
            mamba_shape,
            hyena_shape,
            sched: SchedulerConfig::default(),
            budget_bytes: 0,
            seed: 5,
        };
        cfg.budget_bytes = cfg.footprint_bytes(); // default: everything fits
        cfg
    }

    /// Which model session `i` runs (alternating).
    pub fn model_of(&self, i: usize) -> ModelKind {
        if i % 2 == 0 {
            ModelKind::Mamba
        } else {
            ModelKind::Hyena
        }
    }

    pub fn shape_for(&self, model: ModelKind) -> StateShape {
        match model {
            ModelKind::Hyena => self.hyena_shape,
            _ => self.mamba_shape,
        }
    }

    /// Total state footprint if every session were resident at once.
    pub fn footprint_bytes(&self) -> usize {
        (0..self.sessions).map(|i| self.shape_for(self.model_of(i)).bytes()).sum()
    }
}

/// Result of one simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Tokens produced (= sessions × decode_steps on success).
    pub tokens: u64,
    /// Modeled hardware time: Σ over iteration batches of the slowest step
    /// in the batch, plus modeled spill/restore transfer time.
    pub sim_seconds: f64,
    /// Host wall-clock of the simulation itself.
    pub wall: Duration,
    pub cache: CacheStats,
    pub sched: SchedStats,
    pub batches: u64,
    pub mean_batch: f64,
}

impl SimReport {
    /// Modeled serving throughput.
    pub fn tokens_per_sim_second(&self) -> f64 {
        if self.sim_seconds <= 0.0 {
            return 0.0;
        }
        self.tokens as f64 / self.sim_seconds
    }
}

/// Decoder shape fed to the cost hook for a given state shape (shared with
/// [`crate::fleet`] so fleet nodes price decode steps identically).
pub(crate) fn cost_config(shape: &StateShape) -> crate::workloads::DecoderConfig {
    crate::workloads::DecoderConfig {
        seq_len: 1, // decode cost is O(1) in sequence length
        d_model: shape.d_model,
        mlp_mult: 4,
        dtype_bytes: 2.0,
        fft_tile: 32,
        state_dim: shape.d_state.max(1),
        expand: 1,
        ssd_chunk: 256,
    }
}

/// Per-model decode-step cost table for one scenario (all sessions of a
/// model share a shape), shared by the serial and pooled drivers so their
/// modeled times agree exactly. Costs come from the workload registry: each
/// serving family's canonical [`crate::workloads::Workload`] supplies the
/// decode demand the [`crate::dfmodel::decode`] hook prices.
fn step_cost_fn(cfg: &SimConfig, rdu: &RduConfig) -> impl Fn(ModelKind) -> f64 {
    let per = |model: ModelKind| {
        let shape = cfg.shape_for(model);
        let w = crate::workloads::family_workload(model);
        decode_step_workload(w, &cost_config(&shape), shape.layers, rdu).seconds
    };
    let mamba = per(ModelKind::Mamba);
    let hyena = per(ModelKind::Hyena);
    move |model| match model {
        ModelKind::Hyena => hyena,
        _ => mamba,
    }
}

/// Admit every configured session: synthesize its prompt (deterministic
/// from `cfg.seed` via `rng`) and enqueue its prefill. Shared by both
/// drivers so their session populations are identical.
fn admit_sessions(
    cfg: &SimConfig,
    sched: &mut SessionScheduler,
    rng: &mut XorShift,
) -> BTreeMap<SessionId, Vec<f32>> {
    let mut prompts = BTreeMap::new();
    let now = Instant::now();
    for i in 0..cfg.sessions {
        let id = (i + 1) as SessionId;
        let model = cfg.model_of(i);
        let shape = cfg.shape_for(model);
        let prompt: Vec<f32> = (0..cfg.prompt_tokens * shape.d_model)
            .map(|_| rng.uniform(-1.0, 1.0) as f32)
            .collect();
        prompts.insert(id, prompt);
        sched.admit(id, SessionInfo { model, shape, decode_steps: cfg.decode_steps }, now);
    }
    prompts
}

/// Assemble the closing [`SimReport`] (shared by both drivers).
fn build_report(
    t0: Instant,
    tokens: u64,
    sim_seconds: f64,
    cache: &StateCache,
    sched: &SessionScheduler,
    batches: u64,
    batched_steps: u64,
) -> SimReport {
    SimReport {
        tokens,
        sim_seconds,
        wall: t0.elapsed(),
        cache: cache.stats.clone(),
        sched: sched.stats.clone(),
        batches,
        mean_batch: if batches == 0 { 0.0 } else { batched_steps as f64 / batches as f64 },
    }
}

/// Run `cfg.sessions` sessions to completion through the scheduler + cache
/// on `exec`, timing iteration batches with the DFModel decode-cost hook
/// for `rdu`.
pub fn simulate(exec: &mut dyn Executor, cfg: &SimConfig, rdu: &RduConfig) -> Result<SimReport> {
    let t0 = Instant::now();
    let mut cache = StateCache::new(MemoryBudget::new(cfg.budget_bytes), rdu.spec.dram);
    let mut sched = SessionScheduler::new(cfg.sched);
    let mut rng = XorShift::new(cfg.seed);
    let cost_of = step_cost_fn(cfg, rdu);
    let mut prompts = admit_sessions(cfg, &mut sched, &mut rng);
    let mut last_token: BTreeMap<SessionId, Vec<f32>> = BTreeMap::new();

    let mut tokens = 0u64;
    let mut sim_seconds = 0.0f64;
    let mut batches = 0u64;
    let mut batched_steps = 0u64;
    while !sched.is_idle() {
        let steps = sched.next_batch();
        if steps.is_empty() {
            return Err(anyhow!("scheduler stalled with {} live sessions", sched.live()));
        }
        batches += 1;
        batched_steps += steps.len() as u64;
        let spill0 = cache.stats.spill_seconds;
        // Iteration time = slowest step in the batch (steps share the chip
        // as batched lanes), plus any off-chip spill traffic it triggered.
        let mut batch_seconds = 0.0f64;
        for s in steps {
            let out = match s.phase {
                Phase::Prefill => {
                    let prompt = prompts.remove(&s.id).unwrap_or_default();
                    let shape = cfg.shape_for(s.model);
                    let (state, first) = exec.begin_session(s.model, &prompt, &shape)?;
                    cache.insert(s.id, state);
                    batch_seconds =
                        batch_seconds.max(cost_of(s.model) * cfg.prompt_tokens.max(1) as f64);
                    first
                }
                Phase::Decode => {
                    let token = last_token
                        .get(&s.id)
                        .cloned()
                        .ok_or_else(|| anyhow!("session {} has no previous token", s.id))?;
                    let mut state = cache
                        .checkout(s.id)
                        .ok_or_else(|| anyhow!("session {} lost its cached state", s.id))?;
                    let out = exec.step_decode(s.model, &mut state, &token)?;
                    cache.checkin(s.id, state);
                    batch_seconds = batch_seconds.max(cost_of(s.model));
                    out
                }
            };
            tokens += 1;
            last_token.insert(s.id, out);
            if sched.on_step_done(s.id, Instant::now()) == StepOutcome::Retired {
                cache.remove(s.id);
                last_token.remove(&s.id);
            }
        }
        sim_seconds += batch_seconds + (cache.stats.spill_seconds - spill0);
    }

    Ok(build_report(t0, tokens, sim_seconds, &cache, &sched, batches, batched_steps))
}

/// One session step shipped to a pooled worker: the scheduler-order index,
/// the executor inputs, and (for decode) the session's checked-out state.
struct StepJob {
    idx: usize,
    model: ModelKind,
    phase: Phase,
    shape: StateShape,
    state: Option<SsmState>,
    input: Vec<f32>,
}

/// A pooled worker's answer: the (possibly new) state travels back with
/// the produced token so the main thread can check it into the cache.
struct StepDone {
    idx: usize,
    state: Option<SsmState>,
    result: Result<Vec<f32>>,
}

/// Monotonic id distinguishing [`simulate_pooled`] invocations, so a
/// resident worker's sticky executor from one simulation is never reused
/// by the next (a fresh factory means fresh executors).
static NEXT_SIM_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// A resident worker's sticky executor: `(owning sim id, executor)`.
    /// Built from the factory the first time a simulation's work reaches
    /// this worker and reused for every later batch of the same simulation,
    /// so executor-internal buffers and plan caches warm up exactly once
    /// per worker. Replaced in place when a different simulation arrives.
    static STICKY_EXEC: RefCell<Option<(u64, Box<dyn Executor>)>> = const { RefCell::new(None) };
}

/// Run `f` against this thread's sticky executor for simulation `sim`,
/// building it from `factory` on first touch. Reuse counts
/// `team.sticky_hit`. A factory failure surfaces as `Err` (and is retried
/// on the next step, matching the old per-worker-channel behaviour of one
/// factory call per worker).
fn with_sticky_executor<R>(
    sim: u64,
    factory: &ExecutorFactory,
    f: impl FnOnce(&mut dyn Executor) -> R,
) -> Result<R> {
    STICKY_EXEC.with(|slot| {
        let mut slot = slot.borrow_mut();
        match slot.as_ref() {
            Some((owner, _)) if *owner == sim => {
                crate::runtime::team::sticky_hit_counter().fetch_add(1, Ordering::Relaxed);
            }
            _ => {
                let exec = factory()
                    .map_err(|e| anyhow!("pooled worker failed to build its executor: {e:#}"))?;
                *slot = Some((sim, exec));
            }
        }
        let (_, exec) = slot.as_mut().expect("sticky executor installed above");
        Ok(f(exec.as_mut()))
    })
}

/// Execute one staged step on this thread's sticky executor.
fn run_step_job(sim: u64, factory: &ExecutorFactory, job: &mut StepJob) -> StepDone {
    let idx = job.idx;
    let ran = with_sticky_executor(sim, factory, |exec| match job.phase {
        Phase::Prefill => match exec.begin_session(job.model, &job.input, &job.shape) {
            Ok((state, first)) => StepDone { idx, state: Some(state), result: Ok(first) },
            Err(e) => StepDone { idx, state: None, result: Err(e) },
        },
        Phase::Decode => {
            let mut st = job.state.take().expect("decode job carries its state");
            let r = exec.step_decode(job.model, &mut st, &job.input);
            StepDone { idx, state: Some(st), result: r }
        }
    });
    // Factory failure: the step never ran, so a decode's checked-out state
    // travels back intact for the cache.
    ran.unwrap_or_else(|e| StepDone { idx, state: job.state.take(), result: Err(e) })
}

/// [`simulate`] with each iteration batch's session steps fanned across
/// the resident [`WorkerTeam`] in `threads` contiguous chunks — the pooled
/// mirror of the continuous-batching executor loop. Each resident worker
/// keeps a sticky executor built from `factory` (the same
/// per-worker-executor pattern as [`crate::coordinator::Coordinator`],
/// because executors are thread-affine); the main thread keeps sole
/// ownership of the scheduler and state cache, checking states out before
/// dispatch and back in — in scheduler order — after the batch returns, so
/// cache behaviour stays deterministic regardless of worker interleaving.
///
/// Token streams are bit-identical to [`simulate`]'s (each step depends
/// only on its own session's state); with a budget that holds every state
/// resident, the modeled time is identical too. Under a tight budget the
/// modeled spill *ordering* within a batch may differ, since the pooled
/// driver checks all of a batch's states out before any come back.
pub fn simulate_pooled(
    factory: &ExecutorFactory,
    cfg: &SimConfig,
    rdu: &RduConfig,
    threads: usize,
) -> Result<SimReport> {
    let threads = threads.max(1);
    let sim = NEXT_SIM_ID.fetch_add(1, Ordering::Relaxed);
    let team = WorkerTeam::global();
    let t0 = Instant::now();
    let mut cache = StateCache::new(MemoryBudget::new(cfg.budget_bytes), rdu.spec.dram);
    let mut sched = SessionScheduler::new(cfg.sched);
    let mut rng = XorShift::new(cfg.seed);
    let cost_of = step_cost_fn(cfg, rdu);
    let mut prompts = admit_sessions(cfg, &mut sched, &mut rng);
    let mut last_token: BTreeMap<SessionId, Vec<f32>> = BTreeMap::new();

    let mut tokens = 0u64;
    let mut sim_seconds = 0.0f64;
    let mut batches = 0u64;
    let mut batched_steps = 0u64;
    while !sched.is_idle() {
        let steps = sched.next_batch();
        if steps.is_empty() {
            return Err(anyhow!("scheduler stalled with {} live sessions", sched.live()));
        }
        batches += 1;
        batched_steps += steps.len() as u64;
        let spill0 = cache.stats.spill_seconds;

        // Stage the batch in scheduler order: prompts move out, decode
        // states check out of the cache deterministically.
        let mut jobs: Vec<StepJob> = Vec::with_capacity(steps.len());
        for (idx, s) in steps.iter().enumerate() {
            let job = match s.phase {
                Phase::Prefill => StepJob {
                    idx,
                    model: s.model,
                    phase: s.phase,
                    shape: cfg.shape_for(s.model),
                    state: None,
                    input: prompts.remove(&s.id).unwrap_or_default(),
                },
                Phase::Decode => StepJob {
                    idx,
                    model: s.model,
                    phase: s.phase,
                    shape: cfg.shape_for(s.model),
                    state: Some(
                        cache
                            .checkout(s.id)
                            .ok_or_else(|| anyhow!("session {} lost its cached state", s.id))?,
                    ),
                    input: last_token
                        .get(&s.id)
                        .cloned()
                        .ok_or_else(|| anyhow!("session {} has no previous token", s.id))?,
                },
            };
            jobs.push(job);
        }

        // Fan out contiguous chunks onto the resident team. Jobs park in
        // per-index slots (the claiming worker takes each out exactly
        // once); answers land in matching slots, so claim order cannot
        // affect results. `run` barriers on completion, so borrowing the
        // batch locals is safe.
        let n = jobs.len();
        let ranges = chunk_ranges(n, threads);
        let job_slots: Vec<Mutex<Option<StepJob>>> =
            jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
        let out_slots: Vec<Mutex<Option<StepDone>>> = (0..n).map(|_| Mutex::new(None)).collect();
        team.run(ranges.len(), |c| {
            for i in ranges[c].clone() {
                let mut job = job_slots[i]
                    .lock()
                    .expect("pooled job slot poisoned")
                    .take()
                    .expect("each job is claimed exactly once");
                let done = run_step_job(sim, factory, &mut job);
                *out_slots[i].lock().expect("pooled result slot poisoned") = Some(done);
            }
        });

        // Merge in scheduler order.
        let mut batch_seconds = 0.0f64;
        for (idx, s) in steps.iter().enumerate() {
            let done = out_slots[idx]
                .lock()
                .expect("pooled result slot poisoned")
                .take()
                .expect("one result per step (run() barriers on completion)");
            debug_assert_eq!(done.idx, idx);
            let out = match s.phase {
                Phase::Prefill => {
                    let first = done.result?;
                    cache.insert(s.id, done.state.expect("prefill produces a state"));
                    batch_seconds =
                        batch_seconds.max(cost_of(s.model) * cfg.prompt_tokens.max(1) as f64);
                    first
                }
                Phase::Decode => {
                    let token = done.result?;
                    cache.checkin(s.id, done.state.expect("decode returns its state"));
                    batch_seconds = batch_seconds.max(cost_of(s.model));
                    token
                }
            };
            tokens += 1;
            last_token.insert(s.id, out);
            if sched.on_step_done(s.id, Instant::now()) == StepOutcome::Retired {
                cache.remove(s.id);
                last_token.remove(&s.id);
            }
        }
        sim_seconds += batch_seconds + (cache.stats.spill_seconds - spill0);
    }

    Ok(build_report(t0, tokens, sim_seconds, &cache, &sched, batches, batched_steps))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExecutor;

    #[test]
    fn all_sessions_decode_to_completion() {
        let cfg = SimConfig::demo(10, 6);
        let mut exec = MockExecutor::new(1, cfg.mamba_shape.d_model);
        let r = simulate(&mut exec, &cfg, &RduConfig::hs_scan_mode()).unwrap();
        assert_eq!(r.tokens, 60);
        assert_eq!(r.sched.retired, 10);
        assert_eq!(r.cache.evictions, 0, "full budget: no eviction");
        assert!(r.sim_seconds > 0.0);
        assert!(r.mean_batch >= 1.0);
    }

    #[test]
    fn pooled_sim_matches_serial() {
        let cfg = SimConfig::demo(10, 6);
        let d_model = cfg.mamba_shape.d_model;
        let serial = {
            let mut exec = MockExecutor::new(1, d_model);
            simulate(&mut exec, &cfg, &RduConfig::hs_scan_mode()).unwrap()
        };
        let factory: ExecutorFactory =
            Box::new(move || Ok(Box::new(MockExecutor::new(1, d_model)) as Box<dyn Executor>));
        for threads in [1usize, 2, 4] {
            let pooled =
                simulate_pooled(&factory, &cfg, &RduConfig::hs_scan_mode(), threads).unwrap();
            assert_eq!(pooled.tokens, serial.tokens, "threads={threads}");
            assert_eq!(pooled.sched.retired, serial.sched.retired);
            assert_eq!(pooled.batches, serial.batches);
            // Full budget: no spills, so modeled time is bit-identical.
            assert_eq!(pooled.cache.evictions, 0);
            assert!(
                (pooled.sim_seconds - serial.sim_seconds).abs() == 0.0,
                "threads={threads}: {} vs {}",
                pooled.sim_seconds,
                serial.sim_seconds
            );
        }
    }

    #[test]
    fn pooled_sim_surfaces_factory_failure() {
        let cfg = SimConfig::demo(2, 2);
        let factory: ExecutorFactory = Box::new(|| Err(anyhow!("no executor for you")));
        let err = simulate_pooled(&factory, &cfg, &RduConfig::hs_scan_mode(), 2)
            .expect_err("factory failure must surface");
        assert!(format!("{err:#}").contains("executor"), "{err:#}");
    }

    #[test]
    fn sticky_executor_is_reused_within_a_sim_and_rebuilt_across_sims() {
        use std::sync::atomic::AtomicUsize;
        use std::sync::Arc;
        let builds = Arc::new(AtomicUsize::new(0));
        let b = Arc::clone(&builds);
        let factory: ExecutorFactory = Box::new(move || {
            b.fetch_add(1, Ordering::SeqCst);
            Ok(Box::new(MockExecutor::new(1, 8)) as Box<dyn Executor>)
        });
        // TLS is per-thread, so driving the helper directly on the test
        // thread is deterministic regardless of team width.
        let hits0 = crate::runtime::team::sticky_hit_counter().load(Ordering::Relaxed);
        let sim_a = NEXT_SIM_ID.fetch_add(1, Ordering::Relaxed);
        with_sticky_executor(sim_a, &factory, |_| ()).unwrap();
        with_sticky_executor(sim_a, &factory, |_| ()).unwrap();
        assert_eq!(builds.load(Ordering::SeqCst), 1, "second touch reuses the executor");
        let sim_b = NEXT_SIM_ID.fetch_add(1, Ordering::Relaxed);
        with_sticky_executor(sim_b, &factory, |_| ()).unwrap();
        assert_eq!(builds.load(Ordering::SeqCst), 2, "a new sim id rebuilds");
        let hits1 = crate::runtime::team::sticky_hit_counter().load(Ordering::Relaxed);
        assert!(hits1 >= hits0 + 1, "reuse counts team.sticky_hit ({hits0} -> {hits1})");
    }

    #[test]
    fn failed_factory_is_retried_on_the_next_touch() {
        use std::sync::atomic::AtomicUsize;
        use std::sync::Arc;
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        // First call fails, later calls succeed.
        let factory: ExecutorFactory = Box::new(move || {
            if c.fetch_add(1, Ordering::SeqCst) == 0 {
                Err(anyhow!("transient executor failure"))
            } else {
                Ok(Box::new(MockExecutor::new(1, 8)) as Box<dyn Executor>)
            }
        });
        let sim = NEXT_SIM_ID.fetch_add(1, Ordering::Relaxed);
        let err = with_sticky_executor(sim, &factory, |_| ()).expect_err("first touch fails");
        assert!(format!("{err:#}").contains("executor"), "{err:#}");
        with_sticky_executor(sim, &factory, |_| ()).expect("second touch rebuilds");
        assert_eq!(calls.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn tight_budget_spills_but_stays_correct() {
        let mut cfg = SimConfig::demo(12, 5);
        let full = {
            let mut exec = MockExecutor::new(1, cfg.mamba_shape.d_model);
            simulate(&mut exec, &cfg, &RduConfig::hs_scan_mode()).unwrap()
        };
        cfg.budget_bytes = cfg.footprint_bytes() / 4;
        let tight = {
            let mut exec = MockExecutor::new(1, cfg.mamba_shape.d_model);
            simulate(&mut exec, &cfg, &RduConfig::hs_scan_mode()).unwrap()
        };
        assert_eq!(tight.tokens, full.tokens, "eviction is transparent to completion");
        assert!(tight.cache.evictions > 0, "quarter budget must evict: {:?}", tight.cache);
        assert!(tight.cache.misses > 0);
        assert!(
            tight.sim_seconds > full.sim_seconds,
            "spill traffic costs modeled time: {} vs {}",
            tight.sim_seconds,
            full.sim_seconds
        );
    }
}
